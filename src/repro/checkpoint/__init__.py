from .store import (  # noqa: F401
    CheckpointManager,
    latest_step,
    list_steps,
    load_checkpoint,
    save_checkpoint,
)
