"""Fault-tolerant checkpointing: atomic commit, retention, async writer.

Layout::

    <dir>/step_000123/
        manifest.json          {"step": 123, "leaves": [...], "complete": true}
        arr_000.npy ...        one file per pytree leaf (sharded arrays are
                               gathered per-leaf; on a real multi-host pod
                               each host writes its shard files — the
                               manifest format already carries leaf paths so
                               that extension is mechanical)

Atomicity: write into ``step_X.tmp`` then ``os.replace`` to ``step_X``; a
crash mid-write leaves only a tmp dir that restore ignores and the next save
overwrites.  ``CheckpointManager`` adds retention (keep last N), an async
background writer thread (training never blocks on disk), and auto-resume.
"""
from __future__ import annotations

import json
import os
import queue
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

__all__ = [
    "save_checkpoint",
    "load_checkpoint",
    "latest_step",
    "list_steps",
    "CheckpointManager",
]


def _step_dir(root: str, step: int) -> str:
    return os.path.join(root, f"step_{step:09d}")


def save_checkpoint(root: str, step: int, tree: Any,
                    extra: Optional[Dict] = None) -> str:
    os.makedirs(root, exist_ok=True)
    final = _step_dir(root, step)
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    paths = []
    for i, leaf in enumerate(leaves):
        p = f"arr_{i:05d}.npy"
        np.save(os.path.join(tmp, p), np.asarray(leaf))
        paths.append(p)
    manifest = {
        "step": step,
        "leaves": paths,
        "treedef": str(treedef),
        "extra": extra or {},
        "complete": True,
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def list_steps(root: str) -> list:
    """All *complete* checkpoint steps under ``root``, ascending.

    Torn writes (missing/incomplete manifest, unparsable JSON) are
    skipped — they never surface as restorable steps.
    """
    if not os.path.isdir(root):
        return []
    steps = []
    for name in os.listdir(root):
        if name.startswith("step_") and not name.endswith(".tmp"):
            mf = os.path.join(root, name, "manifest.json")
            if os.path.exists(mf):
                try:
                    with open(mf) as f:
                        m = json.load(f)
                    if m.get("complete"):
                        steps.append(int(m["step"]))
                except Exception:
                    continue
    return sorted(steps)


def latest_step(root: str) -> Optional[int]:
    steps = list_steps(root)
    return steps[-1] if steps else None


def load_checkpoint(root: str, tree_like: Any,
                    step: Optional[int] = None) -> Tuple[Any, int, Dict]:
    """Restore into the structure (and shardings) of ``tree_like``."""
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no complete checkpoint under {root}")
    d = _step_dir(root, step)
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    leaves_like, treedef = jax.tree_util.tree_flatten(tree_like)
    assert len(leaves_like) == len(manifest["leaves"]), (
        "checkpoint/tree structure mismatch"
    )
    new_leaves = []
    for leaf, p in zip(leaves_like, manifest["leaves"]):
        arr = np.load(os.path.join(d, p))
        if hasattr(leaf, "sharding"):
            arr = jax.device_put(arr.astype(leaf.dtype), leaf.sharding)
        new_leaves.append(arr)
    return (
        jax.tree_util.tree_unflatten(treedef, new_leaves),
        step,
        manifest.get("extra", {}),
    )


class CheckpointManager:
    """Async writer + retention + auto-resume."""

    def __init__(self, root: str, keep: int = 3, async_write: bool = True):
        self.root = root
        self.keep = keep
        self.async_write = async_write
        self._q: "queue.Queue" = queue.Queue()
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        if async_write:
            self._thread = threading.Thread(target=self._worker, daemon=True)
            self._thread.start()

    def _worker(self):
        while True:
            item = self._q.get()
            if item is None:
                self._q.task_done()
                return
            step, host_tree, extra = item
            try:
                save_checkpoint(self.root, step, host_tree, extra)
                self._retain()
            except BaseException as e:  # surfaced on next save/wait
                self._error = e
            finally:
                self._q.task_done()

    def _retain(self):
        steps = sorted(
            int(n.split("_")[1])
            for n in os.listdir(self.root)
            if n.startswith("step_") and not n.endswith(".tmp")
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(_step_dir(self.root, s), ignore_errors=True)

    def save(self, step: int, tree: Any, extra: Optional[Dict] = None):
        if self._error:
            raise self._error
        # device->host copy happens here (synchronous, cheap vs disk IO)
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        if self.async_write:
            self._q.put((step, host_tree, extra))
        else:
            save_checkpoint(self.root, step, host_tree, extra)
            self._retain()

    def restore_or_none(self, tree_like: Any):
        step = latest_step(self.root)
        if step is None:
            return None
        return load_checkpoint(self.root, tree_like, step)

    def wait(self):
        """Drain pending writes (call before exit / in tests)."""
        if self._thread is not None:
            self._q.join()
        if self._error:
            raise self._error

    def close(self):
        if self._thread is not None:
            self.wait()
            self._q.put(None)
            self._thread.join(timeout=5)
            self._thread = None
