"""Edge churn as data: :class:`GraphDelta` + the PageRank churn helper.

A delta describes one batch of mutations to the diffusion matrix P
(out-adjacency: edge ``i -> j`` carries ``P[j, i]``):

* ``added``      — edges that do not exist yet, with their weights;
* ``removed``    — existing edges to drop;
* ``reweighted`` — existing edges whose weight changes.

The companion papers (arXiv:1202.3108 §"update equation",
arXiv:1301.3007) show the D-iteration fluid state survives matrix
drift: with ``F = B − (I−P)·H`` invariant along any schedule, changing
``P → P'`` re-seeds the residual as ``F' = F + (P'−P)·H`` — only the
*changed entries* of P contribute, so an incremental re-solve touches
O(|delta|) state instead of restarting cold.  :class:`GraphDelta` is
the unit that flows through :meth:`repro.graph.GraphStore.apply_delta`
and :meth:`repro.api.SolverSession.update_graph`.

For PageRank systems the link-level churn is *not* the P-level churn:
``P[j, i] = damping / out_deg(i)``, so adding or removing one link of
page ``i`` reweights every surviving out-edge of ``i``.
:func:`pagerank_edge_churn` expands link churn into the full P-level
:class:`GraphDelta` (added + removed + the implied reweighting).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

__all__ = ["GraphDelta", "edge_keys", "invert_delta",
           "pagerank_edge_churn", "rotation_churn"]


def edge_keys(src: np.ndarray, dst: np.ndarray) -> np.ndarray:
    """THE composite edge identity: ``src << 32 | dst`` (int64).

    Single definition shared by the delta layer, the CSR splice, and
    the loaders — node ids are int32-ranged, so the key is
    collision-free and order-preserving under (src, dst) lexsort.
    """
    return np.asarray(src, np.int64) << 32 | np.asarray(dst, np.int64)


def _as_edge_array(pairs, name: str) -> np.ndarray:
    arr = np.asarray(pairs, dtype=np.int64)
    if arr.size == 0:
        return np.zeros((0, 2), dtype=np.int64)
    if arr.ndim != 2 or arr.shape[1] != 2:
        raise ValueError(f"{name} must be [[src, dst], ...], got shape "
                         f"{arr.shape}")
    return arr


@dataclasses.dataclass(frozen=True)
class GraphDelta:
    """One batch of edge mutations on the diffusion matrix P.

    ``added``/``removed``/``reweighted`` are ``[*, 2]`` int64
    ``(src, dst)`` pairs; ``added_w``/``reweighted_w`` the matching
    weights.  Pairs must be unique within and across the three groups
    (an edge is added *or* removed *or* reweighted, once).
    """

    added: np.ndarray
    added_w: np.ndarray
    removed: np.ndarray
    reweighted: np.ndarray
    reweighted_w: np.ndarray

    def __post_init__(self):
        if self.added.shape[0] != self.added_w.shape[0]:
            raise ValueError("added / added_w length mismatch")
        if self.reweighted.shape[0] != self.reweighted_w.shape[0]:
            raise ValueError("reweighted / reweighted_w length mismatch")
        keys = np.concatenate([
            self._keys(self.added), self._keys(self.removed),
            self._keys(self.reweighted),
        ])
        if keys.size and np.unique(keys).size != keys.size:
            raise ValueError(
                "duplicate (src, dst) pairs across added/removed/reweighted"
            )

    @staticmethod
    def _keys(pairs: np.ndarray) -> np.ndarray:
        return edge_keys(pairs[:, 0], pairs[:, 1])

    @staticmethod
    def make(
        added_edges=None,
        removed_edges=None,
        reweighted=None,
    ) -> "GraphDelta":
        """Build a delta from loose inputs.

        ``added_edges``/``reweighted`` are ``(src, dst, w)`` triples
        (``[*, 3]`` array or tuple of three arrays); ``removed_edges``
        is ``(src, dst)`` pairs.
        """

        def split_weighted(x, name):
            if x is None:
                return (np.zeros((0, 2), np.int64),
                        np.zeros(0, np.float64))
            if isinstance(x, tuple):
                src, dst, w = x
                pairs = np.stack(
                    [np.asarray(src, np.int64), np.asarray(dst, np.int64)],
                    axis=1)
                return pairs, np.asarray(w, np.float64)
            arr = np.asarray(x)
            if arr.ndim != 2 or arr.shape[1] != 3:
                raise ValueError(f"{name} must be (src, dst, w) triples")
            return (arr[:, :2].astype(np.int64),
                    arr[:, 2].astype(np.float64))

        added, added_w = split_weighted(added_edges, "added_edges")
        rew, rew_w = split_weighted(reweighted, "reweighted")
        if removed_edges is None:
            removed = np.zeros((0, 2), np.int64)
        elif isinstance(removed_edges, tuple):
            src, dst = removed_edges
            removed = np.stack(
                [np.asarray(src, np.int64), np.asarray(dst, np.int64)],
                axis=1)
        else:
            removed = _as_edge_array(removed_edges, "removed_edges")
        return GraphDelta(added=added, added_w=added_w, removed=removed,
                          reweighted=rew, reweighted_w=rew_w)

    # ---- derived -----------------------------------------------------------
    @property
    def n_changes(self) -> int:
        return int(self.added.shape[0] + self.removed.shape[0]
                   + self.reweighted.shape[0])

    @property
    def is_empty(self) -> bool:
        return self.n_changes == 0

    def touched_edges(self) -> Tuple[np.ndarray, np.ndarray]:
        """(src, dst) arrays over every changed edge (all three groups)."""
        pairs = np.concatenate([self.added, self.removed, self.reweighted])
        return pairs[:, 0], pairs[:, 1]

    def touched_sources(self) -> np.ndarray:
        """Unique source nodes whose out-edge sets / weights change."""
        src, _ = self.touched_edges()
        return np.unique(src)

    def churn_per_node(self, n: int) -> np.ndarray:
        """[N] count of changed edges charged to each source node.

        This is the per-node magnitude the balance control plane
        consumes (``LoadSignal.from_graph_churn``): a PID whose nodes
        absorb the churn pays the view-patch + re-diffusion work.
        """
        src, _ = self.touched_edges()
        return np.bincount(src, minlength=n).astype(np.int64)


def invert_delta(store, delta: GraphDelta) -> GraphDelta:
    """The delta that undoes ``delta``, captured BEFORE it is applied.

    Must be called against the store state the delta would mutate: the
    inverse re-adds ``removed`` edges and restores ``reweighted`` edges
    at their *current* weights, which only exist pre-apply.  This is the
    rollback token :meth:`repro.api.SolverSession.update_graph` captures
    so a failure after :meth:`GraphStore.apply_delta` (view patch,
    driver rebuild, re-seed) can restore the store instead of leaving
    the session serving over half-mutated views.
    """
    csr = store.csr()
    src_e, dst_e, w_e = csr.edge_list()
    sorted_keys = edge_keys(src_e, dst_e)

    def old_weights(pairs: np.ndarray, group: str) -> np.ndarray:
        if pairs.shape[0] == 0:
            return np.zeros(0, np.float64)
        keys = GraphDelta._keys(pairs)
        pos = np.searchsorted(sorted_keys, keys)
        ok = (pos < sorted_keys.size) & (sorted_keys[
            np.minimum(pos, sorted_keys.size - 1)] == keys)
        if not ok.all():
            bad = pairs[~ok][0]
            raise ValueError(
                f"cannot invert: {group} edge ({bad[0]}, {bad[1]}) "
                f"does not exist in the store")
        return w_e[pos].astype(np.float64)

    return GraphDelta(
        added=delta.removed,
        added_w=old_weights(delta.removed, "removed"),
        removed=delta.added,
        reweighted=delta.reweighted,
        reweighted_w=old_weights(delta.reweighted, "reweighted"),
    )


def pagerank_edge_churn(
    store,
    added_links=None,
    removed_links=None,
    damping: Optional[float] = None,
) -> GraphDelta:
    """Expand *link-graph* churn into the P-level :class:`GraphDelta`.

    ``store`` holds the PageRank diffusion matrix
    ``P[j, i] = damping / out_deg(i)``.  Adding/removing links of page
    ``i`` changes its out-degree, hence the weight of every surviving
    out-edge of ``i`` — those become ``reweighted`` entries; the links
    themselves become ``added`` (at the new uniform weight) / ``removed``.

    ``damping`` defaults to the value already baked into the store's
    weights (``w · out_deg`` of any existing edge) — passing a value
    that disagrees with the matrix would silently mix dampings.
    """
    added = _as_edge_array(
        added_links if added_links is not None else [], "added_links")
    removed = _as_edge_array(
        removed_links if removed_links is not None else [], "removed_links")
    g = store.csr()
    out_deg = g.out_degree()
    if damping is None:
        lead = np.nonzero(out_deg > 0)[0]
        if lead.size == 0:
            raise ValueError(
                "cannot derive damping from an edgeless store; pass it")
        i0 = int(lead[0])
        damping = float(g.out_neighbors(i0)[1][0] * out_deg[i0])
    new_deg = out_deg.copy()
    np.add.at(new_deg, added[:, 0], 1)
    np.subtract.at(new_deg, removed[:, 0], 1)
    if (new_deg < 0).any():
        raise ValueError("removed_links exceed a node's out-degree")
    touched = np.unique(np.concatenate([added[:, 0], removed[:, 0]]))
    rem_keys = GraphDelta._keys(removed)
    rew_src, rew_dst, rew_w = [], [], []
    for i in touched:
        js, _ = g.out_neighbors(int(i))
        if js.size == 0:
            continue
        keys = edge_keys(np.full(js.size, i), js)
        survive = ~np.isin(keys, rem_keys)
        js = js[survive]
        if js.size == 0 or new_deg[i] == 0:
            continue
        rew_src.append(np.full(js.size, i, dtype=np.int64))
        rew_dst.append(js.astype(np.int64))
        rew_w.append(np.full(js.size, damping / new_deg[i]))
    if rew_src:
        rew = np.stack([np.concatenate(rew_src),
                        np.concatenate(rew_dst)], axis=1)
        rw = np.concatenate(rew_w)
    else:
        rew = np.zeros((0, 2), np.int64)
        rw = np.zeros(0, np.float64)
    if (new_deg[added[:, 0]] == 0).any():  # pragma: no cover - impossible
        raise ValueError("added link on a node with new out-degree 0")
    aw = damping / new_deg[added[:, 0]].astype(np.float64) \
        if added.size else np.zeros(0, np.float64)
    return GraphDelta(added=added, added_w=aw, removed=removed,
                      reweighted=rew, reweighted_w=rw)


def rotation_churn(
    store,
    n_rotations: int,
    seed: int = 0,
    rank: Optional[np.ndarray] = None,
    exclude_top: float = 0.0,
) -> GraphDelta:
    """Link-rotation churn: pages swap one outlink for a fresh target.

    The canonical evolving-web workload (and the delta-re-solve test
    scenario): ``n_rotations`` edge-sampled source pages each drop one
    existing outlink and gain one new uniform-random outlink at the
    same weight — out-degrees are preserved, so a PageRank system needs
    no column renormalization and the delta is exactly ``2·n_rotations``
    changed edges.

    ``rank``/``exclude_top`` optionally keep the top fraction of nodes
    (by ``rank``, e.g. a PageRank estimate) churn-free — mirroring real
    crawls, where established hubs are stable and link churn lives in
    the long tail.  Since a rotation at page ``i`` injects
    ``|ΔP_col(i)|·H_i ≈ 1.7/d_i · H_i`` of fluid and edge sampling
    picks ``i`` with probability ``d_i/L``, each page's expected
    contribution is ``∝ H_i`` — so excluding the top rank mass directly
    bounds the injected fluid ``|F'−F|``.
    """
    rng = np.random.default_rng(seed)
    csr = store.csr()
    src_e, dst_e, w_e = csr.edge_list()
    # canonical CSR => keys already sorted: membership via searchsorted
    # instead of boxing all L keys into a Python set (this runs on the
    # serving path, per graph-update request)
    sorted_keys = edge_keys(src_e, dst_e)
    fresh: set = set()  # keys added by THIS delta

    def is_edge(key: int) -> bool:
        i = int(np.searchsorted(sorted_keys, key))
        return (i < sorted_keys.size and sorted_keys[i] == key) \
            or key in fresh

    ok = np.ones(src_e.shape[0], dtype=bool)
    if exclude_top > 0.0:
        if rank is None:
            raise ValueError("exclude_top needs a rank array")
        hot = np.argsort(-rank)[: int(exclude_top * csr.n)]
        ok = ~np.isin(src_e, hot)
    cand = np.nonzero(ok)[0]
    take = rng.choice(cand, size=min(n_rotations, cand.size),
                      replace=False)
    removed, added, used = [], [], set()
    for e in take:
        s, d_old = int(src_e[e]), int(dst_e[e])
        if (s << 32) | d_old in used:
            continue
        for _ in range(64):  # rejection-sample a fresh destination
            d_new = int(rng.integers(0, csr.n))
            key = (s << 32) | d_new
            if d_new != s and not is_edge(key):
                removed.append((s, d_old))
                used.add((s << 32) | d_old)
                added.append((s, d_new, float(w_e[e])))
                fresh.add(key)
                break
    return GraphDelta.make(
        added_edges=np.array(added, dtype=np.float64).reshape(-1, 3),
        removed_edges=np.array(removed, dtype=np.int64).reshape(-1, 2),
    )
