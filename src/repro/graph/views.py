"""View builders + incremental patchers over the canonical CSR.

Every backend representation the solvers consume is derived here from
one canonical out-adjacency CSR (edges sorted by ``(src, dst)``,
deduplicated) and can be *patched* under a :class:`~repro.graph.delta.
GraphDelta` instead of rebuilt:

* **CSR splice** (:func:`splice_csr`) — remove/insert/reweight rows of
  the canonical arrays keeping the ``(src, dst)`` order, so the result
  is bit-identical to a from-scratch build over the mutated edge list.
* **BSR tile pool** (:class:`BsrTiles`) — the frontier kernel's operand;
  the patcher rewrites only *dirty tiles* (block keys containing a
  changed edge), drops tiles that empty out, inserts new ones in key
  order, and refreshes the block-row occupancy map.
* **Bucketed layout** (:func:`build_bucketed` / :func:`patch_bucketed`)
  — the engine's slotted layout; only buckets owning a changed source
  node are rewritten (edge capacity re-derived; a capacity change
  re-pads but still only dirty buckets are recomputed).
* **Engine layout** (:class:`EngineLayout`) — the graph-derived half of
  ``EngineArrays`` (everything but the RHS-dependent ``f0``), including
  the stable-id BSR tile pool of ``diffusion_backend="bsr"``; dirty
  rows follow dirty buckets.

Each patcher is bit-identical to its from-scratch builder by
construction — enforced by the tier-2 ``graph-update-parity`` CI job
(tests/test_graph_store.py).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from .delta import GraphDelta, edge_keys as _edge_keys

__all__ = [
    "BsrTiles",
    "EngineLayout",
    "build_canonical_csr",
    "splice_csr",
    "build_bsr",
    "patch_bsr",
    "build_bucketed",
    "patch_bucketed",
    "build_engine_layout",
    "patch_engine_layout",
]


# --------------------------------------------------------------------------- #
# canonical CSR
# --------------------------------------------------------------------------- #
def build_canonical_csr(
    src: np.ndarray, dst: np.ndarray, w: np.ndarray, n: int
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """(indptr, indices, weights) sorted by (src, dst).

    Parallel (src, dst) entries are merged by summing their weights —
    the same multigraph semantics as ``CSRGraph.to_dense`` — so any
    legacy multigraph CSR canonicalizes to an equivalent simple graph.
    """
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    w = np.asarray(w, dtype=np.float64)
    order = np.lexsort((dst, src))
    src, dst, w = src[order], dst[order], w[order]
    keys = _edge_keys(src, dst)
    if keys.size:
        first = np.ones(keys.size, dtype=bool)
        first[1:] = keys[1:] != keys[:-1]
        if not first.all():
            w = np.add.reduceat(w, np.nonzero(first)[0])
            src, dst = src[first], dst[first]
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.add.at(indptr, src + 1, 1)
    np.cumsum(indptr, out=indptr)
    return indptr, dst.astype(np.int32), w


def splice_csr(
    indptr: np.ndarray,
    indices: np.ndarray,
    weights: np.ndarray,
    n: int,
    delta: GraphDelta,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Apply ``delta`` to canonical CSR arrays; returns fresh arrays.

    Keeps the (src, dst) sort order, so the result is bit-identical to
    :func:`build_canonical_csr` over the mutated edge list.  Raises
    when an added edge already exists or a removed/reweighted one
    does not.
    """
    edge_src = np.repeat(np.arange(n, dtype=np.int64), np.diff(indptr))
    keys = _edge_keys(edge_src, indices.astype(np.int64))
    weights = weights.copy()

    def locate(pairs: np.ndarray, what: str) -> np.ndarray:
        pk = _edge_keys(pairs[:, 0], pairs[:, 1])
        pos = np.searchsorted(keys, pk)
        ok = (pos < keys.size) if keys.size else np.zeros(pk.size, bool)
        if keys.size:
            ok &= keys[np.minimum(pos, keys.size - 1)] == pk
        if not ok.all():
            bad = pairs[~ok][0]
            raise ValueError(
                f"{what} edge ({bad[0]}, {bad[1]}) does not exist")
        return pos

    if delta.reweighted.shape[0]:
        weights[locate(delta.reweighted, "reweighted")] = delta.reweighted_w
    keep = np.ones(keys.size, dtype=bool)
    if delta.removed.shape[0]:
        keep[locate(delta.removed, "removed")] = False
    kept_keys = keys[keep]
    kept_idx = indices[keep]
    kept_w = weights[keep]
    kept_src = edge_src[keep]
    if delta.added.shape[0]:
        if (delta.added >= n).any() or (delta.added < 0).any():
            raise ValueError("added edge endpoint out of range")
        aorder = np.lexsort((delta.added[:, 1], delta.added[:, 0]))
        apairs = delta.added[aorder]
        aw = delta.added_w[aorder]
        ak = _edge_keys(apairs[:, 0], apairs[:, 1])
        pos = np.searchsorted(keys, ak)
        exists = ((pos < keys.size)
                  & (keys[np.minimum(pos, keys.size - 1)] == ak)
                  if keys.size else np.zeros(ak.size, bool))
        if exists.any():
            bad = apairs[exists][0]
            raise ValueError(
                f"added edge ({bad[0]}, {bad[1]}) already exists "
                "(use reweighted)")
        ins = np.searchsorted(kept_keys, ak)
        new_idx = np.insert(kept_idx, ins, apairs[:, 1].astype(np.int32))
        new_w = np.insert(kept_w, ins, aw)
        new_src = np.insert(kept_src, ins, apairs[:, 0])
    else:
        new_idx, new_w, new_src = kept_idx, kept_w, kept_src
    new_indptr = np.zeros(n + 1, dtype=np.int64)
    np.add.at(new_indptr, new_src + 1, 1)
    np.cumsum(new_indptr, out=new_indptr)
    return new_indptr, new_idx, new_w


# --------------------------------------------------------------------------- #
# BSR tile view (frontier kernel operand)
# --------------------------------------------------------------------------- #
@dataclasses.dataclass
class BsrTiles:
    """Host-side BSR of P: sorted block keys + the occupancy map.

    ``blocks[t]`` is the dense ``[bs, bs]`` tile of block row
    ``block_row[t]`` / block column ``block_col[t]`` with tiles sorted
    by ``block_row * nb + block_col`` (the :func:`repro.kernels.
    diffusion.ref.csr_to_bsr` layout).  ``row_occupied`` is the
    frontier path's block-row occupancy map (rows owning no tile skip
    the kernel's output epilogue).
    """

    blocks: np.ndarray  # [n_blocks, bs, bs] float32
    block_row: np.ndarray  # [n_blocks] int32
    block_col: np.ndarray  # [n_blocks] int32
    n_row_blocks: int
    bs: int

    @property
    def n_blocks(self) -> int:
        return int(self.blocks.shape[0])

    @property
    def row_occupied(self) -> np.ndarray:
        occ = np.zeros(self.n_row_blocks, dtype=bool)
        occ[self.block_row] = True
        return occ

    def keys(self) -> np.ndarray:
        return (self.block_row.astype(np.int64) * self.n_row_blocks
                + self.block_col.astype(np.int64))

    def to_device(self):
        """Wrap as the kernel-facing :class:`BsrMatrix` (device arrays)."""
        from repro.kernels.diffusion import BsrMatrix

        return BsrMatrix(self.blocks, self.block_row, self.block_col,
                         self.n_row_blocks, self.bs)


def build_bsr(indptr, indices, weights, n: int, bs: int) -> BsrTiles:
    from repro.kernels.diffusion.ref import csr_to_bsr

    blocks, br, bc, nrb = csr_to_bsr(
        np.asarray(indptr), np.asarray(indices), np.asarray(weights), n, bs)
    return BsrTiles(blocks=blocks, block_row=br, block_col=bc,
                    n_row_blocks=nrb, bs=bs)


def _bsr_tile_from_csr(indptr, indices, weights, n, bs, br, bc):
    """Rebuild one [bs, bs] tile (block row br, block col bc) from CSR."""
    lo_node = bc * bs
    hi_node = min((bc + 1) * bs, n)
    lo, hi = indptr[lo_node], indptr[hi_node]
    dst = indices[lo:hi].astype(np.int64)
    m = (dst // bs) == br
    tile = np.zeros((bs, bs), dtype=np.float32)
    if m.any():
        src = np.repeat(
            np.arange(lo_node, hi_node, dtype=np.int64),
            np.diff(indptr[lo_node:hi_node + 1]))
        # identical accumulate-into-f32 op as csr_to_bsr (bit parity)
        tile[dst[m] % bs, src[m] % bs] += weights[lo:hi][m]
    return tile


def patch_bsr(view: BsrTiles, indptr, indices, weights, n: int,
              delta: GraphDelta) -> BsrTiles:
    """Rewrite only the dirty tiles of ``view`` for the PATCHED csr.

    Dirty tiles = block keys containing any changed edge.  Tiles that
    become all-zero are dropped (matching a from-scratch build); new
    nonzero tiles are inserted in key order.
    """
    bs, nb = view.bs, view.n_row_blocks
    src, dst = delta.touched_edges()
    if src.size == 0:
        return view
    dirty = np.unique((dst // bs) * nb + (src // bs))
    old_keys = view.keys()
    clean = ~np.isin(old_keys, dirty)
    # a view built over ZERO edges is one all-zero placeholder tile
    # (csr_to_bsr's degenerate form), not a real tile — never carry it
    # into a merge.  Detected exactly via the pre-patch edge count (a
    # genuine zero-weight edge's tile is indistinguishable by bytes).
    n_pre = int(indptr[-1]) - delta.added.shape[0] + delta.removed.shape[0]
    if n_pre == 0:
        clean[:] = False
    new_blocks = [view.blocks[clean]]
    new_keys = [old_keys[clean]]
    for key in dirty:
        br, bc = int(key // nb), int(key % nb)
        tile = _bsr_tile_from_csr(indptr, indices, weights, n, bs, br, bc)
        if np.any(tile):
            new_blocks.append(tile[None])
            new_keys.append(np.array([key], dtype=np.int64))
    blocks = np.concatenate(new_blocks, axis=0)
    keys = np.concatenate(new_keys)
    order = np.argsort(keys)
    blocks, keys = blocks[order], keys[order]
    if keys.size == 0:  # degenerate all-zero matrix, csr_to_bsr's form
        blocks = np.zeros((1, bs, bs), dtype=np.float32)
        keys = np.zeros(1, dtype=np.int64)
    return BsrTiles(
        blocks=blocks,
        block_row=(keys // nb).astype(np.int32),
        block_col=(keys % nb).astype(np.int32),
        n_row_blocks=nb, bs=bs,
    )


# --------------------------------------------------------------------------- #
# bucketed view (engine slotted layout)
# --------------------------------------------------------------------------- #
def _fill_bucket_row(bg, b: int, indptr, indices, weights) -> None:
    """Rewrite bucket ``b``'s edge buffer + out_deg from (patched) CSR."""
    bg.src_slot[b] = 0
    bg.dst[b] = 0
    bg.wgt[b] = 0.0
    cursor = 0
    for s in range(bg.bucket_size):
        node = bg.node_of_slot[b, s]
        if node < 0:
            bg.out_deg[b, s] = 0
            continue
        lo, hi = indptr[node], indptr[node + 1]
        m = int(hi - lo)
        bg.out_deg[b, s] = m
        if m == 0:
            continue
        bg.src_slot[b, cursor:cursor + m] = s
        bg.dst[b, cursor:cursor + m] = bg.slot_of_node[indices[lo:hi]]
        bg.wgt[b, cursor:cursor + m] = weights[lo:hi]
        cursor += m


def build_bucketed(csr_graph, n_buckets: int,
                   order: Optional[np.ndarray] = None):
    """The historical :func:`repro.core.graph.bucketize`, housed here."""
    from repro.core.graph import BucketedGraph

    g = csr_graph
    if order is None:
        order = np.arange(g.n, dtype=np.int64)
    bucket_size = -(-g.n // n_buckets)  # ceil
    n_slots = n_buckets * bucket_size

    node_of_slot = np.full(n_slots, -1, dtype=np.int32)
    node_of_slot[: g.n] = order
    node_of_slot = node_of_slot.reshape(n_buckets, bucket_size)

    slot_of_node = np.empty(g.n, dtype=np.int32)
    slot_of_node[order] = np.arange(g.n, dtype=np.int32)

    out_deg_per_node = g.out_degree()
    out_deg = np.zeros((n_buckets, bucket_size), dtype=np.int32)
    flat_nodes = node_of_slot.reshape(-1)
    valid = flat_nodes >= 0
    out_deg.reshape(-1)[valid] = out_deg_per_node[flat_nodes[valid]]

    per_bucket_edges = out_deg.sum(axis=1)
    edge_cap = max(1, int(per_bucket_edges.max()))
    bg = BucketedGraph(
        node_of_slot=node_of_slot,
        slot_of_node=slot_of_node,
        src_slot=np.zeros((n_buckets, edge_cap), dtype=np.int32),
        dst=np.zeros((n_buckets, edge_cap), dtype=np.int32),
        wgt=np.zeros((n_buckets, edge_cap), dtype=np.float32),
        out_deg=out_deg,
        n=g.n,
        n_edges=g.n_edges,
    )
    for b in range(n_buckets):
        _fill_bucket_row(bg, b, g.indptr, g.indices, g.weights)
    return bg


def patch_bucketed(bg, indptr, indices, weights, n_edges: int,
                   delta: GraphDelta):
    """Rewrite only the buckets owning a changed source node.

    Edge capacity is re-derived from the patched out-degrees; if it
    changes, buffers are re-padded (clean buckets copied, dirty ones
    rebuilt) — the result is always bit-identical to
    :func:`build_bucketed` on the patched graph.
    """
    changed = delta.touched_sources()
    if changed.size == 0:
        return bg
    s = bg.bucket_size
    dirty_buckets = np.unique(bg.slot_of_node[changed] // s)
    # patched out-degrees for changed nodes
    new_deg = (indptr[changed + 1] - indptr[changed]).astype(np.int32)
    flat = bg.out_deg.reshape(-1)
    flat[bg.slot_of_node[changed]] = new_deg
    per_bucket = bg.out_deg.sum(axis=1)
    new_cap = max(1, int(per_bucket.max()))
    if new_cap != bg.edge_cap:
        keep = min(new_cap, bg.edge_cap)
        for name in ("src_slot", "dst", "wgt"):
            old = getattr(bg, name)
            fresh = np.zeros((bg.n_buckets, new_cap), dtype=old.dtype)
            fresh[:, :keep] = old[:, :keep]
            setattr(bg, name, fresh)
    for b in dirty_buckets:
        _fill_bucket_row(bg, int(b), indptr, indices, weights)
    bg.n_edges = n_edges
    return bg


# --------------------------------------------------------------------------- #
# engine layout view (EngineArrays minus the RHS-dependent f0)
# --------------------------------------------------------------------------- #
@dataclasses.dataclass
class EngineLayout:
    """Graph-derived half of ``EngineArrays`` (DESIGN.md §3/§7).

    Rows are *initial* bucket positions (``pos_of_bucket`` maps stable
    bucket id -> home row); ``tiles``/``tile_dst`` is the stable-id BSR
    tile pool of the ``bsr`` diffusion backend.  ``b_of_row`` maps each
    real row back to its stable bucket id for the patcher.
    """

    w: np.ndarray  # [R, S] float64 selection weights (0 = inert slot)
    src_slot: np.ndarray  # [R, E] int32
    dst_bucket: np.ndarray  # [R, E] int32 stable bucket id
    dst_slot: np.ndarray  # [R, E] int32
    wgt: np.ndarray  # [R, E] float64 (0 = padding edge)
    pos_of_bucket: np.ndarray  # [R] int32
    node_of_slot: np.ndarray  # [R, S] int32
    n: int
    n_edges: int
    k: int
    buckets_per_dev: int
    headroom: int
    tiles: Optional[np.ndarray] = None  # [R, T, S, S] compute dtype
    tile_dst: Optional[np.ndarray] = None  # [R, T] int32
    slot_out_deg: Optional[np.ndarray] = None  # [R, S] int32
    t_counts: Optional[np.ndarray] = None  # [R] int32 distinct dst buckets
    # per row — cached so a patch re-derives the tile capacity T from
    # dirty rows only instead of re-scanning the whole pool

    @property
    def n_rows(self) -> int:
        return int(self.w.shape[0])

    @property
    def bucket_size(self) -> int:
        return int(self.w.shape[1])

    @property
    def n_real(self) -> int:
        return self.k * (self.buckets_per_dev - self.headroom)

    def row_of_bucket(self, bid: int) -> int:
        return int(self.pos_of_bucket[bid])


def _retile_rows(layout: EngineLayout, rows: np.ndarray) -> None:
    """Rebuild ``tiles``/``tile_dst`` for ``rows`` in place (zero first).

    Uses the exact accumulate of
    :func:`repro.core.distributed._tile_engine_edges` for bit parity.
    """
    s = layout.bucket_size
    for row in rows:
        layout.tiles[row] = 0.0
        layout.tile_dst[row] = 0
        mask = layout.wgt[row] != 0
        db = layout.dst_bucket[row][mask]
        ds = layout.dst_slot[row][mask]
        ss = layout.src_slot[row][mask]
        wv = layout.wgt[row][mask]
        uniq = np.unique(db)
        layout.tile_dst[row, : uniq.shape[0]] = uniq
        t_of_edge = np.searchsorted(uniq, db)
        np.add.at(layout.tiles, (row, t_of_edge, ds, ss), wv)


def build_engine_layout(
    store,
    k: int,
    buckets_per_dev: int,
    headroom: int,
    tiled: bool,
    dtype: np.dtype,
    order: Optional[np.ndarray] = None,
) -> EngineLayout:
    """Bucketize the store's graph into the engine's fixed-shape layout.

    Real buckets fill ``buckets_per_dev - headroom`` rows per device;
    the rest are inert landing rows for dynamic bucket moves.  Derives
    from the store's bucketed view (shared substrate), so a later
    ``apply_delta`` patches both coherently.
    """
    from repro.core.diteration import default_weights

    real_per_dev = buckets_per_dev - headroom
    assert real_per_dev >= 1, "headroom must leave >=1 real bucket per device"
    n_real = k * real_per_dev
    bg = store.bucketed(n_real, order=order)
    g = store.csr()
    s = bg.bucket_size
    e = bg.edge_cap
    r = k * buckets_per_dev

    layout = EngineLayout(
        w=np.zeros((r, s), dtype=np.float64),
        src_slot=np.zeros((r, e), dtype=np.int32),
        dst_bucket=np.zeros((r, e), dtype=np.int32),
        dst_slot=np.zeros((r, e), dtype=np.int32),
        wgt=np.zeros((r, e), dtype=np.float64),
        pos_of_bucket=np.zeros(r, dtype=np.int32),
        node_of_slot=np.full((r, s), -1, dtype=np.int32),
        n=g.n,
        n_edges=g.n_edges,
        k=k,
        buckets_per_dev=buckets_per_dev,
        headroom=headroom,
    )
    wnode = default_weights(g)
    for d in range(k):
        for j in range(real_per_dev):
            bid = d * real_per_dev + j  # stable bucket id
            row = d * buckets_per_dev + j  # home row
            layout.pos_of_bucket[bid] = row
            nos = bg.node_of_slot[bid]
            layout.node_of_slot[row] = nos
            valid = nos >= 0
            layout.w[row, valid] = wnode[nos[valid]]
            layout.src_slot[row] = bg.src_slot[bid]
            layout.dst_bucket[row] = bg.dst[bid] // s  # stable id
            layout.dst_slot[row] = bg.dst[bid] % s
            layout.wgt[row] = bg.wgt[bid]
    inert_rows = [
        d * buckets_per_dev + j
        for d in range(k)
        for j in range(real_per_dev, buckets_per_dev)
    ]
    for bid, row in zip(range(n_real, r), inert_rows):
        layout.pos_of_bucket[bid] = row
    if tiled:
        from repro.core.distributed import _tile_engine_edges

        layout.tiles, layout.tile_dst = _tile_engine_edges(
            layout.src_slot, layout.dst_bucket, layout.dst_slot,
            layout.wgt, s, np.dtype(dtype),
        )
        layout.t_counts = np.array(
            [np.unique(layout.dst_bucket[row][layout.wgt[row] != 0]).size
             for row in range(r)], dtype=np.int32)
        layout.slot_out_deg = np.zeros((r, s), dtype=np.int32)
        rows_e = np.broadcast_to(
            np.arange(r)[:, None], layout.src_slot.shape)
        real = layout.wgt != 0
        np.add.at(layout.slot_out_deg,
                  (rows_e[real], layout.src_slot[real]), 1)
    return layout


def patch_engine_layout(layout: EngineLayout, store, delta: GraphDelta,
                        order: Optional[np.ndarray] = None) -> EngineLayout:
    """Refresh dirty rows of ``layout`` from the store's PATCHED views.

    Dirty rows = home rows of buckets owning a changed source node.
    Selection weights (1/out-degree) refresh for those rows too; the
    tile pool is retiled per dirty row unless its capacity ``T`` (max
    distinct destination buckets of any row) changes, in which case the
    whole pool is rebuilt (shapes are static under shard_map).
    ``order`` must be the node order the layout was BUILT with (the
    store's cache remembers it) — its bucketed view carries the
    matching slot assignment.
    """
    changed = delta.touched_sources()
    if changed.size == 0:
        return layout
    from repro.core.diteration import default_weights

    bg = store.bucketed(layout.n_real, order=order)
    g = store.csr()
    s = layout.bucket_size
    dirty_buckets = np.unique(bg.slot_of_node[changed] // s)
    dirty_rows = np.array(
        [layout.row_of_bucket(int(b)) for b in dirty_buckets])
    if bg.edge_cap != layout.wgt.shape[1]:
        e = bg.edge_cap
        keep = min(e, layout.wgt.shape[1])
        for name in ("src_slot", "dst_bucket", "dst_slot", "wgt"):
            old = getattr(layout, name)
            fresh = np.zeros((layout.n_rows, e), dtype=old.dtype)
            fresh[:, :keep] = old[:, :keep]
            setattr(layout, name, fresh)
    wnode = default_weights(g)
    for bid, row in zip(dirty_buckets, dirty_rows):
        nos = layout.node_of_slot[row]
        valid = nos >= 0
        layout.w[row] = 0.0
        layout.w[row, valid] = wnode[nos[valid]]
        layout.src_slot[row] = bg.src_slot[bid]
        layout.dst_bucket[row] = bg.dst[bid] // s
        layout.dst_slot[row] = bg.dst[bid] % s
        layout.wgt[row] = bg.wgt[bid]
    layout.n_edges = g.n_edges
    if layout.tiles is not None:
        dtype = layout.tiles.dtype
        # T capacity = max distinct destination buckets over rows;
        # only dirty rows can have changed their count
        for row in dirty_rows:
            mask = layout.wgt[row] != 0
            layout.t_counts[row] = np.unique(
                layout.dst_bucket[row][mask]).size
        t_needed = max(1, int(layout.t_counts.max()))
        if t_needed != layout.tiles.shape[1]:
            from repro.core.distributed import _tile_engine_edges

            layout.tiles, layout.tile_dst = _tile_engine_edges(
                layout.src_slot, layout.dst_bucket, layout.dst_slot,
                layout.wgt, s, np.dtype(dtype))
        else:
            _retile_rows(layout, dirty_rows)
        for row in dirty_rows:
            layout.slot_out_deg[row] = 0
            mask = layout.wgt[row] != 0
            np.add.at(layout.slot_out_deg[row], layout.src_slot[row][mask], 1)
    return layout
