"""One mutable, partition-aware sparse substrate behind every backend.

:class:`GraphStore` owns the canonical out-adjacency CSR and derives
each backend's representation as a cached view (CSR, frontier BSR tile
pool + occupancy map, bucketed/slotted layout, engine layout with
stable-id tiles); :class:`GraphDelta` describes edge churn and
:meth:`GraphStore.apply_delta` patches every materialized view
incrementally (dirty tiles / buckets / rows only).  See DESIGN.md §7.
"""
from .delta import (GraphDelta, invert_delta, pagerank_edge_churn,
                    rotation_churn)
from .store import GraphStore
from .views import BsrTiles, EngineLayout

__all__ = [
    "BsrTiles",
    "EngineLayout",
    "GraphDelta",
    "GraphStore",
    "invert_delta",
    "pagerank_edge_churn",
    "rotation_churn",
]
