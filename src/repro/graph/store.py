""":class:`GraphStore` — the one mutable, partition-aware sparse substrate.

Before this module the graph structure was baked four separate times
(``CSRGraph``, ``BucketedGraph``, the engine's pre-tiled ``[R, T, S, S]``
pool, the frontier path's own BSR build), so no backend could react to
a graph that *changes*.  The store owns the canonical out-adjacency CSR
(edges sorted by ``(src, dst)``, deduplicated) and derives every
backend representation as a cached **view**:

====================  ====================================================
``csr()``             out-adjacency :class:`repro.core.graph.CSRGraph`
``bsr(bs)``           frontier BSR tile pool + block-row occupancy map
``bucketed(B)``       engine slotted layout (:class:`BucketedGraph`)
``engine_layout(..)`` graph half of ``EngineArrays`` incl. stable-id tiles
====================  ====================================================

:meth:`apply_delta` mutates the canonical CSR via an order-preserving
splice and **incrementally patches every materialized view** — dirty
BSR tiles only, dirty buckets only, dirty engine rows only — instead of
rebuilding, then bumps ``version``.  Patched views are bit-identical to
a from-scratch rebuild (tier-2 ``graph-update-parity`` CI job).

The fluid state survives the mutation too: with ``F = B − (I−P)·H``
invariant, ``P → P'`` re-seeds ``F' = F + (P'−P)·H`` (arXiv:1202.3108)
— :meth:`repro.api.SolverSession.update_graph` is the serving-path
consumer.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np

from .delta import GraphDelta
from . import views as _views

__all__ = ["GraphStore"]


def _order_token(order: Optional[np.ndarray]):
    # exact bytes, not hash(bytes): a cache-key collision would patch a
    # view built for a different node order — silently wrong solutions
    if order is None:
        return None
    return np.asarray(order).tobytes()


class GraphStore:
    """Canonical sparse matrix + cached, delta-patchable backend views."""

    def __init__(self, indptr: np.ndarray, indices: np.ndarray,
                 weights: np.ndarray, n: int):
        self._indptr = np.asarray(indptr, dtype=np.int64)
        self._indices = np.asarray(indices, dtype=np.int32)
        self._weights = np.asarray(weights, dtype=np.float64)
        self.n = int(n)
        self.version = 0
        # view cache: key -> (view object, params); orders kept for patching
        self._views: Dict[tuple, tuple] = {}
        self._csr = None

    # ------------------------------------------------------------------ #
    # constructors
    # ------------------------------------------------------------------ #
    @staticmethod
    def from_edges(src, dst, w, n: int) -> "GraphStore":
        indptr, indices, weights = _views.build_canonical_csr(
            np.asarray(src), np.asarray(dst), np.asarray(w), n)
        return GraphStore(indptr, indices, weights, n)

    @staticmethod
    def from_csr(g) -> "GraphStore":
        """Wrap a :class:`CSRGraph`, normalizing row order to canonical."""
        src = np.repeat(np.arange(g.n, dtype=np.int64), np.diff(g.indptr))
        return GraphStore.from_edges(src, g.indices, g.weights, g.n)

    @staticmethod
    def from_edge_file(path: str, n: Optional[int] = None,
                       weighted: bool = False,
                       comments: str = "#") -> "GraphStore":
        """Load a SNAP-style edge-list text file (``src dst`` per line).

        Lines starting with ``comments`` are skipped; with
        ``weighted=True`` a third column supplies edge weights
        (default 1.0).  Self-loops are dropped and duplicate edges
        deduplicated (first weight wins), matching the synthetic
        generators' conventions.  ``n`` defaults to ``max(id) + 1``.
        """
        data = np.loadtxt(path, comments=comments, ndmin=2,
                          dtype=np.float64)
        if data.size == 0:
            raise ValueError(f"edge file {path!r} holds no edges")
        if data.shape[1] < (3 if weighted else 2):
            raise ValueError(
                f"edge file {path!r} needs {'3' if weighted else '2'} "
                f"columns, found {data.shape[1]}")
        src = data[:, 0].astype(np.int64)
        dst = data[:, 1].astype(np.int64)
        w = (data[:, 2].astype(np.float64) if weighted
             else np.ones(src.shape[0]))
        if (src < 0).any() or (dst < 0).any():
            raise ValueError(f"edge file {path!r} holds negative node ids")
        n_eff = int(max(src.max(), dst.max())) + 1 if n is None else int(n)
        if n is not None and ((src >= n).any() or (dst >= n).any()):
            raise ValueError(f"edge file {path!r} holds ids >= n={n}")
        keep = src != dst
        src, dst, w = src[keep], dst[keep], w[keep]
        from .delta import edge_keys

        _, uniq = np.unique(edge_keys(src, dst), return_index=True)
        return GraphStore.from_edges(src[uniq], dst[uniq], w[uniq], n_eff)

    # ------------------------------------------------------------------ #
    # canonical accessors
    # ------------------------------------------------------------------ #
    @property
    def n_edges(self) -> int:
        return int(self._indices.shape[0])

    def csr(self):
        """The canonical view as a :class:`CSRGraph` (shares arrays)."""
        from repro.core.graph import CSRGraph

        if self._csr is None:
            self._csr = CSRGraph(indptr=self._indptr, indices=self._indices,
                                 weights=self._weights, n=self.n)
        return self._csr

    def out_degree(self) -> np.ndarray:
        return np.diff(self._indptr).astype(np.int64)

    def dangling_mask(self) -> np.ndarray:
        return np.diff(self._indptr) == 0

    # ------------------------------------------------------------------ #
    # derived views (cached; patched in place by apply_delta)
    # ------------------------------------------------------------------ #
    def bsr(self, bs: int = 128) -> "_views.BsrTiles":
        key = ("bsr", int(bs))
        hit = self._views.get(key)
        if hit is None:
            view = _views.build_bsr(self._indptr, self._indices,
                                    self._weights, self.n, int(bs))
            self._views[key] = (view, None)
            return view
        return hit[0]

    def bucketed(self, n_buckets: int, order: Optional[np.ndarray] = None):
        key = ("bucket", int(n_buckets), _order_token(order))
        hit = self._views.get(key)
        if hit is None:
            view = _views.build_bucketed(self.csr(), int(n_buckets),
                                         order=order)
            self._views[key] = (view, order)
            return view
        return hit[0]

    def engine_layout(
        self,
        k: int,
        buckets_per_dev: int,
        headroom: int,
        tiled: bool = False,
        dtype=np.float32,
        order: Optional[np.ndarray] = None,
    ) -> "_views.EngineLayout":
        key = ("engine", int(k), int(buckets_per_dev), int(headroom),
               bool(tiled), np.dtype(dtype).str, _order_token(order))
        hit = self._views.get(key)
        if hit is None:
            view = _views.build_engine_layout(
                self, int(k), int(buckets_per_dev), int(headroom),
                bool(tiled), np.dtype(dtype), order=order)
            self._views[key] = (view, order)
            return view
        return hit[0]

    def materialized_views(self) -> Tuple[tuple, ...]:
        """Cache keys of the views currently materialized (testing aid)."""
        return tuple(sorted(self._views, key=repr))

    # ------------------------------------------------------------------ #
    # the delta layer
    # ------------------------------------------------------------------ #
    def apply_delta(self, delta: GraphDelta) -> "GraphStore":
        """Mutate the canonical CSR and patch every materialized view.

        CSR splice first (order-preserving, so the arrays equal a
        from-scratch canonical build over the mutated edge list), then
        each cached view is patched touching only its dirty tiles /
        buckets / rows: bucketed views before engine layouts (an engine
        layout derives from its bucketed view).  Bumps ``version``.
        Returns ``self`` for chaining.

        Views are patched **in place**: any consumer that captured a
        view before the delta now sees the patched arrays.  Problems
        pin the version they snapshot (``Problem.store_version``) and
        ``SolverSession`` refuses to run over a stale snapshot, so
        callers must re-snapshot via ``problem.with_graph(store)``
        (``SolverSession.update_graph`` does both steps atomically).
        """
        if not isinstance(delta, GraphDelta):
            raise TypeError(f"apply_delta wants a GraphDelta, got "
                            f"{type(delta).__name__}")
        if delta.is_empty:
            return self
        old_csr = (self._indptr, self._indices, self._weights, self._csr)
        self._indptr, self._indices, self._weights = _views.splice_csr(
            self._indptr, self._indices, self._weights, self.n, delta)
        self._csr = None  # old CSRGraph wrappers keep the old arrays
        try:
            # bucketed views first: engine layouts read them while patching
            for kind in ("bucket", "bsr", "engine"):
                for key, (view, order) in list(self._views.items()):
                    if key[0] != kind:
                        continue
                    if kind == "bucket":
                        patched = _views.patch_bucketed(
                            view, self._indptr, self._indices,
                            self._weights, self.n_edges, delta)
                    elif kind == "bsr":
                        patched = _views.patch_bsr(
                            view, self._indptr, self._indices,
                            self._weights, self.n, delta)
                    else:
                        patched = _views.patch_engine_layout(
                            view, self, delta, order=order)
                    self._views[key] = (patched, order)
        except Exception:
            # transactional contract: a failed view patch must not leave
            # the store half-mutated at an unbumped version (a session's
            # staleness guard would pass over corrupt views).  The CSR
            # rolls back to the pre-splice arrays; the view cache is
            # dropped wholesale because in-place patching may have
            # partially mutated a view object — holders of captured view
            # references must rebuild from the store (update_graph's
            # rollback path rebuilds its driver, which does exactly that).
            (self._indptr, self._indices,
             self._weights, self._csr) = old_csr
            self._views.clear()
            raise
        self.version += 1
        return self
