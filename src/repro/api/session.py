"""Stateful solving: :class:`SolverSession` + the resumable drivers.

The paper's central object is the fluid pair ``(H, F)`` — the
accumulated history and the residual fluid.  The asynchronous-scheme
companion (arXiv:1202.6168) stresses that this *state* is what travels
between machines; here it is what travels between *solves*:

* ``run(until=...)`` — stream :class:`RoundReport`\\ s while draining F
  (the serving loop's progress feed).
* ``warm_start(b_new)`` — keep H, re-seed ``F = B' − (I−P)·H`` (the
  §2.2 residual identity ``X_exact − H = (I−P)^{-1} F`` applied to the
  new RHS).  A nearby B' leaves |F| tiny, so re-solving costs a small
  fraction of a cold solve — measured in edge-push ops, tested in
  tests/test_api.py.
* ``solve_batch(B)`` — multi-RHS personalized PageRank via a vmapped
  frontier loop (per-column thresholds and convergence masks) over the
  shared edge list.
* ``update_graph(delta)`` — keep (H, F), mutate P: the GraphStore
  patches its views incrementally and the fluid re-seeds via
  ``F' = F + (P'−P)·H`` (arXiv:1202.3108), so a churned graph
  re-solves warm rather than cold (DESIGN.md §7).

Drivers adapt one warm-startable backend each behind a tiny protocol
(``seed`` / ``advance`` / ``x`` / ``residual`` / ``ops`` / ``rounds``);
:mod:`repro.api.backends` reuses them for the one-shot ``solve()``
adapters so the streaming and batch paths are the *same* code the
registry runs.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Iterator, List, Optional, Tuple

import numpy as np

from repro.kernels.tune import resolved_config

from .options import SolverOptions
from .problem import Problem
from .report import RoundReport, SolveReport

__all__ = ["SolverSession"]


# --------------------------------------------------------------------------- #
# the batched multi-RHS kernel (shared by solve_batch and repro.serving)
# --------------------------------------------------------------------------- #
def _bucket_width(c: int, floor: int = 1) -> int:
    """Smallest power of two >= max(c, floor) — the XLA trace bucket.

    Batched solves pad their lane axis to this width so a drifting
    request count replays a compiled trace instead of re-tracing (the
    PR-6 kernel_bench pow2 trick applied to the lane axis)."""
    cp = max(int(floor), 1)
    while cp < c:
        cp *= 2
    return cp


def _edge_device_arrays(problem: Problem):
    """``(src, dst, wgt, w, dang)`` device arrays of the *damped* matrix
    ``problem.p`` — the one edge-list upload every batch path shares."""
    import jax.numpy as jnp

    p = problem.p
    src, dst, wgt = p.edge_list()
    return (jnp.asarray(src, dtype=jnp.int32),
            jnp.asarray(dst, dtype=jnp.int32),
            jnp.asarray(wgt),
            jnp.asarray(problem.node_weights()),
            jnp.asarray(p.dangling_mask()))


_BATCH_FNS: dict = {}


def _batch_fns() -> dict:
    """Process-wide jitted batch kernels, built once.

    The pre-PR-8 ``solve_batch`` closed over the edge arrays and called
    ``lax.while_loop`` through a *fresh* closure per call, so every
    invocation — even at an identical batch width — re-traced and
    re-compiled the loop.  These module-level jitted functions take the
    edge arrays as arguments instead: the jit cache is keyed on shapes
    alone, so a given ``[C_pad, N]`` bucket compiles exactly once per
    process and every later call at that bucket replays the trace.

    All lane-axis state is ``[C, N]`` (lane-major); per-lane reductions
    run over ``axis=1`` and lanes are fully independent — a zero-fluid
    lane selects nothing, pushes nothing, and leaves every other lane's
    arithmetic untouched, which is what makes pow2 zero-padding
    *bitwise* invisible to the real lanes (tested in
    tests/test_serving.py).

    ``solve`` runs to convergence; ``tick`` is the continuous-batching
    micro-step (bounded rounds, resumable); ``warm`` / ``place`` /
    ``clear`` are the lane-lifecycle helpers ``repro.serving`` uses to
    swap converged lanes for queued requests without re-tracing.
    """
    if _BATCH_FNS:
        return _BATCH_FNS
    import jax
    import jax.numpy as jnp

    def _round(f, h, t, ops, lane_rounds, tol_cols, src, dst, wgt, w,
               dang, gamma):
        n = f.shape[1]
        active = jnp.abs(f).sum(axis=1) > tol_cols  # [C]
        sel = ((jnp.abs(f) * w[None, :]) > t[:, None]) & active[:, None]
        sent = jnp.where(sel, f, 0.0)
        h = h + sent
        f = f - sent
        msg = jnp.take(sent, src, axis=1) * wgt[None, :]  # [C, L]
        seg = jax.vmap(
            lambda m: jax.ops.segment_sum(m, dst, num_segments=n))
        f = f + seg(msg)
        edge_active = jnp.take(sel, src, axis=1)  # [C, L]
        dops = jnp.sum(edge_active, axis=1).astype(jnp.int32)
        dops = dops + jnp.sum(
            sel & dang[None, :], axis=1).astype(jnp.int32)
        any_sel = jnp.any(sel, axis=1)
        t = jnp.where(any_sel | ~active, t, t / gamma)
        return (f, h, t, ops + dops,
                lane_rounds + active.astype(jnp.int32))

    def solve(f, h, t, ops, tol_cols, max_rounds, src, dst, wgt, w,
              dang, gamma):
        def cond(state):
            f, h, t, ops, lane_rounds, rounds = state
            return (jnp.any(jnp.abs(f).sum(axis=1) > tol_cols)
                    & (rounds < max_rounds))

        def body(state):
            f, h, t, ops, lane_rounds, rounds = state
            f, h, t, ops, lane_rounds = _round(
                f, h, t, ops, lane_rounds, tol_cols, src, dst, wgt, w,
                dang, gamma)
            return f, h, t, ops, lane_rounds, rounds + 1

        return jax.lax.while_loop(
            cond, body,
            (f, h, t, ops, jnp.zeros_like(ops),
             jnp.zeros((), jnp.int32)))

    def tick(f, h, t, ops, lane_rounds, tol_cols, budget, src, dst, wgt,
             w, dang, gamma):
        def cond(state):
            f, h, t, ops, lane_rounds, done = state
            return (jnp.any(jnp.abs(f).sum(axis=1) > tol_cols)
                    & (done < budget))

        def body(state):
            f, h, t, ops, lane_rounds, done = state
            f, h, t, ops, lane_rounds = _round(
                f, h, t, ops, lane_rounds, tol_cols, src, dst, wgt, w,
                dang, gamma)
            return f, h, t, ops, lane_rounds, done + 1

        return jax.lax.while_loop(
            cond, body,
            (f, h, t, ops, lane_rounds, jnp.zeros((), jnp.int32)))

    def warm(b_col, h_col, src, dst, wgt, w):
        # F' = B' − H + P·H (§2.2) for one lane, entirely on device
        ph = jax.ops.segment_sum(
            jnp.take(h_col, src) * wgt, dst,
            num_segments=b_col.shape[0])
        f_col = b_col - h_col + ph
        t_col = jnp.abs(f_col * w).max() * 2.0
        return f_col, t_col

    def place(f, h, t, ops, lane_rounds, lane, f_col, h_col, t_col):
        f = jax.lax.dynamic_update_slice_in_dim(
            f, f_col[None], lane, axis=0)
        h = jax.lax.dynamic_update_slice_in_dim(
            h, h_col[None], lane, axis=0)
        t = t.at[lane].set(t_col.astype(t.dtype))
        ops = ops.at[lane].set(0)
        lane_rounds = lane_rounds.at[lane].set(0)
        return f, h, t, ops, lane_rounds

    def clear(f, h, lane):
        zero = jnp.zeros((1, f.shape[1]), dtype=f.dtype)
        return (jax.lax.dynamic_update_slice_in_dim(f, zero, lane,
                                                    axis=0),
                jax.lax.dynamic_update_slice_in_dim(h, zero, lane,
                                                    axis=0))

    _BATCH_FNS.update(
        solve=jax.jit(solve), tick=jax.jit(tick), warm=jax.jit(warm),
        place=jax.jit(place), clear=jax.jit(clear))
    return _BATCH_FNS


# --------------------------------------------------------------------------- #
# frontier drivers (single-process jnp / Pallas)
# --------------------------------------------------------------------------- #
class _SegmentSumDriver:
    """frontier:segment_sum — per-edge gather→multiply→segment-sum rounds."""

    native_round = "frontier round"

    def __init__(self, problem: Problem, options: SolverOptions):
        g = problem.p
        self.n = g.n
        self.l = max(g.n_edges, 1)
        (self.src, self.dst, self.wgt, self.w,
         self.dang) = _edge_device_arrays(problem)
        self.gamma = options.gamma
        self._state = None

    def seed(self, f_nodes: np.ndarray,
             h_nodes: Optional[np.ndarray] = None) -> None:
        import jax.numpy as jnp

        f = jnp.asarray(f_nodes)
        h = jnp.zeros_like(f) if h_nodes is None else jnp.asarray(
            h_nodes, dtype=f.dtype)
        t = jnp.abs(f * self.w).max() * 2.0
        self._state = (f, h, t, jnp.zeros((), jnp.int32),
                       jnp.zeros((), jnp.int32))

    def warm_seed(self, b_new: np.ndarray) -> float:
        """Device-resident warm start: ``F' = B' − H + P·H`` without
        materializing H on the host.  The history stays where it lives;
        only the O(N) request payload ``b_new`` crosses to the device.
        Counters reset (new phase).  Returns |F'|_1 (scalar readback).
        """
        import jax
        import jax.numpy as jnp

        f_old, h, _t, _ops, _rounds = self._state
        b = jnp.asarray(b_new, dtype=h.dtype)
        ph = jax.ops.segment_sum(h[self.src] * self.wgt, self.dst,
                                 num_segments=self.n)
        f = b - h + ph
        t = jnp.abs(f * self.w).max() * 2.0
        self._state = (f, h, t, jnp.zeros((), jnp.int32),
                       jnp.zeros((), jnp.int32))
        return float(jnp.abs(f).sum())

    def advance(self, tol: float, round_limit: int) -> None:
        """Run until |F|_1 <= tol or the *total* round count hits the
        limit; resumable (identical round sequence to one long loop)."""
        import jax
        import jax.numpy as jnp

        from repro.core.diteration import frontier_step

        src, dst, wgt, w, dang, n, gamma = (
            self.src, self.dst, self.wgt, self.w, self.dang, self.n,
            self.gamma)

        def cond(state):
            f, h, t, ops, rounds = state
            return (jnp.abs(f).sum() > tol) & (rounds < round_limit)

        def body(state):
            f, h, t, ops, rounds = state
            f, h, t, dops = frontier_step(
                f, h, t, src, dst, wgt, w, dang, n, gamma)
            # dops may promote to int64 under jax_enable_x64; the carry
            # dtype must stay put or while_loop rejects the body
            return f, h, t, ops + dops.astype(ops.dtype), rounds + 1

        self._state = jax.lax.while_loop(cond, body, self._state)

    def x(self) -> np.ndarray:
        return np.asarray(self._state[1], dtype=np.float64)

    def residual(self) -> float:
        import jax.numpy as jnp

        return float(jnp.abs(self._state[0]).sum())

    def ops(self) -> int:
        return int(self._state[3])

    def rounds(self) -> int:
        return int(self._state[4])

    def exhausted(self) -> bool:
        return False

    def move_log(self) -> List[Tuple[int, int, int, int]]:
        return []

    # ---- checkpointable state (node space) --------------------------------
    def fluid(self) -> Tuple[np.ndarray, np.ndarray]:
        """(F, H) as float64 node-space vectors."""
        return (np.asarray(self._state[0], dtype=np.float64),
                np.asarray(self._state[1], dtype=np.float64))

    def threshold(self) -> np.ndarray:
        return np.asarray(self._state[2], dtype=np.float64)

    def set_threshold(self, t: np.ndarray) -> None:
        import jax.numpy as jnp

        t = np.asarray(t, dtype=np.float64).reshape(-1)
        if t.shape != (1,):
            return  # checkpointed at a different width (per-device T):
            # keep the re-derived threshold — any schedule is valid
        f, h, t_old, ops, rounds = self._state
        self._state = (f, h,
                       jnp.asarray(t[0], dtype=t_old.dtype).reshape(()),
                       ops, rounds)

    # ---- batched multi-RHS loop (vmap over columns) -----------------------
    def solve_batch(self, b_matrix: np.ndarray, tol: float,
                    max_rounds: int, pad: bool = True):
        """All columns at once: per-column thresholds + convergence masks.

        Converged columns stop diffusing (their frontier is masked), so
        ops accrue per column exactly as in the single-RHS loop.  The
        lane axis is padded to a pow2 bucket (:func:`_bucket_width`)
        with zero-RHS fill: a zero lane never selects and never pushes,
        so the real lanes are *bitwise* unaffected while XLA compiles
        once per bucket instead of once per batch width (``pad=False``
        keeps the exact width — the parity test's control arm).
        Returns ``(x [N, C], ops [C], rounds, res_cols, stats)``.
        """
        import jax.numpy as jnp

        c = b_matrix.shape[1]
        cp = _bucket_width(c) if pad else c
        b_t = jnp.asarray(np.ascontiguousarray(b_matrix.T))  # [C, N]
        if cp != c:
            f0 = jnp.zeros((cp, self.n), dtype=b_t.dtype).at[:c].set(b_t)
        else:
            f0 = b_t
        h0 = jnp.zeros_like(f0)
        t0 = jnp.abs(f0 * self.w[None, :]).max(axis=1) * 2.0  # [C_pad]
        tol_cols = jnp.full((cp,), tol, dtype=f0.dtype)
        f, h, t, ops, _lane_rounds, rounds = _batch_fns()["solve"](
            f0, h0, t0, jnp.zeros(cp, jnp.int32), tol_cols, max_rounds,
            self.src, self.dst, self.wgt, self.w, self.dang, self.gamma)
        res_cols = np.asarray(
            jnp.abs(f).sum(axis=1), dtype=np.float64)[:c]
        stats = {"bucket": cp,
                 "padding_waste": float((cp - c) / cp)}
        return (np.asarray(h.T, dtype=np.float64)[:, :c],
                np.asarray(ops)[:c], int(rounds), res_cols, stats)


class _BsrFrontierDriver:
    """frontier:pallas — fused BSR frontier rounds (jnp oracle off-TPU)."""

    native_round = "frontier round"

    def __init__(self, problem: Problem, options: SolverOptions):
        import jax.numpy as jnp

        g = problem.p
        self.n = g.n
        self.l = max(g.n_edges, 1)
        # kernel config: explicit options > platform tuned record > defaults
        bs, self.buffer_depth, self.occupancy_threshold = resolved_config(
            "frontier_round_bsr",
            bs=options.bs,
            buffer_depth=options.buffer_depth,
            occupancy_threshold=options.occupancy_threshold,
        )
        # the store's cached BSR view: graph deltas patch dirty tiles
        # in place, so a post-update rebuild re-uploads — not re-tiles
        self.m = problem.graph.bsr(bs=bs).to_device()
        n_pad = self.m.n_row_blocks * bs
        dt = self.m.blocks.dtype
        # device edge list for the device-resident warm start (P·H via
        # segment_sum — the BSR pool only exposes fused rounds)
        src, dst, wgt = g.edge_list()
        self._src_d = jnp.asarray(src, dtype=jnp.int32)
        self._dst_d = jnp.asarray(dst, dtype=jnp.int32)
        self._wgt_d = jnp.asarray(wgt, dtype=dt)
        pad = lambda v, t: jnp.zeros(n_pad, dtype=t).at[: g.n].set(
            jnp.asarray(v, dtype=t))
        self.w = pad(problem.node_weights(), dt)
        self.out_deg = pad(g.out_degree(), jnp.int32)
        self.dang = pad(g.dangling_mask(), bool)
        self.gamma = options.gamma
        self.interpret = options.interpret
        # interpret forces the real kernel; otherwise auto (pallas on
        # TPU, jnp block oracle elsewhere) — same rule as the legacy path
        self.op_backend = "pallas" if options.interpret else None
        self._n_pad = n_pad
        self._dt = dt
        self._state = None

    def seed(self, f_nodes: np.ndarray,
             h_nodes: Optional[np.ndarray] = None) -> None:
        import jax.numpy as jnp

        f = jnp.zeros(self._n_pad, dtype=self._dt).at[: self.n].set(
            jnp.asarray(f_nodes, dtype=self._dt))
        h = jnp.zeros_like(f)
        if h_nodes is not None:
            h = h.at[: self.n].set(jnp.asarray(h_nodes, dtype=self._dt))
        t = jnp.abs(f * self.w).max() * 2.0
        self._state = (f, jnp.abs(f).sum(), h, t,
                       jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32))

    def warm_seed(self, b_new: np.ndarray) -> float:
        """Device-resident warm start over the padded state (see
        :meth:`_SegmentSumDriver.warm_seed`)."""
        import jax
        import jax.numpy as jnp

        f_old, _res, h, _t, _ops, _rounds = self._state
        h_n = h[: self.n]
        b = jnp.asarray(b_new, dtype=self._dt)
        ph = jax.ops.segment_sum(h_n[self._src_d] * self._wgt_d,
                                 self._dst_d, num_segments=self.n)
        f_n = b - h_n + ph
        f = jnp.zeros(self._n_pad, dtype=self._dt).at[: self.n].set(f_n)
        res = jnp.abs(f).sum()
        t = jnp.abs(f * self.w).max() * 2.0
        self._state = (f, res, h, t, jnp.zeros((), jnp.int32),
                       jnp.zeros((), jnp.int32))
        return float(res)

    def advance(self, tol: float, round_limit: int) -> None:
        import jax
        import jax.numpy as jnp

        from repro.kernels.diffusion import frontier_round_bsr

        m, w, out_deg, dang, gamma = (self.m, self.w, self.out_deg,
                                      self.dang, self.gamma)
        op_backend, interpret = self.op_backend, self.interpret
        buffer_depth = self.buffer_depth
        occupancy_threshold = self.occupancy_threshold

        def cond(state):
            f, res, h, t, ops, rounds = state
            return (res > tol) & (rounds < round_limit)

        def body(state):
            f, _res, h, t, ops, rounds = state
            f_new, sent, res = frontier_round_bsr(
                m, f, w, t, backend=op_backend,
                interpret=interpret or None,
                buffer_depth=buffer_depth,
                occupancy_threshold=occupancy_threshold)
            # the op's threshold predicate is authoritative (the pallas
            # backend folds t into the weights); sel follows the sent fluid
            sel = sent != 0
            dops = jnp.sum(jnp.where(sel, out_deg, 0))
            dops = dops + jnp.sum((sel & dang).astype(jnp.int32))
            any_sel = jnp.any(sel)
            t_new = jnp.where(any_sel, t, t / gamma)
            return f_new, res, h + sent, t_new, ops + dops, rounds + 1

        self._state = jax.lax.while_loop(cond, body, self._state)

    def x(self) -> np.ndarray:
        return np.asarray(self._state[2][: self.n], dtype=np.float64)

    def residual(self) -> float:
        return float(self._state[1])

    def ops(self) -> int:
        return int(self._state[4])

    def rounds(self) -> int:
        return int(self._state[5])

    def exhausted(self) -> bool:
        return False

    def move_log(self) -> List[Tuple[int, int, int, int]]:
        return []

    # ---- checkpointable state (node space) --------------------------------
    def fluid(self) -> Tuple[np.ndarray, np.ndarray]:
        return (np.asarray(self._state[0][: self.n], dtype=np.float64),
                np.asarray(self._state[2][: self.n], dtype=np.float64))

    def threshold(self) -> np.ndarray:
        return np.asarray(self._state[3], dtype=np.float64)

    def set_threshold(self, t: np.ndarray) -> None:
        import jax.numpy as jnp

        t = np.asarray(t, dtype=np.float64).reshape(-1)
        if t.shape != (1,):
            return  # cross-width checkpoint: keep the re-derived T
        f, res, h, t_old, ops, rounds = self._state
        self._state = (f, res, h,
                       jnp.asarray(t[0], dtype=t_old.dtype).reshape(()),
                       ops, rounds)


# --------------------------------------------------------------------------- #
# engine driver (shard_map production solver, chunk-granular)
# --------------------------------------------------------------------------- #
def _bsr_buckets_per_dev(n: int, k: int, options: SolverOptions) -> int:
    """BSR tiles are dense [S, S] blocks: cap the bucket size (≤ 512)
    so the tile pool stays MXU-shaped instead of ballooning to
    [R, T, N/K, N/K] on big problems.  Auto-sizing only ever *raises*
    the bucket count the caller configured.  One rule shared by driver
    construction and mid-solve rescale, so a rescaled engine's layout
    always matches what a cold start at the same k would build."""
    max_s = 512
    real_needed = -(-n // (k * max_s))  # ceil
    return max(options.buckets_per_dev, real_needed + options.headroom)


class _EngineDriver:
    """engine:chunk / engine:bsr — the distributed engine, one jitted
    chunk per advance, with the balance control plane between chunks."""

    native_round = "engine round"

    def __init__(self, problem: Problem, options: SolverOptions,
                 diffusion_backend: str):
        import jax
        import jax.numpy as jnp

        from repro.core.distributed import (
            DistributedEngine,
            EngineConfig,
            build_engine_arrays,
        )

        if problem.weights is not None or problem.weight_mode != "inv_out":
            raise ValueError(
                "engine backends run the default inv_out selection "
                "weights; custom Problem.weights cannot be honored"
            )
        k = options.k or 1
        n_dev = len(jax.devices())
        if k > n_dev:
            raise ValueError(
                f"engine backends need k physical devices: k={k} > "
                f"{n_dev} available (use method='simulator' for virtual "
                "PIDs)"
            )
        buckets_per_dev = (_bsr_buckets_per_dev(problem.n, k, options)
                           if diffusion_backend == "bsr"
                           else options.buckets_per_dev)
        self.cfg = EngineConfig(
            k=k,
            target_error=problem.target_error,
            eps=problem.eps,
            buckets_per_dev=buckets_per_dev,
            headroom=options.headroom,
            max_inner=options.max_inner,
            gamma=options.gamma,
            dynamic=options.dynamic,
            policy=options.policy,
            signal=options.signal,
            eta=options.eta,
            z=options.z,
            chunk_rounds=options.chunk_rounds,
            max_chunks=options.max_chunks,
            dtype=options.dtype or jnp.float32,
            diffusion_backend=diffusion_backend,
            pallas_interpret=options.interpret,
            # explicit option > platform tuned record > default depth 1
            pallas_buffer_depth=resolved_config(
                "bsr_gather_spmm", buffer_depth=options.buffer_depth
            )[1],
        )
        # the store's cached engine-layout view: graph deltas patch
        # dirty bucket rows / tiles in place before we land here
        self.arrays = build_engine_arrays(problem.graph, problem.b,
                                          self.cfg)
        self.engine = DistributedEngine(self.arrays, self.cfg)
        self.problem = problem
        self.options = options
        self.l = max(problem.n_edges, 1)
        self._seeded = False

    def seed(self, f_nodes: np.ndarray,
             h_nodes: Optional[np.ndarray] = None) -> None:
        from repro.balance.executors import BucketMoveExecutor

        # fresh policy state per solve phase: a warm start is a new
        # convergence trajectory, stale EMA slopes would misfire
        self._fresh_rebalancer()
        self.ex = BucketMoveExecutor(
            self.engine, self.engine.init_state(f_nodes, h_nodes))
        self._resid = float(np.abs(np.asarray(f_nodes)).sum())
        self._chunks = 0
        self._moves: List[Tuple[int, int, int, int]] = []
        self._prev_ops = np.zeros(self.cfg.k, dtype=np.int64)
        # rescale carry-over: a rescale re-inits the sharded counters at
        # the new width, so phase totals accumulate into host offsets
        self._ops_offset = 0
        self._rounds_offset = 0
        self._warm_maps = None  # (arrays-identity, layout-bytes, maps)
        self._seeded = True

    def _fresh_rebalancer(self) -> None:
        from repro.balance.policies import make_rebalancer

        if self.engine.rebalancer is not None:
            self.engine.rebalancer = make_rebalancer(
                self.cfg.policy or "slope_ema", k=self.cfg.k,
                target_error=self.cfg.target_error, eta=self.cfg.eta,
                z=self.cfg.z, unit="bucket",
            )

    def warm_seed(self, b_new: np.ndarray) -> float:
        """Device-resident warm start over the sharded bucket layout.

        The history H never leaves the devices: the bucketed [R, S]
        state is permuted home-layout-wise, flattened to node space,
        run through ``P·H`` (device segment_sum over a cached device
        edge list), and the re-seeded ``F' = B' − H + P·H`` scattered
        back — all jnp ops.  Only ``b_new`` (the request payload) is
        uploaded and only the scalar |F'|_1 is read back.  Index maps
        are cached per (arrays, bucket-layout) and rebuilt when a
        rescale or bucket move changes either.  Counters reset; the
        rebalancer restarts fresh (new convergence trajectory).
        """
        import jax
        import jax.numpy as jnp

        a, cfg, eng, ex = self.arrays, self.cfg, self.engine, self.ex
        r_rows, s_slots = a.n_rows, a.bucket_size
        rob = np.asarray(ex.row_of_bucket)
        cache = self._warm_maps
        if (cache is None or cache[0] is not a
                or cache[1] != rob.tobytes()):
            src, dst, wgt = self.problem.p.edge_list()
            # cur_of_home[home row] = row currently holding that bucket
            cur = np.empty(r_rows, dtype=np.int64)
            cur[np.asarray(a.pos_of_bucket, dtype=np.int64)] = rob
            inv = np.empty(r_rows, dtype=np.int64)
            inv[cur] = np.arange(r_rows)
            nos = a.node_of_slot  # [R, S], home-row indexed
            valid = nos >= 0
            flat_slot = (np.arange(r_rows)[:, None] * s_slots
                         + np.arange(s_slots)[None, :])[valid]
            maps = {
                "src": jnp.asarray(src, jnp.int32),
                "dst": jnp.asarray(dst, jnp.int32),
                "wgt": jnp.asarray(wgt, cfg.dtype),
                "perm": jnp.asarray(cur, jnp.int32),
                "inv": jnp.asarray(inv, jnp.int32),
                "flat_slot": jnp.asarray(flat_slot, jnp.int32),
                "node_ids": jnp.asarray(nos[valid], jnp.int32),
                "w_home": jnp.asarray(a.w, cfg.dtype).reshape(
                    r_rows, s_slots),
            }
            self._warm_maps = (a, rob.tobytes(), maps)
        maps = self._warm_maps[2]
        st = ex.state
        h_rows = st.h.reshape(r_rows, s_slots)
        h_home = jnp.take(h_rows, maps["perm"], axis=0)
        h_node = jnp.zeros(a.n, cfg.dtype).at[maps["node_ids"]].set(
            h_home.reshape(-1)[maps["flat_slot"]])
        b_dev = jnp.asarray(np.asarray(b_new), cfg.dtype)
        ph = jax.ops.segment_sum(h_node[maps["src"]] * maps["wgt"],
                                 maps["dst"], num_segments=a.n)
        f_node = b_dev - h_node + ph
        f_home = jnp.zeros(r_rows * s_slots, cfg.dtype).at[
            maps["flat_slot"]].set(f_node[maps["node_ids"]]
                                   ).reshape(r_rows, s_slots)
        fw_cur = jnp.take(jnp.abs(f_home) * maps["w_home"], maps["inv"],
                          axis=0)
        t0 = fw_cur.reshape(cfg.k, -1).max(axis=1) * 2.0 + 1e-30
        f_cur = jnp.take(f_home, maps["inv"], axis=0)
        put_row = lambda x: jax.device_put(x, eng.row_sharding)
        ex.state = dataclasses.replace(
            st,
            f=put_row(f_cur.reshape(st.f.shape).astype(cfg.dtype)),
            outbox=put_row(jnp.zeros_like(st.outbox)),
            t=put_row(t0.astype(cfg.dtype)),
            ops=put_row(jnp.zeros(cfg.k, dtype=jnp.int32)),
            rounds=jax.device_put(jnp.zeros((), dtype=jnp.int32),
                                  eng.rep_sharding),
        )
        self._fresh_rebalancer()
        self._resid = float(jnp.abs(f_node).sum())
        self._chunks = 0
        self._moves = []
        self._prev_ops = np.zeros(cfg.k, dtype=np.int64)
        self._ops_offset = 0
        self._rounds_offset = 0
        return self._resid

    def advance(self, tol: float, round_limit: int) -> None:
        """One jitted chunk + one control-plane pass (engine grain)."""
        eng, ex = self.engine, self.ex
        ex.state, stats = eng._chunk(ex.state, *ex.chunk_operands())
        r = np.asarray(stats["r"])
        s_ = np.asarray(stats["s"])
        self._resid = float(np.asarray(stats["residual"])) + float(s_.sum())
        self._chunks += 1
        if self._resid <= tol:
            return
        self._prev_ops = eng.apply_control_plane(
            ex, r, s_, self._chunks, self._prev_ops, self._moves)

    def x(self) -> np.ndarray:
        return self.engine.extract_solution(self.ex.state,
                                            self.ex.row_of_bucket)

    def residual(self) -> float:
        return self._resid

    def ops(self) -> int:
        return self._ops_offset + int(
            np.asarray(self.ex.state.ops).astype(np.int64).sum())

    def rounds(self) -> int:
        return self._rounds_offset + int(np.asarray(self.ex.state.rounds))

    def exhausted(self) -> bool:
        return self._chunks >= self.cfg.max_chunks

    def move_log(self) -> List[Tuple[int, int, int, int]]:
        return list(self._moves)

    # ---- checkpointable state (node space) --------------------------------
    def fluid(self) -> Tuple[np.ndarray, np.ndarray]:
        return (self.engine.gather_nodes(self.ex.state.f,
                                         self.ex.row_of_bucket),
                self.engine.gather_nodes(self.ex.state.h,
                                         self.ex.row_of_bucket))

    def threshold(self) -> np.ndarray:
        return np.asarray(self.ex.state.t, dtype=np.float64)

    def set_threshold(self, t: np.ndarray) -> None:
        import jax

        t = np.asarray(t, dtype=np.float64).reshape(-1)
        if t.shape != (self.cfg.k,):
            return  # checkpointed at a different width: keep the
            # re-derived thresholds (any schedule is a valid D-iteration)
        self.ex.state = dataclasses.replace(
            self.ex.state,
            t=jax.device_put(t.astype(self.cfg.dtype),
                             self.engine.row_sharding))

    # ---- elasticity -------------------------------------------------------
    def note_straggler(self, pid: int, slowdown: float) -> None:
        """Signal-level straggler injection: the control plane sees the
        PID's load inflated by ``slowdown`` (a real straggling device
        cannot be slowed from the host, but the controller's view can —
        it then sheds buckets exactly as in production)."""
        scale = (self.engine.load_scale if self.engine.load_scale
                 is not None else np.ones(self.cfg.k))
        scale = np.asarray(scale, dtype=np.float64).copy()
        scale[pid] = slowdown
        self.engine.load_scale = scale

    def rescale(self, k_new: int,
                strict: bool = False) -> List[Tuple[int, int, int]]:
        """Grow/shrink the pid axis mid-solve (H and F travel in node
        space; shrink drains through the BucketMoveExecutor path when
        the surviving headroom can absorb it).  Returns the executed
        drain triples; they are also appended to the move log as
        ``(chunk, src, dst, units)``."""
        if k_new == self.cfg.k:
            return []
        prev_ops, prev_rounds = self.ops(), self.rounds()
        bpd = (_bsr_buckets_per_dev(self.problem.n, k_new, self.options)
               if self.cfg.diffusion_backend == "bsr"
               else self.options.buckets_per_dev)
        old_scale = self.engine.load_scale
        self.engine, self.ex, drains = self.engine.rescale(
            self.ex, k_new, self.problem.graph, self.problem.b,
            buckets_per_dev=bpd, strict=strict)
        if old_scale is not None:
            # surviving stragglers stay stragglers across the re-mesh;
            # dropped/grown slots are fresh (healthy) capacity
            scale = np.ones(k_new, dtype=np.float64)
            m = min(k_new, old_scale.shape[0])
            scale[:m] = old_scale[:m]
            self.engine.load_scale = scale
        self.cfg = self.engine.cfg
        self.arrays = self.engine.a
        for src, dst, moved in drains:
            self._moves.append((self._chunks, src, dst, moved))
        self._prev_ops = np.zeros(k_new, dtype=np.int64)
        self._ops_offset = prev_ops
        self._rounds_offset = prev_rounds
        return drains

    def note_graph_churn(self, churn_per_node: np.ndarray) -> None:
        """Feed edge churn to the balance control plane.

        The paper's thesis applied to graph drift: the controller needs
        only a per-PID load magnitude, never the structure.  Per-node
        changed-edge counts are mapped onto owning devices through the
        current bucket layout and run through one rebalancer pass as a
        ``graph-churn`` :class:`LoadSignal`, so a device absorbing the
        churn can shed buckets *before* the delta re-solve starts.
        """
        from repro.balance.signals import LoadSignal

        eng = self.engine
        if eng.rebalancer is None:
            return
        a = eng.a
        churn = np.asarray(churn_per_node, dtype=np.int64)
        valid = a.node_of_slot >= 0
        row_churn = np.zeros(a.n_rows, dtype=np.int64)
        rows = np.broadcast_to(
            np.arange(a.n_rows)[:, None], a.node_of_slot.shape)
        np.add.at(row_churn, rows[valid], churn[a.node_of_slot[valid]])
        b_loc = self.cfg.buckets_per_dev
        dev_churn = np.zeros(self.cfg.k, dtype=np.float64)
        for bid in range(a.n_rows):
            home = int(a.pos_of_bucket[bid])  # node map lives at home row
            cur = int(self.ex.row_of_bucket[bid])  # data owner now
            dev_churn[cur // b_loc] += row_churn[home]
        if dev_churn.sum() == 0:
            return
        sig = LoadSignal.from_graph_churn(dev_churn, self.ex.sizes(),
                                          step=self._chunks)
        for plan in eng.rebalancer.propose(sig):
            moved = self.ex.apply(plan)
            if moved:
                self._moves.append((self._chunks, plan.src, plan.dst,
                                    moved))


_DRIVERS = {
    "frontier:segment_sum": lambda p, o: _SegmentSumDriver(p, o),
    "frontier:pallas": lambda p, o: _BsrFrontierDriver(p, o),
    "engine:chunk": lambda p, o: _EngineDriver(p, o, "segment_sum"),
    "engine:bsr": lambda p, o: _EngineDriver(p, o, "bsr"),
}


def _invariant_violation(problem: Problem, b: np.ndarray, h: np.ndarray,
                         f: np.ndarray, edges=None) -> float:
    """|B − (I−P)·H − F|_1 against the problem's *current* matrix.

    Zero (up to accumulation rounding) along any valid D-iteration
    schedule — the checkpoint-integrity oracle: a torn write, a
    corrupted leaf, or a checkpoint taken against a different P all
    violate it by a macroscopic margin.  ``edges`` optionally supplies
    a pre-materialized ``(src, dst, w)`` edge list so repeated checks
    (restore's candidate walk) pay the O(L) materialization once.
    """
    src, dst, w = edges if edges is not None else problem.p.edge_list()
    ph = np.bincount(dst, weights=h[src] * w, minlength=problem.n)
    return float(np.abs(b - h + ph - f).sum())


# --------------------------------------------------------------------------- #
# the session
# --------------------------------------------------------------------------- #
class SolverSession:
    """A long-lived solver owning the (H, F) fluid state of one Problem.

    ``method`` must be a warm-startable registry backend
    (``frontier:segment_sum``, ``frontier:pallas``, ``engine:chunk``,
    ``engine:bsr`` — see ``repro.api.list_backends()``).  The session
    seeds ``F = B, H = 0`` on construction; ``warm_start`` re-seeds F
    for a new RHS while keeping H, resetting the per-phase op/round
    counters so reports measure the *current* solve.
    """

    def __init__(self, problem: Problem,
                 method: str = "frontier:segment_sum",
                 options: Optional[SolverOptions] = None, **kw):
        from .registry import get_backend

        be = get_backend(method)
        if not be.caps.supports_warm_start:
            raise ValueError(
                f"backend {method!r} is one-shot; SolverSession needs a "
                "warm-startable backend "
                "(frontier:segment_sum | frontier:pallas | engine:chunk "
                "| engine:bsr)"
            )
        opts = options if options is not None else SolverOptions()
        if kw:
            opts = dataclasses.replace(opts, **kw)
        self.options = opts.validated(be.caps, method)
        self.problem = problem
        self.method = method
        self._driver = _DRIVERS[method](problem, self.options)
        self._driver.seed(problem.b)
        self._b = np.asarray(problem.b, dtype=np.float64)
        # cached once: warm_start re-derives P·H per serving request and
        # must not pay the O(L) edge-list materialization every time
        self._edges = problem.p.edge_list()
        self._ckpt_step = 0
        self.restored_from: Optional[dict] = None
        # lifetime §2.3 accounting: phase counters reset on every
        # warm_start / update_graph, so re-seeds bank them here first —
        # ``lifetime_ops`` is THE one rule recovery-cost consumers sum
        self._ops_banked = 0
        self._rounds_banked = 0

    # ---- state views ------------------------------------------------------
    @property
    def x(self) -> np.ndarray:
        """Current solution estimate H (node space, float64)."""
        return self._driver.x()

    @property
    def residual(self) -> float:
        return self._driver.residual()

    @property
    def n_ops(self) -> int:
        """Edge pushes charged in the current solve phase (§2.3)."""
        return self._driver.ops()

    @property
    def n_rounds(self) -> int:
        return self._driver.rounds()

    @property
    def lifetime_ops(self) -> int:
        """Edge pushes charged across the session's whole life (§2.3):
        every solve phase since construction or restore, including work
        banked by warm_start / update_graph re-seeds."""
        return self._ops_banked + self._driver.ops()

    @property
    def lifetime_rounds(self) -> int:
        return self._rounds_banked + self._driver.rounds()

    def _bank_phase(self) -> None:
        """Fold the current phase counters into the lifetime totals —
        call ONLY immediately before a re-seed resets them."""
        self._ops_banked += self._driver.ops()
        self._rounds_banked += self._driver.rounds()

    def _tol(self, until: Optional[float]) -> float:
        te = until if until is not None else self.problem.target_error
        return te * self.problem.eps

    def _check_fresh(self) -> None:
        """Refuse to run over a stale graph snapshot.

        Views are patched IN PLACE by ``GraphStore.apply_delta``, so a
        second session sharing the store would otherwise mix patched
        device arrays with its pre-delta (P, B, H) state.  Touching
        ``problem.graph`` raises the store-version mismatch; sessions
        that never materialized a store skip the check (nothing
        shared, nothing to go stale).
        """
        if self.problem.store is not None:
            self.problem.graph

    # ---- streaming solve --------------------------------------------------
    def run(self, until: Optional[float] = None,
            max_rounds: Optional[int] = None,
            chaos=None) -> Iterator[RoundReport]:
        """Drain F toward ``until`` (a target_error), streaming one
        :class:`RoundReport` per trace grain (``options.trace_every``
        frontier rounds / one engine chunk).  The final yielded report
        is the converged (or budget-exhausted) state.

        ``chaos`` is an optional :class:`repro.chaos.SessionInjector`:
        its plan's events fire *before* each grain (rounds = grain
        indices, starting at 1).  A ``kill`` event raises
        :class:`repro.chaos.ChaosKill` — recovery is the caller's
        restore + rescale flow (DESIGN.md §8)."""
        self._check_fresh()
        if chaos is not None:
            chaos.bind(self)
        tol = self._tol(until)
        cap = max_rounds if max_rounds is not None else (
            self.options.max_rounds)
        while True:
            d = self._driver
            if d.residual() <= tol or d.rounds() >= cap or d.exhausted():
                yield RoundReport(d.rounds(), d.residual(), d.ops())
                return
            if chaos is not None:
                chaos.before_grain(self)
                d = self._driver  # chaos may have rebuilt the driver
            if isinstance(d, _EngineDriver):
                d.advance(tol, cap)
            else:
                d.advance(tol, min(d.rounds() + self.options.trace_every,
                                   cap))
            yield RoundReport(d.rounds(), d.residual(), d.ops())

    def solve(self, until: Optional[float] = None,
              max_rounds: Optional[int] = None,
              chaos=None) -> SolveReport:
        """Run to convergence and return the unified report."""
        t0 = time.perf_counter()
        trace = list(self.run(until=until, max_rounds=max_rounds,
                              chaos=chaos))
        d = self._driver
        return SolveReport(
            x=d.x(),
            residual=d.residual(),
            n_ops=d.ops(),
            cost_iterations=d.ops() / d.l,
            n_rounds=d.rounds(),
            converged=d.residual() <= self._tol(until),
            method=self.method,
            trace=trace,
            move_log=d.move_log(),
            wall_time_s=time.perf_counter() - t0,
            extras={"session": True},
        )

    # ---- warm start (§2.2 residual identity) ------------------------------
    def warm_start(self, b_new: np.ndarray) -> float:
        """Re-seed for a new RHS, reusing the accumulated history H.

        ``F' = B' − (I−P)·H = B' − H + P·H`` — exactly the residual the
        old H leaves against the new system, so |F'| (returned) is small
        whenever B' is near the RHS H was built for, and the follow-up
        ``run``/``solve`` charges correspondingly few edge pushes.
        Phase counters (ops, rounds, trace) reset to zero after banking
        into the lifetime totals.

        The serving hot path: drivers exposing ``warm_seed`` re-seed
        entirely on device (H and F never round-trip through host
        numpy; only ``b_new`` is uploaded and the scalar |F'|_1 read
        back) — all four warm-startable backends do.
        """
        self._check_fresh()
        b_new = np.asarray(b_new, dtype=np.float64)
        if b_new.shape != (self.problem.n,):
            raise ValueError(
                f"b_new has shape {b_new.shape}, expected "
                f"({self.problem.n},)"
            )
        self._bank_phase()
        warm_seed = getattr(self._driver, "warm_seed", None)
        if warm_seed is not None:
            resid = warm_seed(b_new)
            self._b = b_new
            self.problem = self.problem.with_b(b_new)
            return resid
        h = self._driver.x()
        src, dst, w = self._edges
        ph = np.bincount(dst, weights=h[src] * w, minlength=self.problem.n)
        f_new = b_new - h + ph
        self._driver.seed(f_new, h)
        self._b = b_new
        self.problem = self.problem.with_b(b_new)
        return float(np.abs(f_new).sum())

    # ---- graph delta (the F' = F + (P'−P)·H update) -----------------------
    def update_graph(self, delta) -> float:
        """Apply edge churn to the Problem's GraphStore and re-seed warm.

        The asynchronous-scheme companions (arXiv:1202.3108,
        arXiv:1301.3007) show the fluid pair survives matrix drift:
        ``F' = F + (P'−P)·H``.  We evaluate it through the invariant
        ``F = B − (I−P)·H`` — i.e. ``F' = B − H + P'·H`` over the
        *patched* matrix — so the cost is the store's dirty-view patch
        plus one O(L) product, and the follow-up ``run``/``solve``
        drains only the churn-injected fluid instead of restarting
        cold.  Routed through :meth:`Problem.with_graph`; on engine
        backends the churn also feeds the balance control plane as a
        ``graph-churn`` LoadSignal.  Phase counters reset (banked into
        the lifetime totals); returns ``|F'|_1``.

        **Transactional**: a malformed delta is rejected before any
        mutation (the CSR splice validates first, and the inverse delta
        is captured up front — both raise with the session untouched);
        a failure *after* the store mutated (view patch, driver
        rebuild, re-seed) rolls the store back via the inverse delta
        and re-seeds the old state over a fresh driver, so the next
        request serves the pre-delta graph instead of a half-patched
        one.  The original exception re-raises either way.
        """
        from repro.graph import GraphDelta, invert_delta

        if not isinstance(delta, GraphDelta):
            raise TypeError(
                f"update_graph wants a GraphDelta, got "
                f"{type(delta).__name__}"
            )
        h = self._driver.x()
        if delta.is_empty:
            return self._driver.residual()
        store = self.problem.graph
        # rollback token; also pre-validates that every removed /
        # reweighted edge exists (raises BEFORE any mutation)
        inverse = invert_delta(store, delta)
        self._bank_phase()
        applied = False
        try:
            store.apply_delta(delta)  # patches every materialized view
            applied = True
            self.problem = self.problem.with_graph(store)
            src, dst, w = self.problem.p.edge_list()
            self._edges = (src, dst, w)
            ph = np.bincount(dst, weights=h[src] * w,
                             minlength=self.problem.n)
            f_new = self._b - h + ph
            # fresh driver over the PATCHED views (cache hits inside the
            # store: tiles/buckets/rows were spliced, not rebuilt)
            self._driver = _DRIVERS[self.method](self.problem,
                                                 self.options)
            self._driver.seed(f_new, h)
        except Exception:
            if applied:
                store.apply_delta(inverse)
            # even a failed apply_delta may have partially patched a
            # view the old driver captured (the store rolls its CSR
            # back and drops the view cache) — rebuild the driver over
            # the restored store and re-seed the held (H, F) via the
            # same invariant identity F = B − H + P·H
            self.problem = self.problem.with_graph(store)
            src, dst, w = self.problem.p.edge_list()
            self._edges = (src, dst, w)
            ph = np.bincount(dst, weights=h[src] * w,
                             minlength=self.problem.n)
            self._driver = _DRIVERS[self.method](self.problem,
                                                 self.options)
            self._driver.seed(self._b - h + ph, h)
            self._batch_driver = None
            raise
        if isinstance(self._driver, _EngineDriver):
            self._driver.note_graph_churn(
                delta.churn_per_node(self.problem.n))
        self._batch_driver = None  # edge list went stale
        return float(np.abs(f_new).sum())

    # ---- elasticity: mid-solve PID rescale --------------------------------
    def rescale(self, k_new: int,
                strict: bool = False) -> List[Tuple[int, int, int]]:
        """Grow/shrink the engine's ``pid`` axis mid-solve.

        Shrink drains the dying devices' buckets through the existing
        :class:`~repro.balance.executors.BucketMoveExecutor` path
        (survivors' headroom rows absorb the moves, logged in the move
        log; with insufficient headroom the drain is skipped — or
        raises under ``strict=True``), then the axis re-meshes at
        ``k_new`` over the store's cached engine-layout view; grow
        re-meshes directly and the rebalancer spreads any residual
        skew.  The accumulated (H, F) fluid pair travels in node space
        — H is never recomputed.  Returns the executed drain triples
        ``(src, dst, units)``.
        """
        self._check_fresh()
        d = self._driver
        if not isinstance(d, _EngineDriver):
            raise ValueError(
                f"rescale needs an engine backend (engine:chunk | "
                f"engine:bsr); {self.method!r} has no pid axis"
            )
        d.problem = self.problem  # warm starts may have re-snapshotted
        drains = d.rescale(k_new, strict=strict)
        self.options = dataclasses.replace(self.options, k=k_new)
        return drains

    # ---- fault tolerance: atomic checkpoint / verified restore ------------
    def checkpoint(self, root: str) -> str:
        """Persist the session's fluid state under ``root`` atomically.

        One step directory per call (monotonic step counter, atomic
        ``os.replace`` commit via :mod:`repro.checkpoint.store`):
        node-space ``(B, F, H)`` + thresholds as array leaves, plus a
        manifest extra carrying method, counters, the move log, and the
        GraphStore version the state was built against.  Returns the
        committed directory path.
        """
        from repro.checkpoint import save_checkpoint

        self._check_fresh()
        d = self._driver
        f, h = d.fluid()
        self._ckpt_step += 1
        tree = {"b": self._b, "f": f, "h": h, "t": d.threshold()}
        extra = {
            "method": self.method,
            "n": self.problem.n,
            "n_edges": self.problem.n_edges,
            "store_version": self.problem.store_version,
            "ops": d.ops(),
            "rounds": d.rounds(),
            "lifetime_ops": self.lifetime_ops,
            "lifetime_rounds": self.lifetime_rounds,
            "residual": d.residual(),
            "move_log": [list(m) for m in d.move_log()],
        }
        return save_checkpoint(root, self._ckpt_step, tree, extra)

    @staticmethod
    def _reject_reason(problem: Problem, b: np.ndarray, f: np.ndarray,
                       h: np.ndarray, extra: dict, rtol: float,
                       edges=None) -> Optional[str]:
        """Why a loaded checkpoint cannot resume against ``problem``
        (None = accept).  The decisive oracle is the §2.2 invariant
        ``B = (I−P)H + F`` evaluated against the problem's CURRENT
        matrix: a torn/corrupted leaf or a checkpoint taken before a
        graph delta both violate it macroscopically."""
        if b.shape != (problem.n,):
            return (f"shape mismatch: checkpoint N={b.shape[0]}, "
                    f"problem N={problem.n}")
        if extra.get("n") not in (None, problem.n):
            return f"stale: checkpoint N={extra['n']} != {problem.n}"
        if extra.get("n_edges") not in (None, problem.n_edges):
            return (f"stale: checkpoint graph had {extra['n_edges']} "
                    f"edges, problem has {problem.n_edges}")
        sv = extra.get("store_version")
        if (sv is not None and problem.store is not None
                and problem.graph.version != sv):
            return (f"stale: GraphStore advanced to version "
                    f"{problem.graph.version}, checkpoint captured {sv}")
        viol = _invariant_violation(problem, b, h, f, edges=edges)
        scale = max(1.0, float(np.abs(b).sum() + np.abs(h).sum()))
        if viol > rtol * scale:
            return (f"invariant violated: |B−(I−P)H−F|₁ = {viol:.3e} > "
                    f"{rtol * scale:.3e} (torn or stale checkpoint)")
        return None

    @classmethod
    def restore(cls, root: str, problem: Problem,
                method: Optional[str] = None,
                options: Optional[SolverOptions] = None,
                step: Optional[int] = None,
                invariant_rtol: float = 1e-4, **kw) -> "SolverSession":
        """Resume a session from the newest checkpoint that *verifies*.

        Every candidate step (newest first; exactly ``step`` when given)
        is loaded and checked — ``B − (I−P)H − F ≈ 0`` against
        ``problem``'s current matrix, N/edge-count/store-version
        agreement — and a failing checkpoint is **rejected rather than
        silently resumed**, falling back to the next older complete
        step.  Raises with the per-step rejection reasons when nothing
        survives.  The restored session keeps the checkpoint's RHS
        (``problem.with_b``), re-seeds ``(F, H)`` and the thresholds,
        and records provenance in ``session.restored_from``.
        """
        import os

        from repro.checkpoint import list_steps, load_checkpoint

        steps = list_steps(root)
        # adversarial directories: step-like dirs that list_steps
        # refused (torn manifest mid-write, permission-denied,
        # unparsable JSON) surface as rejection provenance instead of
        # disappearing silently
        rejected: List[Tuple[int, str]] = []
        complete = {f"step_{s:09d}" for s in steps}
        try:
            entries = sorted(os.listdir(root))
        except OSError:
            entries = []
        for name in entries:
            if (name.startswith("step_") and not name.endswith(".tmp")
                    and name not in complete):
                try:
                    s_bad = int(name.split("_", 1)[1])
                except ValueError:
                    s_bad = -1
                rejected.append(
                    (s_bad, "incomplete or unreadable manifest"))
        if step is not None:
            if step not in steps:
                raise FileNotFoundError(
                    f"no complete checkpoint for step {step} under {root}"
                )
            candidates = [step]
        else:
            candidates = steps[::-1]
        if not candidates:
            raise FileNotFoundError(f"no complete checkpoint under {root}")
        tree_like = {
            "b": np.zeros(problem.n), "f": np.zeros(problem.n),
            "h": np.zeros(problem.n), "t": np.zeros(()),
        }
        edges = problem.p.edge_list()  # once, not per candidate (O(L))
        for s in candidates:
            try:
                tree, _, extra = load_checkpoint(root, tree_like, s)
            except Exception as e:
                rejected.append((s, f"unreadable: {e}"))
                continue
            b, f, h = tree["b"], tree["f"], tree["h"]
            reason = cls._reject_reason(problem, b, f, h, extra,
                                        invariant_rtol, edges=edges)
            if reason is not None:
                rejected.append((s, reason))
                continue
            session = cls(problem.with_b(b),
                          method=method or extra["method"],
                          options=options, **kw)
            session._driver.seed(f, h)
            session._driver.set_threshold(tree["t"])
            session._ckpt_step = s
            session.restored_from = {
                "step": s,
                "ops": extra.get("ops", 0),
                "rounds": extra.get("rounds", 0),
                "lifetime_ops": extra.get("lifetime_ops",
                                          extra.get("ops", 0)),
                "move_log": [tuple(m) for m in extra.get("move_log", [])],
                "rejected": rejected,
            }
            return session
        detail = "; ".join(f"step {s}: {r}" for s, r in rejected)
        raise ValueError(f"no valid checkpoint under {root}: {detail}")

    # ---- batched multi-RHS ------------------------------------------------
    def solve_batch(self, b_matrix: np.ndarray,
                    until: Optional[float] = None,
                    pad: bool = True) -> SolveReport:
        """Solve every column of ``b_matrix`` ([N, C]) over the shared P.

        Runs the vmapped frontier loop (per-column thresholds and
        convergence masks) regardless of the session's method — the
        batch serving path is frontier-native by design (DESIGN.md §4).
        The session's own (H, F) state is untouched.  The lane axis is
        bucket-padded (``pad=False`` opts out — see the driver) so a
        drifting batch width reuses the compiled trace; the padding
        bookkeeping lands in ``extras`` (``bucket``, ``padding_waste``).
        """
        self._check_fresh()
        b_matrix = np.asarray(b_matrix, dtype=np.float64)
        if b_matrix.ndim != 2 or b_matrix.shape[0] != self.problem.n:
            raise ValueError(
                f"b_matrix must be [N, C] with N={self.problem.n}, got "
                f"{b_matrix.shape}"
            )
        if isinstance(self._driver, _SegmentSumDriver):
            batch_driver = self._driver
        else:
            batch_driver = getattr(self, "_batch_driver", None)
            if batch_driver is None:
                batch_driver = _SegmentSumDriver(self.problem, self.options)
                self._batch_driver = batch_driver
        t0 = time.perf_counter()
        tol = self._tol(until)
        x, ops, rounds, res_cols, stats = batch_driver.solve_batch(
            b_matrix, tol, self.options.max_rounds, pad=pad)
        n_ops = int(ops.astype(np.int64).sum())
        return SolveReport(
            x=x,
            residual=float(res_cols.max()),
            n_ops=n_ops,
            cost_iterations=n_ops / max(self.problem.n_edges, 1),
            n_rounds=rounds,
            converged=bool((res_cols <= tol).all()),
            method="frontier:segment_sum",
            trace=[RoundReport(rounds, float(res_cols.max()), n_ops)],
            wall_time_s=time.perf_counter() - t0,
            extras={"batch": b_matrix.shape[1],
                    "bucket": stats["bucket"],
                    "padding_waste": stats["padding_waste"],
                    "ops_per_column": ops.tolist(),
                    "residual_per_column": res_cols.tolist()},
        )
