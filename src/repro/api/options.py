"""One ``SolverOptions`` shape for every backend (DESIGN.md §4).

Each registered backend historically grew its own config dataclass
(``SimulatorConfig``, ``EngineConfig``, bare kwargs on the reference
solvers).  ``SolverOptions`` is the single validated front-door config;
backend adapters translate the relevant subset into their native config
and *reject* — rather than silently ignore — flags the chosen backend
cannot honor.  ``validated(caps)`` is the one choke point: the CLI, the
examples, and ``repro.solve`` all pass through it.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

__all__ = ["SolverOptions", "GAMMA"]

GAMMA = 1.2  # paper default threshold decay

_POLICIES = ("slope_ema", "cost_refresh", "hysteresis")
_SIGNALS = ("residual", "edge-ops")
_PARTITIONS = ("uniform", "cb")
_MODES = ("sequential", "batch")


@dataclasses.dataclass
class SolverOptions:
    """Backend-agnostic solver knobs.

    Fields are grouped by which backends consume them; ``validated``
    raises when a field is set inconsistently (e.g. ``dynamic`` with
    ``k=1``) or targets a backend that cannot honor it (e.g. ``k`` on
    the single-process reference solvers).
    """

    # ---- shared -----------------------------------------------------------
    k: Optional[int] = None  # PIDs / devices (None = backend default)
    dynamic: bool = False  # §2.5.2 dynamic partition controller
    policy: Optional[str] = None  # balance policy name (implies dynamic)
    signal: str = "residual"  # rebalancing signal
    gamma: float = GAMMA
    max_rounds: int = 1_000_000  # frontier rounds / sweeps cap
    max_ops: int = 10**9  # sequential-backend op budget
    verbose: bool = False
    # ---- simulator --------------------------------------------------------
    partition: str = "uniform"
    mode: str = "batch"  # simulator schedule (sequential = paper-exact)
    max_steps: int = 2_000_000
    record_every: int = 1
    # ---- frontier (jnp / pallas) ------------------------------------------
    # kernel-config knobs default to None = "tuned record for this platform
    # if one exists, else the historical default" (bs=128, depth=1, thr=0);
    # an explicit value always wins over the tuned record.
    bs: Optional[int] = None  # BSR block size for frontier:pallas / engine:bsr
    buffer_depth: Optional[int] = None  # tile-pool DMA pipeline depth
    occupancy_threshold: Optional[float] = None  # defer sparse block cols
    interpret: bool = False  # force the Pallas interpreter off-TPU
    trace_every: int = 32  # rounds per trace record (streaming grain)
    # ---- engine -----------------------------------------------------------
    buckets_per_dev: int = 8
    headroom: int = 2
    max_inner: int = 8
    chunk_rounds: int = 4
    max_chunks: int = 4096
    dtype: Any = None  # engine compute dtype (None = engine default)
    # ---- balance controller -----------------------------------------------
    eta: float = 0.5
    z: int = 10

    def validated(self, caps=None, method: str = "?") -> "SolverOptions":
        """Normalize + cross-check; returns a fresh validated copy.

        ``caps`` is the target backend's
        :class:`repro.api.registry.BackendCapabilities`; when given, the
        check also rejects options the backend cannot honor (the
        historical failure mode was *silently ignoring* them — e.g.
        ``--k`` on the engine path of ``launch/solve.py``).
        """
        opt = dataclasses.replace(self)
        if opt.policy is not None:
            if opt.policy not in _POLICIES:
                raise ValueError(
                    f"unknown policy {opt.policy!r}; expected one of "
                    f"{_POLICIES}"
                )
            # a policy is only meaningful with the dynamic controller on:
            # the help text has always claimed --policy implies --dynamic
            opt.dynamic = True
        if opt.signal not in _SIGNALS:
            raise ValueError(
                f"unknown signal {opt.signal!r}; expected one of {_SIGNALS}"
            )
        if opt.partition not in _PARTITIONS:
            raise ValueError(
                f"unknown partition {opt.partition!r}; expected one of "
                f"{_PARTITIONS}"
            )
        if opt.mode not in _MODES:
            raise ValueError(
                f"unknown mode {opt.mode!r}; expected one of {_MODES}"
            )
        if opt.k is not None and opt.k < 1:
            raise ValueError(f"k must be >= 1, got {opt.k}")
        if opt.bs is not None and opt.bs < 1:
            raise ValueError(f"bs must be >= 1, got {opt.bs}")
        if opt.buffer_depth is not None and opt.buffer_depth < 1:
            raise ValueError(
                f"buffer_depth must be >= 1, got {opt.buffer_depth}"
            )
        if opt.occupancy_threshold is not None and not (
            0.0 <= opt.occupancy_threshold < 1.0
        ):
            raise ValueError(
                "occupancy_threshold must be in [0, 1), got "
                f"{opt.occupancy_threshold}"
            )
        if opt.dynamic and opt.k == 1:
            raise ValueError(
                "dynamic partition needs k >= 2 (one PID has nothing to "
                "rebalance); drop --dynamic/--policy or raise k"
            )
        if caps is not None:
            if opt.k is not None and opt.k > 1 and not caps.configurable_k:
                raise ValueError(
                    f"backend {method!r} is single-process; k={opt.k} "
                    "cannot be honored (use 'simulator' or 'engine:*')"
                )
            if opt.dynamic and not caps.supports_dynamic_partition:
                raise ValueError(
                    f"backend {method!r} has no dynamic partition; drop "
                    "--dynamic/--policy or pick 'simulator'/'engine:*'"
                )
        return opt
