"""The :class:`Problem` spec — what the solver front door consumes.

A ``Problem`` is the full statement of one D-iteration instance
``X = P·X + B`` (DESIGN.md §1/§4): the diffusion matrix ``P`` (a
:class:`repro.core.graph.CSRGraph` in out-adjacency form), the source
vector ``B``, the residual-to-error factor ``eps`` (``1 − damping`` for
PageRank systems, ``1 − rho`` in general), the stopping target, the
node-selection weights of §2.2.1, and — for the serving path — an
optional batch of extra right-hand sides (personalized PageRank
preference vectors).

Constructors:

* :meth:`Problem.pagerank` — builds ``(P, B)`` from a raw link graph
  with damping δ, optionally with a ``[N, C]`` personalization batch.
* :meth:`Problem.linear` — wraps an arbitrary spectral-radius<1 system
  (the paper's general signed case, §2).

Since the GraphStore refactor (DESIGN.md §7) a Problem *holds* the
mutable substrate: ``problem.graph`` is the :class:`repro.graph.
GraphStore` owning P, ``problem.p`` its (snapshot) CSR view.  Graph
churn flows through :meth:`with_graph` /
:meth:`repro.api.SolverSession.update_graph`.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Union

import numpy as np

from repro.core.diteration import default_weights
from repro.core.graph import CSRGraph, pagerank_system
from repro.graph import GraphStore

__all__ = ["Problem"]


def _as_store_and_csr(g) -> tuple:
    """Normalize a GraphStore | CSRGraph into (store, csr_view)."""
    if isinstance(g, GraphStore):
        return g, g.csr()
    return None, g  # store created lazily by Problem.graph


@dataclasses.dataclass(frozen=True)
class Problem:
    """One solver instance of ``X = P·X + B``.

    ``p`` is the diffusion matrix in out-adjacency CSR form
    (``P[j, i]`` = weight of edge i → j), ``b`` the primary RHS,
    ``eps`` the residual-to-error factor (stopping rule:
    ``|F|_1 <= target_error * eps``, paper §2.2/§3), ``b_batch`` an
    optional ``[N, C]`` matrix of additional RHS columns for multi-RHS
    serving (each column is an independent system over the same P).
    """

    p: CSRGraph
    b: np.ndarray
    eps: float
    target_error: float
    weights: Optional[np.ndarray] = None  # node-selection w_i (§2.2.1)
    weight_mode: str = "inv_out"
    b_batch: Optional[np.ndarray] = None  # [N, C] extra personalization RHS
    kind: str = "linear"  # "pagerank" | "linear" (provenance tag)
    damping: Optional[float] = None  # set for pagerank problems
    store: Optional[GraphStore] = None  # the mutable substrate owning p
    store_version: Optional[int] = None  # store.version this p snapshots

    def __post_init__(self):
        if self.b.shape != (self.p.n,):
            raise ValueError(
                f"b has shape {self.b.shape}, expected ({self.p.n},)"
            )
        if not (0.0 < self.eps <= 1.0):
            raise ValueError(f"eps must be in (0, 1], got {self.eps}")
        if self.target_error <= 0:
            raise ValueError(
                f"target_error must be > 0, got {self.target_error}"
            )
        if self.weights is not None and self.weights.shape != (self.p.n,):
            raise ValueError(
                f"weights has shape {self.weights.shape}, "
                f"expected ({self.p.n},)"
            )
        if self.b_batch is not None and (
            self.b_batch.ndim != 2 or self.b_batch.shape[0] != self.p.n
        ):
            raise ValueError(
                f"b_batch must be [N, C] with N={self.p.n}, "
                f"got {self.b_batch.shape}"
            )
        if self.store is not None and self.store_version is None:
            object.__setattr__(self, "store_version", self.store.version)

    # ---- derived ----------------------------------------------------------
    @property
    def graph(self) -> GraphStore:
        """The mutable :class:`GraphStore` behind ``p`` (created lazily).

        ``p`` stays the immutable CSR *snapshot* this Problem was
        stated over; the store is where deltas apply
        (:meth:`with_graph`, ``SolverSession.update_graph``).  A
        Problem whose store was mutated WITHOUT re-snapshotting is
        stale — its ``p``/``b`` no longer describe the store's matrix —
        and raises here rather than silently solving a mixed system.
        """
        if self.store is None:
            store = GraphStore.from_csr(self.p)
            object.__setattr__(self, "store", store)
            object.__setattr__(self, "store_version", store.version)
        elif self.store.version != self.store_version:
            raise ValueError(
                f"stale Problem snapshot: its GraphStore advanced to "
                f"version {self.store.version} but this Problem captured "
                f"version {self.store_version}; re-snapshot with "
                "problem.with_graph(store) (SolverSession.update_graph "
                "does this for you)"
            )
        return self.store

    @property
    def n(self) -> int:
        return self.p.n

    @property
    def n_edges(self) -> int:
        return self.p.n_edges

    @property
    def is_batched(self) -> bool:
        return self.b_batch is not None

    @property
    def tol(self) -> float:
        """The |F|_1 stopping tolerance (``target_error * eps``)."""
        return self.target_error * self.eps

    def node_weights(self) -> np.ndarray:
        """Resolved selection weights (explicit array wins over the mode)."""
        if self.weights is not None:
            return self.weights
        return default_weights(self.p, self.weight_mode)

    # ---- constructors -----------------------------------------------------
    @staticmethod
    def pagerank(
        g: Union[CSRGraph, GraphStore],
        damping: float = 0.85,
        target_error: Optional[float] = None,
        personalization: Optional[np.ndarray] = None,
        weight_mode: str = "inv_out",
    ) -> "Problem":
        """PageRank instance on link graph ``g`` (paper's flagship case).

        ``P[j, i] = damping/out_deg(i)``, ``B = (1-damping)/N``,
        ``eps = 1 - damping``; ``target_error`` defaults to the paper's
        ``1/N`` (§3.1).  ``personalization`` is an optional ``[N, C]``
        matrix of preference distributions (columns); each becomes an
        extra RHS ``(1-damping) * pref_c`` for multi-RHS serving.

        ``g`` is the raw *link* graph — a :class:`CSRGraph` or a
        :class:`repro.graph.GraphStore` (e.g. from
        ``GraphStore.from_edge_file``); the Problem's own ``store``
        holds the derived diffusion matrix P.
        """
        if isinstance(g, GraphStore):
            g = g.csr()
        p, b = pagerank_system(g, damping=damping)
        te = target_error if target_error is not None else 1.0 / g.n
        b_batch = None
        if personalization is not None:
            pref = np.asarray(personalization, dtype=np.float64)
            if pref.ndim != 2 or pref.shape[0] != g.n:
                raise ValueError(
                    f"personalization must be [N, C] with N={g.n}, "
                    f"got {pref.shape}"
                )
            b_batch = (1.0 - damping) * pref
        return Problem(
            p=p, b=b, eps=1.0 - damping, target_error=te,
            weight_mode=weight_mode, b_batch=b_batch,
            kind="pagerank", damping=damping,
        )

    @staticmethod
    def linear(
        p: Union[CSRGraph, GraphStore],
        b: np.ndarray,
        eps: Optional[float] = None,
        rho: Optional[float] = None,
        target_error: float = 1e-6,
        weights: Optional[np.ndarray] = None,
        weight_mode: str = "inv_out",
        b_batch: Optional[np.ndarray] = None,
    ) -> "Problem":
        """General system ``X = P·X + B`` with spectral radius(P) < 1.

        Provide either ``eps`` directly or ``rho`` (then
        ``eps = 1 - rho``) — the residual-to-error bound of §2.2.
        """
        if eps is None and rho is None:
            raise ValueError("provide eps or rho (eps = 1 - rho)")
        if eps is None:
            eps = 1.0 - rho
        store, p = _as_store_and_csr(p)
        return Problem(
            p=p, b=np.asarray(b, dtype=np.float64), eps=float(eps),
            target_error=float(target_error), weights=weights,
            weight_mode=weight_mode, b_batch=b_batch, kind="linear",
            store=store,
        )

    def with_b(self, b_new: np.ndarray) -> "Problem":
        """Same system, new primary RHS (the warm-start re-seed case)."""
        return dataclasses.replace(
            self, b=np.asarray(b_new, dtype=np.float64)
        )

    def with_graph(self, graph: Union[GraphStore, CSRGraph]) -> "Problem":
        """Same RHS/targets, new (or mutated) diffusion matrix.

        The delta-re-solve twin of :meth:`with_b`: after
        ``store.apply_delta(delta)``, ``problem.with_graph(store)``
        re-snapshots ``p`` from the store's patched CSR view while
        *sharing* the store (and all its incrementally patched backend
        views).  ``SolverSession.update_graph`` routes through here.
        """
        store, p = _as_store_and_csr(graph)
        if p.n != self.p.n:
            raise ValueError(
                f"with_graph cannot change N ({self.p.n} -> {p.n}); "
                "state vectors B/H/F are node-indexed"
            )
        return dataclasses.replace(
            self, p=p, store=store,
            store_version=store.version if store is not None else None)
