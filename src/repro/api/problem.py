"""The :class:`Problem` spec — what the solver front door consumes.

A ``Problem`` is the full statement of one D-iteration instance
``X = P·X + B`` (DESIGN.md §1/§4): the diffusion matrix ``P`` (a
:class:`repro.core.graph.CSRGraph` in out-adjacency form), the source
vector ``B``, the residual-to-error factor ``eps`` (``1 − damping`` for
PageRank systems, ``1 − rho`` in general), the stopping target, the
node-selection weights of §2.2.1, and — for the serving path — an
optional batch of extra right-hand sides (personalized PageRank
preference vectors).

Constructors:

* :meth:`Problem.pagerank` — builds ``(P, B)`` from a raw link graph
  with damping δ, optionally with a ``[N, C]`` personalization batch.
* :meth:`Problem.linear` — wraps an arbitrary spectral-radius<1 system
  (the paper's general signed case, §2).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.diteration import default_weights
from repro.core.graph import CSRGraph, pagerank_system

__all__ = ["Problem"]


@dataclasses.dataclass(frozen=True)
class Problem:
    """One solver instance of ``X = P·X + B``.

    ``p`` is the diffusion matrix in out-adjacency CSR form
    (``P[j, i]`` = weight of edge i → j), ``b`` the primary RHS,
    ``eps`` the residual-to-error factor (stopping rule:
    ``|F|_1 <= target_error * eps``, paper §2.2/§3), ``b_batch`` an
    optional ``[N, C]`` matrix of additional RHS columns for multi-RHS
    serving (each column is an independent system over the same P).
    """

    p: CSRGraph
    b: np.ndarray
    eps: float
    target_error: float
    weights: Optional[np.ndarray] = None  # node-selection w_i (§2.2.1)
    weight_mode: str = "inv_out"
    b_batch: Optional[np.ndarray] = None  # [N, C] extra personalization RHS
    kind: str = "linear"  # "pagerank" | "linear" (provenance tag)
    damping: Optional[float] = None  # set for pagerank problems

    def __post_init__(self):
        if self.b.shape != (self.p.n,):
            raise ValueError(
                f"b has shape {self.b.shape}, expected ({self.p.n},)"
            )
        if not (0.0 < self.eps <= 1.0):
            raise ValueError(f"eps must be in (0, 1], got {self.eps}")
        if self.target_error <= 0:
            raise ValueError(
                f"target_error must be > 0, got {self.target_error}"
            )
        if self.weights is not None and self.weights.shape != (self.p.n,):
            raise ValueError(
                f"weights has shape {self.weights.shape}, "
                f"expected ({self.p.n},)"
            )
        if self.b_batch is not None and (
            self.b_batch.ndim != 2 or self.b_batch.shape[0] != self.p.n
        ):
            raise ValueError(
                f"b_batch must be [N, C] with N={self.p.n}, "
                f"got {self.b_batch.shape}"
            )

    # ---- derived ----------------------------------------------------------
    @property
    def n(self) -> int:
        return self.p.n

    @property
    def n_edges(self) -> int:
        return self.p.n_edges

    @property
    def is_batched(self) -> bool:
        return self.b_batch is not None

    @property
    def tol(self) -> float:
        """The |F|_1 stopping tolerance (``target_error * eps``)."""
        return self.target_error * self.eps

    def node_weights(self) -> np.ndarray:
        """Resolved selection weights (explicit array wins over the mode)."""
        if self.weights is not None:
            return self.weights
        return default_weights(self.p, self.weight_mode)

    # ---- constructors -----------------------------------------------------
    @staticmethod
    def pagerank(
        g: CSRGraph,
        damping: float = 0.85,
        target_error: Optional[float] = None,
        personalization: Optional[np.ndarray] = None,
        weight_mode: str = "inv_out",
    ) -> "Problem":
        """PageRank instance on link graph ``g`` (paper's flagship case).

        ``P[j, i] = damping/out_deg(i)``, ``B = (1-damping)/N``,
        ``eps = 1 - damping``; ``target_error`` defaults to the paper's
        ``1/N`` (§3.1).  ``personalization`` is an optional ``[N, C]``
        matrix of preference distributions (columns); each becomes an
        extra RHS ``(1-damping) * pref_c`` for multi-RHS serving.
        """
        p, b = pagerank_system(g, damping=damping)
        te = target_error if target_error is not None else 1.0 / g.n
        b_batch = None
        if personalization is not None:
            pref = np.asarray(personalization, dtype=np.float64)
            if pref.ndim != 2 or pref.shape[0] != g.n:
                raise ValueError(
                    f"personalization must be [N, C] with N={g.n}, "
                    f"got {pref.shape}"
                )
            b_batch = (1.0 - damping) * pref
        return Problem(
            p=p, b=b, eps=1.0 - damping, target_error=te,
            weight_mode=weight_mode, b_batch=b_batch,
            kind="pagerank", damping=damping,
        )

    @staticmethod
    def linear(
        p: CSRGraph,
        b: np.ndarray,
        eps: Optional[float] = None,
        rho: Optional[float] = None,
        target_error: float = 1e-6,
        weights: Optional[np.ndarray] = None,
        weight_mode: str = "inv_out",
        b_batch: Optional[np.ndarray] = None,
    ) -> "Problem":
        """General system ``X = P·X + B`` with spectral radius(P) < 1.

        Provide either ``eps`` directly or ``rho`` (then
        ``eps = 1 - rho``) — the residual-to-error bound of §2.2.
        """
        if eps is None and rho is None:
            raise ValueError("provide eps or rho (eps = 1 - rho)")
        if eps is None:
            eps = 1.0 - rho
        return Problem(
            p=p, b=np.asarray(b, dtype=np.float64), eps=float(eps),
            target_error=float(target_error), weights=weights,
            weight_mode=weight_mode, b_batch=b_batch, kind="linear",
        )

    def with_b(self, b_new: np.ndarray) -> "Problem":
        """Same system, new primary RHS (the warm-start re-seed case)."""
        return dataclasses.replace(
            self, b=np.asarray(b_new, dtype=np.float64)
        )
