"""Unified solve results: :class:`SolveReport` + :class:`RoundReport`.

Every backend — reference sequential, frontier jnp/Pallas, distributed
engine, faithful simulator — returns the same report shape with the
same field semantics (DESIGN.md §4):

* ``n_ops`` is the **edge-push count** of §2.3 for every backend: one
  op per edge pushed plus one per selected dangling node
  (``max(out_degree, 1)`` per diffusion).  Backend-specific cost models
  (the simulator's exchange/reassignment charges, its wall-clock
  ``steps·PID_Speed/L`` table metric) live in ``extras`` — they remain
  available but never leak into the cross-backend fields.
* ``cost_iterations = n_ops / L`` — the paper's normalized iteration
  count, directly comparable across backends.
* ``trace`` is the per-round convergence history at each backend's
  native grain (sweeps, frontier rounds, engine chunks, simulator time
  steps), every record carrying the cumulative edge-push count.
* ``move_log`` lists executed dynamic-partition decisions
  ``(when, src, dst, units)``; empty for static/single-PID runs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Tuple

import numpy as np

__all__ = ["RoundReport", "SolveReport"]


@dataclasses.dataclass(frozen=True)
class RoundReport:
    """One progress record: the backend's native round/sweep/chunk/step."""

    round: int  # progress index in the backend's native unit
    residual: float  # |F|_1 (+ in-flight fluid where applicable)
    n_ops: int  # cumulative edge-push ops so far


@dataclasses.dataclass
class SolveReport:
    """What every backend returns from :func:`repro.api.solve`."""

    x: np.ndarray  # solution estimate H ([N] or [N, C] for batched)
    residual: float  # |F|_1 at exit (global upper bound)
    n_ops: int  # elementary edge pushes (§2.3, unified accounting)
    cost_iterations: float  # n_ops / L (paper's normalized cost)
    n_rounds: int  # native rounds/sweeps/steps executed
    converged: bool
    method: str  # registry key that produced this report
    trace: List[RoundReport] = dataclasses.field(default_factory=list)
    move_log: List[Tuple[int, int, int, int]] = dataclasses.field(
        default_factory=list
    )
    wall_time_s: float = 0.0
    extras: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if self.trace and self.n_ops != self.trace[-1].n_ops:
            # the final trace record must agree with the headline count
            raise ValueError(
                f"trace/n_ops mismatch: {self.trace[-1].n_ops} != "
                f"{self.n_ops}"
            )

    def summary(self) -> str:
        return (
            f"[{self.method}] converged={self.converged} "
            f"residual={self.residual:.3e} "
            f"cost={self.cost_iterations:.2f} matvec-equivalents "
            f"({self.n_ops} edge pushes, {self.n_rounds} rounds, "
            f"{len(self.move_log)} moves, {self.wall_time_s:.2f}s)"
        )
