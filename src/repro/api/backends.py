"""Registered backend adapters: one per solver tier (DESIGN.md §4).

Each adapter translates the validated :class:`SolverOptions` subset into
its tier's native config and returns the unified :class:`SolveReport`
with the cross-backend field semantics (edge-push ``n_ops``,
``cost_iterations = n_ops/L``, per-round ``trace``, ``move_log``).

Auto-dispatch priorities encode the measured ordering of the repo's
perf trajectory (BENCH_kernels.json / BENCH_engine.json): the per-edge
frontier path wins small-N CPU runs, the BSR engine path wins at scale
(N ≥ 2^17), the fused Pallas frontier kernel wins on TPU, and the
simulator/sequential tiers are fidelity — not speed — choices.
"""
from __future__ import annotations

import time

import numpy as np

from .options import SolverOptions
from .problem import Problem
from .registry import BackendCapabilities, register_backend
from .report import RoundReport, SolveReport
from .session import SolverSession

_TRACE_CAP = 512  # max records kept from dense per-sweep/step histories


def _downsample(records, cap: int = _TRACE_CAP):
    if len(records) <= cap:
        return list(records)
    stride = -(-len(records) // cap)
    kept = list(records[::stride])
    if records and (not kept or kept[-1] is not records[-1]):
        kept.append(records[-1])
    return kept


def _reject_batch(problem: Problem, method: str) -> None:
    if problem.is_batched:
        raise ValueError(
            f"backend {method!r} has no multi-RHS path; use a frontier "
            "backend (or method='auto') for batched problems"
        )


# --------------------------------------------------------------------------- #
# sequential — paper-exact numpy sweep
# --------------------------------------------------------------------------- #
@register_backend(
    "sequential",
    BackendCapabilities(auto_priority=2),
)
def _solve_sequential(problem: Problem, options: SolverOptions
                      ) -> SolveReport:
    from repro.core.diteration import run_sequential

    _reject_batch(problem, "sequential")
    sweeps: list = []
    t0 = time.perf_counter()
    res = run_sequential(
        problem.p, problem.b,
        target_error=problem.target_error, eps=problem.eps,
        weights=problem.weights if problem.weights is not None
        else problem.node_weights(),
        gamma=options.gamma, max_ops=options.max_ops, trace=sweeps,
    )
    trace = [RoundReport(s, r, o) for s, r, o in _downsample(sweeps)]
    if not trace or trace[-1].n_ops != res.n_ops:
        trace.append(RoundReport(res.n_sweeps, res.residual, res.n_ops))
    return SolveReport(
        x=res.x,
        residual=res.residual,
        n_ops=res.n_ops,
        cost_iterations=res.cost_iterations,
        n_rounds=res.n_sweeps,
        converged=res.residual <= problem.tol,
        method="sequential",
        trace=trace,
        wall_time_s=time.perf_counter() - t0,
        extras={"n_diffusions": res.n_diffusions},
    )


# --------------------------------------------------------------------------- #
# frontier + engine — session-driven (streaming/warm-start machinery)
# --------------------------------------------------------------------------- #
def _session_solve(problem: Problem, options: SolverOptions,
                   method: str) -> SolveReport:
    session = SolverSession(problem, method=method, options=options)
    if problem.is_batched:
        return session.solve_batch(problem.b_batch)
    return session.solve()


@register_backend(
    "frontier:segment_sum",
    BackendCapabilities(
        supports_batch=True, supports_warm_start=True, auto_priority=10,
    ),
)
def _solve_frontier_segment_sum(problem, options):
    return _session_solve(problem, options, "frontier:segment_sum")


@register_backend(
    "frontier:pallas",
    BackendCapabilities(
        # batch serving is frontier:segment_sum-native (the fused kernel
        # has no per-column threshold operand) — claiming batch here
        # would silently solve via the per-edge path after paying the
        # BSR tiling build
        supports_warm_start=True,
        device_kinds=("tpu",),  # runs anywhere, but auto only on TPU —
        # unless a tuned record proves the BSR path out on this platform
        auto_priority=40,
        tune_key="frontier_round_bsr",
    ),
)
def _solve_frontier_pallas(problem, options):
    _reject_batch(problem, "frontier:pallas")
    return _session_solve(problem, options, "frontier:pallas")


@register_backend(
    "engine:chunk",
    BackendCapabilities(
        supports_dynamic_partition=True, supports_warm_start=True,
        configurable_k=True, auto_priority=5,
    ),
)
def _solve_engine_chunk(problem, options):
    _reject_batch(problem, "engine:chunk")
    return _session_solve(problem, options, "engine:chunk")


@register_backend(
    "engine:bsr",
    BackendCapabilities(
        supports_dynamic_partition=True, supports_warm_start=True,
        configurable_k=True, min_auto_n=1 << 17, auto_priority=30,
        tune_key="bsr_gather_spmm",
    ),
)
def _solve_engine_bsr(problem, options):
    _reject_batch(problem, "engine:bsr")
    return _session_solve(problem, options, "engine:bsr")


# --------------------------------------------------------------------------- #
# simulator — faithful K-PID time-stepped reference (§2.2–2.5)
# --------------------------------------------------------------------------- #
@register_backend(
    "simulator",
    BackendCapabilities(
        supports_dynamic_partition=True, configurable_k=True,
        auto_priority=1,
    ),
)
def _solve_simulator(problem: Problem, options: SolverOptions
                     ) -> SolveReport:
    from repro.core.simulator import DistributedSimulator, SimulatorConfig

    _reject_batch(problem, "simulator")
    if problem.weights is not None:
        raise ValueError(
            "the simulator selects weights by mode; set "
            "Problem.weight_mode instead of an explicit weights array"
        )
    cfg = SimulatorConfig(
        k=options.k or 8,
        target_error=problem.target_error,
        eps=problem.eps,
        partition=options.partition,
        dynamic=options.dynamic,
        policy=options.policy,
        signal=options.signal,
        mode=options.mode,
        weight_mode=problem.weight_mode,
        gamma=options.gamma,
        eta=options.eta,
        z=options.z,
        max_steps=options.max_steps,
        record_every=options.record_every,
    )
    t0 = time.perf_counter()
    res = DistributedSimulator(problem.p, problem.b, cfg).run()
    records = list(zip(res.hist_steps.tolist(),
                       res.hist_residual.tolist(),
                       res.hist_edge_ops.tolist()))
    trace = [RoundReport(s, r, o) for s, r, o in _downsample(records)]
    if not trace or trace[-1].n_ops != res.n_edge_ops:
        trace.append(
            RoundReport(res.n_steps, res.residual, res.n_edge_ops))
    return SolveReport(
        x=res.h,
        residual=res.residual,
        n_ops=res.n_edge_ops,
        cost_iterations=res.n_edge_ops / max(problem.n_edges, 1),
        n_rounds=res.n_steps,
        converged=res.converged,
        method="simulator",
        trace=trace,
        move_log=list(res.move_log),
        wall_time_s=time.perf_counter() - t0,
        extras={
            # the simulator's own §2.3/§2.4 wall-clock cost model stays
            # available here (charged ops incl. exchange/reassignment,
            # the paper's steps·PID_Speed/L table metric):
            "cost_steps_iterations": res.cost_iterations,
            "count_active": res.count_active,
            "count_idle": res.count_idle,
            "n_exchanges": res.n_exchanges,
            "n_moves": res.n_moves,
            "hist_sizes": res.hist_sizes,
            "hist_rs": res.hist_rs,
        },
    )
