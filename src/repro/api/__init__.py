"""The solver front door (DESIGN.md §4).

One public surface spanning every tier of the three-tier architecture:

>>> import repro
>>> problem = repro.Problem.pagerank(g, damping=0.85)
>>> report = repro.solve(problem)                      # method="auto"
>>> report = repro.solve(problem, method="simulator", k=16, dynamic=True)
>>> session = repro.SolverSession(problem, "frontier:segment_sum")
>>> session.solve(); session.warm_start(b2); session.solve()

The backend registry (``list_backends()``) maps stable string keys to
solver tiers with capability records; ``solve(..., method="auto")``
picks the fastest eligible backend.  Everything returns the unified
:class:`SolveReport`; warm-start and multi-RHS serving live on
:class:`SolverSession`.

The historical entrypoints (``repro.core.diteration.solve_sequential``,
``solve_frontier_jnp``) are deprecated shims over this registry;
``DistributedSimulator`` / ``DistributedEngine`` remain the engine-room
implementations behind the ``simulator`` / ``engine:*`` keys.
"""
from repro.graph import GraphDelta, GraphStore

from .options import SolverOptions
from .problem import Problem
from .registry import (
    BackendCapabilities,
    get_backend,
    list_backends,
    register_backend,
    solve,
)
from .report import RoundReport, SolveReport
from .session import SolverSession

__all__ = [
    "BackendCapabilities",
    "GraphDelta",
    "GraphStore",
    "Problem",
    "RoundReport",
    "SolveReport",
    "SolverOptions",
    "SolverSession",
    "get_backend",
    "list_backends",
    "register_backend",
    "solve",
]
