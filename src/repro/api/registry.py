"""String-keyed backend registry + the ``solve()`` front door.

Every solver tier registers under a stable key with a capability
record; ``solve(problem, method="auto")`` picks the fastest *eligible*
backend for the problem/options/hardware at hand (DESIGN.md §4).

Registered keys (see :mod:`repro.api.backends`):

====================  =====================================================
``sequential``        paper-exact numpy sweep (ground-truth schedule)
``frontier:segment_sum``  frontier-batched jnp, per-edge segment-sum push
``frontier:pallas``   frontier-batched over the fused BSR Pallas kernel
``engine:chunk``      shard_map engine, per-edge diffusion backend
``engine:bsr``        shard_map engine, BSR tile diffusion backend
``simulator``         faithful time-stepped K-PID simulator (§2.2–2.5)
====================  =====================================================
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

from .options import SolverOptions
from .problem import Problem
from .report import SolveReport

__all__ = [
    "BackendCapabilities",
    "register_backend",
    "get_backend",
    "list_backends",
    "solve",
]


@dataclasses.dataclass(frozen=True)
class BackendCapabilities:
    """What a backend can honor — consulted by validation and auto-dispatch.

    ``device_kinds`` lists the JAX platforms the backend is *at home*
    on; it still runs elsewhere (all backends are portable) but auto
    dispatch prefers native ground.  ``min_auto_n`` gates auto-dispatch
    to sizes where the backend's fixed costs amortize.

    ``tune_key`` names the autotunable kernel behind the backend (a key
    of :data:`repro.kernels.tune.KERNELS`).  When a persisted tuned
    record exists for (tune_key, current platform), auto-dispatch treats
    the backend as native there and ranks it by the record's *measured*
    throughput — measurement beats the hardcoded ``auto_priority``
    (DESIGN.md §9).  Without a record the historical priority ordering
    applies unchanged.
    """

    supports_dynamic_partition: bool = False
    supports_batch: bool = False  # multi-RHS solve_batch via vmap
    supports_warm_start: bool = False  # SolverSession-resumable state
    configurable_k: bool = False  # honors SolverOptions.k > 1
    device_kinds: Tuple[str, ...] = ("cpu", "gpu", "tpu")
    min_auto_n: int = 0
    auto_priority: int = 0  # higher wins among eligible backends
    tune_key: Optional[str] = None  # autotuned kernel behind this backend


@dataclasses.dataclass(frozen=True)
class _Backend:
    name: str
    fn: Callable[[Problem, SolverOptions], SolveReport]
    caps: BackendCapabilities


_REGISTRY: Dict[str, _Backend] = {}


def register_backend(name: str, caps: BackendCapabilities):
    """Decorator: register ``fn(problem, options) -> SolveReport``."""

    def deco(fn):
        if name in _REGISTRY:
            raise ValueError(f"backend {name!r} already registered")
        _REGISTRY[name] = _Backend(name=name, fn=fn, caps=caps)
        return fn

    return deco


def _ensure_loaded() -> None:
    if not _REGISTRY:  # adapters self-register on first import
        from . import backends  # noqa: F401


def get_backend(name: str) -> _Backend:
    _ensure_loaded()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown solver backend {name!r}; registered: "
            f"{sorted(_REGISTRY)}"
        ) from None


def list_backends() -> Dict[str, BackendCapabilities]:
    """Registry snapshot: key -> capabilities (the capability matrix)."""
    _ensure_loaded()
    return {k: b.caps for k, b in sorted(_REGISTRY.items())}


def _tuned_throughput(caps: BackendCapabilities,
                      platform: str) -> Optional[float]:
    """Measured GFLOP/s from the backend's tuned record, if one exists."""
    if caps.tune_key is None:
        return None
    from repro.kernels.tune import best_config

    rec = best_config(caps.tune_key, platform)
    return None if rec is None else rec.throughput_gflops


def _auto_select(problem: Problem, options: SolverOptions) -> str:
    """Pick the fastest eligible backend (documented, deterministic).

    Eligibility: honors the requested k/dynamic/batch; native to the
    current JAX platform; problem size above the backend's auto floor.

    Ranking is measurement-first: a backend whose ``tune_key`` has a
    persisted tuned record for this platform counts as native here and
    ranks by the record's measured throughput; every measured backend
    outranks every unmeasured one, and unmeasured backends keep the
    historical ``auto_priority`` ordering (which encodes the committed
    BENCH_kernels.json / BENCH_engine.json results).  With no records on
    disk — the default state — dispatch is exactly the old priority rule.
    """
    import jax

    platform = jax.default_backend()
    _ensure_loaded()
    want_k = options.k is not None and options.k > 1
    if problem.is_batched and want_k:
        raise ValueError(
            "batched (multi-RHS) problems run on the single-process "
            "vmapped frontier path; k>1 cannot be honored — drop k or "
            "solve the columns as separate problems"
        )
    best: Optional[_Backend] = None
    best_key: Tuple[float, float] = (-1.0, -1.0)
    for be in _REGISTRY.values():
        caps = be.caps
        measured = _tuned_throughput(caps, platform)
        if platform not in caps.device_kinds and measured is None:
            continue
        if problem.n < caps.min_auto_n:
            continue
        if problem.is_batched and not caps.supports_batch:
            continue
        if want_k and not caps.configurable_k:
            continue
        if (options.dynamic or options.policy) and (
            not caps.supports_dynamic_partition
        ):
            continue
        if want_k and caps.configurable_k:
            # the engine needs k physical devices; fall back to the
            # simulator when the host cannot provide them
            if be.name.startswith("engine:") and (
                options.k > len(jax.devices())
            ):
                continue
        key = ((1.0, measured) if measured is not None
               else (0.0, float(caps.auto_priority)))
        if best is None or key > best_key:
            best, best_key = be, key
    if best is None:  # want_k on a 1-device host with engines excluded
        return "simulator" if want_k else "frontier:segment_sum"
    return best.name


def solve(
    problem: Problem,
    method: str = "auto",
    options: Optional[SolverOptions] = None,
    **kw,
) -> SolveReport:
    """The single solver front door: ``repro.solve(problem)``.

    ``method`` is a registry key or ``"auto"``; extra keyword arguments
    are folded into ``options`` (``solve(p, k=8, dynamic=True)``).
    Options are validated against the chosen backend's capabilities —
    inconsistent flags raise instead of being silently dropped.
    """
    opts = options if options is not None else SolverOptions()
    if kw:
        opts = dataclasses.replace(opts, **kw)
    if method in ("auto", None):
        # normalize first so auto-selection sees policy => dynamic
        opts = opts.validated()
        method = _auto_select(problem, opts)
    be = get_backend(method)
    opts = opts.validated(be.caps, method)
    return be.fn(problem, opts)
