"""repro — D-iteration dynamic-partition system (see DESIGN.md).

The public solver surface lives in :mod:`repro.api` and is re-exported
lazily here so ``import repro`` stays lightweight:

>>> import repro
>>> report = repro.solve(repro.Problem.pagerank(g))
"""
_API_NAMES = (
    "BackendCapabilities",
    "GraphDelta",
    "GraphStore",
    "Problem",
    "RoundReport",
    "SolveReport",
    "SolverOptions",
    "SolverSession",
    "get_backend",
    "list_backends",
    "register_backend",
    "solve",
)

__all__ = list(_API_NAMES)


def __getattr__(name):
    if name in _API_NAMES:
        from repro import api

        return getattr(api, name)
    raise AttributeError(f"module 'repro' has no attribute {name!r}")


def __dir__():
    return sorted(list(globals()) + list(_API_NAMES))
