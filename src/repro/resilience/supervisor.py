"""The supervisor: one self-healing serving loop per session.

:class:`SupervisedSession` wraps a :class:`repro.SolverSession` and
upgrades its request-scoped guarantees to service-scoped ones
(DESIGN.md §10):

* **fault absorption** — transient faults (:class:`~repro.chaos.
  ChaosKill`, device loss, torn restores) trigger
  restore-newest-valid + exponential-backoff retry; because the
  supervisor checkpoints at *every* request boundary, a retried
  request replays the identical trajectory the undisturbed stream
  would have taken (determinism is the exactness mechanism, not tight
  tolerances);
* **escalation** — ``trip_after`` consecutive failures trip the
  :class:`CircuitBreaker`: restore, then *rescale to the surviving
  width* (engine backends), then resume;
* **graceful degradation** — every served request feeds a ``latency``
  :class:`~repro.balance.LoadSignal` (virtual clock: §2.3 edge pushes
  over ``op_rate``, inflated by live stragglers) to the
  :class:`DegradationLadder`; overload walks down to cheaper serving
  targets and recovery walks back up, one rung per decision;
* **deadlines / budgets** — a request that exhausts its op budget or
  deadline is served *degraded* (current H, reported residual), never
  dropped;
* **admission** — poison requests (NaN/negative/zero-mass B, stale or
  malformed graph deltas) are rejected per request into the
  :class:`Quarantine`; the session never sees them.

Everything observable lands in the :class:`EventLog` — the soak
harness asserts recovery and ladder behavior from the log alone.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional

import numpy as np

from repro.balance import LoadSignal

from .admission import (Quarantine, RequestRejected, validate_graph_update,
                        validate_rhs)
from .degrade import DegradationLadder
from .events import EventLog
from .retry import CircuitBreaker, RetryPolicy

__all__ = ["RequestOutcome", "SupervisedSession"]

# faults worth retrying: a machine died, jax lost a device, a restore
# tore.  ChaosKill subclasses RuntimeError; poison and programming
# errors (RequestRejected, TypeError, ValueError) are NOT here — they
# fail fast instead of burning the retry budget
_TRANSIENT = (RuntimeError, OSError)


@dataclasses.dataclass
class RequestOutcome:
    """What happened to one request, for the caller and the bench."""

    request_id: object
    kind: str                       # "rank" | "update"
    ok: bool
    rejected: bool = False
    reject_reason: Optional[str] = None
    deferred: bool = False
    x: Optional[np.ndarray] = None
    residual: float = float("nan")
    converged: bool = False
    degraded: bool = False          # served off-nominal (rung > 0 or cut)
    budget_exhausted: bool = False
    deadline_exceeded: bool = False
    rung: str = "nominal"
    ops: int = 0
    rounds: int = 0
    attempts: int = 1
    restores: int = 0
    latency_s: float = 0.0          # virtual (deterministic) latency
    wall_s: float = 0.0


class SupervisedSession:
    """Supervised serving over one solver session (see module doc).

    ``op_rate`` (edge pushes / virtual second) drives the deterministic
    latency clock: service time = attempt pushes / op_rate × the worst
    live straggler factor, plus any backoff the request waited through.
    ``sleep`` is injectable so soaks never wall-sleep through backoff.
    """

    def __init__(self, problem, method: str = "engine:chunk",
                 options=None, *, ckpt_dir: str,
                 retry: Optional[RetryPolicy] = None,
                 breaker: Optional[CircuitBreaker] = None,
                 ladder: Optional[DegradationLadder] = None,
                 deadline_s: Optional[float] = None,
                 op_budget: Optional[int] = None,
                 op_rate: float = 2e6, queue_cap: int = 8,
                 defer_cap: int = 8, keep_checkpoints: int = 4,
                 log: Optional[EventLog] = None,
                 sleep: Optional[Callable[[float], None]] = None):
        from repro.api.session import SolverSession

        self.ckpt_dir = ckpt_dir
        self.retry = retry if retry is not None else RetryPolicy()
        self.breaker = breaker if breaker is not None else CircuitBreaker()
        self.ladder = ladder if ladder is not None else DegradationLadder()
        self.deadline_s = deadline_s
        self.op_budget = op_budget
        self.op_rate = float(op_rate)
        self.queue_cap = queue_cap
        self.defer_cap = defer_cap
        self.keep_checkpoints = keep_checkpoints
        self.vt = 0.0  # virtual clock (seconds)
        self.log = log if log is not None else EventLog(
            clock=lambda: self.vt)
        self._sleep = sleep if sleep is not None else time.sleep
        self.quarantine = Quarantine()
        self.session = SolverSession(problem, method=method,
                                     options=options)
        self.method = method
        self.options = self.session.options
        self._deferred: List = []       # queued GraphDeltas, FIFO
        self._slowdowns: dict = {}      # pid -> live straggler factor
        self.total_ops = 0              # §2.3, across all attempts
        self.wasted_ops = 0             # died un-checkpointed
        self.restores = 0
        self.served = 0
        # recovery base: a fault during the very first request needs a
        # valid step to restore (the seeded state IS one)
        self.session.checkpoint(self.ckpt_dir)
        self._prune_checkpoints()
        self.log.record("start", method=method, n=problem.n,
                        n_edges=problem.n_edges)

    # ------------------------------------------------------------------ #
    # serving
    # ------------------------------------------------------------------ #
    def serve_rank(self, b, request_id=None, chaos=None,
                   queue_depth: int = 0,
                   want_x: bool = True) -> RequestOutcome:
        """Serve one ranking request end to end (see module doc).

        ``chaos`` is a :class:`~repro.chaos.SessionInjector` scheduled
        by the caller's trace; the SAME injector is passed to every
        retry attempt, so a kill fires once at its plan position and
        the retry resumes past it."""
        t0 = time.perf_counter()
        try:
            b = validate_rhs(b, self.session.problem.n)
        except RequestRejected as e:
            return self._reject(request_id, "rank", e, t0)
        backoff_s = 0.0
        req_ops = 0
        restores = 0
        attempt = 1
        while True:
            ops0 = self.session.lifetime_ops
            try:
                self.session.warm_start(b)
                applied = self.ladder.apply(self.session)
                if applied:
                    self.log.record("ladder_override", **applied)
                rung = self.ladder.rung
                scale = rung.target_scale
                until = (None if scale == 1.0
                         else self.session.problem.target_error * scale)
                cut = self._drain(until, rung.round_cap, chaos, ops0)
                break
            except _TRANSIENT as e:
                attempt_ops = self.session.lifetime_ops - ops0
                req_ops += attempt_ops
                self.total_ops += attempt_ops
                self.log.record("fault", request_id=request_id,
                                attempt=attempt, error=type(e).__name__,
                                detail=str(e)[:120])
                tripped = self.breaker.record_failure()
                if attempt >= self.retry.max_attempts:
                    self.log.record("request_failed",
                                    request_id=request_id,
                                    attempts=attempt)
                    return RequestOutcome(
                        request_id=request_id, kind="rank", ok=False,
                        attempts=attempt, restores=restores,
                        ops=req_ops, wall_s=time.perf_counter() - t0)
                restores += 1
                self._recover(escalate=tripped)
                delay = self.retry.delay_s(attempt)
                self._sleep(delay)
                self.vt += delay
                backoff_s += delay
                attempt += 1
        attempt_ops = self.session.lifetime_ops - ops0
        req_ops += attempt_ops
        self.total_ops += attempt_ops
        self.breaker.record_success()
        self.session.checkpoint(self.ckpt_dir)
        self._prune_checkpoints()
        tol = (until if until is not None
               else self.session.problem.target_error
               ) * self.session.problem.eps
        residual = self.session.residual
        converged = residual <= tol
        service_s = (attempt_ops / self.op_rate) * self._straggler_factor()
        latency_s = service_s + backoff_s
        self.vt += service_s
        self.served += 1
        rung_name = self.ladder.rung.name
        degraded = (self.ladder.engaged or cut["budget"] or cut["deadline"]
                    or not converged)
        self.log.record("request_served", request_id=request_id,
                        rung=rung_name, ops=attempt_ops,
                        attempts=attempt, restores=restores,
                        latency_s=round(latency_s, 6),
                        converged=converged, degraded=degraded)
        out = RequestOutcome(
            request_id=request_id, kind="rank", ok=True,
            x=self.session.x if want_x else None,
            residual=residual, converged=converged, degraded=degraded,
            budget_exhausted=cut["budget"],
            deadline_exceeded=cut["deadline"], rung=rung_name,
            ops=req_ops, rounds=self.session.n_rounds, attempts=attempt,
            restores=restores, latency_s=latency_s,
            wall_s=time.perf_counter() - t0)
        self._observe_pressure(latency_s, queue_depth)
        return out

    def serve_update(self, delta, store_version: Optional[int] = None,
                     request_id=None) -> RequestOutcome:
        """Serve one graph-update request: admit, then apply or defer.

        Under a ``defer_updates`` rung the delta queues (the stream
        serves a *stale but real* graph version — exact against the
        effective schedule); the queue flushes on recovery or when it
        exceeds ``defer_cap`` (bounded staleness)."""
        t0 = time.perf_counter()
        deferring = self.ladder.rung.defer_updates
        try:
            # membership is only decidable when nothing is queued ahead
            # of this delta (see admission.validate_graph_update)
            validate_graph_update(
                self.session.problem.graph, delta,
                store_version=store_version,
                queued=len(self._deferred),
                check_membership=not (deferring or self._deferred))
        except RequestRejected as e:
            return self._reject(request_id, "update", e, t0)
        if deferring:
            self._deferred.append(delta)
            self.log.record("update_deferred", request_id=request_id,
                            queued=len(self._deferred))
            if len(self._deferred) > self.defer_cap:
                self.flush_deferred(reason="defer-cap")
            return RequestOutcome(
                request_id=request_id, kind="update", ok=True,
                deferred=True, rung=self.ladder.rung.name,
                wall_s=time.perf_counter() - t0)
        ops = self._apply_update(delta, request_id)
        return RequestOutcome(
            request_id=request_id, kind="update", ok=True, ops=ops,
            rung=self.ladder.rung.name, wall_s=time.perf_counter() - t0)

    def flush_deferred(self, reason: str = "recovered") -> int:
        """Apply every queued delta in arrival order; returns count."""
        n = len(self._deferred)
        if n == 0:
            return 0
        self.log.record("update_flush", count=n, reason=reason)
        while self._deferred:
            delta = self._deferred.pop(0)
            self._apply_update(delta, request_id=None)
        return n

    @property
    def deferred_updates(self) -> int:
        return len(self._deferred)

    # ------------------------------------------------------------------ #
    # elasticity / chaos surface
    # ------------------------------------------------------------------ #
    def rescale(self, k_new: int) -> None:
        """Planned elastic event (capacity change), checkpointed."""
        drains = self.session.rescale(k_new)
        self.session.checkpoint(self.ckpt_dir)
        self._prune_checkpoints()
        self.log.record("rescale", k_new=k_new, drains=len(drains),
                        planned=True)

    def note_straggler(self, pid: int, slowdown: float) -> None:
        """A device slowed down (or healed at ``slowdown=1.0``): feeds
        both the engine's balance signal and the virtual clock."""
        if slowdown <= 1.0:
            self._slowdowns.pop(pid, None)
        else:
            self._slowdowns[pid] = float(slowdown)
        note = getattr(self.session._driver, "note_straggler", None)
        if note is not None:
            note(pid, slowdown)
        self.log.record("straggler", pid=pid, slowdown=slowdown)

    # ------------------------------------------------------------------ #
    # internals
    # ------------------------------------------------------------------ #
    def _drain(self, until, round_cap, chaos, ops0) -> dict:
        """Grain loop with budget/deadline cuts: a request that runs
        out of budget is SERVED with whatever H holds (degraded),
        never dropped."""
        cut = {"budget": False, "deadline": False}
        for _rep in self.session.run(until=until, max_rounds=round_cap,
                                     chaos=chaos):
            attempt_ops = self.session.lifetime_ops - ops0
            if (self.op_budget is not None
                    and attempt_ops >= self.op_budget):
                cut["budget"] = True
                break
            if (self.deadline_s is not None
                    and (attempt_ops / self.op_rate)
                    * self._straggler_factor() >= self.deadline_s):
                cut["deadline"] = True
                break
        return cut

    def _apply_update(self, delta, request_id) -> int:
        """Apply + drain one delta, fault-tolerantly.

        The checkpoint right after ``update_graph`` is load-bearing: a
        fault during the drain must restore the *post-update* state
        (the store already advanced, so pre-update checkpoints are
        version-stale and would force a cold restart); re-draining
        from the restored undrained state replays the identical
        schedule — exactness via determinism, same as serve_rank.
        Draining to the nominal target here keeps the served state
        converged, matching a reference that replays
        ``update_graph`` + ``solve`` at each effective apply point."""
        req_ops = 0
        applied = False
        attempt = 1
        while True:
            ops0 = self.session.lifetime_ops
            try:
                if not applied:
                    try:
                        self.session.update_graph(delta)
                    except (TypeError, ValueError) as e:
                        # not transient: the delta conflicts with the
                        # state it finally applies to (possible for a
                        # deferred delta admitted without a membership
                        # check).  update_graph rolled back — quarantine
                        # the delta and keep serving.
                        self.quarantine.record(request_id,
                                               "conflict-at-apply")
                        self.log.record("update_conflict",
                                        request_id=request_id,
                                        detail=str(e)[:120])
                        return 0
                    applied = True
                    self.session.checkpoint(self.ckpt_dir)
                    self._prune_checkpoints()
                for _rep in self.session.run():
                    pass
                break
            except _TRANSIENT as e:
                req_ops += self.session.lifetime_ops - ops0
                self.total_ops += self.session.lifetime_ops - ops0
                self.log.record("fault", request_id=request_id,
                                attempt=attempt, error=type(e).__name__,
                                detail=str(e)[:120])
                tripped = self.breaker.record_failure()
                if attempt >= self.retry.max_attempts:
                    raise
                self._recover(escalate=tripped)
                delay = self.retry.delay_s(attempt)
                self._sleep(delay)
                self.vt += delay
                attempt += 1
        ops = req_ops + self.session.lifetime_ops - ops0
        self.total_ops += self.session.lifetime_ops - ops0
        self.breaker.record_success()
        self.session.checkpoint(self.ckpt_dir)
        self._prune_checkpoints()
        self.log.record("update_applied", request_id=request_id,
                        n_changes=delta.n_changes,
                        store_version=self.session.problem.store_version)
        return ops

    def _recover(self, escalate: bool) -> None:
        """Restore-newest-valid; on escalation also shrink the pid
        axis to the surviving width (the breaker's theory: a device is
        sick, stop scheduling onto it)."""
        from repro.api.session import SolverSession

        lost = self.session.lifetime_ops
        k_before = getattr(getattr(self.session, "_driver", None),
                           "cfg", None)
        k_before = getattr(k_before, "k", 1)
        try:
            self.session = SolverSession.restore(
                self.ckpt_dir, self.session.problem, method=self.method,
                options=self.options)
            info = self.session.restored_from
            self.wasted_ops += max(
                0, lost - int(info.get("lifetime_ops") or 0))
            self.log.record("restore", step=info["step"],
                            rejected=len(info["rejected"]),
                            escalated=escalate)
        except (FileNotFoundError, ValueError) as e:
            # nothing valid on disk: production comes up cold, not dead
            self.session = SolverSession(self.session.problem,
                                         method=self.method,
                                         options=self.options)
            self.session.checkpoint(self.ckpt_dir)
            self.wasted_ops += lost
            self.log.record("cold_restart", detail=str(e)[:120],
                            escalated=escalate)
        self.restores += 1
        if escalate:
            self.log.record("breaker_trip",
                            failures=self.breaker.consecutive_failures)
            if k_before > 1 and self.method.startswith("engine"):
                drains = self.session.rescale(k_before - 1)
                self.session.checkpoint(self.ckpt_dir)
                self._prune_checkpoints()
                self.log.record("rescale", k_new=k_before - 1,
                                drains=len(drains), planned=False)
            self.breaker.reset()

    def _straggler_factor(self) -> float:
        return max([1.0] + list(self._slowdowns.values()))

    def _observe_pressure(self, latency_s: float, queue_depth: int):
        """Feed the ladder; flush deferred updates once it climbs back
        to a rung that applies updates again."""
        if self.deadline_s is None:
            return
        sig = LoadSignal.from_latency(latency_s, self.deadline_s,
                                      queue_depth=queue_depth,
                                      queue_cap=self.queue_cap,
                                      step=self.served)
        was_deferring = self.ladder.rung.defer_updates
        executed = self.ladder.observe(sig)
        if executed > 0:
            self.log.record("degrade", rung=self.ladder.rung.name,
                            pressure=round(float(sig.values[0]), 4))
        elif executed < 0:
            self.log.record("recover", rung=self.ladder.rung.name,
                            pressure=round(float(sig.values[0]), 4))
        if (was_deferring and not self.ladder.rung.defer_updates
                and self._deferred):
            self.flush_deferred(reason="recovered")

    def _reject(self, request_id, kind: str, e: RequestRejected,
                t0: float) -> RequestOutcome:
        self.quarantine.record(request_id, e.reason)
        self.log.record("request_rejected", request_id=request_id,
                        request_kind=kind, reason=e.reason,
                        detail=str(e)[:120])
        return RequestOutcome(
            request_id=request_id, kind=kind, ok=False, rejected=True,
            reject_reason=e.reason, rung=self.ladder.rung.name,
            wall_s=time.perf_counter() - t0)

    def _prune_checkpoints(self) -> None:
        import os
        import shutil

        from repro.checkpoint import list_steps

        steps = list_steps(self.ckpt_dir)
        for s in steps[:-self.keep_checkpoints]:
            shutil.rmtree(
                os.path.join(self.ckpt_dir, f"step_{s:09d}"),
                ignore_errors=True)
