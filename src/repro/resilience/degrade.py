"""Graceful-degradation ladder: overload sheds quality, not requests.

Each :class:`Rung` names a cheaper serving target; the
:class:`DegradationLadder` walks between rungs on the ±1
recommendations of a :class:`~repro.balance.PressurePolicy` fed with
``latency`` :class:`~repro.balance.LoadSignal`\\ s.  The knobs and
their exactness guarantees (DESIGN.md §10):

=====================  ====================================================
knob                   guarantee when engaged
=====================  ====================================================
defer_updates          **exact** against the *effective* update schedule —
                       the graph the session serves is a real (staler)
                       version; a reference replaying the same effective
                       schedule matches bit-for-bit (§2.2 invariant holds
                       throughout)
occupancy_threshold τ  **exact at convergence** — deferring sparse block
                       columns reorders pushes (any D-iteration schedule
                       converges, §2.2) but the solve still drains to the
                       same tolerance before a response is served
target_scale           **bounded** — served error grows to at most
                       ``scale × target_error`` (the solve stops earlier
                       on the same monotone residual)
round_cap              **best-effort** — the emergency rung: serve
                       whatever H holds when the cap strikes; the
                       response's residual is reported, never hidden
=====================  ====================================================

Ladder order matters: the exact knobs engage first, accuracy-costing
knobs only under sustained overload.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

from repro.balance import LoadSignal, PressurePolicy

__all__ = ["Rung", "DEFAULT_RUNGS", "SERVE_RUNGS", "DegradationLadder"]


@dataclasses.dataclass(frozen=True)
class Rung:
    """One serving target.  ``None`` / ``1.0`` / ``False`` fields mean
    "leave the session's configured behavior alone"."""

    name: str
    target_scale: float = 1.0          # solve until scale × target_error
    occupancy_threshold: Optional[float] = None  # frontier τ override
    round_cap: Optional[int] = None    # hard per-request round budget
    defer_updates: bool = False        # queue graph deltas, serve stale

    def __post_init__(self):
        if self.target_scale < 1.0:
            raise ValueError(
                f"target_scale loosens (>= 1.0), got {self.target_scale}")
        if (self.occupancy_threshold is not None
                and not 0.0 <= self.occupancy_threshold < 1.0):
            raise ValueError(
                f"occupancy_threshold must be in [0, 1), got "
                f"{self.occupancy_threshold}")


DEFAULT_RUNGS: Tuple[Rung, ...] = (
    Rung("nominal"),
    Rung("defer-updates", defer_updates=True),
    Rung("shed-occupancy", defer_updates=True, occupancy_threshold=0.25),
    Rung("loosen-target", defer_updates=True, occupancy_threshold=0.25,
         target_scale=8.0),
    Rung("survival", defer_updates=True, occupancy_threshold=0.5,
         target_scale=32.0, round_cap=64),
)

# The continuous-batching scheduler's ladder (repro.serving): the
# vmapped batch kernel has no per-block occupancy τ to shed — its
# frontier mask is already per-lane — so the exact defer-updates knob
# engages first and overload then walks straight into the bounded /
# best-effort knobs.  round_cap counts *per-lane* rounds (a lane
# admitted late is capped on its own clock, not the batch's), and a
# capped lane retires best-effort with its residual reported — shed
# quality, never requests (DESIGN.md §11).
SERVE_RUNGS: Tuple[Rung, ...] = (
    Rung("nominal"),
    Rung("defer-updates", defer_updates=True),
    Rung("loosen-target", defer_updates=True, target_scale=4.0),
    Rung("loosen-more", defer_updates=True, target_scale=16.0),
    Rung("survival", defer_updates=True, target_scale=64.0,
         round_cap=256),
)


class DegradationLadder:
    """Current-rung state machine over a pressure controller.

    ``observe(signal)`` runs one control step and moves at most one
    rung; the supervisor reads the active rung's knobs per request and
    re-applies live driver overrides via :meth:`apply`.
    """

    def __init__(self, rungs: Tuple[Rung, ...] = DEFAULT_RUNGS,
                 policy: Optional[PressurePolicy] = None):
        if not rungs:
            raise ValueError("ladder needs at least one rung")
        self.rungs = tuple(rungs)
        self.policy = policy if policy is not None else PressurePolicy()
        self.index = 0
        # driver τ to restore when a shed-occupancy rung disengages
        self._base_tau: Optional[float] = None

    @property
    def rung(self) -> Rung:
        return self.rungs[self.index]

    @property
    def engaged(self) -> bool:
        return self.index > 0

    def until(self, base_target: float) -> float:
        return base_target * self.rung.target_scale

    def observe(self, signal: LoadSignal) -> int:
        """One control step: returns the executed rung delta
        (−1 | 0 | +1); the index saturates at the ladder ends."""
        delta = self.policy.update(signal)
        new = min(max(self.index + delta, 0), len(self.rungs) - 1)
        executed = new - self.index
        self.index = new
        return executed

    def apply(self, session) -> dict:
        """Push the active rung's live overrides into the session's
        driver.  Only the frontier drivers expose a τ knob
        (``driver.occupancy_threshold`` is read per advance); other
        knobs are consumed by the supervisor at solve time.  Returns
        the applied overrides for event logging."""
        applied: dict = {}
        d = session._driver
        if hasattr(d, "occupancy_threshold"):
            if self._base_tau is None:
                self._base_tau = float(d.occupancy_threshold)
            tau = (self.rung.occupancy_threshold
                   if self.rung.occupancy_threshold is not None
                   else self._base_tau)
            if float(d.occupancy_threshold) != tau:
                d.occupancy_threshold = tau
                applied["occupancy_threshold"] = tau
        return applied

    def reset(self) -> None:
        self.index = 0
        self.policy.reset_worker(0)

    def history_names(self) -> List[str]:
        return [r.name for r in self.rungs]
