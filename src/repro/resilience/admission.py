"""Per-request admission control: poison stays out of the session.

A serving process dies two ways: a fault kills it (the retry path's
job) or a *request* corrupts it — a NaN personalization vector seeds
NaN fluid that converges never and poisons H for every later request;
a graph delta built against a stale store version splices the wrong
edges.  Admission rejects those per request — the session state is
untouched, the stream keeps flowing — and the :class:`Quarantine`
keeps the evidence.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

__all__ = ["RequestRejected", "Quarantine", "validate_rhs",
           "validate_graph_update"]


class RequestRejected(ValueError):
    """A request that must not reach the session. ``reason`` is the
    machine-readable category, the str() the human detail."""

    def __init__(self, reason: str, detail: str):
        super().__init__(f"{reason}: {detail}")
        self.reason = reason


def validate_rhs(b, n: int, name: str = "b") -> np.ndarray:
    """Admit a personalization / teleport vector or reject it.

    The checks mirror what the §2.2 invariant needs to stay a usable
    oracle: finite entries (NaN/Inf fluid never drains), nonnegative
    mass (PageRank teleport vectors are measures), and positive total
    mass (an all-zero B makes convergence vacuous and the served
    ranking meaningless).  Returns the validated float64 copy.
    """
    arr = np.asarray(b, dtype=np.float64)
    if arr.shape != (n,):
        raise RequestRejected(
            "bad-shape", f"{name} has shape {arr.shape}, expected ({n},)")
    if not np.isfinite(arr).all():
        bad = int(np.flatnonzero(~np.isfinite(arr))[0])
        raise RequestRejected(
            "non-finite", f"{name}[{bad}] = {arr[bad]} is not finite")
    if (arr < 0.0).any():
        bad = int(np.flatnonzero(arr < 0.0)[0])
        raise RequestRejected(
            "negative-mass", f"{name}[{bad}] = {arr[bad]} < 0 — teleport "
            "vectors are nonnegative measures")
    if arr.sum() <= 0.0:
        raise RequestRejected(
            "zero-mass", f"{name} has no mass (sum = {arr.sum()})")
    return arr


def validate_graph_update(store, delta,
                          store_version: Optional[int] = None,
                          queued: int = 0,
                          check_membership: bool = True) -> None:
    """Admit a graph delta against the store's CURRENT state or reject.

    * ``store_version`` (when the client pins one) must match the
      store's *logical* version ``store.version + queued`` — ``queued``
      counts deltas admitted but deferred by the degradation ladder,
      which WILL apply (in order) before this one, so a client tracking
      the update stream is ahead of the store by exactly that many
      versions.  A mismatch means the delta was computed against a
      state the store will never pass through;
    * every endpoint must be a valid node id;
    * weights must be finite and positive (P is substochastic);
    * removed / reweighted edges must exist, added edges must NOT —
      membership is checked against the canonical CSR via the shared
      ``edge_keys`` identity, the same oracle the splice itself uses,
      so admission rejects exactly what the splice would die on.
      Membership is only decidable against the state the delta will
      actually apply to — pass ``check_membership=False`` while deltas
      are queued ahead of it (the transactional apply still validates
      at flush time; a conflict there is quarantined, not fatal).
    """
    from repro.graph.delta import GraphDelta, edge_keys

    if not isinstance(delta, GraphDelta):
        raise RequestRejected(
            "malformed-delta",
            f"expected a GraphDelta, got {type(delta).__name__}")
    if store_version is not None and store.version + queued != store_version:
        raise RequestRejected(
            "stale-store-version",
            f"delta built against store version {store_version}, store "
            f"is at {store.version} with {queued} queued")
    n = store.n
    pairs = np.concatenate([delta.added, delta.removed, delta.reweighted])
    if pairs.size and ((pairs < 0).any() or (pairs >= n).any()):
        bad = pairs[((pairs < 0) | (pairs >= n)).any(axis=1)][0]
        raise RequestRejected(
            "bad-endpoint",
            f"edge ({bad[0]}, {bad[1]}) outside node range [0, {n})")
    for w, group in ((delta.added_w, "added"),
                     (delta.reweighted_w, "reweighted")):
        if w.size and (~np.isfinite(w) | (w <= 0.0)).any():
            bad = float(w[(~np.isfinite(w) | (w <= 0.0))][0])
            raise RequestRejected(
                "bad-weight", f"{group} weight {bad} is not a finite "
                "positive value")
    if not check_membership:
        return
    src_e, dst_e, _ = store.csr().edge_list()
    sorted_keys = edge_keys(src_e, dst_e)

    def member(group_pairs: np.ndarray) -> np.ndarray:
        if group_pairs.shape[0] == 0:
            return np.zeros(0, dtype=bool)
        keys = GraphDelta._keys(group_pairs)
        pos = np.searchsorted(sorted_keys, keys)
        return ((pos < sorted_keys.size)
                & (sorted_keys[np.minimum(pos, sorted_keys.size - 1)]
                   == keys))

    for group_pairs, must_exist, group in (
            (delta.removed, True, "removed"),
            (delta.reweighted, True, "reweighted"),
            (delta.added, False, "added")):
        ok = member(group_pairs)
        if must_exist and not ok.all():
            bad = group_pairs[~ok][0]
            raise RequestRejected(
                "missing-edge", f"{group} edge ({bad[0]}, {bad[1]}) does "
                "not exist in the store")
        if not must_exist and ok.any():
            bad = group_pairs[ok][0]
            raise RequestRejected(
                "duplicate-edge", f"added edge ({bad[0]}, {bad[1]}) "
                "already exists in the store")


class Quarantine:
    """Evidence locker for rejected requests: per-reason counters plus
    the ordered (request_id, reason) trail the soak asserts against."""

    def __init__(self):
        self.by_reason: Dict[str, int] = {}
        self.entries: List[Tuple[object, str]] = []

    def record(self, request_id, reason: str) -> None:
        self.by_reason[reason] = self.by_reason.get(reason, 0) + 1
        self.entries.append((request_id, reason))

    @property
    def total(self) -> int:
        return len(self.entries)

    def to_jsonable(self) -> Dict:
        return {"total": self.total, "by_reason": dict(self.by_reason)}
