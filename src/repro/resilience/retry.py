"""Retry/backoff and circuit-breaking primitives.

Deliberately deterministic: the jitter is a hash of ``(seed, attempt)``
so a soak replay retries on the identical schedule, and the breaker is
count-based (consecutive failures / explicit reset) rather than
wall-clock-based, so tests never sleep to observe a state change.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["RetryPolicy", "CircuitBreaker"]


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Exponential backoff with deterministic jitter.

    Attempt ``a`` (1-based; attempt 1 is the first try, so the first
    *delay* precedes attempt 2) sleeps::

        min(base · mult^(a−1), max) · (1 + jitter·u),   u ~ U[−1, 1)

    where ``u`` is drawn from ``PCG64(seed ⊕ a)`` — same seed, same
    schedule, every replay.
    """

    max_attempts: int = 4
    base_delay_s: float = 0.05
    multiplier: float = 2.0
    max_delay_s: float = 2.0
    jitter: float = 0.5
    seed: int = 0

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError(f"max_attempts must be >= 1, got "
                             f"{self.max_attempts}")
        if not 0.0 <= self.jitter < 1.0:
            raise ValueError(f"jitter must be in [0, 1), got "
                             f"{self.jitter}")

    def delay_s(self, attempt: int) -> float:
        """Backoff before retry number ``attempt`` (>= 1)."""
        if attempt < 1:
            raise ValueError(f"attempt must be >= 1, got {attempt}")
        base = min(self.base_delay_s * self.multiplier ** (attempt - 1),
                   self.max_delay_s)
        u = np.random.Generator(
            np.random.PCG64(self.seed ^ (attempt * 0x9E3779B9))
        ).uniform(-1.0, 1.0)
        return float(base * (1.0 + self.jitter * u))


class CircuitBreaker:
    """Consecutive-failure trip wire for the supervisor's escalation.

    Plain retries handle isolated faults; ``trip_after`` *consecutive*
    failures mean the environment itself is sick (a device that keeps
    dying), and the supervisor escalates to its heavy recovery —
    restore + rescale to the surviving width — then calls
    :meth:`reset`.  ``record_success`` closes the streak.
    """

    def __init__(self, trip_after: int = 3):
        if trip_after < 1:
            raise ValueError(f"trip_after must be >= 1, got {trip_after}")
        self.trip_after = trip_after
        self.consecutive_failures = 0
        self.trips = 0

    @property
    def tripped(self) -> bool:
        return self.consecutive_failures >= self.trip_after

    def record_failure(self) -> bool:
        """Count one failure; returns the post-update tripped state."""
        self.consecutive_failures += 1
        return self.tripped

    def record_success(self) -> None:
        self.consecutive_failures = 0

    def reset(self) -> None:
        """Acknowledge the escalated recovery: re-close the circuit."""
        if self.tripped:
            self.trips += 1
        self.consecutive_failures = 0
