"""Seq-numbered event log: the supervisor's observable behavior.

Every decision the serving layer makes — request served, retry fired,
checkpoint restored, ladder rung engaged/relieved, poison quarantined —
lands here as one :class:`Event`.  The soak harness asserts recovery
and degradation behavior FROM this log (not from internal state), so
the log is the contract: if it is not recorded here, it did not
observably happen.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Iterator, List, Optional

__all__ = ["Event", "EventLog"]


@dataclasses.dataclass(frozen=True)
class Event:
    """One supervisor decision: ``seq`` is the total order, ``t`` the
    log's clock (wall seconds by default, virtual seconds under the
    soak's deterministic clock)."""

    seq: int
    t: float
    kind: str
    detail: Dict

    def to_jsonable(self) -> Dict:
        return {"seq": self.seq, "t": round(float(self.t), 6),
                "kind": self.kind, **self.detail}


class EventLog:
    """Append-only, seq-numbered; ``clock`` is injectable so the soak
    harness records deterministic virtual timestamps."""

    def __init__(self, clock: Optional[Callable[[], float]] = None):
        self._events: List[Event] = []
        self._seq = 0
        self._clock = clock if clock is not None else time.monotonic

    def record(self, kind: str, **detail) -> Event:
        ev = Event(seq=self._seq, t=float(self._clock()), kind=kind,
                   detail=detail)
        self._seq += 1
        self._events.append(ev)
        return ev

    def of_kind(self, *kinds: str) -> List[Event]:
        return [e for e in self._events if e.kind in kinds]

    def counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for e in self._events:
            out[e.kind] = out.get(e.kind, 0) + 1
        return out

    def to_jsonable(self) -> List[Dict]:
        return [e.to_jsonable() for e in self._events]

    def __len__(self) -> int:
        return len(self._events)

    def __iter__(self) -> Iterator[Event]:
        return iter(self._events)
