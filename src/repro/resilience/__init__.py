"""Self-healing serving layer over :class:`repro.SolverSession`.

The session layer (DESIGN.md §4/§8) gives one request-scoped guarantee:
a solve either converges or raises.  This package turns that into a
*service*-scoped guarantee — a supervised stream where every non-poison
request is served, faults are absorbed, and overload degrades quality
instead of availability (DESIGN.md §10):

* :class:`SupervisedSession` — the supervisor: per-request deadlines
  and op budgets, restore-newest + exponential-backoff retry on
  transient faults (:class:`~repro.chaos.ChaosKill`, device loss, torn
  restores), and a :class:`CircuitBreaker` that escalates repeated
  failures to checkpoint-restore-then-rescale.
* :class:`DegradationLadder` / :class:`Rung` — graceful degradation
  driven by a ``latency`` :class:`~repro.balance.LoadSignal` through
  :class:`~repro.balance.PressurePolicy`: overload sheds to cheaper
  serving targets (defer graph updates, looser frontier occupancy τ,
  looser target scale, round caps) and recovers stepwise.
* :mod:`~repro.resilience.admission` — per-request admission control:
  NaN / invariant-violating personalization vectors and stale
  ``store_version`` graph updates are rejected (and quarantined) per
  request without killing the session.
* :class:`EventLog` — seq-numbered, JSON-able record of everything the
  supervisor did (serves, retries, restores, rung moves, rejects), the
  substrate for the soak harness's assertions.
"""
from .admission import (Quarantine, RequestRejected, validate_graph_update,
                        validate_rhs)
from .degrade import DEFAULT_RUNGS, SERVE_RUNGS, DegradationLadder, Rung
from .events import Event, EventLog
from .retry import CircuitBreaker, RetryPolicy
from .supervisor import RequestOutcome, SupervisedSession

__all__ = [
    "CircuitBreaker",
    "DEFAULT_RUNGS",
    "DegradationLadder",
    "Event",
    "EventLog",
    "Quarantine",
    "RequestOutcome",
    "RequestRejected",
    "RetryPolicy",
    "Rung",
    "SERVE_RUNGS",
    "SupervisedSession",
    "validate_graph_update",
    "validate_rhs",
]
