from .loop import StragglerMonitor, TrainLoop, TrainLoopConfig  # noqa: F401
