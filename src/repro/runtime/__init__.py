from .loop import (  # noqa: F401
    ExpertLoadMonitor,
    StragglerMonitor,
    TrainLoop,
    TrainLoopConfig,
)
