"""Fault-tolerant training runtime.

* **checkpoint/restart** — CheckpointManager (atomic, async, retention) +
  auto-resume; the data pipeline is seeded-by-step so a restart replays the
  exact batch stream.
* **straggler detection** — per-step wall-time EMA; the *paper's own slope
  policy* is reused as the detector through the shared
  :mod:`repro.balance` control plane (a straggling host is exactly a
  "slow PID" whose residual-decay slope lags): feed per-host step times as
  the ``step-time`` LoadSignal, get "move load away from host i"
  MovePlans.  In this single-process container the monitor runs against an
  :class:`~repro.balance.executors.AdvisoryExecutor` (reports + tested
  against synthetic host timings); on a pod the drained plan log drives
  the bucket / expert rebalancer.
* **MoE expert rebalancing** — the same policy on per-expert routed-token
  counts (``expert-tokens`` LoadSignal; a hot expert is an overloaded
  Ω_k), fed by the transformer's expert-load tap
  (:func:`repro.models.transformer.set_expert_load_sink`).
* **elastic scaling** — the bucket-granular partition (core.distributed)
  lets K change between chunks; ``TrainLoop.on_world_change`` re-seeds
  the policy through the shared interface (``Rebalancer.reset_worker``).
* **fault injection** — ``crash_at_step`` simulates a hard kill for the
  restart tests.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.balance.executors import AdvisoryExecutor
from repro.balance.plan import MovePlan
from repro.balance.policies import Rebalancer, SlopeEMAPolicy
from repro.balance.signals import LoadSignal
from repro.checkpoint import CheckpointManager

__all__ = ["TrainLoopConfig", "TrainLoop", "StragglerMonitor",
           "ExpertLoadMonitor"]


class StragglerMonitor:
    """Slope-EMA straggler detector: the paper's policy on step times.

    A thin adapter over the shared control plane — per-host step
    durations become a ``step-time`` :class:`LoadSignal`, any
    :class:`Rebalancer` proposes, and an :class:`AdvisoryExecutor`
    records the accepted plans (``self.executor.log`` / ``drain()``).
    A host whose EMA'd log-slowness exceeds the fastest by the paper's
    50% rule is flagged and sheds load.
    """

    def __init__(self, n_hosts: int, eta: float = 0.5, z: int = 10,
                 policy: Optional[Rebalancer] = None):
        self.policy: Rebalancer = policy or SlopeEMAPolicy(
            k=n_hosts, target_error=1e-6, eta=eta, z=z, unit="device"
        )
        self.executor = AdvisoryExecutor(kind="device")
        self.n_hosts = n_hosts
        self._step = 0

    def advise(self, step_times: np.ndarray,
               load_units: Optional[np.ndarray] = None
               ) -> Optional[MovePlan]:
        """step_times: [n_hosts] seconds.  Returns the first MovePlan (or
        None); the full batch lands in ``self.executor.log``.

        The signal plays the role of the residual magnitude (bigger =
        slower PID), so step times feed in directly: the host with the
        largest EMA'd log step-time becomes i_min and sheds load.
        """
        self._step += 1
        sig = LoadSignal.from_step_times(step_times, load_units,
                                         step=self._step)
        plans = self.policy.propose(sig)
        for p in plans:
            self.executor.apply(p)
        return plans[0] if plans else None

    def reseed(self) -> None:
        """Elastic event at unchanged width: re-seed every host's slope."""
        for k in range(self.n_hosts):
            self.policy.reset_worker(k)


class ExpertLoadMonitor:
    """MoE expert rebalancer: the same policy on routed-token counts.

    Register :meth:`observe` via
    :func:`repro.models.transformer.set_expert_load_sink`; every MoE
    layer then streams its per-expert token counts here.  A hot expert
    (slope lagging on the ``expert-tokens`` signal) sheds shards.
    """

    def __init__(self, n_experts: int, eta: float = 0.5, z: int = 10,
                 shards_per_expert: int = 16,
                 policy: Optional[Rebalancer] = None):
        self.policy: Rebalancer = policy or SlopeEMAPolicy(
            k=n_experts, target_error=1e-6, eta=eta, z=z,
            unit="expert-shard"
        )
        self.executor = AdvisoryExecutor(kind="expert-shard")
        self.n_experts = n_experts
        # the movable-unit budget: each expert's capacity is split into
        # this many shards (the 10% move cap needs >= 10 units to act)
        self.shards = np.full(n_experts, shards_per_expert, dtype=np.int64)
        self._step = 0

    def observe(self, token_counts: np.ndarray) -> List[MovePlan]:
        counts = np.asarray(token_counts, np.float64)
        if counts.shape[0] != self.n_experts:
            return []
        self._step += 1
        sig = LoadSignal.from_expert_counts(
            np.maximum(counts, 1e-9), shards_per_expert=self.shards,
            step=self._step)
        plans = self.policy.propose(sig)
        accepted = []
        for p in plans:
            # keep the shard ledger truthful: a source never drops below
            # one shard, and proposals beyond it are clipped like every
            # other executor clips
            units = int(min(p.units, self.shards[p.src] - 1))
            if units < 1:
                continue
            if units != p.units:
                p = MovePlan(src=p.src, dst=p.dst, units=units,
                             kind=p.kind)
            self.executor.apply(p)
            self.shards[p.src] -= units
            self.shards[p.dst] += units
            accepted.append(p)
        return accepted


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int
    ckpt_dir: str
    ckpt_every: int = 50
    keep: int = 3
    log_every: int = 10
    crash_at_step: Optional[int] = None  # fault injection (tests)
    n_hosts: int = 1  # straggler monitor width
    moe_experts: int = 0  # >0 wires the MoE expert-load monitor


class TrainLoop:
    """Generic step loop: state = (params, opt_state); restart-safe."""

    def __init__(
        self,
        step_fn: Callable[[Any, Any, Dict], tuple],
        make_batch: Callable[[int], Dict],
        init_state: Callable[[], tuple],
        cfg: TrainLoopConfig,
    ):
        self.step_fn = step_fn
        self.make_batch = make_batch
        self.init_state = init_state
        self.cfg = cfg
        self.mgr = CheckpointManager(cfg.ckpt_dir, keep=cfg.keep)
        self.monitor = StragglerMonitor(cfg.n_hosts)
        self.expert_monitor = (ExpertLoadMonitor(cfg.moe_experts)
                               if cfg.moe_experts > 0 else None)
        self.metrics_log: list = []

    def run(self, verbose: bool = False) -> Dict[str, Any]:
        if self.expert_monitor is None:
            return self._run(verbose)
        from repro.models.transformer import set_expert_load_sink

        set_expert_load_sink(self.expert_monitor.observe)
        try:
            return self._run(verbose)
        finally:  # injected faults must not leave a stale global sink
            set_expert_load_sink(None)

    def _run(self, verbose: bool = False) -> Dict[str, Any]:
        cfg = self.cfg
        params, opt_state = self.init_state()
        start = 0
        restored = self.mgr.restore_or_none((params, opt_state))
        if restored is not None:
            (params, opt_state), step, _extra = restored
            start = step
            if verbose:
                print(f"[resume] from step {start}")
        t_hist = []
        for step in range(start, cfg.total_steps):
            if cfg.crash_at_step is not None and step == cfg.crash_at_step:
                # simulate a hard kill AFTER some checkpoints were cut
                self.mgr.wait()
                raise RuntimeError(f"injected fault at step {step}")
            batch = self.make_batch(step)
            t0 = time.perf_counter()
            params, opt_state, metrics = self.step_fn(
                params, opt_state, batch
            )
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            t_hist.append(dt)
            self.metrics_log.append(
                {k: float(np.asarray(v)) for k, v in metrics.items()}
                | {"step": step, "sec": dt}
            )
            if verbose and step % cfg.log_every == 0:
                print(f"step {step}: loss={float(metrics['loss']):.4f} "
                      f"({dt*1e3:.0f} ms)")
            if (step + 1) % cfg.ckpt_every == 0:
                self.mgr.save(step + 1, (params, opt_state),
                              extra={"loss": float(metrics["loss"])})
            # advisory straggler scan (single host: vector of one)
            self.monitor.advise(np.full(cfg.n_hosts, dt))
        self.mgr.save(cfg.total_steps, (params, opt_state))
        self.mgr.wait()
        return {
            "params": params,
            "opt_state": opt_state,
            "metrics": self.metrics_log,
            "mean_step_time": float(np.mean(t_hist)) if t_hist else 0.0,
        }

    def on_world_change(self, new_hosts: int):
        """Elastic event: re-seed the policy through the shared interface.

        Unchanged width (host replaced in place) re-seeds every slope via
        ``Rebalancer.reset_worker``; a changed width rebuilds the monitor
        at the new K (the policy state is per-worker and cannot survive a
        dimension change).
        """
        if new_hosts == self.monitor.n_hosts:
            self.monitor.reseed()
        else:
            self.monitor = StragglerMonitor(new_hosts)
