"""Fault-tolerant training runtime.

* **checkpoint/restart** — CheckpointManager (atomic, async, retention) +
  auto-resume; the data pipeline is seeded-by-step so a restart replays the
  exact batch stream.
* **straggler detection** — per-step wall-time EMA; the *paper's own slope
  controller* is reused as the detector (a straggling host is exactly a
  "slow PID" whose residual-decay slope lags): feed per-host step times as
  the progress signal, get "move load away from host i" decisions.  In this
  single-process container the monitor runs in advisory mode (reports +
  tested against synthetic host timings); on a pod it drives the bucket /
  expert rebalancer.
* **elastic scaling** — the bucket-granular partition (core.distributed)
  lets K change between chunks; ``TrainLoop.on_world_change`` re-seeds the
  controller's slopes (DynamicController.reset_pid).
* **fault injection** — ``crash_at_step`` simulates a hard kill for the
  restart tests.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core.partition import DynamicController, DynamicControllerConfig

__all__ = ["TrainLoopConfig", "TrainLoop", "StragglerMonitor"]


class StragglerMonitor:
    """Slope-EMA straggler detector (the paper's controller on step times).

    Feed per-host step durations; a host whose EMA'd log-slowness exceeds
    the fastest by the paper's 50% rule is flagged.  `advise()` returns the
    same MoveInstruction the partition controller would issue.
    """

    def __init__(self, n_hosts: int, eta: float = 0.5, z: int = 10):
        self.ctl = DynamicController(
            DynamicControllerConfig(
                k=n_hosts, target_error=1e-6, eta=eta, z=z
            )
        )
        self.n_hosts = n_hosts

    def advise(self, step_times: np.ndarray,
               load_units: Optional[np.ndarray] = None):
        """step_times: [n_hosts] seconds.  Returns MoveInstruction or None.

        The controller's input plays the role of the residual magnitude
        (bigger = slower PID), so step times feed in directly: the host
        with the largest EMA'd log step-time becomes i_min and sheds load.
        """
        times = np.maximum(np.asarray(step_times, np.float64), 1e-9)
        sizes = (load_units if load_units is not None
                 else np.full(self.n_hosts, 1 << 20))
        return self.ctl.update(times, np.asarray(sizes))


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int
    ckpt_dir: str
    ckpt_every: int = 50
    keep: int = 3
    log_every: int = 10
    crash_at_step: Optional[int] = None  # fault injection (tests)
    n_hosts: int = 1  # straggler monitor width


class TrainLoop:
    """Generic step loop: state = (params, opt_state); restart-safe."""

    def __init__(
        self,
        step_fn: Callable[[Any, Any, Dict], tuple],
        make_batch: Callable[[int], Dict],
        init_state: Callable[[], tuple],
        cfg: TrainLoopConfig,
    ):
        self.step_fn = step_fn
        self.make_batch = make_batch
        self.init_state = init_state
        self.cfg = cfg
        self.mgr = CheckpointManager(cfg.ckpt_dir, keep=cfg.keep)
        self.monitor = StragglerMonitor(cfg.n_hosts)
        self.metrics_log: list = []

    def run(self, verbose: bool = False) -> Dict[str, Any]:
        cfg = self.cfg
        params, opt_state = self.init_state()
        start = 0
        restored = self.mgr.restore_or_none((params, opt_state))
        if restored is not None:
            (params, opt_state), step, _extra = restored
            start = step
            if verbose:
                print(f"[resume] from step {start}")
        t_hist = []
        for step in range(start, cfg.total_steps):
            if cfg.crash_at_step is not None and step == cfg.crash_at_step:
                # simulate a hard kill AFTER some checkpoints were cut
                self.mgr.wait()
                raise RuntimeError(f"injected fault at step {step}")
            batch = self.make_batch(step)
            t0 = time.perf_counter()
            params, opt_state, metrics = self.step_fn(
                params, opt_state, batch
            )
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            t_hist.append(dt)
            self.metrics_log.append(
                {k: float(np.asarray(v)) for k, v in metrics.items()}
                | {"step": step, "sec": dt}
            )
            if verbose and step % cfg.log_every == 0:
                print(f"step {step}: loss={float(metrics['loss']):.4f} "
                      f"({dt*1e3:.0f} ms)")
            if (step + 1) % cfg.ckpt_every == 0:
                self.mgr.save(step + 1, (params, opt_state),
                              extra={"loss": float(metrics["loss"])})
            # advisory straggler scan (single host: vector of one)
            self.monitor.advise(np.full(cfg.n_hosts, dt))
        self.mgr.save(cfg.total_steps, (params, opt_state))
        self.mgr.wait()
        return {
            "params": params,
            "opt_state": opt_state,
            "metrics": self.metrics_log,
            "mean_step_time": float(np.mean(t_hist)) if t_hist else 0.0,
        }

    def on_world_change(self, new_hosts: int):
        """Elastic event: world size changed -> re-seed monitor slopes."""
        self.monitor = StragglerMonitor(new_hosts)
