"""Reference D-iteration solvers (single process).

Three tiers, all solving ``X = P X + B`` with spectral radius(P) < 1:

* :func:`run_sequential` — numpy, paper-exact greedy/threshold schedule,
  one node per elementary step.  Ground truth for schedule semantics.
* :func:`frontier_step` — one TPU-native *frontier-batched* round in pure
  jnp: every node above the threshold diffuses simultaneously
  (gather -> multiply -> segment-sum), threshold decays by gamma when the
  frontier empties.  This is the computational pattern the Pallas kernel
  and the distributed engine implement (DESIGN.md §3); the resumable
  solve loops built on it live in :mod:`repro.api.session`.
* :func:`jacobi_solve` / :func:`power_iteration_cost` — classical baselines
  the paper normalizes against (one unit = one matrix-vector product).

The historical public entrypoints :func:`solve_sequential` and
:func:`solve_frontier_jnp` are **deprecated shims** — they delegate to
the :mod:`repro.api` backend registry (methods ``sequential``,
``frontier:segment_sum`` and ``frontier:pallas``) and re-wrap the
unified :class:`repro.api.SolveReport` into the legacy
:class:`DiterationResult`.  New code should call :func:`repro.solve`.

Convergence/stopping: ``|F|_1 / eps <= target_error`` where
``eps = 1 - damping`` for PageRank systems and ``eps = 1 - rho`` in general —
the residual-to-error bound used throughout the paper (§2.2, §3).
"""
from __future__ import annotations

import dataclasses
import warnings
from functools import partial
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .graph import CSRGraph

__all__ = [
    "DiterationResult",
    "run_sequential",
    "solve_sequential",
    "solve_frontier_jnp",
    "frontier_step",
    "jacobi_solve",
    "residual_l1",
    "default_weights",
    "GAMMA",
]

GAMMA = 1.2  # paper default threshold decay


@dataclasses.dataclass
class DiterationResult:
    x: np.ndarray  # the solution estimate H
    residual: float  # |F|_1 at exit
    n_ops: int  # elementary edge-push operations (paper cost unit)
    n_diffusions: int  # node diffusions
    n_sweeps: int  # threshold sweeps / frontier rounds
    cost_iterations: float  # n_ops / L (paper's normalized iteration count)


def default_weights(g: CSRGraph, mode: str = "inv_out") -> np.ndarray:
    """Node selection weights w_i (paper §2.2.1).

    greedy: w=1; inv_out: 1/#out (paper default); inv_out_in: 1/(#out*#in).
    """
    out = np.maximum(g.out_degree(), 1).astype(np.float64)
    if mode == "greedy":
        return np.ones(g.n)
    if mode == "inv_out":
        return 1.0 / out
    if mode == "inv_out_in":
        inn = np.maximum(g.in_degree(), 1).astype(np.float64)
        return 1.0 / (out * inn)
    raise ValueError(f"unknown weight mode {mode!r}")


def residual_l1(f: np.ndarray) -> float:
    return float(np.abs(f).sum())


# ------------------------------------------------------------------------------
# Paper-exact sequential schedule (numpy)
# ------------------------------------------------------------------------------
def run_sequential(
    g: CSRGraph,
    b: np.ndarray,
    target_error: float,
    eps: float,
    weights: Optional[np.ndarray] = None,
    gamma: float = GAMMA,
    max_ops: int = 10**9,
    trace: Optional[List[Tuple[int, float, int]]] = None,
    observer=None,
) -> DiterationResult:
    """Single-PID D-iteration with the paper's cyclic threshold sweep.

    Elementary op = one edge push (cost model §2.3); dangling diffusions are
    charged one op.  Stops when |F|_1 <= target_error * eps.  ``trace``,
    when given, collects one ``(sweep, |F|_1, cumulative_ops)`` record per
    threshold sweep (the registry's per-round trace); ``observer(f, h)``,
    when given, is called after every sweep with the LIVE state arrays —
    the conservation-oracle hook of tests/test_invariants.py (read-only
    by contract).
    """
    if weights is None:
        weights = default_weights(g)
    f = np.array(b, dtype=np.float64)
    h = np.zeros(g.n, dtype=np.float64)
    tol = target_error * eps
    t_k = float(np.abs(f * weights).max()) * 2.0 + 1e-300
    n_ops = 0
    n_diff = 0
    n_sweeps = 0
    indptr, indices, wgts = g.indptr, g.indices, g.weights
    while residual_l1(f) > tol and n_ops < max_ops:
        # one cyclic sweep at the current threshold
        eligible = np.nonzero(np.abs(f) * weights > t_k)[0]
        n_sweeps += 1
        if eligible.size == 0:
            t_k /= gamma
            continue
        for i in eligible:
            sent = f[i]
            if abs(sent) * weights[i] <= t_k:
                continue  # consumed by an earlier diffusion this sweep
            h[i] += sent
            f[i] = 0.0
            lo, hi = indptr[i], indptr[i + 1]
            if hi > lo:
                np.add.at(f, indices[lo:hi], sent * wgts[lo:hi])
                n_ops += hi - lo
            else:
                n_ops += 1  # dangling: absorb, charge one op
            n_diff += 1
        if trace is not None:
            trace.append((n_sweeps, residual_l1(f), n_ops))
        if observer is not None:
            observer(f, h)
    return DiterationResult(
        x=h,
        residual=residual_l1(f),
        n_ops=n_ops,
        n_diffusions=n_diff,
        n_sweeps=n_sweeps,
        cost_iterations=n_ops / max(g.n_edges, 1),
    )


def solve_sequential(
    g: CSRGraph,
    b: np.ndarray,
    target_error: float,
    eps: float,
    weights: Optional[np.ndarray] = None,
    gamma: float = GAMMA,
    max_ops: int = 10**9,
) -> DiterationResult:
    """Deprecated shim — use ``repro.solve(problem, method="sequential")``.

    Delegates to the :mod:`repro.api` registry and re-wraps the unified
    :class:`SolveReport` into the legacy :class:`DiterationResult`.
    """
    warnings.warn(
        "solve_sequential is deprecated; use repro.solve(Problem.linear(...),"
        " method='sequential')",
        DeprecationWarning,
        stacklevel=2,
    )
    from repro.api import Problem, SolverOptions, solve

    report = solve(
        Problem.linear(g, b, eps=eps, target_error=target_error,
                       weights=weights),
        method="sequential",
        options=SolverOptions(gamma=gamma, max_ops=max_ops),
    )
    return DiterationResult(
        x=report.x,
        residual=report.residual,
        n_ops=report.n_ops,
        n_diffusions=report.extras["n_diffusions"],
        n_sweeps=report.n_rounds,
        cost_iterations=report.cost_iterations,
    )


# ------------------------------------------------------------------------------
# Frontier-batched schedule (jnp) — the TPU-native formulation
# ------------------------------------------------------------------------------
@partial(jax.jit, static_argnames=("n",))
def frontier_step(
    f: jnp.ndarray,
    h: jnp.ndarray,
    t_k: jnp.ndarray,
    src: jnp.ndarray,
    dst: jnp.ndarray,
    wgt: jnp.ndarray,
    weights: jnp.ndarray,
    dangling: jnp.ndarray,
    n: int,
    gamma: float = GAMMA,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One frontier round: diffuse every node with |F_i| w_i > T simultaneously.

    Returns (f, h, t, ops) — ``ops`` charges one op per edge push plus one op
    per *dangling* selected node (absorb-and-charge, matching
    :func:`solve_sequential`'s §2.3 accounting exactly: a diffused node costs
    ``max(out_degree, 1)``).  Zero selected nodes -> threshold decays by
    gamma, matching the sweep semantics.  All shapes static: (src, dst, wgt)
    is the fixed edge list; ``dangling`` is the [N] out-degree-zero mask.
    """
    sel = (jnp.abs(f) * weights) > t_k  # [N] frontier mask
    sent = jnp.where(sel, f, 0.0)
    h = h + sent
    f = f - sent
    msg = sent[src] * wgt  # [L]
    delta = jax.ops.segment_sum(msg, dst, num_segments=n)
    f = f + delta
    edge_active = sel[src]
    ops = jnp.sum(edge_active.astype(jnp.int32))
    ops = ops + jnp.sum((sel & dangling).astype(jnp.int32))
    any_sel = jnp.any(sel)
    t_new = jnp.where(any_sel, t_k, t_k / gamma)
    return f, h, t_new, ops


def solve_frontier_jnp(
    g: CSRGraph,
    b: np.ndarray,
    target_error: float,
    eps: float,
    weights: Optional[np.ndarray] = None,
    gamma: float = GAMMA,
    max_rounds: int = 1_000_000,
    backend: str = "segment_sum",
    bs: int = 128,
    interpret: bool = False,
) -> DiterationResult:
    """Deprecated shim — use ``repro.solve(problem, method="frontier:...")``.

    ``backend="segment_sum"`` maps to the registry key
    ``frontier:segment_sum`` (per-edge gather → multiply → segment-sum
    every round), ``backend="pallas"`` to ``frontier:pallas`` (the fused
    BSR kernel round; jnp block oracle off-TPU unless ``interpret``).
    The solve loops themselves live in :mod:`repro.api.session`.
    """
    warnings.warn(
        "solve_frontier_jnp is deprecated; use "
        "repro.solve(Problem.linear(...), method='frontier:segment_sum' or "
        "'frontier:pallas')",
        DeprecationWarning,
        stacklevel=2,
    )
    if backend not in ("segment_sum", "pallas"):
        raise ValueError(f"unknown frontier backend {backend!r}")
    from repro.api import Problem, SolverOptions, solve

    report = solve(
        Problem.linear(g, b, eps=eps, target_error=target_error,
                       weights=weights),
        method=f"frontier:{backend}",
        options=SolverOptions(gamma=gamma, max_rounds=max_rounds, bs=bs,
                              interpret=interpret),
    )
    return DiterationResult(
        x=report.x,
        residual=report.residual,
        n_ops=report.n_ops,
        n_diffusions=-1,
        n_sweeps=report.n_rounds,
        cost_iterations=report.cost_iterations,
    )


# ------------------------------------------------------------------------------
# Classical baselines (the paper's comparison unit)
# ------------------------------------------------------------------------------
def jacobi_solve(
    g: CSRGraph,
    b: np.ndarray,
    target_error: float,
    eps: float,
    max_iters: int = 100_000,
) -> Tuple[np.ndarray, int]:
    """Jacobi / power iteration X <- P X + B; returns (x, n_matvecs).

    One matvec costs L edge ops — the unit the paper's ``cost_iterations``
    is normalized to, so D-iteration cost tables are directly comparable.
    """
    src, dst, w = g.edge_list()
    x = np.zeros(g.n, dtype=np.float64)
    tol = target_error * eps
    for it in range(1, max_iters + 1):
        px = np.zeros(g.n, dtype=np.float64)
        np.add.at(px, dst, x[src] * w)
        x_new = px + b
        if np.abs(x_new - x).sum() <= tol:
            return x_new, it
        x = x_new
    return x, max_iters
