"""Reference D-iteration solvers (single process).

Three tiers, all solving ``X = P X + B`` with spectral radius(P) < 1:

* :func:`solve_sequential` — numpy, paper-exact greedy/threshold schedule,
  one node per elementary step.  Ground truth for schedule semantics.
* :func:`solve_frontier_jnp` — the TPU-native *frontier-batched* schedule in
  pure jnp under ``lax.while_loop``: every node above the threshold diffuses
  simultaneously (gather -> multiply -> segment-sum), threshold decays by
  gamma when the frontier empties.  This is the computational pattern the
  Pallas kernel and the distributed engine implement (DESIGN.md §3).
* :func:`jacobi_solve` / :func:`power_iteration_cost` — classical baselines
  the paper normalizes against (one unit = one matrix-vector product).

Convergence/stopping: ``|F|_1 / eps <= target_error`` where
``eps = 1 - damping`` for PageRank systems and ``eps = 1 - rho`` in general —
the residual-to-error bound used throughout the paper (§2.2, §3).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .graph import CSRGraph

__all__ = [
    "DiterationResult",
    "solve_sequential",
    "solve_frontier_jnp",
    "frontier_step",
    "jacobi_solve",
    "residual_l1",
    "default_weights",
    "GAMMA",
]

GAMMA = 1.2  # paper default threshold decay


@dataclasses.dataclass
class DiterationResult:
    x: np.ndarray  # the solution estimate H
    residual: float  # |F|_1 at exit
    n_ops: int  # elementary edge-push operations (paper cost unit)
    n_diffusions: int  # node diffusions
    n_sweeps: int  # threshold sweeps / frontier rounds
    cost_iterations: float  # n_ops / L (paper's normalized iteration count)


def default_weights(g: CSRGraph, mode: str = "inv_out") -> np.ndarray:
    """Node selection weights w_i (paper §2.2.1).

    greedy: w=1; inv_out: 1/#out (paper default); inv_out_in: 1/(#out*#in).
    """
    out = np.maximum(g.out_degree(), 1).astype(np.float64)
    if mode == "greedy":
        return np.ones(g.n)
    if mode == "inv_out":
        return 1.0 / out
    if mode == "inv_out_in":
        inn = np.maximum(g.in_degree(), 1).astype(np.float64)
        return 1.0 / (out * inn)
    raise ValueError(f"unknown weight mode {mode!r}")


def residual_l1(f: np.ndarray) -> float:
    return float(np.abs(f).sum())


# ------------------------------------------------------------------------------
# Paper-exact sequential schedule (numpy)
# ------------------------------------------------------------------------------
def solve_sequential(
    g: CSRGraph,
    b: np.ndarray,
    target_error: float,
    eps: float,
    weights: Optional[np.ndarray] = None,
    gamma: float = GAMMA,
    max_ops: int = 10**9,
) -> DiterationResult:
    """Single-PID D-iteration with the paper's cyclic threshold sweep.

    Elementary op = one edge push (cost model §2.3); dangling diffusions are
    charged one op.  Stops when |F|_1 <= target_error * eps.
    """
    if weights is None:
        weights = default_weights(g)
    f = np.array(b, dtype=np.float64)
    h = np.zeros(g.n, dtype=np.float64)
    tol = target_error * eps
    t_k = float(np.abs(f * weights).max()) * 2.0 + 1e-300
    n_ops = 0
    n_diff = 0
    n_sweeps = 0
    indptr, indices, wgts = g.indptr, g.indices, g.weights
    while residual_l1(f) > tol and n_ops < max_ops:
        # one cyclic sweep at the current threshold
        eligible = np.nonzero(np.abs(f) * weights > t_k)[0]
        n_sweeps += 1
        if eligible.size == 0:
            t_k /= gamma
            continue
        for i in eligible:
            sent = f[i]
            if abs(sent) * weights[i] <= t_k:
                continue  # consumed by an earlier diffusion this sweep
            h[i] += sent
            f[i] = 0.0
            lo, hi = indptr[i], indptr[i + 1]
            if hi > lo:
                np.add.at(f, indices[lo:hi], sent * wgts[lo:hi])
                n_ops += hi - lo
            else:
                n_ops += 1  # dangling: absorb, charge one op
            n_diff += 1
    return DiterationResult(
        x=h,
        residual=residual_l1(f),
        n_ops=n_ops,
        n_diffusions=n_diff,
        n_sweeps=n_sweeps,
        cost_iterations=n_ops / max(g.n_edges, 1),
    )


# ------------------------------------------------------------------------------
# Frontier-batched schedule (jnp) — the TPU-native formulation
# ------------------------------------------------------------------------------
@partial(jax.jit, static_argnames=("n",))
def frontier_step(
    f: jnp.ndarray,
    h: jnp.ndarray,
    t_k: jnp.ndarray,
    src: jnp.ndarray,
    dst: jnp.ndarray,
    wgt: jnp.ndarray,
    weights: jnp.ndarray,
    dangling: jnp.ndarray,
    n: int,
    gamma: float = GAMMA,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One frontier round: diffuse every node with |F_i| w_i > T simultaneously.

    Returns (f, h, t, ops) — ``ops`` charges one op per edge push plus one op
    per *dangling* selected node (absorb-and-charge, matching
    :func:`solve_sequential`'s §2.3 accounting exactly: a diffused node costs
    ``max(out_degree, 1)``).  Zero selected nodes -> threshold decays by
    gamma, matching the sweep semantics.  All shapes static: (src, dst, wgt)
    is the fixed edge list; ``dangling`` is the [N] out-degree-zero mask.
    """
    sel = (jnp.abs(f) * weights) > t_k  # [N] frontier mask
    sent = jnp.where(sel, f, 0.0)
    h = h + sent
    f = f - sent
    msg = sent[src] * wgt  # [L]
    delta = jax.ops.segment_sum(msg, dst, num_segments=n)
    f = f + delta
    edge_active = sel[src]
    ops = jnp.sum(edge_active.astype(jnp.int32))
    ops = ops + jnp.sum((sel & dangling).astype(jnp.int32))
    any_sel = jnp.any(sel)
    t_new = jnp.where(any_sel, t_k, t_k / gamma)
    return f, h, t_new, ops


def solve_frontier_jnp(
    g: CSRGraph,
    b: np.ndarray,
    target_error: float,
    eps: float,
    weights: Optional[np.ndarray] = None,
    gamma: float = GAMMA,
    max_rounds: int = 1_000_000,
    backend: str = "segment_sum",
    bs: int = 128,
    interpret: bool = False,
) -> DiterationResult:
    """Frontier-batched D-iteration under ``lax.while_loop``.

    ``backend`` selects the diffusion hot path (DESIGN.md §3 "kernel path"):

    * ``"segment_sum"`` — per-edge gather → multiply → ``segment_sum`` over
      the full edge list every round.  O(L) work per round regardless of the
      frontier; the right backend for tiny N and for CPU.
    * ``"pallas"`` — the fused BSR frontier round
      (:func:`repro.kernels.diffusion.frontier_round_bsr`): P is pre-tiled
      into ``bs``-sized dense blocks once, then every round runs threshold
      masking + tile matmuls + the per-row residual reduction inside one
      kernel sweep, skipping block columns with no fluid above the
      threshold.  Off-TPU it runs the jnp block oracle unless
      ``interpret=True`` forces the real kernel through the Pallas
      interpreter (tests).
    """
    if weights is None:
        weights = default_weights(g)
    tol = target_error * eps
    if backend == "pallas":
        return _solve_frontier_bsr(
            g, b, tol, weights, gamma, max_rounds, bs, interpret
        )
    if backend != "segment_sum":
        raise ValueError(f"unknown frontier backend {backend!r}")
    src, dst, wgt = g.edge_list()
    src = jnp.asarray(src, dtype=jnp.int32)
    dst = jnp.asarray(dst, dtype=jnp.int32)
    wgt = jnp.asarray(wgt)
    wts = jnp.asarray(weights)
    dang = jnp.asarray(g.dangling_mask())
    f0 = jnp.asarray(b)
    h0 = jnp.zeros_like(f0)
    t0 = jnp.abs(f0 * wts).max() * 2.0
    n = g.n

    def cond(state):
        f, h, t, ops, rounds = state
        return (jnp.abs(f).sum() > tol) & (rounds < max_rounds)

    def body(state):
        f, h, t, ops, rounds = state
        f, h, t, dops = frontier_step(
            f, h, t, src, dst, wgt, wts, dang, n, gamma
        )
        return f, h, t, ops + dops, rounds + 1

    f, h, t, ops, rounds = jax.lax.while_loop(
        cond, body, (f0, h0, t0, jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32))
    )
    return DiterationResult(
        x=np.asarray(h),
        residual=float(jnp.abs(f).sum()),
        n_ops=int(ops),
        n_diffusions=-1,
        n_sweeps=int(rounds),
        cost_iterations=float(ops) / max(g.n_edges, 1),
    )


def _solve_frontier_bsr(
    g: CSRGraph,
    b: np.ndarray,
    tol: float,
    weights: np.ndarray,
    gamma: float,
    max_rounds: int,
    bs: int,
    interpret: bool,
) -> DiterationResult:
    """BSR-kernel frontier solve: pre-tile P once, fused rounds after."""
    from repro.kernels.diffusion import frontier_round_bsr, prepare_bsr

    m = prepare_bsr(g.indptr, g.indices, g.weights, g.n, bs=bs)
    n_pad = m.n_row_blocks * bs
    f0 = jnp.zeros(n_pad, dtype=m.blocks.dtype).at[: g.n].set(
        jnp.asarray(b, dtype=m.blocks.dtype)
    )
    w = jnp.zeros(n_pad, dtype=m.blocks.dtype).at[: g.n].set(
        jnp.asarray(weights, dtype=m.blocks.dtype)
    )  # padding slots keep w = 0 and are never selected
    out_deg = jnp.zeros(n_pad, dtype=jnp.int32).at[: g.n].set(
        jnp.asarray(g.out_degree(), dtype=jnp.int32)
    )
    dang = jnp.zeros(n_pad, dtype=bool).at[: g.n].set(
        jnp.asarray(g.dangling_mask())
    )
    h0 = jnp.zeros_like(f0)
    t0 = jnp.abs(f0 * w).max() * 2.0
    op_backend = "pallas" if interpret else None  # None = auto

    def cond(state):
        f, res, h, t, ops, rounds = state
        return (res > tol) & (rounds < max_rounds)

    def body(state):
        f, _res, h, t, ops, rounds = state
        f_new, sent, res = frontier_round_bsr(
            m, f, w, t, backend=op_backend, interpret=interpret or None
        )
        # the op's threshold predicate is authoritative (the pallas backend
        # folds t into the weights); sel follows from the sent fluid
        sel = sent != 0
        dops = jnp.sum(jnp.where(sel, out_deg, 0))
        dops = dops + jnp.sum((sel & dang).astype(jnp.int32))
        any_sel = jnp.any(sel)
        t_new = jnp.where(any_sel, t, t / gamma)
        return f_new, res, h + sent, t_new, ops + dops, rounds + 1

    f, res, h, t, ops, rounds = jax.lax.while_loop(
        cond, body,
        (f0, jnp.abs(f0).sum(), h0, t0,
         jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32)),
    )
    return DiterationResult(
        x=np.asarray(h[: g.n], dtype=np.float64),
        residual=float(res),
        n_ops=int(ops),
        n_diffusions=-1,
        n_sweeps=int(rounds),
        cost_iterations=float(ops) / max(g.n_edges, 1),
    )


# ------------------------------------------------------------------------------
# Classical baselines (the paper's comparison unit)
# ------------------------------------------------------------------------------
def jacobi_solve(
    g: CSRGraph,
    b: np.ndarray,
    target_error: float,
    eps: float,
    max_iters: int = 100_000,
) -> Tuple[np.ndarray, int]:
    """Jacobi / power iteration X <- P X + B; returns (x, n_matvecs).

    One matvec costs L edge ops — the unit the paper's ``cost_iterations``
    is normalized to, so D-iteration cost tables are directly comparable.
    """
    src, dst, w = g.edge_list()
    x = np.zeros(g.n, dtype=np.float64)
    tol = target_error * eps
    for it in range(1, max_iters + 1):
        px = np.zeros(g.n, dtype=np.float64)
        np.add.at(px, dst, x[src] * w)
        x_new = px + b
        if np.abs(x_new - x).sum() <= tol:
            return x_new, it
        x = x_new
    return x, max_iters
