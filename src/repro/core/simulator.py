"""Faithful time-stepped K-PID simulator of the distributed D-iteration.

Implements the paper's §2.2–§2.5 exactly:

* K virtual machines (PIDs); PID_k owns the node set Ω_k and the column block
  C_k(P).  Per time step each PID executes ``PID_Speed = N/K`` elementary
  operations (§2.3).
* Local diffusion (*) pushes fluid only to children INSIDE Ω_k; fluid destined
  to other PIDs accumulates implicitly in ``C_k(P)([H]_k − [H_old]_k)`` and is
  delivered at fluid-exchange time (§2.2.1–2.2.2).
* Threshold schedule: diffuse node i when ``|F_i|·w_i > T_k`` (cyclic sweep);
  if a full sweep finds nothing, ``T_k := T_k/γ`` (γ = 1.2).  Default weight
  ``w_i = 1/#out_i``.
* Exchange trigger ``s_k > r_k/2`` (eq. 1); receivers re-seed
  ``T_k' := min(T_k'·(r_k'+received)/r_k', received)``.
* Idle rule ``r_k < max(s_k/10, target_error·ε/K/10)``; unused budget goes to
  ``count_idle`` (§2.2.1, §2.3).
* Cost accounting (§2.4): one op per local edge push (min 1 per diffusion);
  at exchange the sender is charged one op per nonzero entry of
  ``C_k(P)·ΔH`` computed (once per (dirty node × remote edge)), the receiver
  one op per node update received; partition reassignment charges the number
  of moved nodes to both PIDs.  Costs can exceed the per-step budget — the
  PID is then "frozen" (debt carried into following steps), reproducing the
  freeze artifact the paper notes under Figures 15–18.
* Dynamic partition (§2.5.2): a :mod:`repro.balance` policy (default
  ``SlopeEMAPolicy`` — the paper's slope-EMA controller, exact) runs every
  time step on the per-PID residual signal and its ``MovePlan``\\ s are
  executed by the node-granular ``NodeMoveExecutor`` (boundary-node moves
  from the slowest PID to the fastest one, cooldown Z, §2.4 reassignment
  cost charged by the executor).

Two schedule modes:

* ``mode="sequential"`` — paper-exact: nodes within a sweep diffuse one at a
  time, later diffusions see earlier pushes (Gauss-Seidel flavour).
* ``mode="batch"`` — all eligible nodes of a sweep diffuse against the
  start-of-sweep fluid (Jacobi-within-sweep).  Any schedule is a valid
  D-iteration (the diffusion order is free); this is the vectorized variant
  the TPU engine uses, kept here so large-N figures are tractable in the
  simulator too.  Cost accounting is identical per edge.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.balance.executors import NodeMoveExecutor
from repro.balance.policies import Rebalancer, make_rebalancer
from repro.balance.signals import LoadSignal

from .graph import CSRGraph
from .diteration import default_weights, residual_l1
from .partition import cb_partition, uniform_partition

__all__ = [
    "SimulatorConfig",
    "SimResult",
    "DistributedSimulator",
    "run_cost_experiment",
]

GAMMA = 1.2


@dataclasses.dataclass
class SimulatorConfig:
    k: int
    target_error: float
    eps: float  # ε: 1 - damping for PageRank systems (§2.2.1)
    partition: str = "uniform"  # uniform | cb
    dynamic: bool = False  # enable §2.5.2 controller (slope_ema policy)
    policy: Optional[str] = None  # repro.balance policy name (overrides
    # ``dynamic``): slope_ema | cost_refresh | hysteresis
    signal: str = "residual"  # rebalancing signal: residual | edge-ops
    mode: str = "sequential"  # sequential | batch
    weight_mode: str = "inv_out"  # w_i choice (§2.2.1)
    gamma: float = GAMMA
    eta: float = 0.5  # slope EMA factor
    z: int = 10  # reassignment cooldown
    pid_speed: Optional[int] = None  # default N/K
    max_steps: int = 2_000_000
    record_every: int = 1  # metric recording stride (time steps)
    charge_exchange: bool = True  # False reproduces the *neglected-cost* mode
    seed: int = 0


@dataclasses.dataclass
class SimResult:
    h: np.ndarray  # solution estimate
    converged: bool
    n_steps: int  # wall time steps
    cost_iterations: float  # n_steps * PID_Speed / L   (paper's table metric)
    count_active: np.ndarray  # [K]
    count_idle: np.ndarray  # [K]
    n_exchanges: int
    n_moves: int  # dynamic reassignment events
    residual: float  # |F|_1 + in-flight at exit
    # histories, sampled every record_every steps:
    hist_steps: np.ndarray  # [T] wall step index
    hist_rs: np.ndarray  # [T, K]  r_k + s_k
    hist_sizes: np.ndarray  # [T, K] |Ω_k|
    hist_residual: np.ndarray  # [T] global residual upper bound
    # executed rebalancing decisions: (time step, src, dst, units moved)
    move_log: List[Tuple[int, int, int, int]] = dataclasses.field(
        default_factory=list
    )
    # unified §2.3 edge-push accounting (``max(out_degree, 1)`` per
    # diffusion, locality- and exchange-blind) — the cross-backend
    # ``SolveReport.n_ops`` field; ``count_active`` keeps the full
    # simulator cost model (exchange + reassignment charges) on top
    n_edge_ops: int = 0
    hist_edge_ops: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0, dtype=np.int64)
    )
    # chaos events fired during the run: (step, kind) — empty when the
    # run was undisturbed
    chaos_log: List[Tuple[int, str]] = dataclasses.field(
        default_factory=list
    )

    @property
    def cost_per_pid(self) -> np.ndarray:
        return (self.count_active + self.count_idle) / max(
            1, self.count_active.shape[0]
        )


def _pad_hist(rows: List[np.ndarray], dtype=np.float64) -> np.ndarray:
    """Stack per-step [K] records whose K may have changed mid-run
    (chaos rescale): right-pad each row with zeros to the widest K."""
    if not rows:
        return np.zeros((0, 0), dtype=dtype)
    width = max(r.shape[0] for r in rows)
    out = np.zeros((len(rows), width), dtype=dtype)
    for i, r in enumerate(rows):
        out[i, : r.shape[0]] = r
    return out


def _edge_ranges(indptr: np.ndarray, nodes: np.ndarray) -> np.ndarray:
    """Concatenated edge-buffer indices for ``nodes`` (vectorized ranges)."""
    lens = (indptr[nodes + 1] - indptr[nodes]).astype(np.int64)
    total = int(lens.sum())
    if total == 0:
        return np.empty(0, dtype=np.int64)
    starts = indptr[nodes].astype(np.int64)
    offs = np.concatenate([[0], np.cumsum(lens)[:-1]])
    return np.repeat(starts - offs, lens) + np.arange(total, dtype=np.int64)


class DistributedSimulator:
    """Time-stepped simulation of K PIDs running the D-iteration on (P, B).

    ``rebalancer`` injects any :class:`repro.balance.policies.Rebalancer`;
    when omitted it is built from ``cfg.policy`` (or the legacy
    ``cfg.dynamic`` flag, which means the paper-exact ``slope_ema``).
    """

    def __init__(self, g, b: np.ndarray, cfg: SimulatorConfig,
                 rebalancer: Optional[Rebalancer] = None):
        # the simulator reads the CSR view of the shared substrate; a
        # GraphStore (DESIGN.md §7) is accepted directly
        if not isinstance(g, CSRGraph):
            g = g.csr()
        if cfg.signal not in ("residual", "edge-ops"):
            raise ValueError(
                f"unknown rebalancing signal {cfg.signal!r}; expected "
                "'residual' or 'edge-ops'"
            )
        self.g = g
        self.cfg = cfg
        n, k = g.n, cfg.k
        self.n, self.k = n, k
        self.speed = cfg.pid_speed or max(1, n // k)
        self.weights = default_weights(g, cfg.weight_mode)

        # --- partition state -------------------------------------------------
        if cfg.partition == "uniform":
            self.sets: List[np.ndarray] = uniform_partition(n, k)
        elif cfg.partition == "cb":
            self.sets = cb_partition(g.out_degree(), k)
        else:
            raise ValueError(f"unknown partition {cfg.partition!r}")
        self.owner = np.empty(n, dtype=np.int32)
        for i, s in enumerate(self.sets):
            self.owner[s] = i

        # --- fluid state ------------------------------------------------------
        self.f = np.array(b, dtype=np.float64)
        self.h = np.zeros(n, dtype=np.float64)
        # per-PID outbox: pending remote fluid C_k(P)·ΔH, maintained incrementally
        self.outbox = [np.zeros(n, dtype=np.float64) for _ in range(k)]
        self.touched: List[List[np.ndarray]] = [[] for _ in range(k)]
        self.s_abs = np.zeros(k, dtype=np.float64)  # |outbox_k|_1 (≥, exact for P≥0)
        self.dirty = np.zeros(n, dtype=bool)  # node diffused since last exchange
        self.pending_send_cost = np.zeros(k, dtype=np.int64)

        # --- scheduling state -------------------------------------------------
        t0 = np.abs(self.f) * self.weights
        self.t_k = np.array(
            [
                (t0[s].max() * 2.0 if s.size else 1.0) + 1e-300
                for s in self.sets
            ]
        )
        self.debt = np.zeros(k, dtype=np.float64)  # frozen-PID carryover
        # chaos injection state: per-PID speed multiplier (1 = healthy,
        # 1/slowdown = straggler, 0 = dead) — repro.chaos drives this
        self.speed_factor = np.ones(k, dtype=np.float64)
        self.chaos_log: List[Tuple[int, str]] = []

        # --- counters ---------------------------------------------------------
        self.count_active = np.zeros(k, dtype=np.int64)
        self.count_idle = np.zeros(k, dtype=np.int64)
        self.n_edge_ops = 0  # locality-blind §2.3 edge pushes (SolveReport)
        self.n_exchanges = 0
        self.n_moves = 0

        # --- rebalancing control plane ---------------------------------------
        self._rebalancer_injected = rebalancer is not None
        if rebalancer is not None:
            self.rebalancer: Optional[Rebalancer] = rebalancer
        elif cfg.policy or cfg.dynamic:
            self.rebalancer = make_rebalancer(
                cfg.policy or "slope_ema", k=k,
                target_error=cfg.target_error, eta=cfg.eta, z=cfg.z,
                unit="node",
            )
        else:
            self.rebalancer = None
        self.executor = NodeMoveExecutor(self)
        self.move_log: List[Tuple[int, int, int, int]] = []
        self._prev_active = np.zeros(k, dtype=np.int64)

        self.tol = cfg.target_error * cfg.eps

    # --------------------------------------------------------------------- #
    # local quantities
    # --------------------------------------------------------------------- #
    def r_of(self, k: int) -> float:
        s = self.sets[k]
        return float(np.abs(self.f[s]).sum()) if s.size else 0.0

    def global_residual(self) -> float:
        return residual_l1(self.f) + float(self.s_abs.sum())

    def _idle(self, k: int, r_k: float) -> bool:
        thr = max(
            self.s_abs[k] / 10.0,
            self.cfg.target_error * self.cfg.eps / self.k / 10.0,
        )
        return r_k < thr

    # --------------------------------------------------------------------- #
    # local diffusion (one PID, one time step)
    # --------------------------------------------------------------------- #
    def _diffuse_node(self, k: int, i: int) -> int:
        """Paper-exact single-node diffusion; returns ops charged now."""
        g, f, owner = self.g, self.f, self.owner
        sent = f[i]
        self.h[i] += sent
        f[i] = 0.0
        lo, hi = g.indptr[i], g.indptr[i + 1]
        self.n_edge_ops += max(int(hi - lo), 1)
        ops = 0
        if hi > lo:
            dst = g.indices[lo:hi]
            wgt = g.weights[lo:hi]
            local = owner[dst] == k
            n_local = int(local.sum())
            if n_local:
                np.add.at(f, dst[local], sent * wgt[local])
                ops += n_local
            n_remote = (hi - lo) - n_local
            if n_remote:
                rdst = dst[~local]
                np.add.at(self.outbox[k], rdst, sent * wgt[~local])
                self.s_abs[k] += np.abs(sent * wgt[~local]).sum()
                self.touched[k].append(rdst.astype(np.int64))
                if not self.dirty[i]:
                    self.pending_send_cost[k] += n_remote
        if ops == 0:
            ops = 1  # dangling / all-remote: charge the diffusion itself
        self.dirty[i] = True
        return ops

    def _diffuse_batch(self, k: int, sel: np.ndarray) -> int:
        """Jacobi-within-sweep diffusion of all ``sel`` nodes at once."""
        g, f, owner = self.g, self.f, self.owner
        sent = f[sel].copy()
        self.h[sel] += sent
        f[sel] = 0.0
        eidx = _edge_ranges(g.indptr, sel)
        ops = 0
        if eidx.size:
            dst = g.indices[eidx]
            lens = (g.indptr[sel + 1] - g.indptr[sel]).astype(np.int64)
            sent_per_edge = np.repeat(sent, lens)
            msg = sent_per_edge * g.weights[eidx]
            local = owner[dst] == k
            if local.any():
                np.add.at(f, dst[local], msg[local])
                ops += int(local.sum())
            remote = ~local
            if remote.any():
                rdst = dst[remote]
                np.add.at(self.outbox[k], rdst, msg[remote])
                self.s_abs[k] += np.abs(msg[remote]).sum()
                self.touched[k].append(rdst.astype(np.int64))
                # exchange cost: one per remote edge of newly-dirty nodes
                newly = ~self.dirty[sel]
                if newly.any():
                    node_of_edge = np.repeat(
                        np.arange(sel.size, dtype=np.int64), lens
                    )
                    rem_per_node = np.bincount(
                        node_of_edge[remote], minlength=sel.size
                    )
                    self.pending_send_cost[k] += int(rem_per_node[newly].sum())
        # nodes with zero local pushes still cost ≥1 each
        lens_all = (g.indptr[sel + 1] - g.indptr[sel]).astype(np.int64)
        dangling_like = int((lens_all == 0).sum())
        ops += dangling_like
        self.n_edge_ops += int(np.maximum(lens_all, 1).sum())
        self.dirty[sel] = True
        return max(ops, sel.size)  # each diffusion costs at least one op

    def _local_step(self, k: int) -> None:
        """One time step of PID k: sweeps under the threshold schedule."""
        if self.speed_factor[k] <= 0.0:
            return  # dead machine: no budget, no idle accrual
        budget = self.speed * self.speed_factor[k] + self.debt[k]
        self.debt[k] = 0.0
        cfg = self.cfg
        omega = self.sets[k]
        if omega.size == 0:
            self.count_idle[k] += int(max(budget, 0))
            return
        guard = 0
        while budget > 0:
            guard += 1
            r_k = self.r_of(k)
            if self._idle(k, r_k) or guard > 10_000:
                self.count_idle[k] += int(budget)
                return
            fw = np.abs(self.f[omega]) * self.weights[omega]
            elig = omega[fw > self.t_k[k]]
            if elig.size == 0:
                self.t_k[k] /= cfg.gamma
                continue
            if cfg.mode == "batch":
                # budget-limit by cumulative per-node cost (≥ 1 each)
                lens = np.maximum(
                    (self.g.indptr[elig + 1] - self.g.indptr[elig]), 1
                ).astype(np.int64)
                take = int(np.searchsorted(np.cumsum(lens), budget) + 1)
                sel = elig[:take]
                ops = self._diffuse_batch(k, sel)
                self.count_active[k] += ops
                budget -= ops
            else:
                for i in elig:
                    if abs(self.f[i]) * self.weights[i] <= self.t_k[k]:
                        continue  # consumed earlier this sweep
                    ops = self._diffuse_node(k, int(i))
                    self.count_active[k] += ops
                    budget -= ops
                    if budget <= 0:
                        break
        self.debt[k] = min(budget, 0.0)  # freeze: negative budget carries over

    # --------------------------------------------------------------------- #
    # fluid exchange (§2.2.2)
    # --------------------------------------------------------------------- #
    def _exchange(self, k: int) -> None:
        if not self.touched[k]:
            self.s_abs[k] = 0.0
            return
        idx = np.unique(np.concatenate(self.touched[k]))
        vals = self.outbox[k][idx]
        nz = vals != 0.0
        idx, vals = idx[nz], vals[nz]
        self.outbox[k][:] = 0.0  # cheap O(N) but only at exchange
        self.touched[k] = []
        self.s_abs[k] = 0.0
        # release dirty flags of MY nodes (ΔH baseline resets: H_old := H)
        mine = self.owner == k
        self.dirty &= ~mine
        if self.cfg.charge_exchange:
            self.count_active[k] += int(self.pending_send_cost[k])
            self.debt[k] -= float(self.pending_send_cost[k])
        self.pending_send_cost[k] = 0
        if idx.size == 0:
            return
        self.n_exchanges += 1
        # deliver to receivers
        recv_owner = self.owner[idx]
        self.f[idx] += vals
        for kp in np.unique(recv_owner):
            if kp == k:
                # node moved to us since the push was queued: now local fluid
                continue
            m = recv_owner == kp
            received = float(np.abs(vals[m]).sum())
            n_updates = int(m.sum())
            if self.cfg.charge_exchange:
                self.count_active[kp] += n_updates
                self.debt[kp] -= float(n_updates)
            r_kp = self.r_of(int(kp))
            if received > 0.0:
                if r_kp > 0.0:
                    self.t_k[kp] = min(
                        self.t_k[kp] * (r_kp + received) / r_kp, received
                    )
                else:
                    self.t_k[kp] = received

    # --------------------------------------------------------------------- #
    # dynamic partition (§2.5.2) via the repro.balance control plane
    # --------------------------------------------------------------------- #
    def _load_signal(self, step: int) -> LoadSignal:
        sizes = np.array([s.size for s in self.sets], dtype=np.int64)
        if self.cfg.signal == "edge-ops":
            delta = self.count_active - self._prev_active
            self._prev_active = self.count_active.copy()
            return LoadSignal.from_edge_ops(delta, sizes, step=step)
        rs = np.array(
            [self.r_of(i) + self.s_abs[i] for i in range(self.k)]
        )
        return LoadSignal.from_residuals(rs, sizes, step=step)

    def _repartition(self, step: int) -> None:
        for plan in self.rebalancer.propose(self._load_signal(step)):
            # liveness is the simulator's knowledge, not the policy's: a
            # dead machine (chaos kill) neither sheds nor receives — its
            # zero residual would otherwise make it the policy's
            # favorite receiver and strand fluid on lost capacity
            if (self.speed_factor[plan.src] <= 0.0
                    or self.speed_factor[plan.dst] <= 0.0):
                continue
            moved = self.executor.apply(plan)
            if moved:
                self.move_log.append((step, plan.src, plan.dst, moved))

    # --------------------------------------------------------------------- #
    # chaos hooks: straggler / kill / rescale (repro.chaos, DESIGN.md §8)
    # --------------------------------------------------------------------- #
    def kill_pid(self, pid: int, step: int = 0) -> None:
        """Machine loss: PID ``pid`` stops computing and its Ω is handed
        to the surviving PIDs (balanced contiguous chunks, smallest
        survivors first — the fault-tolerant takeover a production
        cluster performs).  The simulator idealizes state as global, so
        the PID's in-flight outbox is flushed first — *capacity* is
        lost, not fluid (data loss + restore is the session-level chaos
        path).  Receivers are charged the §2.4 reassignment cost."""
        if self.speed_factor[pid] <= 0.0:
            return
        self._exchange(pid)
        self.speed_factor[pid] = 0.0
        if self.rebalancer is not None:
            self.rebalancer.reset_worker(pid)  # its slope history died
        doomed = self.sets[pid]
        self.sets[pid] = np.zeros(0, dtype=np.int64)
        survivors = [kk for kk in range(self.k)
                     if self.speed_factor[kk] > 0.0]
        if not survivors:
            raise ValueError("kill would leave no live PID")
        if doomed.size == 0:
            return
        order = sorted(survivors, key=lambda kk: (self.sets[kk].size, kk))
        for kk, chunk in zip(order, np.array_split(doomed, len(order))):
            if chunk.size == 0:
                continue
            self.sets[kk] = np.concatenate([self.sets[kk], chunk])
            self.owner[chunk] = kk
            self.count_active[kk] += chunk.size
            self.debt[kk] -= float(chunk.size)
            mx = float((np.abs(self.f[chunk]) * self.weights[chunk]).max())
            if mx > 0:
                self.t_k[kk] = min(self.t_k[kk], mx * 1.0001)
            self.move_log.append((step, pid, kk, int(chunk.size)))
            self.n_moves += 1

    def rescale(self, k_new: int, step: int = 0) -> None:
        """Elastic rescale: repartition the live node sets over ``k_new``
        PIDs mid-solve.  All outboxes flush first (every pending push is
        addressed through the owner map, which is about to change), then
        the live Ω's concatenate in PID order and split into ``k_new``
        contiguous near-equal chunks — locality-preserving, and exactly
        the partition a cold start over the same node order would build.
        Per-PID controller state (thresholds, debt, policy slopes) is
        re-seeded; cumulative counters carry over where the PID survives.
        """
        if k_new < 1:
            raise ValueError(f"k_new must be >= 1, got {k_new}")
        k_old = self.k
        if k_new == k_old:
            return
        for kk in range(k_old):
            self._exchange(kk)
        live = [self.sets[kk] for kk in range(k_old)
                if self.sets[kk].size]
        nodes = (np.concatenate(live) if live
                 else np.zeros(0, dtype=np.int64))
        self.sets = [np.asarray(c, dtype=np.int64).copy()
                     for c in np.array_split(nodes, k_new)]
        for i, s in enumerate(self.sets):
            self.owner[s] = i

        def _resize(a, fill=0):
            out = np.full(k_new, fill, dtype=a.dtype)
            m = min(k_new, k_old)
            out[:m] = a[:m]
            return out

        self.k = k_new
        # never mutate the caller's config object: a cfg reused for a
        # twin simulator must still mean the ORIGINAL width
        self.cfg = dataclasses.replace(self.cfg, k=k_new)
        self.speed = self.cfg.pid_speed or max(1, self.n // k_new)
        self.count_active = _resize(self.count_active)
        self.count_idle = _resize(self.count_idle)
        self._prev_active = _resize(self._prev_active)
        self.debt = np.zeros(k_new, dtype=np.float64)
        # surviving DEGRADED machines stay degraded; dead slots are
        # replaced by fresh capacity (replacing lost machines is what a
        # post-kill rescale is for), as is any grown width
        old_sf = self.speed_factor
        self.speed_factor = np.ones(k_new, dtype=np.float64)
        m = min(k_new, k_old)
        keep = old_sf[:m] > 0.0
        self.speed_factor[:m][keep] = old_sf[:m][keep]
        self.outbox = [np.zeros(self.n, dtype=np.float64)
                       for _ in range(k_new)]
        self.touched = [[] for _ in range(k_new)]
        self.s_abs = np.zeros(k_new, dtype=np.float64)
        self.pending_send_cost = np.zeros(k_new, dtype=np.int64)
        t0 = np.abs(self.f) * self.weights
        self.t_k = np.array(
            [(t0[s].max() * 2.0 if s.size else 1.0) + 1e-300
             for s in self.sets]
        )
        if self.rebalancer is not None:
            # policy state is per-worker and cannot survive a width
            # change; a cfg-built policy is rebuilt at k_new, but a
            # caller-injected instance must not be silently swapped for
            # a default one (policy-comparison runs would measure the
            # wrong controller from this step on)
            if self._rebalancer_injected:
                raise ValueError(
                    "rescale cannot resize a caller-injected rebalancer;"
                    " construct the simulator from cfg.policy, or swap "
                    "sim.rebalancer yourself before the rescale event"
                )
            self.rebalancer = make_rebalancer(
                self.cfg.policy or "slope_ema", k=k_new,
                target_error=self.cfg.target_error, eta=self.cfg.eta,
                z=self.cfg.z, unit="node",
            )
        self.move_log.append((step, -1, -1, k_new))  # rescale marker

    def _fire_chaos(self, plan, cursor: int, step: int) -> int:
        """Fire every due event (shared ``ChaosPlan.fire_due`` rule);
        returns the advanced cursor."""
        due, cursor = plan.fire_due(cursor, step)
        for ev in due:
            if ev.kind == "straggler":
                self.speed_factor[ev.pid] = 1.0 / ev.slowdown
            elif ev.kind == "kill":
                self.kill_pid(ev.pid, step=step)
            elif ev.kind == "rescale":
                self.rescale(ev.k_new, step=step)
            self.chaos_log.append((step, ev.kind))
        return cursor

    # --------------------------------------------------------------------- #
    # main loop
    # --------------------------------------------------------------------- #
    def run(self, chaos=None) -> SimResult:
        """Run to convergence.  ``chaos`` is an optional
        :class:`repro.chaos.ChaosPlan` whose straggler/kill/rescale
        events fire in the step loop (rounds = simulator time steps);
        the plan is validated against this simulator's width up front.
        """
        if chaos is not None:
            from repro.chaos.plan import SIM_KINDS

            chaos.validate(self.k, kinds=SIM_KINDS)
        chaos_cursor = 0
        cfg = self.cfg
        hist_steps: List[int] = []
        hist_rs: List[np.ndarray] = []
        hist_sizes: List[np.ndarray] = []
        hist_res: List[float] = []
        hist_eops: List[int] = []
        step = 0
        speed_steps = 0  # Σ per-step nominal PID_Speed: a chaos rescale
        # changes self.speed mid-run, and the §2.3 wall-clock metric
        # must price each step at the speed it actually ran under
        converged = False
        while step < cfg.max_steps:
            step += 1
            if chaos is not None:
                chaos_cursor = self._fire_chaos(chaos, chaos_cursor, step)
            speed_steps += self.speed  # after chaos: a rescale at this
            # step changes the speed THIS step's local work runs under
            for k in range(self.k):
                self._local_step(k)
            # exchange check (eq. 1): s_k > r_k / 2
            for k in range(self.k):
                if self.s_abs[k] > 0 and self.s_abs[k] > self.r_of(k) / 2.0:
                    self._exchange(k)
            if self.rebalancer is not None:
                self._repartition(step)
            if step % cfg.record_every == 0:
                hist_steps.append(step)
                hist_rs.append(
                    np.array(
                        [self.r_of(i) + self.s_abs[i] for i in range(self.k)]
                    )
                )
                hist_sizes.append(
                    np.array([s.size for s in self.sets], dtype=np.int64)
                )
                hist_res.append(self.global_residual())
                hist_eops.append(self.n_edge_ops)
            if self.global_residual() <= self.tol:
                converged = True
                break
        return SimResult(
            h=self.h.copy(),
            converged=converged,
            n_steps=step,
            cost_iterations=speed_steps / max(1, self.g.n_edges),
            count_active=self.count_active.copy(),
            count_idle=self.count_idle.copy(),
            n_exchanges=self.n_exchanges,
            n_moves=self.n_moves,
            residual=self.global_residual(),
            hist_steps=np.array(hist_steps, dtype=np.int64),
            hist_rs=(_pad_hist(hist_rs) if hist_rs
                     else np.zeros((0, self.k))),
            hist_sizes=(
                _pad_hist(hist_sizes, dtype=np.int64) if hist_sizes
                else np.zeros((0, self.k))
            ),
            hist_residual=np.array(hist_res, dtype=np.float64),
            move_log=list(self.move_log),
            n_edge_ops=self.n_edge_ops,
            hist_edge_ops=np.array(hist_eops, dtype=np.int64),
            chaos_log=list(self.chaos_log),
        )


def run_cost_experiment(
    g: CSRGraph,
    b: np.ndarray,
    eps: float,
    ks: Tuple[int, ...] = (1, 2, 4, 8, 16),
    partitions: Tuple[str, ...] = ("uniform", "cb"),
    dynamics: Tuple[bool, ...] = (False, True),
    target_error: Optional[float] = None,
    mode: str = "sequential",
    max_steps: int = 2_000_000,
) -> Dict[Tuple[int, str, bool], float]:
    """Paper Tables 1–3 protocol: normalized cost for each (K, partition, dyn).

    ``target_error`` defaults to 1/N as in §3.1.
    """
    te = target_error if target_error is not None else 1.0 / g.n
    out: Dict[Tuple[int, str, bool], float] = {}
    for k in ks:
        for part in partitions:
            for dyn in dynamics:
                cfg = SimulatorConfig(
                    k=k,
                    target_error=te,
                    eps=eps,
                    partition=part,
                    dynamic=dyn,
                    mode=mode,
                    max_steps=max_steps,
                    record_every=50,
                )
                res = DistributedSimulator(g, b, cfg).run()
                out[(k, part, dyn)] = res.cost_iterations
    return out
