"""The paper's contribution: D-iteration + dynamic partition strategy.

Layers:
  graph        — CSR / bucketed graph containers + generators (paper §3 data)
  diteration   — reference solvers (sequential paper-exact, frontier jnp)
  partition    — static Uniform/CB partitions + the dynamic slope controller
  simulator    — faithful time-stepped K-PID simulation (§2.2–2.5)
  distributed  — production shard_map engine (TPU-native adaptation)

Rebalancing decisions flow through the shared :mod:`repro.balance`
control plane (policies, LoadSignals, MovePlans, per-granularity
executors — DESIGN.md §5); the simulator and the engine are its node-
and bucket-granular consumers.
"""
from .graph import (
    BucketedGraph,
    CSRGraph,
    bucketize,
    host_block_graph,
    pagerank_system,
    power_law_graph,
    random_dd_system,
    webgraph_like,
)
from .diteration import (
    DiterationResult,
    default_weights,
    frontier_step,
    jacobi_solve,
    residual_l1,
    run_sequential,
    solve_frontier_jnp,
    solve_sequential,
)
from .partition import (
    DynamicController,
    DynamicControllerConfig,
    MoveInstruction,
    apply_move,
    cb_partition,
    uniform_partition,
)
from .simulator import (
    DistributedSimulator,
    SimResult,
    SimulatorConfig,
    run_cost_experiment,
)
