"""Production distributed D-iteration engine (TPU-native adaptation).

This is the deployable counterpart of :mod:`repro.core.simulator`
(DESIGN.md §3).  The paper's point-to-point, one-node-at-a-time scheme is
mapped onto JAX-native constructs:

* **shard_map over a ``pid`` device axis** — each device plays one PID.
* **Bucket-granular state** — nodes are packed into fixed-size buckets
  (the ``GraphStore`` engine-layout view, DESIGN.md §7, which graph
  deltas patch row-by-row); every device owns a *fixed* number of
  bucket rows (static shapes), some of which are inert headroom.  The
  :mod:`repro.balance` control plane moves whole buckets between devices
  (``MovePlan`` kind ``bucket`` executed by ``BucketMoveExecutor``) by
  permuting the bucket-indexed arrays in-graph (``jnp.take`` on the sharded
  axis lowers to collective-permute / all-gather under SPMD), so load can
  move without any reshaping — this is also the elastic-scaling path.  The
  engine takes any ``Rebalancer`` policy; the legacy ``dynamic`` flag maps
  to the paper-exact ``slope_ema``.
* **Frontier-batched local diffusion** — every local node above the
  threshold diffuses simultaneously (a valid D-iteration schedule); the push
  becomes gather → multiply → ``segment_sum``.
* **reduce-scatter fluid exchange** — remote contributions accumulate in a
  per-device full-length outbox; one ``psum_scatter`` over the ``pid`` axis
  delivers every device exactly the fluid destined to its slots.  The paper's
  ``s_k > r_k/2`` rule decides *when* the exchange happens (evaluated
  in-graph with any-device-fires semantics, so the collective stays
  congruent across devices).
* **Threshold schedule** — per-device T with γ decay and the paper's
  receive-time re-seed ``T := min(T·(r+recv)/r, recv)``.

The same engine is lowered in the multi-pod dry-run (launch/dryrun.py) as the
solver "architecture" entry, proving the collective schedule compiles on the
production meshes.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.balance.executors import BucketMoveExecutor
from repro.balance.plan import MovePlan
from repro.balance.policies import Rebalancer, make_rebalancer
from repro.balance.signals import LoadSignal
from repro.parallel.compat import shard_map

from .graph import CSRGraph  # noqa: F401  (public signature type)

__all__ = [
    "EngineConfig",
    "EngineArrays",
    "EngineState",
    "DistributedEngine",
    "build_engine_arrays",
]

GAMMA = 1.2


@dataclasses.dataclass
class EngineConfig:
    k: int  # devices on the 'pid' axis
    target_error: float
    eps: float
    buckets_per_dev: int = 8  # owned bucket rows per device (incl. headroom)
    headroom: int = 2  # inert bucket rows per device for load moves
    max_inner: int = 8  # max local rounds between exchanges
    gamma: float = GAMMA
    dynamic: bool = False  # enable the control plane (slope_ema policy)
    policy: Optional[str] = None  # repro.balance policy name (overrides
    # ``dynamic``): slope_ema | cost_refresh | hysteresis
    signal: str = "residual"  # rebalancing signal: residual | edge-ops
    eta: float = 0.5
    z: int = 10
    chunk_rounds: int = 4  # exchange cycles per jitted chunk
    max_chunks: int = 4096
    dtype: jnp.dtype = jnp.float32
    diffusion_backend: str = "segment_sum"  # per-edge scatter | "bsr":
    # bucket-tiled dense blocks (MXU path; Pallas gather kernel on TPU,
    # einsum + segment-sum elsewhere)
    pallas_interpret: bool = False  # force the Pallas tile kernel through
    # the interpreter off-TPU (parity tests only — emulation speed)
    pallas_buffer_depth: int = 1  # tile-pool DMA pipeline depth for the
    # gather kernel (1 = automatic BlockSpec pipelining; >= 2 = manual
    # async-copy ring; bit-identical results either way)


@dataclasses.dataclass
class EngineArrays:
    """Static bucket-major tensors fed to the engine (device-shardable).

    R = K * buckets_per_dev rows, S = bucket_size slots per row,
    E = edge capacity per row.  Row r is owned by device r // buckets_per_dev.
    ``pos_of_bucket`` maps a *stable bucket id* to its current row; edge
    destinations are stored as (stable bucket id, in-bucket slot) so bucket
    moves only update the small replicated position map.
    """

    f0: np.ndarray  # [R, S] initial fluid
    w: np.ndarray  # [R, S] selection weights (0 = inert slot)
    src_slot: np.ndarray  # [R, E] in-bucket source slot of each edge
    dst_bucket: np.ndarray  # [R, E] destination stable bucket id
    dst_slot: np.ndarray  # [R, E] destination in-bucket slot
    wgt: np.ndarray  # [R, E] edge weight (0 = padding edge)
    pos_of_bucket: np.ndarray  # [R] stable bucket id -> initial row
    node_of_slot: np.ndarray  # [R, S] global node id or -1 (initial rows)
    n: int
    n_edges: int
    # BSR tiling of the bucket-local edges (diffusion_backend="bsr"):
    # ``tiles[r, t]`` is the dense [S, S] block pushing fluid from the bucket
    # currently at row ``r`` into stable bucket ``tile_dst[r, t]``
    # (``tiles[r, t][dst_slot, src_slot] = weight``; padding tiles are zero
    # and point at bucket 0 — harmless).  Row-indexed on purpose: a bucket
    # move permutes whole tile groups with the same ``jnp.take`` that moves
    # f/h/w, while ``tile_dst`` stores *stable* ids and never changes.
    tiles: Optional[np.ndarray] = None  # [R, T, S, S]
    tile_dst: Optional[np.ndarray] = None  # [R, T] int32
    slot_out_deg: Optional[np.ndarray] = None  # [R, S] int32 real edges per
    # slot — the bsr path's §2.3 op counter (no per-edge gather needed)

    @property
    def n_rows(self) -> int:
        return int(self.f0.shape[0])

    @property
    def bucket_size(self) -> int:
        return int(self.f0.shape[1])

    @property
    def edge_cap(self) -> int:
        return int(self.wgt.shape[1])


def build_engine_arrays(
    g,
    b: np.ndarray,
    cfg: EngineConfig,
    order: Optional[np.ndarray] = None,
) -> EngineArrays:
    """Bucketize (P, B) into the engine's fixed-shape layout.

    ``g`` is a :class:`repro.graph.GraphStore` or a :class:`CSRGraph`
    (wrapped into a throwaway store).  The graph-derived half comes
    from the store's cached **engine-layout view** — so after
    ``store.apply_delta`` only dirty rows/tiles were recomputed — and
    only the RHS-dependent ``f0`` is materialized here.

    Real buckets fill ``buckets_per_dev - headroom`` rows per device; the
    remaining rows are inert landing slots for dynamic bucket moves.
    """
    from repro.graph import GraphStore

    store = g if isinstance(g, GraphStore) else GraphStore.from_csr(g)
    lay = store.engine_layout(
        cfg.k, cfg.buckets_per_dev, cfg.headroom,
        tiled=cfg.diffusion_backend != "segment_sum",
        dtype=np.dtype(cfg.dtype), order=order,
    )
    f0 = np.zeros((lay.n_rows, lay.bucket_size), dtype=np.float64)
    valid = lay.node_of_slot >= 0
    f0[valid] = np.asarray(b, dtype=np.float64)[lay.node_of_slot[valid]]
    return EngineArrays(
        tiles=lay.tiles,
        tile_dst=lay.tile_dst,
        slot_out_deg=lay.slot_out_deg,
        f0=f0,
        w=lay.w,
        src_slot=lay.src_slot,
        dst_bucket=lay.dst_bucket,
        dst_slot=lay.dst_slot,
        wgt=lay.wgt,
        pos_of_bucket=lay.pos_of_bucket,
        node_of_slot=lay.node_of_slot,
        n=lay.n,
        n_edges=lay.n_edges,
    )


def _tile_push_stable(
    tiles: jax.Array,  # [B_loc, T, S, S] this device's tile groups
    tile_dst: jax.Array,  # [B_loc, T] stable destination bucket ids
    sent: jax.Array,  # [B_loc, S] masked fluid leaving this round
    r_total: int,
    *,
    use_pallas: bool,
    interpret: bool = False,
    buffer_depth: int = 1,
    visits: Optional[tuple] = None,
) -> jax.Array:
    """delta[bid] = sum of tile @ sent over tiles targeting stable bucket bid.

    Two implementations of the same contraction:

    * Pallas (TPU / forced-interpret): the tiles stay in their row-owned
      pool; an in-graph ``argsort`` of the destination ids builds the
      dst-sorted visit order that :func:`bsr_gather_spmm_pallas` consumes via
      scalar prefetch, and the visit-derived occupancy map masks buckets no
      tile targets (their output blocks are uninitialised by design).
    * einsum + segment-sum: XLA batched-matmul path, the CPU default.

    Padding tiles are all-zero and point at bucket 0 — they contribute
    nothing either way.
    """
    b_loc, t_cap, s, _ = tiles.shape
    dst_flat = tile_dst.reshape(-1)
    if use_pallas:
        from repro.kernels.diffusion import bsr_gather_spmm_pallas

        order, visit_dst, visit_col, occ = (
            visits if visits is not None
            else _tile_visit_order(tile_dst, r_total))
        out = bsr_gather_spmm_pallas(
            tiles.reshape(-1, s, s), order, visit_dst, visit_col,
            sent[:, :, None], r_total, bs=s, interpret=interpret,
            buffer_depth=buffer_depth,
        )
        return jnp.where(occ[:, None], out[..., 0], jnp.zeros_like(out[..., 0]))
    partial = jnp.einsum("btij,bj->bti", tiles, sent)
    return jax.ops.segment_sum(
        partial.reshape(-1, s), dst_flat, num_segments=r_total
    )


def _tile_visit_order(tile_dst: jax.Array, r_total: int):
    """dst-sorted visit tables for the gather kernel + the row-occupancy
    mask.  Loop-invariant given ``tile_dst`` — hoist out of the round loop
    (an ``argsort`` per round would partially undo the kernel's win)."""
    t_cap = tile_dst.shape[1]
    dst_flat = tile_dst.reshape(-1)
    order = jnp.argsort(dst_flat).astype(jnp.int32)
    occ = jnp.zeros(r_total, bool).at[dst_flat].set(True)
    return (order, dst_flat[order], (order // t_cap).astype(jnp.int32), occ)


def _tile_engine_edges(
    src_slot: np.ndarray,  # [R, E]
    dst_bucket: np.ndarray,  # [R, E] stable bucket ids
    dst_slot: np.ndarray,  # [R, E]
    wgt: np.ndarray,  # [R, E] (0 = padding)
    s: int,
    dtype: np.dtype,
) -> Tuple[np.ndarray, np.ndarray]:
    """Group each row's edge buffer into dense [S, S] per-destination tiles.

    The tile capacity T is the max distinct destination buckets of any row
    (shared across rows/devices so shard_map sees one static shape); unused
    tile slots stay zero with ``tile_dst = 0``.
    """
    r = src_slot.shape[0]
    groups = []
    t_max = 1
    for row in range(r):
        mask = wgt[row] != 0
        uniq = np.unique(dst_bucket[row][mask])
        groups.append(uniq)
        t_max = max(t_max, uniq.shape[0])
    tiles = np.zeros((r, t_max, s, s), dtype=dtype)  # compute dtype: the
    # engine casts anyway, and a float64 intermediate doubles peak memory
    tile_dst = np.zeros((r, t_max), dtype=np.int32)
    for row in range(r):
        mask = wgt[row] != 0
        db = dst_bucket[row][mask]
        ds = dst_slot[row][mask]
        ss = src_slot[row][mask]
        wv = wgt[row][mask]
        uniq = groups[row]
        tile_dst[row, : uniq.shape[0]] = uniq
        t_of_edge = np.searchsorted(uniq, db)
        np.add.at(tiles, (row, t_of_edge, ds, ss), wv)
    return tiles, tile_dst


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class EngineState:
    """Sharded solver state.

    ``f``/``h`` are [R, S] sharded on rows; ``outbox`` is [K, R*S] sharded on
    its first axis (each device holds ITS full-length outbox); ``t``/``ops``
    are [K] sharded one-per-device; ``pos_of_bucket`` is replicated.
    """

    f: jax.Array
    h: jax.Array
    outbox: jax.Array
    t: jax.Array
    pos_of_bucket: jax.Array
    ops: jax.Array
    rounds: jax.Array


# ---------------------------------------------------------------------- #
# compiled-program reuse across engine instances
#
# Engines are rebuilt wholesale on graph updates, checkpoint restores and
# driver re-seeds (SolverSession treats the engine as disposable), but the
# traced chunk program depends only on the STATIC build inputs below — not
# on the array contents.  Without this cache every rebuilt engine carried
# a fresh ``@jax.jit`` closure and re-paid the full XLA compile (~seconds)
# even though the HLO was bit-identical, which made serving a graph-update
# stream ~50× slower than the math requires.  Meshes are interned too, so
# shardings stay identity-equal across rebuilds and device buffers can be
# reused as-is.
# ---------------------------------------------------------------------- #
_MESH_CACHE: dict = {}
_CHUNK_CACHE: dict = {}


def _shared_mesh(devs, axis: str) -> Mesh:
    key = (tuple(d.id for d in devs), axis)
    hit = _MESH_CACHE.get(key)
    if hit is None:
        hit = Mesh(np.array(devs), (axis,))
        _MESH_CACHE[key] = hit
    return hit


@jax.jit
def _repart(state: EngineState, row_perm, new_pos, operands):
    take = lambda x: jnp.take(x, row_perm, axis=0)
    new_state = EngineState(
        f=take(state.f), h=take(state.h), outbox=state.outbox,
        t=state.t, pos_of_bucket=new_pos, ops=state.ops,
        rounds=state.rounds)
    return new_state, tuple(take(x) for x in operands)


class DistributedEngine:
    """shard_map production solver for ``X = P X + B``."""

    def __init__(
        self,
        arrays: EngineArrays,
        cfg: EngineConfig,
        mesh: Optional[Mesh] = None,
        axis: str = "pid",
        rebalancer: Optional[Rebalancer] = None,
    ):
        if cfg.signal not in ("residual", "edge-ops"):
            raise ValueError(
                f"unknown rebalancing signal {cfg.signal!r}; expected "
                "'residual' or 'edge-ops'"
            )
        if cfg.diffusion_backend not in ("segment_sum", "bsr"):
            raise ValueError(
                f"unknown diffusion backend {cfg.diffusion_backend!r}; "
                "expected 'segment_sum' or 'bsr'"
            )
        if cfg.diffusion_backend == "bsr" and arrays.tiles is None:
            raise ValueError(
                "diffusion_backend='bsr' needs tiled arrays — build them "
                "with build_engine_arrays(..., cfg) using the same config"
            )
        self.a = arrays
        self.cfg = cfg
        self.axis = axis
        if mesh is None:
            devs = jax.devices()[: cfg.k]
            assert len(devs) == cfg.k, (
                f"need {cfg.k} devices for the pid axis, have "
                f"{len(jax.devices())}"
            )
            mesh = _shared_mesh(devs, axis)
        self.mesh = mesh
        self.row_sharding = NamedSharding(mesh, P(axis))
        self.rep_sharding = NamedSharding(mesh, P())
        if rebalancer is not None:
            self.rebalancer: Optional[Rebalancer] = rebalancer
        elif cfg.policy or cfg.dynamic:
            self.rebalancer = make_rebalancer(
                cfg.policy or "slope_ema", k=cfg.k,
                target_error=cfg.target_error, eta=cfg.eta, z=cfg.z,
                unit="bucket",
            )
        else:
            self.rebalancer = None
        # chaos/straggler injection hook: a [K] factor multiplying the
        # load signal the control plane sees (None = healthy).  A real
        # straggling device cannot be slowed from here, but its *signal*
        # can — the controller then sheds load exactly as it would in
        # production (repro.chaos.SessionInjector sets this).
        self.load_scale: Optional[np.ndarray] = None
        chunk_key = (
            axis, tuple(d.id for d in self.mesh.devices.flat),
            cfg.k, cfg.buckets_per_dev, arrays.bucket_size,
            arrays.n_rows, cfg.diffusion_backend, cfg.pallas_interpret,
            cfg.pallas_buffer_depth, cfg.gamma, cfg.max_inner,
            cfg.chunk_rounds,
        )
        hit = _CHUNK_CACHE.get(chunk_key)
        if hit is None:
            hit = self._build_chunk()
            _CHUNK_CACHE[chunk_key] = hit
        self._chunk = hit
        self._repartition = self._build_repartition()

    # ------------------------------------------------------------------ #
    # state init
    # ------------------------------------------------------------------ #
    def init_state(
        self,
        f_nodes: Optional[np.ndarray] = None,
        h_nodes: Optional[np.ndarray] = None,
    ) -> EngineState:
        """Fresh sharded state in the *initial* bucket layout.

        ``f_nodes``/``h_nodes`` optionally seed the fluid and history
        from node-space vectors — the warm-start path
        (``SolverSession.warm_start`` re-seeds ``F = B' − (I−P)H`` and
        keeps the accumulated H, §2.2 residual identity).  Defaults
        reproduce the cold start ``F = B, H = 0``.
        """
        a, cfg = self.a, self.cfg
        dt = cfg.dtype
        put_row = lambda x: jax.device_put(x, self.row_sharding)
        put_rep = lambda x: jax.device_put(x, self.rep_sharding)
        f0 = a.f0 if f_nodes is None else self._to_slots(f_nodes)
        h0 = (np.zeros(a.f0.shape) if h_nodes is None
              else self._to_slots(h_nodes))
        fw = np.abs(f0) * a.w
        t0 = (fw.reshape(cfg.k, -1).max(axis=1) * 2.0 + 1e-30).astype(dt)
        self.w = put_row(a.w.astype(dt))
        self.src_slot = put_row(a.src_slot)
        self.dst_bucket = put_row(a.dst_bucket)
        self.dst_slot = put_row(a.dst_slot)
        self.wgt = put_row(a.wgt.astype(dt))
        if cfg.diffusion_backend == "bsr":
            self.tiles = put_row(np.asarray(a.tiles, dtype=dt))
            self.tile_dst = put_row(a.tile_dst)
            self.slot_out_deg = put_row(a.slot_out_deg)
        else:
            self.tiles = self.tile_dst = self.slot_out_deg = None
        return EngineState(
            f=put_row(f0.astype(dt)),
            h=put_row(h0.astype(dt)),
            outbox=put_row(
                np.zeros((cfg.k, a.n_rows * a.bucket_size), dtype=dt)
            ),
            t=put_row(t0),
            pos_of_bucket=put_rep(a.pos_of_bucket.astype(np.int32)),
            ops=put_row(np.zeros(cfg.k, dtype=np.int32)),
            rounds=put_rep(np.zeros((), dtype=np.int32)),
        )

    def _to_slots(self, v_nodes: np.ndarray) -> np.ndarray:
        """Scatter a node-space [N] vector into the initial [R, S] layout."""
        a = self.a
        out = np.zeros(a.f0.shape, dtype=np.float64)
        valid = a.node_of_slot >= 0
        out[valid] = np.asarray(v_nodes, dtype=np.float64)[
            a.node_of_slot[valid]
        ]
        return out

    # ------------------------------------------------------------------ #
    # the jitted chunk: cfg.chunk_rounds × (adaptive local rounds + exchange)
    # ------------------------------------------------------------------ #
    def _build_chunk(self):
        cfg, a, axis = self.cfg, self.a, self.axis
        s = a.bucket_size
        r_total = a.n_rows
        b_loc = cfg.buckets_per_dev
        k = cfg.k
        use_bsr = cfg.diffusion_backend == "bsr"
        pallas_path = (jax.default_backend() == "tpu"
                       or cfg.pallas_interpret)

        def tile_push(tiles, tile_dst, sent, pos, visits):
            """BSR push: dense [S, S] tile matmuls instead of the per-edge
            scatter.  Returns the full-length [R*S] contribution in *row*
            space (current bucket positions).  ``visits`` is the chunk-level
            precomputed dst-sorted visit table (pallas path only)."""
            contrib_stable = _tile_push_stable(
                tiles, tile_dst, sent, r_total,
                use_pallas=pallas_path,
                interpret=cfg.pallas_interpret,
                buffer_depth=cfg.pallas_buffer_depth,
                visits=visits,
            )  # [R, S] indexed by stable bucket id
            # stable bucket space -> current row space via the position map
            inv = jnp.zeros(r_total, jnp.int32).at[pos].set(
                jnp.arange(r_total, dtype=jnp.int32)
            )
            return contrib_stable[inv].reshape(-1)

        def local_round(f, h, obox, t_d, ops_d, pos, operands, my_start,
                        visits, dang):
            """One frontier round on this device's [B_loc, S] rows.

            ``obox`` is the device's full-length [R*S] outbox; ``dang``
            is the [B_loc, S] dangling-slot mask (real node, zero real
            edges) charged one op per selected round — the §2.3
            accounting every other tier uses (edge pushes plus one per
            selected dangling node).
            """
            w, src_slot, dst_bucket, dst_slot, wgt = operands[:5]
            fw = jnp.abs(f) * w
            sel = fw > t_d  # [B_loc, S]
            any_sel = jnp.any(sel)
            sent = jnp.where(sel, f, jnp.zeros_like(f))
            h = h + sent
            f = f - sent
            if use_bsr:
                tiles, tile_dst = operands[5], operands[6]
                contrib = tile_push(tiles, tile_dst, sent, pos, visits)
            else:
                row_idx = jnp.arange(f.shape[0])[:, None]
                msg = sent[row_idx, src_slot] * wgt  # [B_loc, E]
                flat_dst = pos[dst_bucket] * s + dst_slot  # [B_loc, E]
                contrib = jax.ops.segment_sum(
                    msg.reshape(-1), flat_dst.reshape(-1),
                    num_segments=r_total * s,
                )
            mine = jax.lax.dynamic_slice(
                contrib, (my_start,), (b_loc * s,)
            ).reshape(f.shape)
            f = f + mine
            contrib = jax.lax.dynamic_update_slice(
                contrib, jnp.zeros(b_loc * s, contrib.dtype), (my_start,)
            )
            obox = obox + contrib
            t_d = jnp.where(any_sel, t_d, t_d / cfg.gamma)
            if use_bsr:
                # same §2.3 count without the per-edge gather: every slot's
                # real edges all fire when the slot is selected
                slot_deg = operands[7]
                ops_d = ops_d + jnp.sum(
                    jnp.where(sel, slot_deg, 0)).astype(jnp.int32)
            else:
                row_idx = jnp.arange(f.shape[0])[:, None]
                active_edges = sel[row_idx, src_slot] & (wgt != 0)
                ops_d = ops_d + jnp.sum(active_edges).astype(jnp.int32)
            ops_d = ops_d + jnp.sum(sel & dang).astype(jnp.int32)
            return f, h, obox, t_d, ops_d

        def chunk(f, h, outbox, t, pos, ops, rounds, *operands):
            """shard_map body.  Per-device shards:

            f, h, w, src_slot, ...: [B_loc, S] / [B_loc, E]
            outbox: [1, R*S]   t, ops: [1]   pos: [R] replicated
            operands: w, src_slot, dst_bucket, dst_slot, wgt
            [, tiles [B_loc, T, S, S], tile_dst [B_loc, T],
             slot_out_deg [B_loc, S] when bsr]
            """
            idx = jax.lax.axis_index(axis)
            my_start = idx * b_loc * s
            obox = outbox[0]
            t_d = t[0]
            ops_d = ops[0]
            # visit tables depend only on tile_dst: compute once per chunk,
            # not once per round (argsort inside the while_loop body would
            # not be hoisted by XLA)
            visits = (_tile_visit_order(operands[6], r_total)
                      if use_bsr and pallas_path else None)
            # dangling-slot mask: real node (w != 0) with zero real edges.
            # Loop-invariant given the operands; the bsr path reads it off
            # the prebuilt per-slot degrees, the per-edge path rebuilds
            # them from the edge buffer (no operand-signature change).
            if use_bsr:
                slot_deg = operands[7]
            else:
                w_op, src_slot_op, wgt_op = (operands[0], operands[1],
                                             operands[4])
                row_idx = jnp.arange(w_op.shape[0])[:, None]
                slot_deg = jnp.zeros(w_op.shape, jnp.int32).at[
                    row_idx, src_slot_op
                ].add((wgt_op != 0).astype(jnp.int32))
            dang = (operands[0] != 0) & (slot_deg == 0)

            def body(carry):
                f, h, obox, t_d, ops_d, i, fire = carry
                f, h, obox, t_d, ops_d = local_round(
                    f, h, obox, t_d, ops_d, pos, operands, my_start,
                    visits, dang)
                r_k = jnp.sum(jnp.abs(f))
                s_k = jnp.sum(jnp.abs(obox))
                fire_local = (s_k > r_k / 2.0).astype(jnp.int32)
                fire = jax.lax.pmax(fire_local, axis)
                return f, h, obox, t_d, ops_d, i + 1, fire

            def cond(carry):
                *_, i, fire = carry
                return (i < cfg.max_inner) & (fire == 0)

            f, h, obox, t_d, ops_d, i, _fire = jax.lax.while_loop(
                cond, body,
                (f, h, obox, t_d, ops_d, jnp.zeros((), jnp.int32),
                 jnp.zeros((), jnp.int32)),
            )
            # ---- fluid exchange: reduce-scatter outbox over devices ----
            r_before = jnp.sum(jnp.abs(f))
            delta = jax.lax.psum_scatter(
                obox.reshape(k, b_loc * s), axis, scatter_dimension=0,
                tiled=False,
            ).reshape(f.shape)
            f = f + delta
            received = jnp.sum(jnp.abs(delta))
            t_new = jnp.where(
                received > 0,
                jnp.minimum(
                    jnp.where(
                        r_before > 0,
                        t_d * (r_before + received) / r_before,
                        received,
                    ),
                    received,
                ),
                t_d,
            )
            obox = jnp.zeros_like(obox)
            return (f, h, obox[None], t_new[None], pos, ops_d[None],
                    rounds + i)

        n_operands = 8 if use_bsr else 5
        pr, pp = P(axis), P()
        mapped = shard_map(
            chunk,
            mesh=self.mesh,
            in_specs=(pr, pr, pr, pr, pp, pr, pp) + (pr,) * n_operands,
            out_specs=(pr, pr, pr, pr, pp, pr, pp),
            check_vma=False,
        )

        @jax.jit
        def run_chunk(state: EngineState, *operands):
            f, h, outbox, t, pos, ops, rounds = (
                state.f, state.h, state.outbox, state.t,
                state.pos_of_bucket, state.ops, state.rounds)
            for _ in range(cfg.chunk_rounds):
                f, h, outbox, t, pos, ops, rounds = mapped(
                    f, h, outbox, t, pos, ops, rounds, *operands)
            new = EngineState(f=f, h=h, outbox=outbox, t=t,
                              pos_of_bucket=pos, ops=ops, rounds=rounds)
            stats = {
                "r": jnp.sum(jnp.abs(f.reshape(cfg.k, -1)), axis=1),
                "s": jnp.sum(jnp.abs(outbox), axis=1),
                "residual": jnp.sum(jnp.abs(f)),
            }
            return new, stats

        return run_chunk

    # ------------------------------------------------------------------ #
    # in-graph bucket repartition (dynamic strategy / elastic scaling)
    # ------------------------------------------------------------------ #
    def _build_repartition(self):
        def run(state, row_perm, new_pos, operands):
            # _repart is the shared module-level jit (see _CHUNK_CACHE)
            new_state, arrs = _repart(state, row_perm, new_pos,
                                      tuple(operands))
            # keep row-sharded layout after the gather
            arrs = tuple(
                jax.device_put(x, self.row_sharding) for x in arrs
            )
            new_state = EngineState(
                f=jax.device_put(new_state.f, self.row_sharding),
                h=jax.device_put(new_state.h, self.row_sharding),
                outbox=new_state.outbox,
                t=new_state.t,
                pos_of_bucket=new_state.pos_of_bucket,
                ops=new_state.ops,
                rounds=new_state.rounds,
            )
            return new_state, arrs

        return run

    # ------------------------------------------------------------------ #
    # outer solve loop (host-driven controller, jitted chunks)
    # ------------------------------------------------------------------ #
    def solve(self, verbose: bool = False):
        cfg, a = self.cfg, self.a
        ex = BucketMoveExecutor(self, self.init_state())
        tol = cfg.target_error * cfg.eps
        history = []
        move_log = []
        n_moves = 0
        prev_ops = np.zeros(cfg.k, dtype=np.int64)
        resid = float("inf")
        chunk_i = -1
        for chunk_i in range(cfg.max_chunks):
            ex.state, stats = self._chunk(ex.state, *ex.chunk_operands())
            r = np.asarray(stats["r"])
            s_ = np.asarray(stats["s"])
            resid = float(np.asarray(stats["residual"])) + float(s_.sum())
            history.append(
                (int(np.asarray(ex.state.rounds)), resid, (r + s_).copy())
            )
            if verbose:
                print(f"chunk {chunk_i}: residual={resid:.3e} "
                      f"rounds={int(np.asarray(ex.state.rounds))}")
            if resid <= tol:
                break
            prev_ops = self.apply_control_plane(
                ex, r, s_, chunk_i, prev_ops, move_log)
        n_moves = len(move_log)
        x = self.extract_solution(ex.state, ex.row_of_bucket)
        ops = np.asarray(ex.state.ops).copy()
        return x, {
            "residual": resid,
            "chunks": chunk_i + 1,
            "rounds": int(np.asarray(ex.state.rounds)),
            "moves": n_moves,
            "move_log": move_log,
            "history": history,
            "converged": resid <= tol,
            "ops": ops,
            "n_edge_ops": int(ops.astype(np.int64).sum()),
        }

    def apply_control_plane(self, ex, r: np.ndarray, s_: np.ndarray,
                            step: int, prev_ops: np.ndarray,
                            move_log: list) -> np.ndarray:
        """One rebalancer pass on post-chunk stats (shared by ``solve``
        and the API session driver so the decision logic cannot
        diverge).  Builds the configured LoadSignal, applies every
        proposed MovePlan through ``ex``, appends executed moves to
        ``move_log`` as ``(step, src, dst, units)``, and returns the
        updated cumulative-ops baseline."""
        if self.rebalancer is None:
            return prev_ops
        sizes = ex.sizes()
        scale = (self.load_scale if self.load_scale is not None
                 else np.ones(self.cfg.k))
        if self.cfg.signal == "edge-ops":
            ops = np.asarray(ex.state.ops).astype(np.int64)
            # the on-device counter is int32 and cumulative over the
            # whole solve; recover the true per-chunk delta through
            # wraparound (valid while one chunk stays under 2^32 ops)
            delta = (ops - prev_ops) & 0xFFFFFFFF
            sig = LoadSignal.from_edge_ops(delta * scale, sizes, step=step)
            prev_ops = ops
        else:
            sig = LoadSignal.from_residuals((r + s_) * scale, sizes,
                                            step=step)
        for plan in self.rebalancer.propose(sig):
            moved = ex.apply(plan)
            if moved:
                move_log.append((step, plan.src, plan.dst, moved))
        return prev_ops

    def gather_nodes(self, values, row_of_bucket: np.ndarray) -> np.ndarray:
        """Gather a bucket-space [R, S] state array back to node space:
        a bucket id's data lives at its *current* row while the node map
        indexes its *initial* row."""
        a = self.a
        v = np.asarray(values).reshape(a.n_rows, a.bucket_size)
        x = np.zeros(a.n, dtype=np.float64)
        for bid in range(a.n_rows):
            row0 = int(a.pos_of_bucket[bid])  # initial row (node map)
            row1 = int(row_of_bucket[bid])  # current row (data)
            nodes = a.node_of_slot[row0]
            valid = nodes >= 0
            if valid.any():
                x[nodes[valid]] = v[row1, valid]
        return x

    def extract_solution(self, state: EngineState,
                         row_of_bucket: np.ndarray) -> np.ndarray:
        """Gather H back to node space."""
        return self.gather_nodes(state.h, row_of_bucket)

    def _plan_move(self, row_of_bucket: np.ndarray, src_dev: int,
                   dst_dev: int, n_move: int, keep_min: int = 1
                   ) -> Tuple[Optional[np.ndarray], np.ndarray, int]:
        """Plan a row permutation moving up to ``n_move`` real buckets from
        ``src_dev`` to free (inert) rows on ``dst_dev``.

        ``keep_min`` is the floor of real buckets left on the source —
        1 for rebalancing moves (a PID never empties itself), 0 for the
        rescale drain (a dying device hands everything over).

        Returns ``(perm, new_row_of_bucket, moved)`` with
        ``perm[i] = old row whose contents land in new row i``
        (``jnp.take`` semantics).
        """
        cfg = self.cfg
        b_loc = cfg.buckets_per_dev
        n_real = cfg.k * (b_loc - cfg.headroom)
        dev_of_bucket = row_of_bucket // b_loc
        src_real = np.nonzero(dev_of_bucket[:n_real] == src_dev)[0]
        inert_ids = np.arange(n_real, row_of_bucket.shape[0])
        dst_free = inert_ids[dev_of_bucket[inert_ids] == dst_dev]
        moved = int(min(n_move, max(src_real.size - keep_min, 0),
                        dst_free.size))
        if moved == 0:
            return None, row_of_bucket, 0
        new_map = row_of_bucket.copy()
        perm = np.arange(row_of_bucket.shape[0], dtype=np.int32)
        for bid, q in zip(src_real[-moved:], dst_free[:moved]):
            p_row, q_row = int(new_map[bid]), int(new_map[q])
            perm[q_row], perm[p_row] = p_row, q_row
            new_map[bid], new_map[q] = q_row, p_row
        return perm, new_map, moved

    # ------------------------------------------------------------------ #
    # mid-solve PID rescale (elastic scale-up / device loss)
    # ------------------------------------------------------------------ #
    def _free_rows_per_device(self, row_of_bucket: np.ndarray) -> np.ndarray:
        """Inert (landing-capable) bucket rows currently on each device."""
        cfg = self.cfg
        n_real = cfg.k * (cfg.buckets_per_dev - cfg.headroom)
        dev_of_bucket = row_of_bucket // cfg.buckets_per_dev
        return np.bincount(dev_of_bucket[n_real:], minlength=cfg.k)

    def drain_for_shrink(self, ex, k_new: int):
        """Evacuate every real bucket owned by devices >= ``k_new`` onto
        the survivors' inert headroom rows, one bucket at a time to the
        survivor with the most free rows (deterministic, load-levelling).

        Runs through the existing :class:`~repro.balance.executors.
        BucketMoveExecutor` path — the same in-graph permutation the
        dynamic partition uses — so the drain IS a sequence of executed
        ``MovePlan``\\ s, returned as ``(src, dst, moved)`` triples.
        Raises when the surviving headroom cannot absorb the evacuation.
        """
        cfg = self.cfg
        sizes = ex.sizes()
        need = int(sizes[k_new:].sum())
        free = self._free_rows_per_device(ex.row_of_bucket)
        have = int(free[:k_new].sum())
        if need > have:
            raise ValueError(
                f"cannot shrink to k={k_new}: {need} real buckets must "
                f"evacuate but survivors have only {have} free headroom "
                f"rows (raise EngineConfig.headroom)"
            )
        drains = []
        for d in range(k_new, cfg.k):
            while ex.sizes()[d] > 0:
                free = self._free_rows_per_device(ex.row_of_bucket)
                free[k_new:] = -1  # dying devices never receive
                dst = int(np.argmax(free))
                moved = ex.apply(
                    MovePlan(src=d, dst=dst, units=1, kind="bucket"),
                    keep_min=0)
                assert moved == 1, (d, dst, moved)
                drains.append((d, dst, moved))
        return drains

    def rescale(self, ex, k_new: int, g, b: np.ndarray,
                buckets_per_dev: Optional[int] = None,
                strict: bool = False):
        """Grow/shrink the ``pid`` axis mid-solve without recomputing H.

        Shrink first *drains* the dying devices through the executor
        path (:meth:`drain_for_shrink` — headroom rows absorb the
        moves), so every byte of solver state leaves a lost device
        through the same collective permutation the rebalancer uses;
        then the axis is re-meshed at ``k_new`` over the store's cached
        engine-layout view and the fluid pair ``(F, H)`` is carried over
        in node space (the invariant ``B = (I−P)H + F`` travels with
        it).  Grow is the same re-mesh without a drain; the fresh
        layout is balanced by construction and the rebalancer spreads
        any residual skew.

        When the survivors' headroom cannot absorb the evacuation the
        drain is skipped and the state rides the node-space carry alone
        (``strict=True`` raises instead — tests that must exercise the
        executor drain use it).

        Returns ``(engine, executor, drains)`` — a NEW engine bound to
        ``k_new`` devices with a freshly seeded policy, its executor in
        the cold-start bucket layout of ``k_new`` (so a replay of the
        post-rescale move log over a cold start reproduces the
        ownership map exactly), and the executed drain triples.
        """
        cfg = self.cfg
        if k_new == cfg.k:
            return self, ex, []
        if k_new < 1:
            raise ValueError(f"k_new must be >= 1, got {k_new}")
        n_dev = len(jax.devices())
        if k_new > n_dev:
            raise ValueError(
                f"rescale to k={k_new} needs {k_new} physical devices, "
                f"have {n_dev}"
            )
        drains = []
        if k_new < cfg.k:
            need = int(ex.sizes()[k_new:].sum())
            have = int(self._free_rows_per_device(
                ex.row_of_bucket)[:k_new].sum())
            if need <= have or strict:
                drains = self.drain_for_shrink(ex, k_new)
        f_nodes = self.gather_nodes(ex.state.f, ex.row_of_bucket)
        h_nodes = self.gather_nodes(ex.state.h, ex.row_of_bucket)
        new_cfg = dataclasses.replace(
            cfg, k=k_new,
            buckets_per_dev=(buckets_per_dev if buckets_per_dev is not None
                             else cfg.buckets_per_dev))
        arrays = build_engine_arrays(g, b, new_cfg)
        engine = DistributedEngine(arrays, new_cfg, axis=self.axis)
        new_ex = BucketMoveExecutor(engine,
                                    engine.init_state(f_nodes, h_nodes))
        return engine, new_ex, drains
