"""Graph containers + generators for the D-iteration system.

The D-iteration solves ``X = P @ X + B`` where column ``i`` of ``P`` holds the
outgoing diffusion weights of node ``i`` (``P[j, i]`` = weight of edge i -> j).
We therefore store the graph in *out-adjacency* form (CSC of P == CSR of P^T):
for each node, the list of its out-neighbors and the corresponding column
weights.  This is the only layout the diffusion sweep ever touches.

Two layouts:

* :class:`CSRGraph` — compressed out-adjacency (indptr / indices / weights),
  used by the reference solver, the faithful simulator and all tests.
* :class:`BucketedGraph` — bucket-major, fixed-shape edge list used by the
  production distributed engine and the Pallas diffusion kernel (static
  shapes, bucket-granular dynamic repartition).

Since the GraphStore refactor (DESIGN.md §7) both are *views* of
:class:`repro.graph.GraphStore`, the one mutable substrate every
backend derives its representation from: ``store.csr()`` returns a
:class:`CSRGraph`; :func:`bucketize` delegates to the store's bucketed
view builder.  The dataclasses stay as the stable container types (and
as the deprecated direct-construction path for code that never needs
``apply_delta``); new code should build a ``GraphStore`` and ask it for
views so graph churn patches them incrementally.

Generators reproduce the paper's synthetic data (§3.1: power-law 1/k^alpha for
in- and out-degree, alpha = 1.5) and a web-graph stand-in matched to Table 4
(L/N ratio, dangling-node fraction) for the offline uk-2007-05 substitution.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

__all__ = [
    "CSRGraph",
    "BucketedGraph",
    "power_law_graph",
    "webgraph_like",
    "host_block_graph",
    "pagerank_system",
    "random_dd_system",
    "bucketize",
]


@dataclasses.dataclass
class CSRGraph:
    """Out-adjacency of the diffusion matrix P (column-major of P).

    ``indices[indptr[i]:indptr[i+1]]`` are the out-neighbors ``j`` of node
    ``i`` and ``weights[...]`` the matching ``P[j, i]`` entries.
    """

    indptr: np.ndarray  # [N+1] int64
    indices: np.ndarray  # [L] int32
    weights: np.ndarray  # [L] float64
    n: int

    # ---- derived quantities -------------------------------------------------
    @property
    def n_edges(self) -> int:
        return int(self.indices.shape[0])

    def out_degree(self) -> np.ndarray:
        return np.diff(self.indptr).astype(np.int64)

    def in_degree(self) -> np.ndarray:
        deg = np.zeros(self.n, dtype=np.int64)
        np.add.at(deg, self.indices, 1)
        return deg

    def out_neighbors(self, i: int) -> Tuple[np.ndarray, np.ndarray]:
        lo, hi = self.indptr[i], self.indptr[i + 1]
        return self.indices[lo:hi], self.weights[lo:hi]

    def dangling_mask(self) -> np.ndarray:
        return np.diff(self.indptr) == 0

    # ---- conversions ---------------------------------------------------------
    def to_dense(self) -> np.ndarray:
        """Dense P with P[j, i] = weight of edge i -> j.  Small graphs only.

        Parallel edges accumulate (np.add.at — the same summation every
        solver's scatter applies; fancy ``+=`` would silently drop
        duplicates).
        """
        p = np.zeros((self.n, self.n), dtype=np.float64)
        for i in range(self.n):
            js, ws = self.out_neighbors(i)
            np.add.at(p, (js, np.full(js.size, i)), ws)
        return p

    def edge_list(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(src, dst, w) arrays of length L (src repeated per out-edge)."""
        src = np.repeat(np.arange(self.n, dtype=np.int32), np.diff(self.indptr))
        return src, self.indices.astype(np.int32), self.weights

    def reorder(self, perm: np.ndarray) -> "CSRGraph":
        """Relabel nodes so that new node ``k`` is old node ``perm[k]``.

        Used for the paper's node-ordering experiments (Tables 2/3: nodes
        ordered by out-degree / in-degree before partitioning).
        """
        perm = np.asarray(perm)
        inv = np.empty_like(perm)
        inv[perm] = np.arange(self.n)
        counts = np.diff(self.indptr)[perm]
        indptr = np.zeros(self.n + 1, dtype=np.int64)
        np.cumsum(counts, out=indptr[1:])
        indices = np.empty_like(self.indices)
        weights = np.empty_like(self.weights)
        for new_i, old_i in enumerate(perm):
            lo, hi = self.indptr[old_i], self.indptr[old_i + 1]
            nlo = indptr[new_i]
            indices[nlo : nlo + (hi - lo)] = inv[self.indices[lo:hi]]
            weights[nlo : nlo + (hi - lo)] = self.weights[lo:hi]
        return CSRGraph(indptr=indptr, indices=indices, weights=weights, n=self.n)

    @staticmethod
    def from_edges(
        src: np.ndarray, dst: np.ndarray, w: np.ndarray, n: int
    ) -> "CSRGraph":
        order = np.argsort(src, kind="stable")
        src, dst, w = src[order], dst[order], w[order]
        indptr = np.zeros(n + 1, dtype=np.int64)
        np.add.at(indptr, src + 1, 1)
        np.cumsum(indptr, out=indptr)
        return CSRGraph(
            indptr=indptr,
            indices=dst.astype(np.int32),
            weights=w.astype(np.float64),
            n=n,
        )


# ------------------------------------------------------------------------------
# Bucket-major fixed-shape layout (production engine / Pallas kernel)
# ------------------------------------------------------------------------------
@dataclasses.dataclass
class BucketedGraph:
    """Bucket-major edge-list layout with static shapes.

    Nodes are packed into ``n_buckets`` buckets of ``bucket_size`` slots
    (padded with inert slots).  Each bucket carries a fixed-capacity edge
    buffer; edge ``e`` of bucket ``b`` reads fluid from local slot
    ``src_slot[b, e]`` and pushes to *global flattened slot* ``dst[b, e]``
    with weight ``wgt[b, e]``.  Padding edges have ``wgt == 0`` and point at
    slot 0 (harmless: zero contribution).

    The *bucket* is the unit of dynamic repartition: the slope controller
    moves whole buckets between PIDs, so every array here can stay
    statically shaped while ownership changes (DESIGN.md §3).
    """

    node_of_slot: np.ndarray  # [n_buckets, bucket_size] int32 global node id or -1
    slot_of_node: np.ndarray  # [N] int32 flattened slot id of each node
    src_slot: np.ndarray  # [n_buckets, edge_cap] int32 (local slot in bucket)
    dst: np.ndarray  # [n_buckets, edge_cap] int32 (global flattened slot)
    wgt: np.ndarray  # [n_buckets, edge_cap] float32
    out_deg: np.ndarray  # [n_buckets, bucket_size] int32 true out-degree
    n: int
    n_edges: int

    @property
    def n_buckets(self) -> int:
        return int(self.node_of_slot.shape[0])

    @property
    def bucket_size(self) -> int:
        return int(self.node_of_slot.shape[1])

    @property
    def edge_cap(self) -> int:
        return int(self.dst.shape[1])

    @property
    def n_slots(self) -> int:
        return self.n_buckets * self.bucket_size


def bucketize(
    g: CSRGraph,
    n_buckets: int,
    order: Optional[np.ndarray] = None,
) -> BucketedGraph:
    """Pack ``g`` into ``n_buckets`` equal buckets (node order preserved).

    ``order`` optionally permutes nodes before packing (e.g. CB ordering so
    buckets have roughly equal edge counts).  Edge buffers are sized to the
    max per-bucket edge count (padded elsewhere) — per-bucket skew is exactly
    what the dynamic controller then balances at runtime.

    Deprecated alias over the GraphStore bucketed-view builder
    (:func:`repro.graph.views.build_bucketed`); prefer
    ``GraphStore.bucketed(n_buckets)`` which additionally keeps the
    view patched under :meth:`~repro.graph.GraphStore.apply_delta`.
    """
    from repro.graph.views import build_bucketed

    return build_bucketed(g, n_buckets, order=order)


# ------------------------------------------------------------------------------
# Generators
# ------------------------------------------------------------------------------
def _power_law_degrees(n: int, alpha: float, d_min: int, d_max: int, rng) -> np.ndarray:
    """Sample degrees from P(k) ∝ 1/k^alpha on [d_min, d_max] (inverse CDF)."""
    ks = np.arange(d_min, d_max + 1, dtype=np.float64)
    pmf = ks ** (-alpha)
    pmf /= pmf.sum()
    return rng.choice(ks.astype(np.int64), size=n, p=pmf)


def power_law_graph(
    n: int,
    alpha: float = 1.5,
    d_min: int = 0,
    d_max: Optional[int] = None,
    seed: int = 0,
    dedupe: bool = True,
) -> CSRGraph:
    """Synthetic graph per paper §3.1: power-law 1/k^alpha in- and out-degree.

    Out-degrees are sampled from the power law; each out-stub is wired to a
    destination drawn proportionally to a power-law in-degree weight
    (configuration-model style).  ``d_min = 0`` keeps a realistic dangling
    fraction (paper Table 4: 0.8–4.1%).  Weights are unnormalized adjacency
    (1.0); use :func:`pagerank_system` to build (P, B).
    """
    rng = np.random.default_rng(seed)
    if d_max is None:
        d_max = max(4, int(np.sqrt(n) * 4))
    out_deg = _power_law_degrees(n, alpha, max(d_min, 0) + 1, d_max, rng) - (
        1 if d_min == 0 else 0
    )
    # in-degree attractiveness, power-law as well
    in_w = _power_law_degrees(n, alpha, 1, d_max, rng).astype(np.float64)
    in_p = in_w / in_w.sum()

    src = np.repeat(np.arange(n, dtype=np.int64), out_deg)
    dst = rng.choice(n, size=src.shape[0], p=in_p)
    # drop self loops
    keep = src != dst
    src, dst = src[keep], dst[keep]
    if dedupe:
        key = src * n + dst
        _, uniq = np.unique(key, return_index=True)
        src, dst = src[uniq], dst[uniq]
    w = np.ones(src.shape[0], dtype=np.float64)
    return CSRGraph.from_edges(src.astype(np.int32), dst.astype(np.int32), w, n)


def webgraph_like(
    n: int,
    links_per_node: float = 12.9,
    dangling_frac: float = 0.041,
    seed: int = 1,
) -> CSRGraph:
    """uk-2007-05 stand-in matched to paper Table 4 (L/N, dangling fraction).

    Power-law degrees with a locality bias (web graphs link mostly within a
    host neighborhood) so partitions see realistic locality, plus an explicit
    dangling set.
    """
    rng = np.random.default_rng(seed)
    target_l = int(n * links_per_node)
    alpha = 1.5
    d_max = max(8, int(np.sqrt(n) * 8))
    out_deg = _power_law_degrees(n, alpha, 1, d_max, rng)
    out_deg = np.round(out_deg * (target_l / out_deg.sum())).astype(np.int64)
    out_deg = np.maximum(out_deg, 1)
    dangling = rng.choice(n, size=int(n * dangling_frac), replace=False)
    out_deg[dangling] = 0

    src = np.repeat(np.arange(n, dtype=np.int64), out_deg)
    # locality bias: 80% of links land within +/- n/100 of the source
    local = rng.random(src.shape[0]) < 0.8
    span = max(2, n // 100)
    offs = rng.integers(-span, span + 1, size=src.shape[0])
    dst_local = np.clip(src + offs, 0, n - 1)
    in_w = _power_law_degrees(n, alpha, 1, d_max, rng).astype(np.float64)
    dst_global = rng.choice(n, size=src.shape[0], p=in_w / in_w.sum())
    dst = np.where(local, dst_local, dst_global)
    keep = src != dst
    src, dst = src[keep], dst[keep]
    key = src * n + dst
    _, uniq = np.unique(key, return_index=True)
    src, dst = src[uniq], dst[uniq]
    w = np.ones(src.shape[0], dtype=np.float64)
    return CSRGraph.from_edges(src.astype(np.int32), dst.astype(np.int32), w, n)


def host_block_graph(
    n: int,
    host_size: int = 128,
    links_per_node: float = 8.0,
    intra_frac: float = 0.92,
    span_hosts: int = 2,
    dangling_frac: float = 0.02,
    alpha: float = 1.5,
    seed: int = 0,
) -> CSRGraph:
    """Host-ordered web-graph stand-in with block-compressible structure.

    Real web crawls sorted URL-lexicographically (e.g. uk-2007-05) put the
    bulk of their links inside the source's host and its neighbours — the
    locality WebGraph compression and the BSR diffusion kernel both exploit.
    Nodes are grouped into consecutive hosts of ``host_size``;
    ``intra_frac`` of the links stay inside the source's host, the rest land
    within ``±span_hosts`` hosts.  With BSR block size ``bs == host_size``
    the tiling therefore has at most ``2 * span_hosts + 1`` blocks per block
    column — dense MXU tiles instead of scattered singletons.

    Out-degrees are power-law ``1/k^alpha`` rescaled to ``links_per_node``;
    ``dangling_frac`` of the nodes keep zero out-degree (paper Table 4).
    """
    rng = np.random.default_rng(seed)
    d_max = max(8, int(np.sqrt(n)))
    out_deg = _power_law_degrees(n, alpha, 1, d_max, rng)
    target_l = int(n * links_per_node)
    out_deg = np.round(out_deg * (target_l / out_deg.sum())).astype(np.int64)
    out_deg = np.maximum(out_deg, 1)
    if dangling_frac > 0:
        dangling = rng.choice(n, size=int(n * dangling_frac), replace=False)
        out_deg[dangling] = 0

    src = np.repeat(np.arange(n, dtype=np.int64), out_deg)
    host = src // host_size
    n_hosts = -(-n // host_size)
    intra = rng.random(src.shape[0]) < intra_frac
    # intra-host: uniform slot inside the source's host block
    dst_intra = host * host_size + rng.integers(0, host_size, src.shape[0])
    # inter-host: a nearby host (crawl-order neighbourhood)
    hop = rng.integers(-span_hosts, span_hosts + 1, src.shape[0])
    h2 = np.clip(host + hop, 0, n_hosts - 1)
    dst_inter = h2 * host_size + rng.integers(0, host_size, src.shape[0])
    dst = np.where(intra, dst_intra, dst_inter)
    dst = np.minimum(dst, n - 1)
    keep = src != dst
    src, dst = src[keep], dst[keep]
    key = src * n + dst
    _, uniq = np.unique(key, return_index=True)
    src, dst = src[uniq], dst[uniq]
    w = np.ones(src.shape[0], dtype=np.float64)
    return CSRGraph.from_edges(src.astype(np.int32), dst.astype(np.int32), w, n)


def pagerank_system(
    g: CSRGraph, damping: float = 0.85
) -> Tuple[CSRGraph, np.ndarray]:
    """PageRank instance of X = P X + B on graph ``g``.

    P[j, i] = damping / out_deg(i) for each edge i->j; B = (1-damping)/N.
    Dangling fluid is absorbed into history (standard D-iteration treatment;
    DESIGN.md §1).  Returns (P_graph, B).
    """
    out_deg = g.out_degree().astype(np.float64)
    src, dst, _ = g.edge_list()
    w = damping / out_deg[src]
    p = CSRGraph.from_edges(src, dst, w, g.n)
    b = np.full(g.n, (1.0 - damping) / g.n, dtype=np.float64)
    return p, b


def random_dd_system(
    n: int, density: float = 0.05, rho: float = 0.8, seed: int = 0,
    signed: bool = True,
) -> Tuple[CSRGraph, np.ndarray]:
    """Random diagonally-dominant system (spectral radius <= rho) for tests.

    Entries may be signed (the paper's general case, §2).  Column sums of |P|
    are scaled to ``rho`` so convergence of the diffusion is guaranteed.
    """
    rng = np.random.default_rng(seed)
    mask = rng.random((n, n)) < density
    np.fill_diagonal(mask, False)
    vals = rng.standard_normal((n, n)) if signed else rng.random((n, n))
    p = np.where(mask, vals, 0.0)
    col_norm = np.abs(p).sum(axis=0)
    scale = np.where(col_norm > 0, rho / np.maximum(col_norm, 1e-12), 0.0)
    p = p * scale[None, :]
    # to out-adjacency CSR: edges i->j where p[j, i] != 0
    dst, src = np.nonzero(p)  # p[dst, src]
    w = p[dst, src]
    g = CSRGraph.from_edges(
        src.astype(np.int32), dst.astype(np.int32), w.astype(np.float64), n
    )
    b = rng.standard_normal(n) if signed else rng.random(n)
    return g, b.astype(np.float64)
