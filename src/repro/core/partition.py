"""Partition strategies for the distributed D-iteration (paper §2.5).

Three strategies:

* :func:`uniform_partition` — Ω_k are contiguous equal-node-count ranges
  (§2.5.1, "Uniform partition").
* :func:`cb_partition` — Cost-Balanced: contiguous ranges with (approximately)
  equal out-degree sums Σ#out = L/K (§2.5.1, "CB partition").
* :class:`DynamicController` — the paper's contribution (§2.5.2): a
  measurement-driven controller that equalizes per-PID convergence *slopes*
  by moving nodes from the slowest PID to the fastest one, with a cooldown
  to damp oscillation.  It is deliberately ignorant of the graph structure —
  the whole point of the paper is that load balance emerges from the
  *observed* residual decay rates alone.

The controller is reused at three levels of the system through the
:mod:`repro.balance` control plane (DESIGN.md §5/§6), where it is wrapped
as ``SlopeEMAPolicy`` and its decisions travel as granularity-agnostic
``MovePlan``\\ s:

1. node-granular in the faithful simulator (paper-exact reproduction),
2. bucket-granular in the production distributed solver (static shapes),
3. device-granular in the runtime as a straggler/elastic policy (a
   straggling host is exactly a "slow PID") and expert-granular as the
   MoE rebalancer (a hot expert is exactly an overloaded Ω_k).

This module keeps only the paper-exact primitives (§2.5.1 static
partitions, the §2.5.2 slope-EMA update, :func:`apply_move`); policy
plumbing, alternative policies, and executors live in
:mod:`repro.balance`.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "uniform_partition",
    "cb_partition",
    "partition_bounds_to_sets",
    "DynamicControllerConfig",
    "DynamicController",
    "MoveInstruction",
    "slope_ema_update",
]


# ------------------------------------------------------------------------------
# Static partitions (§2.5.1)
# ------------------------------------------------------------------------------
def uniform_partition(n: int, k: int) -> List[np.ndarray]:
    """Ω_1 = {0..N/K-1}, Ω_2 = {N/K..2N/K-1}, ... (paper uses 1-based ids)."""
    bounds = np.linspace(0, n, k + 1).astype(np.int64)
    return [np.arange(bounds[i], bounds[i + 1], dtype=np.int64) for i in range(k)]


def cb_partition(out_deg: np.ndarray, k: int) -> List[np.ndarray]:
    """Cost-Balanced contiguous partition: Σ_{n∈Ω_k} #out_n ≈ L/K.

    Greedy boundary placement on the cumulative out-degree curve — the paper
    chose CB "for the simplicity of its computation"; we match that spirit:
    boundary ω_{k+1} is the first node where the running cost reaches k·L/K.
    Dangling nodes (deg 0) still cost one op to absorb, so they are counted
    with weight 1 (cost model §2.3/§2.4).
    """
    n = out_deg.shape[0]
    cost = np.maximum(out_deg.astype(np.float64), 1.0)
    cum = np.concatenate([[0.0], np.cumsum(cost)])
    total = cum[-1]
    bounds = [0]
    for i in range(1, k):
        target = total * i / k
        # first index where cumulative cost >= target, at least 1 past previous
        b = int(np.searchsorted(cum, target))
        b = min(max(b, min(bounds[-1] + 1, n)), max(n - (k - i), 0))
        b = max(b, bounds[-1])  # k > n: allow empty tail sets
        bounds.append(b)
    bounds.append(n)
    return [np.arange(bounds[i], bounds[i + 1], dtype=np.int64) for i in range(k)]


def partition_bounds_to_sets(bounds: Sequence[int]) -> List[np.ndarray]:
    return [
        np.arange(bounds[i], bounds[i + 1], dtype=np.int64)
        for i in range(len(bounds) - 1)
    ]


# ------------------------------------------------------------------------------
# Dynamic partition controller (§2.5.2) — the paper's contribution
# ------------------------------------------------------------------------------
@dataclasses.dataclass
class DynamicControllerConfig:
    """Paper defaults, §2.5.2."""

    k: int
    target_error: float
    eta: float = 0.5  # EMA factor η
    z: int = 10  # cooldown steps Z
    max_move_frac: float = 0.1  # min(·, 0.1) cap on the moved fraction
    # trigger: slope_min < slope_max + log10(0.5)  («difference more than 50%»)
    trigger_log10: float = math.log10(0.5)

    @property
    def eps_c(self) -> float:
        """ε' = target_error/K/1000 — keeps log defined when r+s → 0."""
        return self.target_error / self.k / 1000.0


@dataclasses.dataclass
class MoveInstruction:
    """«move n_move units from PID src to PID dst» (src is the slowest)."""

    src: int  # i_min — slowest PID (smallest slope = largest residual exponent)
    dst: int  # i_max — fastest PID
    n_move: int  # |Ω_src| · min((slope_min+1)/(slope_max+1), 0.1)


def slope_ema_update(slope: np.ndarray, r_plus_s: np.ndarray,
                     eta: float, eps_c: float) -> np.ndarray:
    """The §2.5.2 slope update, shared by every slope-based policy::

        slope_k := slope_k·(1−η) − log10(r_k + s_k + ε')·η
    """
    r_plus_s = np.asarray(r_plus_s, dtype=np.float64)
    return slope * (1.0 - eta) - np.log10(r_plus_s + eps_c) * eta


class DynamicController:
    """Slope-EMA load balancer (paper §2.5.2), unit-agnostic.

    Feed it the per-PID residual magnitude ``r_k + s_k`` (or any positive
    per-worker progress signal: per-expert token counts, per-device step
    times) once per time step together with the current per-PID set sizes;
    it returns a :class:`MoveInstruction` when the imbalance rule fires.

    Paper-exact update::

        slope_k := slope_k·(1−η) − log10(r_k + s_k + ε')·η          (EMA)
        fire iff slope_min < slope_max + log10(0.5)                 (50% rule)
        n_move = |Ω_imin| · min((slope_min+1)/(slope_max+1), 0.1)
        cooldown: modified sets frozen for Z steps

    ``−slope_k`` tracks the exponent of the residual, so *larger* slope =
    *faster* convergence; i_min is the slowest PID and sheds load.
    """

    def __init__(self, cfg: DynamicControllerConfig):
        self.cfg = cfg
        self.slope = np.zeros(cfg.k, dtype=np.float64)
        self.cooldown = np.zeros(cfg.k, dtype=np.int64)
        self.n_updates = 0
        self.n_moves = 0

    def update(
        self, r_plus_s: np.ndarray, set_sizes: np.ndarray
    ) -> Optional[MoveInstruction]:
        cfg = self.cfg
        self.slope = slope_ema_update(self.slope, r_plus_s, cfg.eta,
                                      cfg.eps_c)
        self.n_updates += 1
        self.cooldown = np.maximum(self.cooldown - 1, 0)

        eligible = np.nonzero(self.cooldown == 0)[0]
        if eligible.size < 2:
            return None
        i_min = int(eligible[np.argmin(self.slope[eligible])])
        i_max = int(eligible[np.argmax(self.slope[eligible])])
        if i_min == i_max:
            return None
        s_min, s_max = self.slope[i_min], self.slope[i_max]
        if not (s_min < s_max + cfg.trigger_log10):
            return None
        ratio = (s_min + 1.0) / (s_max + 1.0) if (s_max + 1.0) != 0 else 1.0
        frac = min(max(ratio, 0.0), cfg.max_move_frac)
        n_move = int(set_sizes[i_min] * frac)
        if n_move < 1:
            return None
        self.cooldown[i_min] = cfg.z
        self.cooldown[i_max] = cfg.z
        self.n_moves += 1
        return MoveInstruction(src=i_min, dst=i_max, n_move=n_move)

    def reset_pid(self, k: int) -> None:
        """Re-seed a PID's slope after an external event (elastic join/leave)."""
        self.slope[k] = 0.0
        self.cooldown[k] = self.cfg.z


def apply_move(
    sets: List[np.ndarray], move: MoveInstruction
) -> Tuple[List[np.ndarray], int]:
    """Move the *tail* nodes of Ω_src to Ω_dst (boundary nodes for contiguous
    partitions — matches the boundary evolution in paper Fig 4/9).

    Returns the new sets and the number of nodes actually moved (≤ n_move,
    never emptying the source).  Reassignment cost is charged by the caller
    (§2.4: count_active += nodes modified, to both PIDs).
    """
    src_set = sets[move.src]
    n_move = min(move.n_move, max(src_set.size - 1, 0))
    if n_move == 0:
        return sets, 0
    moved, kept = src_set[-n_move:], src_set[:-n_move]
    new_sets = list(sets)
    new_sets[move.src] = kept
    # keep destination sorted so its cyclic sweep order stays deterministic
    new_sets[move.dst] = np.sort(np.concatenate([sets[move.dst], moved]))
    return new_sets, int(n_move)
