"""Pure-jnp oracle for the BSR fluid-push kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "bsr_spmm_ref",
    "frontier_round_ref",
    "csr_to_bsr",
    "dense_to_bsr",
]


@functools.partial(jax.jit, static_argnames=("n_row_blocks",))
def bsr_spmm_ref(
    blocks: jax.Array,  # [n_blocks, bs, bs]
    block_row: jax.Array,  # [n_blocks]
    block_col: jax.Array,  # [n_blocks]
    x: jax.Array,  # [n_col_blocks, bs, C]
    n_row_blocks: int,
) -> jax.Array:
    """delta[r] = sum_{i: block_row[i]==r} blocks[i] @ x[block_col[i]]."""
    partial = jnp.einsum(
        "bij,bjc->bic", blocks, x[block_col]
    )  # [n_blocks, bs, C]
    return jax.ops.segment_sum(partial, block_row, num_segments=n_row_blocks)


def frontier_round_ref(
    blocks: np.ndarray,  # [n_blocks, bs, bs]
    block_row: np.ndarray,  # [n_blocks]
    block_col: np.ndarray,  # [n_blocks]
    f: np.ndarray,  # [n] or [n, C] residual fluid (n = n_row_blocks * bs)
    w: np.ndarray,  # [n] selection weights
    t: float,  # threshold
):
    """Pure-numpy twin of the fused frontier round (oracle for the kernel).

    Returns ``(f_new, sent, res)`` where ``f_new = F - sent + P @ sent``,
    ``sent = where(|F| * w > t, F, 0)`` and ``res = |f_new|_1``.
    """
    squeeze = f.ndim == 1
    f2 = f[:, None] if squeeze else f
    bs = blocks.shape[1]
    sel = np.abs(f2) * w[:, None] > t
    sent = np.where(sel, f2, 0.0)
    xt = sent.reshape(-1, bs, f2.shape[1])
    partial = np.einsum("bij,bjc->bic", blocks, xt[block_col])
    delta = np.zeros_like(xt)
    np.add.at(delta, block_row, partial)
    f_new = (f2 - sent) + delta.reshape(f2.shape)
    res = float(np.abs(f_new).sum())
    if squeeze:
        return f_new[:, 0], sent[:, 0], res
    return f_new, sent, res


def dense_to_bsr(p: np.ndarray, bs: int):
    """Dense [N, M] -> (blocks, block_row, block_col) keeping nonzero tiles.

    Rows/cols are zero-padded to multiples of ``bs``; block_row is sorted.
    """
    n, m = p.shape
    nr = -(-n // bs)
    nc = -(-m // bs)
    pad = np.zeros((nr * bs, nc * bs), dtype=p.dtype)
    pad[:n, :m] = p
    tiles = pad.reshape(nr, bs, nc, bs).transpose(0, 2, 1, 3)
    occ = np.abs(tiles).sum(axis=(2, 3)) > 0
    rows, cols = np.nonzero(occ)  # row-major order => sorted by row
    blocks = tiles[rows, cols]
    if blocks.shape[0] == 0:  # degenerate all-zero matrix
        blocks = np.zeros((1, bs, bs), dtype=p.dtype)
        rows = np.zeros(1, dtype=np.int64)
        cols = np.zeros(1, dtype=np.int64)
    return (
        blocks.astype(np.float32),
        rows.astype(np.int32),
        cols.astype(np.int32),
    )


def csr_to_bsr(indptr: np.ndarray, indices: np.ndarray, weights: np.ndarray,
               n: int, bs: int):
    """Out-adjacency CSR of P (edges i->j, weight P[j,i]) -> BSR of P.

    P[j, i] lives in block (j // bs, i // bs).  Returns
    (blocks [n_blocks, bs, bs], block_row, block_col, n_row_blocks).
    """
    nb = -(-n // bs)
    src = np.repeat(np.arange(n, dtype=np.int64), np.diff(indptr))
    dst = indices.astype(np.int64)
    br = dst // bs
    bc = src // bs
    key = br * nb + bc
    order = np.argsort(key, kind="stable")
    src, dst, w, key = src[order], dst[order], weights[order], key[order]
    uniq, first = np.unique(key, return_index=True)
    n_blocks = uniq.shape[0] if uniq.shape[0] else 1
    blocks = np.zeros((n_blocks, bs, bs), dtype=np.float32)
    block_of_edge = np.searchsorted(uniq, key)
    blocks[block_of_edge, dst % bs, src % bs] += w
    block_row = (uniq // nb).astype(np.int32)
    block_col = (uniq % nb).astype(np.int32)
    if uniq.shape[0] == 0:
        block_row = np.zeros(1, dtype=np.int32)
        block_col = np.zeros(1, dtype=np.int32)
    return blocks, block_row, block_col, nb
