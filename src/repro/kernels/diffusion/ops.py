"""Public jit'd wrapper for the BSR diffusion push.

Chooses the Pallas kernel on TPU and interpret-mode / jnp oracle elsewhere,
and masks never-visited output row blocks (the kernel leaves them
uninitialised by design — revisiting-output accumulation only touches rows
that own at least one block).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .kernel import bsr_spmm_pallas, frontier_round_bsr_pallas
from .ref import bsr_spmm_ref, csr_to_bsr

__all__ = ["bsr_spmm", "frontier_round_bsr", "prepare_bsr", "BsrMatrix"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


class BsrMatrix:
    """Host-prepared BSR operand: static structure + device arrays."""

    def __init__(self, blocks, block_row, block_col, n_row_blocks, bs):
        self.blocks = jnp.asarray(blocks)
        self.block_row = jnp.asarray(block_row, dtype=jnp.int32)
        self.block_col = jnp.asarray(block_col, dtype=jnp.int32)
        self.n_row_blocks = int(n_row_blocks)
        self.bs = int(bs)
        occ = np.zeros(n_row_blocks, dtype=bool)
        occ[np.asarray(block_row)] = True
        self.row_occupied = jnp.asarray(occ)

    @property
    def n_blocks(self) -> int:
        return int(self.blocks.shape[0])

    @property
    def density(self) -> float:
        return self.n_blocks / max(self.n_row_blocks**2, 1)


def prepare_bsr(indptr, indices, weights, n, bs=128) -> BsrMatrix:
    blocks, br, bc, nrb = csr_to_bsr(
        np.asarray(indptr), np.asarray(indices), np.asarray(weights), n, bs
    )
    return BsrMatrix(blocks, br, bc, nrb, bs)


def bsr_spmm(
    m: BsrMatrix,
    x: jax.Array,  # [n_col_blocks*bs] or [n_col_blocks*bs, C]
    *,
    use_pallas: bool | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """delta = P @ x with P in BSR form.  Returns same leading shape as x."""
    squeeze = x.ndim == 1
    if squeeze:
        x = x[:, None]
    c = x.shape[1]
    xt = x.reshape(-1, m.bs, c)
    if use_pallas is None:
        use_pallas = True
    if interpret is None:
        interpret = not _on_tpu()
    if use_pallas:
        out = bsr_spmm_pallas(
            m.blocks, m.block_row, m.block_col, xt, m.n_row_blocks,
            bs=m.bs, interpret=interpret,
        )
        out = jnp.where(m.row_occupied[:, None, None], out, 0.0)
    else:
        out = bsr_spmm_ref(
            m.blocks, m.block_row, m.block_col, xt, m.n_row_blocks
        )
    out = out.reshape(-1, c)
    return out[:, 0] if squeeze else out


def frontier_round_bsr(
    m: BsrMatrix,
    f: jax.Array,  # [n] or [n, C] residual fluid, n = n_row_blocks * bs
    w: jax.Array,  # [n] selection weights (0 = padding / inert slot)
    t: jax.Array,  # scalar threshold (traced value is fine)
    *,
    backend: str | None = None,  # None/"auto" | "pallas" | "block"
    interpret: bool | None = None,
    buffer_depth: int = 1,
    occupancy_threshold: float = 0.0,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One fused frontier round ``F' = F - sent + P @ sent`` over BSR ``m``.

    ``sent = where(|F| * w > t, F, 0)`` — every node above the threshold
    diffuses simultaneously (the frontier-batched D-iteration schedule).
    Returns ``(f_new, sent, res)`` with ``res = |f_new|_1`` (scalar).

    ``buffer_depth`` (pallas backend only) selects the tile-fetch pipeline
    depth — pure data movement, bit-identical results across depths.

    ``occupancy_threshold`` defers sparse block columns: a column block whose
    fraction of above-threshold nodes is <= the threshold keeps its fluid
    this round and diffuses later (the D-iteration schedule permits any
    subset of nodes to fire per round, so this is exact, not approximate —
    deferred fluid is *kept*, never dropped).  0.0 (default) arms every
    column with at least one above-threshold node, the historical behaviour.

    Backends:

    * ``pallas`` — the fused TPU kernel: masking, the block-column occupancy
      skip, and the per-row residual reduction run inside one grid sweep;
      block rows with no tiles fall back to the kept fluid via the
      row-occupancy epilogue (the kernel leaves them uninitialised).
    * ``block`` — jnp oracle (einsum over tiles + segment-sum), the fast
      path on CPU where interpret-mode Pallas is emulation-speed.
    * ``auto``/None — pallas on TPU, block elsewhere.
    """
    squeeze = f.ndim == 1
    f2 = f[:, None] if squeeze else f
    c = f2.shape[1]
    if backend in (None, "auto"):
        backend = "pallas" if _on_tpu() else "block"
    if backend == "pallas":
        # the kernel folds the threshold into the weights (wt = w/t, select
        # when |f|*wt > 1); the wrapper MUST use the identical rounded
        # predicate or a boundary node could be "sent" by one side and
        # "kept" by the other, double-counting or losing its fluid.
        wt_flat = (w / t).astype(f2.dtype)
        sel = jnp.abs(f2) * wt_flat[:, None] > 1.0
    else:
        sel = jnp.abs(f2) * w[:, None] > t
    blk = sel.reshape(-1, m.bs * c)
    if occupancy_threshold > 0.0:
        frac = jnp.mean(blk.astype(f2.dtype), axis=1)
        col_active = (frac > occupancy_threshold).astype(jnp.int32)
        # only nodes in armed columns fire; the rest keep their fluid.
        sel = jnp.logical_and(
            sel, (col_active != 0).repeat(m.bs)[:, None]
        )
    else:
        col_active = jnp.any(blk, axis=1).astype(jnp.int32)
    sent = jnp.where(sel, f2, jnp.zeros_like(f2))
    if backend == "block":
        xt = sent.reshape(-1, m.bs, c)
        delta = bsr_spmm_ref(
            m.blocks, m.block_row, m.block_col, xt, m.n_row_blocks
        )
        f_new = (f2 - sent) + delta.reshape(f2.shape)
        res = jnp.sum(jnp.abs(f_new))
    elif backend == "pallas":
        if interpret is None:
            interpret = not _on_tpu()
        ft = f2.reshape(-1, m.bs, c)
        wt = wt_flat.reshape(-1, m.bs, 1)
        out, row_l1 = frontier_round_bsr_pallas(
            m.blocks.astype(f2.dtype), m.block_row, m.block_col, col_active,
            ft, wt, m.n_row_blocks, bs=m.bs, interpret=interpret,
            buffer_depth=buffer_depth,
        )
        # rows owning no block never get their output tile initialised:
        # substitute the kept fluid (F - sent) and its |·|_1 there.
        keep = (f2 - sent).reshape(-1, m.bs, c)
        occ = m.row_occupied
        f_new = jnp.where(occ[:, None, None], out, keep).reshape(f2.shape)
        keep_l1 = jnp.sum(jnp.abs(keep), axis=(1, 2))
        res = jnp.sum(jnp.where(occ, row_l1[:, 0], keep_l1))
    else:
        raise ValueError(f"unknown frontier backend {backend!r}")
    if squeeze:
        return f_new[:, 0], sent[:, 0], res
    return f_new, sent, res
