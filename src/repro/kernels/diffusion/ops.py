"""Public jit'd wrapper for the BSR diffusion push.

Chooses the Pallas kernel on TPU and interpret-mode / jnp oracle elsewhere,
and masks never-visited output row blocks (the kernel leaves them
uninitialised by design — revisiting-output accumulation only touches rows
that own at least one block).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .kernel import bsr_spmm_pallas
from .ref import bsr_spmm_ref, csr_to_bsr

__all__ = ["bsr_spmm", "prepare_bsr", "BsrMatrix"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


class BsrMatrix:
    """Host-prepared BSR operand: static structure + device arrays."""

    def __init__(self, blocks, block_row, block_col, n_row_blocks, bs):
        self.blocks = jnp.asarray(blocks)
        self.block_row = jnp.asarray(block_row, dtype=jnp.int32)
        self.block_col = jnp.asarray(block_col, dtype=jnp.int32)
        self.n_row_blocks = int(n_row_blocks)
        self.bs = int(bs)
        occ = np.zeros(n_row_blocks, dtype=bool)
        occ[np.asarray(block_row)] = True
        self.row_occupied = jnp.asarray(occ)

    @property
    def n_blocks(self) -> int:
        return int(self.blocks.shape[0])

    @property
    def density(self) -> float:
        return self.n_blocks / max(self.n_row_blocks**2, 1)


def prepare_bsr(indptr, indices, weights, n, bs=128) -> BsrMatrix:
    blocks, br, bc, nrb = csr_to_bsr(
        np.asarray(indptr), np.asarray(indices), np.asarray(weights), n, bs
    )
    return BsrMatrix(blocks, br, bc, nrb, bs)


def bsr_spmm(
    m: BsrMatrix,
    x: jax.Array,  # [n_col_blocks*bs] or [n_col_blocks*bs, C]
    *,
    use_pallas: bool | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """delta = P @ x with P in BSR form.  Returns same leading shape as x."""
    squeeze = x.ndim == 1
    if squeeze:
        x = x[:, None]
    c = x.shape[1]
    xt = x.reshape(-1, m.bs, c)
    if use_pallas is None:
        use_pallas = True
    if interpret is None:
        interpret = not _on_tpu()
    if use_pallas:
        out = bsr_spmm_pallas(
            m.blocks, m.block_row, m.block_col, xt, m.n_row_blocks,
            bs=m.bs, interpret=interpret,
        )
        out = jnp.where(m.row_occupied[:, None, None], out, 0.0)
    else:
        out = bsr_spmm_ref(
            m.blocks, m.block_row, m.block_col, xt, m.n_row_blocks
        )
    out = out.reshape(-1, c)
    return out[:, 0] if squeeze else out
