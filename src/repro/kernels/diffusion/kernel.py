"""Block-sparse (BSR) fluid-push kernel — the D-iteration hot loop on TPU.

The paper's elementary operation is a scalar push ``F[j] += sent * P[j, i]``.
A TPU has no efficient scalar scatter; the TPU-native adaptation (DESIGN.md
§3) preprocesses P into Block-Sparse-Row form — ``n_blocks`` dense
``[bs, bs]`` tiles, each tagged with its (block_row, block_col) — and turns
one frontier round into a sequence of dense tile matmuls on the MXU:

    delta[block_row] += P_block @ sent[block_col]

Grid: one step per nonzero block, sorted by block_row.  The output tile for
a block row stays resident in VMEM across all its blocks (revisiting output
pattern); it is zero-initialised on first visit.  Block coordinates arrive
via scalar prefetch (``PrefetchScalarGridSpec``) so the BlockSpec index_maps
can route HBM→VMEM DMAs for exactly the tiles the sparse structure touches.

Supports a multi-source right-hand side ``x: [n_col_blocks*bs, C]`` so many
diffusion vectors (e.g. personalized-PageRank columns) share one sweep of
the sparse structure; ``C = 1`` is the paper's case but wider C raises
arithmetic intensity from O(1) to O(C) per weight byte.

DMA pipelining (``buffer_depth``): the tile pool is the dominant byte
stream (``bs*bs`` weights vs ``bs*C`` fluid per step, and C is small).
With ``buffer_depth == 1`` the tile fetch rides Pallas's automatic
double-buffered BlockSpec pipeline.  With ``buffer_depth >= 2`` the tile
operand stays in HBM (``memory_space=ANY``) and the kernel rotates manual
async copies through a ``[depth, bs, bs]`` VMEM ring: step ``i`` computes
out of slot ``i % depth`` while the DMAs for steps ``i+1 .. i+depth-1``
are already in flight.  The occupancy skip composes with the ring — a
block column with no fluid above threshold never has its DMA *started*,
so inactive tiles cost neither bytes nor MXU issue slots.  Both paths
execute the identical accumulation order, so results are bit-identical
across depths (test-enforced).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = [
    "bsr_spmm_pallas",
    "bsr_gather_spmm_pallas",
    "frontier_round_bsr_pallas",
]


def _kernel(block_row_ref, block_col_ref, blocks_ref, x_ref, o_ref):
    """One grid step: o[block_row[i]] += blocks[i] @ x[block_col[i]]."""
    i = pl.program_id(0)

    is_first = i == 0
    new_row = block_row_ref[i] != block_row_ref[jnp.maximum(i - 1, 0)]

    @pl.when(jnp.logical_or(is_first, new_row))
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        blocks_ref[0], x_ref[0], preferred_element_type=o_ref.dtype
    )


@functools.partial(
    jax.jit, static_argnames=("n_row_blocks", "interpret", "bs")
)
def bsr_spmm_pallas(
    blocks: jax.Array,  # [n_blocks, bs, bs]   dense tiles of P
    block_row: jax.Array,  # [n_blocks] int32, sorted ascending
    block_col: jax.Array,  # [n_blocks] int32
    x: jax.Array,  # [n_col_blocks, bs, C]  (sent fluid, tiled)
    n_row_blocks: int,
    *,
    bs: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """delta = P_bsr @ x, returns [n_row_blocks, bs, C].

    ``blocks[i]`` holds P[rows of block_row[i], cols of block_col[i]] with
    layout ``blocks[i][r, c] = P[block_row[i]*bs + r, block_col[i]*bs + c]``.

    Requires block_row sorted; empty block rows are fine (their output tile
    is zeroed by the epilogue wrapper in ops.py).
    """
    n_blocks = blocks.shape[0]
    c = x.shape[-1]
    out_shape = jax.ShapeDtypeStruct((n_row_blocks, bs, c), x.dtype)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # block_row, block_col
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((1, bs, bs), lambda i, br, bc: (i, 0, 0)),
            pl.BlockSpec((1, bs, c), lambda i, br, bc: (bc[i], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bs, c), lambda i, br, bc: (br[i], 0, 0)),
    )
    fn = pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )
    # output blocks never visited keep uninitialised garbage; mask them in
    # ops.py via the row-occupancy map (cheap [n_row_blocks] bool).
    return fn(block_row, block_col, blocks, x)


# --------------------------------------------------------------------------- #
# gather-indirection SpMM: tiles stay in a row-owned layout (the distributed
# engine permutes them with bucket moves); a per-round visit order — sorted by
# destination block — arrives through scalar prefetch, so the same revisiting-
# output accumulation works without ever materialising a gathered/sorted copy
# of the tile array in HBM.
# --------------------------------------------------------------------------- #
def _gather_kernel(visit_block_ref, visit_row_ref, visit_col_ref,
                   blocks_ref, x_ref, o_ref):
    """Step i: o[visit_row[i]] += blocks[visit_block[i]] @ x[visit_col[i]]."""
    i = pl.program_id(0)
    is_first = i == 0
    new_row = visit_row_ref[i] != visit_row_ref[jnp.maximum(i - 1, 0)]

    @pl.when(jnp.logical_or(is_first, new_row))
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        blocks_ref[0], x_ref[0], preferred_element_type=o_ref.dtype
    )


def _gather_kernel_dma(visit_block_ref, visit_row_ref, visit_col_ref,
                       blocks_hbm_ref, x_ref, o_ref, buf_ref, sem_ref,
                       *, n_visits: int, depth: int):
    """Manual-DMA twin of :func:`_gather_kernel` (``buffer_depth >= 2``).

    The tile pool stays in HBM; a ``[depth, bs, bs]`` VMEM ring holds the
    in-flight gathers.  Step ``i`` waits on slot ``i % depth``, multiplies,
    then immediately reuses the slot to start the copy for step
    ``i + depth`` — so up to ``depth`` tile gathers overlap the MXU work.
    """
    i = pl.program_id(0)
    is_first = i == 0
    new_row = visit_row_ref[i] != visit_row_ref[jnp.maximum(i - 1, 0)]

    def tile_dma(slot, step):
        return pltpu.make_async_copy(
            blocks_hbm_ref.at[visit_block_ref[step]],
            buf_ref.at[slot],
            sem_ref.at[slot],
        )

    @pl.when(is_first)
    def _warmup():
        for d in range(min(depth, n_visits)):
            tile_dma(d, d).start()

    @pl.when(jnp.logical_or(is_first, new_row))
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    slot = jax.lax.rem(i, depth)
    tile_dma(slot, i).wait()
    o_ref[...] += jnp.dot(
        buf_ref[slot], x_ref[0], preferred_element_type=o_ref.dtype
    )

    nxt = jnp.minimum(i + depth, n_visits - 1)

    @pl.when(i + depth < n_visits)
    def _prefetch():
        tile_dma(slot, nxt).start()


@functools.partial(
    jax.jit,
    static_argnames=("n_row_blocks", "interpret", "bs", "buffer_depth"),
)
def bsr_gather_spmm_pallas(
    blocks: jax.Array,  # [n_tiles, bs, bs] row-owned tile pool (any order)
    visit_block: jax.Array,  # [V] int32 index into ``blocks``
    visit_row: jax.Array,  # [V] int32 destination block row, sorted ascending
    visit_col: jax.Array,  # [V] int32 source block col of each visit
    x: jax.Array,  # [n_col_blocks, bs, C]
    n_row_blocks: int,
    *,
    bs: int,
    interpret: bool = False,
    buffer_depth: int = 1,
) -> jax.Array:
    """delta = sum_i blocks[visit_block[i]] @ x[visit_col[i]] into visit_row[i].

    The visit arrays may be computed in-graph (e.g. ``argsort`` of the
    destination ids each round) — scalar prefetch takes traced values.
    Rows never visited keep uninitialised garbage; callers mask them with the
    visit-derived row-occupancy map.

    ``buffer_depth`` selects the tile-fetch strategy: 1 = automatic BlockSpec
    pipelining, >= 2 = a manual ``depth``-deep async-copy ring (see module
    docstring).  Results are bit-identical across depths.
    """
    if buffer_depth < 1:
        raise ValueError(f"buffer_depth must be >= 1, got {buffer_depth}")
    v = visit_block.shape[0]
    c = x.shape[-1]
    out_shape = jax.ShapeDtypeStruct((n_row_blocks, bs, c), x.dtype)
    if buffer_depth == 1:
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,  # visit_block, visit_row, visit_col
            grid=(v,),
            in_specs=[
                pl.BlockSpec((1, bs, bs), lambda i, vb, vr, vc: (vb[i], 0, 0)),
                pl.BlockSpec((1, bs, c), lambda i, vb, vr, vc: (vc[i], 0, 0)),
            ],
            out_specs=pl.BlockSpec(
                (1, bs, c), lambda i, vb, vr, vc: (vr[i], 0, 0)
            ),
        )
        body = _gather_kernel
    else:
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(v,),
            in_specs=[
                pl.BlockSpec(memory_space=pltpu.ANY),  # tile pool stays in HBM
                pl.BlockSpec((1, bs, c), lambda i, vb, vr, vc: (vc[i], 0, 0)),
            ],
            out_specs=pl.BlockSpec(
                (1, bs, c), lambda i, vb, vr, vc: (vr[i], 0, 0)
            ),
            scratch_shapes=[
                pltpu.VMEM((buffer_depth, bs, bs), blocks.dtype),
                pltpu.SemaphoreType.DMA((buffer_depth,)),
            ],
        )
        body = functools.partial(
            _gather_kernel_dma, n_visits=v, depth=buffer_depth
        )
    fn = pl.pallas_call(
        body,
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )
    return fn(visit_block, visit_row, visit_col, blocks, x)


# --------------------------------------------------------------------------- #
# fused frontier round: threshold masking of the sent fluid, the block-column
# occupancy skip, and the per-block-row residual reduction all live inside the
# kernel, so one grid sweep turns F into F' = F - sent + P @ sent and emits the
# per-row |F'|_1 partial sums the solver's stopping rule needs.
# --------------------------------------------------------------------------- #
def _frontier_kernel(block_row_ref, block_col_ref, col_active_ref,
                     blocks_ref, f_col_ref, wt_col_ref, f_row_ref, wt_row_ref,
                     o_ref, l1_ref, *, n_blocks: int):
    """Grid step i (blocks sorted by block_row):

    * first visit of a row: seed o with the row's kept fluid
      ``where(sel, 0, f)`` (the un-diffused residual) where
      ``sel = (|f| * wt > 1) & (col_active[row] != 0)`` — a node only counts
      as "sent" if its own block column is armed this round, so deferred
      columns (occupancy threshold) keep their fluid intact,
    * active column: accumulate ``blocks[i] @ sent(col)`` where
      ``sent = where(|f| * wt > 1, f, 0)`` is recomputed in-register —
      ``wt = w / T`` folds the threshold into the weights so no scalar
      operand is needed,
    * inactive column (no fluid above threshold anywhere in the col block —
      most tiles late in convergence — or deferred below the occupancy
      threshold): the matmul is skipped entirely,
    * last visit of a row: reduce ``|o|_1`` into the per-row residual output.
    """
    i = pl.program_id(0)
    row = block_row_ref[i]
    prev_row = block_row_ref[jnp.maximum(i - 1, 0)]
    next_row = block_row_ref[jnp.minimum(i + 1, n_blocks - 1)]
    first = jnp.logical_or(i == 0, row != prev_row)
    last = jnp.logical_or(i == n_blocks - 1, next_row != row)

    @pl.when(first)
    def _seed_kept_fluid():
        fr = f_row_ref[0]
        sel = jnp.logical_and(
            jnp.abs(fr) * wt_row_ref[0] > 1.0, col_active_ref[row] != 0
        )
        o_ref[0] = jnp.where(sel, jnp.zeros_like(fr), fr)

    @pl.when(col_active_ref[block_col_ref[i]] != 0)
    def _push():
        fc = f_col_ref[0]
        sent = jnp.where(jnp.abs(fc) * wt_col_ref[0] > 1.0, fc,
                         jnp.zeros_like(fc))
        o_ref[0] += jnp.dot(
            blocks_ref[0], sent, preferred_element_type=o_ref.dtype
        )

    @pl.when(last)
    def _row_residual():
        l1_ref[0, 0] = jnp.sum(jnp.abs(o_ref[0]))


def _frontier_kernel_dma(block_row_ref, block_col_ref, col_active_ref,
                         blocks_hbm_ref, f_col_ref, wt_col_ref, f_row_ref,
                         wt_row_ref, o_ref, l1_ref, buf_ref, sem_ref,
                         *, n_blocks: int, depth: int):
    """Manual-DMA twin of :func:`_frontier_kernel` (``buffer_depth >= 2``).

    The occupancy skip gates the *DMA* as well as the matmul: a tile whose
    block column carries no above-threshold fluid is never copied out of
    HBM.  Start and wait use the identical predicate, so every started copy
    is waited exactly once and slot ``j % depth`` is free again before step
    ``j + depth`` reuses it.
    """
    i = pl.program_id(0)
    row = block_row_ref[i]
    prev_row = block_row_ref[jnp.maximum(i - 1, 0)]
    next_row = block_row_ref[jnp.minimum(i + 1, n_blocks - 1)]
    first = jnp.logical_or(i == 0, row != prev_row)
    last = jnp.logical_or(i == n_blocks - 1, next_row != row)

    def tile_dma(slot, step):
        return pltpu.make_async_copy(
            blocks_hbm_ref.at[step], buf_ref.at[slot], sem_ref.at[slot]
        )

    def col_armed(step):
        return col_active_ref[block_col_ref[step]] != 0

    @pl.when(i == 0)
    def _warmup():
        for d in range(min(depth, n_blocks)):
            @pl.when(col_armed(d))
            def _start(d=d):
                tile_dma(d, d).start()

    @pl.when(first)
    def _seed_kept_fluid():
        fr = f_row_ref[0]
        sel = jnp.logical_and(
            jnp.abs(fr) * wt_row_ref[0] > 1.0, col_active_ref[row] != 0
        )
        o_ref[0] = jnp.where(sel, jnp.zeros_like(fr), fr)

    slot = jax.lax.rem(i, depth)

    @pl.when(col_armed(i))
    def _push():
        tile_dma(slot, i).wait()
        fc = f_col_ref[0]
        sent = jnp.where(jnp.abs(fc) * wt_col_ref[0] > 1.0, fc,
                         jnp.zeros_like(fc))
        o_ref[0] += jnp.dot(
            buf_ref[slot], sent, preferred_element_type=o_ref.dtype
        )

    # slot is free again (its copy was waited above, or never started);
    # immediately refill it with the tile this slot serves next.
    nxt = jnp.minimum(i + depth, n_blocks - 1)

    @pl.when(jnp.logical_and(i + depth < n_blocks, col_armed(nxt)))
    def _prefetch():
        tile_dma(slot, nxt).start()

    @pl.when(last)
    def _row_residual():
        l1_ref[0, 0] = jnp.sum(jnp.abs(o_ref[0]))


@functools.partial(
    jax.jit,
    static_argnames=("n_row_blocks", "interpret", "bs", "buffer_depth"),
)
def frontier_round_bsr_pallas(
    blocks: jax.Array,  # [n_blocks, bs, bs] dense tiles of P, row-sorted
    block_row: jax.Array,  # [n_blocks] int32, sorted ascending
    block_col: jax.Array,  # [n_blocks] int32
    col_active: jax.Array,  # [n_col_blocks] int32 occupancy of the frontier
    f: jax.Array,  # [n_col_blocks, bs, C] residual fluid, tiled
    wt: jax.Array,  # [n_col_blocks, bs, 1] selection weights / threshold
    n_row_blocks: int,
    *,
    bs: int = 128,
    interpret: bool = False,
    buffer_depth: int = 1,
) -> tuple[jax.Array, jax.Array]:
    """One fused frontier round over the BSR structure.

    Returns ``(f_new, row_l1)`` with ``f_new: [n_row_blocks, bs, C]`` holding
    ``F - sent + P @ sent`` for every *occupied* block row and
    ``row_l1: [n_row_blocks, 1]`` its per-row |·|_1.  Rows that own no block
    are left uninitialised (garbage) in BOTH outputs by design — the ops.py
    wrapper substitutes the kept fluid ``F - sent`` there via the
    row-occupancy map.  The square tiling (n_col_blocks == n_row_blocks)
    means the f/wt operands serve double duty: indexed by block_col for the
    sent gather and by block_row for the kept-fluid seeding.

    ``buffer_depth`` selects the tile-fetch strategy: 1 = automatic BlockSpec
    pipelining, >= 2 = a manual ``depth``-deep async-copy ring whose DMAs are
    occupancy-gated (see module docstring).  Bit-identical across depths.
    """
    if buffer_depth < 1:
        raise ValueError(f"buffer_depth must be >= 1, got {buffer_depth}")
    n_blocks = blocks.shape[0]
    c = f.shape[-1]
    out_shape = (
        jax.ShapeDtypeStruct((n_row_blocks, bs, c), f.dtype),
        jax.ShapeDtypeStruct((n_row_blocks, 1), f.dtype),
    )
    fluid_specs = [
        pl.BlockSpec((1, bs, c), lambda i, br, bc, ca: (bc[i], 0, 0)),
        pl.BlockSpec((1, bs, 1), lambda i, br, bc, ca: (bc[i], 0, 0)),
        pl.BlockSpec((1, bs, c), lambda i, br, bc, ca: (br[i], 0, 0)),
        pl.BlockSpec((1, bs, 1), lambda i, br, bc, ca: (br[i], 0, 0)),
    ]
    out_specs = (
        pl.BlockSpec((1, bs, c), lambda i, br, bc, ca: (br[i], 0, 0)),
        pl.BlockSpec((1, 1), lambda i, br, bc, ca: (br[i], 0)),
    )
    if buffer_depth == 1:
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,  # block_row, block_col, col_active
            grid=(n_blocks,),
            in_specs=[
                pl.BlockSpec((1, bs, bs), lambda i, br, bc, ca: (i, 0, 0)),
                *fluid_specs,
            ],
            out_specs=out_specs,
        )
        body = functools.partial(_frontier_kernel, n_blocks=n_blocks)
    else:
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(n_blocks,),
            in_specs=[
                pl.BlockSpec(memory_space=pltpu.ANY),  # tile pool stays in HBM
                *fluid_specs,
            ],
            out_specs=out_specs,
            scratch_shapes=[
                pltpu.VMEM((buffer_depth, bs, bs), blocks.dtype),
                pltpu.SemaphoreType.DMA((buffer_depth,)),
            ],
        )
        body = functools.partial(
            _frontier_kernel_dma, n_blocks=n_blocks, depth=buffer_depth
        )
    fn = pl.pallas_call(
        body,
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )
    return fn(block_row, block_col, col_active, blocks, f, wt, f, wt)
