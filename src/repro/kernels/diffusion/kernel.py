"""Block-sparse (BSR) fluid-push kernel — the D-iteration hot loop on TPU.

The paper's elementary operation is a scalar push ``F[j] += sent * P[j, i]``.
A TPU has no efficient scalar scatter; the TPU-native adaptation (DESIGN.md
§3) preprocesses P into Block-Sparse-Row form — ``n_blocks`` dense
``[bs, bs]`` tiles, each tagged with its (block_row, block_col) — and turns
one frontier round into a sequence of dense tile matmuls on the MXU:

    delta[block_row] += P_block @ sent[block_col]

Grid: one step per nonzero block, sorted by block_row.  The output tile for
a block row stays resident in VMEM across all its blocks (revisiting output
pattern); it is zero-initialised on first visit.  Block coordinates arrive
via scalar prefetch (``PrefetchScalarGridSpec``) so the BlockSpec index_maps
can route HBM→VMEM DMAs for exactly the tiles the sparse structure touches.

Supports a multi-source right-hand side ``x: [n_col_blocks*bs, C]`` so many
diffusion vectors (e.g. personalized-PageRank columns) share one sweep of
the sparse structure; ``C = 1`` is the paper's case but wider C raises
arithmetic intensity from O(1) to O(C) per weight byte.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["bsr_spmm_pallas"]


def _kernel(block_row_ref, block_col_ref, blocks_ref, x_ref, o_ref):
    """One grid step: o[block_row[i]] += blocks[i] @ x[block_col[i]]."""
    i = pl.program_id(0)

    is_first = i == 0
    new_row = block_row_ref[i] != block_row_ref[jnp.maximum(i - 1, 0)]

    @pl.when(jnp.logical_or(is_first, new_row))
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        blocks_ref[0], x_ref[0], preferred_element_type=o_ref.dtype
    )


@functools.partial(
    jax.jit, static_argnames=("n_row_blocks", "interpret", "bs")
)
def bsr_spmm_pallas(
    blocks: jax.Array,  # [n_blocks, bs, bs]   dense tiles of P
    block_row: jax.Array,  # [n_blocks] int32, sorted ascending
    block_col: jax.Array,  # [n_blocks] int32
    x: jax.Array,  # [n_col_blocks, bs, C]  (sent fluid, tiled)
    n_row_blocks: int,
    *,
    bs: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """delta = P_bsr @ x, returns [n_row_blocks, bs, C].

    ``blocks[i]`` holds P[rows of block_row[i], cols of block_col[i]] with
    layout ``blocks[i][r, c] = P[block_row[i]*bs + r, block_col[i]*bs + c]``.

    Requires block_row sorted; empty block rows are fine (their output tile
    is zeroed by the epilogue wrapper in ops.py).
    """
    n_blocks = blocks.shape[0]
    c = x.shape[-1]
    out_shape = jax.ShapeDtypeStruct((n_row_blocks, bs, c), x.dtype)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # block_row, block_col
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((1, bs, bs), lambda i, br, bc: (i, 0, 0)),
            pl.BlockSpec((1, bs, c), lambda i, br, bc: (bc[i], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bs, c), lambda i, br, bc: (br[i], 0, 0)),
    )
    fn = pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )
    # output blocks never visited keep uninitialised garbage; mask them in
    # ops.py via the row-occupancy map (cheap [n_row_blocks] bool).
    return fn(block_row, block_col, blocks, x)
