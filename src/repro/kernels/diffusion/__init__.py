from .ops import (  # noqa: F401
    BsrMatrix,
    bsr_spmm,
    frontier_round_bsr,
    prepare_bsr,
)
from .ref import (  # noqa: F401
    bsr_spmm_ref,
    csr_to_bsr,
    dense_to_bsr,
    frontier_round_ref,
)
from .kernel import (  # noqa: F401
    bsr_gather_spmm_pallas,
    bsr_spmm_pallas,
    frontier_round_bsr_pallas,
)
