from .ops import BsrMatrix, bsr_spmm, prepare_bsr  # noqa: F401
from .ref import bsr_spmm_ref, csr_to_bsr, dense_to_bsr  # noqa: F401
from .kernel import bsr_spmm_pallas  # noqa: F401
