"""Byte/flop cost model for the BSR diffusion kernels (roofline + VMEM).

The D-iteration hot loop is fluid movement: per grid step the kernels read
one ``[bs, bs]`` weight tile plus ``O(bs*C)`` fluid and do ``2*bs*bs*C``
flops, so arithmetic intensity is ~``C/2`` flops per byte — firmly
memory-bound for the paper's ``C = 1``.  This module is the single source
of truth for that model; the autotuner's feasibility check, the
``benchmarks/roofline.py`` table and the per-config ``roofline_fraction``
emitted into BENCH_kernels.json all derive from it.

Platform peak numbers are *nominal* datasheet values (TPU v5e for the tpu
entry); they anchor the roofline fraction, they are not measurements.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

__all__ = [
    "HwSpec",
    "PLATFORM_SPECS",
    "KernelCost",
    "frontier_round_cost",
    "gather_spmm_cost",
    "ideal_time_s",
    "dma_compute_ratio",
    "vmem_bytes",
    "vmem_ok",
    "roofline_fraction",
]


@dataclasses.dataclass(frozen=True)
class HwSpec:
    """Nominal hardware envelope used to anchor the roofline."""

    name: str
    peak_flops: float  # f32 (cpu/gpu) / bf16-MXU (tpu) peak, flop/s
    mem_bw: float  # main-memory bandwidth, bytes/s (HBM on tpu/gpu)
    vmem_budget: int  # fast-memory budget for kernel operands, bytes


PLATFORM_SPECS: Dict[str, HwSpec] = {
    # TPU v5e datasheet: 197 TFLOP/s bf16, 819 GB/s HBM, 128 MiB VMEM —
    # budget leaves headroom for the compiler's own buffers.
    "tpu": HwSpec("tpu-v5e", 197e12, 819e9, 64 * 2**20),
    # A100-class card: 19.5 TFLOP/s f32, 1.56 TB/s HBM2e.
    "gpu": HwSpec("gpu-a100", 19.5e12, 1.555e12, 48 * 2**20),
    # a few AVX2 cores — nominal, the CPU path is the jnp oracle anyway.
    "cpu": HwSpec("cpu-host", 2e11, 4e10, 32 * 2**20),
}


@dataclasses.dataclass(frozen=True)
class KernelCost:
    """Bytes moved / flops issued by one kernel sweep."""

    bytes_tiles: float  # the tile-pool stream (what buffer_depth pipelines)
    bytes_fluid: float  # f / wt / output traffic
    flops: float

    @property
    def total_bytes(self) -> float:
        return self.bytes_tiles + self.bytes_fluid

    @property
    def arithmetic_intensity(self) -> float:
        return self.flops / max(self.total_bytes, 1.0)


def frontier_round_cost(
    n_row_blocks: int,
    bs: int,
    c: int,
    n_blocks_active: int,
    dtype_bytes: int = 4,
) -> KernelCost:
    """One fused frontier round at a given frontier occupancy.

    ``n_blocks_active`` is the number of tiles whose block column is armed
    — the occupancy skip means inactive tiles cost *nothing* (no DMA, no
    matmul), which is why the model is parametric in the swept density.
    """
    bytes_tiles = float(n_blocks_active) * bs * bs * dtype_bytes
    # per active visit: f_col [bs, C] + wt_col [bs]; per block row: the
    # kept-fluid seed read (f_row + wt_row), the output write and the
    # per-row |.|_1 cell.
    bytes_fluid = (
        float(n_blocks_active) * (bs * c + bs)
        + float(n_row_blocks) * (2 * (bs * c + bs) + bs * c + 1)
    ) * dtype_bytes
    flops = 2.0 * n_blocks_active * bs * bs * c
    return KernelCost(bytes_tiles, bytes_fluid, flops)


def gather_spmm_cost(
    n_row_blocks: int,
    bs: int,
    c: int,
    n_visits: int,
    dtype_bytes: int = 4,
) -> KernelCost:
    """One gather-indirection SpMM sweep over ``n_visits`` tile visits."""
    bytes_tiles = float(n_visits) * bs * bs * dtype_bytes
    bytes_fluid = (
        float(n_visits) * bs * c + float(n_row_blocks) * bs * c
    ) * dtype_bytes
    flops = 2.0 * n_visits * bs * bs * c
    return KernelCost(bytes_tiles, bytes_fluid, flops)


def ideal_time_s(cost: KernelCost, spec: HwSpec) -> Tuple[float, str]:
    """Roofline-ideal runtime and which wall binds it."""
    t_mem = cost.total_bytes / spec.mem_bw
    t_comp = cost.flops / spec.peak_flops
    if t_mem >= t_comp:
        return t_mem, "memory"
    return t_comp, "compute"


def dma_compute_ratio(cost: KernelCost, spec: HwSpec) -> float:
    """DMA time over MXU time — >1 means the tile stream is the bottleneck
    and deeper buffering can only hide (never remove) the gap."""
    t_comp = cost.flops / spec.peak_flops
    t_dma = cost.bytes_tiles / spec.mem_bw
    return t_dma / max(t_comp, 1e-30)


def vmem_bytes(bs: int, c: int, buffer_depth: int,
               dtype_bytes: int = 4) -> int:
    """Peak VMEM held by one grid step of the frontier/gather kernels.

    ``buffer_depth == 1`` rides the automatic BlockSpec pipeline, which
    double-buffers the tile operand; ``>= 2`` replaces it with the manual
    ``[depth, bs, bs]`` ring.  The fluid operands (f/wt, col + row views)
    and the output tile stay on the automatic double-buffered path in both
    modes.
    """
    tile_ring = max(2, buffer_depth) * bs * bs
    fluid = 2 * 2 * (bs * c + bs)  # (f, wt) x (col, row) double-buffered
    out = 2 * (bs * c + 1)
    return (tile_ring + fluid + out) * dtype_bytes


def vmem_ok(bs: int, c: int, buffer_depth: int, spec: HwSpec,
            dtype_bytes: int = 4) -> bool:
    return vmem_bytes(bs, c, buffer_depth, dtype_bytes) <= spec.vmem_budget


def roofline_fraction(measured_s: float, ideal_s: float) -> float:
    """Fraction of the roofline the measurement achieves (1.0 = at the
    roof; interpret/oracle timings land far below it by design)."""
    if measured_s <= 0.0:
        return 0.0
    return ideal_s / measured_s
