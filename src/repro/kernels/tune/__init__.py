"""Autotuning for the BSR diffusion kernels (DESIGN.md §9).

``run_sweep`` measures (bs, buffer_depth, occupancy threshold) on the
current platform and persists the winner as a versioned JSON record;
``records`` load those at dispatch time so ``solve(method="auto")`` ranks
backends by measured throughput, and the session drivers resolve their
kernel config (``resolved_config``) from the same records.
"""
from .model import (  # noqa: F401
    PLATFORM_SPECS,
    HwSpec,
    KernelCost,
    dma_compute_ratio,
    frontier_round_cost,
    gather_spmm_cost,
    ideal_time_s,
    roofline_fraction,
    vmem_bytes,
    vmem_ok,
)
from .records import (  # noqa: F401
    DEFAULT_BS,
    DEFAULT_BUFFER_DEPTH,
    DEFAULT_OCCUPANCY_THRESHOLD,
    KERNELS,
    RECORD_VERSION,
    TunedConfig,
    best_config,
    clear_cache,
    load_record,
    record_path,
    resolved_config,
    save_record,
    tune_dir,
)
from .sweep import run_sweep  # noqa: F401
