"""Autotune sweep for the BSR diffusion kernels.

Sweeps (block size ``bs``, ``buffer_depth``, occupancy threshold) on the
current platform, rejects configs whose VMEM footprint exceeds the
platform budget, times the survivors and persists the winner as a
versioned JSON record (:mod:`.records`) that the backend registry feeds
into measured auto-dispatch.

Timing path per platform:

* **tpu** — the compiled Pallas kernel itself (``timing_path="pallas"``).
* **cpu/gpu** — the jnp block oracle (``timing_path="oracle"``): the
  einsum+segment-sum twin is what actually runs there, so its timing *is*
  the deployable throughput.  ``buffer_depth`` does not exist on the
  oracle path, so all depths share one measurement per (bs, threshold)
  and the shallowest feasible depth wins the tie.
"""
from __future__ import annotations

import time
from datetime import datetime, timezone
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import records
from .model import (
    PLATFORM_SPECS,
    dma_compute_ratio,
    frontier_round_cost,
    gather_spmm_cost,
    ideal_time_s,
    roofline_fraction,
    vmem_bytes,
)

__all__ = ["run_sweep"]


def _timeit(fn, *args, iters: int = 3) -> float:
    jax.block_until_ready(fn(*args))  # compile + warm
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def _problem(n: int, bs: int, c: int, density: float, seed: int):
    """A host-ordered web graph + mid-convergence frontier at ``density``."""
    from repro.core import host_block_graph, pagerank_system
    from repro.kernels.diffusion import prepare_bsr

    g = host_block_graph(n, host_size=bs, links_per_node=8.0,
                         intra_frac=0.92, span_hosts=2, seed=seed + 1)
    p, _b = pagerank_system(g)
    m = prepare_bsr(p.indptr, p.indices, p.weights, p.n, bs=bs)
    n_pad = m.n_row_blocks * bs
    rng = np.random.default_rng(seed)
    n_blocks = n_pad // bs
    n_hot = max(1, int(round(density * n_blocks)))
    hot = rng.choice(n_blocks, size=n_hot, replace=False)
    f = np.full((n_pad, c), 0.25, dtype=np.float32)
    for b in hot:
        f[b * bs: (b + 1) * bs] = 2.0
    f *= rng.choice([-1.0, 1.0], size=(n_pad, c))
    f[p.n:] = 0.0
    w = np.zeros(n_pad, np.float32)
    w[: p.n] = 1.0
    return m, jnp.asarray(f), jnp.asarray(w), jnp.float32(1.0)


def _n_active_blocks(m, f, w, t, occ_threshold: float) -> int:
    """Tiles whose block column is armed under the given threshold."""
    sel = np.abs(np.asarray(f)) * np.asarray(w)[:, None] > float(t)
    blk = sel.reshape(m.n_row_blocks, -1)
    if occ_threshold > 0.0:
        col_active = blk.mean(axis=1) > occ_threshold
    else:
        col_active = blk.any(axis=1)
    return int(col_active[np.asarray(m.block_col)].sum())


def run_sweep(
    kernel: str = "frontier_round_bsr",
    *,
    n: int = 4096,
    c: int = 1,
    density: float = 0.25,
    bs_list: Sequence[int] = (32, 64, 128),
    depths: Sequence[int] = (1, 2, 4),
    occupancy_thresholds: Sequence[float] = (0.0,),
    iters: int = 3,
    seed: int = 0,
    save: bool = True,
    platform: Optional[str] = None,
    verbose: bool = True,
) -> dict:
    """Run the sweep and (optionally) persist the winning config record."""
    if kernel not in records.KERNELS:
        raise ValueError(
            f"unknown kernel {kernel!r}; expected one of {records.KERNELS}"
        )
    if platform is None:
        platform = jax.default_backend()
    spec = PLATFORM_SPECS.get(platform, PLATFORM_SPECS["cpu"])
    pallas_timing = platform == "tpu"
    rows = []
    for bs in bs_list:
        m, f, w, t = _problem(n, bs, c, density, seed)
        oracle_cache: dict = {}  # (occ) -> measured us, shared across depths
        for occ in occupancy_thresholds:
            n_act = _n_active_blocks(m, f, w, t, occ)
            if kernel == "frontier_round_bsr":
                cost = frontier_round_cost(m.n_row_blocks, bs, c, n_act)
            else:
                cost = gather_spmm_cost(m.n_row_blocks, bs, c, m.n_blocks)
            ideal_s, bound = ideal_time_s(cost, spec)
            for depth in depths:
                vb = vmem_bytes(bs, c, depth)
                feasible = vb <= spec.vmem_budget
                row = {
                    "bs": bs,
                    "buffer_depth": depth,
                    "occupancy_threshold": occ,
                    "feasible": feasible,
                    "vmem_bytes": vb,
                    "n_blocks_active": n_act,
                    "bound": bound,
                    "dma_compute_ratio": round(
                        dma_compute_ratio(cost, spec), 3),
                    "measured_us": None,
                    "throughput_gflops": None,
                    "roofline_fraction": None,
                }
                if feasible:
                    us = _measure(kernel, m, f, w, t, depth, occ,
                                  pallas_timing, iters, oracle_cache)
                    row["measured_us"] = round(us, 2)
                    row["throughput_gflops"] = round(
                        cost.flops / (us * 1e-6) / 1e9, 4)
                    row["roofline_fraction"] = round(
                        roofline_fraction(us * 1e-6, ideal_s), 6)
                rows.append(row)
                if verbose:
                    shown = (f"{row['measured_us']}us"
                             if feasible else "VMEM-infeasible")
                    print(f"[tune:{kernel}] bs={bs} depth={depth} "
                          f"occ={occ}: {shown}")
    timed = [r for r in rows if r["measured_us"] is not None]
    if not timed:
        raise RuntimeError(
            "no feasible config in the sweep — every (bs, depth) exceeded "
            f"the {spec.name} VMEM budget of {spec.vmem_budget} bytes"
        )
    win = min(timed, key=lambda r: (r["measured_us"], r["buffer_depth"],
                                    -r["bs"]))
    record = {
        "version": records.RECORD_VERSION,
        "kernel": kernel,
        "platform": platform,
        "device_kind": jax.devices()[0].device_kind,
        "jax_version": jax.__version__,
        "created_utc": datetime.now(timezone.utc).isoformat(),
        "timing_path": "pallas" if pallas_timing else "oracle",
        "problem": {"n": n, "c": c, "density": density, "seed": seed,
                    "iters": iters},
        "best": {
            "bs": win["bs"],
            "buffer_depth": win["buffer_depth"],
            "occupancy_threshold": win["occupancy_threshold"],
            "measured_us": win["measured_us"],
            "throughput_gflops": win["throughput_gflops"],
            "roofline_fraction": win["roofline_fraction"],
            "vmem_bytes": win["vmem_bytes"],
        },
        "sweep": rows,
    }
    if save:
        path = records.save_record(record)
        if verbose:
            print(f"[tune:{kernel}] best bs={win['bs']} "
                  f"depth={win['buffer_depth']} -> {path}")
    return record


def _measure(kernel, m, f, w, t, depth, occ, pallas_timing, iters,
             oracle_cache) -> float:
    """One timed config; oracle timings are cached across depths."""
    from repro.kernels.diffusion import (
        bsr_gather_spmm_pallas,
        bsr_spmm_ref,
        frontier_round_bsr,
    )

    if not pallas_timing and occ in oracle_cache:
        return oracle_cache[occ]
    if kernel == "frontier_round_bsr":
        backend = "pallas" if pallas_timing else "block"

        @jax.jit
        def fn(fv):
            f_new, _s, res = frontier_round_bsr(
                m, fv, w, t, backend=backend,
                interpret=False if pallas_timing else None,
                buffer_depth=depth if pallas_timing else 1,
                occupancy_threshold=occ,
            )
            return f_new, res

        us = _timeit(fn, f, iters=iters)
    else:  # bsr_gather_spmm
        c = f.shape[-1]
        xt = f.reshape(m.n_row_blocks, m.bs, c)
        order = jnp.arange(m.n_blocks, dtype=jnp.int32)
        if pallas_timing:

            @jax.jit
            def fn(x):
                return bsr_gather_spmm_pallas(
                    m.blocks, order, m.block_row, m.block_col, x,
                    m.n_row_blocks, bs=m.bs, interpret=False,
                    buffer_depth=depth,
                )

        else:

            @jax.jit
            def fn(x):
                return bsr_spmm_ref(m.blocks, m.block_row, m.block_col, x,
                                    m.n_row_blocks)

        us = _timeit(fn, xt, iters=iters)
    if not pallas_timing:
        oracle_cache[occ] = us
    return us
