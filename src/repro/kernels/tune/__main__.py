"""CLI: ``python -m repro.kernels.tune [--kernel K] [--smoke]``.

Writes the winning config record under ``$REPRO_TUNE_DIR`` (default
``results/tuned/``) and prints the sweep.  ``--smoke`` runs the tiny
CI-sized sweep (seconds on CPU via the oracle path).
"""
from __future__ import annotations

import argparse
import json

from . import records, run_sweep


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.kernels.tune")
    ap.add_argument("--kernel", default="all",
                    choices=("all",) + records.KERNELS)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI sweep (n=1024, bs in {32,64}, 1 iter)")
    ap.add_argument("--n", type=int, default=4096)
    ap.add_argument("--c", type=int, default=1)
    ap.add_argument("--density", type=float, default=0.25)
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-save", action="store_true")
    args = ap.parse_args(argv)

    kw = dict(n=args.n, c=args.c, density=args.density, iters=args.iters,
              seed=args.seed, save=not args.no_save)
    if args.smoke:
        kw.update(n=1024, bs_list=(32, 64), depths=(1, 2), iters=1)
    kernels = records.KERNELS if args.kernel == "all" else (args.kernel,)
    for kernel in kernels:
        rec = run_sweep(kernel, **kw)
        print(json.dumps(rec["best"], indent=2, sort_keys=True))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
