"""Versioned on-disk records of autotuned kernel configs.

One JSON file per (kernel, platform) pair under the tune directory
(``$REPRO_TUNE_DIR`` or ``results/tuned/``).  The backend registry loads
these at dispatch time: a backend whose ``tune_key`` has a record for the
current platform is ranked by *measured* throughput instead of its
hardcoded ``auto_priority`` (DESIGN.md §9).  No records on disk — the
default state — reproduces the historical priority-only dispatch exactly.

Records are versioned; a version mismatch is treated as "no record"
(stale tunings must never steer dispatch after the schema moves on).
"""
from __future__ import annotations

import dataclasses
import json
import os
import pathlib
from typing import Dict, Optional, Tuple

RECORD_VERSION = 1
KERNELS = ("frontier_round_bsr", "bsr_gather_spmm")
ENV_VAR = "REPRO_TUNE_DIR"
DEFAULT_DIR = "results/tuned"

__all__ = [
    "RECORD_VERSION",
    "KERNELS",
    "ENV_VAR",
    "TunedConfig",
    "tune_dir",
    "record_path",
    "save_record",
    "load_record",
    "best_config",
    "clear_cache",
    "resolved_config",
]

# defaults used whenever no tuned record (or explicit option) says otherwise
DEFAULT_BS = 128
DEFAULT_BUFFER_DEPTH = 1
DEFAULT_OCCUPANCY_THRESHOLD = 0.0

_REQUIRED_KEYS = (
    "version", "kernel", "platform", "device_kind", "jax_version",
    "created_utc", "timing_path", "problem", "best", "sweep",
)
_BEST_KEYS = (
    "bs", "buffer_depth", "occupancy_threshold", "measured_us",
    "throughput_gflops", "roofline_fraction", "vmem_bytes",
)


@dataclasses.dataclass(frozen=True)
class TunedConfig:
    """The winning config of a sweep, as dispatch and drivers consume it."""

    kernel: str
    platform: str
    bs: int
    buffer_depth: int
    occupancy_threshold: float
    measured_us: float
    throughput_gflops: float


_CACHE: Dict[Tuple[str, str, str], Optional[dict]] = {}


def tune_dir() -> pathlib.Path:
    return pathlib.Path(os.environ.get(ENV_VAR, DEFAULT_DIR))


def record_path(kernel: str, platform: str) -> pathlib.Path:
    return tune_dir() / f"{kernel}__{platform}.json"


def validate_record(record: dict) -> None:
    missing = [k for k in _REQUIRED_KEYS if k not in record]
    if missing:
        raise ValueError(f"tune record missing keys: {missing}")
    bad = [k for k in _BEST_KEYS if k not in record["best"]]
    if bad:
        raise ValueError(f"tune record 'best' missing keys: {bad}")
    if record["kernel"] not in KERNELS:
        raise ValueError(f"unknown kernel {record['kernel']!r}")


def save_record(record: dict) -> pathlib.Path:
    """Validate + write; returns the path.  Invalidates the read cache."""
    validate_record(record)
    path = record_path(record["kernel"], record["platform"])
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(record, indent=2, sort_keys=True) + "\n")
    clear_cache()
    return path


def load_record(kernel: str, platform: str) -> Optional[dict]:
    """Read (cached) a record; None if absent, unreadable or stale-versioned."""
    key = (kernel, platform, str(tune_dir()))
    if key in _CACHE:
        return _CACHE[key]
    rec: Optional[dict] = None
    path = record_path(kernel, platform)
    try:
        rec = json.loads(path.read_text())
        validate_record(rec)
        if rec.get("version") != RECORD_VERSION:
            rec = None
    except (OSError, ValueError, KeyError):
        rec = None
    _CACHE[key] = rec
    return rec


def best_config(kernel: str, platform: str) -> Optional[TunedConfig]:
    rec = load_record(kernel, platform)
    if rec is None:
        return None
    b = rec["best"]
    return TunedConfig(
        kernel=kernel,
        platform=platform,
        bs=int(b["bs"]),
        buffer_depth=int(b["buffer_depth"]),
        occupancy_threshold=float(b["occupancy_threshold"]),
        measured_us=float(b["measured_us"]),
        throughput_gflops=float(b["throughput_gflops"]),
    )


def clear_cache() -> None:
    """Drop the read cache (tests repoint ``$REPRO_TUNE_DIR`` mid-process)."""
    _CACHE.clear()


def resolved_config(
    kernel: str,
    *,
    platform: Optional[str] = None,
    bs: Optional[int] = None,
    buffer_depth: Optional[int] = None,
    occupancy_threshold: Optional[float] = None,
) -> Tuple[int, int, float]:
    """Merge explicit options over the tuned record over the defaults.

    The precedence drivers rely on: an explicitly-set ``SolverOptions``
    field always wins; otherwise the platform's tuned record; otherwise
    the historical defaults (bs=128, depth=1, threshold=0).
    """
    if platform is None:
        import jax

        platform = jax.default_backend()
    rec = best_config(kernel, platform)
    return (
        bs if bs is not None else (rec.bs if rec else DEFAULT_BS),
        buffer_depth if buffer_depth is not None
        else (rec.buffer_depth if rec else DEFAULT_BUFFER_DEPTH),
        occupancy_threshold if occupancy_threshold is not None
        else (rec.occupancy_threshold if rec
              else DEFAULT_OCCUPANCY_THRESHOLD),
    )
