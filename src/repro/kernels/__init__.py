"""Pallas TPU kernels for the system's compute hot-spots.

Each kernel family is a subpackage with three modules:

* ``kernel.py`` — the ``pl.pallas_call`` + ``BlockSpec`` TPU kernel,
* ``ops.py``    — the jit'd public wrapper (auto-interpret on CPU),
* ``ref.py``    — the pure-jnp oracle used by tests and as the XLA fallback.

Families (DESIGN.md §3):

* ``diffusion`` — block-sparse (BSR) fluid push: the D-iteration hot loop
  recast as dense [bs x bs] tile matmuls on the MXU (the TPU-native
  replacement for the paper's scalar scatter push).
* ``segment``   — two-stage sorted segment-sum (one-hot-matmul partials +
  cheap block add): GNN message passing and embedding-bag gather-reduce.
* ``fm``        — factorization-machine pairwise interaction via the
  O(nk) sum-square trick, fused over batch tiles.
* ``attention`` — blockwise causal flash attention with GQA for the LM
  architectures (online softmax, VMEM accumulators).
"""
from . import diffusion, segment, fm, attention  # noqa: F401
