"""Blockwise causal flash attention with GQA (Pallas TPU).

Online-softmax attention (FlashAttention-style, adapted to the TPU memory
hierarchy): the [Sq, Sk] score matrix is never materialised in HBM; per
(batch, q-head, q-block) the kernel streams k/v blocks through VMEM keeping
running max ``m``, normalizer ``l`` and the [bq, dh] accumulator in VMEM
scratch across the innermost kv grid dimension.  GQA is expressed purely in
the k/v BlockSpec index maps (q head h reads kv head ``h // group``), so no
KV replication ever hits HBM.

Grid: (batch, q_heads, n_q_blocks, n_kv_blocks), kv innermost (sequential on
TPU, which is what lets scratch carry state between kv steps).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_pallas"]

NEG_INF = -1.0e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, bq: int, bk: int, n_kv: int):
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0]  # [bq, dh]
    k = k_ref[0, 0]  # [bk, dh]
    v = v_ref[0, 0]  # [bk, dh]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # [bq, bk]

    if causal:
        iq = pl.program_id(2)
        q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = q_pos >= k_pos
        s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]  # [bq, 1]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    if causal:
        p = jnp.where(mask, p, 0.0)
    alpha = jnp.exp(m_prev - m_new)  # [bq, 1]
    l_ref[...] = alpha * l_ref[...] + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_ref[...] = m_new

    @pl.when(ik == n_kv - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "bq", "bk", "interpret"),
)
def flash_attention_pallas(
    q: jax.Array,  # [B, Hq, Sq, Dh]
    k: jax.Array,  # [B, Hkv, Sk, Dh]
    v: jax.Array,  # [B, Hkv, Sk, Dh]
    *,
    causal: bool = True,
    bq: int = 128,
    bk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, hq, sq, dh = q.shape
    _, hkv, sk, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    assert sq % bq == 0 and sk % bk == 0, (sq, bq, sk, bk)
    nq, nk = sq // bq, sk // bk
    scale = 1.0 / (dh**0.5)

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, bq=bq, bk=bk, n_kv=nk
    )
    return pl.pallas_call(
        kernel,
        grid=(b, hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, dh), lambda b_, h, iq, ik: (b_, h, iq, 0)),
            pl.BlockSpec(
                (1, 1, bk, dh), lambda b_, h, iq, ik: (b_, h // group, ik, 0)
            ),
            pl.BlockSpec(
                (1, 1, bk, dh), lambda b_, h, iq, ik: (b_, h // group, ik, 0)
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, bq, dh), lambda b_, h, iq, ik: (b_, h, iq, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
