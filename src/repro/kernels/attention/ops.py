"""Public attention wrapper: pads sequence, picks kernel vs oracle."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import flash_attention_pallas
from .ref import attention_ref

__all__ = ["flash_attention"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(
    jax.jit,
    static_argnames=("causal", "bq", "bk", "use_pallas", "interpret"),
)
def flash_attention(
    q: jax.Array,  # [B, Hq, Sq, Dh]
    k: jax.Array,  # [B, Hkv, Sk, Dh]
    v: jax.Array,
    *,
    causal: bool = True,
    bq: int = 128,
    bk: int = 128,
    use_pallas: bool = True,
    interpret: bool | None = None,
) -> jax.Array:
    if not use_pallas:
        return attention_ref(q, k, v, causal=causal)
    if interpret is None:
        interpret = not _on_tpu()
    b, hq, sq, dh = q.shape
    sk = k.shape[2]
    bq_ = min(bq, sq) if sq % bq else bq
    bk_ = min(bk, sk) if sk % bk else bk
    sq_pad = -(-sq // bq_) * bq_
    sk_pad = -(-sk // bk_) * bk_
    if sq_pad != sq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, sq_pad - sq), (0, 0)))
    if sk_pad != sk:
        # pad keys AFTER the real ones; causal mask with q_pos>=k_pos keeps
        # padded keys unattended for real queries only when sq==sk; for
        # safety we park padded keys at +inf distance via masking in-kernel
        # (causal) or slice below (bidirectional exactness requires no pad).
        k = jnp.pad(k, ((0, 0), (0, 0), (0, sk_pad - sk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, sk_pad - sk), (0, 0)))
    out = flash_attention_pallas(
        q, k, v, causal=causal, bq=bq_, bk=bk_, interpret=interpret
    )
    return out[:, :, :sq]
