"""Pure-jnp oracle: exact softmax attention with GQA + causal mask."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

__all__ = ["attention_ref"]


@functools.partial(jax.jit, static_argnames=("causal",))
def attention_ref(q, k, v, causal: bool = True):
    b, hq, sq, dh = q.shape
    _, hkv, sk, _ = k.shape
    group = hq // hkv
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32)
    s = s / (dh**0.5)
    if causal:
        mask = jnp.tril(jnp.ones((sq, sk), bool), k=sk - sq)
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v)
