"""Factorization-machine pairwise-interaction kernel.

FM second-order term per sample (Rendle, ICDM'10), O(F·D) via the
sum-square trick:

    y = 0.5 * sum_d [ (sum_f v_fd)^2 - sum_f v_fd^2 ]

where ``v`` is the field-embedding already scaled by the feature value.
The kernel fuses both reductions and the final combine over a batch tile so
the [B, F, D] tensor is read from HBM exactly once (the XLA fallback
materialises the squared tensor).  Pure VPU work — reductions + elementwise.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["fm_interaction_pallas"]


def _kernel(v_ref, o_ref):
    v = v_ref[0]  # [TB, F, D]
    s1 = jnp.sum(v, axis=1)  # [TB, D]
    s2 = jnp.sum(v * v, axis=1)  # [TB, D]
    o_ref[0] = 0.5 * jnp.sum(s1 * s1 - s2, axis=-1)  # [TB]


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def fm_interaction_pallas(
    v: jax.Array,  # [B, F, D] field embeddings (scaled by feature values)
    *,
    tile: int = 256,
    interpret: bool = False,
) -> jax.Array:
    b, f, d = v.shape
    assert b % tile == 0, (b, tile)
    n_tiles = b // tile
    v4 = v.reshape(n_tiles, tile, f, d)
    out = pl.pallas_call(
        _kernel,
        grid=(n_tiles,),
        in_specs=[pl.BlockSpec((1, tile, f, d), lambda i: (i, 0, 0, 0))],
        out_specs=pl.BlockSpec((1, tile), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_tiles, tile), v.dtype),
        interpret=interpret,
    )(v4)
    return out.reshape(b)
