"""Public wrapper for the FM interaction kernel (pads batch to tile)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import fm_interaction_pallas
from .ref import fm_interaction_ref

__all__ = ["fm_interaction"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(
    jax.jit, static_argnames=("tile", "use_pallas", "interpret")
)
def fm_interaction(
    v: jax.Array,  # [B, F, D]
    *,
    tile: int = 256,
    use_pallas: bool = True,
    interpret: bool | None = None,
) -> jax.Array:
    if not use_pallas:
        return fm_interaction_ref(v)
    if interpret is None:
        interpret = not _on_tpu()
    b = v.shape[0]
    b_pad = -(-b // tile) * tile
    if b_pad != b:
        v = jnp.concatenate(
            [v, jnp.zeros((b_pad - b,) + v.shape[1:], v.dtype)]
        )
    out = fm_interaction_pallas(v, tile=tile, interpret=interpret)
    return out[:b]
