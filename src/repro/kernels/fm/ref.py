"""Pure-jnp oracle for the FM pairwise interaction."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["fm_interaction_ref", "fm_interaction_naive"]


@jax.jit
def fm_interaction_ref(v: jax.Array) -> jax.Array:
    """Sum-square trick, [B, F, D] -> [B]."""
    s1 = v.sum(axis=1)
    s2 = (v * v).sum(axis=1)
    return 0.5 * (s1 * s1 - s2).sum(axis=-1)


@jax.jit
def fm_interaction_naive(v: jax.Array) -> jax.Array:
    """O(F^2) literal pairwise sum — the definition, for tiny tests."""
    inter = jnp.einsum("bfd,bgd->bfg", v, v)
    f = v.shape[1]
    mask = jnp.triu(jnp.ones((f, f), bool), k=1)
    return (inter * mask[None]).sum(axis=(1, 2))
