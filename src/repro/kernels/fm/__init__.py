from .ops import fm_interaction  # noqa: F401
from .ref import fm_interaction_naive, fm_interaction_ref  # noqa: F401
from .kernel import fm_interaction_pallas  # noqa: F401
