"""Pure-jnp oracles for segment ops and embedding-bag."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

__all__ = ["segment_sum_ref", "embedding_bag_ref"]


@functools.partial(jax.jit, static_argnames=("n_segments",))
def segment_sum_ref(data, seg_ids, n_segments: int):
    return jax.ops.segment_sum(data, seg_ids, num_segments=n_segments)


@functools.partial(jax.jit, static_argnames=("mode",))
def embedding_bag_ref(table, ids, weights=None, mode: str = "sum"):
    """out[b] = reduce_l table[ids[b, l]] (* weights[b, l]).

    ids: [B, L] int32 (pad with any valid row + weight 0).
    """
    emb = table[ids]  # [B, L, D]
    if weights is not None:
        emb = emb * weights[..., None]
    if mode == "sum":
        return emb.sum(axis=1)
    if mode == "mean":
        denom = (
            weights.sum(axis=1, keepdims=True)
            if weights is not None
            else jnp.full((ids.shape[0], 1), ids.shape[1], emb.dtype)
        )
        return emb.sum(axis=1) / jnp.maximum(denom, 1e-9)
    if mode == "max":
        return emb.max(axis=1)
    raise ValueError(mode)
