"""Sorted segment-sum kernel (two-stage seg-matmul) for TPU.

GNN message passing and recsys embedding-bag both reduce edge/bag values
by a sorted segment id.  TPUs have no atomic scatter-add; the TPU-idiomatic
formulation (cf. FeatGraph/FusedMM-style seg-matmul) is:

  stage 1 (Pallas, MXU): tile the E edges into chunks of ``T``.  Because ids
    are sorted, a chunk's segments span at most ``T`` consecutive values, so
    they fit inside a window of ``T + bs_out`` output rows anchored at
    ``base = seg[first] // bs_out * bs_out``.  The chunk reduction becomes a
    one-hot matmul ``partial = onehot(seg - base)^T @ data`` ([W, T] @
    [T, D]) which runs on the MXU instead of as serialized scalar stores.

  stage 2 (XLA, cheap): scatter-add the ``n_tiles`` windows at their block
    offsets — O(E/T · W · D) work, ~(W/T)× the input, done with one
    vectorized scatter.

Padding edges carry ``seg_id = n_segments_padded`` which lands outside every
window (one-hot row of zeros) and therefore contributes nothing.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["segment_sum_tiles"]


def _kernel(seg_ref, data_ref, out_ref, *, window: int, bs_out: int):
    """partial[i] = onehot(seg_tile - base)^T @ data_tile."""
    seg = seg_ref[0]  # [T] int32
    base = (seg[0] // bs_out) * bs_out
    local = seg - base  # in [0, window) for real edges
    cols = jax.lax.broadcasted_iota(jnp.int32, (seg.shape[0], window), 1)
    onehot = (local[:, None] == cols).astype(data_ref.dtype)  # [T, W]
    out_ref[0] = jax.lax.dot_general(
        onehot,
        data_ref[0],
        (((0,), (0,)), ((), ())),  # contract over the T edges
        preferred_element_type=out_ref.dtype,
    )


@functools.partial(
    jax.jit, static_argnames=("tile", "bs_out", "interpret")
)
def segment_sum_tiles(
    data: jax.Array,  # [E_pad, D], E_pad % tile == 0
    seg_ids: jax.Array,  # [E_pad] int32 sorted; pad rows = big sentinel
    *,
    tile: int = 512,
    bs_out: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Stage-1 partials: [n_tiles, window, D] with window = tile + bs_out."""
    e, d = data.shape
    assert e % tile == 0, (e, tile)
    n_tiles = e // tile
    window = tile + bs_out
    seg2d = seg_ids.reshape(n_tiles, tile)
    data2d = data.reshape(n_tiles, tile, d)
    return pl.pallas_call(
        functools.partial(_kernel, window=window, bs_out=bs_out),
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((1, tile), lambda i: (i, 0)),
            pl.BlockSpec((1, tile, d), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, window, d), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_tiles, window, d), data.dtype),
        interpret=interpret,
    )(seg2d, data2d)
