from .ops import embedding_bag, pad_sorted_edges, segment_sum_sorted  # noqa: F401
from .ref import embedding_bag_ref, segment_sum_ref  # noqa: F401
from .kernel import segment_sum_tiles  # noqa: F401
