"""Public wrappers: sorted segment-sum and embedding-bag on the TPU kernel.

``segment_sum_sorted`` = Pallas stage-1 partials + vectorized block-add
epilogue.  ``embedding_bag`` = XLA row gather + the same reduction kernel
(the gather is memory-bound and already optimal in XLA; the reduction is
the scatter-shaped part the kernel replaces — see kernel.py docstring).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import segment_sum_tiles
from .ref import embedding_bag_ref, segment_sum_ref

__all__ = ["segment_sum_sorted", "embedding_bag", "pad_sorted_edges"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def pad_sorted_edges(data, seg_ids, tile: int):
    """Pad E to a multiple of ``tile``; pad ids get an out-of-window sentinel."""
    e = data.shape[0]
    e_pad = -(-e // tile) * tile
    if e_pad != e:
        data = jnp.concatenate(
            [data, jnp.zeros((e_pad - e,) + data.shape[1:], data.dtype)]
        )
        seg_ids = jnp.concatenate(
            [seg_ids, jnp.full((e_pad - e,), jnp.int32(2**30), jnp.int32)]
        )
    return data, seg_ids


@functools.partial(
    jax.jit,
    static_argnames=("n_segments", "tile", "bs_out", "use_pallas",
                     "interpret"),
)
def segment_sum_sorted(
    data: jax.Array,  # [E, D]
    seg_ids: jax.Array,  # [E] int32 sorted ascending
    n_segments: int,
    *,
    tile: int = 512,
    bs_out: int = 128,
    use_pallas: bool = True,
    interpret: bool | None = None,
) -> jax.Array:
    if not use_pallas:
        return segment_sum_ref(data, seg_ids, n_segments)
    if interpret is None:
        interpret = not _on_tpu()
    data_p, seg_p = pad_sorted_edges(data, seg_ids, tile)
    partials = segment_sum_tiles(
        data_p, seg_p, tile=tile, bs_out=bs_out, interpret=interpret
    )  # [n_tiles, W, D]
    n_tiles, window, d = partials.shape
    # stage 2: add each window at its base offset
    bases = (seg_p.reshape(n_tiles, tile)[:, 0] // bs_out) * bs_out
    n_out_pad = -(-n_segments // bs_out) * bs_out + window
    rows = bases[:, None] + jnp.arange(window, dtype=jnp.int32)[None, :]
    rows = jnp.minimum(rows, n_out_pad - 1)  # sentinel tiles park at the end
    out = jnp.zeros((n_out_pad, d), partials.dtype)
    out = out.at[rows.reshape(-1)].add(partials.reshape(-1, d))
    return out[:n_segments]


@functools.partial(
    jax.jit, static_argnames=("mode", "use_pallas", "interpret")
)
def embedding_bag(
    table: jax.Array,  # [V, D]
    ids: jax.Array,  # [B, L] int32
    weights: jax.Array | None = None,  # [B, L]
    mode: str = "sum",
    use_pallas: bool = True,
    interpret: bool | None = None,
) -> jax.Array:
    if mode != "sum" or not use_pallas:
        return embedding_bag_ref(table, ids, weights, mode)
    b, l = ids.shape
    emb = table[ids.reshape(-1)]  # [B*L, D] XLA gather
    if weights is not None:
        emb = emb * weights.reshape(-1)[:, None]
    seg = jnp.repeat(jnp.arange(b, dtype=jnp.int32), l)
    return segment_sum_sorted(
        emb, seg, b, use_pallas=True, interpret=interpret
    )
