"""Unified rebalancing control plane (DESIGN.md §5).

The paper's claim is that one measurement-driven controller "equalizes
the computation load between PIDs without any deep analysis of the
matrix or graph structure" — i.e. the same policy works at any
granularity.  This package is that claim turned into an architecture:

* :class:`~repro.balance.signals.LoadSignal` — the one measurement
  container every layer produces (per-PID residuals, per-device edge-op
  counts, per-host step wall-times, per-expert token counts).
* :class:`~repro.balance.policies.Rebalancer` — the policy protocol:
  ``propose(LoadSignal) -> [MovePlan]`` + ``reset_worker(k)``.  Three
  implementations ship: :class:`SlopeEMAPolicy` (paper §2.5.2 exact),
  :class:`CostRefreshPolicy` (periodic CB re-split from observed costs),
  :class:`HysteresisPolicy` (slope-EMA with a deadband and multi-move
  batching), :class:`PressurePolicy` (serving-tier overload control:
  ±1 degradation-ladder rung recommendations from a ``latency``
  signal).
* :class:`~repro.balance.plan.MovePlan` — granularity-agnostic
  "move ``units`` from worker ``src`` to worker ``dst``" decision with a
  declared unit kind (``node`` | ``bucket`` | ``expert-shard`` |
  ``device``).
* :mod:`~repro.balance.executors` — per-granularity executors that turn
  a MovePlan into actual state mutation: node moves in the faithful
  simulator (with the §2.4 reassignment-cost charging), bucket-row
  permutations in the distributed engine, and an advisory recorder for
  the runtime's straggler / MoE paths.

Consumers: :mod:`repro.core.simulator` (node-granular),
:mod:`repro.core.distributed` (bucket-granular),
:mod:`repro.runtime.loop` (device- and expert-granular).
"""
from .plan import MovePlan
from .signals import LoadSignal
from .policies import (
    CostRefreshPolicy,
    HysteresisPolicy,
    PressurePolicy,
    Rebalancer,
    SlopeEMAPolicy,
    make_rebalancer,
)
from .executors import (
    AdvisoryExecutor,
    BucketMoveExecutor,
    MoveExecutor,
    NodeMoveExecutor,
)

__all__ = [
    "LoadSignal",
    "MovePlan",
    "Rebalancer",
    "SlopeEMAPolicy",
    "CostRefreshPolicy",
    "HysteresisPolicy",
    "PressurePolicy",
    "make_rebalancer",
    "MoveExecutor",
    "NodeMoveExecutor",
    "BucketMoveExecutor",
    "AdvisoryExecutor",
]
