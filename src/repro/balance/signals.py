"""The one measurement container behind every rebalancing decision.

Every consumer layer reduces its bookkeeping to the same two vectors —
a positive per-worker load magnitude and the per-worker unit counts —
so policies stay blind to the granularity, exactly as the paper's
controller is blind to the graph structure:

==============  =====================================  ==============
kind            values[k]                              unit
==============  =====================================  ==============
residual        r_k + s_k (fluid left + in flight)     node / bucket
edge-ops        edge operations charged this window    node / bucket
step-time       wall-clock seconds of worker k's step  device
expert-tokens   tokens routed to expert shard k        expert-shard
graph-churn     changed edges owned by worker k        node / bucket
latency         serving pressure (deadline + queue)    request stream
==============  =====================================  ==============

The convention throughout: **larger value = slower / more loaded
worker** (the paper's residual magnitude plays exactly this role in
§2.5.2 — the PID with the largest remaining residual has the lagging
slope and sheds load).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

__all__ = ["LoadSignal", "SIGNAL_KINDS"]

SIGNAL_KINDS = ("residual", "edge-ops", "step-time", "expert-tokens",
                "graph-churn", "latency", "queue-depth")


@dataclasses.dataclass
class LoadSignal:
    """Per-worker load measurement at one control step.

    ``values`` — [K] positive magnitudes (larger = more loaded);
    ``sizes`` — [K] load units currently owned by each worker;
    ``kind`` — which measurement produced ``values``;
    ``step`` — producer's control-step counter (simulator time step,
    engine chunk index, runtime step).
    """

    values: np.ndarray
    sizes: np.ndarray
    kind: str = "residual"
    step: int = 0

    def __post_init__(self):
        self.values = np.asarray(self.values, dtype=np.float64)
        self.sizes = np.asarray(self.sizes, dtype=np.int64)
        if self.values.shape != self.sizes.shape:
            raise ValueError(
                f"values {self.values.shape} vs sizes {self.sizes.shape}"
            )
        if self.kind not in SIGNAL_KINDS:
            raise ValueError(
                f"unknown signal kind {self.kind!r}; expected one of "
                f"{SIGNAL_KINDS}"
            )

    @property
    def k(self) -> int:
        return int(self.values.shape[0])

    # ------------------------------------------------------------------ #
    # producers
    # ------------------------------------------------------------------ #
    @classmethod
    def from_residuals(cls, r_plus_s: np.ndarray, sizes: np.ndarray,
                       step: int = 0) -> "LoadSignal":
        """§2.5.2's native signal: per-PID ``r_k + s_k``."""
        return cls(values=r_plus_s, sizes=sizes, kind="residual", step=step)

    @classmethod
    def from_edge_ops(cls, ops_delta: np.ndarray, sizes: np.ndarray,
                      step: int = 0) -> "LoadSignal":
        """Edge operations charged since the previous control step."""
        return cls(values=np.maximum(ops_delta, 0), sizes=sizes,
                   kind="edge-ops", step=step)

    @classmethod
    def from_step_times(cls, seconds: np.ndarray,
                        load_units: Optional[np.ndarray] = None,
                        step: int = 0) -> "LoadSignal":
        """Per-host step wall-times (a straggler is a slow PID).

        Times are normalized to fractions of the total so the slope
        policies see residual-like magnitudes in (0, 1) — the §2.5.2
        move-fraction formula ``(slope_min+1)/(slope_max+1)`` assumes the
        signal exponent is negative, and fractions make the signal
        independent of the absolute step duration.
        """
        seconds = np.maximum(np.asarray(seconds, np.float64), 1e-9)
        if load_units is None:
            load_units = np.full(seconds.shape[0], 1 << 20)
        return cls(values=seconds / seconds.sum(), sizes=load_units,
                   kind="step-time", step=step)

    @classmethod
    def from_graph_churn(cls, churn_counts: np.ndarray,
                         sizes: np.ndarray, step: int = 0) -> "LoadSignal":
        """Changed-edge counts per worker after a graph delta.

        A worker whose nodes absorb the churn pays the view-patch work
        *and* re-diffuses the injected fluid ``(P'−P)·H`` — the paper's
        thesis applied to graph drift: the controller needs only this
        magnitude, no structural analysis.  Counts are normalized to
        fractions (see :meth:`from_step_times` for why).
        """
        churn = np.maximum(np.asarray(churn_counts, np.float64), 0.0)
        total = churn.sum()
        if total > 0:
            churn = churn / total
        return cls(values=churn, sizes=sizes, kind="graph-churn", step=step)

    @classmethod
    def from_latency(cls, latency_s: float, deadline_s: float,
                     queue_depth: int = 0, queue_cap: int = 8,
                     step: int = 0) -> "LoadSignal":
        """Serving-tier pressure: deadline headroom plus queue backlog.

        Unlike the skew signals above, this one is NOT normalized to
        fractions — overload is about absolute headroom, not relative
        imbalance.  ``values[0]`` is a dimensionless pressure where
        1.0 means "at the deadline with an empty queue"; a
        :class:`~repro.balance.policies.PressurePolicy` thresholds it
        to drive the serving degradation ladder up and down:

            pressure = latency/deadline + queue_depth/queue_cap

        ``sizes[0]`` carries the raw queue depth so event logs can
        recover it without re-deriving.
        """
        if deadline_s <= 0:
            raise ValueError(f"deadline_s must be positive, got "
                             f"{deadline_s}")
        pressure = (max(float(latency_s), 0.0) / float(deadline_s)
                    + max(int(queue_depth), 0) / max(int(queue_cap), 1))
        return cls(values=np.array([pressure]),
                   sizes=np.array([max(int(queue_depth), 0)]),
                   kind="latency", step=step)

    @classmethod
    def from_queue(cls, oldest_wait_s: float, deadline_s: float,
                   queue_depth: int = 0, queue_cap: int = 8,
                   step: int = 0) -> "LoadSignal":
        """Continuous-batching backlog pressure (the scheduler's signal).

        The per-request variant (:meth:`from_latency`) measures a
        latency that already *happened*; a batch scheduler needs the
        leading indicator — how long the queue's HEAD has been waiting
        plus how deep the backlog is — so it can shed quality before
        any request actually misses its deadline:

            pressure = oldest_wait/deadline + queue_depth/queue_cap

        Same conventions as ``from_latency``: NOT normalized (overload
        is absolute), 1.0 ≈ "head request at the deadline with an empty
        queue", ``sizes[0]`` carries the raw depth for event logs.
        """
        if deadline_s <= 0:
            raise ValueError(f"deadline_s must be positive, got "
                             f"{deadline_s}")
        pressure = (max(float(oldest_wait_s), 0.0) / float(deadline_s)
                    + max(int(queue_depth), 0) / max(int(queue_cap), 1))
        return cls(values=np.array([pressure]),
                   sizes=np.array([max(int(queue_depth), 0)]),
                   kind="queue-depth", step=step)

    @classmethod
    def from_expert_counts(cls, token_counts: np.ndarray,
                           shards_per_expert: Optional[np.ndarray] = None,
                           step: int = 0) -> "LoadSignal":
        """Per-expert routed-token counts (a hot expert is a hot Ω_k).

        Counts are normalized to routing fractions (see
        :meth:`from_step_times` for why).
        """
        token_counts = np.maximum(
            np.asarray(token_counts, np.float64), 1e-12)
        return cls(values=token_counts / token_counts.sum(),
                   sizes=(shards_per_expert if shards_per_expert is not None
                          else np.ones(token_counts.shape[0],
                                       dtype=np.int64)),
                   kind="expert-tokens", step=step)
