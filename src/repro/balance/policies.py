"""Pluggable rebalancing policies.

All policies implement the :class:`Rebalancer` protocol —
``propose(LoadSignal) -> list[MovePlan]`` once per control step plus
``reset_worker(k)`` for elastic events — and are deliberately ignorant
of what a load unit is (node, bucket, expert shard, device slice).

* :class:`SlopeEMAPolicy` — the paper's §2.5.2 controller, verbatim: it
  wraps :class:`repro.core.partition.DynamicController` so decisions
  are bit-identical to the historical inline wiring.
* :class:`CostRefreshPolicy` — periodic Cost-Balanced re-split (§2.5.1
  made dynamic): every ``period`` steps, recompute cost-proportional
  target sizes from the EMA'd signal and plan the greedy set of moves
  toward them.
* :class:`HysteresisPolicy` — slope-EMA with a deadband (the trigger
  must persist ``patience`` consecutive steps) and multi-move batching
  (pairs slowest↔fastest extremes in one shot).
* :class:`PressurePolicy` — overload controller for the serving tier:
  EMAs a ``latency`` signal's absolute pressure and emits ±1 rung
  recommendations for the degradation ladder (DESIGN.md §10) instead of
  MovePlans — structurally it is still a Rebalancer (``propose``
  returns ``[]``), so it plugs into the same control loop.
"""
from __future__ import annotations

from typing import List, Optional, Protocol, runtime_checkable

import numpy as np

from .plan import MovePlan
from .signals import LoadSignal

__all__ = [
    "Rebalancer",
    "SlopeEMAPolicy",
    "CostRefreshPolicy",
    "HysteresisPolicy",
    "PressurePolicy",
    "make_rebalancer",
    "POLICY_NAMES",
]


@runtime_checkable
class Rebalancer(Protocol):
    """Policy protocol: one ``propose`` per control step."""

    def propose(self, signal: LoadSignal) -> List[MovePlan]:
        ...

    def reset_worker(self, k: int) -> None:
        """Re-seed worker ``k``'s state after an external event
        (elastic join/leave, checkpoint restore)."""
        ...


class SlopeEMAPolicy:
    """Paper §2.5.2, exact — a thin adapter over
    :class:`~repro.core.partition.DynamicController`.

    The controller is fed ``signal.values``/``signal.sizes`` exactly as
    the historical inline call sites did, so the move sequence (and
    therefore the simulator's ``cost_iterations``) is unchanged by the
    control-plane refactor.
    """

    def __init__(self, k: int, target_error: float, eta: float = 0.5,
                 z: int = 10, max_move_frac: float = 0.1,
                 unit: str = "node"):
        # deferred import: core.simulator imports this package at load
        from repro.core.partition import (
            DynamicController,
            DynamicControllerConfig,
        )

        self.ctl = DynamicController(
            DynamicControllerConfig(
                k=k, target_error=target_error, eta=eta, z=z,
                max_move_frac=max_move_frac,
            )
        )
        self.unit = unit

    @property
    def n_moves(self) -> int:
        return self.ctl.n_moves

    def propose(self, signal: LoadSignal) -> List[MovePlan]:
        mi = self.ctl.update(signal.values, signal.sizes)
        if mi is None:
            return []
        return [MovePlan.from_instruction(mi, kind=self.unit)]

    def reset_worker(self, k: int) -> None:
        self.ctl.reset_pid(k)


class CostRefreshPolicy:
    """Periodic CB re-split from observed costs (§2.5.1 made dynamic).

    Every ``period`` control steps: EMA the signal, derive per-unit
    costs ``c_k = ema_k / |Ω_k|``, compute cost-proportional target
    sizes ``target_k ∝ 1/c_k``, and emit the greedy batch of moves from
    over-target to under-target workers.  Fires only when the max/mean
    cost imbalance exceeds ``tol`` (deadband against churn).
    """

    def __init__(self, k: int, period: int = 50, eta: float = 0.5,
                 tol: float = 0.2, max_move_frac: float = 0.25,
                 unit: str = "node"):
        self.k = k
        self.period = period
        self.eta = eta
        self.tol = tol
        self.max_move_frac = max_move_frac
        self.unit = unit
        self.ema: Optional[np.ndarray] = None
        self.n_moves = 0
        self._since = 0

    def propose(self, signal: LoadSignal) -> List[MovePlan]:
        v = np.maximum(signal.values, 1e-12)
        self.ema = v if self.ema is None else (
            self.ema * (1.0 - self.eta) + v * self.eta)
        self._since += 1
        if self._since < self.period:
            return []
        self._since = 0
        sizes = signal.sizes.astype(np.float64)
        live = sizes > 0
        if live.sum() < 2:
            return []
        if self.ema.max() <= (1.0 + self.tol) * self.ema.mean():
            return []
        per_unit = np.where(live, self.ema / np.maximum(sizes, 1.0), np.inf)
        inv = np.where(live, 1.0 / np.maximum(per_unit, 1e-12), 0.0)
        if inv.sum() <= 0:
            return []
        target = sizes.sum() * inv / inv.sum()
        excess = np.where(live, sizes - target, 0.0)
        plans: List[MovePlan] = []
        for _ in range(self.k):
            i = int(np.argmax(excess))
            j = int(np.argmin(excess))
            units = int(min(excess[i], -excess[j],
                            max(sizes[i] - 1, 0) * self.max_move_frac))
            if i == j or units < 1:
                break
            plans.append(MovePlan(src=i, dst=j, units=units,
                                  kind=self.unit))
            excess[i] -= units
            excess[j] += units
            sizes[i] -= units
            sizes[j] += units
        self.n_moves += len(plans)
        return plans

    def reset_worker(self, k: int) -> None:
        if self.ema is not None:
            self.ema[k] = float(self.ema.mean())
        self._since = 0


class HysteresisPolicy:
    """Slope-EMA with deadband + multi-move batching.

    Same slope update as §2.5.2::

        slope_k := slope_k·(1−η) − log10(value_k + ε')·η

    but the 50% trigger must hold for ``patience`` consecutive steps
    (deadband against transient spikes), the required gap is widened by
    ``deadband`` decades, and on firing up to ``max_moves`` extreme
    pairs (slowest↔fastest, 2nd-slowest↔2nd-fastest, …) move in one
    batch, each under the paper's 10% cap and the Z cooldown.
    """

    def __init__(self, k: int, target_error: float, eta: float = 0.5,
                 z: int = 10, max_move_frac: float = 0.1,
                 deadband: float = 0.1, patience: int = 3,
                 max_moves: int = 2, unit: str = "node"):
        # the paper-exact constants/update come from core.partition so a
        # fix there propagates to every slope policy (deferred import:
        # core.simulator imports this package at load)
        from repro.core.partition import DynamicControllerConfig

        cfg = DynamicControllerConfig(k=k, target_error=target_error,
                                      eta=eta, z=z,
                                      max_move_frac=max_move_frac)
        self.k = k
        self.eta = eta
        self.z = z
        self.max_move_frac = max_move_frac
        self.deadband = deadband
        self.patience = patience
        self.max_moves = max_moves
        self.unit = unit
        self.eps_c = cfg.eps_c
        self.trigger_log10 = cfg.trigger_log10
        self.slope = np.zeros(k, dtype=np.float64)
        self.cooldown = np.zeros(k, dtype=np.int64)
        self.streak = 0
        self.n_moves = 0

    def propose(self, signal: LoadSignal) -> List[MovePlan]:
        from repro.core.partition import slope_ema_update

        self.slope = slope_ema_update(self.slope, signal.values,
                                      self.eta, self.eps_c)
        self.cooldown = np.maximum(self.cooldown - 1, 0)
        eligible = np.nonzero(self.cooldown == 0)[0]
        if eligible.size < 2:
            self.streak = 0
            return []
        order = eligible[np.argsort(self.slope[eligible])]
        s_min = self.slope[order[0]]
        s_max = self.slope[order[-1]]
        if not (s_min < s_max + self.trigger_log10 - self.deadband):
            self.streak = 0
            return []
        self.streak += 1
        if self.streak < self.patience:
            return []
        self.streak = 0
        plans: List[MovePlan] = []
        n_pairs = min(self.max_moves, order.size // 2)
        for p in range(n_pairs):
            i_min = int(order[p])
            i_max = int(order[-1 - p])
            lo, hi = self.slope[i_min], self.slope[i_max]
            if p > 0 and not (lo < hi + self.trigger_log10 - self.deadband):
                break  # inner pairs must independently satisfy the rule
            ratio = (lo + 1.0) / (hi + 1.0) if (hi + 1.0) != 0 else 1.0
            frac = min(max(ratio, 0.0), self.max_move_frac)
            units = int(signal.sizes[i_min] * frac)
            if units < 1:
                continue
            self.cooldown[i_min] = self.z
            self.cooldown[i_max] = self.z
            plans.append(MovePlan(src=i_min, dst=i_max, units=units,
                                  kind=self.unit))
        self.n_moves += len(plans)
        return plans

    def reset_worker(self, k: int) -> None:
        self.slope[k] = 0.0
        self.cooldown[k] = self.z
        self.streak = 0


class PressurePolicy:
    """Hysteretic overload controller driving the degradation ladder.

    Same deadband idiom as :class:`HysteresisPolicy`, but the decision
    space is vertical (shed work / restore quality) instead of
    horizontal (move load between workers): the EMA'd worst-worker
    pressure must sit above ``hi`` for ``patience`` consecutive steps to
    recommend stepping DOWN one rung (+1), or below ``lo`` for
    ``patience`` steps to recommend stepping back UP (−1), with a
    ``z``-step cooldown after every decision so the ladder never
    oscillates faster than the signal can respond.

    ``update(signal) -> int`` is the primary API (the ladder calls it
    once per served request); ``propose`` is the Rebalancer-protocol
    shim — it forwards to ``update``, stashes the decision in
    ``last_delta``, and returns no MovePlans.
    """

    def __init__(self, k: int = 1, target_error: float = 0.0,
                 eta: float = 0.3, z: int = 4, hi: float = 1.0,
                 lo: float = 0.5, patience: int = 2,
                 unit: str = "request", **_ignored):
        if lo >= hi:
            raise ValueError(f"need lo < hi, got lo={lo} hi={hi}")
        self.k = k
        self.eta = eta
        self.z = z
        self.hi = hi
        self.lo = lo
        self.patience = patience
        self.unit = unit
        self.ema: Optional[float] = None
        self.last_delta = 0
        self.n_moves = 0
        self._hi_streak = 0
        self._lo_streak = 0
        self._cooldown = 0

    def update(self, signal: LoadSignal) -> int:
        """One control step: returns −1 (relieve), 0 (hold), +1 (shed)."""
        p = float(signal.values.max()) if signal.values.size else 0.0
        self.ema = p if self.ema is None else (
            self.ema * (1.0 - self.eta) + p * self.eta)
        if self._cooldown > 0:
            self._cooldown -= 1
        if self.ema > self.hi:
            self._hi_streak += 1
            self._lo_streak = 0
        elif self.ema < self.lo:
            self._lo_streak += 1
            self._hi_streak = 0
        else:
            self._hi_streak = 0
            self._lo_streak = 0
        if self._cooldown > 0:
            return 0
        if self._hi_streak >= self.patience:
            self._hi_streak = 0
            self._cooldown = self.z
            self.n_moves += 1
            return 1
        if self._lo_streak >= self.patience:
            self._lo_streak = 0
            self._cooldown = self.z
            self.n_moves += 1
            return -1
        return 0

    def propose(self, signal: LoadSignal) -> List[MovePlan]:
        self.last_delta = self.update(signal)
        return []

    def reset_worker(self, k: int) -> None:
        self.ema = None
        self.last_delta = 0
        self._hi_streak = 0
        self._lo_streak = 0
        self._cooldown = self.z


POLICY_NAMES = ("slope_ema", "cost_refresh", "hysteresis", "pressure")


def make_rebalancer(name: str, k: int, target_error: float,
                    eta: float = 0.5, z: int = 10,
                    unit: str = "node", **kw) -> Rebalancer:
    """Config-string dispatch used by SimulatorConfig/EngineConfig."""
    if name == "slope_ema":
        return SlopeEMAPolicy(k=k, target_error=target_error, eta=eta,
                              z=z, unit=unit, **kw)
    if name == "cost_refresh":
        return CostRefreshPolicy(k=k, eta=eta, unit=unit, **kw)
    if name == "hysteresis":
        return HysteresisPolicy(k=k, target_error=target_error, eta=eta,
                                z=z, unit=unit, **kw)
    if name == "pressure":
        return PressurePolicy(k=k, target_error=target_error, eta=eta,
                              z=z, unit=unit, **kw)
    raise ValueError(
        f"unknown rebalancing policy {name!r}; expected one of "
        f"{POLICY_NAMES}"
    )
