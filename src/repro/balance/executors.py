"""Per-granularity executors: MovePlan -> actual state mutation.

Policies decide; executors act.  Each executor handles exactly one unit
kind and reports how many units actually moved (0 when the plan is
infeasible — e.g. the source would be emptied, or no free landing rows
exist), so policies/consumers can account moves truthfully.

* :class:`NodeMoveExecutor` — node-granular, drives the faithful
  simulator: boundary-node reassignment via
  :func:`repro.core.partition.apply_move`, owner-map update, the §2.4
  reassignment-cost charging (moved nodes billed to BOTH PIDs), and the
  receiver-threshold re-seed.
* :class:`BucketMoveExecutor` — bucket-granular, drives the distributed
  engine: plans a row permutation onto the destination device's inert
  headroom rows and applies it in-graph (``jnp.take`` on the sharded
  axis).
* :class:`AdvisoryExecutor` — records plans without acting; the
  runtime's straggler monitor and the MoE expert rebalancer run in this
  mode inside a single process (on a pod the log drives the bucket /
  expert-shard movers).
"""
from __future__ import annotations

from typing import List, Protocol, runtime_checkable

import numpy as np

from .plan import MovePlan

__all__ = [
    "MoveExecutor",
    "NodeMoveExecutor",
    "BucketMoveExecutor",
    "AdvisoryExecutor",
]


@runtime_checkable
class MoveExecutor(Protocol):
    kind: str

    def apply(self, plan: MovePlan) -> int:
        """Execute ``plan``; return the number of units actually moved."""
        ...


class NodeMoveExecutor:
    """Node moves inside :class:`repro.core.simulator.DistributedSimulator`.

    Owns the full §2.5.2 move side-effects that used to live inline in
    the simulator's ``_repartition``:

    * tail-boundary reassignment (:func:`apply_move`, never emptying the
      source set),
    * owner-map update for the moved nodes,
    * §2.4 cost charging — the number of re-affected nodes is billed to
      BOTH PIDs' ``count_active`` and pushed into their debt (freeze
      artifact),
    * receiver threshold re-seed — the destination may now hold hotter
      fluid than its current T.
    """

    kind = "node"

    def __init__(self, sim):
        self.sim = sim

    def apply(self, plan: MovePlan) -> int:
        from repro.core.partition import apply_move

        sim = self.sim
        new_sets, moved = apply_move(sim.sets, plan.to_instruction())
        if moved == 0:
            return 0
        sim.sets = new_sets
        sim.n_moves += 1
        sim.owner[sim.sets[plan.dst]] = plan.dst
        # §2.4: charge the number of re-affected nodes to both PIDs
        sim.count_active[plan.src] += moved
        sim.count_active[plan.dst] += moved
        sim.debt[plan.src] -= moved
        sim.debt[plan.dst] -= moved
        # thresholds: receiving PID may now hold hotter fluid than its T
        s_dst = sim.sets[plan.dst]
        if s_dst.size:
            mx = float(
                (np.abs(sim.f[s_dst]) * sim.weights[s_dst]).max()
            )
            if mx > 0:
                sim.t_k[plan.dst] = min(sim.t_k[plan.dst], mx * 1.0001)
        return moved


class BucketMoveExecutor:
    """Bucket-row moves inside :class:`repro.core.distributed.DistributedEngine`.

    Owns the mutable solve-time layout state: the stable-bucket → row
    map plus the row-permuted edge/weight arrays and the sharded
    :class:`EngineState`.  ``apply`` plans a permutation of up to
    ``plan.units`` real buckets from the source device's tail onto the
    destination device's inert rows and runs the engine's jitted
    in-graph repartition.
    """

    kind = "bucket"

    def __init__(self, engine, state):
        self.engine = engine
        self.state = state
        self.row_of_bucket = np.array(engine.a.pos_of_bucket)
        self.w = engine.w
        self.src_slot = engine.src_slot
        self.dst_bucket = engine.dst_bucket
        self.dst_slot = engine.dst_slot
        self.wgt = engine.wgt
        # BSR tile operands travel with their rows too (None when the
        # engine runs the per-edge segment-sum backend)
        self.tiles = getattr(engine, "tiles", None)
        self.tile_dst = getattr(engine, "tile_dst", None)
        self.slot_out_deg = getattr(engine, "slot_out_deg", None)

    def chunk_operands(self) -> tuple:
        """Row-sharded operands in the order the engine's chunk expects."""
        ops = (self.w, self.src_slot, self.dst_bucket, self.dst_slot,
               self.wgt)
        if self.tiles is not None:
            ops = ops + (self.tiles, self.tile_dst, self.slot_out_deg)
        return ops

    def sizes(self) -> np.ndarray:
        """Real (non-inert) buckets currently owned per device."""
        eng = self.engine
        cfg = eng.cfg
        n_real = cfg.k * (cfg.buckets_per_dev - cfg.headroom)
        dev_of_bucket = self.row_of_bucket // cfg.buckets_per_dev
        return np.bincount(dev_of_bucket[:n_real], minlength=cfg.k)

    def apply(self, plan: MovePlan, keep_min: int = 1) -> int:
        """Execute ``plan``.  ``keep_min=1`` (rebalancing) never empties
        the source device; the rescale drain passes ``keep_min=0`` so a
        dying device can hand over its last bucket."""
        import jax

        eng = self.engine
        perm, new_map, moved = eng._plan_move(
            self.row_of_bucket, plan.src, plan.dst, plan.units,
            keep_min=keep_min)
        if moved == 0:
            return 0
        self.row_of_bucket = new_map
        self.state, arrs = eng._repartition(
            self.state,
            jax.device_put(perm, eng.rep_sharding),
            jax.device_put(new_map.astype(np.int32), eng.rep_sharding),
            self.chunk_operands())
        (self.w, self.src_slot, self.dst_bucket, self.dst_slot,
         self.wgt) = arrs[:5]
        if self.tiles is not None:
            self.tiles, self.tile_dst, self.slot_out_deg = arrs[5:8]
        return moved


class AdvisoryExecutor:
    """Records plans without acting (single-process runtime mode).

    ``log`` keeps every accepted plan; ``drain()`` hands them to
    whatever actually migrates load (bucket mover, expert-shard
    re-placer) and clears the log.
    """

    def __init__(self, kind: str = "device"):
        self.kind = kind
        self.log: List[MovePlan] = []

    def apply(self, plan: MovePlan) -> int:
        self.log.append(plan)
        return plan.units

    def drain(self) -> List[MovePlan]:
        out, self.log = self.log, []
        return out
