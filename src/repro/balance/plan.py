"""Granularity-agnostic move decisions.

A rebalancing policy never sees what a "unit" is — it emits
:class:`MovePlan`\\ s, and a per-granularity executor (see
:mod:`repro.balance.executors`) turns them into node reassignments,
bucket-row permutations, or expert-shard migrations.
"""
from __future__ import annotations

import dataclasses

__all__ = ["MovePlan", "UNIT_KINDS"]

UNIT_KINDS = ("node", "bucket", "expert-shard", "device")


@dataclasses.dataclass(frozen=True)
class MovePlan:
    """«move ``units`` load units from worker ``src`` to worker ``dst``».

    ``src`` is always the overloaded / slow worker shedding load (the
    paper's i_min: the PID with the lagging convergence slope).
    """

    src: int
    dst: int
    units: int
    kind: str = "node"

    def __post_init__(self):
        if self.kind not in UNIT_KINDS:
            raise ValueError(
                f"unknown unit kind {self.kind!r}; expected one of "
                f"{UNIT_KINDS}"
            )
        if self.units < 1:
            raise ValueError(f"units must be >= 1, got {self.units}")
        if self.src == self.dst:
            raise ValueError("src == dst move is a no-op")

    def to_instruction(self):
        """Down-convert for the §2.5.2 primitives in ``core.partition``."""
        # deferred import: core.simulator imports this package at load
        from repro.core.partition import MoveInstruction

        return MoveInstruction(src=self.src, dst=self.dst,
                               n_move=self.units)

    @classmethod
    def from_instruction(cls, mi, kind: str = "node") -> "MovePlan":
        return cls(src=mi.src, dst=mi.dst, units=mi.n_move, kind=kind)
