"""Production mesh definitions (DESIGN.md §6).

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod slice).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; ``pod`` is an outer
data-parallel axis so the only cross-pod (DCN) traffic is the gradient
all-reduce — which the gradient-compression path (repro.optim.compression)
targets.

Defined as functions so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before any jax initialisation).
"""
from __future__ import annotations

__all__ = ["make_production_mesh", "mesh_rules"]


def make_production_mesh(*, multi_pod: bool = False):
    from repro.parallel.compat import make_mesh, mesh_axis_types_kw

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes, **mesh_axis_types_kw(len(axes)))


def mesh_rules(multi_pod: bool):
    from repro.parallel.axes import DEFAULT_RULES, SINGLE_AXIS_RULES

    return DEFAULT_RULES if multi_pod else SINGLE_AXIS_RULES
