"""Serving drivers.

Two modes:

* ``lm`` (default, legacy invocation) — batched prefill + decode for
  any LM arch (reduced config on CPU; production shardings proven by
  the decode/prefill dry-run cells).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --gen 16

* ``rank`` — warm-start multi-RHS PageRank serving on a
  :class:`repro.SolverSession` (DESIGN.md §4): one cold solve builds
  the (H, F) fluid state, then a stream of perturbed teleport vectors
  is served via ``warm_start`` (re-seed ``F = B' − (I−P)H``, §2.2) and
  a personalization batch via the vmapped ``solve_batch`` path.  Prints
  the edge-push ops each warm request saved vs a cold solve.

  The request stream also carries **graph updates** (DESIGN.md §7):
  every ``--churn-every`` requests a link-rotation delta of
  ``--churn`` × L edges flows through ``session.update_graph`` — the
  GraphStore patches its views in place and the fluid re-seeds via
  ``F' = F + (P'−P)·H``, so the evolving graph re-solves warm instead
  of cold.

  Every request passes **admission control** (DESIGN.md §10): poison
  personalization vectors (NaN / negative / zero mass — exercised by
  ``--poison-every``) and stale or malformed graph deltas are rejected
  into a quarantine WITHOUT killing the session; the stream keeps
  serving and the quarantine tally prints at exit.  A graph update
  that fails mid-apply rolls back transactionally (the session keeps
  serving the pre-delta graph).

  The serving process is **elastic and fault tolerant** (DESIGN.md §8):
  ``--ckpt-dir`` cuts an atomic checkpoint of the (H, F) fluid state
  after every request; ``--resume`` restores the newest checkpoint that
  passes the ``B = (I−P)H + F`` invariant check instead of solving
  cold (torn/stale steps are rejected and skipped); ``--rescale-at R
  --rescale-k K`` shrinks/grows the engine's pid axis mid-stream
  (device loss / scale-up) without recomputing H — engine methods only.

    PYTHONPATH=src python -m repro.launch.serve rank --n 20000 --requests 8
    PYTHONPATH=src python -m repro.launch.serve rank --churn 0.01 \\
        --churn-every 3
    PYTHONPATH=src python -m repro.launch.serve rank --ckpt-dir /tmp/ck \\
        --resume
"""
import argparse
import sys
import time

import numpy as np


def lm_main(argv):
    import jax
    import jax.numpy as jnp

    from repro.configs import ARCH_IDS, get_arch
    from repro.configs.smoke import smoke_setup
    from repro.data import lm_token_batch
    from repro.models import transformer as lm

    ap = argparse.ArgumentParser(prog="serve [lm]")
    ap.add_argument("--arch", required=True,
                    choices=[a for a in ARCH_IDS])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args(argv)

    spec = get_arch(args.arch)
    assert spec.family == "lm", "serving applies to the LM archs"
    cfg, _, _ = smoke_setup(args.arch)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    max_seq = args.prompt + args.gen
    prompts = jnp.asarray(
        lm_token_batch(0, args.batch, args.prompt, cfg.vocab)["tokens"])

    prefill = jax.jit(lambda p, t: lm.prefill_step(p, t, cfg,
                                                   max_seq=max_seq))
    decode = jax.jit(lambda p, c, t: lm.decode_step(p, c, t, cfg))

    t0 = time.time()
    cache, logits = prefill(params, prompts)
    jax.block_until_ready(logits)
    print(f"[{args.arch}] prefill {args.batch}x{args.prompt}: "
          f"{(time.time()-t0)*1e3:.0f} ms")
    toks = jnp.argmax(logits, -1)
    outs = [toks]
    t0 = time.time()
    for _ in range(args.gen - 1):
        logits, cache = decode(params, cache, toks)
        toks = jnp.argmax(logits, -1)
        outs.append(toks)
    jax.block_until_ready(toks)
    dt = time.time() - t0
    print(f"decode {args.gen-1} steps: {dt*1e3:.0f} ms "
          f"({args.batch*(args.gen-1)/dt:.0f} tok/s)")
    print("generated ids:",
          np.stack([np.asarray(t) for t in outs], 1)[0][:12].tolist())


def rank_main(argv):
    ap = argparse.ArgumentParser(prog="serve rank")
    ap.add_argument("--n", type=int, default=20_000)
    ap.add_argument("--method", default="frontier:segment_sum",
                    help="warm-startable registry key")
    ap.add_argument("--requests", type=int, default=8,
                    help="warm-start requests to serve after the cold "
                    "solve")
    ap.add_argument("--batch", type=int, default=8,
                    help="personalization columns for the solve_batch "
                    "demo")
    ap.add_argument("--drift", type=float, default=0.02,
                    help="per-request fractional perturbation of B")
    ap.add_argument("--churn", type=float, default=0.0,
                    help="graph-update request: fraction of edges "
                    "link-rotated per update (0 disables)")
    ap.add_argument("--churn-every", type=int, default=3,
                    help="serve a graph-update request every this many "
                    "warm requests")
    ap.add_argument("--poison-every", type=int, default=0,
                    help="inject a poison (NaN) personalization vector "
                    "every this many requests to exercise admission "
                    "control (0 disables)")
    ap.add_argument("--target-error", type=float, default=None)
    ap.add_argument("--k", type=int, default=None,
                    help="engine methods: devices on the pid axis")
    ap.add_argument("--ckpt-dir", default=None,
                    help="atomic fluid-state checkpoint after every "
                    "served request (DESIGN.md §8)")
    ap.add_argument("--resume", action="store_true",
                    help="restore the newest VALID checkpoint from "
                    "--ckpt-dir instead of a cold solve")
    ap.add_argument("--rescale-at", type=int, default=None,
                    help="request index at which to rescale the pid "
                    "axis (engine methods)")
    ap.add_argument("--rescale-k", type=int, default=None,
                    help="pid-axis width to rescale to at --rescale-at")
    ap.add_argument("--no-batching", action="store_true",
                    help="serve the stream strictly sequentially (the "
                    "pre-scheduler path; output is bit-identical to it)")
    ap.add_argument("--max-lanes", type=int, default=16,
                    help="continuous batching: lane-axis cap (pow2)")
    ap.add_argument("--rounds-per-tick", type=int, default=32,
                    help="continuous batching: frontier rounds per "
                    "scheduler micro-step")
    args = ap.parse_args(argv)
    if args.churn > 0 and args.churn_every < 1:
        ap.error("--churn-every must be >= 1 when --churn is set")
    if (args.rescale_at is None) != (args.rescale_k is None):
        ap.error("--rescale-at and --rescale-k go together")
    if args.resume and not args.ckpt_dir:
        ap.error("--resume needs --ckpt-dir")
    # the scheduler is frontier-native and stateless across processes:
    # session-exclusive features (checkpoint/resume, pid-axis rescale,
    # engine backends) keep the sequential path (DESIGN.md §11
    # migration note)
    sequential = (args.no_batching or args.ckpt_dir or args.resume
                  or args.rescale_at is not None
                  or args.method != "frontier:segment_sum")
    if sequential:
        return _rank_sequential(args)
    return _rank_batched(args)


def _rank_batched(args):
    """Default rank serving: the request stream flows through the
    continuous-batching :class:`repro.serving.Scheduler` — same seeded
    stream (drift chain, poison schedule, churn deltas) as the
    sequential path, but rank requests between graph updates are
    served concurrently in kernel lanes.  Graph updates are natural
    drain barriers: the scheduler flushes each delta against the
    post-predecessor store, exactly the sequential ordering."""
    import repro
    from repro.core import webgraph_like
    from repro.graph import rotation_churn
    from repro.resilience import RequestRejected
    from repro.serving import Scheduler

    rng = np.random.default_rng(0)
    g = webgraph_like(args.n, seed=1)
    problem = repro.Problem.pagerank(g, target_error=args.target_error)
    print(f"N={g.n} L={g.n_edges} method={args.method} "
          f"target_error={problem.target_error:.2e}")
    sch = Scheduler(problem, max_lanes=args.max_lanes,
                    rounds_per_tick=args.rounds_per_tick)
    print(f"[mode ] continuous batching: max_lanes={sch.batcher.max_lanes}"
          f" rounds_per_tick={sch.rounds_per_tick} "
          f"pool_capacity={sch.pool.capacity}")

    printed = 0

    def drain_and_report():
        nonlocal printed
        sch.run_until_idle()
        for r in sch.results[printed:]:
            print(f"[served {r.request_id}] |res|={r.residual:.2e} "
                  f"{r.ops} ops, {r.rounds} rounds, "
                  f"pool_hit={r.pool_hit}, lat={r.latency_s:.3f}s"
                  + (f" [degraded rung={r.rung}]" if r.degraded else ""))
        printed = len(sch.results)

    t0 = time.time()
    b = problem.b
    for req in range(args.requests):
        if args.churn > 0 and req % args.churn_every == args.churn_every - 1:
            drain_and_report()  # the update's drain barrier
            n_rot = max(1, int(args.churn * sch.problem.n_edges) // 2)
            delta = rotation_churn(sch.problem.graph, n_rot,
                                   seed=1000 + req)
            try:
                sch.submit_update(
                    delta, store_version=sch.problem.store_version)
                sch.run_until_idle()  # flush: apply at the barrier
                print(f"[update {req}] {delta.n_changes} changed edges "
                      f"applied, store at version "
                      f"{sch.problem.store_version}")
            except RequestRejected as e:
                print(f"[quarantine {req}] update rejected: {e}")
            continue
        b = b * (1.0 + args.drift * rng.standard_normal(g.n))
        b = np.abs(b)
        b_req = b
        if args.poison_every and req % args.poison_every == (
                args.poison_every - 1):
            b_req = b.copy()
            b_req[rng.integers(g.n)] = np.nan  # a client sent garbage
        try:
            sch.submit(b_req, cluster=0, request_id=req)
        except RequestRejected as e:
            print(f"[quarantine {req}] rank request rejected: {e}")
    drain_and_report()
    wall = time.time() - t0
    if sch.quarantine.total:
        print(f"[quarantine] {sch.quarantine.total} rejected: "
              f"{sch.quarantine.to_jsonable()['by_reason']}")
    served = len(sch.results)
    lat = sch.latency_percentiles()
    print(f"[stats] served={served} dropped={sch.dropped} "
          f"qps={served / max(wall, 1e-9):.2f} "
          f"pool_hit_rate={sch.pool.hit_rate:.2f} "
          f"occupancy={sch.batcher.mean_occupancy:.2f} "
          f"p50={lat['p50']:.3f}s p99={lat['p99']:.3f}s "
          f"rung={sch.ladder.rung.name}")


def _rank_sequential(args):
    """The pre-scheduler rank loop, preserved verbatim: one
    warm-started session, strictly one request at a time.  The
    ``--no-batching`` regression test holds this path's output
    bit-identical to the pre-PR-8 CLI."""
    import repro
    from repro.core import webgraph_like

    rng = np.random.default_rng(0)
    g = webgraph_like(args.n, seed=1)
    problem = repro.Problem.pagerank(g, target_error=args.target_error)
    options = repro.SolverOptions(k=args.k)
    print(f"N={g.n} L={g.n_edges} method={args.method} "
          f"target_error={problem.target_error:.2e}")

    session = None
    if args.resume:
        try:
            t0 = time.time()
            session = repro.SolverSession.restore(
                args.ckpt_dir, problem, method=args.method,
                options=options)
            info = session.restored_from
            print(f"[resume] step {info['step']} "
                  f"({len(info['rejected'])} rejected), residual="
                  f"{session.residual:.2e}, {time.time()-t0:.2f}s — "
                  "H carried over, no cold solve")
            session.solve()  # drain whatever fluid remains
            # no cold baseline this process: savings are reported
            # against what a cold solve of this problem WOULD cost
            baseline_ops = None
        except FileNotFoundError:
            print("[resume] no checkpoint yet — starting cold")
            session = None
        except ValueError as e:
            # checkpoints exist but every step was rejected (torn /
            # stale / wrong graph): serving must come up cold, not die
            print(f"[resume] no VALID checkpoint ({e}) — starting cold")
            session = None
    if session is None:
        session = repro.SolverSession(problem, method=args.method,
                                      options=options)
        t0 = time.time()
        cold = session.solve()
        baseline_ops = cold.n_ops
        print(f"[cold ] {cold.n_ops} edge pushes, {cold.n_rounds} "
              f"rounds, {time.time()-t0:.2f}s — the serving baseline")
    if args.ckpt_dir:
        print(f"[ckpt ] {session.checkpoint(args.ckpt_dir)}")

    from repro.graph import rotation_churn
    from repro.resilience import (Quarantine, RequestRejected,
                                  validate_graph_update, validate_rhs)

    quarantine = Quarantine()
    b = problem.b
    for req in range(args.requests):
        if args.rescale_at is not None and req == args.rescale_at:
            t0 = time.time()
            drains = session.rescale(args.rescale_k)
            print(f"[rescale {req}] pid axis -> k={args.rescale_k} "
                  f"({len(drains)} buckets drained through the executor "
                  f"path), {time.time()-t0:.2f}s — H not recomputed")
        if args.churn > 0 and req % args.churn_every == args.churn_every - 1:
            # a graph-update request: the crawl delivered link churn
            n_rot = max(1, int(args.churn * session.problem.n_edges) // 2)
            delta = rotation_churn(session.problem.graph, n_rot,
                                   seed=1000 + req)
            t0 = time.time()
            try:
                # admission: a delta built against a stale store
                # version or naming edges the store doesn't hold never
                # reaches the session
                validate_graph_update(
                    session.problem.graph, delta,
                    store_version=session.problem.store_version)
                resid0 = session.update_graph(delta)
            except RequestRejected as e:
                quarantine.record(req, e.reason)
                print(f"[quarantine {req}] update rejected: {e}")
                continue
            except Exception as e:
                # update_graph rolled the store back: the session still
                # serves the pre-delta graph, the stream keeps flowing
                quarantine.record(req, "update-failed")
                print(f"[quarantine {req}] update failed, rolled back: "
                      f"{e}")
                continue
            rep = session.solve()
            saved = (f"{1.0 - rep.n_ops / max(baseline_ops, 1):.0%}"
                     if baseline_ops else "n/a")
            print(f"[update {req}] {delta.n_changes} changed edges "
                  f"|F0|={resid0:.2e} {rep.n_ops} ops ({saved} saved "
                  f"vs cold), {rep.n_rounds} rounds, {time.time()-t0:.2f}s")
            if args.ckpt_dir:
                session.checkpoint(args.ckpt_dir)
            continue
        # a drifting teleport vector: what a freshness-weighted or
        # user-conditioned ranking update looks like between requests
        b = b * (1.0 + args.drift * rng.standard_normal(g.n))
        b = np.abs(b)
        b_req = b
        if args.poison_every and req % args.poison_every == (
                args.poison_every - 1):
            b_req = b.copy()
            b_req[rng.integers(g.n)] = np.nan  # a client sent garbage
        t0 = time.time()
        try:
            b_ok = validate_rhs(b_req, g.n)
        except RequestRejected as e:
            quarantine.record(req, e.reason)
            print(f"[quarantine {req}] rank request rejected: {e}")
            continue
        resid0 = session.warm_start(b_ok)
        rep = session.solve()
        saved = (f"{1.0 - rep.n_ops / max(baseline_ops, 1):.0%}"
                 if baseline_ops else "n/a")
        print(f"[warm {req}] |F0|={resid0:.2e} {rep.n_ops} ops "
              f"({saved} saved vs cold), {rep.n_rounds} rounds, "
              f"{time.time()-t0:.2f}s")
        if args.ckpt_dir:
            session.checkpoint(args.ckpt_dir)
    if quarantine.total:
        print(f"[quarantine] {quarantine.total} rejected: "
              f"{quarantine.to_jsonable()['by_reason']}")

    # personalized batch: C independent teleport columns, one vmapped run
    hot = rng.choice(g.n, size=args.batch, replace=False)
    pref = np.zeros((g.n, args.batch))
    pref[hot, np.arange(args.batch)] = 1.0
    t0 = time.time()
    batch = session.solve_batch((1.0 - problem.damping) * pref)
    dt = time.time() - t0
    print(f"[batch] {args.batch} personalized columns in one vmapped "
          f"solve: {batch.n_ops} ops, {batch.n_rounds} rounds, {dt:.2f}s "
          f"({args.batch/max(dt, 1e-9):.1f} rankings/s), "
          f"converged={batch.converged}")
    for c in range(min(3, args.batch)):
        top = np.argsort(-batch.x[:, c])[:3]
        print(f"  persona {c} (seed node {hot[c]}): top-3 {top.tolist()}")


def main():
    argv = sys.argv[1:]
    if argv and argv[0] == "rank":
        return rank_main(argv[1:])
    if argv and argv[0] == "lm":
        argv = argv[1:]
    return lm_main(argv)


if __name__ == "__main__":
    main()
