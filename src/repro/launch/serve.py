"""Serving driver: batched prefill + decode for any LM arch (reduced config
on CPU; production shardings proven by the decode/prefill dry-run cells).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --gen 16
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def main():
    from repro.configs import ARCH_IDS, get_arch
    from repro.configs.smoke import smoke_setup
    from repro.data import lm_token_batch
    from repro.models import transformer as lm

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True,
                    choices=[a for a in ARCH_IDS])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    spec = get_arch(args.arch)
    assert spec.family == "lm", "serving applies to the LM archs"
    cfg, _, _ = smoke_setup(args.arch)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    max_seq = args.prompt + args.gen
    prompts = jnp.asarray(
        lm_token_batch(0, args.batch, args.prompt, cfg.vocab)["tokens"])

    prefill = jax.jit(lambda p, t: lm.prefill_step(p, t, cfg,
                                                   max_seq=max_seq))
    decode = jax.jit(lambda p, c, t: lm.decode_step(p, c, t, cfg))

    t0 = time.time()
    cache, logits = prefill(params, prompts)
    jax.block_until_ready(logits)
    print(f"[{args.arch}] prefill {args.batch}x{args.prompt}: "
          f"{(time.time()-t0)*1e3:.0f} ms")
    toks = jnp.argmax(logits, -1)
    outs = [toks]
    t0 = time.time()
    for _ in range(args.gen - 1):
        logits, cache = decode(params, cache, toks)
        toks = jnp.argmax(logits, -1)
        outs.append(toks)
    jax.block_until_ready(toks)
    dt = time.time() - t0
    print(f"decode {args.gen-1} steps: {dt*1e3:.0f} ms "
          f"({args.batch*(args.gen-1)/dt:.0f} tok/s)")
    print("generated ids:",
          np.stack([np.asarray(t) for t in outs], 1)[0][:12].tolist())


if __name__ == "__main__":
    main()
