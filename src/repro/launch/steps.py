"""Step-function builders per (family × cell kind).

Each builder returns ``(step_fn, abstract_args, in_specs, out_specs)`` ready
for ``jax.jit(step_fn, in_shardings=...).lower(*abstract_args)`` — used both
by the dry-run (ShapeDtypeStructs, production mesh) and by the real drivers
(concrete arrays, any mesh or none).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.common import ArchSpec, ShapeCell
from repro.models import gnn as gnn_model
from repro.models import recsys as fm_model
from repro.models import transformer as lm
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.parallel.axes import logical_to_spec
from repro.parallel.sharding import input_sharding_specs, param_sharding_specs

__all__ = ["build_cell_step", "effective_overrides"]


def effective_overrides(spec: ArchSpec, cell: ShapeCell,
                        dp_shards: int) -> Dict[str, Any]:
    """Per-mesh adjustment: keep per-device microbatch >= 1 and divisible."""
    ov = dict(cell.overrides)
    if spec.family == "lm" and cell.kind == "train":
        nm = ov.get("n_microbatches", 1)
        if nm > 1:
            batch = cell.meta["batch"]
            nm = min(nm, max(1, batch // dp_shards))
            while batch % nm or (batch // nm) % dp_shards:
                nm -= 1
                if nm <= 1:
                    nm = 1
                    break
            ov["n_microbatches"] = nm
    return ov


def _opt_specs(pspecs):
    return {
        "master": pspecs,
        "m": jax.tree.map(lambda s: s, pspecs),
        "v": jax.tree.map(lambda s: s, pspecs),
        "step": P(),
    }


def build_cell_step(
    spec: ArchSpec,
    cell: ShapeCell,
    rules: Dict[str, Any],
    ocfg: Optional[AdamWConfig] = None,
    dp_shards: int = 1,
    axis_sizes: Optional[Dict[str, int]] = None,
):
    """Returns (step_fn, abstract_args tuple, in_specs tuple)."""
    import dataclasses

    ov = effective_overrides(spec, cell, dp_shards)
    cfg = (dataclasses.replace(spec.model_cfg, **ov) if ov
           else spec.model_cfg)
    ocfg = ocfg or AdamWConfig()
    inputs = cell.inputs()
    in_axes = cell.input_axes
    batch_specs = input_sharding_specs(inputs, in_axes, rules,
                                   axis_sizes=axis_sizes)

    if spec.family == "lm":
        params_abs = jax.eval_shape(
            lambda k: lm.init_params(cfg, k), jax.random.PRNGKey(0)
        )
        pspecs = param_sharding_specs(params_abs, "lm", rules,
                              axis_sizes=axis_sizes)
        if cell.kind == "train":
            opt_abs = jax.eval_shape(adamw_init, params_abs)
            ospecs = _opt_specs(pspecs)
            nm = cfg.n_microbatches
            # microbatching happens HERE via per-microbatch value_and_grad
            # accumulated in a scan carry (fp32), NOT inside the loss —
            # backprop through a scan-of-forwards would store every
            # microbatch's residuals and erase the memory win.
            import dataclasses as _dc

            cfg_mb = _dc.replace(cfg, n_microbatches=1)

            def step(params, opt_state, batch):
                if nm <= 1:
                    loss, grads = jax.value_and_grad(
                        lambda p: lm.train_loss(p, batch, cfg_mb)
                    )(params)
                else:
                    b = batch["tokens"].shape[0]
                    from repro.parallel.axes import hint as _hint

                    tok = batch["tokens"].reshape(nm, b // nm, -1)
                    lab = batch["labels"].reshape(nm, b // nm, -1)
                    tok = _hint(tok, None, "batch", None)
                    lab = _hint(lab, None, "batch", None)

                    def mb_body(carry, tl):
                        acc_loss, acc_g = carry
                        t, l_ = tl
                        loss, g = jax.value_and_grad(
                            lambda p: lm.train_loss(
                                p, {"tokens": t, "labels": l_}, cfg_mb)
                        )(params)
                        acc_g = jax.tree.map(
                            lambda a, x: a + x.astype(jnp.float32),
                            acc_g, g)
                        return (acc_loss + loss, acc_g), None

                    zero_g = jax.tree.map(
                        lambda p: jnp.zeros(p.shape, jnp.float32), params)
                    (loss, grads), _ = jax.lax.scan(
                        mb_body, (jnp.zeros((), jnp.float32), zero_g),
                        (tok, lab))
                    loss = loss / nm
                    grads = jax.tree.map(lambda g: g / nm, grads)
                params, opt_state, m = adamw_update(
                    grads, opt_state, ocfg, param_dtype=cfg.dtype
                )
                return params, opt_state, {"loss": loss, **m}

            return (step, (params_abs, opt_abs, inputs),
                    (pspecs, ospecs, batch_specs))

        if cell.kind == "prefill":

            def step(params, batch):
                cache, logits = lm.prefill_step(params, batch["tokens"], cfg)
                return cache["k"], cache["v"], logits

            return step, (params_abs, inputs), (pspecs, batch_specs)

        if cell.kind == "decode":

            def step(params, batch):
                cache = {"k": batch["cache_k"], "v": batch["cache_v"],
                         "pos": batch["pos"]}
                if "cache_k_scale" in batch:
                    cache["k_scale"] = batch["cache_k_scale"]
                    cache["v_scale"] = batch["cache_v_scale"]
                logits, new_cache = lm.decode_step(
                    params, cache, batch["tokens"], cfg
                )
                return logits, new_cache["k"], new_cache["v"]

            return step, (params_abs, inputs), (pspecs, batch_specs)

    elif spec.family == "gnn":
        params_abs = jax.eval_shape(
            lambda k: gnn_model.init_params(cfg, k), jax.random.PRNGKey(0)
        )
        pspecs = param_sharding_specs(params_abs, "gnn", rules,
                              axis_sizes=axis_sizes)
        opt_abs = jax.eval_shape(adamw_init, params_abs)
        ospecs = _opt_specs(pspecs)

        def step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(
                lambda p: gnn_model.loss_fn(p, batch, cfg)
            )(params)
            params, opt_state, m = adamw_update(
                grads, opt_state, ocfg, param_dtype=cfg.dtype
            )
            return params, opt_state, {"loss": loss, **m}

        return (step, (params_abs, opt_abs, inputs),
                (pspecs, ospecs, batch_specs))

    elif spec.family == "recsys":
        params_abs = jax.eval_shape(
            lambda k: fm_model.init_params(cfg, k), jax.random.PRNGKey(0)
        )
        pspecs = param_sharding_specs(params_abs, "recsys", rules,
                              axis_sizes=axis_sizes)
        if cell.kind == "train":
            opt_abs = jax.eval_shape(adamw_init, params_abs)
            ospecs = _opt_specs(pspecs)

            def step(params, opt_state, batch):
                loss, grads = jax.value_and_grad(
                    lambda p: fm_model.loss_fn(p, batch, cfg)
                )(params)
                params, opt_state, m = adamw_update(
                    grads, opt_state, ocfg, param_dtype=cfg.dtype
                )
                return params, opt_state, {"loss": loss, **m}

            return (step, (params_abs, opt_abs, inputs),
                    (pspecs, ospecs, batch_specs))

        if cell.kind == "serve":

            def step(params, batch):
                return fm_model.forward_logits(params, batch["ids"], cfg)

            return step, (params_abs, inputs), (pspecs, batch_specs)

        if cell.kind == "retrieval":

            def step(params, batch):
                return fm_model.retrieval_score(
                    params, batch["user_ids"], batch["cand_ids"], cfg
                )

            return step, (params_abs, inputs), (pspecs, batch_specs)

    raise ValueError((spec.family, cell.kind))
