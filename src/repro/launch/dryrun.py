import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this proves on 512 placeholder devices that the sharding
config is coherent: ``jax.jit(step, in_shardings=...).lower(...).compile()``
must succeed, fit memory, and produce the cost/collective numbers the
roofline analysis (benchmarks/roofline.py) consumes.

Usage:
  python -m repro.launch.dryrun --arch qwen1.5-0.5b --cell train_4k --mesh single
  python -m repro.launch.dryrun --all            # every cell × both meshes
  python -m repro.launch.dryrun --solver         # the paper's engine entry

Outputs: results/dryrun/<arch>__<cell>__<mesh>.json
"""
import argparse
import json
import re
import sys
import time
import traceback

import jax
import numpy as np

from repro.parallel.compat import cost_analysis_dict, mesh_axis_types_kw

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s+(\w+)\[([\d,]*)\][^\s]*\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(",
)
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[([^\]]*)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{([^}]*)\}")


_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_WHILE_RE = re.compile(
    r"while\(.*?\),\s*condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_CALL_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _split_computations(hlo_text: str):
    """{computation name: [lines]} from post-optimization HLO text.

    Headers are column-0 lines ending in ``{`` whose first token is the
    computation name (possibly prefixed with ENTRY); parameter lists can
    contain nested tuple parens, so only the name is parsed.
    """
    comps = {}
    cur = None
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if (not line.startswith(" ") and stripped.endswith("{")
                and (stripped.startswith("%")
                     or stripped.startswith("ENTRY"))):
            m = _COMP_HDR_RE.match(stripped)
            if m:
                cur = m.group(1)
                comps[cur] = []
                continue
        if cur is not None:
            if stripped == "}":
                cur = None
            else:
                comps[cur].append(stripped)
    return comps


_SHAPE_RE = re.compile(r"\w+\[(\d+)[,\]]")


def _while_trips(line: str, trip_candidates) -> int:
    """Trip count of a while from its carried-tuple shapes.

    A lax.scan of length T stacks its xs/ys with leading dim T; the while
    op's printed result tuple exposes those leading dims.  We vote among
    the candidate trip counts for this loop's NESTING DEPTH (stacked layer
    params thread through outer loops too, so depth-blind voting
    mis-attributes the microbatch loop to the layer count).
    """
    if not trip_candidates:
        return 1
    votes = {}
    for m in _SHAPE_RE.finditer(line.split(" while(")[0]):
        d = int(m.group(1))
        if d in trip_candidates:
            votes[d] = votes.get(d, 0) + 1
    if not votes:
        return 1
    return max(votes.items(), key=lambda kv: kv[1])[0]


def _loop_multipliers(comps, trip_candidates=()):
    """Multiplier per computation = product of enclosing while trip counts.

    XLA:CPU's cost_analysis counts while bodies ONCE (verified in
    EXPERIMENTS.md §Dry-run), so the collective inventory must re-apply
    the trip counts.  ``trip_candidates`` is either a flat set (depth-blind)
    or a list of per-depth sets: ``[ {outermost trips}, {depth-1 trips},
    ... ]`` — a while at nesting depth d only votes within candidates[d]
    (falling back to the last entry for deeper loops).
    """
    if trip_candidates and isinstance(trip_candidates, (set, frozenset)):
        by_depth = [set(trip_candidates)]
    else:
        by_depth = [set(s) for s in trip_candidates] or [set()]

    children = {}  # comp -> [(child_comp, kind, payload)]
    for name, lines in comps.items():
        for line in lines:
            wm = _WHILE_RE.search(line)
            if wm:
                cond, body = wm.group(1), wm.group(2)
                children.setdefault(name, []).append(("while", body, line))
                children.setdefault(name, []).append(("call", cond, None))
            elif "to_apply=" in line and "fusion" not in line:
                cm = _CALL_RE.search(line)
                if cm:
                    children.setdefault(name, []).append(
                        ("call", cm.group(1), None))

    referenced = {c for kids in children.values() for _, c, _ in kids}
    roots = [n for n in comps if n not in referenced]
    mult = {}

    def walk(name, m, depth):
        if m <= mult.get(name, 0):
            return
        mult[name] = m
        for kind, child, line in children.get(name, []):
            if kind == "while":
                cand = by_depth[min(depth, len(by_depth) - 1)]
                trips = _while_trips(line, cand)
                walk(child, m * max(trips, 1), depth + 1)
            else:
                walk(child, m, depth)

    for r in roots:
        walk(r, 1, 0)
    return mult


def parse_collectives(hlo_text: str, trip_candidates=()):
    """Loop-aware per-device collective inventory from post-SPMD HLO.

    Each collective record carries ``trips`` — the product of enclosing
    while-loop trip counts (scan-over-layers × microbatch scan × ...) —
    and ``moved_bytes`` already scaled by it.  ``trip_candidates`` are the
    known scan lengths of the lowered cell (layers, microbatches, chunks).

    Bytes-moved estimate per op (ring algorithms, per participating device):
      all-reduce:        2·b·(g-1)/g      (b = result bytes)
      all-gather:        b·(g-1)/g        (b = full gathered result)
      reduce-scatter:    b·(g-1)          (b = scattered result)
      all-to-all:        b·(g-1)/g
      collective-permute: b
    """
    comps = _split_computations(hlo_text)
    mult = _loop_multipliers(comps, trip_candidates)
    out = []
    for comp_name, lines in comps.items():
        trips = mult.get(comp_name, 1)
        for line in lines:
            m = _COLL_RE.search(line)
            if not m:
                continue
            dtype, dims, op = m.group(1), m.group(2), m.group(3)
            if dtype not in _DTYPE_BYTES:
                continue
            n_elem = 1
            if dims:
                for d in dims.split(","):
                    n_elem *= int(d)
            nbytes = n_elem * _DTYPE_BYTES[dtype]
            g = 1
            iota = ""
            gm = _GROUPS_RE.search(line)
            if gm:
                g = int(gm.group(2))
                iota = gm.group(3)
            else:
                gl = _GROUPS_LIST_RE.search(line)
                if gl:
                    first = gl.group(1).split("}")[0].strip("{} ")
                    g = len([t for t in first.split(",")
                             if t.strip() != ""])
            if op == "all-reduce":
                moved = 2.0 * nbytes * (g - 1) / max(g, 1)
            elif op == "all-gather":
                moved = nbytes * (g - 1) / max(g, 1)
            elif op == "reduce-scatter":
                moved = float(nbytes) * (g - 1)
            elif op == "all-to-all":
                moved = nbytes * (g - 1) / max(g, 1)
            else:  # collective-permute
                moved = float(nbytes)
            out.append(
                {"op": op, "dtype": dtype, "result_bytes": nbytes,
                 "group_size": g, "groups_iota": iota, "trips": trips,
                 "moved_bytes": moved * trips}
            )
    return out


def classify_link(rec, n_single_pod=256):
    """DCN if the group stride spans pods (iota factor >= chips/pod)."""
    iota = rec.get("groups_iota", "")
    if rec["group_size"] == 2 and iota.startswith("2,"):
        return "dcn"  # leading pod-axis split
    # groups over contiguous in-pod ranges are ICI
    return "ici"


def lower_cell(arch_id: str, cell_name: str, multi_pod: bool,
               out_dir: str = None, verbose: bool = True):
    from repro.configs import get_arch
    from repro.launch.mesh import make_production_mesh, mesh_rules
    from repro.launch.steps import build_cell_step
    from repro.parallel.axes import axis_rules

    spec = get_arch(arch_id)
    cell = spec.cells[cell_name]
    if cell.skip:
        return {"arch": arch_id, "cell": cell_name, "skipped": cell.skip}
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    if cell.meta.get("mesh_only") and cell.meta["mesh_only"] != mesh_name:
        return {"arch": arch_id, "cell": cell_name,
                "skipped": f"mesh_only={cell.meta['mesh_only']}"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = {**mesh_rules(multi_pod), **spec.rules_override,
             **cell.rules_override}
    dp = 1
    batch_axes = rules.get("batch")
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if isinstance(batch_axes, tuple):
        for a in batch_axes:
            dp *= sizes.get(a, 1)
    elif batch_axes:
        dp = sizes.get(batch_axes, 1)

    t0 = time.time()
    with axis_rules(rules, mesh=mesh):
        step, args, in_specs = build_cell_step(spec, cell, rules,
                                               dp_shards=dp,
                                               axis_sizes=sizes)
        from jax.sharding import NamedSharding

        in_shardings = jax.tree.map(
            lambda s: NamedSharding(mesh, s), in_specs,
            is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec),
        )
        # train: donate params+opt; decode: donate the batch (cache
        # buffers alias their updated outputs — in-place KV update)
        donate = ((0, 1) if cell.kind == "train"
                  else (1,) if cell.kind == "decode" else ())
        with mesh:
            jitted = jax.jit(step, in_shardings=in_shardings,
                             donate_argnums=donate)
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    ca = cost_analysis_dict(compiled)
    hlo = compiled.as_text()
    # known scan lengths of this cell -> while trip counts per nesting
    # depth (see parse_collectives; XLA:CPU cost analysis counts loop
    # bodies once, and stacked layer params thread through outer loops,
    # so candidates must be depth-indexed)
    trip_candidates = []
    if spec.family == "lm":
        import dataclasses as _dc

        from repro.launch.steps import effective_overrides

        ov = effective_overrides(spec, cell, dp)
        cfg_eff = (_dc.replace(spec.model_cfg, **ov) if ov
                   else spec.model_cfg)
        l = cfg_eff.n_layers
        nm = cfg_eff.n_microbatches
        seq = cell.meta.get("seq", 0)
        n_ce = (seq // cfg_eff.ce_chunk
                if (cell.kind == "train" and seq
                    and seq // cfg_eff.ce_chunk > 1) else 0)
        n_attn = (seq // cfg_eff.attn_q_chunk
                  if (cfg_eff.attn_q_chunk and seq
                      and cell.kind in ("train", "prefill")) else 0)
        inner = {l} | ({n_ce} if n_ce else set()) \
            | ({n_attn} if n_attn else set())
        if cell.kind == "train" and nm > 1:
            trip_candidates = [{nm}, inner,
                               ({n_ce} if n_ce else set())
                               | ({n_attn} if n_attn else set())]
        else:
            trip_candidates = [inner,
                               ({n_ce} if n_ce else set())
                               | ({n_attn} if n_attn else set())]
    colls = parse_collectives(hlo, trip_candidates)
    for c in colls:
        c["link"] = classify_link(c)
    result = {
        "arch": arch_id,
        "cell": cell_name,
        "mesh": "pod2x16x16" if multi_pod else "pod16x16",
        "n_devices": int(np.prod(mesh.devices.shape)),
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        },
        "cost": {
            "flops_per_device": ca.get("flops"),
            "bytes_per_device": ca.get("bytes accessed"),
        },
        "collectives": {
            "count": len(colls),
            "moved_bytes_total": sum(c["moved_bytes"] for c in colls),
            "moved_bytes_ici": sum(
                c["moved_bytes"] for c in colls if c["link"] == "ici"),
            "moved_bytes_dcn": sum(
                c["moved_bytes"] for c in colls if c["link"] == "dcn"),
            "by_op": _by_op(colls),
            "records": colls[:200],
        },
        "meta": cell.meta,
        "kind": cell.kind,
    }
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        fname = f"{arch_id}__{cell_name}__{result['mesh']}.json"
        with open(os.path.join(out_dir, fname), "w") as f:
            json.dump(result, f, indent=1)
    if verbose:
        mm = result["memory"]
        print(
            f"[OK] {arch_id} × {cell_name} × {result['mesh']}: "
            f"args={_gb(mm['argument_bytes'])} temp={_gb(mm['temp_bytes'])} "
            f"flops/dev={result['cost']['flops_per_device']:.3e} "
            f"colls={result['collectives']['count']} "
            f"({_gb(result['collectives']['moved_bytes_total'])}) "
            f"compile={result['compile_s']:.0f}s"
        )
    return result


def _by_op(colls):
    agg = {}
    for c in colls:
        a = agg.setdefault(c["op"], {"count": 0, "moved_bytes": 0.0})
        a["count"] += 1
        a["moved_bytes"] += c["moved_bytes"]
    return agg


def _gb(x):
    return "n/a" if x is None else f"{x/2**30:.2f}GiB"


def lower_solver(multi_pod: bool, out_dir: str = None, verbose=True):
    """Dry-run the paper's production engine chunk on the big mesh.

    Solver sizing: web-scale synthetic instance, N = 16.7M nodes packed in
    4096-slot buckets, 6 real + 2 headroom buckets per device.
    """
    from repro.core.distributed import EngineConfig
    from repro.launch.mesh import make_production_mesh

    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = make_production_mesh(multi_pod=multi_pod)
    k = int(np.prod(mesh.devices.shape))
    axis = mesh.axis_names  # treat the whole mesh as one pid axis
    # flatten mesh to a single 'pid' axis view for the solver
    flat_mesh = jax.sharding.Mesh(
        mesh.devices.reshape(-1), ("pid",), **mesh_axis_types_kw(1)
    )
    cfg = EngineConfig(
        k=k, target_error=1e-8, eps=0.15,
        buckets_per_dev=8, headroom=2, chunk_rounds=1,
    )
    bucket_size = 4096
    edge_cap = bucket_size * 16  # L/N ~ 12.9 with skew headroom
    r = k * cfg.buckets_per_dev
    from repro.core.distributed import DistributedEngine

    sds = lambda s, d: jax.ShapeDtypeStruct(s, d)
    # build the engine chunk directly with abstract args (no host arrays)
    eng = DistributedEngine.__new__(DistributedEngine)
    eng.a = type("A", (), {"bucket_size": bucket_size, "n_rows": r,
                           "edge_cap": edge_cap})()
    eng.cfg = cfg
    eng.axis = "pid"
    eng.mesh = flat_mesh
    run_chunk = DistributedEngine._build_chunk(eng)

    from repro.core.distributed import EngineState

    dt = jnp.float32
    row = lambda *s: sds(tuple(s), dt)
    rowi = lambda *s: sds(tuple(s), jnp.int32)
    state = EngineState(
        f=row(r, bucket_size),
        h=row(r, bucket_size),
        outbox=row(k, r * bucket_size),
        t=row(k),
        pos_of_bucket=rowi(r),
        ops=sds((k,), jnp.int32),
        rounds=sds((), jnp.int32),
    )
    sh = lambda spec: NamedSharding(flat_mesh, spec)
    state_sh = EngineState(
        f=sh(P("pid")), h=sh(P("pid")), outbox=sh(P("pid")),
        t=sh(P("pid")), pos_of_bucket=sh(P()), ops=sh(P("pid")),
        rounds=sh(P()),
    )
    args = (state, row(r, bucket_size), rowi(r, edge_cap),
            rowi(r, edge_cap), rowi(r, edge_cap), row(r, edge_cap))
    shards = (state_sh, sh(P("pid")), sh(P("pid")), sh(P("pid")),
              sh(P("pid")), sh(P("pid")))
    t0 = time.time()
    with flat_mesh:
        lowered = jax.jit(
            lambda s, w, ss, db, dsl, wg: run_chunk(s, w, ss, db, dsl, wg),
            in_shardings=shards,
        ).lower(*args)
        compiled = lowered.compile()
    mem = compiled.memory_analysis()
    ca = cost_analysis_dict(compiled)
    colls = parse_collectives(compiled.as_text())
    for c in colls:
        c["link"] = classify_link(c)
    result = {
        "arch": "diteration-solver",
        "cell": f"N{r*bucket_size}_L{r*edge_cap}",
        "mesh": "pod2x16x16" if multi_pod else "pod16x16",
        "n_devices": k,
        "compile_s": round(time.time() - t0, 2),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
        },
        "cost": {"flops_per_device": ca.get("flops"),
                 "bytes_per_device": ca.get("bytes accessed")},
        "collectives": {
            "count": len(colls),
            "moved_bytes_total": sum(c["moved_bytes"] for c in colls),
            "by_op": _by_op(colls),
            "records": colls[:100],
        },
        "kind": "solve",
        "meta": {"n": r * bucket_size, "edges": r * edge_cap},
    }
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        with open(os.path.join(
                out_dir, f"solver__chunk__{result['mesh']}.json"), "w") as f:
            json.dump(result, f, indent=1)
    if verbose:
        print(f"[OK] solver × {result['mesh']}: "
              f"flops/dev={result['cost']['flops_per_device']:.3e} "
              f"colls={result['collectives']['count']}")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--cell")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--solver", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    out = args.out or os.path.abspath(RESULTS_DIR)

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    if args.solver:
        for mp in meshes:
            lower_solver(mp, out_dir=out)
        return

    if args.all:
        import subprocess

        from repro.configs import ARCH_IDS, get_arch

        failures = []
        for aid in ARCH_IDS:
            spec = get_arch(aid)
            for cname in spec.cells:
                for mp in meshes:
                    mesh_name = "multi" if mp else "single"
                    fname = os.path.join(
                        out,
                        f"{aid}__{cname}__"
                        f"{'pod2x16x16' if mp else 'pod16x16'}.json")
                    if os.path.exists(fname):
                        print(f"[skip] {aid} × {cname} × {mesh_name} (done)")
                        continue
                    cmd = [sys.executable, "-m", "repro.launch.dryrun",
                           "--arch", aid, "--cell", cname,
                           "--mesh", mesh_name, "--out", out]
                    r = subprocess.run(cmd, capture_output=True, text=True,
                                       timeout=3600)
                    sys.stdout.write(r.stdout)
                    if r.returncode != 0:
                        failures.append((aid, cname, mesh_name))
                        print(f"[FAIL] {aid} × {cname} × {mesh_name}:\n"
                              + r.stderr[-2000:])
        # the solver entries
        for mp in meshes:
            try:
                lower_solver(mp, out_dir=out)
            except Exception:
                traceback.print_exc()
                failures.append(("solver", "chunk", str(mp)))
        print(f"\n{'=' * 60}\nfailures: {len(failures)}")
        for f_ in failures:
            print("  FAIL:", f_)
        sys.exit(1 if failures else 0)

    assert args.arch and args.cell, "--arch and --cell (or --all)"
    for mp in meshes:
        lower_cell(args.arch, args.cell, mp, out_dir=out)


if __name__ == "__main__":
    main()
