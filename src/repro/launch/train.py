"""Training driver: ``--arch <id>`` selects any assigned architecture.

On this CPU container it runs the REDUCED (smoke) configuration through the
full fault-tolerant runtime (data pipeline -> AdamW -> checkpoints ->
auto-resume); on real hardware the same step functions lower with the
production mesh shardings (see launch/dryrun.py for the lowering proof).

  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b --steps 50
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np


def main():
    from repro.configs import ARCH_IDS, get_arch
    from repro.configs.smoke import smoke_setup
    from repro.models import gnn as gnn_model
    from repro.models import recsys as fm_model
    from repro.models import transformer as lm
    from repro.optim import AdamWConfig, adamw_init, adamw_update
    from repro.runtime import TrainLoop, TrainLoopConfig

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args()

    cfg, batch0, family = smoke_setup(args.arch)
    ocfg = AdamWConfig(lr=args.lr, warmup_steps=10, total_steps=args.steps)
    loss_of = {
        "lm": lambda p, b: lm.train_loss(p, b, cfg),
        "gnn": lambda p, b: gnn_model.loss_fn(p, b, cfg),
        "recsys": lambda p, b: fm_model.loss_fn(p, b, cfg),
    }[family]
    init_of = {
        "lm": lm.init_params,
        "gnn": gnn_model.init_params,
        "recsys": fm_model.init_params,
    }[family]

    def init_state():
        p = init_of(cfg, jax.random.PRNGKey(0))
        n = sum(x.size for x in jax.tree.leaves(p))
        print(f"[{args.arch}] reduced config: {n/1e6:.2f}M params "
              f"(family={family})")
        return p, adamw_init(p)

    @jax.jit
    def step_fn(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: loss_of(p, batch))(params)
        params, opt_state, m = adamw_update(grads, opt_state, ocfg,
                                            param_dtype=cfg.dtype)
        return params, opt_state, {"loss": loss, **m}

    def make_batch(step):
        if family == "lm":
            from repro.data import lm_token_batch

            b = lm_token_batch(step, 2, 32, cfg.vocab)
            return {k: jnp.asarray(v) for k, v in b.items()}
        if family == "recsys":
            from repro.data import criteo_like_batch

            b = criteo_like_batch(step, 32, cfg.n_fields,
                                  cfg.vocab_per_field)
            return {k: jnp.asarray(v) for k, v in b.items()}
        return batch0  # GNN: fixed full-batch graph

    ckpt = args.ckpt or f"/tmp/repro_{args.arch.replace('.', '_')}_ckpt"
    loop = TrainLoop(step_fn, make_batch, init_state,
                     TrainLoopConfig(total_steps=args.steps, ckpt_dir=ckpt,
                                     ckpt_every=25, log_every=10))
    out = loop.run(verbose=True)
    losses = [m["loss"] for m in out["metrics"]]
    print(f"loss {losses[0]:.4f} -> {losses[-1]:.4f} over "
          f"{len(losses)} steps")


if __name__ == "__main__":
    main()
