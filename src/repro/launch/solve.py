"""Distributed D-iteration solve driver.

Runs the production shard_map engine over all visible JAX devices on a
synthetic PageRank instance (or the faithful simulator with --simulate for
paper-protocol runs).

  PYTHONPATH=src python -m repro.launch.solve --n 20000 --dynamic
  PYTHONPATH=src python -m repro.launch.solve --simulate --k 16
  PYTHONPATH=src python -m repro.launch.solve --policy hysteresis
"""
import argparse

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20_000)
    ap.add_argument("--graph", choices=["powerlaw", "web"], default="web")
    ap.add_argument("--target-error", type=float, default=None)
    ap.add_argument("--dynamic", action="store_true")
    ap.add_argument("--policy", default=None,
                    choices=["slope_ema", "cost_refresh", "hysteresis"],
                    help="rebalancing policy (implies dynamic)")
    ap.add_argument("--simulate", action="store_true",
                    help="faithful K-PID simulator instead of the engine")
    ap.add_argument("--k", type=int, default=None,
                    help="PID count (simulator) — engine uses all devices")
    ap.add_argument("--buckets-per-dev", type=int, default=8)
    args = ap.parse_args()

    from repro.core import (
        DistributedSimulator,
        SimulatorConfig,
        pagerank_system,
        power_law_graph,
        webgraph_like,
    )

    g = (power_law_graph(args.n, seed=0) if args.graph == "powerlaw"
         else webgraph_like(args.n, seed=1))
    p, b = pagerank_system(g)
    te = args.target_error or 1.0 / args.n
    print(f"N={g.n} L={g.n_edges} target_error={te:.2e}")

    if args.simulate:
        k = args.k or 8
        cfg = SimulatorConfig(k=k, target_error=te, eps=0.15,
                              dynamic=args.dynamic, policy=args.policy,
                              mode="batch", record_every=100)
        res = DistributedSimulator(p, b, cfg).run()
        print(f"simulator K={k}: converged={res.converged} "
              f"cost={res.cost_iterations:.2f} moves={res.n_moves}")
        return

    import jax

    from repro.core.distributed import (
        DistributedEngine,
        EngineConfig,
        build_engine_arrays,
    )

    k = len(jax.devices())
    cfg = EngineConfig(k=k, target_error=te, eps=0.15,
                       buckets_per_dev=args.buckets_per_dev, headroom=2,
                       dynamic=args.dynamic and k > 1,
                       policy=args.policy if k > 1 else None)
    eng = DistributedEngine(build_engine_arrays(p, b, cfg), cfg)
    x, info = eng.solve(verbose=True)
    print(f"engine K={k}: converged={info['converged']} "
          f"rounds={info['rounds']} moves={info['moves']} "
          f"residual={info['residual']:.2e}")
    print("top-5:", np.argsort(-x)[:5].tolist())


if __name__ == "__main__":
    main()
