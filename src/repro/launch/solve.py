"""Distributed D-iteration solve driver — the CLI over ``repro.solve``.

Every run goes through the :mod:`repro.api` front door: a
:class:`Problem` + :class:`SolverOptions` + a registry ``--method``
key (or ``auto``).  Flag combinations are validated — ``--k`` is
honored (or rejected) on every path and ``--policy`` implies
``--dynamic`` everywhere, instead of the historical behavior where the
engine path silently ignored both.

  PYTHONPATH=src python -m repro.launch.solve --n 20000 --dynamic --k 8
  PYTHONPATH=src python -m repro.launch.solve --method simulator --k 16
  PYTHONPATH=src python -m repro.launch.solve --method engine:bsr
  PYTHONPATH=src python -m repro.launch.solve --policy hysteresis --k 8
  PYTHONPATH=src python -m repro.launch.solve --graph-file web.txt

``--graph-file`` loads a SNAP-style edge-list text file (``src dst``
per line, ``#`` comments) through :meth:`repro.GraphStore.
from_edge_file` — real-graph workloads beyond the synthetic
generators.
"""
import argparse

import numpy as np


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        description="Solve a synthetic PageRank instance through the "
        "repro.api backend registry."
    )
    ap.add_argument("--n", type=int, default=20_000)
    ap.add_argument("--graph", choices=["powerlaw", "web"], default="web")
    ap.add_argument("--graph-file", default=None,
                    help="SNAP-style edge-list file ('src dst' lines, "
                    "'#' comments) loaded via GraphStore.from_edge_file; "
                    "overrides --n/--graph")
    ap.add_argument("--weighted", action="store_true",
                    help="--graph-file has a third weight column")
    ap.add_argument("--target-error", type=float, default=None,
                    help="stopping target (default 1/N, paper §3.1)")
    ap.add_argument("--method", default="auto",
                    help="registry key (see repro.list_backends()) or "
                    "'auto'")
    ap.add_argument("--simulate", action="store_true",
                    help="alias for --method simulator")
    ap.add_argument("--k", type=int, default=None,
                    help="PID/device count; validated against the chosen "
                    "backend (raises instead of being silently ignored)")
    ap.add_argument("--dynamic", action="store_true",
                    help="enable the §2.5.2 dynamic partition controller")
    ap.add_argument("--policy", default=None,
                    choices=["slope_ema", "cost_refresh", "hysteresis"],
                    help="rebalancing policy (implies --dynamic)")
    ap.add_argument("--signal", default="residual",
                    choices=["residual", "edge-ops"])
    ap.add_argument("--partition", default="uniform",
                    choices=["uniform", "cb"])
    ap.add_argument("--buckets-per-dev", type=int, default=8)
    ap.add_argument("--verbose", action="store_true")
    return ap


def main(argv=None):
    args = build_parser().parse_args(argv)
    if args.simulate:
        if args.method not in ("auto", "simulator"):
            raise SystemExit(
                f"--simulate conflicts with --method {args.method!r}"
            )
        args.method = "simulator"

    import repro
    from repro.core import power_law_graph, webgraph_like

    if args.graph_file is not None:
        store = repro.GraphStore.from_edge_file(args.graph_file,
                                                weighted=args.weighted)
        g = store.csr()
        print(f"loaded {args.graph_file}: N={g.n} L={g.n_edges}")
    else:
        g = (power_law_graph(args.n, seed=0) if args.graph == "powerlaw"
             else webgraph_like(args.n, seed=1))
    problem = repro.Problem.pagerank(g, target_error=args.target_error)
    print(f"N={g.n} L={g.n_edges} target_error={problem.target_error:.2e}")

    k = args.k
    if k is None and args.method.startswith("engine:"):
        # the engine's historical CLI default: one PID per visible device
        import jax

        k = len(jax.devices())
    options = repro.SolverOptions(
        k=k,
        dynamic=args.dynamic,
        policy=args.policy,
        signal=args.signal,
        partition=args.partition,
        buckets_per_dev=args.buckets_per_dev,
        mode="batch",
        record_every=100,
        verbose=args.verbose,
    )
    # validate the flag set up front so a rejected combination exits
    # cleanly, while genuine solver failures keep their tracebacks
    try:
        if args.method == "auto":
            options.validated()
        else:
            options.validated(repro.get_backend(args.method).caps,
                              args.method)
    except (KeyError, ValueError) as e:
        raise SystemExit(f"inconsistent flags: {e}")
    report = repro.solve(problem, method=args.method, options=options)
    print(report.summary())
    if report.move_log:
        print(f"moves: {report.move_log[:8]}"
              f"{' ...' if len(report.move_log) > 8 else ''}")
    print("top-5:", np.argsort(-report.x)[:5].tolist())
    return report


if __name__ == "__main__":
    main()
