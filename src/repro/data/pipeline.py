"""Deterministic synthetic data pipelines for every model family.

Everything is seeded-by-step so a restarted job regenerates the exact batch
stream (checkpoint/restart reproducibility without storing data offsets).

* LM: zipf-distributed token streams (power-law unigram like web text).
* GNN: padded static-shape graph batches from repro.core.graph generators,
  a real fanout neighbor sampler for the minibatch_lg shape, and the
  DimeNet triplet builder (capped triplets per edge).
* RecSys: criteo-like power-law categorical ids + click labels.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.graph import CSRGraph

__all__ = [
    "lm_token_batch",
    "make_gnn_batch",
    "molecule_batch",
    "build_triplets",
    "criteo_like_batch",
    "NeighborSampler",
]


# --------------------------------------------------------------------------- #
# LM
# --------------------------------------------------------------------------- #
def lm_token_batch(step: int, batch: int, seq: int, vocab: int,
                   seed: int = 0) -> Dict[str, np.ndarray]:
    """Zipf tokens; labels = next token (teacher forcing)."""
    rng = np.random.default_rng(seed * 1_000_003 + step)
    toks = rng.zipf(1.3, size=(batch, seq + 1)) % vocab
    toks = toks.astype(np.int32)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


# --------------------------------------------------------------------------- #
# GNN
# --------------------------------------------------------------------------- #
def build_triplets(src: np.ndarray, dst: np.ndarray, n_nodes: int,
                   max_per_edge: int = 8, seed: int = 0
                   ) -> Tuple[np.ndarray, np.ndarray]:
    """DimeNet triplets: for edge e=(j->i), incoming edges f=(k->j), k != i.

    Capped at ``max_per_edge`` incoming edges per target edge (cutoff
    neighborhoods; DESIGN.md §5 records the cap).  Returns (trip_e, trip_f).
    """
    rng = np.random.default_rng(seed)
    e_count = src.shape[0]
    # incoming edge ids per node (f = (k -> j) indexed by dst == j)
    order = np.argsort(dst, kind="stable")
    sorted_dst = dst[order]
    starts = np.searchsorted(sorted_dst, np.arange(n_nodes))
    ends = np.searchsorted(sorted_dst, np.arange(n_nodes) + 1)
    trip_e, trip_f = [], []
    for e in range(e_count):
        j = src[e]
        lo, hi = starts[j], ends[j]
        if hi <= lo:
            continue
        incoming = order[lo:hi]
        incoming = incoming[src[incoming] != dst[e]]  # k != i
        if incoming.size > max_per_edge:
            incoming = rng.choice(incoming, max_per_edge, replace=False)
        trip_e.extend([e] * incoming.size)
        trip_f.extend(incoming.tolist())
    if not trip_e:
        trip_e, trip_f = [0], [0]
    return (np.asarray(trip_e, np.int32), np.asarray(trip_f, np.int32))


def make_gnn_batch(
    g: CSRGraph,
    d_feat: int,
    n_classes: int = 0,
    with_pos: bool = False,
    with_triplets: bool = False,
    max_trip_per_edge: int = 8,
    d_out: int = 1,
    seed: int = 0,
) -> Dict[str, np.ndarray]:
    """Full-graph batch with features/labels (static shapes, no padding
    needed — the graph itself is the batch)."""
    rng = np.random.default_rng(seed)
    src, dst, _ = g.edge_list()
    batch = {
        "x": rng.standard_normal((g.n, d_feat)).astype(np.float32),
        "src": src.astype(np.int32),
        "dst": dst.astype(np.int32),
        "node_mask": np.ones(g.n, np.float32),
        "edge_mask": np.ones(src.shape[0], np.float32),
    }
    if n_classes:
        batch["labels"] = rng.integers(0, n_classes, g.n).astype(np.int32)
    else:
        batch["labels"] = rng.standard_normal((g.n, d_out)).astype(np.float32)
    if with_pos:
        batch["pos"] = rng.standard_normal((g.n, 3)).astype(np.float32)
        batch["z"] = rng.integers(0, 10, g.n).astype(np.int32)
    if with_triplets:
        te, tf = build_triplets(src, dst, g.n, max_trip_per_edge, seed)
        batch["trip_e"], batch["trip_f"] = te, tf
        batch["trip_mask"] = np.ones(te.shape[0], np.float32)
    return batch


def molecule_batch(
    n_graphs: int,
    nodes_per_graph: int = 30,
    edges_per_graph: int = 64,
    d_feat: int = 16,
    with_triplets: bool = False,
    graph_labels: bool = True,
    seed: int = 0,
) -> Dict[str, np.ndarray]:
    """Batched small graphs (molecule shape): block-diagonal edge list."""
    rng = np.random.default_rng(seed)
    n = n_graphs * nodes_per_graph
    e = n_graphs * edges_per_graph
    src = np.concatenate([
        rng.integers(0, nodes_per_graph, edges_per_graph) + i * nodes_per_graph
        for i in range(n_graphs)
    ]).astype(np.int32)
    dst = np.concatenate([
        rng.integers(0, nodes_per_graph, edges_per_graph) + i * nodes_per_graph
        for i in range(n_graphs)
    ]).astype(np.int32)
    batch = {
        "x": rng.standard_normal((n, d_feat)).astype(np.float32),
        "pos": (rng.standard_normal((n, 3)) * 2.0).astype(np.float32),
        "z": rng.integers(0, 10, n).astype(np.int32),
        "src": src,
        "dst": dst,
        "node_mask": np.ones(n, np.float32),
        "edge_mask": np.ones(e, np.float32),
        "graph_ids": np.repeat(np.arange(n_graphs, dtype=np.int32),
                               nodes_per_graph),
        "labels": rng.standard_normal(n_graphs).astype(np.float32)
        if graph_labels else rng.standard_normal((n, 1)).astype(np.float32),
    }
    if with_triplets:
        te, tf = build_triplets(src, dst, n, 8, seed)
        batch["trip_e"], batch["trip_f"] = te, tf
        batch["trip_mask"] = np.ones(te.shape[0], np.float32)
    return batch


@dataclasses.dataclass
class NeighborSampler:
    """GraphSAGE-style fanout sampler over a CSR graph (minibatch_lg shape).

    Produces padded, static-shape subgraph batches: seed nodes + per-hop
    sampled neighbors, with a relabelled edge list (messages flow sampled
    neighbor -> target).  Real systems sample on host CPU exactly like this.
    """

    g: CSRGraph
    fanouts: Tuple[int, ...] = (15, 10)
    seed: int = 0

    def sample(self, batch_nodes: int, step: int, d_feat: int,
               n_classes: int = 0) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(self.seed * 99991 + step)
        seeds = rng.integers(0, self.g.n, batch_nodes).astype(np.int64)
        layers = [seeds]
        edges_src, edges_dst = [], []
        frontier = seeds
        for fanout in self.fanouts:
            # sample `fanout` out-neighbors of each frontier node
            deg = (self.g.indptr[frontier + 1]
                   - self.g.indptr[frontier]).astype(np.int64)
            picks = rng.integers(0, np.maximum(deg, 1),
                                 (fanout, frontier.shape[0]))
            nbr = self.g.indices[
                np.minimum(self.g.indptr[frontier] + picks,
                           np.maximum(self.g.indptr[frontier + 1] - 1, 0))
            ].astype(np.int64)
            valid = (deg > 0)[None, :].repeat(fanout, 0)
            # edges: neighbor -> frontier node (messages toward seeds)
            edges_src.append(nbr.T.reshape(-1))
            edges_dst.append(np.repeat(frontier, fanout))
            mask = valid.T.reshape(-1)
            edges_src[-1] = edges_src[-1][mask]
            edges_dst[-1] = edges_dst[-1][mask]
            frontier = np.unique(nbr[valid.T.T].reshape(-1)) \
                if valid.any() else frontier
            layers.append(frontier)
        all_src = np.concatenate(edges_src)
        all_dst = np.concatenate(edges_dst)
        nodes = np.unique(np.concatenate([all_src, all_dst, seeds]))
        relabel = {int(v): i for i, v in enumerate(nodes)}
        src_l = np.array([relabel[int(v)] for v in all_src], np.int32)
        dst_l = np.array([relabel[int(v)] for v in all_dst], np.int32)
        # pad to static shapes
        n_pad = self._node_budget(batch_nodes)
        e_pad = self._edge_budget(batch_nodes)
        n_real, e_real = nodes.shape[0], src_l.shape[0]
        n_keep = min(n_real, n_pad)
        e_keep_mask = (src_l < n_keep) & (dst_l < n_keep)
        src_l, dst_l = src_l[e_keep_mask], dst_l[e_keep_mask]
        e_keep = min(src_l.shape[0], e_pad)
        rng2 = np.random.default_rng(step)
        x = rng2.standard_normal((n_pad, d_feat)).astype(np.float32)
        batch = {
            "x": x,
            "src": np.zeros(e_pad, np.int32),
            "dst": np.zeros(e_pad, np.int32),
            "node_mask": np.zeros(n_pad, np.float32),
            "edge_mask": np.zeros(e_pad, np.float32),
        }
        batch["src"][:e_keep] = src_l[:e_keep]
        batch["dst"][:e_keep] = dst_l[:e_keep]
        batch["node_mask"][:n_keep] = 1.0
        batch["edge_mask"][:e_keep] = 1.0
        if n_classes:
            batch["labels"] = rng2.integers(
                0, n_classes, n_pad).astype(np.int32)
        else:
            batch["labels"] = rng2.standard_normal((n_pad, 1)).astype(
                np.float32)
        return batch

    def _node_budget(self, batch_nodes: int) -> int:
        tot = batch_nodes
        f = batch_nodes
        for fanout in self.fanouts:
            f = f * fanout
            tot += f
        return tot

    def _edge_budget(self, batch_nodes: int) -> int:
        tot = 0
        f = batch_nodes
        for fanout in self.fanouts:
            tot += f * fanout
            f = f * fanout
        return tot


def build_halo_batch(
    g: CSRGraph,
    n_shards: int,
    d_feat: int,
    n_classes: int = 0,
    seed: int = 0,
    b_max: Optional[int] = None,
    e_cap: Optional[int] = None,
) -> Dict[str, np.ndarray]:
    """Locality-partitioned GNN batch (models/gnn._forward_gin_halo).

    Nodes are contiguously sharded (the paper's uniform Ω_k); each edge is
    assigned to its destination's shard; per shard, the non-local source
    nodes become *halo* slots addressed as
    ``N_loc + owner(src)·B_max + publish_pos(src)``.
    """
    rng = np.random.default_rng(seed)
    src, dst, _ = g.edge_list()
    n_pad = -(-g.n // n_shards) * n_shards
    n_loc = n_pad // n_shards
    own_src = src // n_loc
    own_dst = dst // n_loc

    # publish lists: for each shard, the local nodes remote shards reference
    remote = own_src != own_dst
    pub_nodes = np.unique(src[remote])  # global ids, sorted
    pub_owner = pub_nodes // n_loc
    # position of each published node within its owner's publish list
    pub_pos = np.zeros(pub_nodes.shape[0], dtype=np.int64)
    counts = np.bincount(pub_owner, minlength=n_shards)
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    pub_pos = np.arange(pub_nodes.shape[0]) - starts[pub_owner]
    bmax = int(counts.max()) if b_max is None else b_max
    assert counts.max() <= bmax, (counts.max(), bmax)
    boundary = np.zeros((n_shards, bmax), dtype=np.int32)
    for s in range(n_shards):
        ids = pub_nodes[pub_owner == s] % n_loc
        boundary[s, : ids.shape[0]] = ids
    # halo slot of a published global node id
    halo_slot = {int(v): int(n_loc + o * bmax + p)
                 for v, o, p in zip(pub_nodes, pub_owner, pub_pos)}

    # per-shard edge buffers (edges live with their destination's shard)
    order = np.argsort(own_dst, kind="stable")
    src_o, dst_o = src[order], dst[order]
    own_o = own_dst[order]
    per_shard = np.bincount(own_o, minlength=n_shards)
    ecap = int(per_shard.max()) if e_cap is None else e_cap
    assert per_shard.max() <= ecap, (per_shard.max(), ecap)
    src_slot = np.zeros((n_shards, ecap), dtype=np.int32)
    dst_local = np.zeros((n_shards, ecap), dtype=np.int32)
    edge_mask = np.zeros((n_shards, ecap), dtype=np.float32)
    estarts = np.concatenate([[0], np.cumsum(per_shard)[:-1]])
    for s in range(n_shards):
        lo, hi = estarts[s], estarts[s] + per_shard[s]
        es, ed = src_o[lo:hi], dst_o[lo:hi]
        local = (es // n_loc) == s
        slots = np.where(
            local, es % n_loc,
            np.array([halo_slot.get(int(v), n_loc) for v in es]),
        )
        src_slot[s, : hi - lo] = slots
        dst_local[s, : hi - lo] = ed % n_loc
        edge_mask[s, : hi - lo] = 1.0
    batch = {
        "x": rng.standard_normal((n_pad, d_feat)).astype(np.float32),
        "src_slot": src_slot.reshape(-1),
        "dst_local": dst_local.reshape(-1),
        "edge_mask": edge_mask.reshape(-1),
        "boundary": boundary,
        "node_mask": (np.arange(n_pad) < g.n).astype(np.float32),
    }
    if n_classes:
        batch["labels"] = rng.integers(0, n_classes, n_pad).astype(np.int32)
    else:
        batch["labels"] = rng.standard_normal((n_pad, 1)).astype(np.float32)
    return batch


# --------------------------------------------------------------------------- #
# RecSys
# --------------------------------------------------------------------------- #
def criteo_like_batch(step: int, batch: int, n_fields: int,
                      vocab_per_field: int, seed: int = 0
                      ) -> Dict[str, np.ndarray]:
    """Power-law categorical ids (hot head, long tail) + click labels."""
    rng = np.random.default_rng(seed * 7_777_777 + step)
    ids = (rng.zipf(1.2, size=(batch, n_fields)) - 1) % vocab_per_field
    ctr_logit = (ids[:, 0] % 17 - 8) / 4.0
    labels = (rng.random(batch) < 1 / (1 + np.exp(-ctr_logit))).astype(
        np.int32
    )
    return {"ids": ids.astype(np.int32), "labels": labels}
