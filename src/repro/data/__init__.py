from .pipeline import (  # noqa: F401
    build_halo_batch,
    criteo_like_batch,
    lm_token_batch,
    make_gnn_batch,
    molecule_batch,
    build_triplets,
    NeighborSampler,
)
