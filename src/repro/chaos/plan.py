"""Deterministic chaos plans: the event taxonomy of DESIGN.md §8.

A :class:`ChaosPlan` is a *seeded, replayable* schedule of disruptions
against a running solve — the elasticity/fault-tolerance counterpart of
the paper's dynamic partition story (the PID set itself changes while
the solve is in flight, the regime of the asynchronous companion
arXiv:1202.6168).  Five event kinds:

====================  =====================================================
``straggler(pid, slowdown)``  the PID computes ``slowdown``× slower from
                              this round on (simulator: budget cut;
                              engine: the control plane's load signal is
                              scaled — the controller sees what a real
                              straggler would make it see)
``kill(pid, round)``          the PID is lost: the simulator reassigns
                              its Ω to survivors; a session raises
                              :class:`~repro.chaos.inject.ChaosKill`
                              (recovery = restore + rescale, the
                              production flow)
``rescale(k_new, round)``     grow/shrink the PID set mid-solve
                              (``DistributedEngine.rescale`` /
                              ``DistributedSimulator.rescale``)
``churn_burst(frac, round)``  a burst of link rotations (``frac``·L
                              edges) through ``SolverSession.
                              update_graph`` (sessions only)
``checkpoint_crash(round)``   a checkpoint write that tears mid-flight:
                              the newest step is written then corrupted,
                              so restore MUST reject it and fall back
====================  =====================================================

Events are pinned to a *round* — the consumer's native grain (simulator
time step / session run grain) — so a plan replays bit-identically from
a failure log: ``ChaosPlan.random(seed=...)`` is pure in its arguments
and every derived randomness (churn seeds) is folded from the plan seed.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, List, Optional, Tuple

import numpy as np

__all__ = ["ChaosEvent", "ChaosPlan", "EVENT_KINDS"]

EVENT_KINDS = ("straggler", "kill", "rescale", "churn_burst",
               "checkpoint_crash")

# which kinds each consumer can honor (validated up front, not mid-run)
SIM_KINDS = ("straggler", "kill", "rescale")
SESSION_KINDS = EVENT_KINDS


@dataclasses.dataclass(frozen=True)
class ChaosEvent:
    """One disruption, pinned to a round of the consumer's native grain."""

    kind: str
    round: int
    pid: Optional[int] = None  # straggler / kill target
    slowdown: Optional[float] = None  # straggler factor (> 1)
    k_new: Optional[int] = None  # rescale width
    frac: Optional[float] = None  # churn_burst: fraction of L rotated
    seed: int = 0  # derived randomness (churn edge picks)

    def __post_init__(self):
        if self.kind not in EVENT_KINDS:
            raise ValueError(
                f"unknown chaos event kind {self.kind!r}; expected one of "
                f"{EVENT_KINDS}"
            )
        if self.round < 0:
            raise ValueError(f"round must be >= 0, got {self.round}")
        if self.kind == "straggler":
            if self.pid is None or self.slowdown is None:
                raise ValueError("straggler needs pid and slowdown")
            if self.slowdown <= 1.0:
                raise ValueError(
                    f"slowdown must be > 1 (got {self.slowdown}); use no "
                    "event for a healthy PID"
                )
        elif self.kind == "kill":
            if self.pid is None:
                raise ValueError("kill needs pid")
        elif self.kind == "rescale":
            if self.k_new is None or self.k_new < 1:
                raise ValueError(f"rescale needs k_new >= 1, got "
                                 f"{self.k_new}")
        elif self.kind == "churn_burst":
            if self.frac is None or not (0.0 < self.frac <= 0.5):
                raise ValueError(
                    f"churn_burst needs frac in (0, 0.5], got {self.frac}"
                )


class ChaosPlan:
    """An ordered, seeded batch of :class:`ChaosEvent`\\ s.

    Construct explicitly (builder methods chain) or via :meth:`random`.
    ``at(round)`` yields the events pinned to that round; ``validate``
    checks the plan against a consumer (k width, supported kinds)
    *before* the solve starts, so an impossible plan fails loudly
    instead of mid-flight.
    """

    def __init__(self, events: Optional[List[ChaosEvent]] = None,
                 seed: int = 0):
        self.seed = int(seed)
        self.events: List[ChaosEvent] = sorted(
            events or [], key=lambda e: (e.round, EVENT_KINDS.index(e.kind))
        )

    # ---- builders ---------------------------------------------------------
    def _add(self, ev: ChaosEvent) -> "ChaosPlan":
        self.events.append(ev)
        self.events.sort(key=lambda e: (e.round, EVENT_KINDS.index(e.kind)))
        return self

    def straggler(self, pid: int, slowdown: float,
                  round: int = 0) -> "ChaosPlan":
        return self._add(ChaosEvent("straggler", round, pid=pid,
                                    slowdown=float(slowdown)))

    def kill(self, pid: int, round: int) -> "ChaosPlan":
        return self._add(ChaosEvent("kill", round, pid=pid))

    def rescale(self, k_new: int, round: int) -> "ChaosPlan":
        return self._add(ChaosEvent("rescale", round, k_new=int(k_new)))

    def churn_burst(self, frac: float, round: int,
                    seed: Optional[int] = None) -> "ChaosPlan":
        s = self.seed + 7919 * round if seed is None else seed
        return self._add(ChaosEvent("churn_burst", round, frac=float(frac),
                                    seed=int(s)))

    def checkpoint_crash(self, round: int) -> "ChaosPlan":
        return self._add(ChaosEvent("checkpoint_crash", round))

    # ---- generation -------------------------------------------------------
    @staticmethod
    def random(seed: int, k: int, rounds: int, n_events: int = 3,
               kinds: Tuple[str, ...] = SIM_KINDS) -> "ChaosPlan":
        """A deterministic plan: same arguments ⇒ same events, always.

        Rescale targets stay in [max(1, k//2), k] so a random plan never
        asks for more PIDs than the consumer started with; kill targets
        avoid PID 0 so at least one worker always survives.
        """
        rng = np.random.default_rng(seed)
        plan = ChaosPlan(seed=seed)
        for i in range(n_events):
            kind = kinds[int(rng.integers(0, len(kinds)))]
            if kind == "kill" and k < 2:
                kind = "straggler"  # a 1-PID world has nobody to die
            rnd = int(rng.integers(1, max(rounds, 2)))
            if kind == "straggler":
                plan.straggler(int(rng.integers(0, k)),
                               float(2 ** rng.integers(1, 4)), round=rnd)
            elif kind == "kill":
                plan.kill(int(rng.integers(1, max(k, 2))), round=rnd)
            elif kind == "rescale":
                plan.rescale(int(rng.integers(max(1, k // 2), k + 1)),
                             round=rnd)
            elif kind == "churn_burst":
                plan.churn_burst(float(rng.uniform(0.002, 0.05)), round=rnd,
                                 seed=int(rng.integers(0, 2**31)))
            else:
                plan.checkpoint_crash(round=rnd)
        return plan

    # ---- consumption ------------------------------------------------------
    def at(self, round: int) -> List[ChaosEvent]:
        return [e for e in self.events if e.round == round]

    def fire_due(self, cursor: int,
                 now: int) -> Tuple[List[ChaosEvent], int]:
        """Events not yet consumed (``>= cursor``) whose round has
        arrived (``<= now``), plus the advanced cursor — THE shared
        firing rule of the simulator step loop and the session
        injector (events are kept sorted by round)."""
        due = []
        while (cursor < len(self.events)
               and self.events[cursor].round <= now):
            due.append(self.events[cursor])
            cursor += 1
        return due, cursor

    def validate(self, k: int, kinds: Tuple[str, ...] = SESSION_KINDS
                 ) -> "ChaosPlan":
        """Check every event against the consumer's width and abilities.

        ``k`` is tracked through rescale events so a straggler/kill
        scheduled after a shrink is validated against the *post-shrink*
        width.
        """
        width = k
        for ev in self.events:
            if ev.kind not in kinds:
                raise ValueError(
                    f"event {ev.kind!r} unsupported here (supported: "
                    f"{kinds})"
                )
            if ev.kind in ("straggler", "kill") and ev.pid >= width:
                raise ValueError(
                    f"{ev.kind} targets pid {ev.pid} but only {width} "
                    f"PIDs exist at round {ev.round}"
                )
            if ev.kind == "rescale":
                width = ev.k_new
        return self

    def __iter__(self) -> Iterator[ChaosEvent]:
        return iter(self.events)

    def __len__(self) -> int:
        return len(self.events)

    def __repr__(self) -> str:
        evs = ", ".join(
            f"{e.kind}@{e.round}" for e in self.events
        )
        return f"ChaosPlan(seed={self.seed}, [{evs}])"
