"""Injection + recovery: wiring a :class:`ChaosPlan` into live solves.

Two consumers (DESIGN.md §8):

* the **simulator** takes the plan directly —
  ``DistributedSimulator.run(chaos=plan)`` fires straggler/kill/rescale
  events in its step loop (virtual PIDs, so every event is behavioral:
  budgets shrink, Ω sets hand over, the width changes);
* a **session** takes a :class:`SessionInjector` —
  ``SolverSession.run(chaos=injector)`` calls :meth:`SessionInjector.
  before_grain` once per grain.  ``kill`` raises :class:`ChaosKill`
  (a machine loss is a crash, not a callback); :class:`ChaosRunner`
  implements the production recovery flow around it: periodic
  checkpoints, restore-newest-valid, optional rescale to the surviving
  width, and the recovery-cost accounting ``benchmarks/chaos_bench.py``
  reports.

Grain/round bookkeeping: the injector counts grains *globally* across
restore attempts (``global_grain``), so a plan keeps firing at the
right absolute position even after a kill truncated one ``run`` loop.
"""
from __future__ import annotations

import os
from typing import List, Optional, Tuple

import numpy as np

from .plan import ChaosEvent, ChaosPlan

__all__ = ["ChaosKill", "SessionInjector", "ChaosRunner",
           "tear_checkpoint"]


class ChaosKill(RuntimeError):
    """PID ``pid`` was lost at grain ``round`` — the in-flight solve
    dies with it; recovery is restore + rescale (DESIGN.md §8)."""

    def __init__(self, pid: int, round: int):
        super().__init__(f"chaos: pid {pid} killed at grain {round}")
        self.pid = pid
        self.round = round


def tear_checkpoint(path: str) -> None:
    """Simulate a write that tore *after* the atomic commit: the step
    directory exists with a complete manifest, but the H leaf's bytes
    are garbage.  Only the §2.2 invariant check can catch this — which
    is exactly what ``SolverSession.restore`` does."""
    leaf = os.path.join(path, "arr_00002.npy")  # h (b, f, h, t key order)
    arr = np.load(leaf)
    np.save(leaf, np.zeros_like(arr))


class SessionInjector:
    """Fires plan events into ``SolverSession.run`` grain boundaries."""

    def __init__(self, plan: ChaosPlan, ckpt_dir: Optional[str] = None):
        self.plan = plan
        self.ckpt_dir = ckpt_dir
        self.global_grain = 0
        self._cursor = 0
        self.log: List[Tuple[int, str]] = []
        # edge pushes charged before a churn_burst re-seeded the session
        # (update_graph resets the phase counters): consumers that sum
        # total work add this back (ChaosRunner does)
        self.absorbed_ops = 0

    def bind(self, session) -> None:
        """Validate the plan against the session's backend up front."""
        from repro.api.session import _EngineDriver

        if isinstance(session._driver, _EngineDriver):
            kinds = ("straggler", "kill", "rescale", "churn_burst",
                     "checkpoint_crash")
            k = session._driver.cfg.k
        else:
            # single-process frontier drivers have no pid axis
            kinds = ("kill", "churn_burst", "checkpoint_crash")
            k = 1
        # only the not-yet-fired tail: a bind after recovery (restore +
        # rescale) must not re-judge events that already fired against
        # the pre-disruption width
        remaining = self.plan.events[self._cursor:]
        if (self.ckpt_dir is None
                and any(e.kind == "checkpoint_crash" for e in remaining)):
            raise ValueError(
                "plan schedules checkpoint_crash but the injector has no "
                "ckpt_dir"
            )
        ChaosPlan(remaining, seed=self.plan.seed).validate(k, kinds=kinds)

    def before_grain(self, session) -> None:
        """Advance the GLOBAL grain counter (it spans restore attempts —
        a kill truncates one ``run`` loop, not the plan's timeline) and
        fire every due event via the shared ``ChaosPlan.fire_due``."""
        self.global_grain += 1
        due, self._cursor = self.plan.fire_due(self._cursor,
                                               self.global_grain)
        for ev in due:
            self._fire(session, ev)

    def _fire(self, session, ev: ChaosEvent) -> None:
        self.log.append((self.global_grain, ev.kind))
        if ev.kind == "straggler":
            session._driver.note_straggler(ev.pid, ev.slowdown)
        elif ev.kind == "kill":
            raise ChaosKill(ev.pid, self.global_grain)
        elif ev.kind == "rescale":
            session.rescale(ev.k_new)
        elif ev.kind == "churn_burst":
            from repro.graph import rotation_churn

            n_rot = max(1, int(ev.frac * session.problem.n_edges) // 2)
            delta = rotation_churn(session.problem.graph, n_rot,
                                   seed=ev.seed)
            # update_graph rebuilds the driver (phase counters reset to
            # zero): bank the pushes charged so far first
            self.absorbed_ops += session.n_ops
            session.update_graph(delta)
        elif ev.kind == "checkpoint_crash":
            path = session.checkpoint(self.ckpt_dir)
            tear_checkpoint(path)


class ChaosRunner:
    """One fault-tolerant solve under a plan, with the recovery loop.

    The production flow in miniature: checkpoint every
    ``checkpoint_every`` grains; on :class:`ChaosKill` restore the
    newest checkpoint that passes the invariant check and — when the
    backend has a pid axis and ``rescale_on_kill`` — shrink to the
    surviving width before resuming.  ``measure`` also runs an
    undisturbed twin and reports the recovery cost in §2.3 edge
    pushes (the chaos bench's row).
    """

    def __init__(self, problem, method: str, plan: ChaosPlan,
                 ckpt_dir: str, options=None, checkpoint_every: int = 1,
                 rescale_on_kill: bool = True, max_recoveries: int = 8):
        self.problem = problem
        self.method = method
        self.plan = plan
        self.ckpt_dir = ckpt_dir
        self.options = options
        self.checkpoint_every = checkpoint_every
        self.rescale_on_kill = rescale_on_kill
        self.max_recoveries = max_recoveries
        self.kills: List[ChaosKill] = []
        self.injector = SessionInjector(plan, ckpt_dir=ckpt_dir)

    def run(self, until: Optional[float] = None):
        """Returns ``(session, disturbed_ops, wasted_ops)``.

        ``disturbed_ops`` sums ``SolverSession.lifetime_ops`` per
        attempt — THE one §2.3 accounting rule: every edge push charged
        across all attempts, including work a kill destroyed and pushes
        a churn re-seed banked (``update_graph`` folds them into the
        session's lifetime totals, so nothing is counted twice and
        nothing leaks).  ``wasted_ops`` is the part that died
        un-checkpointed (attempt lifetime minus the restored
        checkpoint's recorded lifetime).
        """
        from repro.api.session import SolverSession

        session = SolverSession(self.problem, method=self.method,
                                options=self.options)
        # base checkpoint of the seeded state: a kill can fire before
        # the first periodic checkpoint, and recovery needs SOMETHING
        # valid to restore (cold restart = restoring the seed)
        session.checkpoint(self.ckpt_dir)
        total_ops = 0
        wasted_ops = 0
        while True:
            try:
                grains = 0
                for _rep in session.run(until=until, chaos=self.injector):
                    grains += 1
                    if grains % self.checkpoint_every == 0:
                        session.checkpoint(self.ckpt_dir)
                return (session, total_ops + session.lifetime_ops,
                        wasted_ops)
            except ChaosKill as kill:
                self.kills.append(kill)
                if len(self.kills) > self.max_recoveries:
                    raise
                lost = session.lifetime_ops
                total_ops += lost
                k_before = getattr(getattr(session._driver, "cfg", None),
                                   "k", 1)
                try:
                    session = SolverSession.restore(
                        self.ckpt_dir, session.problem,
                        method=self.method, options=self.options)
                    wasted_ops += max(
                        0, lost - (session.restored_from["lifetime_ops"]
                                   or 0))
                except (FileNotFoundError, ValueError):
                    # every step rejected (e.g. all checkpoints pre-date
                    # a churn_burst): production falls back to a COLD
                    # restart of the current problem, it does not die
                    session = SolverSession(session.problem,
                                            method=self.method,
                                            options=self.options)
                    session.checkpoint(self.ckpt_dir)  # fresh base
                    wasted_ops += lost
                if (self.rescale_on_kill and k_before > 1
                        and session.method.startswith("engine")):
                    session.rescale(k_before - 1)

    def measure(self, until: Optional[float] = None) -> dict:
        """Disturbed vs undisturbed twin: the recovery-cost row."""
        from repro.api.session import SolverSession

        ref_session = SolverSession(self.problem, method=self.method,
                                    options=self.options)
        ref = ref_session.solve(until=until)
        session, disturbed_ops, wasted = self.run(until=until)
        rep = session.solve(until=until)  # already converged: no-op read
        undisturbed = ref_session.lifetime_ops  # == ref.n_ops: one phase
        return {
            "undisturbed_ops": int(undisturbed),
            "disturbed_ops": int(disturbed_ops),
            "overhead_ops": int(disturbed_ops - undisturbed),
            "overhead_frac": float(
                (disturbed_ops - undisturbed) / max(undisturbed, 1)),
            "wasted_ops": int(wasted),
            "recovered_ops": int(disturbed_ops - wasted),
            "final_attempt_ops": int(session.lifetime_ops),
            "kills": len(self.kills),
            "x_err_l1": float(np.abs(rep.x - ref.x).sum()),
            "converged": bool(rep.converged and ref.converged),
            "chaos_log": list(self.injector.log),
        }
