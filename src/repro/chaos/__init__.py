"""Deterministic chaos harness: seeded disruption plans + injectors.

The elasticity/fault-tolerance proof layer (DESIGN.md §8): a
:class:`ChaosPlan` schedules straggler / kill / rescale / churn-burst /
checkpoint-crash events against a running solve, the injectors replay
it bit-identically, and the §2.2 invariant ``B = (I−P)H + F`` is the
recovery oracle throughout.

>>> from repro.chaos import ChaosPlan, ChaosRunner
>>> plan = ChaosPlan(seed=0).kill(pid=1, round=3).rescale(2, round=5)
>>> runner = ChaosRunner(problem, "engine:chunk", plan, ckpt_dir="/tmp/ck")
>>> runner.measure()  # recovery overhead vs an undisturbed twin
"""
from .inject import ChaosKill, ChaosRunner, SessionInjector, tear_checkpoint
from .plan import EVENT_KINDS, ChaosEvent, ChaosPlan

__all__ = [
    "EVENT_KINDS",
    "ChaosEvent",
    "ChaosKill",
    "ChaosPlan",
    "ChaosRunner",
    "SessionInjector",
    "tear_checkpoint",
]
