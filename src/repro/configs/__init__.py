"""Architecture registry: the 10 assigned (arch × shape) configs
+ the paper's own solver config.

``get_arch(arch_id)`` -> ArchSpec; ``ARCH_IDS`` lists all ids for
``--arch`` flags in the launchers.
"""
from __future__ import annotations

from typing import Dict

from .common import ArchSpec, ShapeCell  # noqa: F401

_MODULES = {
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "command-r-plus-104b": "command_r_plus_104b",
    "qwen1.5-0.5b": "qwen1_5_0_5b",
    "mistral-large-123b": "mistral_large_123b",
    "meshgraphnet": "meshgraphnet",
    "egnn": "egnn",
    "gin-tu": "gin_tu",
    "dimenet": "dimenet",
    "fm": "fm",
}

ARCH_IDS = list(_MODULES)


def get_arch(arch_id: str) -> ArchSpec:
    import importlib

    if arch_id not in _MODULES:
        raise KeyError(
            f"unknown arch {arch_id!r}; choose from {ARCH_IDS}"
        )
    mod = importlib.import_module(f".{_MODULES[arch_id]}", __package__)
    return mod.spec()


def all_cells():
    """Iterate (arch_id, cell_name) over the 40 assigned cells."""
    for aid in ARCH_IDS:
        spec = get_arch(aid)
        for cname in spec.cells:
            yield aid, cname
