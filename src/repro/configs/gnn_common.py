"""Shared GNN shape cells (the 4 assigned shapes × 4 GNN archs).

Shapes (assignment table):

  full_graph_sm   N=2,708  E=10,556  d_feat=1,433   (cora-scale full batch)
  minibatch_lg    reddit-scale sampled training: the *lowered input* is the
                  padded fanout-(15,10) subgraph from data.NeighborSampler —
                  1024 seeds -> 169,984 node / 168,960 edge budget.
                  The 232,965-node / 114.6M-edge parent graph lives host-side
                  in the sampler (that IS the system design: sampling is a
                  host pipeline stage).
  ogb_products    N=2,449,029  E=61,859,140  d_feat=100 (full-batch-large)
  molecule        128 graphs x (30 nodes, 64 edges), batched block-diagonal

DimeNet triplets are capped at 8 incoming edges per directed edge
(cutoff-neighborhood semantics; DESIGN.md §5) -> T = 8·E padded.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax.numpy as jnp

from .common import ArchSpec, ShapeCell, sds

F32, I32 = jnp.float32, jnp.int32

# (n_nodes, n_edges, d_feat, n_classes); n/e are PADDED to the mesh
# (nodes % 32 == 0, edges % 512 == 0 — masks carry validity), the real
# assignment sizes are kept in n_real/e_real for the records.
SHAPE_DIMS = {
    "full_graph_sm": dict(n=2720, e=10752, n_real=2708, e_real=10556,
                          d_feat=1433, classes=7),
    "minibatch_lg": dict(n=169_984, e=168_960, n_real=169_984,
                         e_real=168_960, d_feat=602, classes=41),
    "ogb_products": dict(n=2_449_056, e=61_859_328, n_real=2_449_029,
                         e_real=61_859_140, d_feat=100, classes=47),
    "molecule": dict(n=128 * 30, e=128 * 64, n_real=128 * 30,
                     e_real=128 * 64, d_feat=16, classes=0, graphs=128),
}

TRIP_PER_EDGE = 8


def gnn_cells(arch: str, base_cfg) -> Dict[str, ShapeCell]:
    """Build the 4 cells for one GNN arch (configs differ per cell in
    d_feat / n_classes / task, applied via overrides)."""
    needs_pos = arch in ("egnn", "dimenet", "meshgraphnet")
    needs_trip = arch == "dimenet"

    def make_inputs(dims, graphs: Optional[int]):
        def inputs():
            n, e, df = dims["n"], dims["e"], dims["d_feat"]
            d = {
                "x": sds((n, df), F32),
                "src": sds((e,), I32),
                "dst": sds((e,), I32),
                "node_mask": sds((n,), F32),
                "edge_mask": sds((e,), F32),
            }
            if needs_pos:
                d["pos"] = sds((n, 3), F32)
            if needs_trip:
                t = e * TRIP_PER_EDGE
                d["z"] = sds((n,), I32)
                d["trip_e"] = sds((t,), I32)
                d["trip_f"] = sds((t,), I32)
                d["trip_mask"] = sds((t,), F32)
            if graphs:
                d["graph_ids"] = sds((n,), I32)
                d["labels"] = sds((graphs,), F32)
            elif dims["classes"]:
                d["labels"] = sds((n,), I32)
            else:
                d["labels"] = sds((n, base_cfg.d_out), F32)
            return d

        return inputs

    axes = {
        "x": ("nodes", None),
        "pos": ("nodes", None),
        "z": ("nodes",),
        "src": ("edges",),
        "dst": ("edges",),
        "node_mask": ("nodes",),
        "edge_mask": ("edges",),
        "trip_e": ("edges",),
        "trip_f": ("edges",),
        "trip_mask": ("edges",),
        "graph_ids": ("nodes",),
        "labels": ("nodes",),  # graph labels replicate fine too
    }

    cells = {}
    for name, dims in SHAPE_DIMS.items():
        graphs = dims.get("graphs")
        overrides = {"d_feat": dims["d_feat"]}
        if graphs:
            overrides |= {"n_classes": 0, "task": "graph", "d_out": 1}
        else:
            if dims["classes"]:
                overrides |= {"n_classes": dims["classes"], "task": "node"}
            else:
                overrides |= {"n_classes": 0, "task": "node"}
        cells[name] = ShapeCell(
            name=name,
            kind="train",
            inputs=make_inputs(dims, graphs),
            input_axes=axes,
            overrides=overrides,
            meta={"n_nodes": dims["n"], "n_edges": dims["e"],
                  "n_real": dims["n_real"], "e_real": dims["e_real"],
                  **({"n_triplets": dims["e"] * TRIP_PER_EDGE}
                     if needs_trip else {})},
        )
    return cells
