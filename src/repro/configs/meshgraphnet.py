"""meshgraphnet [gnn] — 15 MP layers, d_hidden=128, sum aggregator,
2-layer MLPs.  [arXiv:2010.03409; unverified]
"""
from repro.models.gnn import GNNConfig
from .common import ArchSpec
from .gnn_common import gnn_cells

ARCH_ID = "meshgraphnet"


def model_cfg() -> GNNConfig:
    return GNNConfig(
        name=ARCH_ID,
        arch="meshgraphnet",
        n_layers=15,
        d_hidden=128,
        d_feat=1433,  # per-cell override
        d_edge=4,  # rel-pos + distance
        d_out=2,
    )


def spec() -> ArchSpec:
    cfg = model_cfg()
    return ArchSpec(
        arch_id=ARCH_ID,
        family="gnn",
        model_cfg=cfg,
        cells=gnn_cells("meshgraphnet", cfg),
        source="arXiv:2010.03409; unverified",
    )
