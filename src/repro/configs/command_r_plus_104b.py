"""command-r-plus-104b [dense] — 64L d=12288 96H (GQA kv=8) d_ff=33792
vocab=256000, GQA, no-bias.  [hf:CohereForAI/c4ai-command-r-v01; unverified]
"""
import jax.numpy as jnp

from repro.models.transformer import TransformerConfig
from .common import ArchSpec, ShapeCell, lm_cells, sds

ARCH_ID = "command-r-plus-104b"


def model_cfg() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID,
        n_layers=64,
        d_model=12288,
        n_heads=96,
        n_kv_heads=8,
        d_head=128,
        d_ff=33792,
        vocab=256000,
        qkv_bias=False,
        dtype=jnp.bfloat16,
    )


def _int8_decode_cell(cfg) -> ShapeCell:
    """OPTIMIZED decode variant: int8 KV cache (per-token-head scales,
    KIVI-style) + TP-only serving weights — the §Perf B2 combination the
    bf16 cache could not afford memory-wise (13 GiB weights + 4.3 GiB
    cache > HBM; int8 halves the cache)."""
    shape = (cfg.n_layers, 128, 32768, cfg.n_kv_heads, cfg.head_dim)
    sshape = shape[:-1]
    cache_axes = ("layers", "batch", "kv_seq", None, None)
    scale_axes = ("layers", "batch", "kv_seq", None)
    return ShapeCell(
        name="decode_32k_int8", kind="decode",
        inputs=lambda: {
            "tokens": sds((128,), jnp.int32),
            "cache_k": sds(shape, jnp.int8),
            "cache_v": sds(shape, jnp.int8),
            "cache_k_scale": sds(sshape, jnp.float32),
            "cache_v_scale": sds(sshape, jnp.float32),
            "pos": sds((), jnp.int32),
        },
        input_axes={
            "tokens": ("batch",), "cache_k": cache_axes,
            "cache_v": cache_axes, "cache_k_scale": scale_axes,
            "cache_v_scale": scale_axes, "pos": (),
        },
        rules_override={"embed": None},  # TP-only serving weights
        meta={"tokens": 128, "batch": 128, "seq": 32768, "kv_bytes": 1,
              "extra": True,
              "note": "OPTIMIZED: int8 KV + TP-only weights (SPerf B3)"},
    )


def spec() -> ArchSpec:
    cfg = model_cfg()
    cells = lm_cells(cfg, train_microbatches=16)
    cells["decode_32k_int8"] = _int8_decode_cell(cfg)
    return ArchSpec(
        arch_id=ARCH_ID,
        family="lm",
        model_cfg=cfg,
        # 104B: per-device microbatch of 1 keeps remat carry ~6 GB
        cells=cells,
        source="hf:CohereForAI/c4ai-command-r-v01; unverified",
    )
