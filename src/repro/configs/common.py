"""Arch/shape cell machinery shared by all config files.

Every assigned architecture file exposes ``spec() -> ArchSpec``; a cell =
(arch × input shape) defines exactly what the dry-run lowers:

* ``kind``       — which step function (train / prefill / decode / serve /
                   retrieval) the cell lowers,
* ``inputs()``   — ShapeDtypeStruct stand-ins for the step's data inputs,
* ``input_axes`` — logical sharding axes per input key,
* ``overrides``  — per-shape model-config knobs (microbatches, attn chunk),
* ``meta``       — tokens/batch bookkeeping for the roofline's 6ND term.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["ShapeCell", "ArchSpec", "sds", "lm_cells"]


def sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


@dataclasses.dataclass
class ShapeCell:
    name: str
    kind: str  # train | prefill | decode | serve | retrieval
    inputs: Callable[[], Dict[str, Any]]
    input_axes: Dict[str, Tuple[Optional[str], ...]]
    overrides: Dict[str, Any] = dataclasses.field(default_factory=dict)
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)
    skip: Optional[str] = None  # reason string if the cell is skipped
    # per-cell physical rule overrides (merged over the arch-level ones),
    # e.g. TP-only serving weights for the int8-KV decode variant
    rules_override: Dict[str, Any] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class ArchSpec:
    arch_id: str
    family: str  # lm | gnn | recsys
    model_cfg: Any
    cells: Dict[str, ShapeCell]
    source: str = ""  # provenance tag from the assignment table
    # per-arch physical rule overrides (e.g. act_seq off for small d_model
    # where the remat carry fits HBM without sequence-parallel residuals)
    rules_override: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def cfg_for(self, cell: ShapeCell):
        if not cell.overrides:
            return self.model_cfg
        return dataclasses.replace(self.model_cfg, **cell.overrides)


# --------------------------------------------------------------------------- #
# LM shape set (shared by all five LM archs)
# --------------------------------------------------------------------------- #
def lm_cells(
    cfg,
    *,
    train_microbatches: int = 1,
    prefill_batch_override: Optional[int] = None,
    sub_quadratic: bool = False,
) -> Dict[str, ShapeCell]:
    """train_4k / prefill_32k / decode_32k / long_500k for an LM config.

    ``long_500k`` lowers serve_step (decode with a 512k KV cache) — decode
    cost is LINEAR in cache length, so the cell runs for every arch; the
    full-attention *prefill* at 512k would be quadratic and is NOT claimed
    (DESIGN.md §5 records this reading).
    """
    v = cfg.vocab
    tok = jnp.int32

    def train_inputs():
        return {
            "tokens": sds((256, 4096), tok),
            "labels": sds((256, 4096), tok),
        }

    def prefill_inputs():
        b = prefill_batch_override or 32
        return {"tokens": sds((b, 32768), tok)}

    def decode_inputs():
        return {
            "tokens": sds((128,), tok),
            "cache_k": sds(
                (cfg.n_layers, 128, 32768, cfg.n_kv_heads, cfg.head_dim),
                cfg.dtype,
            ),
            "cache_v": sds(
                (cfg.n_layers, 128, 32768, cfg.n_kv_heads, cfg.head_dim),
                cfg.dtype,
            ),
            "pos": sds((), jnp.int32),
        }

    def long_inputs():
        return {
            "tokens": sds((1,), tok),
            "cache_k": sds(
                (cfg.n_layers, 1, 524288, cfg.n_kv_heads, cfg.head_dim),
                cfg.dtype,
            ),
            "cache_v": sds(
                (cfg.n_layers, 1, 524288, cfg.n_kv_heads, cfg.head_dim),
                cfg.dtype,
            ),
            "pos": sds((), jnp.int32),
        }

    cache_axes_32k = ("layers", "batch", "kv_seq", None, None)
    cache_axes_500k = ("layers", None, "kv_seq", None, None)
    return {
        "train_4k": ShapeCell(
            name="train_4k",
            kind="train",
            inputs=train_inputs,
            input_axes={"tokens": ("batch", None),
                        "labels": ("batch", None)},
            overrides={"n_microbatches": train_microbatches},
            meta={"tokens": 256 * 4096, "batch": 256, "seq": 4096},
        ),
        "prefill_32k": ShapeCell(
            name="prefill_32k",
            kind="prefill",
            inputs=prefill_inputs,
            input_axes={"tokens": ("batch", None)},
            overrides={"attn_q_chunk": 2048, "remat": False},
            meta={"tokens": (prefill_batch_override or 32) * 32768,
                  "batch": prefill_batch_override or 32, "seq": 32768},
        ),
        "decode_32k": ShapeCell(
            name="decode_32k",
            kind="decode",
            inputs=decode_inputs,
            input_axes={
                "tokens": ("batch",),
                "cache_k": cache_axes_32k,
                "cache_v": cache_axes_32k,
                "pos": (),
            },
            meta={"tokens": 128, "batch": 128, "seq": 32768,
                  "note": "decode-only, one new token vs 32k cache"},
        ),
        "long_500k": ShapeCell(
            name="long_500k",
            kind="decode",
            inputs=long_inputs,
            input_axes={
                "tokens": ("batch",),
                "cache_k": cache_axes_500k,
                "cache_v": cache_axes_500k,
                "pos": (),
            },
            meta={"tokens": 1, "batch": 1, "seq": 524288,
                  "note": ("decode-only (linear in seq); 512k prefill not "
                           "claimed for full-attention archs")},
        ),
    }
