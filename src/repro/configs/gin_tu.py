"""gin-tu [gnn] — 5 layers, d_hidden=64, sum aggregator, learnable eps.
[arXiv:1810.00826; paper]
"""
import jax.numpy as jnp

from repro.models.gnn import GNNConfig
from .common import ArchSpec, ShapeCell, sds
from .gnn_common import gnn_cells

ARCH_ID = "gin-tu"

# products-scale locality partition, measured on the Table-4-matched graph
# (N=2,449,029, E=61.46M, contiguous uniform shards = the paper's Omega_k):
# K=16 -> max published boundary 63,048/shard, max edges 3,911,163/shard,
# remote-edge fraction 24.9% (EXPERIMENTS.md SPerf C).
HALO_K = 16
HALO_N_PAD = 2_449_040  # ceil(N/16)*16
HALO_B_MAX = 65_536
HALO_E_CAP = 3_911_680


def halo_cell(cfg) -> ShapeCell:
    def inputs():
        e_tot = HALO_K * HALO_E_CAP
        return {
            "x": sds((HALO_N_PAD, 100), jnp.float32),
            "src_slot": sds((e_tot,), jnp.int32),
            "dst_local": sds((e_tot,), jnp.int32),
            "edge_mask": sds((e_tot,), jnp.float32),
            "boundary": sds((HALO_K, HALO_B_MAX), jnp.int32),
            "node_mask": sds((HALO_N_PAD,), jnp.float32),
            "labels": sds((HALO_N_PAD,), jnp.int32),
        }

    axes = {
        "x": ("nodes", None), "src_slot": ("nodes",),
        "dst_local": ("nodes",), "edge_mask": ("nodes",),
        "boundary": ("nodes", None), "node_mask": ("nodes",),
        "labels": ("nodes",),
    }
    return ShapeCell(
        name="ogb_products_halo", kind="train", inputs=inputs,
        input_axes=axes,
        overrides={"d_feat": 100, "n_classes": 47, "task": "node"},
        meta={"n_nodes": HALO_N_PAD, "n_edges": HALO_K * HALO_E_CAP,
              "n_real": 2_449_029, "e_real": 61_464_267,
              "mesh_only": "pod16x16", "extra": True,
              "note": "OPTIMIZED variant: locality partition + halo "
                      "exchange (paper's more-links-inside-Omega_k)"},
    )


def model_cfg() -> GNNConfig:
    return GNNConfig(
        name=ARCH_ID,
        arch="gin",
        n_layers=5,
        d_hidden=64,
        d_feat=1433,  # per-cell override
        eps_learnable=True,
        n_classes=7,
    )


def spec() -> ArchSpec:
    cfg = model_cfg()
    cells = gnn_cells("gin", cfg)
    cells["ogb_products_halo"] = halo_cell(cfg)
    return ArchSpec(
        arch_id=ARCH_ID,
        family="gnn",
        model_cfg=cfg,
        cells=cells,
        source="arXiv:1810.00826; paper",
    )
