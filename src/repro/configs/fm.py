"""fm [recsys] — 39 sparse fields, embed_dim=10, 2-way FM interaction via
the O(nk) sum-square trick; 10^6 rows per field -> 39M-row fused table.
[ICDM'10 (Rendle); paper]
"""
import jax.numpy as jnp

from repro.models.recsys import FMConfig
from .common import ArchSpec, ShapeCell, sds

ARCH_ID = "fm"
I32 = jnp.int32


def model_cfg() -> FMConfig:
    return FMConfig(
        name=ARCH_ID,
        n_fields=39,
        vocab_per_field=1_000_000,
        embed_dim=10,
    )


def spec() -> ArchSpec:
    cfg = model_cfg()
    f = cfg.n_fields

    def batch_inputs(b):
        def inputs():
            return {"ids": sds((b, f), I32), "labels": sds((b,), I32)}
        return inputs

    def retrieval_inputs():
        return {
            "user_ids": sds((f - 1,), I32),
            "cand_ids": sds((1_000_000,), I32),
        }

    axes = {"ids": ("batch", None), "labels": ("batch",)}
    cells = {
        "train_batch": ShapeCell(
            name="train_batch", kind="train",
            inputs=batch_inputs(65_536), input_axes=axes,
            meta={"batch": 65_536},
        ),
        "serve_p99": ShapeCell(
            name="serve_p99", kind="serve",
            inputs=batch_inputs(512), input_axes=axes,
            meta={"batch": 512, "note": "online-inference latency shape"},
        ),
        "serve_bulk": ShapeCell(
            name="serve_bulk", kind="serve",
            inputs=batch_inputs(262_144), input_axes=axes,
            meta={"batch": 262_144, "note": "offline scoring"},
        ),
        "retrieval_cand": ShapeCell(
            name="retrieval_cand", kind="retrieval",
            inputs=retrieval_inputs,
            input_axes={"user_ids": (None,), "cand_ids": ("batch",)},
            meta={"batch": 1, "n_candidates": 1_000_000,
                  "note": "one query vs 1M candidates, single matvec"},
        ),
    }
    return ArchSpec(
        arch_id=ARCH_ID,
        family="recsys",
        model_cfg=cfg,
        cells=cells,
        source="ICDM'10 (Rendle); paper",
    )
