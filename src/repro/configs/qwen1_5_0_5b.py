"""qwen1.5-0.5b [dense] — 24L d=1024 16H (GQA kv=16) d_ff=2816
vocab=151936, QKV bias.  [hf:Qwen/Qwen1.5-0.5B; hf]
"""
import jax.numpy as jnp

from repro.models.transformer import TransformerConfig
from .common import ArchSpec, lm_cells

ARCH_ID = "qwen1.5-0.5b"


def model_cfg() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID,
        n_layers=24,
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        d_head=64,
        d_ff=2816,
        vocab=151936,
        qkv_bias=True,
        dtype=jnp.bfloat16,
    )


def spec() -> ArchSpec:
    cfg = model_cfg()
    return ArchSpec(
        arch_id=ARCH_ID,
        family="lm",
        model_cfg=cfg,
        cells=lm_cells(cfg, train_microbatches=1),
        source="hf:Qwen/Qwen1.5-0.5B; hf",
    )
