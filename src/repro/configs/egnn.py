"""egnn [gnn] — 4 layers, d_hidden=64, E(n)-equivariant updates.
[arXiv:2102.09844; paper]
"""
from repro.models.gnn import GNNConfig
from .common import ArchSpec
from .gnn_common import gnn_cells

ARCH_ID = "egnn"


def model_cfg() -> GNNConfig:
    return GNNConfig(
        name=ARCH_ID,
        arch="egnn",
        n_layers=4,
        d_hidden=64,
        d_feat=1433,  # per-cell override
        d_out=1,
    )


def spec() -> ArchSpec:
    cfg = model_cfg()
    return ArchSpec(
        arch_id=ARCH_ID,
        family="gnn",
        model_cfg=cfg,
        cells=gnn_cells("egnn", cfg),
        source="arXiv:2102.09844; paper",
    )
