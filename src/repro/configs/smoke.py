"""Reduced same-family configs + tiny batches for per-arch CPU smoke tests.

Each assigned architecture gets a shrunken clone (few layers, narrow dims,
tiny vocab/tables/graphs) that preserves the family structure — MoE stays
MoE with shared experts, GQA ratios survive, DimeNet keeps triplets — so one
forward/train step on CPU exercises the same code paths the full config
lowers on the production mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.data import criteo_like_batch, lm_token_batch, molecule_batch

__all__ = ["smoke_setup"]


def _lm_shrink(cfg):
    moe = cfg.moe
    if moe is not None:
        moe = dataclasses.replace(
            moe, n_experts=min(moe.n_experts, 8), top_k=min(moe.top_k, 2),
            d_ff_expert=16, pad_experts_to=8,
        )
    return dataclasses.replace(
        cfg,
        n_layers=2,
        d_model=64,
        n_heads=max(2, cfg.n_heads // 8),
        n_kv_heads=max(1, cfg.n_kv_heads // 8),
        d_head=16,
        d_ff=96,
        vocab=128,
        moe=moe,
        dtype=jnp.float32,
        ce_chunk=16,
        n_microbatches=1,
    )


def smoke_setup(arch_id: str) -> Tuple[Any, Dict[str, Any], str]:
    """Returns (reduced model cfg, tiny batch dict, family)."""
    spec = get_arch(arch_id)
    rng = np.random.default_rng(0)
    if spec.family == "lm":
        cfg = _lm_shrink(spec.model_cfg)
        b = lm_token_batch(0, 2, 32, cfg.vocab)
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        return cfg, batch, "lm"
    if spec.family == "gnn":
        cfg = dataclasses.replace(
            spec.model_cfg,
            n_layers=min(spec.model_cfg.n_layers, 3),
            d_hidden=16,
            d_feat=8,
            n_bilinear=4,
            n_spherical=4,
            n_radial=4,
        )
        arch = spec.model_cfg.arch
        if arch == "dimenet":
            cfg = dataclasses.replace(cfg, task="graph", n_classes=0)
            raw = molecule_batch(4, nodes_per_graph=10, edges_per_graph=20,
                                 d_feat=8, with_triplets=True)
        elif arch == "egnn":
            cfg = dataclasses.replace(cfg, task="graph", n_classes=0)
            raw = molecule_batch(4, nodes_per_graph=10, edges_per_graph=20,
                                 d_feat=8)
        elif arch == "gin":
            cfg = dataclasses.replace(cfg, task="node", n_classes=5,
                                      d_out=5)
            raw = molecule_batch(4, nodes_per_graph=10, edges_per_graph=20,
                                 d_feat=8, graph_labels=False)
            raw["labels"] = rng.integers(0, 5, raw["x"].shape[0]).astype(
                np.int32)
        else:  # meshgraphnet: node regression
            cfg = dataclasses.replace(cfg, task="node", n_classes=0)
            raw = molecule_batch(4, nodes_per_graph=10, edges_per_graph=20,
                                 d_feat=8, graph_labels=False)
            raw["labels"] = rng.standard_normal(
                (raw["x"].shape[0], cfg.d_out)).astype(np.float32)
        batch = {k: jnp.asarray(v) for k, v in raw.items()}
        return cfg, batch, "gnn"
    if spec.family == "recsys":
        cfg = dataclasses.replace(
            spec.model_cfg, n_fields=6, vocab_per_field=100, embed_dim=8)
        raw = criteo_like_batch(0, 32, cfg.n_fields, cfg.vocab_per_field)
        batch = {k: jnp.asarray(v) for k, v in raw.items()}
        return cfg, batch, "recsys"
    raise ValueError(spec.family)
