"""qwen2-moe-a2.7b [moe] — 24L d=2048 16H (GQA kv=16) d_ff=1408
vocab=151936, MoE 60 routed top-4 + 4 shared experts.
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]
"""
import jax.numpy as jnp

from repro.models.transformer import MoEConfig, TransformerConfig
from .common import ArchSpec, lm_cells

ARCH_ID = "qwen2-moe-a2.7b"


def model_cfg() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID,
        n_layers=24,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_head=128,
        d_ff=1408,
        vocab=151936,
        qkv_bias=True,
        moe=MoEConfig(n_experts=60, top_k=4, d_ff_expert=1408,
                      n_shared=4, pad_experts_to=64),
        dtype=jnp.bfloat16,
    )


def spec() -> ArchSpec:
    cfg = model_cfg()
    return ArchSpec(
        arch_id=ARCH_ID,
        family="lm",
        model_cfg=cfg,
        cells=lm_cells(cfg, train_microbatches=2),
        source="hf:Qwen/Qwen1.5-MoE-A2.7B; hf",
    )
