"""mistral-large-123b [dense] — 88L d=12288 96H (GQA kv=8) d_ff=28672
vocab=32768.  [hf:mistralai/Mistral-Large-Instruct-2407; unverified]
"""
import jax.numpy as jnp

from repro.models.transformer import TransformerConfig
from .common import ArchSpec, lm_cells

ARCH_ID = "mistral-large-123b"


def model_cfg() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID,
        n_layers=88,
        d_model=12288,
        n_heads=96,
        n_kv_heads=8,
        d_head=128,
        d_ff=28672,
        vocab=32768,
        qkv_bias=False,
        dtype=jnp.bfloat16,
    )


def spec() -> ArchSpec:
    cfg = model_cfg()
    return ArchSpec(
        arch_id=ARCH_ID,
        family="lm",
        model_cfg=cfg,
        cells=lm_cells(cfg, train_microbatches=16),
        source="hf:mistralai/Mistral-Large-Instruct-2407; unverified",
    )
