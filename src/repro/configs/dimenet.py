"""dimenet [gnn] — 6 interaction blocks, d_hidden=128, n_bilinear=8,
n_spherical=7, n_radial=6; directional messages with triplet aggregation.
Triplets capped at 8 per edge (cutoff neighborhoods, DESIGN.md §5).
[arXiv:2003.03123; unverified]
"""
from repro.models.gnn import GNNConfig
from .common import ArchSpec
from .gnn_common import gnn_cells

ARCH_ID = "dimenet"


def model_cfg() -> GNNConfig:
    return GNNConfig(
        name=ARCH_ID,
        arch="dimenet",
        n_layers=6,  # interaction blocks
        d_hidden=128,
        d_feat=16,  # per-cell override
        n_bilinear=8,
        n_spherical=7,
        n_radial=6,
        d_out=1,
        task="graph",
    )


def spec() -> ArchSpec:
    cfg = model_cfg()
    return ArchSpec(
        arch_id=ARCH_ID,
        family="gnn",
        model_cfg=cfg,
        cells=gnn_cells("dimenet", cfg),
        source="arXiv:2003.03123; unverified",
    )
