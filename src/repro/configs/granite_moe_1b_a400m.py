"""granite-moe-1b-a400m [moe] — 24L d=1024 16H (GQA kv=8) d_ff=512
vocab=49155, MoE 32 experts top-8, no shared experts.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]
"""
import jax.numpy as jnp

from repro.models.transformer import MoEConfig, TransformerConfig
from .common import ArchSpec, lm_cells

ARCH_ID = "granite-moe-1b-a400m"


def model_cfg() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID,
        n_layers=24,
        d_model=1024,
        n_heads=16,
        n_kv_heads=8,
        d_head=64,
        d_ff=512,
        vocab=49155,
        qkv_bias=False,
        moe=MoEConfig(n_experts=32, top_k=8, d_ff_expert=512, n_shared=0),
        dtype=jnp.bfloat16,
    )


def spec() -> ArchSpec:
    cfg = model_cfg()
    return ArchSpec(
        arch_id=ARCH_ID,
        family="lm",
        model_cfg=cfg,
        cells=lm_cells(cfg, train_microbatches=1),
        source="hf:ibm-granite/granite-3.0-1b-a400m-base; hf",
    )
