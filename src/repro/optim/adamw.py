"""AdamW with fp32 master weights, sharded optimizer state, schedules.

Mixed-precision contract: model params may be bf16; the optimizer keeps an
fp32 master copy plus fp32 moments — all sharded identically to the params
(ZeRO-3 style under the 2D mesh; the sharding specs come from
repro.parallel.sharding so opt state never concentrates on one device).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "AdamWConfig",
    "adamw_init",
    "adamw_update",
    "clip_by_global_norm",
    "cosine_schedule",
    "linear_warmup",
]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"  # cosine | linear | constant
    min_lr_frac: float = 0.1


def adamw_init(params: Any) -> Dict[str, Any]:
    f32 = lambda p: p.astype(jnp.float32)
    return {
        "master": jax.tree.map(f32, params),
        "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "step": jnp.zeros((), jnp.int32),
    }


def cosine_schedule(step, cfg: AdamWConfig):
    warm = linear_warmup(step, cfg)
    t = jnp.clip(
        (step - cfg.warmup_steps)
        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * t)
    )
    return warm * cos


def linear_warmup(step, cfg: AdamWConfig):
    return jnp.minimum(1.0, (step + 1) / jnp.maximum(cfg.warmup_steps, 1))


def _lr_at(step, cfg: AdamWConfig):
    if cfg.schedule == "cosine":
        return cfg.lr * cosine_schedule(step, cfg)
    if cfg.schedule == "linear":
        t = jnp.clip(step / jnp.maximum(cfg.total_steps, 1), 0.0, 1.0)
        return cfg.lr * linear_warmup(step, cfg) * (1 - (1 - cfg.min_lr_frac) * t)
    return cfg.lr * linear_warmup(step, cfg)


def clip_by_global_norm(grads: Any, max_norm: float) -> Tuple[Any, jax.Array]:
    gn = jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(grads))
    )
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gn


def adamw_update(
    grads: Any,
    opt_state: Dict[str, Any],
    cfg: AdamWConfig,
    param_dtype=jnp.bfloat16,
) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    """Returns (new_params_in_model_dtype, new_opt_state, metrics)."""
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    if cfg.grad_clip > 0:
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    else:
        gnorm = jnp.zeros(())
    step = opt_state["step"] + 1
    lr = _lr_at(step, cfg)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    new_m = jax.tree.map(
        lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g, opt_state["m"], grads
    )
    new_v = jax.tree.map(
        lambda v, g: cfg.b2 * v + (1 - cfg.b2) * g * g, opt_state["v"], grads
    )

    def upd(p, m, v):
        mhat = m / b1c
        vhat = v / b2c
        return p - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                         + cfg.weight_decay * p)

    new_master = jax.tree.map(upd, opt_state["master"], new_m, new_v)
    new_params = jax.tree.map(lambda p: p.astype(param_dtype), new_master)
    return (
        new_params,
        {"master": new_master, "m": new_m, "v": new_v, "step": step},
        {"lr": lr, "grad_norm": gnorm},
    )
