from .adamw import (  # noqa: F401
    AdamWConfig,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    cosine_schedule,
    linear_warmup,
)
from .compression import (  # noqa: F401
    CompressionState,
    compress_int8,
    decompress_int8,
    ef_topk_compress,
    ef_topk_init,
)
