"""Gradient compression for cross-pod data parallelism.

Two composable schemes (distributed-optimization tricks for the DCN hop,
DESIGN.md §6):

* **int8 quantized all-reduce** — per-tensor symmetric int8 with an fp32
  scale; 4× less DCN traffic for the pod-level gradient reduction.
* **error-feedback top-k** — keep the top-k fraction of gradient entries,
  accumulate the rest in a local residual (Stich et al.; SGD with memory),
  so sparsification stays unbiased over time.

On hardware these wrap the pod-axis psum inside shard_map (compress →
all-reduce int8/sparse → decompress).  The pure functions here are exactly
those wrappers' bodies and are unit-tested for the EF contract
(compressed + residual == original).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "compress_int8",
    "decompress_int8",
    "CompressionState",
    "ef_topk_init",
    "ef_topk_compress",
]


def compress_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)).astype(jnp.float32) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jax.Array, scale: jax.Array,
                    dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


class CompressionState(NamedTuple):
    residual: Any  # error-feedback memory, same tree as grads


def ef_topk_init(grads: Any) -> CompressionState:
    return CompressionState(
        residual=jax.tree.map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads
        )
    )


def ef_topk_compress(
    grads: Any, state: CompressionState, k_frac: float = 0.1
) -> Tuple[Any, CompressionState]:
    """Per-leaf magnitude top-k with error feedback.

    Returns (sparse-but-dense-layout grads, new residual state).  The dense
    layout keeps SPMD-friendly static shapes; on the wire the zeros compress
    (or use (values, indices) pairs on a real deployment).
    """

    def one(g, r):
        acc = g.astype(jnp.float32) + r
        k = max(1, int(acc.size * k_frac))
        flat = jnp.abs(acc.reshape(-1))
        thresh = jax.lax.top_k(flat, k)[0][-1]
        mask = (jnp.abs(acc) >= thresh).astype(jnp.float32)
        sent = acc * mask
        return sent.astype(g.dtype), acc - sent

    outs = jax.tree.map(one, grads, state.residual)
    sent = jax.tree.map(lambda o: o[0], outs,
                        is_leaf=lambda x: isinstance(x, tuple))
    resid = jax.tree.map(lambda o: o[1], outs,
                         is_leaf=lambda x: isinstance(x, tuple))
    return sent, CompressionState(residual=resid)
