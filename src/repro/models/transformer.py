"""Decoder-only transformer LM family (dense + MoE) in pure JAX.

Covers the five assigned LM architectures: GQA attention (with optional QKV
bias, Qwen-style), RMSNorm, RoPE, SwiGLU FFN, optional MoE FFN (shared +
routed experts, top-k routing with capacity-based dispatch), untied LM head.

Scale features:

* **scan-over-layers** with stacked [L, ...] params — one compiled layer
  body regardless of depth (88-layer Mistral-Large compiles as fast as the
  0.5B model).
* **remat** (activation checkpointing) around the scanned layer body.
* **gradient-accumulation microbatching** in the loss wrapper (configured
  per input shape so the 104B cells fit HBM).
* **chunked cross-entropy** — [B, S, V] logits are never materialised;
  the sequence is processed in chunks against the vocab-sharded LM head.
* **logical sharding hints** (repro.parallel.axes) — batch/heads/mlp/vocab
  annotations that the production mesh maps to (pod, data, model).
* decode path with a static KV cache, sequence-sharded for the long-context
  cells (distributed-softmax attention; DESIGN.md §6).

Attention uses the XLA einsum formulation by default (what the dry-run
lowers and the roofline measures); the Pallas flash kernel
(repro.kernels.attention) is the TPU drop-in, validated in interpret mode.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.parallel.compat import shard_map

from repro.parallel.axes import hint

__all__ = [
    "MoEConfig",
    "TransformerConfig",
    "init_params",
    "train_loss",
    "prefill_step",
    "decode_step",
    "init_cache",
]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0  # shared experts, fused into one dense SwiGLU
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01  # load-balance auxiliary loss
    pad_experts_to: int = 0  # pad expert tensors for even EP sharding

    @property
    def e_pad(self) -> int:
        return max(self.n_experts, self.pad_experts_to)


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: Optional[int] = None
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    moe: Optional[MoEConfig] = None
    dtype: Any = jnp.bfloat16
    # per-shape knobs (overridden by launch configs):
    ce_chunk: int = 1024
    n_microbatches: int = 1
    remat: bool = True
    attn_q_chunk: Optional[int] = None  # q-chunked attention (long prefill)

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def n_params(self) -> int:
        """Total parameter count (for 6ND model-flops accounting)."""
        d, v, l = self.d_model, self.vocab, self.n_layers
        hd = self.head_dim
        attn = d * hd * (self.n_heads * 2 + self.n_kv_heads * 2)
        if self.qkv_bias:
            attn += hd * (self.n_heads + 2 * self.n_kv_heads)
        if self.moe is None:
            ffn = 3 * d * self.d_ff
        else:
            m = self.moe
            ffn = m.n_experts * 3 * d * m.d_ff_expert + d * m.n_experts
            if m.n_shared:
                ffn += 3 * d * (m.d_ff_expert * m.n_shared)
        norms = 2 * d
        return l * (attn + ffn + norms) + 2 * v * d + d

    @property
    def n_active_params(self) -> int:
        """Active per-token params (MoE: routed top-k + shared only)."""
        if self.moe is None:
            return self.n_params
        d, l, m = self.d_model, self.n_layers, self.moe
        routed_all = m.n_experts * 3 * d * m.d_ff_expert
        routed_act = m.top_k * 3 * d * m.d_ff_expert
        return self.n_params - l * (routed_all - routed_act)


# --------------------------------------------------------------------------- #
# init
# --------------------------------------------------------------------------- #
def init_params(cfg: TransformerConfig, key: jax.Array) -> Dict:
    d, hd = cfg.d_model, cfg.head_dim
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    l, v = cfg.n_layers, cfg.vocab
    dt = cfg.dtype
    k = iter(jax.random.split(key, 24))

    def dense(key, shape, fan_in):
        return (jax.random.normal(key, shape, jnp.float32)
                / math.sqrt(fan_in)).astype(dt)

    layers: Dict[str, Any] = {
        "ln1": jnp.ones((l, d), dt),
        "ln2": jnp.ones((l, d), dt),
        "wq": dense(next(k), (l, d, hq * hd), d),
        "wk": dense(next(k), (l, d, hkv * hd), d),
        "wv": dense(next(k), (l, d, hkv * hd), d),
        "wo": dense(next(k), (l, hq * hd, d), hq * hd),
    }
    if cfg.qkv_bias:
        layers["bq"] = jnp.zeros((l, hq * hd), dt)
        layers["bk"] = jnp.zeros((l, hkv * hd), dt)
        layers["bv"] = jnp.zeros((l, hkv * hd), dt)
    if cfg.moe is None:
        layers["w1"] = dense(next(k), (l, d, cfg.d_ff), d)
        layers["w3"] = dense(next(k), (l, d, cfg.d_ff), d)
        layers["w2"] = dense(next(k), (l, cfg.d_ff, d), cfg.d_ff)
    else:
        m = cfg.moe
        layers["router"] = dense(next(k), (l, d, m.n_experts), d)
        layers["ew1"] = dense(next(k), (l, m.e_pad, d, m.d_ff_expert), d)
        layers["ew3"] = dense(next(k), (l, m.e_pad, d, m.d_ff_expert), d)
        layers["ew2"] = dense(
            next(k), (l, m.e_pad, m.d_ff_expert, d), m.d_ff_expert
        )
        if m.n_shared:
            fs = m.d_ff_expert * m.n_shared
            layers["sw1"] = dense(next(k), (l, d, fs), d)
            layers["sw3"] = dense(next(k), (l, d, fs), d)
            layers["sw2"] = dense(next(k), (l, fs, d), fs)
    return {
        "embed": dense(next(k), (v, d), d),
        "layers": layers,
        "final_norm": jnp.ones((d,), dt),
        "lm_head": dense(next(k), (d, v), d),
    }


# --------------------------------------------------------------------------- #
# building blocks
# --------------------------------------------------------------------------- #
def rmsnorm(x, scale, eps):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps).astype(x.dtype)) * scale


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [B, S, H, Dh]; positions: [B, S] (or [S])."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = jnp.exp(
        -math.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half
    )
    ang = positions[..., None].astype(jnp.float32) * freqs  # [B, S, half]
    cos = jnp.cos(ang)[..., None, :].astype(x.dtype)  # [B, S, 1, half]
    sin = jnp.sin(ang)[..., None, :].astype(x.dtype)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)


def _attention(q, k, v, causal: bool, kv_pos_limit=None,
               q_chunk: Optional[int] = None):
    """q: [B,Sq,Hq,Dh], k/v: [B,Sk,Hkv,Dh] -> [B,Sq,Hq,Dh] (einsum form).

    ``q_chunk`` streams the query dim through lax.scan so the [Sq, Sk]
    score matrix is never fully materialised (XLA-level flash for long
    prefill; the Pallas kernel replaces this on TPU).
    """
    b, sq, hq, dh = q.shape
    if q_chunk is not None and sq > q_chunk and sq % q_chunk == 0:
        nch = sq // q_chunk
        qc = q.reshape(b, nch, q_chunk, hq, dh).swapaxes(0, 1)
        starts = jnp.arange(nch) * q_chunk

        def body(_, xs):
            st, qblk = xs
            out = _attention_block(qblk, k, v, causal, kv_pos_limit, st)
            return None, out

        _, outs = jax.lax.scan(body, None, (starts, qc))
        return outs.swapaxes(0, 1).reshape(b, sq, hq, dh)
    return _attention_block(q, k, v, causal, kv_pos_limit, 0)


def _attention_block(q, k, v, causal: bool, kv_pos_limit=None, q_start=0):
    b, sq, hq, dh = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    group = hq // hkv
    k = jnp.repeat(k, group, axis=2)
    v = jnp.repeat(v, group, axis=2)
    q = hint(q, "batch", None, "heads", None)
    k = hint(k, "batch", "kv_seq" if kv_pos_limit is not None else None,
             "heads", None)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32)
    s = s / math.sqrt(dh)
    if causal:
        qpos = q_start + jnp.arange(sq)
        kpos = jnp.arange(sk)
        mask = qpos[:, None] >= kpos[None, :]
        s = jnp.where(mask[None, None], s, -1e30)
    if kv_pos_limit is not None:  # decode: mask cache beyond current pos
        kpos = jnp.arange(sk)
        s = jnp.where((kpos <= kv_pos_limit)[None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v)
    return out


def _decode_attn_dist(q, ck, cv, kk, vv, pos, cfg, mesh, rules,
                      scales=None):
    """Distributed decode attention over a sequence-sharded KV cache.

    Baseline pjit decode all-gathers the WHOLE cache per layer (the
    dynamic_update_slice at ``pos`` on a kv_seq-sharded dim forces a
    reshard - 1 GiB x L for command-r decode_32k; EXPERIMENTS.md SPerf B).
    This shard_map version keeps every cache shard local: the owning shard
    applies the update in place, each shard computes partial attention
    over its S_loc keys, and the softmax is combined with tiny
    pmax/psum([B,H]) collectives (flash-decoding's split-KV scheme).

    Returns None when the cell's sharding doesn't apply (no kv_seq axis or
    non-divisible dims) so the caller can fall back to the pjit path.
    """
    from jax.sharding import PartitionSpec as P

    kv_ax = rules.get("kv_seq")
    if not isinstance(kv_ax, str) or mesh is None:
        return None
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    b, s, hkv, dh = ck.shape
    hq = q.shape[2]
    s_shards = sizes.get(kv_ax, 1)
    if s_shards <= 1 or s % s_shards:
        return None
    s_loc = s // s_shards
    group = hq // hkv

    def _san(axes, dim):
        kept, rem = [], dim
        for a in (axes if isinstance(axes, tuple)
                  else (axes,) if axes else ()):
            n = sizes.get(a, 1)
            if n > 1 and rem % n == 0:
                kept.append(a)
                rem //= n
        return tuple(kept) if kept else None

    b_ax = _san(rules.get("batch"), b)

    def block(q, ck, cv, kk, vv, pos, *sc):
        bl = q.shape[0]
        idx = jax.lax.axis_index(kv_ax)
        start = idx * s_loc
        off = jnp.clip(pos - start, 0, s_loc - 1)
        in_rng = (pos >= start) & (pos < start + s_loc)
        ck_new = jax.lax.dynamic_update_slice(ck, kk, (0, off, 0, 0))
        cv_new = jax.lax.dynamic_update_slice(cv, vv, (0, off, 0, 0))
        ck = jnp.where(in_rng, ck_new, ck)
        cv = jnp.where(in_rng, cv_new, cv)
        outs_scale = ()
        ks = vs = None
        if sc:  # int8 KV: scales FACTOR OUT of the einsums (per token,head)
            ks, vs, ks_new, vs_new = sc
            ks_u = jax.lax.dynamic_update_slice(ks, ks_new, (0, off, 0))
            vs_u = jax.lax.dynamic_update_slice(vs, vs_new, (0, off, 0))
            ks = jnp.where(in_rng, ks_u, ks)
            vs = jnp.where(in_rng, vs_u, vs)
            ck_q, cv_q = ck, cv
            # cast only (bf16); never materialise the scaled cache —
            # scores multiply by ks afterwards, vs folds into p below
            ck = ck.astype(q.dtype)
            cv = cv.astype(q.dtype)
            outs_scale = (ks, vs)
        # grouped-query local scores without materialising repeated KV
        qg = q.reshape(bl, 1, hkv, group, dh)
        sres = jnp.einsum("bqhgd,bkhd->bhgqk", qg, ck).astype(jnp.float32)
        if ks is not None:
            sres = sres * ks.transpose(0, 2, 1)[:, :, None, None, :]
        sres = sres / math.sqrt(dh)
        kpos = start + jnp.arange(s_loc)
        sres = jnp.where((kpos <= pos)[None, None, None, None, :],
                         sres, -1e30)
        m_loc = sres.max(-1)  # [B,Hkv,G,1]
        m = jax.lax.pmax(m_loc, kv_ax)
        p = jnp.exp(sres - m[..., None])
        l_loc = p.sum(-1)
        pv = p if vs is None else (
            p * vs.transpose(0, 2, 1)[:, :, None, None, :])
        o_loc = jnp.einsum("bhgqk,bkhd->bqhgd", pv.astype(cv.dtype), cv)
        l = jax.lax.psum(l_loc, kv_ax)  # [B,Hkv,G,1]
        o = jax.lax.psum(o_loc.astype(jnp.float32), kv_ax)
        denom = jnp.maximum(l, 1e-30).transpose(0, 3, 1, 2)[..., None]
        out = (o / denom).reshape(bl, 1, hq, dh).astype(q.dtype)
        if sc:
            return (out, ck_q, cv_q) + outs_scale
        return out, ck, cv

    spec_q = P(b_ax, None, None, None)
    spec_c = P(b_ax, kv_ax, None, None)
    spec_s = P(b_ax, kv_ax, None)
    if scales is not None:
        ks, vs, ks_new, vs_new = scales
        mapped = shard_map(
            block, mesh=mesh,
            in_specs=(spec_q, spec_c, spec_c, spec_q, spec_q, P(),
                      spec_s, spec_s, P(b_ax, None, None),
                      P(b_ax, None, None)),
            out_specs=(spec_q, spec_c, spec_c, spec_s, spec_s),
            check_vma=False,
        )
        return mapped(q, ck, cv, kk, vv, pos, ks, vs, ks_new, vs_new)
    mapped = shard_map(
        block, mesh=mesh,
        in_specs=(spec_q, spec_c, spec_c, spec_q, spec_q, P()),
        out_specs=(spec_q, spec_c, spec_c),
        check_vma=False,
    )
    return mapped(q, ck, cv, kk, vv, pos)


def _moe_ffn_ep(lp, x, cfg: TransformerConfig, mesh, rules):
    """Expert-parallel MoE via shard_map + all_to_all (GShard proper).

    The pjit scatter-based dispatch (_moe_ffn below) lets SPMD materialise
    a full [E, cap, D] buffer per device and all-reduce it (~5.7 GiB/layer
    for qwen2-moe; EXPERIMENTS.md §Perf A).  Here tokens are routed
    locally per device, exchanged with ONE all_to_all over the expert
    axis (bytes ≈ T_loc·D — three orders of magnitude less), experts
    compute on their local shard, and a reverse all_to_all returns the
    outputs.  Local-capacity dropping replaces global-capacity dropping
    (standard GShard semantics).
    """
    from jax.sharding import PartitionSpec as P

    m = cfg.moe
    b, s, d = x.shape
    ep_ax = rules.get("expert")
    batch_ax = rules.get("batch")
    # internal token split over the expert axis even when the global
    # residual stream is not sequence-sharded (free slice in, one bf16
    # all-gather out instead of f32 reshards at every boundary)
    seq_ax = rules.get("act_seq") or ep_ax
    fsdp_ax = rules.get("embed") if isinstance(rules.get("embed"), str) \
        else None
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    ep = sizes[ep_ax]
    e_loc = m.e_pad // ep

    def block(xb, router, ew1, ew3, ew2):
        bl, sl, _ = xb.shape
        t_loc = bl * sl
        xf = xb.reshape(t_loc, d)
        logits = (xf @ router).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_w, gate_i = jax.lax.top_k(probs, m.top_k)
        gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)
        cap = max(1, int(math.ceil(
            t_loc * m.top_k / m.e_pad * m.capacity_factor)))
        flat_e = gate_i.reshape(-1)
        onehot = jax.nn.one_hot(flat_e, m.e_pad, dtype=jnp.int32)
        pos = (jnp.cumsum(onehot, axis=0) * onehot - 1).max(axis=-1)
        keep = pos < cap
        tok_idx = jnp.repeat(jnp.arange(t_loc), m.top_k)
        send = jnp.zeros((m.e_pad, cap, d), xb.dtype)
        send = send.at[
            jnp.where(keep, flat_e, 0), jnp.where(keep, pos, cap - 1)
        ].add(jnp.where(keep[:, None], xf[tok_idx], 0.0))
        # exchange: [E, cap, D] -> [E_loc, ep*cap, D]
        recv = jax.lax.all_to_all(
            send, ep_ax, split_axis=0, concat_axis=1, tiled=True)
        if fsdp_ax is not None:  # FSDP: regather sharded D dim
            ew1_ = jax.lax.all_gather(ew1, fsdp_ax, axis=1, tiled=True)
            ew3_ = jax.lax.all_gather(ew3, fsdp_ax, axis=1, tiled=True)
            ew2_ = jax.lax.all_gather(ew2, fsdp_ax, axis=2, tiled=True)
        else:
            ew1_, ew3_, ew2_ = ew1, ew3, ew2
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", recv, ew1_)) \
            * jnp.einsum("ecd,edf->ecf", recv, ew3_)
        eo = jnp.einsum("ecf,efd->ecd", h, ew2_)
        back = jax.lax.all_to_all(
            eo, ep_ax, split_axis=1, concat_axis=0, tiled=True)
        gathered = back[jnp.where(keep, flat_e, 0),
                        jnp.where(keep, pos, cap - 1)]
        gathered = jnp.where(keep[:, None], gathered, 0.0)
        w = gate_w.reshape(-1)[:, None].astype(xb.dtype)
        out = jax.ops.segment_sum(gathered * w, tok_idx,
                                  num_segments=t_loc)
        me = probs.mean(axis=0)
        ce = jnp.bincount(flat_e, length=m.n_experts).astype(jnp.float32) \
            / max(t_loc * m.top_k, 1)
        aux = m.n_experts * jnp.sum(me * ce) * m.router_aux_weight
        aux = jax.lax.pmean(aux, tuple(mesh.axis_names))
        return out.reshape(bl, sl, d), aux

    mapped = shard_map(
        block,
        mesh=mesh,
        in_specs=(P(batch_ax, seq_ax, None), P(None, None),
                  P(ep_ax, fsdp_ax, None), P(ep_ax, fsdp_ax, None),
                  P(ep_ax, None, fsdp_ax)),
        out_specs=(P(batch_ax, seq_ax, None), P()),
        check_vma=False,
    )
    out, aux = mapped(x, lp["router"], lp["ew1"], lp["ew3"], lp["ew2"])
    if m.n_shared:
        xf = x.reshape(b * s, d)
        sh = jax.nn.silu(xf @ lp["sw1"]) * (xf @ lp["sw3"])
        out = out + (sh @ lp["sw2"]).reshape(b, s, d)
    return out, aux


def _moe(lp, x, cfg: TransformerConfig):
    """Route to the shard_map EP path when a mesh + expert axis are live."""
    from repro.parallel.axes import current_mesh, current_rules

    mesh = current_mesh()
    rules = current_rules() or {}
    ep_ax = rules.get("expert")
    if mesh is not None and isinstance(ep_ax, str):
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        ep = sizes.get(ep_ax, 1)
        b, s, _ = x.shape
        batch_ax = rules.get("batch")
        seq_ax = rules.get("act_seq") or ep_ax
        bsh = 1
        for a in (batch_ax if isinstance(batch_ax, tuple)
                  else (batch_ax,) if batch_ax else ()):
            bsh *= sizes.get(a, 1)
        ssh = sizes.get(seq_ax, 1) if isinstance(seq_ax, str) else 1
        if (ep > 1 and cfg.moe.e_pad % ep == 0 and b % bsh == 0
                and s % ssh == 0 and (b * s) // (bsh * ssh) >= 1):
            return _moe_ffn_ep(lp, x, cfg, mesh, rules)
    return _moe_ffn(lp, x, cfg)


# --------------------------------------------------------------------------- #
# balance-plane tap: per-expert routed-token counts (repro.balance hook)
# --------------------------------------------------------------------------- #
_expert_load_sink = None


def set_expert_load_sink(fn) -> None:
    """Register ``fn(counts: np.ndarray[n_experts])`` as the expert-load sink.

    Every MoE dispatch then streams its per-expert routed-token counts to
    ``fn`` (via ``jax.debug.callback``, so it works under jit) — the
    ``expert-tokens`` LoadSignal of the :mod:`repro.balance` control plane:
    a hot expert is an overloaded Ω_k and the same slope policy that moves
    nodes/buckets proposes expert-shard moves.  Pass ``None`` to unhook.
    Register BEFORE the step function is traced; the tap is baked in at
    trace time (dispatch at call time goes through the module global, so
    re-registering a different sink needs no re-trace).

    Active on the pjit dispatch path (``_moe_ffn``); the expert-parallel
    shard_map path keeps its per-shard stats local (documented semantic
    difference) and does not tap.
    """
    global _expert_load_sink
    _expert_load_sink = fn


def _dispatch_expert_load(counts) -> None:
    if _expert_load_sink is not None:
        import numpy as np

        _expert_load_sink(np.asarray(counts))


def _tap_expert_load(counts) -> None:
    if _expert_load_sink is not None:  # traced-in only when hooked
        jax.debug.callback(_dispatch_expert_load, counts)


def _moe_ffn(lp, x, cfg: TransformerConfig):
    """Capacity-based top-k MoE (GShard-style dispatch via sorted scatter)."""
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)
    logits = (xf @ lp["router"]).astype(jnp.float32)  # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, gate_i = jax.lax.top_k(probs, m.top_k)  # [T, k]
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)
    cap = int(math.ceil(t * m.top_k / m.n_experts * m.capacity_factor))
    # position of each (token, slot) within its expert via cumsum of one-hot
    flat_e = gate_i.reshape(-1)  # [T*k]
    onehot = jax.nn.one_hot(flat_e, m.n_experts, dtype=jnp.int32)
    pos_in_e = jnp.cumsum(onehot, axis=0) * onehot - 1  # [T*k, E]
    pos = pos_in_e.max(axis=-1)  # [T*k]
    keep = pos < cap
    # dispatch buffer [E_pad, cap, D] (padding experts receive no tokens)
    buf = jnp.zeros((m.e_pad, cap, d), x.dtype)
    tok_idx = jnp.repeat(jnp.arange(t), m.top_k)
    buf = buf.at[
        jnp.where(keep, flat_e, 0),
        jnp.where(keep, pos, cap - 1),
    ].add(jnp.where(keep[:, None], xf[tok_idx], 0.0))
    buf = hint(buf, "expert", None, None)
    # expert SwiGLU
    h1 = jnp.einsum("ecd,edf->ecf", buf, lp["ew1"])
    h3 = jnp.einsum("ecd,edf->ecf", buf, lp["ew3"])
    h = jax.nn.silu(h1) * h3
    eo = jnp.einsum("ecf,efd->ecd", h, lp["ew2"])  # [E, cap, D]
    eo = hint(eo, "expert", None, None)
    # combine
    gathered = eo[jnp.where(keep, flat_e, 0), jnp.where(keep, pos, cap - 1)]
    gathered = jnp.where(keep[:, None], gathered, 0.0)
    w = gate_w.reshape(-1)[:, None].astype(x.dtype)
    out = jax.ops.segment_sum(gathered * w, tok_idx, num_segments=t)
    # auxiliary load-balance loss (Switch-style)
    me = probs.mean(axis=0)  # mean router prob per expert
    counts = jnp.bincount(flat_e, length=m.n_experts)
    _tap_expert_load(counts)
    ce = counts.astype(jnp.float32) / max(t * m.top_k, 1)
    aux = m.n_experts * jnp.sum(me * ce) * m.router_aux_weight
    if m.n_shared:
        sh = jax.nn.silu(xf @ lp["sw1"]) * (xf @ lp["sw3"])
        out = out + sh @ lp["sw2"]
    return out.reshape(b, s, d), aux


def _dense_ffn(lp, x):
    h = jax.nn.silu(x @ lp["w1"]) * (x @ lp["w3"])
    h = hint(h, "batch", None, "mlp")
    return h @ lp["w2"]


def _layer(lp, x, positions, cfg: TransformerConfig,
           cache: Optional[Tuple] = None, pos_limit=None):
    """One decoder layer.  cache=(k_cache, v_cache) for decode."""
    b, s, d = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    y = rmsnorm(x, lp["ln1"], cfg.norm_eps)
    q = y @ lp["wq"]
    kk = y @ lp["wk"]
    vv = y @ lp["wv"]
    if cfg.qkv_bias:
        q, kk, vv = q + lp["bq"], kk + lp["bk"], vv + lp["bv"]
    q = q.reshape(b, s, hq, hd)
    kk = kk.reshape(b, s, hkv, hd)
    vv = vv.reshape(b, s, hkv, hd)
    q = rope(q, positions, cfg.rope_theta)
    kk = rope(kk, positions, cfg.rope_theta)
    new_cache = None
    if cache is None:
        attn = _attention(q, kk, vv, causal=True, q_chunk=cfg.attn_q_chunk)
    else:
        pos0 = positions[0, 0] if positions.ndim == 2 else positions[0]
        from repro.parallel.axes import current_mesh, current_rules

        mesh = current_mesh()
        rules = current_rules() or {}
        if len(cache) == 4:  # int8 KV cache: (ck, cv, k_scale, v_scale)
            ck, cv, ks, vs = cache
            ks_new = jnp.max(jnp.abs(kk), axis=-1) / 127.0 + 1e-8
            vs_new = jnp.max(jnp.abs(vv), axis=-1) / 127.0 + 1e-8
            kk_q = jnp.clip(jnp.round(kk / ks_new[..., None]),
                            -127, 127).astype(jnp.int8)
            vv_q = jnp.clip(jnp.round(vv / vs_new[..., None]),
                            -127, 127).astype(jnp.int8)
            dist = None
            if mesh is not None:
                dist = _decode_attn_dist(
                    q, ck, cv, kk_q, vv_q, pos0, cfg, mesh, rules,
                    scales=(ks, vs, ks_new.astype(jnp.float32),
                            vs_new.astype(jnp.float32)))
            if dist is not None:
                attn, ck, cv, ks, vs = dist
            else:  # single-device fallback: dequantize-then-attend
                ck = jax.lax.dynamic_update_slice(ck, kk_q, (0, pos0, 0, 0))
                cv = jax.lax.dynamic_update_slice(cv, vv_q, (0, pos0, 0, 0))
                ks = jax.lax.dynamic_update_slice(
                    ks, ks_new.astype(jnp.float32), (0, pos0, 0))
                vs = jax.lax.dynamic_update_slice(
                    vs, vs_new.astype(jnp.float32), (0, pos0, 0))
                ckf = ck.astype(jnp.float32) * ks[..., None]
                cvf = cv.astype(jnp.float32) * vs[..., None]
                attn = _attention(q, ckf.astype(q.dtype),
                                  cvf.astype(q.dtype), causal=False,
                                  kv_pos_limit=pos_limit)
            new_cache = (ck, cv, ks, vs)
            attn = hint(attn, "batch", None, "heads", None)
            x = x + (attn.reshape(b, s, hq * hd) @ lp["wo"])
            y = rmsnorm(x, lp["ln2"], cfg.norm_eps)
            if cfg.moe is None:
                x = x + _dense_ffn(lp, y)
                aux = jnp.zeros((), jnp.float32)
            else:
                ffn, aux = _moe(lp, y, cfg)
                x = x + ffn
            x = hint(x, "batch", "act_seq", "act_embed")
            return x, new_cache, aux
        ck, cv = cache  # [B, Smax, Hkv, Dh]
        dist = None
        if mesh is not None:
            dist = _decode_attn_dist(q, ck, cv, kk, vv, pos0, cfg, mesh,
                                     rules)
        if dist is not None:
            attn, ck, cv = dist
        else:
            ck = jax.lax.dynamic_update_slice(ck, kk, (0, pos0, 0, 0))
            cv = jax.lax.dynamic_update_slice(cv, vv, (0, pos0, 0, 0))
            ck = hint(ck, "batch", "kv_seq", None, None)
            cv = hint(cv, "batch", "kv_seq", None, None)
            attn = _attention(q, ck, cv, causal=False,
                              kv_pos_limit=pos_limit)
        new_cache = (ck, cv)
    attn = hint(attn, "batch", None, "heads", None)
    x = x + (attn.reshape(b, s, hq * hd) @ lp["wo"])
    y = rmsnorm(x, lp["ln2"], cfg.norm_eps)
    if cfg.moe is None:
        x = x + _dense_ffn(lp, y)
        aux = jnp.zeros((), jnp.float32)
    else:
        ffn, aux = _moe(lp, y, cfg)
        x = x + ffn
    x = hint(x, "batch", "act_seq", "act_embed")
    return x, new_cache, aux


def _stack_scan(params, x, positions, cfg: TransformerConfig):
    """scan over stacked layers (+ remat)."""

    def body(carry, lp):
        x, aux = carry
        x, _, a = _layer(lp, x, positions, cfg)
        return (x, aux + a), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    (x, aux), _ = jax.lax.scan(body_fn, (x, jnp.zeros((), jnp.float32)),
                               params["layers"])
    return x, aux


# --------------------------------------------------------------------------- #
# training loss (chunked CE + microbatching)
# --------------------------------------------------------------------------- #
def _chunked_ce(x, lm_head, labels, chunk: int):
    """mean token CE without materialising [B, S, V]."""
    b, s, d = x.shape
    chunk = min(chunk, s)
    n_chunks = s // chunk
    assert s % chunk == 0, (s, chunk)
    xc = x.reshape(b, n_chunks, chunk, d).swapaxes(0, 1)
    lc = labels.reshape(b, n_chunks, chunk).swapaxes(0, 1)

    def body(tot, xl):
        xch, lch = xl
        logits = (xch @ lm_head).astype(jnp.float32)
        logits = hint(logits, "batch", None, "vocab")
        lz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lch[..., None], axis=-1)[..., 0]
        return tot + jnp.sum(lz - gold), None

    tot, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xc, lc))
    return tot / (b * s)


def _forward_loss(params, tokens, labels, cfg: TransformerConfig):
    x = params["embed"][tokens]
    x = hint(x, "batch", "act_seq", "act_embed")
    positions = jnp.broadcast_to(
        jnp.arange(tokens.shape[1]), tokens.shape
    )
    x, aux = _stack_scan(params, x, positions, cfg)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return _chunked_ce(x, params["lm_head"], labels, cfg.ce_chunk) + aux


def train_loss(params, batch, cfg: TransformerConfig):
    """Mean CE over the (optionally microbatched) global batch."""
    tokens, labels = batch["tokens"], batch["labels"]
    nm = cfg.n_microbatches
    if nm <= 1:
        return _forward_loss(params, tokens, labels, cfg)
    b = tokens.shape[0]
    assert b % nm == 0, (b, nm)
    tok = tokens.reshape(nm, b // nm, -1)
    lab = labels.reshape(nm, b // nm, -1)
    # keep each microbatch data-sharded (not the microbatch dim itself)
    tok = hint(tok, None, "batch", None)
    lab = hint(lab, None, "batch", None)

    def body(tot, tl):
        t, l_ = tl
        return tot + _forward_loss(params, t, l_, cfg), None

    tot, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (tok, lab))
    return tot / nm


# --------------------------------------------------------------------------- #
# serving
# --------------------------------------------------------------------------- #
def init_cache(cfg: TransformerConfig, batch: int, max_seq: int,
               dtype=None) -> Dict:
    dt = dtype or cfg.dtype
    shape = (cfg.n_layers, batch, max_seq, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt),
            "pos": jnp.zeros((), jnp.int32)}


def prefill_step(params, tokens, cfg: TransformerConfig,
                 max_seq: Optional[int] = None):
    """Process a prompt, return (cache, last-token logits).

    ``max_seq`` pads the returned cache so decode can continue past the
    prompt length.
    """
    b, s = tokens.shape
    x = params["embed"][tokens]
    x = hint(x, "batch", "act_seq", "act_embed")
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    def body(carry, lp):
        x = carry
        y, cache_l, _ = _layer_prefill(lp, x, positions, cfg)
        return y, cache_l

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, caches = jax.lax.scan(body_fn, x, params["layers"])
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = (x[:, -1] @ params["lm_head"]).astype(jnp.float32)
    ck, cv = caches
    if max_seq is not None and max_seq > s:
        pad = ((0, 0), (0, 0), (0, max_seq - s), (0, 0), (0, 0))
        ck, cv = jnp.pad(ck, pad), jnp.pad(cv, pad)
    cache = {"k": ck, "v": cv, "pos": jnp.array(s, jnp.int32)}
    return cache, logits


def _layer_prefill(lp, x, positions, cfg):
    b, s, d = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    y = rmsnorm(x, lp["ln1"], cfg.norm_eps)
    q = y @ lp["wq"]
    kk = y @ lp["wk"]
    vv = y @ lp["wv"]
    if cfg.qkv_bias:
        q, kk, vv = q + lp["bq"], kk + lp["bk"], vv + lp["bv"]
    q = rope(q.reshape(b, s, hq, hd), positions, cfg.rope_theta)
    kk = rope(kk.reshape(b, s, hkv, hd), positions, cfg.rope_theta)
    vv = vv.reshape(b, s, hkv, hd)
    attn = _attention(q, kk, vv, causal=True, q_chunk=cfg.attn_q_chunk)
    x = x + (attn.reshape(b, s, hq * hd) @ lp["wo"])
    y = rmsnorm(x, lp["ln2"], cfg.norm_eps)
    if cfg.moe is None:
        x = x + _dense_ffn(lp, y)
        aux = jnp.zeros((), jnp.float32)
    else:
        ffn, aux = _moe(lp, y, cfg)
        x = x + ffn
    kk = hint(kk, "batch", "kv_seq", None, None)
    vv = hint(vv, "batch", "kv_seq", None, None)
    return x, (kk, vv), aux


def decode_step(params, cache, tokens, cfg: TransformerConfig):
    """One decode step: tokens [B] -> logits [B, V], updated cache.

    The KV cache is [L, B, Smax, Hkv, Dh], sequence-sharded on the model
    axis for the long-context cells (distributed softmax over kv_seq).
    """
    b = tokens.shape[0]
    pos = cache["pos"]
    x = params["embed"][tokens][:, None, :]  # [B, 1, D]
    x = hint(x, "batch", "act_seq", "act_embed")
    positions = jnp.broadcast_to(pos[None, None], (b, 1))

    quant = "k_scale" in cache

    def body(x, lp_cache):
        if quant:
            lp, ck, cv, ks, vs = lp_cache
            cache_l = (ck, cv, ks, vs)
        else:
            lp, ck, cv = lp_cache
            cache_l = (ck, cv)
        y, new_cache, _ = _layer(lp, x, positions, cfg, cache=cache_l,
                                 pos_limit=pos)
        return y, new_cache

    if quant:
        x, (new_k, new_v, new_ks, new_vs) = jax.lax.scan(
            body, x,
            (params["layers"], cache["k"], cache["v"],
             cache["k_scale"], cache["v_scale"]),
        )
    else:
        x, (new_k, new_v) = jax.lax.scan(
            body, x, (params["layers"], cache["k"], cache["v"])
        )
    x = rmsnorm(x[:, 0], params["final_norm"], cfg.norm_eps)
    logits = (x @ params["lm_head"]).astype(jnp.float32)
    logits = hint(logits, "batch", "vocab")
    out_cache = {"k": new_k, "v": new_v, "pos": pos + 1}
    if quant:
        out_cache["k_scale"] = new_ks
        out_cache["v_scale"] = new_vs
    return logits, out_cache
