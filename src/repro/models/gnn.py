"""GNN architectures: MeshGraphNet, EGNN, GIN, DimeNet (pure JAX).

Message passing is built on the sorted-segment primitive — JAX has no native
sparse SpMM beyond BCOO, so scatter/gather over an explicit edge list IS the
system (kernel_taxonomy §GNN).  The XLA path uses ``jax.ops.segment_sum``;
the Pallas seg-matmul kernel (repro.kernels.segment) is the TPU drop-in for
the same contract (sorted ids).

Batch convention (static shapes, padded):

    batch = {
      "x":        [N, F]   node features,
      "pos":      [N, 3]   coordinates (EGNN / DimeNet),
      "z":        [N]      atom types (DimeNet),
      "src","dst":[E]      directed edges (messages flow src -> dst),
      "edge_attr":[E, Fe]  edge features (MeshGraphNet),
      "node_mask":[N]      1.0 = real node,
      "edge_mask":[E]      1.0 = real edge,
      "graph_ids":[N]      graph id per node (batched small graphs),
      "labels":   task-dependent,
      # DimeNet only:
      "trip_e":   [T]      target edge id  (message j->i being updated)
      "trip_f":   [T]      source edge id  (incoming message k->j)
      "trip_mask":[T]
    }

Distribution: edges are sharded over the whole mesh ("edges" logical axis),
node states over ("pod","data") — the aggregation's cross-shard scatter-add
is the same collective pattern as the solver's fluid exchange (DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.parallel.compat import shard_map

from repro.parallel.axes import hint

__all__ = [
    "GNNConfig",
    "init_params",
    "loss_fn",
    "forward",
]


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str
    arch: str  # meshgraphnet | egnn | gin | dimenet
    n_layers: int
    d_hidden: int
    d_feat: int  # input node feature dim
    d_edge: int = 0  # input edge feature dim (meshgraphnet)
    d_out: int = 1
    n_classes: int = 0  # >0 => classification
    # gin
    eps_learnable: bool = True
    # dimenet
    n_bilinear: int = 8
    n_spherical: int = 7
    n_radial: int = 6
    n_atom_types: int = 16
    cutoff: float = 5.0
    dtype: Any = jnp.float32
    task: str = "node"  # node | graph


# --------------------------------------------------------------------------- #
# shared pieces
# --------------------------------------------------------------------------- #
def _mlp_init(key, dims, dt):
    ws, bs = [], []
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        key, k1 = jax.random.split(key)
        ws.append((jax.random.normal(k1, (a, b), jnp.float32)
                   / math.sqrt(a)).astype(dt))
        bs.append(jnp.zeros((b,), dt))
    return {"w": ws, "b": bs}


def _mlp(p, x, act=jax.nn.silu, final_act=False, norm=False):
    n = len(p["w"])
    for i, (w, b) in enumerate(zip(p["w"], p["b"])):
        x = x @ w + b
        if i < n - 1 or final_act:
            x = act(x)
    if norm:
        mu = x.mean(-1, keepdims=True)
        var = x.var(-1, keepdims=True)
        x = (x - mu) * jax.lax.rsqrt(var + 1e-6)
    return x


def _agg(messages, dst, n_nodes, edge_mask=None):
    """Masked scatter-add of edge messages onto destination nodes."""
    if edge_mask is not None:
        messages = messages * edge_mask[:, None]
    out = jax.ops.segment_sum(messages, dst, num_segments=n_nodes)
    return hint(out, "nodes", None)


# --------------------------------------------------------------------------- #
# init
# --------------------------------------------------------------------------- #
def init_params(cfg: GNNConfig, key: jax.Array) -> Dict:
    dt = cfg.dtype
    d = cfg.d_hidden
    keys = iter(jax.random.split(key, 64 + 8 * cfg.n_layers))
    p: Dict[str, Any] = {}
    if cfg.arch == "gin":
        p["embed"] = _mlp_init(next(keys), (cfg.d_feat, d), dt)
        p["eps"] = jnp.zeros((cfg.n_layers,), dt)
        p["mlps"] = [
            _mlp_init(next(keys), (d, d, d), dt) for _ in range(cfg.n_layers)
        ]
        p["readout"] = _mlp_init(
            next(keys), (d, d, cfg.n_classes or cfg.d_out), dt
        )
    elif cfg.arch == "meshgraphnet":
        p["node_enc"] = _mlp_init(next(keys), (cfg.d_feat, d, d), dt)
        p["edge_enc"] = _mlp_init(next(keys), (cfg.d_edge or 4, d, d), dt)
        p["edge_mlps"] = [
            _mlp_init(next(keys), (3 * d, d, d), dt)
            for _ in range(cfg.n_layers)
        ]
        p["node_mlps"] = [
            _mlp_init(next(keys), (2 * d, d, d), dt)
            for _ in range(cfg.n_layers)
        ]
        p["decoder"] = _mlp_init(next(keys), (d, d, cfg.d_out), dt)
    elif cfg.arch == "egnn":
        p["embed"] = _mlp_init(next(keys), (cfg.d_feat, d), dt)
        p["edge_mlps"] = [
            _mlp_init(next(keys), (2 * d + 1, d, d), dt)
            for _ in range(cfg.n_layers)
        ]
        p["coord_mlps"] = [
            _mlp_init(next(keys), (d, d, 1), dt)
            for _ in range(cfg.n_layers)
        ]
        p["node_mlps"] = [
            _mlp_init(next(keys), (2 * d, d, d), dt)
            for _ in range(cfg.n_layers)
        ]
        p["readout"] = _mlp_init(next(keys), (d, d, cfg.d_out), dt)
    elif cfg.arch == "dimenet":
        nb, ns, nr = cfg.n_bilinear, cfg.n_spherical, cfg.n_radial
        p["atom_embed"] = (
            jax.random.normal(next(keys), (cfg.n_atom_types, d), jnp.float32)
            * 0.1
        ).astype(dt)
        p["rbf_proj"] = _mlp_init(next(keys), (nr, d), dt)
        p["edge_embed"] = _mlp_init(next(keys), (3 * d, d), dt)
        p["blocks"] = []
        for _ in range(cfg.n_layers):
            k1, k2, k3, k4 = (next(keys) for _ in range(4))
            p["blocks"].append(
                {
                    "sbf_proj": _mlp_init(k1, (ns * nr, nb), dt),
                    "w_bil": (
                        jax.random.normal(k2, (nb, d, d), jnp.float32)
                        / math.sqrt(nb * d)
                    ).astype(dt),
                    "msg_mlp": _mlp_init(k3, (2 * d, d, d), dt),
                    "out_mlp": _mlp_init(k4, (d, d), dt),
                }
            )
        p["readout"] = _mlp_init(next(keys), (d, d, cfg.d_out), dt)
    else:
        raise ValueError(cfg.arch)
    return p


# --------------------------------------------------------------------------- #
# forward per arch
# --------------------------------------------------------------------------- #
def _forward_gin_halo(p, batch, cfg, mesh, rules):
    """Locality-partitioned GIN aggregation (the paper's §3 insight:
    "favour partition sets such that there are more links inside Ω_k").

    Nodes are contiguously sharded (= the paper's uniform Ω_k); edges are
    pre-sorted to their destination's shard; each shard publishes only its
    *boundary* rows (nodes some other shard references).  Per layer the
    halo exchange all-gathers [K, B_max, d] instead of all-reducing the
    full [N, d] aggregate — traffic drops by the boundary fraction (~7×
    measured on the products-scale graph; EXPERIMENTS.md §Perf C).

    Batch layout (built by data.build_halo_batch):
      x           [N_pad, F]        node-sharded
      src_slot    [K·E_cap]         per-edge index into [h_loc ++ halo]
      dst_local   [K·E_cap]         local dst in [0, N_loc)
      edge_mask   [K·E_cap]
      boundary    [K, B_max]        local ids each shard publishes
      labels      [N_pad]
    """
    from jax.sharding import PartitionSpec as P

    node_ax = rules.get("nodes")
    if mesh is None or node_ax is None:
        return None
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    k = 1
    for a in (node_ax if isinstance(node_ax, tuple) else (node_ax,)):
        k *= sizes.get(a, 1)
    n_pad = batch["x"].shape[0]
    if k <= 1 or n_pad % k or batch["src_slot"].shape[0] % k:
        return None
    n_loc = n_pad // k
    b_max = batch["boundary"].shape[1]

    def block(x, src_slot, dst_local, edge_mask, boundary):
        h = _mlp(p["embed"], x, final_act=True)  # [N_loc, d]
        for l in range(cfg.n_layers):
            publish = h[boundary[0]]  # [B_max, d]
            halo = jax.lax.all_gather(
                publish, node_ax, axis=0, tiled=True)  # [K*B_max, d]
            table = jnp.concatenate([h, halo], axis=0)
            msgs = table[src_slot] * edge_mask[:, None]
            agg = jax.ops.segment_sum(msgs, dst_local,
                                      num_segments=n_loc)
            h = _mlp(p["mlps"][l], (1.0 + p["eps"][l]) * h + agg,
                     final_act=True)
        return _mlp(p["readout"], h)

    mapped = shard_map(
        block,
        mesh=mesh,
        in_specs=(P(node_ax, None), P(node_ax), P(node_ax), P(node_ax),
                  P(node_ax, None)),
        out_specs=P(node_ax, None),
        check_vma=False,
    )
    return mapped(batch["x"], batch["src_slot"], batch["dst_local"],
                  batch["edge_mask"], batch["boundary"])


def _forward_gin(p, batch, cfg):
    x = batch["x"]
    n = x.shape[0]
    src, dst = batch["src"], batch["dst"]
    em = batch.get("edge_mask")
    h = _mlp(p["embed"], x, final_act=True)
    for l in range(cfg.n_layers):
        msgs = h[src]
        msgs = hint(msgs, "edges", None)
        agg = _agg(msgs, dst, n, em)
        h = _mlp(p["mlps"][l], (1.0 + p["eps"][l]) * h + agg,
                 final_act=True)
        h = hint(h, "nodes", None)
    return h


def _forward_meshgraphnet(p, batch, cfg):
    x, src, dst = batch["x"], batch["src"], batch["dst"]
    n = x.shape[0]
    em = batch.get("edge_mask")
    e = batch.get("edge_attr")
    if e is None:
        pos = batch.get("pos")
        if pos is not None:
            rel = pos[src] - pos[dst]
            dist = jnp.linalg.norm(rel, axis=-1, keepdims=True)
            e = jnp.concatenate([rel, dist], -1)
        else:
            e = jnp.ones((src.shape[0], 4), x.dtype)
    h = _mlp(p["node_enc"], x, norm=True)
    he = _mlp(p["edge_enc"], e, norm=True)
    for l in range(cfg.n_layers):
        he_in = jnp.concatenate([he, h[src], h[dst]], -1)
        he_in = hint(he_in, "edges", None)
        he = he + _mlp(p["edge_mlps"][l], he_in, norm=True)
        agg = _agg(he, dst, n, em)
        h = h + _mlp(p["node_mlps"][l],
                     jnp.concatenate([h, agg], -1), norm=True)
        h = hint(h, "nodes", None)
    return _mlp(p["decoder"], h)


def _forward_egnn(p, batch, cfg):
    x, src, dst = batch["x"], batch["src"], batch["dst"]
    pos = batch["pos"]
    n = x.shape[0]
    em = batch.get("edge_mask")
    h = _mlp(p["embed"], x, final_act=True)
    for l in range(cfg.n_layers):
        rel = pos[src] - pos[dst]  # [E, 3]
        d2 = jnp.sum(rel * rel, axis=-1, keepdims=True)
        m_in = jnp.concatenate([h[src], h[dst], d2], -1)
        m_in = hint(m_in, "edges", None)
        m = _mlp(p["edge_mlps"][l], m_in, final_act=True)
        cw = _mlp(p["coord_mlps"][l], m)  # [E, 1]
        if em is not None:
            cw = cw * em[:, None]
        denom = 1.0 + jnp.abs(d2)  # normalized coordinate update
        pos = pos + jax.ops.segment_sum(
            rel / denom * cw, dst, num_segments=n
        ) / max(1, 8)
        agg = _agg(m, dst, n, em)
        h = h + _mlp(p["node_mlps"][l],
                     jnp.concatenate([h, agg], -1))
        h = hint(h, "nodes", None)
    return h, pos


def _bessel_rbf(d, cutoff, n_radial, dtype):
    """DimeNet radial basis: sqrt(2/c)·sin(nπd/c)/d, smooth-enveloped."""
    d = jnp.maximum(d, 1e-6)
    n = jnp.arange(1, n_radial + 1, dtype=jnp.float32)
    x = d[:, None] / cutoff  # [E, 1]
    rbf = jnp.sqrt(2.0 / cutoff) * jnp.sin(n[None] * jnp.pi * x) / d[:, None]
    env = jnp.where(x < 1.0, 0.5 * (jnp.cos(jnp.pi * x) + 1.0), 0.0)
    return (rbf * env).astype(dtype)


def _legendre(cos_a, n_spherical):
    """P_l(cos α), l = 0..n_spherical-1, by recurrence."""
    outs = [jnp.ones_like(cos_a), cos_a]
    for l in range(2, n_spherical):
        outs.append(
            ((2 * l - 1) * cos_a * outs[-1] - (l - 1) * outs[-2]) / l
        )
    return jnp.stack(outs[:n_spherical], axis=-1)  # [T, ns]


def _forward_dimenet(p, batch, cfg):
    src, dst = batch["src"], batch["dst"]  # directed edges j -> i
    pos, z = batch["pos"], batch["z"]
    n = pos.shape[0]
    e_count = src.shape[0]
    em = batch.get("edge_mask")
    trip_e, trip_f = batch["trip_e"], batch["trip_f"]
    tm = batch.get("trip_mask")

    rel = pos[src] - pos[dst]
    dist = jnp.linalg.norm(rel + 1e-9, axis=-1)  # [E]
    rbf = _bessel_rbf(dist, cfg.cutoff, cfg.n_radial, pos.dtype)  # [E, nr]
    rbf_h = _mlp(p["rbf_proj"], rbf)  # [E, d]

    hz = p["atom_embed"][z]  # [N, d]
    m = _mlp(
        p["edge_embed"],
        jnp.concatenate([hz[src], hz[dst], rbf_h], -1),
        final_act=True,
    )  # [E, d] directed messages
    m = hint(m, "edges", None)

    # triplet angles: edge e = (j->i), incoming f = (k->j)
    # cos(angle) between -rel[f] (j->k reversed) and rel[e]? DimeNet uses the
    # angle at j between (j->i) and (j->k); rel vectors are src - dst.
    v1 = rel[trip_e]  # j - i direction proxy
    v2 = rel[trip_f]  # k - j
    cos_a = jnp.sum(v1 * v2, -1) / (
        jnp.linalg.norm(v1, axis=-1) * jnp.linalg.norm(v2, axis=-1) + 1e-9
    )
    leg = _legendre(cos_a, cfg.n_spherical)  # [T, ns]
    rbf_t = _bessel_rbf(
        dist[trip_f], cfg.cutoff, cfg.n_radial, pos.dtype
    )  # [T, nr]
    sbf = (leg[:, :, None] * rbf_t[:, None, :]).reshape(
        trip_e.shape[0], -1
    )  # [T, ns*nr]

    node_out = jnp.zeros((n, cfg.d_hidden), m.dtype)
    for blk in p["blocks"]:
        sp = _mlp(blk["sbf_proj"], sbf)  # [T, nb]
        msrc = m[trip_f]  # [T, d]
        inter = jnp.einsum("tb,td,bdh->th", sp, msrc, blk["w_bil"])
        if tm is not None:
            inter = inter * tm[:, None]
        inter = hint(inter, "edges", None)
        agg_t = jax.ops.segment_sum(
            inter, trip_e, num_segments=e_count
        )  # [E, d]
        m = m + _mlp(
            blk["msg_mlp"], jnp.concatenate([m, agg_t], -1), final_act=True
        )
        m = hint(m, "edges", None)
        node_out = node_out + _agg(_mlp(blk["out_mlp"], m), dst, n, em)
    return _mlp(p["readout"], node_out)  # [N, d_out]


def forward(params, batch, cfg: GNNConfig):
    if cfg.arch == "gin":
        if "src_slot" in batch:  # locality-partitioned halo mode
            from repro.parallel.axes import current_mesh, current_rules

            out = _forward_gin_halo(params, batch, cfg, current_mesh(),
                                    current_rules() or {})
            if out is not None:
                return out
            raise ValueError(
                "halo batch requires a mesh with a 'nodes' axis")
        h = _forward_gin(params, batch, cfg)
        return _mlp(params["readout"], h)
    if cfg.arch == "meshgraphnet":
        return _forward_meshgraphnet(params, batch, cfg)
    if cfg.arch == "egnn":
        h, _pos = _forward_egnn(params, batch, cfg)
        return _mlp(params["readout"], h)
    if cfg.arch == "dimenet":
        return _forward_dimenet(params, batch, cfg)
    raise ValueError(cfg.arch)


# --------------------------------------------------------------------------- #
# loss
# --------------------------------------------------------------------------- #
def _graph_pool(node_vals, graph_ids, n_graphs, node_mask=None):
    if node_mask is not None:
        node_vals = node_vals * node_mask[:, None]
    return jax.ops.segment_sum(node_vals, graph_ids, num_segments=n_graphs)


def loss_fn(params, batch, cfg: GNNConfig):
    out = forward(params, batch, cfg)  # [N, C or d_out]
    nm = batch.get("node_mask")
    if cfg.task == "graph":
        gid = batch["graph_ids"]
        n_graphs = batch["labels"].shape[0]
        pooled = _graph_pool(out, gid, n_graphs, nm)
        if cfg.n_classes:
            lz = jax.nn.logsumexp(pooled.astype(jnp.float32), -1)
            gold = jnp.take_along_axis(
                pooled.astype(jnp.float32),
                batch["labels"][:, None], axis=-1)[:, 0]
            return jnp.mean(lz - gold)
        return jnp.mean(
            (pooled[:, 0] - batch["labels"].astype(jnp.float32)) ** 2
        )
    # node task
    if cfg.n_classes:
        logits = out.astype(jnp.float32)
        lz = jax.nn.logsumexp(logits, -1)
        gold = jnp.take_along_axis(
            logits, batch["labels"][:, None], axis=-1)[:, 0]
        per = lz - gold
        if nm is not None:
            return jnp.sum(per * nm) / jnp.maximum(nm.sum(), 1.0)
        return jnp.mean(per)
    err = (out - batch["labels"].astype(out.dtype)) ** 2
    if nm is not None:
        return (jnp.sum(err.mean(-1) * nm)
                / jnp.maximum(nm.sum(), 1.0)).astype(jnp.float32)
    return jnp.mean(err).astype(jnp.float32)
