"""Model definitions for the assigned architectures (pure JAX)."""
