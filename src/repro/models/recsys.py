"""Factorization Machine recsys model (Rendle, ICDM'10) with huge tables.

y(x) = w0 + Σ_f w[x_f] + Σ_{f<g} ⟨v[x_f], v[x_g]⟩        (x_f categorical)

Implementation notes (kernel_taxonomy §RecSys):

* One fused embedding table ``[n_fields · vocab_per_field, D]`` with static
  per-field offsets (the classic TBE layout); the lookup is ``jnp.take`` —
  JAX has no native EmbeddingBag, so the gather + interaction IS the system.
* The pairwise term uses the O(F·D) sum-square trick; the fused Pallas
  kernel (repro.kernels.fm) is the TPU hot path, the jnp expression the
  XLA / dry-run path.
* Tables are row-sharded over the ``model`` axis ("rows" logical axis);
  lookups from data-parallel batches become all-to-all-ish gathers under
  SPMD — exactly the skewed-access pattern the paper's dynamic partition
  controller rebalances (DESIGN.md §5: Ω = table rows).
* ``retrieval_score``: one query against N candidate vectors as a batched
  dot — FM's interaction with a candidate item factorises into
  ⟨u_sum, v_c⟩ + const(c), so retrieval is a single [N, D] matvec.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.parallel.axes import hint

__all__ = [
    "FMConfig",
    "init_params",
    "forward_logits",
    "loss_fn",
    "retrieval_score",
]


@dataclasses.dataclass(frozen=True)
class FMConfig:
    name: str
    n_fields: int = 39
    vocab_per_field: int = 1_000_000
    embed_dim: int = 10
    dtype: Any = jnp.float32

    @property
    def n_rows(self) -> int:
        return self.n_fields * self.vocab_per_field


def init_params(cfg: FMConfig, key: jax.Array) -> Dict:
    k1, k2 = jax.random.split(key)
    scale = 1.0 / math.sqrt(cfg.embed_dim)
    return {
        "table": (
            jax.random.normal(k1, (cfg.n_rows, cfg.embed_dim), jnp.float32)
            * scale
        ).astype(cfg.dtype),
        "lin_table": jnp.zeros((cfg.n_rows,), cfg.dtype),
        "bias": jnp.zeros((), cfg.dtype),
    }


def _flat_ids(ids: jax.Array, cfg: FMConfig) -> jax.Array:
    """[B, F] per-field ids -> fused-table row ids."""
    offs = (jnp.arange(cfg.n_fields, dtype=ids.dtype)
            * cfg.vocab_per_field)
    return ids + offs[None, :]


def forward_logits(params, ids: jax.Array, cfg: FMConfig) -> jax.Array:
    """ids: [B, F] int32 -> logits [B]."""
    rows = _flat_ids(ids, cfg)
    v = params["table"][rows]  # [B, F, D] — the hot gather
    v = hint(v, "batch", None, None)
    lin = params["lin_table"][rows].sum(-1)  # [B]
    s1 = v.sum(axis=1)
    s2 = (v * v).sum(axis=1)
    pair = 0.5 * (s1 * s1 - s2).sum(-1)
    return (params["bias"] + lin + pair).astype(jnp.float32)


def loss_fn(params, batch, cfg: FMConfig):
    """Binary cross-entropy on click labels."""
    logits = forward_logits(params, batch["ids"], cfg)
    y = batch["labels"].astype(jnp.float32)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )


def retrieval_score(params, user_ids: jax.Array, cand_ids: jax.Array,
                    cfg: FMConfig) -> jax.Array:
    """Score ONE user context against N candidate items (retrieval_cand).

    user_ids: [F-1] context features; cand_ids: [N] ids in the last field.
    FM score vs candidate c = const(u) + w[c] + ⟨Σ_f v_f, v_c⟩, i.e. one
    matvec over the candidate embedding block — no per-candidate loop.
    """
    f = cfg.n_fields
    offs = jnp.arange(f - 1, dtype=user_ids.dtype) * cfg.vocab_per_field
    u_rows = user_ids + offs
    vu = params["table"][u_rows]  # [F-1, D]
    u_sum = vu.sum(0)  # [D]
    u_pair = 0.5 * ((u_sum * u_sum) - (vu * vu).sum(0)).sum()
    u_lin = params["lin_table"][u_rows].sum()

    c_rows = cand_ids + (f - 1) * cfg.vocab_per_field
    vc = params["table"][c_rows]  # [N, D]
    vc = hint(vc, "batch", None)
    scores = (
        params["bias"]
        + u_lin
        + u_pair
        + params["lin_table"][c_rows]
        + vc @ u_sum
    )
    return scores.astype(jnp.float32)
