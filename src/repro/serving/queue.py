"""Request admission queue for the continuous-batching scheduler.

A deliberately boring FIFO: the interesting decisions (admission
validation, lane placement, shedding) live in
:class:`~repro.serving.scheduler.Scheduler`.  What the queue *does* own
is the bookkeeping the pressure signal and the benchmark read —
depth, peak depth, and the waiting time of the oldest entry — so
backlog is observable without walking the deque.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Deque, Dict, Optional

import numpy as np

__all__ = ["Request", "RequestQueue"]


@dataclasses.dataclass
class Request:
    """One rank (personalized-PageRank) request.

    ``cluster`` names the personalization family the RHS drifts around
    — the :class:`~repro.serving.pool.SessionPool` key component that
    makes warm H-state reuse possible across requests of the same
    family.  ``arrival_t`` is scheduler-clock seconds (virtual under
    the benchmark's deterministic clock); ``until`` optionally loosens
    the per-request target_error (the degradation ladder may loosen it
    further).
    """

    request_id: int
    b: np.ndarray
    cluster: int = 0
    arrival_t: float = 0.0
    until: Optional[float] = None
    kind: str = "rank"


class RequestQueue:
    """FIFO of validated :class:`Request`\\ s with backlog accounting."""

    def __init__(self):
        self._q: Deque[Request] = collections.deque()
        self.enqueued = 0
        self.dequeued = 0
        self.depth_peak = 0

    def push(self, req: Request) -> None:
        self._q.append(req)
        self.enqueued += 1
        self.depth_peak = max(self.depth_peak, len(self._q))

    def pop(self) -> Request:
        req = self._q.popleft()
        self.dequeued += 1
        return req

    def push_front(self, req: Request) -> None:
        """Return a popped-but-unplaced request to the head (lane
        saturation race) without recounting it."""
        self._q.appendleft(req)
        self.dequeued -= 1

    def peek(self) -> Optional[Request]:
        return self._q[0] if self._q else None

    @property
    def depth(self) -> int:
        return len(self._q)

    def oldest_wait(self, now: float) -> float:
        """Seconds the head request has been waiting (0 when empty)."""
        return max(now - self._q[0].arrival_t, 0.0) if self._q else 0.0

    def to_jsonable(self) -> Dict:
        return {"depth": self.depth, "depth_peak": self.depth_peak,
                "enqueued": self.enqueued, "dequeued": self.dequeued}

    def __len__(self) -> int:
        return len(self._q)
