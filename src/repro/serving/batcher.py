"""In-flight (continuous) batching over the vmapped frontier kernel.

The lane axis of the ``[C, N]`` batch state is a set of *slots*, not a
batch: each lane carries one request's fluid pair ``(F, H)`` plus its
own threshold, tolerance, and §2.3 op counter.  ``micro()`` advances
every occupied lane a bounded number of frontier rounds through ONE
jitted while-loop dispatch; a lane whose residual certificate clears
its tolerance retires *individually* — its H-column leaves for the
session pool, the lane zeroes, and a queued request is placed into it
on the next tick while the other lanes keep diffusing.  That is the
sglang-style continuous-batching loop with convergence playing the
role of end-of-sequence.

Two width disciplines keep XLA quiet (DESIGN.md §11):

* the lane axis only ever *doubles* (pow2 growth up to ``max_lanes``),
  so a whole serving run touches at most ``log2(max_lanes)`` traces of
  the shared :func:`repro.api.session._batch_fns` kernels;
* placement / clearing use jitted dynamic-slice helpers with the lane
  index as a *traced* argument — admitting into lane 7 and lane 12 is
  the same compiled program.

The kernels are the very ones ``SolverSession.solve_batch`` runs — the
serving tier adds lifecycle, not arithmetic.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.api.session import (_batch_fns, _bucket_width,
                               _edge_device_arrays)

from .queue import Request

__all__ = ["ContinuousBatcher", "LaneInfo", "MicroReport", "RetiredLane"]


@dataclasses.dataclass
class LaneInfo:
    """Host-side view of one occupied lane."""

    request: Request
    admitted_t: float
    pool_hit: bool
    tol: float
    until_eff: float
    round_cap: Optional[int] = None
    rung: str = "nominal"


@dataclasses.dataclass
class RetiredLane:
    """One request leaving its lane (converged or round-capped)."""

    info: LaneInfo
    x: np.ndarray          # served solution (host, float64)
    h_dev: object          # the lane's H column, still device-resident
    residual: float
    ops: int
    rounds: int
    degraded: bool         # round_cap struck before the certificate


@dataclasses.dataclass
class MicroReport:
    """What one ``micro()`` dispatch did."""

    rounds_run: int
    ops_delta: int
    retired: List[RetiredLane]
    occupied: int          # lanes busy during this dispatch
    width: int             # current pow2 lane-axis width
    active_after: int      # lanes still unconverged


class ContinuousBatcher:
    """Slot-level batch state + lifecycle over one graph snapshot."""

    def __init__(self, problem, gamma: float = 1.2, max_lanes: int = 64,
                 min_lanes: int = 4):
        if max_lanes < 1:
            raise ValueError(f"max_lanes must be >= 1, got {max_lanes}")
        self.gamma = float(gamma)
        self.max_lanes = _bucket_width(max_lanes)
        self.min_lanes = min(_bucket_width(min_lanes), self.max_lanes)
        self.graph_switches = 0
        self._bind(problem)
        # lifetime accounting (the bench's occupancy + padding story)
        self.ticks = 0
        self.rounds_total = 0
        self.ops_total = 0
        self.lane_rounds_total = 0   # occupied-lane rounds actually used
        self.width_rounds_total = 0  # lane-axis slots paid for
        self.retired_total = 0

    # ------------------------------------------------------------------ #
    # state plumbing
    # ------------------------------------------------------------------ #
    def _bind(self, problem) -> None:
        """(Re)build device edge arrays + empty lane state for
        ``problem``'s current graph snapshot."""
        import jax.numpy as jnp

        self.problem = problem
        self.n = problem.n
        (self.src, self.dst, self.wgt, self.w,
         self.dang) = _edge_device_arrays(problem)
        self.width = self.min_lanes
        self.lanes: List[Optional[LaneInfo]] = [None] * self.width
        self.f = jnp.zeros((self.width, self.n))
        self.h = jnp.zeros_like(self.f)
        self.t = jnp.zeros((self.width,), dtype=self.f.dtype)
        self.ops = jnp.zeros((self.width,), dtype=jnp.int32)
        self.lane_rounds = jnp.zeros((self.width,), dtype=jnp.int32)
        self._tol_cols = np.zeros(self.width, dtype=np.float64)
        self._ops_host = np.zeros(self.width, dtype=np.int64)

    def _grow(self) -> None:
        import jax.numpy as jnp

        new = min(self.width * 2, self.max_lanes)
        if new == self.width:
            return
        pad = new - self.width
        self.f = jnp.concatenate(
            [self.f, jnp.zeros((pad, self.n), dtype=self.f.dtype)])
        self.h = jnp.concatenate(
            [self.h, jnp.zeros((pad, self.n), dtype=self.h.dtype)])
        self.t = jnp.concatenate(
            [self.t, jnp.zeros((pad,), dtype=self.t.dtype)])
        self.ops = jnp.concatenate(
            [self.ops, jnp.zeros((pad,), dtype=self.ops.dtype)])
        self.lane_rounds = jnp.concatenate(
            [self.lane_rounds,
             jnp.zeros((pad,), dtype=self.lane_rounds.dtype)])
        self.lanes.extend([None] * pad)
        self._tol_cols = np.concatenate(
            [self._tol_cols, np.zeros(pad)])
        self._ops_host = np.concatenate(
            [self._ops_host, np.zeros(pad, dtype=np.int64)])
        self.width = new

    # ------------------------------------------------------------------ #
    # lifecycle
    # ------------------------------------------------------------------ #
    @property
    def occupied(self) -> int:
        return sum(1 for la in self.lanes if la is not None)

    @property
    def has_capacity(self) -> bool:
        return (any(la is None for la in self.lanes)
                or self.width < self.max_lanes)

    def free_lane(self) -> Optional[int]:
        for i, la in enumerate(self.lanes):
            if la is None:
                return i
        if self.width < self.max_lanes:
            prev = self.width
            self._grow()
            return prev
        return None

    def admit(self, req: Request, now: float, tol: float,
              until_eff: float, h_seed=None,
              round_cap: Optional[int] = None,
              rung: str = "nominal") -> Optional[int]:
        """Place ``req`` into a free lane (growing the pow2 width if
        needed).  ``h_seed`` is a pooled device H-column — the §2.2
        warm start runs on device either way (``h_seed=None`` seeds
        H=0, which degenerates to the cold path F=B).  Returns the
        lane index, or None when saturated at ``max_lanes``."""
        import jax.numpy as jnp

        lane = self.free_lane()
        if lane is None:
            return None
        b_col = jnp.asarray(req.b, dtype=self.f.dtype)
        h_col = (jnp.zeros((self.n,), dtype=self.f.dtype)
                 if h_seed is None else jnp.asarray(h_seed,
                                                    dtype=self.f.dtype))
        fns = _batch_fns()
        f_col, t_col = fns["warm"](b_col, h_col, self.src, self.dst,
                                   self.wgt, self.w)
        (self.f, self.h, self.t, self.ops,
         self.lane_rounds) = fns["place"](
            self.f, self.h, self.t, self.ops, self.lane_rounds, lane,
            f_col, h_col, t_col)
        self._tol_cols[lane] = tol
        self._ops_host[lane] = 0
        self.lanes[lane] = LaneInfo(
            request=req, admitted_t=now, pool_hit=h_seed is not None,
            tol=float(tol), until_eff=float(until_eff),
            round_cap=round_cap, rung=rung)
        return lane

    def micro(self, budget: int) -> MicroReport:
        """One continuous-batching micro-step: up to ``budget`` frontier
        rounds for every active lane in a single compiled dispatch,
        then per-lane retirement checks."""
        import jax.numpy as jnp

        occupied = self.occupied
        if occupied == 0:
            return MicroReport(0, 0, [], 0, self.width, 0)
        fns = _batch_fns()
        tol_dev = jnp.asarray(self._tol_cols, dtype=self.f.dtype)
        ops_before = int(self._ops_host.sum())
        (self.f, self.h, self.t, self.ops, self.lane_rounds,
         rounds_run) = fns["tick"](
            self.f, self.h, self.t, self.ops, self.lane_rounds, tol_dev,
            budget, self.src, self.dst, self.wgt, self.w, self.dang,
            self.gamma)
        resid = np.asarray(jnp.abs(self.f).sum(axis=1),
                           dtype=np.float64)
        self._ops_host = np.asarray(self.ops, dtype=np.int64)
        lane_rounds = np.asarray(self.lane_rounds, dtype=np.int64)
        rounds_run = int(rounds_run)
        ops_delta = int(self._ops_host.sum()) - ops_before

        retired: List[RetiredLane] = []
        active_after = 0
        for lane, info in enumerate(self.lanes):
            if info is None:
                continue
            converged = resid[lane] <= self._tol_cols[lane]
            capped = (info.round_cap is not None
                      and lane_rounds[lane] >= info.round_cap)
            if not (converged or capped):
                active_after += 1
                continue
            h_dev = self.h[lane]
            retired.append(RetiredLane(
                info=info,
                x=np.asarray(h_dev, dtype=np.float64),
                h_dev=h_dev,
                residual=float(resid[lane]),
                ops=int(self._ops_host[lane]),
                rounds=int(lane_rounds[lane]),
                degraded=bool(capped and not converged),
            ))
            self.f, self.h = fns["clear"](self.f, self.h, lane)
            self.lanes[lane] = None
            self._tol_cols[lane] = 0.0

        self.ticks += 1
        self.rounds_total += rounds_run
        self.ops_total += ops_delta
        self.lane_rounds_total += occupied * rounds_run
        self.width_rounds_total += self.width * rounds_run
        self.retired_total += len(retired)
        return MicroReport(rounds_run, ops_delta, retired, occupied,
                           self.width, active_after)

    def graph_switched(self, problem) -> None:
        """Rebind to a patched graph snapshot.  Only legal at a drain
        barrier — in-flight fluid was diffused through the old P and
        its §2.3 accounting would silently go stale."""
        if self.occupied:
            raise RuntimeError(
                f"graph_switched with {self.occupied} lanes in flight; "
                "drain first")
        self.graph_switches += 1
        self._bind(problem)

    # ------------------------------------------------------------------ #
    # reporting
    # ------------------------------------------------------------------ #
    @property
    def mean_occupancy(self) -> float:
        """Occupied-lane fraction of the lane-axis slots actually paid
        for across all executed rounds (the padding-waste complement)."""
        return (self.lane_rounds_total / self.width_rounds_total
                if self.width_rounds_total else 0.0)

    def to_jsonable(self) -> Dict:
        return {"width": self.width, "max_lanes": self.max_lanes,
                "occupied": self.occupied, "ticks": self.ticks,
                "rounds_total": self.rounds_total,
                "ops_total": self.ops_total,
                "retired_total": self.retired_total,
                "mean_occupancy": round(self.mean_occupancy, 4),
                "graph_switches": self.graph_switches}
