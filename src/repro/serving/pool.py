"""Device-resident pool of warm H-states, keyed by graph version and
personalization cluster.

The §2.2 residual identity ``F' = B' − H + P·H`` makes *any* held H a
valid warm start, and the closer H's provenance is to the incoming RHS
the smaller |F'| — so the pool keys on ``(store_version,
personalization-cluster)``: requests of the same family re-enter the
lane loop with most of their diffusion already banked (≈88% push
savings at 2% drift, PR 3), while a graph delta bumps
``store_version`` and every pre-delta entry *naturally misses* — the
same staleness discipline the PR-4 checkpoint guard enforces, applied
to pooled fluid instead of persisted fluid.

Entries hold device arrays (jax buffers); nothing round-trips through
host numpy on the hit path.  Capacity is bounded with LRU eviction —
an evicted cluster simply pays the cold path again, it is never wrong.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Dict, Optional, Tuple

__all__ = ["PoolEntry", "SessionPool"]


@dataclasses.dataclass
class PoolEntry:
    """One pooled H-state: the device-resident history vector plus the
    provenance the benchmark reports (how much work the entry banks)."""

    h: object  # [N] device array
    store_version: int
    cluster: int
    ops_banked: int = 0
    puts: int = 0


class SessionPool:
    """LRU map ``(store_version, cluster) -> PoolEntry``.

    ``get`` refreshes recency (a hit is a use); ``put`` inserts or
    refreshes and evicts the least-recently-used entry beyond
    ``capacity``.  ``invalidate`` drops entries from other store
    versions in bulk — optional hygiene after a graph delta: stale
    entries can never hit again (the key includes the version), so
    invalidation only frees device memory earlier.
    """

    def __init__(self, capacity: int = 32):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self._entries: "collections.OrderedDict[Tuple[int, int], PoolEntry]" \
            = collections.OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.invalidations = 0

    def _key(self, store_version, cluster: int) -> Tuple[int, int]:
        # a store-less Problem (no GraphStore) has version None: its
        # graph can never drift, so it keys as the constant version 0
        return (0 if store_version is None else int(store_version),
                int(cluster))

    def get(self, store_version: int, cluster: int) -> Optional[PoolEntry]:
        entry = self._entries.get(self._key(store_version, cluster))
        if entry is None:
            self.misses += 1
            return None
        self._entries.move_to_end(self._key(store_version, cluster))
        self.hits += 1
        return entry

    def put(self, store_version: int, cluster: int, h,
            ops_banked: int = 0) -> PoolEntry:
        key = self._key(store_version, cluster)
        entry = self._entries.get(key)
        if entry is None:
            entry = PoolEntry(h=h, store_version=key[0],
                              cluster=int(cluster))
            self._entries[key] = entry
        else:
            entry.h = h
        entry.ops_banked += int(ops_banked)
        entry.puts += 1
        self._entries.move_to_end(key)
        while len(self._entries) > self.capacity:
            self._entries.popitem(last=False)
            self.evictions += 1
        return entry

    def invalidate(self, keep_version: Optional[int] = None) -> int:
        """Drop entries whose version != ``keep_version`` (all entries
        when None).  Returns the number dropped."""
        if keep_version is None:
            dropped = len(self._entries)
            self._entries.clear()
        else:
            stale = [k for k in self._entries if k[0] != int(keep_version)]
            for k in stale:
                del self._entries[k]
            dropped = len(stale)
        self.invalidations += dropped
        return dropped

    def __contains__(self, key: Tuple[int, int]) -> bool:
        return self._key(*key) in self._entries

    def __len__(self) -> int:
        return len(self._entries)

    @property
    def device_buffers(self) -> int:
        return len(self._entries)

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def to_jsonable(self) -> Dict:
        return {"capacity": self.capacity, "entries": len(self._entries),
                "hits": self.hits, "misses": self.misses,
                "hit_rate": round(self.hit_rate, 4),
                "evictions": self.evictions,
                "invalidations": self.invalidations}
