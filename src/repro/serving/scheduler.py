"""The continuous-batching scheduler: queue → lanes → pool, under
pressure control.

One :meth:`Scheduler.step` is the whole serving policy, in order:

1. **arrivals** — open-loop requests whose ``arrival_t`` has passed
   move from the future list into the FIFO;
2. **graph updates** — pending deltas flush at a *drain barrier*
   (stop admitting, let lanes finish, swap P, bump ``store_version``)
   unless the active rung defers them (``defer_cap`` bounds staleness);
3. **admission** — queued requests fill free lanes; the
   :class:`~repro.serving.pool.SessionPool` is consulted keyed by
   ``(store_version, cluster)`` and a hit seeds the lane's H on device
   (the §2.2 warm start), a miss seeds H=0;
4. **micro-step** — one bounded-round dispatch of the shared batch
   kernel advances every active lane; the virtual clock charges the
   executed rounds and §2.3 edge pushes;
5. **retirement** — converged (or round-capped) lanes serve their
   response, bank their H back into the pool, and free the slot;
6. **pressure** — a ``queue-depth`` :class:`~repro.balance.LoadSignal`
   feeds the :class:`~repro.resilience.DegradationLadder`: sustained
   backlog walks down rungs (defer updates → loosen target → round
   caps) and *every* request is still served — overload sheds quality,
   never requests (``dropped`` is structurally zero; the bench gates
   it at exactly zero).

Determinism: with ``arrival_t`` supplied by the caller and the default
virtual clock, a serving run is a pure function of (problem, request
stream, knobs) — same schedule, same §2.3 op counts, same event log.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.balance import LoadSignal
from repro.resilience import (DegradationLadder, EventLog, Quarantine,
                              RequestRejected, SERVE_RUNGS,
                              validate_graph_update, validate_rhs)
from repro.balance.policies import PressurePolicy

from .batcher import ContinuousBatcher
from .pool import SessionPool
from .queue import Request, RequestQueue

__all__ = ["Scheduler", "ServedRequest"]


@dataclasses.dataclass
class ServedRequest:
    """One completed rank request, for the caller and the bench."""

    request_id: int
    cluster: int
    x: np.ndarray
    residual: float
    converged: bool
    degraded: bool              # round-capped before the certificate
    rung: str
    until_eff: float            # target_error actually served
    pool_hit: bool
    ops: int
    rounds: int
    wait_s: float               # arrival -> lane placement
    latency_s: float            # arrival -> response


class Scheduler:
    """Continuous-batching rank server over one :class:`repro.Problem`.

    ``submit`` validates at the door (poison is quarantined and raises
    :class:`~repro.resilience.RequestRejected` — the stream continues);
    ``step`` runs one scheduling round; ``run_until_idle`` drives the
    loop until every accepted request is served.  Completed requests
    accumulate in ``results`` in retirement order.
    """

    def __init__(self, problem, *, max_lanes: int = 64,
                 min_lanes: int = 4, rounds_per_tick: int = 32,
                 pool_capacity: int = 32, gamma: float = 1.2,
                 ladder: Optional[DegradationLadder] = None,
                 deadline_s: float = 1.0, queue_cap: int = 64,
                 op_rate: float = 2e6, round_overhead_s: float = 2e-4,
                 defer_cap: int = 16, log: Optional[EventLog] = None):
        self.problem = problem
        self.batcher = ContinuousBatcher(problem, gamma=gamma,
                                         max_lanes=max_lanes,
                                         min_lanes=min_lanes)
        self.pool = SessionPool(capacity=pool_capacity)
        self.queue = RequestQueue()
        self.ladder = ladder if ladder is not None else DegradationLadder(
            rungs=SERVE_RUNGS, policy=PressurePolicy())
        self.deadline_s = float(deadline_s)
        self.queue_cap = int(queue_cap)
        self.rounds_per_tick = int(rounds_per_tick)
        self.op_rate = float(op_rate)
        self.round_overhead_s = float(round_overhead_s)
        self.defer_cap = int(defer_cap)
        self.vt = 0.0
        self.log = log if log is not None else EventLog(
            clock=lambda: self.vt)
        self.quarantine = Quarantine()
        self.results: List[ServedRequest] = []
        self.dropped = 0            # structurally zero; reported anyway
        self.deferred_updates: List[object] = []
        self.applied_updates = 0
        self.update_conflicts = 0
        self._future: List[Request] = []   # arrival_t-sorted backlog
        self._draining = False
        self._next_id = 0
        self._steps = 0
        self._latencies: List[float] = []
        self.pool_hits_served = 0

    # ------------------------------------------------------------------ #
    # intake
    # ------------------------------------------------------------------ #
    @property
    def now(self) -> float:
        return self.vt

    def submit(self, b, cluster: int = 0,
               arrival_t: Optional[float] = None,
               request_id: Optional[int] = None,
               until: Optional[float] = None) -> int:
        """Validate and accept one rank request.  Raises
        :class:`RequestRejected` on poison (after quarantining it);
        the scheduler survives and keeps serving."""
        rid = request_id if request_id is not None else self._next_id
        self._next_id = max(self._next_id, rid) + 1
        try:
            b = validate_rhs(b, self.problem.n)
        except RequestRejected as e:
            self.quarantine.record(rid, e.reason)
            self.log.record("request_rejected", request_id=rid,
                            reason=e.reason)
            raise
        t_arr = float(arrival_t) if arrival_t is not None else self.vt
        req = Request(request_id=rid, b=b, cluster=int(cluster),
                      arrival_t=t_arr, until=until)
        if t_arr > self.vt:
            self._future.append(req)
            self._future.sort(key=lambda r: (r.arrival_t, r.request_id))
        else:
            self.queue.push(req)
        return rid

    def submit_update(self, delta,
                      store_version: Optional[int] = None) -> None:
        """Validate and queue one graph delta.  Applied at the next
        drain barrier (immediately if the rung allows; deferred while
        the ladder says so, bounded by ``defer_cap``).  Raises
        :class:`RequestRejected` on poison (after quarantining)."""
        store = self.problem.graph
        try:
            validate_graph_update(
                store, delta, store_version=store_version,
                queued=len(self.deferred_updates),
                check_membership=not self.deferred_updates)
        except RequestRejected as e:
            self.quarantine.record(f"update@{len(self.deferred_updates)}",
                                   e.reason)
            self.log.record("request_rejected", request_id="update",
                            reason=e.reason)
            raise
        self.deferred_updates.append(delta)

    # ------------------------------------------------------------------ #
    # the scheduling loop
    # ------------------------------------------------------------------ #
    def _admit_due_arrivals(self) -> None:
        due = 0
        for req in self._future:
            if req.arrival_t > self.vt:
                break
            self.queue.push(req)
            due += 1
        if due:
            del self._future[:due]

    def _flush_updates_at_barrier(self) -> None:
        """Drain-then-apply: stop admissions once a flush is wanted,
        swap P only when no fluid is in flight."""
        rung = self.ladder.rung
        want_flush = self.deferred_updates and (
            not rung.defer_updates
            or len(self.deferred_updates) >= self.defer_cap)
        if want_flush:
            self._draining = True
        if not self._draining:
            return
        if self.batcher.occupied:
            return  # lanes still draining toward the barrier
        store = self.problem.graph
        for delta in self.deferred_updates:
            try:
                store.apply_delta(delta)
                self.applied_updates += 1
            except Exception as e:  # conflict after deferral: quarantine
                self.update_conflicts += 1
                self.quarantine.record("update", "update-conflict")
                self.log.record("update_conflict",
                                detail=str(e)[:120])
        self.deferred_updates = []
        self.problem = self.problem.with_graph(store)
        self.batcher.graph_switched(self.problem)
        # stale pool entries can never hit again (the key embeds the
        # version) — invalidation just frees their device buffers now
        freed = self.pool.invalidate(
            keep_version=self.problem.store_version)
        self.log.record("update_applied",
                        version=self.problem.store_version,
                        pool_freed=freed)
        self._draining = False

    def _admit(self) -> None:
        rung = self.ladder.rung
        while self.queue.depth and not self._draining:
            if not self.batcher.has_capacity:
                break
            req = self.queue.pop()
            te = (req.until if req.until is not None
                  else self.problem.target_error)
            until_eff = te * rung.target_scale
            tol = until_eff * self.problem.eps
            entry = self.pool.get(self.problem.store_version, req.cluster)
            lane = self.batcher.admit(
                req, now=self.vt, tol=tol, until_eff=until_eff,
                h_seed=None if entry is None else entry.h,
                round_cap=rung.round_cap, rung=rung.name)
            if lane is None:  # saturated race; requeue at the head
                self.queue.push_front(req)
                break
            self.log.record("admit", request_id=req.request_id,
                            lane=lane, pool_hit=entry is not None,
                            rung=rung.name)

    def _retire(self, retired) -> None:
        for r in retired:
            req = r.info.request
            latency = self.vt - req.arrival_t
            self.pool.put(self.problem.store_version, req.cluster,
                          r.h_dev, ops_banked=r.ops)
            served = ServedRequest(
                request_id=req.request_id, cluster=req.cluster, x=r.x,
                residual=r.residual,
                converged=not r.degraded,
                degraded=r.degraded or r.info.rung != "nominal",
                rung=r.info.rung, until_eff=r.info.until_eff,
                pool_hit=r.info.pool_hit, ops=r.ops, rounds=r.rounds,
                wait_s=r.info.admitted_t - req.arrival_t,
                latency_s=latency)
            self.results.append(served)
            self._latencies.append(latency)
            if r.info.pool_hit:
                self.pool_hits_served += 1
            self.log.record("request_served",
                            request_id=req.request_id,
                            latency=round(latency, 6), ops=r.ops,
                            degraded=served.degraded, rung=r.info.rung)

    def _observe_pressure(self) -> None:
        signal = LoadSignal.from_queue(
            oldest_wait_s=self.queue.oldest_wait(self.vt),
            deadline_s=self.deadline_s,
            queue_depth=self.queue.depth + len(self._future),
            queue_cap=self.queue_cap, step=self._steps)
        before = self.ladder.rung.name
        executed = self.ladder.observe(signal)
        if executed > 0:
            self.log.record("degrade", rung=self.ladder.rung.name,
                            pressure=float(signal.values[0]))
        elif executed < 0:
            self.log.record("recover", rung=self.ladder.rung.name,
                            from_rung=before,
                            pressure=float(signal.values[0]))

    def step(self) -> int:
        """One scheduling round; returns the number of requests served
        this step."""
        self._steps += 1
        self._admit_due_arrivals()
        if (self.deferred_updates and not self._future
                and not self.queue.depth and not self.batcher.occupied):
            # nothing left to serve: a defer rung must not starve the
            # update stream forever
            self._draining = True
        self._flush_updates_at_barrier()
        self._admit()
        report = self.batcher.micro(self.rounds_per_tick)
        self.vt += (report.rounds_run * self.round_overhead_s
                    + report.ops_delta / self.op_rate)
        self._retire(report.retired)
        if (report.occupied == 0 and not self.queue.depth
                and self._future):
            # idle gap in the open-loop schedule: jump to next arrival
            self.vt = max(self.vt, self._future[0].arrival_t)
        self._observe_pressure()
        return len(report.retired)

    def run_until_idle(self, max_steps: int = 1_000_000) -> int:
        """Drive ``step`` until every accepted request (and pending
        update) is finished.  Returns requests served."""
        served = 0
        for _ in range(max_steps):
            if not (self._future or self.queue.depth
                    or self.batcher.occupied or self.deferred_updates):
                break
            served += self.step()
        else:
            raise RuntimeError(
                f"run_until_idle did not converge in {max_steps} steps "
                f"(queue={self.queue.depth}, lanes={self.batcher.occupied})")
        return served

    # ------------------------------------------------------------------ #
    # reporting
    # ------------------------------------------------------------------ #
    def latency_percentiles(self) -> Dict[str, float]:
        if not self._latencies:
            return {"p50": 0.0, "p99": 0.0, "mean": 0.0}
        arr = np.asarray(self._latencies)
        return {"p50": float(np.percentile(arr, 50)),
                "p99": float(np.percentile(arr, 99)),
                "mean": float(arr.mean())}

    def to_jsonable(self) -> Dict:
        return {
            "steps": self._steps,
            "served": len(self.results),
            "dropped": self.dropped,
            "pool": self.pool.to_jsonable(),
            "queue": self.queue.to_jsonable(),
            "batcher": self.batcher.to_jsonable(),
            "quarantine": self.quarantine.to_jsonable(),
            "applied_updates": self.applied_updates,
            "update_conflicts": self.update_conflicts,
            "rung": self.ladder.rung.name,
            "latency": self.latency_percentiles(),
            "events": self.log.counts(),
        }
