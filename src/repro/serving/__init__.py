"""Continuous-batching serving tier for personalized-rank requests.

The asynchronous-distribution companions (arXiv:1202.6168,
arXiv:1301.3007) frame the serving regime this package targets: many
concurrent diffusion computations sharing one matrix, where throughput
comes from keeping the hardware saturated, not from any per-request
trick.  Concretely (DESIGN.md §11):

* :class:`Scheduler` — queue → lanes → pool control loop with
  admission control, drain-barrier graph updates, and
  pressure-ladder overload shedding;
* :class:`ContinuousBatcher` — slot-level in-flight batching through
  the same jitted kernels ``SolverSession.solve_batch`` runs (pow2
  lane buckets, per-lane convergence, per-lane §2.3 op accounting);
* :class:`SessionPool` — device-resident warm H-states keyed by
  ``(store_version, personalization-cluster)`` with LRU eviction;
* :class:`RequestQueue` / :class:`Request` — FIFO with the backlog
  accounting the ``queue-depth`` LoadSignal reads.

:func:`solo_reference` is the benchmark's sequential twin: the exact
pre-batching ``serve.py rank`` semantics (one warm-started
SolverSession chained across requests), for QPS baselines and
per-request parity checks.
"""
from .batcher import ContinuousBatcher, LaneInfo, MicroReport, RetiredLane
from .pool import PoolEntry, SessionPool
from .queue import Request, RequestQueue
from .scheduler import Scheduler, ServedRequest

__all__ = [
    "ContinuousBatcher",
    "LaneInfo",
    "MicroReport",
    "PoolEntry",
    "Request",
    "RequestQueue",
    "RetiredLane",
    "Scheduler",
    "ServedRequest",
    "SessionPool",
    "solo_reference",
]


def solo_reference(problem, bs, method: str = "frontier:segment_sum",
                   until=None):
    """Serve ``bs`` ([N, C]) strictly sequentially — the pre-batching
    ``serve.py rank`` path: one session, warm-started per request.

    Returns ``(x [N, C] float64, ops [C], wall_s)``.  This is the
    benchmark's QPS baseline and the parity reference for the batched
    path (both converge to the same tolerance, so per-request solutions
    agree within ~2× the served target_error in exact arithmetic).
    """
    import time

    import numpy as np

    from repro.api.session import SolverSession

    bs = np.asarray(bs, dtype=np.float64)
    xs = np.zeros_like(bs)
    ops = np.zeros(bs.shape[1], dtype=np.int64)
    t0 = time.perf_counter()
    session = SolverSession(problem, method=method)
    for c in range(bs.shape[1]):
        session.warm_start(bs[:, c])
        rep = session.solve(until=until)
        xs[:, c] = rep.x
        ops[c] = rep.n_ops
    return xs, ops, time.perf_counter() - t0
