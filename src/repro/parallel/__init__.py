from .axes import (  # noqa: F401
    axis_rules,
    current_rules,
    hint,
    logical_to_spec,
)
from .sharding import (  # noqa: F401
    input_sharding_specs,
    param_sharding_specs,
)
