"""Parameter / input PartitionSpec rules per model family.

Key-name-driven: each family maps param-leaf names to logical axis tuples;
:func:`repro.parallel.axes.logical_to_spec` resolves them against the active
mesh rules.  Unknown leaves fall back to replicated — visible in the dry-run
memory analysis if something important is missed.

LM weights end up 2D-sharded (FSDP over ``data`` × TP over ``model``), the
optimizer state shards identically (ZeRO-3 style), MoE expert tensors shard
on the expert dim (EP), recsys tables shard on rows, GNN inputs shard on the
edge/node dims.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .axes import AxisVal, logical_to_spec

__all__ = ["param_sharding_specs", "input_sharding_specs", "LM_PARAM_AXES",
           "GNN_PARAM_AXES", "RECSYS_PARAM_AXES"]

# --------------------------------------------------------------------------- #
# logical axes by param-leaf name
# --------------------------------------------------------------------------- #
LM_PARAM_AXES: Dict[str, Tuple[Optional[str], ...]] = {
    "embed": ("vocab", "embed"),
    "lm_head": ("embed", "vocab"),
    "final_norm": (None,),
    "ln1": (None, None),
    "ln2": (None, None),
    "wq": (None, "embed", "heads"),
    "wk": (None, "embed", "heads"),
    "wv": (None, "embed", "heads"),
    "wo": (None, "heads", "embed"),
    "bq": (None, "heads"),
    "bk": (None, "heads"),
    "bv": (None, "heads"),
    "w1": (None, "embed", "mlp"),
    "w3": (None, "embed", "mlp"),
    "w2": (None, "mlp", "embed"),
    "router": (None, None, None),
    "ew1": (None, "expert", "embed", None),
    "ew3": (None, "expert", "embed", None),
    "ew2": (None, "expert", None, "embed"),
    "sw1": (None, "embed", "mlp"),
    "sw3": (None, "embed", "mlp"),
    "sw2": (None, "mlp", "embed"),
}

GNN_PARAM_AXES: Dict[str, Tuple[Optional[str], ...]] = {
    # MLP weights: modest sizes -> TP over 'model' on the wide dim
    "w_in": ("feat", "mlp"),
    "w_out": ("mlp", "feat"),
    "w": ("feat", "mlp"),
    "b": (None,),
    "scale": (None,),
}

RECSYS_PARAM_AXES: Dict[str, Tuple[Optional[str], ...]] = {
    "table": ("rows", None),
    "lin_table": ("rows",),
    "bias": (),
    "w": (None, "mlp"),
}


def _sanitize(spec: P, shape, axis_sizes: Optional[Dict[str, int]]) -> P:
    """Drop mesh axes whose shard count does not divide the dim size.

    Real inputs are padded to divisible sizes in the configs; this is the
    safety net for leftovers (e.g. a [64, 1] readout or a 49155 vocab)."""
    if axis_sizes is None or shape is None:
        return spec
    dims = []
    for i, entry in enumerate(spec):
        if entry is None or i >= len(shape):
            dims.append(entry)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        kept = []
        size = shape[i]
        for a in axes:
            n = axis_sizes.get(a, 1)
            if n > 1 and size % n == 0:
                kept.append(a)
                size //= n
        if not kept:
            dims.append(None)
        elif len(kept) == 1:
            dims.append(kept[0])
        else:
            dims.append(tuple(kept))
    return P(*dims)


def _leaf_spec(name: str, leaf, axes_map, rules,
               axis_sizes: Optional[Dict[str, int]] = None) -> P:
    la = axes_map.get(name)
    ndim = len(leaf.shape) if hasattr(leaf, "shape") else 0
    if la is None or len(la) != ndim:
        # default: replicate (norms/scalars) — or pad logical tuple
        if la is not None and len(la) < ndim:
            la = (None,) * (ndim - len(la)) + tuple(la)
        else:
            return P()
    spec = logical_to_spec(la, rules)
    return _sanitize(spec, getattr(leaf, "shape", None), axis_sizes)


def param_sharding_specs(
    params: Any,
    family: str,
    rules: Dict[str, AxisVal],
    mesh: Optional[Mesh] = None,
    axis_sizes: Optional[Dict[str, int]] = None,
):
    """PartitionSpec (or NamedSharding if mesh given) tree matching params."""
    axes_map = {
        "lm": LM_PARAM_AXES,
        "gnn": GNN_PARAM_AXES,
        "recsys": RECSYS_PARAM_AXES,
    }[family]
    if axis_sizes is None and mesh is not None:
        axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for path, leaf in flat:
        name = None
        for entry in reversed(path):
            if isinstance(entry, jax.tree_util.DictKey):
                name = str(entry.key)
                break
        spec = _leaf_spec(name or "", leaf, axes_map, rules, axis_sizes)
        specs.append(NamedSharding(mesh, spec) if mesh is not None else spec)
    return jax.tree_util.tree_unflatten(treedef, specs)


def input_sharding_specs(
    inputs: Any,
    logical: Dict[str, Tuple[Optional[str], ...]],
    rules: Dict[str, AxisVal],
    mesh: Optional[Mesh] = None,
    axis_sizes: Optional[Dict[str, int]] = None,
):
    """Specs for an input dict given {key: logical axes} annotations."""
    if axis_sizes is None and mesh is not None:
        axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def one(key, leaf):
        la = logical.get(key, None)
        if la is None:
            spec = P()
        else:
            spec = logical_to_spec(la, rules)
            spec = _sanitize(spec, getattr(leaf, "shape", None), axis_sizes)
        return NamedSharding(mesh, spec) if mesh is not None else spec

    return {k: one(k, v) for k, v in inputs.items()}
