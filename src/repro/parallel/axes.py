"""Logical-axis sharding hints (mesh-agnostic model code).

Models annotate activations with *logical* axis names
(``hint(x, "batch", "seq", "embed")``); a context manager installs the
mapping from logical names to physical mesh axes.  Outside any mapping the
hint is a no-op, so the same model code runs in single-device tests and in
the 512-chip dry-run unchanged.

Default production mapping (DESIGN.md §6):

    batch  -> ("pod", "data")   (outer DP over pods, inner DP in-pod)
    embed  -> "data"            (FSDP shard of the hidden dim where useful)
    heads  -> "model"           (tensor parallel attention)
    mlp    -> "model"           (tensor parallel FFN)
    expert -> "model"           (expert parallel MoE)
    vocab  -> "model"           (sharded embed/unembed + chunked CE)
    edges  -> ("pod", "data", "model")  (GNN edge-parallel over everything)
    nodes  -> ("pod", "data")   (GNN node shards)
    rows   -> "model"           (recsys embedding-table row shards)
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import PartitionSpec as P

__all__ = ["axis_rules", "current_rules", "hint", "logical_to_spec",
           "DEFAULT_RULES", "SINGLE_AXIS_RULES"]

AxisVal = Union[None, str, Tuple[str, ...]]

DEFAULT_RULES: Dict[str, AxisVal] = {
    "batch": ("pod", "data"),
    "seq": None,
    "embed": "data",  # PARAM dim only (FSDP); activations use act_embed
    "act_embed": None,
    # Megatron-SP style: residual-stream seq dim sharded over TP between
    # layers (all-gathered inside attention/MLP automatically by SPMD) —
    # cuts the remat carry by the TP degree.
    "act_seq": "model",
    "heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "expert": "model",
    "vocab": "model",
    "layers": None,
    "edges": ("pod", "data", "model"),
    "nodes": ("pod", "data"),
    "feat": None,
    "rows": "model",
    "kv_seq": "model",  # long-context decode: sequence-sharded KV cache
}

# single-pod mapping: identical but without the "pod" axis
SINGLE_AXIS_RULES: Dict[str, AxisVal] = {
    **DEFAULT_RULES,
    "batch": "data",
    "edges": ("data", "model"),
    "nodes": "data",
}

_state = threading.local()


def current_rules() -> Optional[Dict[str, AxisVal]]:
    return getattr(_state, "rules", None)


def current_mesh():
    """Mesh installed by axis_rules (for shard_map-based layers)."""
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def axis_rules(rules: Dict[str, AxisVal], mesh=None):
    prev = current_rules()
    prev_mesh = current_mesh()
    _state.rules = rules
    _state.mesh = mesh
    try:
        yield
    finally:
        _state.rules = prev
        _state.mesh = prev_mesh


def logical_to_spec(names: Sequence[Optional[str]],
                    rules: Optional[Dict[str, AxisVal]] = None) -> P:
    rules = rules if rules is not None else (current_rules() or {})
    return P(*[rules.get(n) if n else None for n in names])


def hint(x: jax.Array, *names: Optional[str]) -> jax.Array:
    """with_sharding_constraint by logical names; no-op without rules."""
    rules = current_rules()
    if not rules:
        return x
    spec = logical_to_spec(names, rules)
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:
        return x  # no mesh context (e.g. plain CPU test) — ignore
