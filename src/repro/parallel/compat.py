"""Version-tolerant shims over moving JAX APIs.

The repo targets the baked-in toolchain (jax 0.4.x at the time of
writing) but keeps working as call sites migrate:

* ``shard_map`` — lives at ``jax.experimental.shard_map.shard_map`` on
  0.4.x (kwarg ``check_rep``) and at ``jax.shard_map`` on newer releases
  (kwarg ``check_vma``).  :func:`shard_map` accepts either spelling of
  the replication-check kwarg and forwards whichever the installed
  version understands.
* ``make_mesh`` — newer JAX grew an ``axis_types=`` kwarg (and the
  ``jax.sharding.AxisType`` enum).  :func:`make_mesh` forwards it when
  supported and silently drops it otherwise (0.4.x meshes are always
  "auto" in the relevant sense).

Import from here instead of touching ``jax.shard_map`` directly — the
bare attribute access raises ``AttributeError`` on 0.4.x.
"""
from __future__ import annotations

import inspect

import jax

__all__ = ["shard_map", "make_mesh", "mesh_axis_types_kw",
           "cost_analysis_dict"]


def cost_analysis_dict(compiled) -> dict:
    """``compiled.cost_analysis()`` as a flat dict across JAX versions.

    0.4.x returns a list with one dict per partition; newer JAX returns
    the dict directly.  Missing analysis normalizes to ``{}``.
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}


if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
else:  # jax <= 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

_SM_PARAMS = set(inspect.signature(_shard_map).parameters)
_CHECK_KW = "check_vma" if "check_vma" in _SM_PARAMS else (
    "check_rep" if "check_rep" in _SM_PARAMS else None)


def shard_map(f, mesh, in_specs, out_specs, check_vma=None, check_rep=None):
    """``jax.shard_map`` across JAX versions.

    ``check_vma``/``check_rep`` are the same switch under two names;
    pass either (or neither).
    """
    kw = {}
    flag = check_vma if check_vma is not None else check_rep
    if flag is not None and _CHECK_KW is not None:
        kw[_CHECK_KW] = flag
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, **kw)


_MM_PARAMS = set(inspect.signature(jax.make_mesh).parameters)


def mesh_axis_types_kw(n_axes: int) -> dict:
    """``{"axis_types": (Auto,)*n}`` when this JAX supports it, else {}."""
    if "axis_types" in _MM_PARAMS and hasattr(jax.sharding, "AxisType"):
        return {"axis_types": (jax.sharding.AxisType.Auto,) * n_axes}
    return {}


def make_mesh(axis_shapes, axis_names, **kwargs):
    """``jax.make_mesh`` that tolerates ``axis_types=`` on old versions."""
    if "axis_types" in kwargs and "axis_types" not in _MM_PARAMS:
        kwargs.pop("axis_types")
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)
