"""End-to-end driver (the paper's kind): distributed PageRank on a web-scale
stand-in graph with the dynamic partition strategy.

Reproduces the paper's headline experiment shape through the
``repro.solve`` front door: a web graph (uk-2007-05 stand-in, Table
4-matched), K PIDs on the ``simulator`` backend, uniform start, dynamic
rebalancing; then reports the speed-up vs K=1 (the paper's
``steps·PID_Speed/L`` wall-clock metric, kept in
``report.extras["cost_steps_iterations"]``) and the partition
evolution.

Run:  PYTHONPATH=src python examples/solve_web.py [--n 50000] [--k 16]
"""
import argparse
import time

import repro
from repro.core import webgraph_like

ap = argparse.ArgumentParser()
ap.add_argument("--n", type=int, default=50_000)
ap.add_argument("--k", type=int, default=16)
args = ap.parse_args()

print(f"building web-like graph N={args.n} (uk-2007-05 stand-in) ...")
g = webgraph_like(args.n, seed=1)
problem = repro.Problem.pagerank(g, target_error=1.0 / g.n)
print(f"  L = {g.n_edges} (L/N = {g.n_edges / g.n:.1f})")

opts = dict(mode="batch", record_every=100)

t0 = time.time()
base = repro.solve(problem, method="simulator", k=1, **opts)
base_cost = base.extras["cost_steps_iterations"]
print(f"[K=1 ]  cost = {base_cost:.2f}  ({time.time() - t0:.1f}s wall)")

for dyn in (False, True):
    t0 = time.time()
    res = repro.solve(problem, method="simulator", k=args.k, dynamic=dyn,
                      **opts)
    cost = res.extras["cost_steps_iterations"]
    tag = "dyn " if dyn else "stat"
    print(f"[K={args.k} {tag}] cost = {cost:.2f}  "
          f"speedup = {base_cost / cost:.2f}x  "
          f"moves = {len(res.move_log)}  ({time.time() - t0:.1f}s wall)")
    sizes = res.extras["hist_sizes"]
    if dyn and sizes.size:
        print(f"  partition sizes: start={sizes[0].tolist()[:8]} "
              f"-> end={sizes[-1].tolist()[:8]}")
