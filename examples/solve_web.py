"""End-to-end driver (the paper's kind): distributed PageRank on a web-scale
stand-in graph with the dynamic partition strategy.

Reproduces the paper's headline experiment shape: a web graph (uk-2007-05
stand-in, Table 4-matched), K PIDs, uniform start, dynamic rebalancing; then
reports the speed-up vs K=1 and the partition evolution.

Run:  PYTHONPATH=src python examples/solve_web.py [--n 50000] [--k 16]
"""
import argparse
import time

import numpy as np

from repro.core import (
    DistributedSimulator,
    SimulatorConfig,
    pagerank_system,
    webgraph_like,
)

ap = argparse.ArgumentParser()
ap.add_argument("--n", type=int, default=50_000)
ap.add_argument("--k", type=int, default=16)
args = ap.parse_args()

print(f"building web-like graph N={args.n} (uk-2007-05 stand-in) ...")
g = webgraph_like(args.n, seed=1)
p, b = pagerank_system(g)
print(f"  L = {g.n_edges} (L/N = {g.n_edges / g.n:.1f})")

t0 = time.time()
base = DistributedSimulator(
    p, b, SimulatorConfig(k=1, target_error=1.0 / g.n, eps=0.15,
                          mode="batch", record_every=100)
).run()
print(f"[K=1 ]  cost = {base.cost_iterations:.2f}  "
      f"({time.time() - t0:.1f}s wall)")

for dyn in (False, True):
    t0 = time.time()
    res = DistributedSimulator(
        p, b, SimulatorConfig(k=args.k, target_error=1.0 / g.n, eps=0.15,
                              partition="uniform", dynamic=dyn,
                              mode="batch", record_every=100)
    ).run()
    tag = "dyn " if dyn else "stat"
    print(f"[K={args.k} {tag}] cost = {res.cost_iterations:.2f}  "
          f"speedup = {base.cost_iterations / res.cost_iterations:.2f}x  "
          f"moves = {res.n_moves}  ({time.time() - t0:.1f}s wall)")
    if dyn and res.hist_sizes.size:
        print(f"  partition sizes: start={res.hist_sizes[0].tolist()[:8]} "
              f"-> end={res.hist_sizes[-1].tolist()[:8]}")
