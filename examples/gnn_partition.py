"""The paper's technique applied beyond the solver: the dynamic partition
controller as a load balancer for skewed GNN edge shards (DESIGN.md §5).

A power-law graph is bucketised into edge shards; shard costs are wildly
imbalanced (degree skew).  The slope controller — fed only the observed
per-shard work, exactly as it is fed per-PID residuals in the paper —
rebalances buckets until the max/mean shard cost ratio collapses.  A GIN
model then trains a few steps on the graph to show the surrounding pipeline.

Run:  PYTHONPATH=src python examples/gnn_partition.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import power_law_graph
from repro.core.partition import (
    DynamicController,
    DynamicControllerConfig,
    apply_move,
    uniform_partition,
)
from repro.data import make_gnn_batch
from repro.models import gnn

K = 8
g = power_law_graph(4000, seed=3)
# order nodes by degree -> adversarially skewed uniform partition
order = np.argsort(-g.out_degree(), kind="stable")
g = g.reorder(order)
deg = np.maximum(g.out_degree(), 1)

sets = uniform_partition(g.n, K)
ctl = DynamicController(
    DynamicControllerConfig(k=K, target_error=1e-6, z=3))

print("balancing edge shards with the paper's slope controller:")
for step in range(60):
    costs = np.array([deg[s].sum() for s in sets], dtype=np.float64)
    imb = costs.max() / costs.mean()
    if step % 10 == 0:
        print(f"  step {step:3d}: shard costs max/mean = {imb:.2f} "
              f"(sizes {[s.size for s in sets]})")
    move = ctl.update(costs, np.array([s.size for s in sets]))
    if move is not None:
        sets, _ = apply_move(sets, move)
costs = np.array([deg[s].sum() for s in sets], dtype=np.float64)
print(f"  final:     shard costs max/mean = "
      f"{costs.max() / costs.mean():.2f}")

print("\ntraining GIN on the balanced graph:")
cfg = gnn.GNNConfig(name="demo", arch="gin", n_layers=3, d_hidden=32,
                    d_feat=16, n_classes=5)
batch = {k: jnp.asarray(v) for k, v in
         make_gnn_batch(g, d_feat=16, n_classes=5).items()}
params = gnn.init_params(cfg, jax.random.PRNGKey(0))
grad_fn = jax.jit(jax.value_and_grad(
    lambda p: gnn.loss_fn(p, batch, cfg)))
from repro.optim import clip_by_global_norm

for i in range(10):
    loss, grads = grad_fn(params)
    grads, _ = clip_by_global_norm(grads, 1.0)
    params = jax.tree.map(lambda p, g_: p - 1e-3 * g_, params, grads)
    if i % 3 == 0:
        print(f"  step {i}: loss = {float(loss):.4f}")
print("done.")
