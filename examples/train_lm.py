"""Train a transformer LM end-to-end with the full runtime substrate:
deterministic data pipeline, AdamW with fp32 master weights, atomic async
checkpoints, auto-resume, straggler monitor.

Default is a ~14M-param model that trains a few hundred steps in minutes on
this CPU container; ``--width 512 --layers 12`` gives the ~100M-param
configuration (same code path) for real hardware.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import argparse

import jax
import jax.numpy as jnp

from repro.data import lm_token_batch
from repro.models.transformer import TransformerConfig, init_params, train_loss
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.runtime import TrainLoop, TrainLoopConfig

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--width", type=int, default=256)
ap.add_argument("--layers", type=int, default=4)
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--seq", type=int, default=128)
ap.add_argument("--vocab", type=int, default=4096)
ap.add_argument("--ckpt", default="/tmp/repro_lm_ckpt")
args = ap.parse_args()

cfg = TransformerConfig(
    name="example-lm",
    n_layers=args.layers,
    d_model=args.width,
    n_heads=max(2, args.width // 64),
    n_kv_heads=max(1, args.width // 128),
    d_ff=args.width * 3,
    vocab=args.vocab,
    dtype=jnp.float32,
    ce_chunk=args.seq,
)
ocfg = AdamWConfig(lr=3e-4, warmup_steps=20, total_steps=args.steps)


def init_state():
    p = init_params(cfg, jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree.leaves(p))
    print(f"model: {n/1e6:.1f}M params")
    return p, adamw_init(p)


@jax.jit
def step_fn(params, opt_state, batch):
    loss, grads = jax.value_and_grad(
        lambda p: train_loss(p, batch, cfg))(params)
    params, opt_state, m = adamw_update(grads, opt_state, ocfg,
                                        param_dtype=cfg.dtype)
    return params, opt_state, {"loss": loss, **m}


def make_batch(step):
    b = lm_token_batch(step, args.batch, args.seq, args.vocab)
    return {k: jnp.asarray(v) for k, v in b.items()}


loop = TrainLoop(
    step_fn, make_batch, init_state,
    TrainLoopConfig(total_steps=args.steps, ckpt_dir=args.ckpt,
                    ckpt_every=50, log_every=10),
)
out = loop.run(verbose=True)
first = out["metrics"][0]["loss"] if out["metrics"] else float("nan")
last = out["metrics"][-1]["loss"] if out["metrics"] else float("nan")
print(f"done: loss {first:.3f} -> {last:.3f} "
      f"({out['mean_step_time']*1e3:.0f} ms/step); "
      f"checkpoints in {args.ckpt} (re-run resumes automatically)")
