"""Quickstart: solve PageRank with the D-iteration, three ways.

The three tiers of the architecture are three ``method=`` strings on
the same :func:`repro.solve` front door (DESIGN.md §4):

1. ``sequential``  — reference solver (paper §2.1 pseudo-code).
2. ``simulator``   — faithful K-PID simulator with the dynamic
                     partition (§2.2–2.5).
3. ``engine:chunk``— production distributed engine (shard_map; uses
                     however many JAX devices exist — 1 on plain CPU).

Run:  PYTHONPATH=src python examples/quickstart.py [--n 2000]
"""
import argparse

import numpy as np

import repro
from repro.core import jacobi_solve, power_law_graph

ap = argparse.ArgumentParser()
ap.add_argument("--n", type=int, default=2000)
args = ap.parse_args()

N = args.n
print(f"generating power-law graph (alpha=1.5), N={N} ...")
g = power_law_graph(N, alpha=1.5, seed=0)
problem = repro.Problem.pagerank(g, damping=0.85, target_error=1.0 / N)
print(f"  L = {g.n_edges} links, {int(g.dangling_mask().sum())} dangling")

# --- 1. reference solver ---------------------------------------------------
ref = repro.solve(problem, method="sequential")
print(f"[sequential]  cost = {ref.cost_iterations:.2f} matvec-equivalents, "
      f"|F| = {ref.residual:.2e}")
x_jac, iters = jacobi_solve(problem.p, problem.b,
                            target_error=1.0 / N, eps=0.15)
print(f"[jacobi]      cost = {iters} matvecs  "
      f"(D-iteration is {iters / ref.cost_iterations:.1f}x cheaper)")

# --- 2. K-PID simulator with dynamic partition ------------------------------
sim = repro.solve(problem, method="simulator", k=8, dynamic=True,
                  mode="sequential", record_every=50)
err = np.abs(sim.x - ref.x).max()
print(f"[simulator]   K=8 dynamic: cost = {sim.cost_iterations:.2f}, "
      f"moves = {len(sim.move_log)}, "
      f"exchanges = {sim.extras['n_exchanges']}, "
      f"max|Δx| vs sequential = {err:.2e}")

# --- 3. production engine ----------------------------------------------------
import jax

k = len(jax.devices())
eng = repro.solve(problem, method="engine:chunk", k=k, dynamic=k > 1)
print(f"[engine]      K={k} devices: converged={eng.converged} "
      f"rounds={eng.n_rounds} max|Δx| = {np.abs(eng.x - ref.x).max():.2e}")

top = np.argsort(-ref.x)[:5]
print("top-5 PageRank nodes:", top.tolist())
