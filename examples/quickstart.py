"""Quickstart: solve PageRank with the D-iteration, three ways.

1. Reference sequential solver (paper §2.1 pseudo-code).
2. Faithful K-PID simulator with the dynamic partition (§2.2–2.5).
3. Production distributed engine (shard_map; uses however many JAX devices
   exist — 1 on a plain CPU run).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (
    DistributedSimulator,
    SimulatorConfig,
    jacobi_solve,
    pagerank_system,
    power_law_graph,
    solve_sequential,
)
from repro.core.distributed import (
    DistributedEngine,
    EngineConfig,
    build_engine_arrays,
)

N = 2000
print(f"generating power-law graph (alpha=1.5), N={N} ...")
g = power_law_graph(N, alpha=1.5, seed=0)
p, b = pagerank_system(g, damping=0.85)
print(f"  L = {g.n_edges} links, {int(g.dangling_mask().sum())} dangling")

# --- 1. reference solver ---------------------------------------------------
res = solve_sequential(p, b, target_error=1.0 / N, eps=0.15)
print(f"[sequential]  cost = {res.cost_iterations:.2f} matvec-equivalents, "
      f"|F| = {res.residual:.2e}")
x_jac, iters = jacobi_solve(p, b, target_error=1.0 / N, eps=0.15)
print(f"[jacobi]      cost = {iters} matvecs  "
      f"(D-iteration is {iters / res.cost_iterations:.1f}x cheaper)")

# --- 2. K-PID simulator with dynamic partition ------------------------------
cfg = SimulatorConfig(k=8, target_error=1.0 / N, eps=0.15,
                      partition="uniform", dynamic=True, record_every=50)
sim = DistributedSimulator(p, b, cfg).run()
err = np.abs(sim.h - res.x).max()
print(f"[simulator]   K=8 dynamic: cost = {sim.cost_iterations:.2f}, "
      f"moves = {sim.n_moves}, exchanges = {sim.n_exchanges}, "
      f"max|Δx| vs sequential = {err:.2e}")

# --- 3. production engine ----------------------------------------------------
import jax

k = len(jax.devices())
ecfg = EngineConfig(k=k, target_error=1.0 / N, eps=0.15,
                    buckets_per_dev=8, headroom=2, dynamic=k > 1)
eng = DistributedEngine(build_engine_arrays(p, b, ecfg), ecfg)
x, info = eng.solve()
print(f"[engine]      K={k} devices: converged={info['converged']} "
      f"rounds={info['rounds']} max|Δx| = {np.abs(x - res.x).max():.2e}")

top = np.argsort(-res.x)[:5]
print("top-5 PageRank nodes:", top.tolist())
