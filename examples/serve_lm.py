"""Serve a small LM with batched requests: prefill the prompt batch, then
decode tokens for every sequence in lock-step (static KV cache, the same
decode_step the 32k/500k dry-run cells lower).

Run:  PYTHONPATH=src python examples/serve_lm.py [--batch 8] [--gen 32]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import lm_token_batch
from repro.models.transformer import (
    TransformerConfig,
    decode_step,
    init_params,
    prefill_step,
)

ap = argparse.ArgumentParser()
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--prompt", type=int, default=64)
ap.add_argument("--gen", type=int, default=32)
args = ap.parse_args()

cfg = TransformerConfig(
    name="serve-demo", n_layers=4, d_model=256, n_heads=4, n_kv_heads=2,
    d_ff=768, vocab=4096, dtype=jnp.float32, ce_chunk=64,
)
params = init_params(cfg, jax.random.PRNGKey(0))
max_seq = args.prompt + args.gen

prompts = jnp.asarray(
    lm_token_batch(0, args.batch, args.prompt, cfg.vocab)["tokens"]
)

prefill = jax.jit(lambda p, t: prefill_step(p, t, cfg, max_seq=max_seq))
decode = jax.jit(lambda p, c, t: decode_step(p, c, t, cfg))

t0 = time.time()
cache, logits = prefill(params, prompts)
jax.block_until_ready(logits)
t_prefill = time.time() - t0
print(f"prefill: {args.batch}x{args.prompt} tokens in {t_prefill*1e3:.0f} ms"
      f" ({args.batch * args.prompt / t_prefill:.0f} tok/s)")

tokens = jnp.argmax(logits, -1)
generated = [tokens]
t0 = time.time()
for _ in range(args.gen - 1):
    logits, cache = decode(params, cache, tokens)
    tokens = jnp.argmax(logits, -1)
    generated.append(tokens)
jax.block_until_ready(tokens)
t_dec = time.time() - t0
out = np.stack([np.asarray(t) for t in generated], 1)
print(f"decode: {args.gen - 1} steps x {args.batch} seqs in "
      f"{t_dec*1e3:.0f} ms ({args.batch * (args.gen - 1) / t_dec:.0f} tok/s)")
print("sample generations (token ids):")
for row in out[:3]:
    print("  ", row[:16].tolist(), "...")
