"""Faithful K-PID simulator (paper §2.2–2.5)."""
import numpy as np
import pytest

from repro.core import DistributedSimulator, SimulatorConfig

EPS = 0.15


def _run(p, b, **kw):
    kw.setdefault("target_error", 1e-6)
    kw.setdefault("eps", EPS)
    kw.setdefault("record_every", 25)
    cfg = SimulatorConfig(**kw)
    return DistributedSimulator(p, b, cfg).run()


@pytest.mark.parametrize("k", [1, 2, 4, 8])
@pytest.mark.parametrize("partition", ["uniform", "cb"])
def test_converges_to_solution(small_pagerank, k, partition):
    p, b, x = small_pagerank
    res = _run(p, b, k=k, partition=partition)
    assert res.converged
    np.testing.assert_allclose(res.h, x, atol=1e-5)


@pytest.mark.parametrize("dynamic", [False, True])
def test_dynamic_converges(small_pagerank, dynamic):
    p, b, x = small_pagerank
    res = _run(p, b, k=4, dynamic=dynamic)
    assert res.converged
    np.testing.assert_allclose(res.h, x, atol=1e-5)


def test_batch_mode_matches(small_pagerank):
    p, b, x = small_pagerank
    res = _run(p, b, k=4, mode="batch", dynamic=True)
    assert res.converged
    np.testing.assert_allclose(res.h, x, atol=1e-5)


def test_cost_accounting(small_pagerank):
    """active+idle per PID ~ steps × PID_Speed (cost model §2.3)."""
    p, b, _ = small_pagerank
    res = _run(p, b, k=4)
    speed = p.n // 4
    budget = res.n_steps * speed
    per_pid = res.count_active + res.count_idle
    # freeze/debt can shift ops by up to a couple of steps' budget
    assert np.all(per_pid <= budget + 3 * speed)
    assert res.count_active.sum() > 0


def test_k1_matches_sequential_cost_scale(small_pagerank):
    """K=1 normalized cost is O(1) matvecs (paper Table 1: ~2.4 at 1/N)."""
    p, b, _ = small_pagerank
    res = _run(p, b, k=1, target_error=1.0 / p.n)
    assert res.converged
    assert res.cost_iterations < 25  # small-N looser bound, same order


def test_dynamic_beats_static_on_skewed_order(skewed_pagerank):
    """Paper Tables 2/3: dynamic rescues badly-ordered partitions."""
    p, b, _ = skewed_pagerank
    costs = {}
    for dyn in (False, True):
        res = _run(p, b, k=16, dynamic=dyn, target_error=1.0 / p.n)
        assert res.converged
        costs[dyn] = res.cost_iterations
    assert costs[True] < costs[False]


def test_dynamic_moves_fire_on_skew(skewed_pagerank):
    p, b, _ = skewed_pagerank
    res = _run(p, b, k=8, dynamic=True, target_error=1.0 / p.n)
    assert res.n_moves >= 1
    # partition sizes actually changed from uniform
    assert res.hist_sizes.shape[1] == 8
    assert res.hist_sizes[-1].std() > 0


def test_exchange_fires(small_pagerank):
    p, b, _ = small_pagerank
    res = _run(p, b, k=4)
    assert res.n_exchanges > 0


def test_speedup_with_k(small_pagerank):
    """More PIDs converge in fewer wall steps (parallelism claim C3)."""
    p, b, _ = small_pagerank
    r1 = _run(p, b, k=1, target_error=1.0 / p.n)
    r4 = _run(p, b, k=4, target_error=1.0 / p.n)
    assert r4.cost_iterations < r1.cost_iterations


def test_charge_exchange_matters(small_pagerank):
    """Charging the exchange cost can only slow convergence (C1)."""
    p, b, _ = small_pagerank
    free = _run(p, b, k=8, charge_exchange=False, target_error=1.0 / p.n)
    paid = _run(p, b, k=8, charge_exchange=True, target_error=1.0 / p.n)
    assert paid.cost_iterations >= free.cost_iterations - 1e-9
