"""Partition strategies + dynamic controller (paper §2.5)."""
import numpy as np
import pytest

from repro.core import (
    DynamicController,
    DynamicControllerConfig,
    apply_move,
    cb_partition,
    uniform_partition,
)

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # optional dep: property tests skip, fallbacks run
    HAVE_HYPOTHESIS = False


def _check_uniform_covers(n, k):
    if k > n:
        k = n
    sets = uniform_partition(n, k)
    assert len(sets) == k
    cat = np.concatenate([s for s in sets if s.size])
    assert cat.shape[0] == n
    assert np.array_equal(np.sort(cat), np.arange(n))


def _check_cb_covers_and_balances(n, k, seed):
    rng = np.random.default_rng(seed)
    deg = rng.zipf(1.6, n).astype(np.int64)
    sets = cb_partition(deg, k)
    cat = np.concatenate([s for s in sets if s.size])
    assert np.array_equal(np.sort(cat), np.arange(n))
    if k > n:
        return  # degenerate: empty sets allowed, balance bound vacuous
    # CB: per-set cost within a factor of the largest single cost + mean
    cost = np.maximum(deg, 1)
    per = np.array([cost[s].sum() for s in sets])
    assert per.max() <= cost.sum() / k + cost.max() + 1


if HAVE_HYPOTHESIS:

    @settings(max_examples=25, deadline=None)
    @given(n=st.integers(1, 5000), k=st.integers(1, 64))
    def test_uniform_partition_covers(n, k):
        _check_uniform_covers(n, k)

    @settings(max_examples=25, deadline=None)
    @given(
        n=st.integers(10, 2000),
        k=st.integers(1, 16),
        seed=st.integers(0, 99),
    )
    def test_cb_partition_covers_and_balances(n, k, seed):
        _check_cb_covers_and_balances(n, k, seed)


@pytest.mark.parametrize(
    "n,k", [(1, 1), (7, 3), (100, 64), (5000, 64), (64, 64)]
)
def test_uniform_partition_covers_cases(n, k):
    """Deterministic fallback for the property test (no hypothesis)."""
    _check_uniform_covers(n, k)


@pytest.mark.parametrize(
    "n,k,seed", [(10, 1, 0), (100, 16, 42), (2000, 16, 3), (11, 16, 7)]
)
def test_cb_partition_covers_and_balances_cases(n, k, seed):
    """Deterministic fallback for the property test (no hypothesis)."""
    _check_cb_covers_and_balances(n, k, seed)


def test_controller_moves_from_slow_to_fast():
    cfg = DynamicControllerConfig(k=3, target_error=1e-6, z=2)
    ctl = DynamicController(cfg)
    sizes = np.array([100, 100, 100])
    move = None
    # PID 0 keeps a large residual (slow), PID 2 converges fast
    for t in range(6):
        rs = np.array([1e-1, 10.0 ** (-2 - t), 10.0 ** (-4 - 2 * t)])
        move = ctl.update(rs, sizes) or move
    assert move is not None
    assert move.src == 0  # slowest sheds load
    assert move.dst == 2
    assert 0 < move.n_move <= 10  # capped at 10% of |Ω_src|


def test_controller_cooldown():
    cfg = DynamicControllerConfig(k=2, target_error=1e-6, z=10)
    ctl = DynamicController(cfg)
    sizes = np.array([100, 100])
    fired = []
    for t in range(12):
        rs = np.array([1e-1, 10.0 ** (-3 - t)])
        mv = ctl.update(rs, sizes)
        fired.append(mv is not None)
    # after the first fire, both PIDs are frozen for Z=10 steps
    first = fired.index(True)
    assert not any(fired[first + 1 : first + 10])


def test_controller_no_fire_when_balanced():
    cfg = DynamicControllerConfig(k=4, target_error=1e-6)
    ctl = DynamicController(cfg)
    sizes = np.full(4, 50)
    for t in range(20):
        rs = np.full(4, 10.0 ** (-t))  # identical progress
        assert ctl.update(rs, sizes) is None


def test_apply_move_preserves_nodes():
    sets = [np.arange(0, 50), np.arange(50, 60)]
    from repro.core.partition import MoveInstruction

    new, moved = apply_move(sets, MoveInstruction(src=0, dst=1, n_move=5))
    assert moved == 5
    cat = np.sort(np.concatenate(new))
    assert np.array_equal(cat, np.arange(60))
    assert new[0].size == 45 and new[1].size == 15


def test_apply_move_never_empties_source():
    sets = [np.arange(0, 3), np.arange(3, 60)]
    from repro.core.partition import MoveInstruction

    new, moved = apply_move(sets, MoveInstruction(src=0, dst=1, n_move=99))
    assert moved == 2
    assert new[0].size == 1
