"""Optimizer, checkpointing, runtime fault tolerance, data pipeline."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    CheckpointManager,
    latest_step,
    load_checkpoint,
    save_checkpoint,
)
from repro.data import NeighborSampler, criteo_like_batch, lm_token_batch
from repro.core import power_law_graph
from repro.optim import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    compress_int8,
    decompress_int8,
    ef_topk_compress,
    ef_topk_init,
)
from repro.runtime import StragglerMonitor


# --------------------------------------------------------------------------- #
# optimizer
# --------------------------------------------------------------------------- #
def test_adamw_converges_quadratic():
    cfg = AdamWConfig(lr=0.1, warmup_steps=5, total_steps=200,
                      weight_decay=0.0, grad_clip=0)
    target = jnp.asarray([3.0, -2.0, 0.5])
    params = {"w": jnp.zeros(3)}
    opt = adamw_init(params)
    for _ in range(200):
        g = {"w": 2 * (params["w"] - target)}
        params, opt, _ = adamw_update(g, opt, cfg, param_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=1e-2)


def test_grad_clip():
    g = {"a": jnp.full(100, 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) > 99
    total = float(jnp.sqrt(sum(jnp.sum(x**2)
                               for x in jax.tree.leaves(clipped))))
    assert abs(total - 1.0) < 1e-5


def test_bf16_master_weights():
    cfg = AdamWConfig(lr=1e-3)
    params = {"w": jnp.ones(4, jnp.bfloat16)}
    opt = adamw_init(params)
    assert opt["master"]["w"].dtype == jnp.float32
    new_p, opt, _ = adamw_update({"w": jnp.ones(4, jnp.bfloat16)}, opt, cfg)
    assert new_p["w"].dtype == jnp.bfloat16
    assert opt["master"]["w"].dtype == jnp.float32


def test_compression_contracts():
    rng = np.random.default_rng(0)
    g = {"a": jnp.asarray(rng.standard_normal(256).astype(np.float32))}
    st = ef_topk_init(g)
    sent, st2 = ef_topk_compress(g, st, k_frac=0.1)
    # error feedback: sent + residual == grad exactly
    np.testing.assert_allclose(
        np.asarray(sent["a"] + st2.residual["a"]), np.asarray(g["a"]),
        rtol=1e-6)
    # sparsity: ~10% entries kept
    nz = float((sent["a"] != 0).mean())
    assert nz <= 0.15
    q, s = compress_int8(g["a"])
    deq = decompress_int8(q, s)
    assert float(jnp.abs(deq - g["a"]).max()) <= float(s) + 1e-7


# --------------------------------------------------------------------------- #
# checkpoint
# --------------------------------------------------------------------------- #
def test_checkpoint_roundtrip(tmp_path):
    tree = {"w": jnp.arange(10.0), "n": {"b": jnp.ones((2, 3))}}
    save_checkpoint(str(tmp_path), 7, tree, extra={"loss": 1.5})
    restored, step, extra = load_checkpoint(str(tmp_path), tree)
    assert step == 7 and extra["loss"] == 1.5
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.arange(10.0))


def test_checkpoint_atomicity_ignores_tmp(tmp_path):
    tree = {"w": jnp.ones(3)}
    save_checkpoint(str(tmp_path), 5, tree)
    # simulate a crashed half-write
    os.makedirs(tmp_path / "step_000000009.tmp")
    assert latest_step(str(tmp_path)) == 5


def test_checkpoint_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_write=False)
    tree = {"w": jnp.ones(3)}
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    steps = sorted(
        int(n.split("_")[1]) for n in os.listdir(tmp_path)
        if n.startswith("step_"))
    assert steps == [3, 4]


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_write=True)
    tree = {"w": jnp.arange(5.0)}
    mgr.save(11, tree)
    mgr.wait()
    assert latest_step(str(tmp_path)) == 11
    mgr.close()


# --------------------------------------------------------------------------- #
# straggler monitor
# --------------------------------------------------------------------------- #
def test_straggler_monitor_flags_slow_host():
    mon = StragglerMonitor(n_hosts=4, z=2)
    mv = None
    for t in range(8):
        times = np.array([0.1, 0.1, 0.1, 0.8])  # host 3 is 8× slower
        mv = mon.advise(times) or mv
    assert mv is not None
    assert mv.src == 3  # slow host sheds load


# --------------------------------------------------------------------------- #
# data pipeline
# --------------------------------------------------------------------------- #
def test_lm_batch_deterministic():
    a = lm_token_batch(3, 4, 16, 100, seed=1)
    b = lm_token_batch(3, 4, 16, 100, seed=1)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = lm_token_batch(4, 4, 16, 100, seed=1)
    assert not np.array_equal(a["tokens"], c["tokens"])
    assert a["tokens"].max() < 100
    # teacher forcing alignment
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])


def test_neighbor_sampler_budgets():
    g = power_law_graph(5000, seed=2)
    s = NeighborSampler(g, fanouts=(5, 3))
    batch = s.sample(batch_nodes=64, step=0, d_feat=8, n_classes=4)
    n_budget = 64 * (1 + 5 + 15)
    e_budget = 64 * 5 + 320 * 3
    assert batch["x"].shape == (n_budget, 8)
    assert batch["src"].shape == (e_budget,)
    # edges respect the node budget
    real = batch["edge_mask"] > 0
    assert batch["src"][real].max() < n_budget
    assert batch["node_mask"].sum() > 0


def test_criteo_batch():
    b = criteo_like_batch(0, 128, 10, 1000)
    assert b["ids"].shape == (128, 10)
    assert b["ids"].max() < 1000
    assert set(np.unique(b["labels"])) <= {0, 1}
