"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # optional dep: property tests skip, fallbacks run
    HAVE_HYPOTHESIS = False

from repro.core import pagerank_system, power_law_graph
from repro.kernels.attention import attention_ref, flash_attention
from repro.kernels.diffusion import bsr_spmm, bsr_spmm_ref, prepare_bsr
from repro.kernels.fm import (
    fm_interaction,
    fm_interaction_naive,
    fm_interaction_ref,
)
from repro.kernels.segment import (
    embedding_bag,
    embedding_bag_ref,
    segment_sum_ref,
    segment_sum_sorted,
)

RNG = np.random.default_rng(0)


# --------------------------------------------------------------------------- #
# diffusion / BSR
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("n,seed", [(300, 0), (500, 2), (900, 5)])
@pytest.mark.parametrize("cols", [1, 4])
def test_bsr_diffusion_vs_dense(n, seed, cols):
    g = power_law_graph(n, seed=seed)
    p, _ = pagerank_system(g)
    m = prepare_bsr(p.indptr, p.indices, p.weights, p.n, bs=128)
    x = RNG.standard_normal(
        (m.n_row_blocks * 128, cols) if cols > 1 else (m.n_row_blocks * 128,)
    ).astype(np.float32)
    out = np.asarray(bsr_spmm(m, jnp.asarray(x)))
    ref = np.asarray(bsr_spmm(m, jnp.asarray(x), use_pallas=False))
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("bs", [8, 64, 128])
def test_bsr_block_sizes(bs):
    g = power_law_graph(200, seed=7)
    p, _ = pagerank_system(g)
    m = prepare_bsr(p.indptr, p.indices, p.weights, p.n, bs=bs)
    x = RNG.standard_normal(m.n_row_blocks * bs).astype(np.float32)
    out = np.asarray(bsr_spmm(m, jnp.asarray(x)))
    ref = np.asarray(bsr_spmm(m, jnp.asarray(x), use_pallas=False))
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


def test_bsr_empty_rows_masked():
    """Rows with no blocks must come out exactly zero."""
    import numpy as np

    from repro.kernels.diffusion.ref import dense_to_bsr
    from repro.kernels.diffusion.ops import BsrMatrix

    p = np.zeros((256, 256), np.float32)
    p[:128, :128] = RNG.standard_normal((128, 128))
    blocks, br, bc = dense_to_bsr(p, 128)
    m = BsrMatrix(blocks, br, bc, 2, 128)
    x = RNG.standard_normal(256).astype(np.float32)
    out = np.asarray(bsr_spmm(m, jnp.asarray(x)))
    assert np.all(out[128:] == 0)
    np.testing.assert_allclose(out[:128], p[:128] @ x, rtol=2e-5, atol=2e-5)


# --------------------------------------------------------------------------- #
# segment
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize(
    "e,d,s", [(100, 4, 7), (513, 8, 64), (2048, 32, 500), (4096, 128, 11)]
)
def test_segment_sum_shapes(e, d, s):
    seg = np.sort(RNG.integers(0, s, e)).astype(np.int32)
    data = RNG.standard_normal((e, d)).astype(np.float32)
    out = np.asarray(segment_sum_sorted(jnp.asarray(data), jnp.asarray(seg), s))
    ref = np.asarray(segment_sum_ref(jnp.asarray(data), jnp.asarray(seg), s))
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)


def _check_segment_sum(e, d, s, seed):
    rng = np.random.default_rng(seed)
    seg = np.sort(rng.integers(0, s, e)).astype(np.int32)
    data = rng.standard_normal((e, d)).astype(np.float32)
    out = np.asarray(
        segment_sum_sorted(jnp.asarray(data), jnp.asarray(seg), s, tile=128)
    )
    ref = np.asarray(segment_sum_ref(jnp.asarray(data), jnp.asarray(seg), s))
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)


if HAVE_HYPOTHESIS:

    @settings(max_examples=15, deadline=None)
    @given(
        e=st.integers(1, 600),
        d=st.sampled_from([1, 3, 8]),
        s=st.integers(1, 64),
        seed=st.integers(0, 1000),
    )
    def test_segment_sum_property(e, d, s, seed):
        _check_segment_sum(e, d, s, seed)


@pytest.mark.parametrize(
    "e,d,s,seed", [(1, 1, 1, 0), (257, 3, 5, 11), (600, 8, 64, 3)]
)
def test_segment_sum_property_cases(e, d, s, seed):
    """Deterministic fallback for the property test (no hypothesis)."""
    _check_segment_sum(e, d, s, seed)


@pytest.mark.parametrize("mode", ["sum", "mean", "max"])
def test_embedding_bag_modes(mode):
    table = RNG.standard_normal((500, 16)).astype(np.float32)
    ids = RNG.integers(0, 500, (32, 8)).astype(np.int32)
    o = np.asarray(embedding_bag(jnp.asarray(table), jnp.asarray(ids),
                                 mode=mode))
    r = np.asarray(embedding_bag_ref(jnp.asarray(table), jnp.asarray(ids),
                                     mode=mode))
    np.testing.assert_allclose(o, r, rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------------------- #
# fm
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("b,f,d", [(7, 5, 4), (300, 39, 10), (256, 26, 32)])
def test_fm_vs_naive(b, f, d):
    v = RNG.standard_normal((b, f, d)).astype(np.float32)
    o = np.asarray(fm_interaction(jnp.asarray(v)))
    r = np.asarray(fm_interaction_ref(jnp.asarray(v)))
    n = np.asarray(fm_interaction_naive(jnp.asarray(v)))
    np.testing.assert_allclose(o, r, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(r, n, rtol=1e-2, atol=1e-2)


def _check_fm(b, f, d, seed):
    rng = np.random.default_rng(seed)
    v = rng.standard_normal((b, f, d)).astype(np.float32)
    o = np.asarray(fm_interaction(jnp.asarray(v)))
    n = np.asarray(fm_interaction_naive(jnp.asarray(v)))
    np.testing.assert_allclose(o, n, rtol=5e-2, atol=5e-2)


if HAVE_HYPOTHESIS:

    @settings(max_examples=15, deadline=None)
    @given(
        b=st.integers(1, 300),
        f=st.integers(2, 40),
        d=st.integers(1, 32),
        seed=st.integers(0, 1000),
    )
    def test_fm_property(b, f, d, seed):
        _check_fm(b, f, d, seed)


@pytest.mark.parametrize(
    "b,f,d,seed", [(1, 2, 1, 0), (17, 13, 7, 9), (300, 40, 32, 5)]
)
def test_fm_property_cases(b, f, d, seed):
    """Deterministic fallback for the property test (no hypothesis)."""
    _check_fm(b, f, d, seed)


# --------------------------------------------------------------------------- #
# attention
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize(
    "b,hq,hkv,s,dh,causal",
    [
        (2, 4, 2, 256, 64, True),
        (1, 8, 1, 128, 32, True),  # MQA
        (2, 4, 4, 384, 64, False),  # MHA bidirectional
        (1, 2, 1, 100, 64, True),  # padded seq
        (1, 16, 2, 128, 128, True),
    ],
)
def test_flash_attention(b, hq, hkv, s, dh, causal):
    q = (RNG.standard_normal((b, hq, s, dh)) * 0.2).astype(np.float32)
    k = (RNG.standard_normal((b, hkv, s, dh)) * 0.2).astype(np.float32)
    v = RNG.standard_normal((b, hkv, s, dh)).astype(np.float32)
    o = np.asarray(
        flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                        causal=causal)
    )
    r = np.asarray(
        attention_ref(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                      causal=causal)
    )
    np.testing.assert_allclose(o, r, rtol=2e-3, atol=2e-3)


def test_flash_attention_bf16():
    q = (RNG.standard_normal((1, 4, 128, 64)) * 0.2).astype(jnp.bfloat16)
    k = (RNG.standard_normal((1, 2, 128, 64)) * 0.2).astype(jnp.bfloat16)
    v = RNG.standard_normal((1, 2, 128, 64)).astype(jnp.bfloat16)
    o = flash_attention(q, k, v, causal=True)
    r = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(o, np.float32), np.asarray(r, np.float32),
        rtol=3e-2, atol=3e-2,
    )
