"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # optional dep: property tests skip, fallbacks run
    HAVE_HYPOTHESIS = False

from repro.core import CSRGraph, pagerank_system, power_law_graph
from repro.kernels.attention import attention_ref, flash_attention
from repro.kernels.diffusion import (
    BsrMatrix,
    bsr_gather_spmm_pallas,
    bsr_spmm,
    bsr_spmm_ref,
    frontier_round_bsr,
    frontier_round_ref,
    prepare_bsr,
)
from repro.kernels.fm import (
    fm_interaction,
    fm_interaction_naive,
    fm_interaction_ref,
)
from repro.kernels.segment import (
    embedding_bag,
    embedding_bag_ref,
    segment_sum_ref,
    segment_sum_sorted,
)

RNG = np.random.default_rng(0)


# --------------------------------------------------------------------------- #
# diffusion / BSR
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("n,seed", [(300, 0), (500, 2), (900, 5)])
@pytest.mark.parametrize("cols", [1, 4])
def test_bsr_diffusion_vs_dense(n, seed, cols):
    g = power_law_graph(n, seed=seed)
    p, _ = pagerank_system(g)
    m = prepare_bsr(p.indptr, p.indices, p.weights, p.n, bs=128)
    x = RNG.standard_normal(
        (m.n_row_blocks * 128, cols) if cols > 1 else (m.n_row_blocks * 128,)
    ).astype(np.float32)
    out = np.asarray(bsr_spmm(m, jnp.asarray(x)))
    ref = np.asarray(bsr_spmm(m, jnp.asarray(x), use_pallas=False))
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("bs", [8, 64, 128])
def test_bsr_block_sizes(bs):
    g = power_law_graph(200, seed=7)
    p, _ = pagerank_system(g)
    m = prepare_bsr(p.indptr, p.indices, p.weights, p.n, bs=bs)
    x = RNG.standard_normal(m.n_row_blocks * bs).astype(np.float32)
    out = np.asarray(bsr_spmm(m, jnp.asarray(x)))
    ref = np.asarray(bsr_spmm(m, jnp.asarray(x), use_pallas=False))
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


def test_bsr_empty_rows_masked():
    """Rows with no blocks must come out exactly zero."""
    import numpy as np

    from repro.kernels.diffusion.ref import dense_to_bsr
    from repro.kernels.diffusion.ops import BsrMatrix

    p = np.zeros((256, 256), np.float32)
    p[:128, :128] = RNG.standard_normal((128, 128))
    blocks, br, bc = dense_to_bsr(p, 128)
    m = BsrMatrix(blocks, br, bc, 2, 128)
    x = RNG.standard_normal(256).astype(np.float32)
    out = np.asarray(bsr_spmm(m, jnp.asarray(x)))
    assert np.all(out[128:] == 0)
    np.testing.assert_allclose(out[:128], p[:128] @ x, rtol=2e-5, atol=2e-5)


# --------------------------------------------------------------------------- #
# diffusion / fused frontier round (pallas ≈ block oracle ≈ numpy ref)
# --------------------------------------------------------------------------- #
def _check_frontier_round(n, c, seed, t_quantile, bs=64):
    """Parity of the fused frontier round across all three backends on a
    random CSR graph, at a threshold hitting ``t_quantile`` of the fluid."""
    rng = np.random.default_rng(seed)
    if n == 1:  # single node, no edges (all-dangling degenerate graph)
        p = CSRGraph(indptr=np.zeros(2, np.int64),
                     indices=np.zeros(0, np.int32),
                     weights=np.zeros(0, np.float64), n=1)
    else:
        g = power_law_graph(n, seed=seed)
        p, _ = pagerank_system(g)
    m = prepare_bsr(p.indptr, p.indices, p.weights, p.n, bs=bs)
    n_pad = m.n_row_blocks * bs
    f = np.zeros((n_pad, c), np.float32)
    f[: p.n] = rng.standard_normal((p.n, c))
    w = np.zeros(n_pad, np.float32)
    w[: p.n] = 1.0 / np.maximum(np.diff(p.indptr), 1)
    fw = (np.abs(f) * w[:, None]).ravel()
    if t_quantile >= 1.0:
        t = float(fw.max()) * 2.0 + 1.0  # empty frontier
    else:
        t = max(float(np.quantile(fw, t_quantile)), 1e-6)
    f_in = f[:, 0] if c == 1 else f
    fr, sr, rr = frontier_round_ref(
        np.asarray(m.blocks), np.asarray(m.block_row),
        np.asarray(m.block_col), f_in, w, t)
    for backend in ("block", "pallas"):
        fo, so, ro = frontier_round_bsr(
            m, jnp.asarray(f_in), jnp.asarray(w), jnp.float32(t),
            backend=backend, interpret=True if backend == "pallas" else None)
        np.testing.assert_allclose(np.asarray(fo), fr, rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(so), sr, rtol=1e-6, atol=1e-6)
        assert abs(float(ro) - rr) <= 1e-3 * max(rr, 1.0), (backend, ro, rr)


if HAVE_HYPOTHESIS:

    @settings(max_examples=10, deadline=None)
    @given(
        n=st.integers(2, 300),
        c=st.sampled_from([1, 3]),
        seed=st.integers(0, 1000),
        t_quantile=st.sampled_from([0.0, 0.5, 0.9, 1.0]),
    )
    def test_frontier_round_property(n, c, seed, t_quantile):
        _check_frontier_round(n, c, seed, t_quantile)


@pytest.mark.parametrize(
    "n,c,seed,t_quantile",
    [
        (1, 1, 0, 0.0),  # single-node graph
        (2, 1, 3, 0.5),
        (150, 1, 1, 0.0),  # full frontier
        (150, 1, 1, 1.0),  # empty frontier: f must pass through unchanged
        (300, 3, 7, 0.5),
        (257, 1, 11, 0.9),  # sparse frontier (occupancy skip exercised)
    ],
)
def test_frontier_round_cases(n, c, seed, t_quantile):
    """Deterministic fallback for the property test (no hypothesis)."""
    _check_frontier_round(n, c, seed, t_quantile)


def test_frontier_round_interleaved_empty_rows():
    """Block rows 1 and 3 own no tiles: the kernel leaves their output
    uninitialised — the epilogue must substitute the kept fluid exactly."""
    bs = 8
    rng = np.random.default_rng(5)
    p = np.zeros((4 * bs, 4 * bs), np.float32)
    p[:bs, :bs] = rng.random((bs, bs)) * 0.1  # block row 0
    p[2 * bs : 3 * bs, bs : 2 * bs] = rng.random((bs, bs)) * 0.1  # row 2
    from repro.kernels.diffusion.ref import dense_to_bsr

    blocks, br, bc = dense_to_bsr(p, bs)
    m = BsrMatrix(blocks, br, bc, 4, bs)
    assert not m.row_occupied[1] and not m.row_occupied[3]
    f = rng.standard_normal(4 * bs).astype(np.float32)
    w = np.ones(4 * bs, np.float32)
    t = 0.5
    fr, sr, rr = frontier_round_ref(blocks, br, bc, f, w, t)
    for backend in ("block", "pallas"):
        fo, so, ro = frontier_round_bsr(
            m, jnp.asarray(f), jnp.asarray(w), jnp.float32(t),
            backend=backend, interpret=True if backend == "pallas" else None)
        np.testing.assert_allclose(np.asarray(fo), fr, rtol=2e-5, atol=2e-5)
        # empty rows keep exactly the un-diffused residual
        keep = np.where(np.abs(f) * w > t, 0.0, f)
        np.testing.assert_allclose(np.asarray(fo)[bs : 2 * bs],
                                   keep[bs : 2 * bs], atol=0)


def test_bsr_spmm_interleaved_empty_rows_masked():
    """bsr_spmm's row-occupancy epilogue zeroes every block row without
    tiles, also when occupied/empty rows interleave."""
    bs = 8
    rng = np.random.default_rng(9)
    p = np.zeros((4 * bs, 4 * bs), np.float32)
    p[:bs] = rng.standard_normal((bs, 4 * bs))
    p[2 * bs : 3 * bs] = rng.standard_normal((bs, 4 * bs))
    from repro.kernels.diffusion.ref import dense_to_bsr

    blocks, br, bc = dense_to_bsr(p, bs)
    m = BsrMatrix(blocks, br, bc, 4, bs)
    x = rng.standard_normal(4 * bs).astype(np.float32)
    out = np.asarray(bsr_spmm(m, jnp.asarray(x)))
    assert np.all(out[bs : 2 * bs] == 0) and np.all(out[3 * bs :] == 0)
    np.testing.assert_allclose(out, p @ x, rtol=2e-4, atol=2e-4)


def test_bsr_gather_spmm_shuffled_pool():
    """The gather kernel consumes tiles from an arbitrarily-ordered pool
    through the visit indirection (the engine's row-owned layout)."""
    bs = 16
    rng = np.random.default_rng(3)
    n_tiles, nrb = 24, 6
    pool = rng.standard_normal((n_tiles, bs, bs)).astype(np.float32) * 0.1
    dst = rng.integers(0, nrb, n_tiles).astype(np.int32)
    col = rng.integers(0, nrb, n_tiles).astype(np.int32)
    x = rng.standard_normal((nrb, bs, 2)).astype(np.float32)
    order = np.argsort(dst, kind="stable").astype(np.int32)
    out = np.asarray(bsr_gather_spmm_pallas(
        jnp.asarray(pool), jnp.asarray(order), jnp.asarray(dst[order]),
        jnp.asarray(col[order]), jnp.asarray(x), nrb, bs=bs,
        interpret=True))
    ref = np.zeros((nrb, bs, 2), np.float32)
    for i in range(n_tiles):
        ref[dst[i]] += pool[i] @ x[col[i]]
    occ = np.zeros(nrb, bool)
    occ[dst] = True
    np.testing.assert_allclose(out[occ], ref[occ], rtol=2e-4, atol=2e-4)


# --------------------------------------------------------------------------- #
# diffusion / multi-buffered DMA pipeline (buffer_depth > 1)
# --------------------------------------------------------------------------- #
def _frontier_fixture(n=300, c=3, seed=7, bs=64, t_quantile=0.5):
    rng = np.random.default_rng(seed)
    g = power_law_graph(n, seed=seed)
    p, _ = pagerank_system(g)
    m = prepare_bsr(p.indptr, p.indices, p.weights, p.n, bs=bs)
    n_pad = m.n_row_blocks * bs
    f = np.zeros((n_pad, c), np.float32)
    f[: p.n] = rng.standard_normal((p.n, c))
    w = np.zeros(n_pad, np.float32)
    w[: p.n] = 1.0 / np.maximum(np.diff(p.indptr), 1)
    fw = (np.abs(f) * w[:, None]).ravel()
    t = max(float(np.quantile(fw, t_quantile)), 1e-6)
    return m, f, w, t


@pytest.mark.parametrize("depth", [1, 2, 4])
def test_frontier_round_buffer_depths_match_ref(depth):
    """Each pipeline depth reproduces the numpy twin (interpret mode)."""
    m, f, w, t = _frontier_fixture()
    fr, sr, rr = frontier_round_ref(
        np.asarray(m.blocks), np.asarray(m.block_row),
        np.asarray(m.block_col), f, w, t)
    fo, so, ro = frontier_round_bsr(
        m, jnp.asarray(f), jnp.asarray(w), jnp.float32(t),
        backend="pallas", interpret=True, buffer_depth=depth)
    np.testing.assert_allclose(np.asarray(fo), fr, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(so), sr, rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("depth", [2, 4])
def test_frontier_round_depth_bit_parity(depth):
    """The multi-buffered ring is BIT-identical to the depth-1 kernel:
    the pipeline reorders DMA issue, never the accumulation order."""
    m, f, w, t = _frontier_fixture()
    out1 = frontier_round_bsr(
        m, jnp.asarray(f), jnp.asarray(w), jnp.float32(t),
        backend="pallas", interpret=True, buffer_depth=1)
    outd = frontier_round_bsr(
        m, jnp.asarray(f), jnp.asarray(w), jnp.float32(t),
        backend="pallas", interpret=True, buffer_depth=depth)
    for a, b in zip(out1, outd):
        assert np.array_equal(np.asarray(a), np.asarray(b)), (
            f"depth={depth} not bit-identical to depth=1")


@pytest.mark.parametrize("depth", [2, 4])
def test_gather_spmm_depth_bit_parity(depth):
    """Same bit-parity contract for the engine's gather kernel."""
    bs = 16
    rng = np.random.default_rng(3)
    n_tiles, nrb = 24, 6
    pool = rng.standard_normal((n_tiles, bs, bs)).astype(np.float32) * 0.1
    dst = rng.integers(0, nrb, n_tiles).astype(np.int32)
    col = rng.integers(0, nrb, n_tiles).astype(np.int32)
    x = rng.standard_normal((nrb, bs, 2)).astype(np.float32)
    order = np.argsort(dst, kind="stable").astype(np.int32)
    args = (jnp.asarray(pool), jnp.asarray(order), jnp.asarray(dst[order]),
            jnp.asarray(col[order]), jnp.asarray(x), nrb)
    out1 = np.asarray(bsr_gather_spmm_pallas(
        *args, bs=bs, interpret=True, buffer_depth=1))
    outd = np.asarray(bsr_gather_spmm_pallas(
        *args, bs=bs, interpret=True, buffer_depth=depth))
    assert np.array_equal(out1, outd)


def test_gather_spmm_depth_exceeds_visits():
    """A pipeline deeper than the visit list must still be exact (the
    warmup clamps to n_visits)."""
    bs = 8
    rng = np.random.default_rng(11)
    pool = rng.standard_normal((2, bs, bs)).astype(np.float32)
    dst = np.array([0, 1], np.int32)
    col = np.array([1, 0], np.int32)
    x = rng.standard_normal((2, bs, 1)).astype(np.float32)
    order = np.arange(2, dtype=np.int32)
    out = np.asarray(bsr_gather_spmm_pallas(
        jnp.asarray(pool), jnp.asarray(order), jnp.asarray(dst),
        jnp.asarray(col), jnp.asarray(x), 2, bs=bs, interpret=True,
        buffer_depth=4))
    ref = np.stack([pool[0] @ x[1], pool[1] @ x[0]])
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)


def test_frontier_round_rejects_bad_depth():
    m, f, w, t = _frontier_fixture(n=100, c=1)
    with pytest.raises(ValueError):
        frontier_round_bsr(m, jnp.asarray(f), jnp.asarray(w),
                           jnp.float32(t), backend="pallas",
                           interpret=True, buffer_depth=0)


def test_occupancy_threshold_defers_exactly():
    """τ > 0 suppresses low-occupancy block columns this round — the
    pallas and block backends agree on the deferred frontier, and τ=0
    reproduces the historical behavior bitwise."""
    m, f, w, t = _frontier_fixture(n=300, c=1, t_quantile=0.9)
    outs = {}
    for backend in ("block", "pallas"):
        fo, so, ro = frontier_round_bsr(
            m, jnp.asarray(f), jnp.asarray(w), jnp.float32(t),
            backend=backend, interpret=True, occupancy_threshold=0.5)
        outs[backend] = np.asarray(fo)
    np.testing.assert_allclose(outs["block"], outs["pallas"],
                               rtol=2e-4, atol=2e-4)
    # τ=0 must be the historical behavior exactly
    f0, _, _ = frontier_round_bsr(
        m, jnp.asarray(f), jnp.asarray(w), jnp.float32(t),
        backend="block", occupancy_threshold=0.0)
    fh, _, _ = frontier_round_bsr(
        m, jnp.asarray(f), jnp.asarray(w), jnp.float32(t),
        backend="block")
    assert np.array_equal(np.asarray(f0), np.asarray(fh))


# --------------------------------------------------------------------------- #
# segment
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize(
    "e,d,s", [(100, 4, 7), (513, 8, 64), (2048, 32, 500), (4096, 128, 11)]
)
def test_segment_sum_shapes(e, d, s):
    seg = np.sort(RNG.integers(0, s, e)).astype(np.int32)
    data = RNG.standard_normal((e, d)).astype(np.float32)
    out = np.asarray(segment_sum_sorted(jnp.asarray(data), jnp.asarray(seg), s))
    ref = np.asarray(segment_sum_ref(jnp.asarray(data), jnp.asarray(seg), s))
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)


def _check_segment_sum(e, d, s, seed):
    rng = np.random.default_rng(seed)
    seg = np.sort(rng.integers(0, s, e)).astype(np.int32)
    data = rng.standard_normal((e, d)).astype(np.float32)
    out = np.asarray(
        segment_sum_sorted(jnp.asarray(data), jnp.asarray(seg), s, tile=128)
    )
    ref = np.asarray(segment_sum_ref(jnp.asarray(data), jnp.asarray(seg), s))
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)


if HAVE_HYPOTHESIS:

    @settings(max_examples=15, deadline=None)
    @given(
        e=st.integers(1, 600),
        d=st.sampled_from([1, 3, 8]),
        s=st.integers(1, 64),
        seed=st.integers(0, 1000),
    )
    def test_segment_sum_property(e, d, s, seed):
        _check_segment_sum(e, d, s, seed)


@pytest.mark.parametrize(
    "e,d,s,seed", [(1, 1, 1, 0), (257, 3, 5, 11), (600, 8, 64, 3)]
)
def test_segment_sum_property_cases(e, d, s, seed):
    """Deterministic fallback for the property test (no hypothesis)."""
    _check_segment_sum(e, d, s, seed)


@pytest.mark.parametrize("mode", ["sum", "mean", "max"])
def test_embedding_bag_modes(mode):
    table = RNG.standard_normal((500, 16)).astype(np.float32)
    ids = RNG.integers(0, 500, (32, 8)).astype(np.int32)
    o = np.asarray(embedding_bag(jnp.asarray(table), jnp.asarray(ids),
                                 mode=mode))
    r = np.asarray(embedding_bag_ref(jnp.asarray(table), jnp.asarray(ids),
                                     mode=mode))
    np.testing.assert_allclose(o, r, rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------------------- #
# fm
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("b,f,d", [(7, 5, 4), (300, 39, 10), (256, 26, 32)])
def test_fm_vs_naive(b, f, d):
    v = RNG.standard_normal((b, f, d)).astype(np.float32)
    o = np.asarray(fm_interaction(jnp.asarray(v)))
    r = np.asarray(fm_interaction_ref(jnp.asarray(v)))
    n = np.asarray(fm_interaction_naive(jnp.asarray(v)))
    np.testing.assert_allclose(o, r, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(r, n, rtol=1e-2, atol=1e-2)


def _check_fm(b, f, d, seed):
    rng = np.random.default_rng(seed)
    v = rng.standard_normal((b, f, d)).astype(np.float32)
    o = np.asarray(fm_interaction(jnp.asarray(v)))
    n = np.asarray(fm_interaction_naive(jnp.asarray(v)))
    np.testing.assert_allclose(o, n, rtol=5e-2, atol=5e-2)


if HAVE_HYPOTHESIS:

    @settings(max_examples=15, deadline=None)
    @given(
        b=st.integers(1, 300),
        f=st.integers(2, 40),
        d=st.integers(1, 32),
        seed=st.integers(0, 1000),
    )
    def test_fm_property(b, f, d, seed):
        _check_fm(b, f, d, seed)


@pytest.mark.parametrize(
    "b,f,d,seed", [(1, 2, 1, 0), (17, 13, 7, 9), (300, 40, 32, 5)]
)
def test_fm_property_cases(b, f, d, seed):
    """Deterministic fallback for the property test (no hypothesis)."""
    _check_fm(b, f, d, seed)


# --------------------------------------------------------------------------- #
# attention
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize(
    "b,hq,hkv,s,dh,causal",
    [
        (2, 4, 2, 256, 64, True),
        (1, 8, 1, 128, 32, True),  # MQA
        (2, 4, 4, 384, 64, False),  # MHA bidirectional
        (1, 2, 1, 100, 64, True),  # padded seq
        (1, 16, 2, 128, 128, True),
    ],
)
def test_flash_attention(b, hq, hkv, s, dh, causal):
    q = (RNG.standard_normal((b, hq, s, dh)) * 0.2).astype(np.float32)
    k = (RNG.standard_normal((b, hkv, s, dh)) * 0.2).astype(np.float32)
    v = RNG.standard_normal((b, hkv, s, dh)).astype(np.float32)
    o = np.asarray(
        flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                        causal=causal)
    )
    r = np.asarray(
        attention_ref(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                      causal=causal)
    )
    np.testing.assert_allclose(o, r, rtol=2e-3, atol=2e-3)


def test_flash_attention_bf16():
    q = (RNG.standard_normal((1, 4, 128, 64)) * 0.2).astype(jnp.bfloat16)
    k = (RNG.standard_normal((1, 2, 128, 64)) * 0.2).astype(jnp.bfloat16)
    v = RNG.standard_normal((1, 2, 128, 64)).astype(jnp.bfloat16)
    o = flash_attention(q, k, v, causal=True)
    r = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(o, np.float32), np.asarray(r, np.float32),
        rtol=3e-2, atol=3e-2,
    )
