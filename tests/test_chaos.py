"""Elastic fault tolerance: checkpointed sessions, rescale, chaos plans.

Acceptance (ISSUE 5):

* on the N=4096 webgraph, ``kill(pid)`` at mid-solve followed by
  ``restore`` + ``rescale(k−1)`` converges to ``|Δx|₁ ≤ 1e-6`` of an
  undisturbed reference solve (subprocess, 8 fake host devices);
* ``rescale`` up/down produces bucket ownership identical to a cold
  start at ``k_new`` plus the same rebalancer trace (MovePlan-level
  replay, PR 2 style);
* a torn or stale checkpoint is REJECTED (the ``B = (I−P)H + F``
  invariant check) rather than silently resumed — restore falls back
  to the newest step that verifies.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import repro
from repro.chaos import (
    ChaosEvent,
    ChaosKill,
    ChaosPlan,
    ChaosRunner,
    SessionInjector,
    tear_checkpoint,
)
from repro.core import webgraph_like

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


@pytest.fixture(scope="module")
def web1024_problem():
    return repro.Problem.pagerank(webgraph_like(1024, seed=1))


# --------------------------------------------------------------------------- #
# the plan: deterministic, validated, replayable
# --------------------------------------------------------------------------- #
def test_plan_random_is_deterministic(repro_seed):
    a = ChaosPlan.random(seed=repro_seed + 42, k=8, rounds=20, n_events=5)
    b = ChaosPlan.random(seed=repro_seed + 42, k=8, rounds=20, n_events=5)
    assert [
        (e.kind, e.round, e.pid, e.slowdown, e.k_new, e.frac, e.seed)
        for e in a
    ] == [
        (e.kind, e.round, e.pid, e.slowdown, e.k_new, e.frac, e.seed)
        for e in b
    ]
    c = ChaosPlan.random(seed=repro_seed + 43, k=8, rounds=20, n_events=5)
    assert repr(a) != repr(c) or a.events != c.events


def test_plan_random_k1_is_consumable(repro_seed):
    """Random plans for a 1-PID world never schedule a kill (nobody may
    die) and always pass their own validation."""
    for s in range(6):
        plan = ChaosPlan.random(seed=repro_seed + s, k=1, rounds=10,
                                n_events=6)
        assert all(e.kind != "kill" for e in plan)
        plan.validate(1, kinds=("straggler", "kill", "rescale"))


def test_plan_validation():
    with pytest.raises(ValueError, match="unknown chaos event"):
        ChaosEvent("meteor", 1)
    with pytest.raises(ValueError, match="slowdown must be > 1"):
        ChaosPlan().straggler(0, 1.0)
    with pytest.raises(ValueError, match="frac"):
        ChaosPlan().churn_burst(0.9, round=1)
    with pytest.raises(ValueError, match="k_new"):
        ChaosPlan().rescale(0, round=1)
    plan = ChaosPlan().kill(5, round=3)
    with pytest.raises(ValueError, match="only 4 PIDs"):
        plan.validate(4)
    # width is tracked THROUGH rescale events
    plan2 = ChaosPlan().rescale(2, round=1).kill(3, round=5)
    with pytest.raises(ValueError, match="only 2 PIDs"):
        plan2.validate(8)
    with pytest.raises(ValueError, match="unsupported"):
        ChaosPlan().churn_burst(0.01, round=1).validate(
            4, kinds=("straggler", "kill", "rescale"))
    # events sort by round and at() slices exactly
    p3 = ChaosPlan().kill(1, round=7).straggler(0, 2.0, round=2)
    assert [e.round for e in p3] == [2, 7]
    assert [e.kind for e in p3.at(7)] == ["kill"]
    assert p3.at(3) == []


# --------------------------------------------------------------------------- #
# checkpoint / restore: resume, tear, staleness
# --------------------------------------------------------------------------- #
def test_checkpoint_resume_bit_equal(web1024_problem, tmp_path):
    """Mid-solve checkpoint -> restore -> finish == one undisturbed
    solve, exactly (the frontier loop is deterministic)."""
    problem = web1024_problem
    full = repro.SolverSession(problem,
                               method="frontier:segment_sum").solve()
    session = repro.SolverSession(problem, method="frontier:segment_sum")
    for i, _ in enumerate(session.run()):
        if i >= 3:
            break
    path = session.checkpoint(str(tmp_path))
    assert os.path.exists(os.path.join(path, "manifest.json"))
    restored = repro.SolverSession.restore(str(tmp_path), problem)
    assert restored.restored_from["step"] == 1
    assert restored.method == "frontier:segment_sum"  # from the manifest
    assert restored.residual == pytest.approx(session.residual, rel=1e-6)
    rep = restored.solve()
    assert rep.converged
    np.testing.assert_array_equal(rep.x, full.x)


def test_restore_rejects_torn_falls_back(web1024_problem, tmp_path):
    """A corrupted newest step (complete manifest, garbage H bytes) is
    rejected by the invariant check; restore resumes the previous one."""
    problem = web1024_problem
    session = repro.SolverSession(problem, method="frontier:segment_sum")
    for i, _ in enumerate(session.run()):
        if i >= 2:
            break
    session.checkpoint(str(tmp_path))
    for i, _ in enumerate(session.run(max_rounds=session.n_rounds + 64)):
        pass
    newest = session.checkpoint(str(tmp_path))
    tear_checkpoint(newest)
    restored = repro.SolverSession.restore(str(tmp_path), problem)
    assert restored.restored_from["step"] == 1
    assert restored.restored_from["rejected"], "tear went undetected"
    assert "invariant" in restored.restored_from["rejected"][0][1]
    # with no fallback left, restore raises instead of resuming garbage
    tear_checkpoint(os.path.join(str(tmp_path), "step_000000001"))
    with pytest.raises(ValueError, match="invariant violated"):
        repro.SolverSession.restore(str(tmp_path), problem)


def test_restore_rejects_stale_after_graph_delta(tmp_path):
    """A checkpoint cut BEFORE a graph delta must not resume against
    the patched matrix."""
    from repro.graph import rotation_churn

    problem = repro.Problem.pagerank(webgraph_like(512, seed=2))
    session = repro.SolverSession(problem, method="frontier:segment_sum")
    session.solve()
    session.checkpoint(str(tmp_path))
    session.update_graph(rotation_churn(session.problem.graph, 20, seed=3))
    session.solve()
    with pytest.raises(ValueError, match="stale"):
        repro.SolverSession.restore(str(tmp_path), session.problem)
    # a post-delta checkpoint restores fine against the same problem
    session.checkpoint(str(tmp_path))
    restored = repro.SolverSession.restore(str(tmp_path), session.problem)
    assert restored.restored_from["step"] == 2


def test_restore_across_methods(web1024_problem, tmp_path):
    """Checkpoints are layout-free node-space state: an engine-written
    step restores into a frontier session (and vice versa); only the
    thresholds are width-bound and re-derive when shapes disagree."""
    problem = web1024_problem
    eng = repro.SolverSession(problem, method="engine:chunk")
    for i, _ in enumerate(eng.run()):
        if i >= 1:
            break
    eng.checkpoint(str(tmp_path / "eng"))
    front = repro.SolverSession.restore(str(tmp_path / "eng"), problem,
                                        method="frontier:segment_sum")
    rep = front.solve()
    assert rep.converged
    ref = repro.SolverSession(problem,
                              method="frontier:segment_sum").solve()
    assert np.abs(rep.x - ref.x).sum() <= 2 * problem.target_error
    # and the mirror direction
    fr = repro.SolverSession(problem, method="frontier:segment_sum")
    for i, _ in enumerate(fr.run()):
        if i >= 1:
            break
    fr.checkpoint(str(tmp_path / "fr"))
    eng2 = repro.SolverSession.restore(str(tmp_path / "fr"), problem,
                                       method="engine:chunk")
    rep2 = eng2.solve()
    assert rep2.converged
    assert np.abs(rep2.x - ref.x).sum() <= 2 * problem.target_error


def test_restore_missing_and_explicit_step(web1024_problem, tmp_path):
    with pytest.raises(FileNotFoundError):
        repro.SolverSession.restore(str(tmp_path / "void"),
                                    web1024_problem)
    session = repro.SolverSession(web1024_problem,
                                  method="frontier:segment_sum")
    next(iter(session.run()))
    session.checkpoint(str(tmp_path))
    with pytest.raises(FileNotFoundError, match="step 9"):
        repro.SolverSession.restore(str(tmp_path), web1024_problem,
                                    step=9)
    restored = repro.SolverSession.restore(str(tmp_path), web1024_problem,
                                           step=1)
    assert restored.restored_from["step"] == 1


# --------------------------------------------------------------------------- #
# session injection: kill raises, churn re-seeds, crash tears
# --------------------------------------------------------------------------- #
def test_injector_kill_raises_chaoskill(web1024_problem):
    plan = ChaosPlan().kill(0, round=2)
    session = repro.SolverSession(web1024_problem,
                                  method="frontier:segment_sum")
    with pytest.raises(ChaosKill, match="killed at grain 2"):
        for _ in session.run(chaos=SessionInjector(plan)):
            pass


def test_injector_checkpoint_crash_needs_dir(web1024_problem):
    plan = ChaosPlan().checkpoint_crash(round=1)
    session = repro.SolverSession(web1024_problem,
                                  method="frontier:segment_sum")
    with pytest.raises(ValueError, match="no .*ckpt_dir"):
        list(session.run(chaos=SessionInjector(plan)))


def test_injector_rejects_pid_events_on_frontier(web1024_problem):
    """Single-process backends have no pid axis: straggler/rescale
    plans must fail at bind time, not mid-run."""
    session = repro.SolverSession(web1024_problem,
                                  method="frontier:segment_sum")
    with pytest.raises(ValueError, match="unsupported"):
        list(session.run(
            chaos=SessionInjector(ChaosPlan().straggler(0, 2.0, round=1))))
    with pytest.raises(ValueError, match="rescale needs an engine"):
        session.rescale(2)


def test_chaos_runner_kill_churn_crash_recovers(tmp_path, repro_seed):
    """The full production flow on one session: crash at grain 4,
    restore, absorb a churn burst, survive a torn checkpoint write,
    die and recover again — still converges, every recovery verified
    by the invariant oracle inside restore.  (Own Problem: churn
    mutates the shared store.)"""
    problem = repro.Problem.pagerank(webgraph_like(1024, seed=1))
    plan = (ChaosPlan(seed=repro_seed)
            .kill(0, round=4)
            .churn_burst(0.01, round=7, seed=repro_seed + 5)
            .checkpoint_crash(round=9)
            .kill(0, round=12))
    runner = ChaosRunner(problem, "frontier:segment_sum", plan,
                         ckpt_dir=str(tmp_path), checkpoint_every=2)
    m = runner.measure()
    assert m["converged"]
    assert m["kills"] == 2
    assert [k for _, k in m["chaos_log"]] == [
        "kill", "churn_burst", "checkpoint_crash", "kill"]
    # churn changed the matrix: the runner's x legitimately differs from
    # the pre-churn reference, but conservation still pins correctness
    assert m["disturbed_ops"] > 0


def test_chaos_runner_kill_after_churn_cold_restarts(tmp_path,
                                                     repro_seed):
    """A kill right after a churn burst, with every checkpoint cut
    pre-churn: restore rejects them all (stale against the patched P)
    and the runner falls back to a COLD restart instead of dying."""
    problem = repro.Problem.pagerank(webgraph_like(1024, seed=1))
    plan = (ChaosPlan(seed=repro_seed)
            .churn_burst(0.01, round=3, seed=repro_seed + 1)
            .kill(0, round=4))
    runner = ChaosRunner(problem, "frontier:segment_sum", plan,
                         ckpt_dir=str(tmp_path),
                         checkpoint_every=10**6)  # only the base ckpt
    m = runner.measure()
    assert m["converged"] and m["kills"] == 1


def test_chaos_runner_churn_ops_accounting(tmp_path, repro_seed):
    """disturbed_ops counts EVERY push across churn re-seeds: the
    injector banks the phase counters update_graph resets."""
    problem = repro.Problem.pagerank(webgraph_like(1024, seed=1))
    plan = ChaosPlan(seed=repro_seed).churn_burst(
        0.01, round=5, seed=repro_seed + 9)
    runner = ChaosRunner(problem, "frontier:segment_sum", plan,
                         ckpt_dir=str(tmp_path), checkpoint_every=2)
    session, disturbed, _wasted = runner.run()
    assert runner.injector.absorbed_ops > 0
    assert disturbed == runner.injector.absorbed_ops + session.n_ops


def test_chaos_runner_kill_before_first_checkpoint(web1024_problem,
                                                   tmp_path):
    """A kill that fires before any periodic checkpoint recovers from
    the runner's base checkpoint of the seeded state (cold restart),
    instead of dying on an empty checkpoint dir."""
    plan = ChaosPlan().kill(0, round=1)
    runner = ChaosRunner(web1024_problem, "frontier:segment_sum", plan,
                         ckpt_dir=str(tmp_path), checkpoint_every=10**6)
    m = runner.measure()
    assert m["converged"] and m["kills"] == 1
    assert m["x_err_l1"] <= 2 * web1024_problem.target_error


# --------------------------------------------------------------------------- #
# simulator chaos: behavioral (budgets, takeover, width change)
# --------------------------------------------------------------------------- #
def _sim(problem, dynamic=True, k=4):
    from repro.core.simulator import DistributedSimulator, SimulatorConfig

    cfg = SimulatorConfig(k=k, target_error=problem.target_error,
                          eps=problem.eps, mode="batch", dynamic=dynamic,
                          record_every=50)
    return DistributedSimulator(problem.p, problem.b, cfg)


def test_simulator_chaos_deterministic_replay(web1024_problem):
    plan = ChaosPlan(seed=1).straggler(1, 4.0, round=3).kill(
        2, round=10).rescale(2, round=25)
    r1 = _sim(web1024_problem).run(chaos=plan)
    plan2 = ChaosPlan(seed=1).straggler(1, 4.0, round=3).kill(
        2, round=10).rescale(2, round=25)
    r2 = _sim(web1024_problem).run(chaos=plan2)
    assert r1.converged and r2.converged
    assert r1.n_steps == r2.n_steps
    assert r1.n_edge_ops == r2.n_edge_ops
    np.testing.assert_array_equal(r1.h, r2.h)
    assert r1.chaos_log == r2.chaos_log


def test_simulator_kill_hands_over_and_converges(web1024_problem):
    base = _sim(web1024_problem).run()
    sim = _sim(web1024_problem)
    res = sim.run(chaos=ChaosPlan().kill(3, round=5))
    assert res.converged
    assert sim.sets[3].size == 0 and sim.speed_factor[3] == 0.0
    assert all(sim.sets[k].size > 0 for k in range(3))
    # takeover is logged as §2.4-charged moves from the dead PID
    handovers = [m for m in res.move_log if m[1] == 3]
    assert handovers and sum(m[3] for m in handovers) > 0
    assert np.abs(res.h - base.h).sum() <= 2 * web1024_problem.target_error


def test_simulator_rescale_mid_solve(web1024_problem):
    base = _sim(web1024_problem).run()
    sim = _sim(web1024_problem)
    res = sim.run(chaos=ChaosPlan().rescale(2, round=8))
    assert res.converged and sim.k == 2 and len(sim.sets) == 2
    assert sorted(np.concatenate(sim.sets).tolist()) == list(range(1024))
    assert np.abs(res.h - base.h).sum() <= 2 * web1024_problem.target_error
    # histories survived the width change (padded, not ragged)
    assert res.hist_rs.ndim == 2 and res.hist_sizes.ndim == 2


def test_simulator_straggler_survives_rescale(web1024_problem):
    """A rescale replaces DEAD capacity but must not cure surviving
    degraded machines: the straggler's slowdown persists across the
    width change."""
    sim = _sim(web1024_problem)
    plan = ChaosPlan().straggler(1, 4.0, round=3).rescale(2, round=8)
    res = sim.run(chaos=plan)
    assert res.converged
    assert sim.speed_factor.tolist() == [1.0, 0.25]


def test_simulator_straggler_dynamic_beats_static(web1024_problem):
    """The paper's §2.5.2 point under degradation: a 4× straggler costs
    the static partition far more than the dynamic one."""
    mk_plan = lambda: ChaosPlan().straggler(1, 4.0, round=5)
    base_dyn = _sim(web1024_problem, dynamic=True).run()
    dyn = _sim(web1024_problem, dynamic=True).run(chaos=mk_plan())
    stat = _sim(web1024_problem, dynamic=False).run(chaos=mk_plan())
    assert dyn.converged and stat.converged
    overhead_dyn = dyn.n_steps - base_dyn.n_steps
    base_stat = _sim(web1024_problem, dynamic=False).run()
    overhead_stat = stat.n_steps - base_stat.n_steps
    assert overhead_stat > overhead_dyn, (overhead_stat, overhead_dyn)


# --------------------------------------------------------------------------- #
# ACCEPTANCE: N=4096 kill -> restore -> rescale(k-1), and the MovePlan
# replay for rescale up/down (subprocess: 8 fake host devices)
# --------------------------------------------------------------------------- #
ACCEPTANCE_SCRIPT = textwrap.dedent(
    """
    import os, tempfile
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, {src!r})
    import numpy as np
    import repro
    from repro.balance.plan import MovePlan
    from repro.chaos import ChaosPlan, ChaosRunner
    from repro.core import webgraph_like

    g = webgraph_like(4096, seed=1)
    problem = repro.Problem.pagerank(g, target_error=2.5e-7)
    options = repro.SolverOptions(k=2)

    # ---- kill at mid-solve -> restore -> rescale(k-1) ------------------
    plan = ChaosPlan(seed=0).kill(pid=1, round=5)
    with tempfile.TemporaryDirectory() as ckpt:
        runner = ChaosRunner(problem, "engine:chunk", plan,
                             ckpt_dir=ckpt, options=options,
                             checkpoint_every=2, rescale_on_kill=True)
        m = runner.measure()
    assert m["converged"], m
    assert m["kills"] == 1, m
    assert m["x_err_l1"] <= 1e-6, m["x_err_l1"]
    print("KILL-RESTORE-RESCALE ops overhead:", m["overhead_ops"],
          "|dx|1:", m["x_err_l1"])

    # ---- rescale down with a STRICT executor drain + MovePlan replay ---
    opts = repro.SolverOptions(k=4, buckets_per_dev=12, headroom=4)
    session = repro.SolverSession(problem, method="engine:chunk",
                                  options=opts)
    for i, _ in enumerate(session.run()):
        if i >= 2:
            break
    drains = session.rescale(3, strict=True)
    d = session._driver
    assert d.cfg.k == 3
    # every evacuated bucket left the dying device through the executor
    assert len(drains) == 8, drains  # 12-4 real buckets on the dead dev
    assert all(src == 3 and dst < 3 for src, dst, _ in drains), drains
    # after the re-mesh the executor sits in the COLD-START layout of
    # k_new — balanced by construction, the replay baseline
    assert d.ex.sizes().tolist() == [8, 8, 8], d.ex.sizes()
    assert np.array_equal(d.ex.row_of_bucket, d.engine.a.pos_of_bucket)

    # force post-rescale rebalancer-style moves, then replay the full
    # post-rescale MovePlan trace over a cold-start map (PR 2 style)
    i0 = len(d._moves)
    for plan_ in (MovePlan(src=0, dst=2, units=2, kind="bucket"),
                  MovePlan(src=1, dst=0, units=1, kind="bucket")):
        moved = d.ex.apply(plan_)
        assert moved == plan_.units, (plan_, moved)
        d._moves.append((d._chunks, plan_.src, plan_.dst, moved))
    rep = session.solve()
    assert rep.converged
    ref = repro.SolverSession(problem, method="frontier:segment_sum"
                              ).solve()
    assert np.abs(rep.x - ref.x).sum() <= 1e-6

    cold_map = np.array(d.engine.a.pos_of_bucket)
    for (_, src, dst, units) in d._moves[i0:]:
        _, cold_map, moved = d.engine._plan_move(cold_map, src, dst,
                                                 units)
        assert moved == units
    assert np.array_equal(cold_map, d.ex.row_of_bucket), (
        cold_map, d.ex.row_of_bucket)

    # ---- rescale UP mid-solve: cold-start-at-k_new ownership -----------
    s2 = repro.SolverSession(problem, method="engine:chunk",
                             options=repro.SolverOptions(k=2))
    for i, _ in enumerate(s2.run()):
        if i >= 1:
            break
    ops_before = s2.n_ops
    assert s2.rescale(4) == []  # grow needs no drain
    d2 = s2._driver
    assert d2.cfg.k == 4
    assert np.array_equal(d2.ex.row_of_bucket, d2.engine.a.pos_of_bucket)
    assert s2.n_ops >= ops_before  # phase counters survive the re-mesh
    rep2 = s2.solve()
    assert rep2.converged
    assert np.abs(rep2.x - ref.x).sum() <= 1e-6
    print("ACCEPT_OK")
    """
)


def test_chaos_acceptance_subprocess():
    """N=4096 kill->restore->rescale(k-1) within 1e-6 of undisturbed,
    plus the MovePlan-level rescale ownership replay."""
    r = subprocess.run(
        [sys.executable, "-c",
         ACCEPTANCE_SCRIPT.format(src=os.path.abspath(SRC))],
        capture_output=True, text=True, timeout=900,
    )
    assert r.returncode == 0, r.stderr[-4000:]
    assert "ACCEPT_OK" in r.stdout


# --------------------------------------------------------------------------- #
# engine straggler signal injection (8 fake devices)
# --------------------------------------------------------------------------- #
STRAGGLER_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, {src!r})
    import numpy as np
    import repro
    from repro.chaos import ChaosPlan, SessionInjector
    from repro.core import webgraph_like

    g = webgraph_like(2048, seed=1)
    problem = repro.Problem.pagerank(g, target_error=2.5e-7)
    # enough movable buckets per device that the paper's 10% move cap
    # yields >= 1 unit (the PR 2 replay sizing), and a long enough
    # solve for the hysteresis patience to trip
    options = repro.SolverOptions(k=4, policy="hysteresis",
                                  buckets_per_dev=24, headroom=4)
    session = repro.SolverSession(problem, method="engine:chunk",
                                  options=options)
    plan = ChaosPlan().straggler(pid=2, slowdown=64.0, round=2)
    rep = session.solve(chaos=SessionInjector(plan))
    assert rep.converged
    scale = session._driver.engine.load_scale
    assert scale is not None and scale[2] == 64.0, scale
    # the inflated signal made the controller shed load away from pid 2
    sheds = [m for m in rep.move_log if m[1] == 2]
    assert sheds, rep.move_log
    ref = repro.SolverSession(problem,
                              method="frontier:segment_sum").solve()
    assert np.abs(rep.x - ref.x).sum() <= 2 * problem.target_error
    print("STRAGGLER_OK")
    """
)


def test_engine_straggler_signal_sheds_load_subprocess():
    r = subprocess.run(
        [sys.executable, "-c",
         STRAGGLER_SCRIPT.format(src=os.path.abspath(SRC))],
        capture_output=True, text=True, timeout=900,
    )
    assert r.returncode == 0, r.stderr[-4000:]
    assert "STRAGGLER_OK" in r.stdout
