"""kernels.tune: record persistence, sweep, measured dispatch, perf gate.

The contract under test (DESIGN.md §9):

* tuned records round-trip through versioned JSON and a stale version is
  treated as "no record";
* the sweep respects the VMEM feasibility model (infeasible configs are
  never timed);
* ``resolved_config`` precedence is explicit option > platform record >
  historical default;
* ``method="auto"`` demonstrably flips its backend choice when a tuned
  record appears for the current platform — and reverts when it is gone;
* the perf gate fails on a synthetic 2x slowdown (both metric kinds) and
  skips wall metrics across platforms.
"""
import json
import os
import sys

import numpy as np
import pytest

# the perf gate lives in benchmarks/, which is not an installed package
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import repro
from repro.api.registry import _auto_select
from repro.core import webgraph_like
from repro.kernels.tune import (
    DEFAULT_BS,
    DEFAULT_BUFFER_DEPTH,
    DEFAULT_OCCUPANCY_THRESHOLD,
    RECORD_VERSION,
    best_config,
    clear_cache,
    load_record,
    record_path,
    resolved_config,
    run_sweep,
    save_record,
    vmem_bytes,
    vmem_ok,
)
from repro.kernels.tune.model import PLATFORM_SPECS

from benchmarks import perf_gate


def _record(kernel="frontier_round_bsr", platform="cpu", *,
            version=RECORD_VERSION, bs=64, buffer_depth=2,
            occupancy_threshold=0.1, gflops=123.0):
    return {
        "version": version,
        "kernel": kernel,
        "platform": platform,
        "device_kind": "test-device",
        "jax_version": "0.0.test",
        "created_utc": "2026-08-08T00:00:00+00:00",
        "timing_path": "oracle",
        "problem": {"n": 4096, "c": 1, "density": 0.25},
        "best": {
            "bs": bs,
            "buffer_depth": buffer_depth,
            "occupancy_threshold": occupancy_threshold,
            "measured_us": 10.0,
            "throughput_gflops": gflops,
            "roofline_fraction": 0.5,
            "vmem_bytes": vmem_bytes(bs, 1, buffer_depth),
        },
        "sweep": [],
    }


@pytest.fixture()
def tune_dir(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_TUNE_DIR", str(tmp_path))
    clear_cache()
    yield tmp_path
    clear_cache()


# --------------------------------------------------------------------------- #
# records
# --------------------------------------------------------------------------- #
def test_record_round_trip(tune_dir):
    path = save_record(_record())
    assert path == record_path("frontier_round_bsr", "cpu")
    rec = load_record("frontier_round_bsr", "cpu")
    assert rec is not None and rec["best"]["bs"] == 64
    best = best_config("frontier_round_bsr", "cpu")
    assert (best.bs, best.buffer_depth, best.occupancy_threshold) == \
        (64, 2, 0.1)
    assert best.throughput_gflops == 123.0


def test_stale_version_is_no_record(tune_dir):
    rec = _record(version=RECORD_VERSION + 1)
    record_path("frontier_round_bsr", "cpu").parent.mkdir(
        parents=True, exist_ok=True)
    record_path("frontier_round_bsr", "cpu").write_text(json.dumps(rec))
    clear_cache()
    assert load_record("frontier_round_bsr", "cpu") is None
    assert best_config("frontier_round_bsr", "cpu") is None


def test_save_rejects_malformed(tune_dir):
    rec = _record()
    del rec["best"]["throughput_gflops"]
    with pytest.raises(ValueError):
        save_record(rec)
    with pytest.raises(ValueError):
        save_record(_record(kernel="not_a_kernel"))


def test_resolved_config_precedence(tune_dir):
    # no record: historical defaults
    assert resolved_config("frontier_round_bsr", platform="cpu") == (
        DEFAULT_BS, DEFAULT_BUFFER_DEPTH, DEFAULT_OCCUPANCY_THRESHOLD)
    save_record(_record(bs=64, buffer_depth=2, occupancy_threshold=0.1))
    # record beats defaults
    assert resolved_config("frontier_round_bsr", platform="cpu") == \
        (64, 2, 0.1)
    # explicit options beat the record, field by field
    assert resolved_config("frontier_round_bsr", platform="cpu",
                           bs=256) == (256, 2, 0.1)
    assert resolved_config("frontier_round_bsr", platform="cpu",
                           buffer_depth=1, occupancy_threshold=0.0) == \
        (64, 1, 0.0)


# --------------------------------------------------------------------------- #
# model / sweep
# --------------------------------------------------------------------------- #
def test_vmem_feasibility_model():
    spec = PLATFORM_SPECS["tpu"]
    assert vmem_ok(128, 1, 2, spec)
    # a tile ring this deep cannot fit the 64 MiB budget
    assert not vmem_ok(2048, 64, 8, spec)
    assert vmem_bytes(128, 1, 4) > vmem_bytes(128, 1, 2)


def test_sweep_skips_infeasible_and_persists(tune_dir):
    rec = run_sweep(
        "frontier_round_bsr", n=1024, c=1, density=0.5,
        bs_list=(32,), depths=(1, 2), iters=1, save=True,
        verbose=False)
    # persisted and loadable through the registry-facing reader
    import jax

    platform = jax.default_backend()
    assert record_path("frontier_round_bsr", platform).exists()
    clear_cache()
    best = best_config("frontier_round_bsr", platform)
    assert best is not None and best.measured_us > 0
    assert rec["timing_path"] in ("oracle", "pallas")
    timed = [r for r in rec["sweep"] if r["feasible"]]
    assert timed and all(r["measured_us"] > 0 for r in timed)
    for r in rec["sweep"]:
        if not r["feasible"]:
            assert r.get("measured_us") is None


# --------------------------------------------------------------------------- #
# measured dispatch flip
# --------------------------------------------------------------------------- #
def _small_problem():
    return repro.Problem.pagerank(webgraph_like(2048, seed=1),
                                  target_error=1e-6)


def test_auto_dispatch_flips_on_record(tune_dir, monkeypatch):
    import jax

    platform = jax.default_backend()
    p = _small_problem()
    opts = repro.SolverOptions()
    without = _auto_select(p, opts)
    assert without == "frontier:segment_sum"  # historical priority rule
    save_record(_record(platform=platform, gflops=999.0))
    with_rec = _auto_select(p, opts)
    assert with_rec == "frontier:pallas"
    # record gone -> old behavior again
    record_path("frontier_round_bsr", platform).unlink()
    clear_cache()
    assert _auto_select(p, opts) == without


def test_auto_dispatch_measured_ranks_beat_priorities(tune_dir):
    import jax

    platform = jax.default_backend()
    # both tuned backends measured: higher throughput wins regardless of
    # auto_priority (engine:bsr priority 30 < frontier:pallas 40)
    save_record(_record("frontier_round_bsr", platform, gflops=10.0))
    save_record(_record("bsr_gather_spmm", platform, gflops=500.0))
    p = repro.Problem.pagerank(webgraph_like(1 << 17, seed=1),
                               target_error=1e-6)
    assert _auto_select(p, repro.SolverOptions()) == "engine:bsr"


@pytest.mark.parametrize("platform,n,expect_without,expect_with", [
    ("cpu", 2048, "frontier:segment_sum", "frontier:pallas"),
    ("tpu", 2048, "frontier:pallas", "frontier:pallas"),
    ("gpu", 2048, "frontier:segment_sum", "frontier:pallas"),
])
def test_auto_dispatch_platform_matrix(tune_dir, monkeypatch, platform,
                                       n, expect_without, expect_with):
    """Capability matrix over mocked platforms: without a record the
    priority rule holds per-platform; a record makes the tuned backend
    native and top-ranked everywhere."""
    import jax

    monkeypatch.setattr(jax, "default_backend", lambda: platform)
    p = repro.Problem.pagerank(webgraph_like(n, seed=1),
                               target_error=1e-6)
    opts = repro.SolverOptions()
    assert _auto_select(p, opts) == expect_without
    save_record(_record(platform=platform, gflops=999.0))
    assert _auto_select(p, opts) == expect_with


def test_auto_dispatch_batch_and_dynamic_unaffected(tune_dir):
    """Gates the record must NOT override: frontier:pallas has no batch
    path and no dynamic partition, so those requests keep their backend
    even with a dominant tuned record present."""
    import jax

    platform = jax.default_backend()
    save_record(_record(platform=platform, gflops=9999.0))
    g = webgraph_like(2048, seed=1)
    pref = np.full((2048, 3), 1.0 / 2048, np.float32)
    pb = repro.Problem.pagerank(g, target_error=1e-6,
                                personalization=pref)
    assert _auto_select(pb, repro.SolverOptions()) == \
        "frontier:segment_sum"
    p = repro.Problem.pagerank(g, target_error=1e-6)
    dyn = _auto_select(p, repro.SolverOptions(dynamic=True, k=4))
    assert dyn != "frontier:pallas"


def test_solve_end_to_end_matches_across_flip(tune_dir):
    """The flipped backend must solve to the same answer."""
    import jax

    p = _small_problem()
    r0 = repro.solve(p, method="auto")
    save_record(_record(platform=jax.default_backend(), gflops=999.0,
                        bs=DEFAULT_BS, buffer_depth=2,
                        occupancy_threshold=0.0))
    r1 = repro.solve(p, method="auto")
    assert r0.method == "frontier:segment_sum"
    assert r1.method == "frontier:pallas"
    np.testing.assert_allclose(r0.x, r1.x, atol=1e-4)


# --------------------------------------------------------------------------- #
# perf gate
# --------------------------------------------------------------------------- #
def _bench_payload(skip_us=100.0, n_ops=1000.0):
    return {
        "meta": {"platform": "cpu"},
        "sections": {
            "kernels": {"rows": [{
                "n": 4096, "c": 1, "density": 0.5, "buffer_depth": 1,
                "pallas_skip_us": skip_us, "segment_sum_us": 300.0,
            }]},
            "api": {"rows": [{
                "method": "auto", "n": 4096, "n_ops": n_ops,
                "wall_s": 1.0,
            }]},
        },
    }


def test_perf_gate_passes_identical():
    base = perf_gate.make_baseline(_bench_payload())
    cur = perf_gate.extract_metrics(_bench_payload())
    results, ok = perf_gate.compare(cur, base, platform="cpu")
    assert ok and all(r["status"] == "ok" for r in results)


def test_perf_gate_fails_on_2x_wall_slowdown():
    base = perf_gate.make_baseline(_bench_payload(skip_us=100.0))
    cur = perf_gate.extract_metrics(_bench_payload(skip_us=210.0))
    results, ok = perf_gate.compare(cur, base, platform="cpu")
    assert not ok
    failed = [r for r in results if r["status"] == "fail"]
    assert any("pallas_skip_us" in r["metric"] for r in failed)


def test_perf_gate_fails_on_counter_regression():
    # counters get the tight band: +20% ops is already a failure
    base = perf_gate.make_baseline(_bench_payload(n_ops=1000.0))
    cur = perf_gate.extract_metrics(_bench_payload(n_ops=1200.0))
    _results, ok = perf_gate.compare(cur, base, platform="cpu")
    assert not ok


def test_perf_gate_platform_mismatch_skips_wall_only():
    base = perf_gate.make_baseline(_bench_payload())
    # 10x wall slowdown AND 2x counter regression, on another platform
    cur = perf_gate.extract_metrics(
        _bench_payload(skip_us=1000.0, n_ops=2000.0))
    results, ok = perf_gate.compare(cur, base, platform="tpu")
    assert not ok  # the counter still fails
    status = {r["metric"]: r["status"] for r in results}
    assert status["kernels/pallas_skip_us/n4096.c1.d0.5.bd1"] == \
        "skipped_platform"
    assert status["api/n_ops/auto.n4096"] == "fail"


def test_perf_gate_missing_metric_fails():
    base = perf_gate.make_baseline(_bench_payload())
    cur = perf_gate.extract_metrics(
        {"meta": {"platform": "cpu"}, "sections": {}})
    results, ok = perf_gate.compare(cur, base, platform="cpu")
    assert not ok
    assert all(r["status"] == "missing" for r in results)


def test_perf_gate_improvement_is_not_failure():
    base = perf_gate.make_baseline(_bench_payload(skip_us=100.0))
    cur = perf_gate.extract_metrics(_bench_payload(skip_us=10.0))
    results, ok = perf_gate.compare(cur, base, platform="cpu")
    assert ok
    assert any(r["status"] == "improved" for r in results)


def test_committed_baseline_matches_committed_bench():
    """The repo ships BENCH.json + perf_baseline.json in lockstep."""
    import os

    if not (os.path.exists("BENCH.json")
            and os.path.exists(perf_gate.BASELINE_PATH)):
        pytest.skip("committed artifacts not present")
    with open("BENCH.json") as fh:
        payload = json.load(fh)
    with open(perf_gate.BASELINE_PATH) as fh:
        baseline = json.load(fh)
    results, ok = perf_gate.compare(
        perf_gate.extract_metrics(payload), baseline,
        platform=baseline.get("meta", {}).get("platform"))
    assert ok, [r for r in results if r["status"] in ("fail", "missing")]
