"""Production shard_map engine: single-device in-process, multi-device via
a subprocess with 8 fake host devices (smoke tests must see 1 device)."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import pagerank_system, power_law_graph
from repro.core.distributed import (
    DistributedEngine,
    EngineConfig,
    build_engine_arrays,
)


def test_engine_k1_matches_dense(small_pagerank):
    p, b, x = small_pagerank
    cfg = EngineConfig(k=1, target_error=1e-6, eps=0.15,
                       buckets_per_dev=8, headroom=2)
    arrs = build_engine_arrays(p, b, cfg)
    eng = DistributedEngine(arrs, cfg)
    xs, info = eng.solve()
    assert info["converged"]
    np.testing.assert_allclose(xs, x, atol=1e-5)


def test_engine_k1_bsr_matches_dense(small_pagerank):
    """BSR tile backend: same fixed point through the dense-tile push."""
    p, b, x = small_pagerank
    cfg = EngineConfig(k=1, target_error=1e-6, eps=0.15,
                       buckets_per_dev=8, headroom=2,
                       diffusion_backend="bsr")
    arrs = build_engine_arrays(p, b, cfg)
    assert arrs.tiles is not None and arrs.tile_dst is not None
    eng = DistributedEngine(arrs, cfg)
    xs, info = eng.solve()
    assert info["converged"]
    np.testing.assert_allclose(xs, x, atol=1e-5)


def test_engine_k1_bsr_pallas_interpret():
    """The Pallas gather kernel inside the jitted chunk (interpret mode)."""
    g = power_law_graph(200, seed=3)
    p, b = pagerank_system(g)
    x = np.linalg.solve(np.eye(g.n) - p.to_dense(), b)
    cfg = EngineConfig(k=1, target_error=1e-6, eps=0.15,
                       buckets_per_dev=6, headroom=2,
                       diffusion_backend="bsr", pallas_interpret=True,
                       max_inner=4, chunk_rounds=2)
    arrs = build_engine_arrays(p, b, cfg)
    eng = DistributedEngine(arrs, cfg)
    xs, info = eng.solve()
    assert info["converged"]
    np.testing.assert_allclose(xs, x, atol=1e-5)


def test_engine_tile_push_pallas_parity(small_pagerank):
    """einsum and Pallas-gather implementations of the tile push agree."""
    import jax.numpy as jnp

    from repro.core.distributed import _tile_push_stable

    p, b, _ = small_pagerank
    cfg = EngineConfig(k=1, target_error=1e-6, eps=0.15,
                       buckets_per_dev=8, headroom=2,
                       diffusion_backend="bsr")
    a = build_engine_arrays(p, b, cfg)
    rng = np.random.default_rng(1)
    sent = rng.standard_normal((a.n_rows, a.bucket_size)).astype(np.float32)
    o1 = _tile_push_stable(
        jnp.asarray(a.tiles, jnp.float32), jnp.asarray(a.tile_dst),
        jnp.asarray(sent), a.n_rows, use_pallas=False)
    o2 = _tile_push_stable(
        jnp.asarray(a.tiles, jnp.float32), jnp.asarray(a.tile_dst),
        jnp.asarray(sent), a.n_rows, use_pallas=True, interpret=True)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-4, atol=2e-4)


def test_engine_arrays_roundtrip(small_pagerank):
    """Every node and edge lands exactly once in the bucketed layout."""
    p, b, _ = small_pagerank
    cfg = EngineConfig(k=2, target_error=1e-6, eps=0.15,
                       buckets_per_dev=6, headroom=2)
    a = build_engine_arrays(p, b, cfg)
    nodes = a.node_of_slot[a.node_of_slot >= 0]
    assert np.array_equal(np.sort(nodes), np.arange(p.n))
    assert int((a.wgt != 0).sum()) == p.n_edges
    np.testing.assert_allclose(a.f0.sum(), b.sum(), rtol=1e-12)


MULTI_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, {src!r})
    import numpy as np
    from repro.core import pagerank_system, power_law_graph
    from repro.core.distributed import (
        DistributedEngine, EngineConfig, build_engine_arrays)

    g = power_law_graph(1200, seed=7)
    order = np.argsort(-g.out_degree(), kind="stable")
    g = g.reorder(order)
    p, b = pagerank_system(g)
    P = np.zeros((g.n, g.n))
    for i in range(g.n):
        js, ws = p.out_neighbors(i)
        P[js, i] += ws
    x_ref = np.linalg.solve(np.eye(g.n) - P, b)

    for K, dyn in [(4, False), (8, True)]:
        cfg = EngineConfig(k=K, target_error=1e-6, eps=0.15,
                           buckets_per_dev=12, headroom=4, dynamic=dyn)
        arrs = build_engine_arrays(p, b, cfg)
        eng = DistributedEngine(arrs, cfg)
        xs, info = eng.solve()
        assert info["converged"], (K, dyn, info["residual"])
        err = np.abs(xs - x_ref).max()
        assert err < 1e-5, (K, dyn, err)

    # deterministic repartition test: force a bucket move mid-solve through
    # the balance control plane's executor and check the solution is still
    # exact (state+edges travel with buckets) — the MovePlan round-trip at
    # bucket granularity
    from repro.balance import BucketMoveExecutor, MovePlan

    cfg = EngineConfig(k=4, target_error=1e-6, eps=0.15,
                       buckets_per_dev=12, headroom=4, dynamic=False)
    arrs = build_engine_arrays(p, b, cfg)
    eng = DistributedEngine(arrs, cfg)
    ex = BucketMoveExecutor(eng, eng.init_state())
    sizes0 = ex.sizes().copy()
    ex.state, _ = eng._chunk(ex.state, ex.w, ex.src_slot, ex.dst_bucket,
                             ex.dst_slot, ex.wgt)
    moved = ex.apply(MovePlan(src=0, dst=3, units=2, kind="bucket"))
    assert moved == 2, moved
    sizes1 = ex.sizes()
    assert sizes1[0] == sizes0[0] - 2 and sizes1[3] == sizes0[3] + 2
    # a move exceeding the destination headroom is clipped to free rows
    moved2 = ex.apply(MovePlan(src=1, dst=3, units=99, kind="bucket"))
    assert moved2 == cfg.headroom - 2, moved2  # only 2 inert rows left
    tol = cfg.target_error * cfg.eps
    for _ in range(cfg.max_chunks):
        ex.state, stats = eng._chunk(ex.state, ex.w, ex.src_slot,
                                     ex.dst_bucket, ex.dst_slot, ex.wgt)
        resid = float(np.asarray(stats["residual"])) + float(
            np.asarray(stats["s"]).sum())
        if resid <= tol:
            break
    assert resid <= tol, resid
    h = np.asarray(ex.state.h).reshape(arrs.n_rows, arrs.bucket_size)
    x2 = np.zeros(arrs.n)
    for bid in range(arrs.n_rows):
        nodes = arrs.node_of_slot[int(arrs.pos_of_bucket[bid])]
        valid = nodes >= 0
        if valid.any():
            x2[nodes[valid]] = h[int(ex.row_of_bucket[bid]), valid]
    err = np.abs(x2 - x_ref).max()
    assert err < 1e-5, ("post-move solution wrong", err)
    print("MULTI_OK")
    """
)


def test_engine_multidevice_subprocess():
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    r = subprocess.run(
        [sys.executable, "-c", MULTI_SCRIPT.format(src=src)],
        capture_output=True, text=True, timeout=600,
    )
    assert r.returncode == 0, r.stderr[-3000:]
    assert "MULTI_OK" in r.stdout


REPLAY_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, {src!r})
    import numpy as np
    from repro.core import pagerank_system, power_law_graph
    from repro.core.distributed import (
        DistributedEngine, EngineConfig, build_engine_arrays)
    from repro.balance import BucketMoveExecutor, MovePlan

    g = power_law_graph(1600, seed=7)
    order = np.argsort(-g.out_degree(), kind="stable")
    g = g.reorder(order)
    p, b = pagerank_system(g)
    P = np.zeros((g.n, g.n))
    for i in range(g.n):
        js, ws = p.out_neighbors(i)
        P[js, i] += ws
    x_ref = np.linalg.solve(np.eye(g.n) - P, b)

    # ---- replay: both diffusion backends must make the SAME MovePlan
    # decisions and converge to the same residual -----------------------
    out = {{}}
    for be in ("segment_sum", "bsr"):
        cfg = EngineConfig(k=8, target_error=1e-8, eps=0.15,
                           buckets_per_dev=40, headroom=8, dynamic=True,
                           eta=0.9, diffusion_backend=be)
        arrs = build_engine_arrays(p, b, cfg)
        eng = DistributedEngine(arrs, cfg)
        xs, info = eng.solve()
        assert info["converged"], (be, info["residual"])
        err = np.abs(xs - x_ref).max()
        assert err < 1e-5, (be, err)
        out[be] = (info["move_log"], info["residual"], xs)
    seg, bsr = out["segment_sum"], out["bsr"]
    assert len(seg[0]) > 0, "replay exercised no bucket moves"
    assert seg[0] == bsr[0], ("MovePlan decisions diverged",
                              seg[0], bsr[0])
    assert abs(seg[1] - bsr[1]) <= 1e-5, (seg[1], bsr[1])
    assert np.abs(seg[2] - bsr[2]).max() < 1e-5

    # ---- forced mid-solve move under the bsr backend: the tile groups
    # must travel with their bucket rows ---------------------------------
    cfg = EngineConfig(k=4, target_error=1e-6, eps=0.15,
                       buckets_per_dev=12, headroom=4,
                       diffusion_backend="bsr")
    arrs = build_engine_arrays(p, b, cfg)
    eng = DistributedEngine(arrs, cfg)
    ex = BucketMoveExecutor(eng, eng.init_state())
    ex.state, _ = eng._chunk(ex.state, *ex.chunk_operands())
    moved = ex.apply(MovePlan(src=0, dst=3, units=2, kind="bucket"))
    assert moved == 2, moved
    tol = cfg.target_error * cfg.eps
    for _ in range(cfg.max_chunks):
        ex.state, stats = eng._chunk(ex.state, *ex.chunk_operands())
        resid = float(np.asarray(stats["residual"])) + float(
            np.asarray(stats["s"]).sum())
        if resid <= tol:
            break
    assert resid <= tol, resid
    h = np.asarray(ex.state.h).reshape(arrs.n_rows, arrs.bucket_size)
    x2 = np.zeros(arrs.n)
    for bid in range(arrs.n_rows):
        nodes = arrs.node_of_slot[int(arrs.pos_of_bucket[bid])]
        valid = nodes >= 0
        if valid.any():
            x2[nodes[valid]] = h[int(ex.row_of_bucket[bid]), valid]
    err = np.abs(x2 - x_ref).max()
    assert err < 1e-5, ("post-move bsr solution wrong", err)
    print("REPLAY_OK")
    """
)


def test_engine_backend_replay_subprocess():
    """Acceptance: identical MovePlans + same residual for either backend."""
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    r = subprocess.run(
        [sys.executable, "-c", REPLAY_SCRIPT.format(src=src)],
        capture_output=True, text=True, timeout=600,
    )
    assert r.returncode == 0, r.stderr[-3000:]
    assert "REPLAY_OK" in r.stdout
