"""Numerical equivalence of the §Perf optimized paths vs the pjit baselines
(subprocess with 8 fake devices; EXPERIMENTS.md §Perf A/B/C)."""
import os
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, {src!r})
    import numpy as np
    import jax
    import jax.numpy as jnp

    from repro.models.transformer import (
        MoEConfig, TransformerConfig, decode_step, init_params,
        prefill_step, train_loss)
    from repro.parallel.axes import axis_rules

    from repro.parallel.compat import make_mesh
    mesh = make_mesh((2, 4), ("data", "model"))
    rules = {{"batch": "data", "act_seq": "model", "expert": "model",
             "kv_seq": "model", "heads": "model", "mlp": "model",
             "vocab": "model", "embed": "data", "act_embed": None}}

    # ---- A: EP MoE (shard_map all_to_all) vs pjit dispatch -------------
    cfg = TransformerConfig(
        name="t", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
        d_ff=64, vocab=64, dtype=jnp.float32, ce_chunk=8,
        # capacity 8.0 -> no drops in either scheme; aux weight 0 because
        # EP computes load-balance stats per shard (documented semantic
        # difference: mean-of-products vs product-of-means)
        moe=MoEConfig(n_experts=6, top_k=2, d_ff_expert=16, n_shared=1,
                      pad_experts_to=8, capacity_factor=8.0,
                      router_aux_weight=0.0))
    p = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {{"tokens": jnp.asarray(rng.integers(0, 64, (4, 8)), jnp.int32),
             "labels": jnp.asarray(rng.integers(0, 64, (4, 8)), jnp.int32)}}
    loss_plain = float(train_loss(p, batch, cfg))  # no mesh -> pjit path
    with axis_rules(rules, mesh=mesh), mesh:
        loss_ep = float(jax.jit(
            lambda p, b: train_loss(p, b, cfg))(p, batch))
    # capacity_factor=8 -> no token drops in either scheme
    assert abs(loss_plain - loss_ep) < 2e-4, (loss_plain, loss_ep)
    print("EP_MOE_OK", loss_plain, loss_ep)

    # grads flow through the EP path
    with axis_rules(rules, mesh=mesh), mesh:
        g = jax.jit(jax.grad(lambda p: train_loss(p, batch, cfg)))(p)
    gsum = float(jnp.abs(g["layers"]["ew1"]).sum())
    assert np.isfinite(gsum) and gsum > 0, gsum
    print("EP_MOE_GRADS_OK")

    # ---- B: distributed split-KV decode vs pjit decode -----------------
    dcfg = TransformerConfig(
        name="d", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
        d_ff=64, vocab=64, qkv_bias=True, dtype=jnp.float32, ce_chunk=8)
    dp = init_params(dcfg, jax.random.PRNGKey(1))
    toks = jnp.asarray(rng.integers(0, 64, (4, 8)), jnp.int32)
    cache, _ = prefill_step(dp, toks, dcfg, max_seq=16)
    nxt = jnp.asarray(rng.integers(0, 64, (4,)), jnp.int32)
    logits_plain, cache_plain = decode_step(dp, cache, nxt, dcfg)
    with axis_rules(rules, mesh=mesh), mesh:
        logits_dist, cache_dist = jax.jit(
            lambda p, c, t: decode_step(p, c, t, dcfg))(dp, cache, nxt)
    err = float(jnp.abs(logits_dist - logits_plain).max())
    assert err < 2e-3, err
    kerr = float(jnp.abs(cache_dist["k"] - cache_plain["k"]).max())
    assert kerr < 1e-5, kerr
    print("DIST_DECODE_OK", err)
    """
)


def test_optimized_paths_match_baselines():
    src = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT.format(src=src)],
        capture_output=True, text=True, timeout=600,
    )
    assert r.returncode == 0, r.stderr[-3000:]
    assert "EP_MOE_OK" in r.stdout
    assert "DIST_DECODE_OK" in r.stdout


HALO_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, {src!r})
    import numpy as np
    import jax
    import jax.numpy as jnp

    from repro.core import power_law_graph
    from repro.data import build_halo_batch, make_gnn_batch
    from repro.models import gnn
    from repro.parallel.axes import axis_rules

    g = power_law_graph(640, seed=4)
    cfg = gnn.GNNConfig(name="g", arch="gin", n_layers=3, d_hidden=16,
                        d_feat=8, n_classes=5)
    p = gnn.init_params(cfg, jax.random.PRNGKey(0))
    plain = {{k: jnp.asarray(v)
             for k, v in make_gnn_batch(g, 8, n_classes=5).items()}}
    out_plain = np.asarray(gnn.forward(p, plain, cfg))
    from repro.parallel.compat import make_mesh
    mesh = make_mesh((4, 2), ("data", "model"))
    halo_np = build_halo_batch(g, 4, 8, n_classes=5)
    halo_np["x"][:g.n] = np.asarray(plain["x"])
    halo = {{k: jnp.asarray(v) for k, v in halo_np.items()}}
    with axis_rules({{"nodes": "data"}}, mesh=mesh), mesh:
        out_halo = np.asarray(jax.jit(
            lambda p, b: gnn.forward(p, b, cfg))(p, halo))
    err = np.abs(out_halo[: g.n] - out_plain[: g.n]).max()
    assert err < 2e-4, err
    print("HALO_OK", err)
    """
)


def test_halo_aggregation_matches_plain():
    src = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    r = subprocess.run(
        [sys.executable, "-c", HALO_SCRIPT.format(src=src)],
        capture_output=True, text=True, timeout=600,
    )
    assert r.returncode == 0, r.stderr[-3000:]
    assert "HALO_OK" in r.stdout
