"""The repro.balance control plane: policies, executors, equivalence.

Covers the ISSUE's edge cases — a move that would empty the source set,
all-PIDs-in-cooldown, ``reset_pid`` after an elastic event, MovePlan
round-tripping through each executor — plus the acceptance criterion
that ``SlopeEMAPolicy`` through the control plane is decision-for-
decision identical to feeding the raw §2.5.2 ``DynamicController`` the
same signals.
"""
import numpy as np
import pytest

from repro.balance import (
    AdvisoryExecutor,
    CostRefreshPolicy,
    HysteresisPolicy,
    LoadSignal,
    MovePlan,
    NodeMoveExecutor,
    SlopeEMAPolicy,
    make_rebalancer,
)
from repro.core import (
    DistributedSimulator,
    DynamicController,
    DynamicControllerConfig,
    MoveInstruction,
    SimulatorConfig,
    apply_move,
)


# --------------------------------------------------------------------------- #
# apply_move / DynamicController edge cases
# --------------------------------------------------------------------------- #
def test_apply_move_exact_size_never_empties():
    sets = [np.arange(0, 5), np.arange(5, 20)]
    new, moved = apply_move(sets, MoveInstruction(src=0, dst=1, n_move=5))
    assert moved == 4 and new[0].size == 1
    assert np.array_equal(np.sort(np.concatenate(new)), np.arange(20))


def test_apply_move_singleton_source_is_noop():
    sets = [np.array([3]), np.arange(4, 20)]
    new, moved = apply_move(sets, MoveInstruction(src=0, dst=1, n_move=1))
    assert moved == 0
    assert np.array_equal(new[0], sets[0])
    assert np.array_equal(new[1], sets[1])


def test_controller_all_pids_in_cooldown():
    """k=2: one fire freezes both; no move can fire until Z expires."""
    cfg = DynamicControllerConfig(k=2, target_error=1e-6, z=5)
    ctl = DynamicController(cfg)
    sizes = np.array([100, 100])
    fired_at = None
    for t in range(20):
        rs = np.array([1e-1, 10.0 ** (-3 - t)])  # huge persistent skew
        mv = ctl.update(rs, sizes)
        if mv is not None:
            if fired_at is None:
                fired_at = t
            else:
                # refire only after the full cooldown window
                assert t - fired_at >= cfg.z
                fired_at = t
    assert fired_at is not None
    # immediately after a fire both PIDs sit in cooldown -> no eligible pair
    assert (ctl.cooldown > 0).all() or fired_at is not None


def test_controller_reset_pid_after_elastic_event():
    cfg = DynamicControllerConfig(k=3, target_error=1e-6, z=4)
    ctl = DynamicController(cfg)
    sizes = np.full(3, 90)
    for t in range(5):
        ctl.update(np.array([1e-1, 10.0 ** (-2 - t), 10.0 ** (-4 - t)]),
                   sizes)
    assert abs(ctl.slope[1]) > 0
    ctl.reset_pid(1)
    assert ctl.slope[1] == 0.0
    assert ctl.cooldown[1] == cfg.z
    # the re-seeded PID cannot be picked while its cooldown runs
    mv = ctl.update(np.array([1e-1, 1e-30, 1e-8]), sizes)
    if mv is not None:
        assert 1 not in (mv.src, mv.dst)


# --------------------------------------------------------------------------- #
# MovePlan round-trips
# --------------------------------------------------------------------------- #
def test_moveplan_instruction_roundtrip():
    plan = MovePlan(src=2, dst=0, units=7, kind="bucket")
    mi = plan.to_instruction()
    assert (mi.src, mi.dst, mi.n_move) == (2, 0, 7)
    back = MovePlan.from_instruction(mi, kind="bucket")
    assert back == plan


def test_moveplan_validation():
    with pytest.raises(ValueError):
        MovePlan(src=0, dst=0, units=1)
    with pytest.raises(ValueError):
        MovePlan(src=0, dst=1, units=0)
    with pytest.raises(ValueError):
        MovePlan(src=0, dst=1, units=1, kind="galaxy")


def test_moveplan_through_node_executor(small_pagerank):
    p, b, _ = small_pagerank
    cfg = SimulatorConfig(k=4, target_error=1e-6, eps=0.15)
    sim = DistributedSimulator(p, b, cfg)
    ex = NodeMoveExecutor(sim)
    size0 = sim.sets[0].size
    active_before = sim.count_active.copy()
    moved = ex.apply(MovePlan(src=0, dst=2, units=10, kind="node"))
    assert moved == 10
    assert sim.sets[0].size == size0 - 10
    assert (sim.owner[sim.sets[2]] == 2).all()
    # §2.4 reassignment cost lands on BOTH PIDs, via the executor
    assert sim.count_active[0] - active_before[0] == 10
    assert sim.count_active[2] - active_before[2] == 10
    assert sim.debt[0] == -10 and sim.debt[2] == -10
    assert sim.n_moves == 1
    # a plan that would empty the source is clipped, never emptied
    moved = ex.apply(MovePlan(src=0, dst=1, units=10_000, kind="node"))
    assert moved > 0
    assert sim.sets[0].size == 1


def test_moveplan_through_advisory_executor():
    ex = AdvisoryExecutor(kind="device")
    p1 = MovePlan(src=3, dst=0, units=2, kind="device")
    p2 = MovePlan(src=1, dst=2, units=1, kind="device")
    assert ex.apply(p1) == 2
    assert ex.apply(p2) == 1
    assert ex.log == [p1, p2]
    assert ex.drain() == [p1, p2]
    assert ex.log == []


# bucket-executor round-trip rides in the multi-device subprocess test
# (tests/test_distributed_engine.py) where >1 fake device exists.


# --------------------------------------------------------------------------- #
# policies
# --------------------------------------------------------------------------- #
def test_slope_ema_policy_matches_raw_controller():
    """Same signals -> same decisions as the bare DynamicController."""
    rng = np.random.default_rng(0)
    k = 6
    pol = SlopeEMAPolicy(k=k, target_error=1e-6, z=3)
    ctl = DynamicController(DynamicControllerConfig(k=k, target_error=1e-6,
                                                    z=3))
    sizes = np.full(k, 200)
    for t in range(60):
        vals = 10.0 ** (-rng.uniform(0, 6, k) - t / 10.0)
        plans = pol.propose(LoadSignal.from_residuals(vals, sizes, step=t))
        mi = ctl.update(vals, sizes)
        if mi is None:
            assert plans == []
        else:
            assert len(plans) == 1
            assert (plans[0].src, plans[0].dst, plans[0].units) == (
                mi.src, mi.dst, mi.n_move)


def test_cost_refresh_policy_moves_toward_balance():
    pol = CostRefreshPolicy(k=4, period=5, tol=0.1, unit="node")
    sizes = np.array([400, 200, 200, 200])
    vals = np.array([8.0, 1.0, 1.0, 1.0])  # worker 0 does 8x the work
    plans = []
    for t in range(5):
        plans = pol.propose(LoadSignal.from_edge_ops(vals, sizes, step=t))
    assert plans, "periodic refresh must fire on persistent imbalance"
    assert all(p.src == 0 for p in plans)
    assert all(p.units >= 1 for p in plans)


def test_cost_refresh_policy_quiet_when_balanced():
    pol = CostRefreshPolicy(k=4, period=3, tol=0.2)
    sizes = np.full(4, 100)
    for t in range(12):
        assert pol.propose(
            LoadSignal.from_edge_ops(np.full(4, 5.0), sizes, step=t)
        ) == []


def test_hysteresis_policy_patience_and_batching():
    pol = HysteresisPolicy(k=6, target_error=1e-6, z=4, patience=3,
                           max_moves=2, deadband=0.05)
    sizes = np.full(6, 300)
    vals = np.array([1e-1, 1e-1, 1e-4, 1e-4, 1e-9, 1e-9])
    fired = []
    for t in range(10):
        plans = pol.propose(LoadSignal.from_residuals(vals, sizes, step=t))
        fired.append(plans)
        if plans:
            break
    n_empty = sum(1 for p in fired if not p)
    assert n_empty >= pol.patience - 1, "deadband must delay the first fire"
    batch = fired[-1]
    assert 1 <= len(batch) <= 2
    # slowest worker sheds first; both moves pair extremes
    assert batch[0].src in (0, 1) and batch[0].dst in (4, 5)


def test_make_rebalancer_dispatch_and_unknown():
    for name, cls in [("slope_ema", SlopeEMAPolicy),
                      ("cost_refresh", CostRefreshPolicy),
                      ("hysteresis", HysteresisPolicy)]:
        pol = make_rebalancer(name, k=4, target_error=1e-6, unit="bucket")
        assert isinstance(pol, cls)
        assert pol.unit == "bucket"
    with pytest.raises(ValueError):
        make_rebalancer("nope", k=4, target_error=1e-6)


# --------------------------------------------------------------------------- #
# acceptance: control-plane SlopeEMA == historical inline controller
# --------------------------------------------------------------------------- #
class _RecordingRebalancer:
    """Wraps a policy; records every (signal, decision) pair."""

    def __init__(self, inner):
        self.inner = inner
        self.signals = []
        self.plans = []

    def propose(self, sig):
        self.signals.append((sig.values.copy(), sig.sizes.copy()))
        plans = self.inner.propose(sig)
        self.plans.extend(plans)
        return plans

    def reset_worker(self, k):
        self.inner.reset_worker(k)


def test_simulator_slope_ema_decision_equivalence(skewed_pagerank):
    """Replaying the recorded signals through a raw DynamicController must
    reproduce the exact move sequence the control plane executed — and the
    ``dynamic=True`` legacy flag must give the identical seeded run."""
    p, b, _ = skewed_pagerank
    te = 1.0 / p.n
    cfg = SimulatorConfig(k=8, target_error=te, eps=0.15, record_every=50)
    rec = _RecordingRebalancer(
        SlopeEMAPolicy(k=8, target_error=te, unit="node"))
    sim = DistributedSimulator(p, b, cfg, rebalancer=rec)
    res = sim.run()
    assert res.converged and len(res.move_log) >= 1

    # 1) decision-for-decision identity vs the bare §2.5.2 controller
    ctl = DynamicController(DynamicControllerConfig(k=8, target_error=te))
    replayed = []
    for vals, sizes in rec.signals:
        mi = ctl.update(vals, sizes)
        if mi is not None:
            replayed.append((mi.src, mi.dst, mi.n_move))
    proposed = [(pl.src, pl.dst, pl.units) for pl in rec.plans]
    assert replayed == proposed

    # 2) the legacy dynamic=True flag builds the same policy: identical run
    cfg2 = SimulatorConfig(k=8, target_error=te, eps=0.15, dynamic=True,
                           record_every=50)
    res2 = DistributedSimulator(p, b, cfg2).run()
    assert res2.move_log == res.move_log
    assert res2.cost_iterations == res.cost_iterations
    assert res2.n_steps == res.n_steps
    np.testing.assert_array_equal(res2.h, res.h)


# --------------------------------------------------------------------------- #
# runtime adapters
# --------------------------------------------------------------------------- #
def test_straggler_monitor_reseed_and_log():
    from repro.runtime import StragglerMonitor

    mon = StragglerMonitor(n_hosts=4, z=2)
    mv = None
    for _ in range(8):
        mv = mon.advise(np.array([0.1, 0.1, 0.1, 0.9])) or mv
    assert mv is not None and mv.src == 3 and mv.kind == "device"
    assert len(mon.executor.log) >= 1
    mon.reseed()
    assert (mon.policy.ctl.slope == 0).all()
    assert (mon.policy.ctl.cooldown > 0).all()


def test_expert_load_monitor_flags_hot_expert():
    from repro.runtime import ExpertLoadMonitor

    mon = ExpertLoadMonitor(n_experts=4, z=2)
    plans = []
    for _ in range(8):
        plans += mon.observe(np.array([900.0, 10.0, 10.0, 10.0]))
    assert plans and plans[0].src == 0
    assert all(p.kind == "expert-shard" for p in plans)
    # wrong-width observation is ignored, not fatal
    assert mon.observe(np.array([1.0, 2.0])) == []


def test_moe_expert_tap_feeds_sink():
    import jax
    import jax.numpy as jnp

    from repro.models.transformer import (
        MoEConfig, TransformerConfig, init_params, set_expert_load_sink,
        train_loss)

    cfg = TransformerConfig(
        name="t", n_layers=1, d_model=16, n_heads=2, n_kv_heads=1,
        d_ff=32, vocab=32, dtype=jnp.float32, ce_chunk=8,
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=8, n_shared=0,
                      pad_experts_to=4))
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, 32, (2, 8)), jnp.int32),
             "labels": jnp.asarray(rng.integers(0, 32, (2, 8)), jnp.int32)}
    seen = []
    set_expert_load_sink(seen.append)
    try:
        loss = jax.jit(lambda p, b: train_loss(p, b, cfg))(params, batch)
        jax.block_until_ready(loss)
    finally:
        set_expert_load_sink(None)
    assert seen, "expert-load tap must fire under jit"
    assert seen[0].shape == (4,)
    assert seen[0].sum() == 2 * 8 * 2  # every (token, top-k slot) routed
