"""Serving-tier tests: session pool LRU, pow2 lane padding, the
continuous batcher's slot lifecycle, scheduler end-to-end parity, and
the ``--no-batching`` sequential-path regression contract.

The pool corners ISSUE 9 names explicitly: an evicted H forces the cold
path (never a wrong answer); a pool hit after ``update_graph`` must
miss on the stale ``store_version``; capacity-1 and churn-under-
eviction behave.
"""
import os
import re
import subprocess
import sys

import numpy as np
import pytest

import repro
from repro.core import webgraph_like
from repro.graph import GraphStore, rotation_churn
from repro.serving import (ContinuousBatcher, Request, RequestQueue,
                           Scheduler, SessionPool, solo_reference)

ROOT = os.path.join(os.path.dirname(__file__), "..")
SRC = os.path.join(ROOT, "src")
SERVE_SCRIPT_TIMEOUT = 600


def store_problem(n=300, seed=1, target_error=None):
    store = GraphStore.from_csr(webgraph_like(n, seed=seed))
    return repro.Problem.pagerank(store, target_error=target_error)


def drifting_bs(problem, count, drift=0.05, seed=0):
    rng = np.random.default_rng(seed)
    b = np.asarray(problem.b, dtype=np.float64)
    out = []
    for _ in range(count):
        b = np.abs(b * (1.0 + drift * rng.standard_normal(problem.n)))
        out.append(b)
    return out


# --------------------------------------------------------------------------- #
# SessionPool: LRU + versioning corners
# --------------------------------------------------------------------------- #
def test_pool_capacity_one_evicts_previous():
    pool = SessionPool(capacity=1)
    pool.put(0, 0, h="hA")
    pool.put(0, 1, h="hB")          # evicts (0, 0)
    assert pool.get(0, 0) is None   # the evicted entry is gone (miss)
    assert pool.get(0, 1).h == "hB"
    assert pool.evictions == 1 and len(pool) == 1


def test_pool_lru_order_refreshed_by_get():
    pool = SessionPool(capacity=2)
    pool.put(0, 0, h="a")
    pool.put(0, 1, h="b")
    assert pool.get(0, 0).h == "a"  # refreshes (0,0): (0,1) is now LRU
    pool.put(0, 2, h="c")
    assert pool.get(0, 1) is None   # (0,1) was evicted, not (0,0)
    assert pool.get(0, 0).h == "a"


def test_pool_stale_store_version_misses():
    pool = SessionPool(capacity=4)
    pool.put(0, 7, h="old")
    assert pool.get(1, 7) is None   # same cluster, bumped version: miss
    assert pool.invalidate(keep_version=1) == 1
    assert pool.get(0, 7) is None and len(pool) == 0


def test_pool_churn_under_eviction_stays_bounded():
    pool = SessionPool(capacity=3)
    for i in range(40):
        pool.put(0, i % 7, h=f"h{i}")
        assert len(pool) <= 3
    assert pool.evictions > 0
    # the 3 most recently put clusters are resident
    assert pool.get(0, 39 % 7) is not None


def test_pool_none_version_keys_as_zero():
    pool = SessionPool(capacity=2)
    e = pool.put(None, 4, h="x")
    assert e.store_version == 0
    assert pool.get(None, 4) is e and pool.get(0, 4) is e


# --------------------------------------------------------------------------- #
# pow2 bucket padding (satellite: retrace fix + bit parity)
# --------------------------------------------------------------------------- #
def test_solve_batch_padding_bit_parity_and_waste():
    problem = store_problem()
    bs = np.stack(drifting_bs(problem, 3), axis=1)       # C=3 -> bucket 4
    r_pad = repro.SolverSession(problem).solve_batch(bs, pad=True)
    r_raw = repro.SolverSession(problem).solve_batch(bs, pad=False)
    assert r_pad.converged and r_raw.converged
    assert np.array_equal(r_pad.x, r_raw.x)              # bitwise
    assert r_pad.extras["ops_per_column"] == r_raw.extras["ops_per_column"]
    assert r_pad.extras["bucket"] == 4
    assert r_pad.extras["padding_waste"] == pytest.approx(0.25)
    assert r_raw.extras["bucket"] == 3
    assert r_raw.extras["padding_waste"] == 0.0


def test_solve_batch_same_bucket_reuses_trace():
    from repro.api.session import _batch_fns

    problem = store_problem()
    session = repro.SolverSession(problem)
    bs = drifting_bs(problem, 4)
    session.solve_batch(np.stack(bs[:3], axis=1))        # bucket 4
    fns = _batch_fns()
    cached = fns["solve"]._cache_size()
    session.solve_batch(np.stack(bs, axis=1))            # C=4: same bucket
    assert fns["solve"]._cache_size() == cached, (
        "a same-bucket batch width recompiled the solve kernel")


# --------------------------------------------------------------------------- #
# ContinuousBatcher: slot lifecycle
# --------------------------------------------------------------------------- #
def test_batcher_staggered_retire_and_refill():
    problem = store_problem()
    tol = problem.target_error * problem.eps
    bs = drifting_bs(problem, 3)
    bat = ContinuousBatcher(problem, max_lanes=2, min_lanes=2)
    # lane 0 gets a LOOSE tolerance (retires early), lane 1 a tight one
    bat.admit(Request(0, bs[0]), now=0.0, tol=tol * 1e3,
              until_eff=problem.target_error * 1e3)
    bat.admit(Request(1, bs[1]), now=0.0, tol=tol,
              until_eff=problem.target_error)
    assert bat.occupied == 2 and not bat.has_capacity
    retired = []
    for _ in range(400):
        retired += bat.micro(8).retired
        if retired:
            break
    assert [r.info.request.request_id for r in retired] == [0], (
        "the loose lane should retire first, alone")
    # the freed slot takes the queued request while lane 1 is in flight
    lane = bat.admit(Request(2, bs[2]), now=1.0, tol=tol,
                     until_eff=problem.target_error)
    assert lane == 0 and bat.occupied == 2
    for _ in range(2000):
        retired += bat.micro(32).retired
        if bat.occupied == 0:
            break
    assert sorted(r.info.request.request_id for r in retired) == [0, 1, 2]
    assert all(not r.degraded for r in retired)
    assert bat.retired_total == 3 and bat.occupied == 0


def test_batcher_graph_switch_requires_drain():
    problem = store_problem()
    tol = problem.target_error * problem.eps
    bat = ContinuousBatcher(problem, max_lanes=2)
    bat.admit(Request(0, np.asarray(problem.b)), now=0.0, tol=tol,
              until_eff=problem.target_error)
    with pytest.raises(RuntimeError, match="drain"):
        bat.graph_switched(problem)


# --------------------------------------------------------------------------- #
# Scheduler: end-to-end parity, pool reuse, eviction, staleness
# --------------------------------------------------------------------------- #
def test_scheduler_parity_and_pool_hits():
    problem = store_problem()
    te = problem.target_error
    bs = drifting_bs(problem, 6)
    sch = Scheduler(problem, max_lanes=4, rounds_per_tick=16,
                    deadline_s=1e9)
    for i, b in enumerate(bs):
        sch.submit(b, cluster=i % 2, request_id=i)
        sch.run_until_idle()
    assert len(sch.results) == 6 and sch.dropped == 0
    # first request of each cluster is cold, the rest re-enter warm
    hits = [r.pool_hit for r in sorted(sch.results,
                                       key=lambda r: r.request_id)]
    assert hits == [False, False, True, True, True, True]
    xs, _, _ = solo_reference(problem, np.stack(bs, axis=1))
    for r in sch.results:
        dx = float(np.abs(r.x - xs[:, r.request_id]).sum())
        assert dx <= 2.0 * te, (r.request_id, dx)
        assert r.converged and not r.degraded


def test_scheduler_eviction_forces_cold_path():
    problem = store_problem()
    sch = Scheduler(problem, max_lanes=2, pool_capacity=1,
                    deadline_s=1e9)
    # c0 cold -> c1 cold (evicts c0's H) -> c0 cold AGAIN -> c0 warm
    for i, cluster in enumerate([0, 1, 0, 0]):
        sch.submit(drifting_bs(problem, 1, seed=10 + i)[0],
                   cluster=cluster, request_id=i)
        sch.run_until_idle()
    hits = [r.pool_hit for r in sorted(sch.results,
                                       key=lambda r: r.request_id)]
    assert hits == [False, False, False, True]
    assert sch.pool.evictions >= 2
    assert all(r.converged for r in sch.results)


def test_scheduler_update_invalidates_pool():
    problem = store_problem()
    sch = Scheduler(problem, max_lanes=2, deadline_s=1e9)
    sch.submit(drifting_bs(problem, 1)[0], cluster=0, request_id=0)
    sch.run_until_idle()
    # touching .graph materializes the Problem's own store for p
    v0 = sch.problem.graph.version
    delta = rotation_churn(sch.problem.graph, 2, seed=42)
    sch.submit_update(delta, store_version=v0)
    sch.run_until_idle()
    assert sch.problem.store_version == v0 + 1
    assert sch.pool.invalidations >= 1      # pre-delta H was dropped
    # post-update same-cluster request: stale version can never hit
    sch.submit(drifting_bs(problem, 1, seed=9)[0], cluster=0,
               request_id=1)
    sch.run_until_idle()
    by_id = {r.request_id: r for r in sch.results}
    assert by_id[1].pool_hit is False and by_id[1].converged
    # and the freshly banked post-update H hits
    sch.submit(drifting_bs(problem, 1, seed=11)[0], cluster=0,
               request_id=2)
    sch.run_until_idle()
    assert {r.request_id: r.pool_hit
            for r in sch.results}[2] is True


def test_scheduler_overload_sheds_quality_not_requests():
    problem = store_problem()
    bs = drifting_bs(problem, 12)
    sch = Scheduler(problem, max_lanes=2, rounds_per_tick=8,
                    deadline_s=0.005, queue_cap=4)
    for i, b in enumerate(bs):
        sch.submit(b, cluster=i % 2, request_id=i,
                   arrival_t=i * 1e-4)    # far beyond service capacity
    sch.run_until_idle()
    assert len(sch.results) == 12 and sch.dropped == 0
    assert sch.log.counts().get("degrade", 0) >= 1
    assert any(r.degraded for r in sch.results)
    # degraded responses still carry the tolerance they WERE served at
    for r in sch.results:
        if r.degraded and r.converged:
            assert r.until_eff >= problem.target_error


def test_scheduler_quarantines_poison_and_survives():
    from repro.resilience import RequestRejected

    problem = store_problem()
    sch = Scheduler(problem, max_lanes=2, deadline_s=1e9)
    bad = np.asarray(problem.b, dtype=np.float64).copy()
    bad[17] = np.nan
    with pytest.raises(RequestRejected):
        sch.submit(bad, request_id=0)
    sch.submit(drifting_bs(problem, 1)[0], request_id=1)
    sch.run_until_idle()
    assert [r.request_id for r in sch.results] == [1]
    assert sch.quarantine.total == 1 and sch.dropped == 0


def test_queue_backlog_accounting():
    q = RequestQueue()
    q.push(Request(0, b=None, arrival_t=1.0))
    q.push(Request(1, b=None, arrival_t=2.0))
    assert q.depth == 2 and q.depth_peak == 2
    assert q.oldest_wait(5.0) == pytest.approx(4.0)
    first = q.pop()
    q.push_front(first)             # saturation requeue keeps order
    assert q.pop().request_id == 0 and q.pop().request_id == 1
    assert q.enqueued == 2 and q.dequeued == 2


def test_queue_depth_load_signal():
    from repro.balance import LoadSignal

    sig = LoadSignal.from_queue(oldest_wait_s=0.02, deadline_s=0.01,
                                queue_depth=4, queue_cap=8, step=3)
    assert sig.kind == "queue-depth"
    assert float(sig.values[0]) == pytest.approx(2.0 + 0.5)


# --------------------------------------------------------------------------- #
# serve.py rank: --no-batching stays the pre-scheduler path
# --------------------------------------------------------------------------- #
def test_serve_cli_no_batching_matches_sequential_replay():
    """The escape hatch is bit-identical to the pre-PR-8 loop: the
    [cold]/[warm] op counts in its stdout equal an in-process replay of
    the original sequential semantics (same seeds, same session)."""
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "rank",
         "--n", "300", "--requests", "2", "--batch", "2",
         "--no-batching"],
        capture_output=True, text=True, timeout=SERVE_SCRIPT_TIMEOUT,
        env={**os.environ, "PYTHONPATH": os.path.abspath(SRC)},
        cwd=ROOT,
    )
    assert r.returncode == 0, r.stderr[-4000:]
    cold = re.search(r"\[cold \] (\d+) edge pushes", r.stdout)
    warms = re.findall(r"\[warm (\d+)\] \S+ (\d+) ops", r.stdout)
    assert cold and len(warms) == 2, r.stdout
    # in-process replay of the pre-scheduler loop, same seeded stream
    rng = np.random.default_rng(0)
    g = webgraph_like(300, seed=1)
    problem = repro.Problem.pagerank(g)
    session = repro.SolverSession(problem, method="frontier:segment_sum")
    rep = session.solve()
    assert int(cold.group(1)) == rep.n_ops
    b = problem.b
    for req in range(2):
        b = np.abs(b * (1.0 + 0.02 * rng.standard_normal(g.n)))
        session.warm_start(b)
        rep = session.solve()
        assert warms[req] == (str(req), str(rep.n_ops))


def test_serve_cli_batched_is_default_and_serves():
    """Without --no-batching the stream routes through the scheduler:
    [mode]/[served]/[stats] lines appear and nothing is dropped."""
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "rank",
         "--n", "300", "--requests", "3", "--max-lanes", "4"],
        capture_output=True, text=True, timeout=SERVE_SCRIPT_TIMEOUT,
        env={**os.environ, "PYTHONPATH": os.path.abspath(SRC)},
        cwd=ROOT,
    )
    assert r.returncode == 0, r.stderr[-4000:]
    assert "[mode ] continuous batching" in r.stdout
    assert len(re.findall(r"\[served \d+\]", r.stdout)) == 3
    assert re.search(r"\[stats\] served=3 dropped=0", r.stdout)
