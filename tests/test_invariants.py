"""Fluid-conservation + residual-monotonicity oracles, all six backends.

The D-iteration's defining invariant (§2.2, and the restore oracle of
the chaos harness): along ANY schedule, ``B = (I−P)·H + F`` where F
includes in-flight fluid.  After every round/exchange each backend must
satisfy ``|B − (I−P)H − F|₁ ≤ ε`` (ε scaled to the backend's compute
dtype) and report a monotonically non-increasing residual — for
nonnegative PageRank systems every diffusion strictly shrinks |F|₁ by
the dangling/damping leak, and an exchange only relocates it.

``repro.api.session._invariant_violation`` is the single shared
implementation — the same function ``SolverSession.restore`` uses to
reject torn checkpoints, so this suite is also the chaos harness's
oracle pinned under test.
"""
import numpy as np
import pytest

import repro
from repro.api.session import _invariant_violation
from repro.core import pagerank_system, power_law_graph

# (method, session kwargs, invariant rtol) — f64 backends get a tight
# bound, f32 ones a dtype-scaled bound
F64_RTOL = 1e-10
F32_RTOL = 1e-5


@pytest.fixture(scope="module")
def problem400():
    g = power_law_graph(400, seed=3)
    return repro.Problem.pagerank(g, target_error=1e-6)


def _check(problem, snapshots, rtol, method):
    """snapshots: iterable of (h, f_total, residual) after each grain."""
    prev = np.inf
    n_checked = 0
    for h, f, resid in snapshots:
        viol = _invariant_violation(problem, problem.b, h, f)
        scale = max(1.0, float(np.abs(problem.b).sum() + np.abs(h).sum()))
        assert viol <= rtol * scale, (
            f"{method}: conservation broken at grain {n_checked}: "
            f"{viol:.3e} > {rtol * scale:.3e}"
        )
        assert resid <= prev * (1 + 1e-6) + 1e-12, (
            f"{method}: residual increased at grain {n_checked}: "
            f"{resid:.6e} > {prev:.6e}"
        )
        prev = resid
        n_checked += 1
    assert n_checked >= 3, f"{method}: too few grains observed"


# --------------------------------------------------------------------------- #
# session-driven backends (frontier + engine), grain = trace round / chunk
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("method,opts,rtol", [
    ("frontier:segment_sum", {}, F32_RTOL),
    ("frontier:pallas", {"interpret": True, "bs": 64}, F32_RTOL),
    ("engine:chunk", {}, F32_RTOL),
    ("engine:bsr", {}, F32_RTOL),
])
def test_session_backends_conserve_fluid(problem400, method, opts, rtol):
    options = repro.SolverOptions(trace_every=4, **opts)
    session = repro.SolverSession(problem400, method=method,
                                  options=options)

    def snapshots():
        for rep in session.run():
            f, h = session._driver.fluid()
            yield h, f, rep.residual

    _check(problem400, snapshots(), rtol, method)
    assert session.residual <= problem400.tol


# --------------------------------------------------------------------------- #
# sequential — observer hook, grain = threshold sweep
# --------------------------------------------------------------------------- #
def test_sequential_conserves_fluid(problem400):
    from repro.core.diteration import run_sequential

    recs = []
    res = run_sequential(
        problem400.p, problem400.b, target_error=1e-6, eps=0.15,
        observer=lambda f, h: recs.append(
            (h.copy(), f.copy(), float(np.abs(f).sum()))),
    )
    assert res.residual <= problem400.tol
    _check(problem400, recs, F64_RTOL, "sequential")


# --------------------------------------------------------------------------- #
# simulator — manual step loop, invariant checked after EVERY exchange,
# F includes the in-flight outboxes
# --------------------------------------------------------------------------- #
def _sim_snapshots(sim, max_steps=50_000):
    step = 0
    while step < max_steps:
        step += 1
        for k in range(sim.k):
            sim._local_step(k)
        for k in range(sim.k):
            if sim.s_abs[k] > 0 and sim.s_abs[k] > sim.r_of(k) / 2.0:
                sim._exchange(k)
                yield (sim.h.copy(), sim.f + np.sum(sim.outbox, axis=0),
                       sim.global_residual())
        if sim.rebalancer is not None:
            sim._repartition(step)
        yield (sim.h.copy(), sim.f + np.sum(sim.outbox, axis=0),
               sim.global_residual())
        if sim.global_residual() <= sim.tol:
            return


@pytest.mark.parametrize("dynamic", [False, True])
def test_simulator_conserves_fluid(problem400, dynamic):
    from repro.core.simulator import DistributedSimulator, SimulatorConfig

    cfg = SimulatorConfig(k=4, target_error=1e-6, eps=0.15, mode="batch",
                          dynamic=dynamic)
    sim = DistributedSimulator(problem400.p, problem400.b, cfg)
    _check(problem400, _sim_snapshots(sim), F64_RTOL,
           f"simulator(dynamic={dynamic})")
    assert sim.global_residual() <= sim.tol


# --------------------------------------------------------------------------- #
# the same oracle under chaos — recovery must land back ON the manifold
# --------------------------------------------------------------------------- #
def test_simulator_chaos_preserves_invariant(problem400):
    """kill + rescale relocate capacity, never fluid: conservation holds
    to f64 precision through both events (the chaos-recovery oracle)."""
    from repro.chaos import ChaosPlan
    from repro.core.simulator import DistributedSimulator, SimulatorConfig

    cfg = SimulatorConfig(k=4, target_error=1e-6, eps=0.15, mode="batch",
                          dynamic=True)
    sim = DistributedSimulator(problem400.p, problem400.b, cfg)
    plan = ChaosPlan(seed=0).straggler(1, 4.0, round=2).kill(
        3, round=5).rescale(2, round=9)
    res = sim.run(chaos=plan)
    assert res.converged
    assert [k for _, k in res.chaos_log] == ["straggler", "kill",
                                             "rescale"]
    f_total = sim.f + np.sum(sim.outbox, axis=0)
    viol = _invariant_violation(problem400, problem400.b, sim.h, f_total)
    assert viol <= F64_RTOL * max(
        1.0, float(np.abs(problem400.b).sum() + np.abs(sim.h).sum()))


def test_restored_session_satisfies_invariant(problem400, tmp_path):
    """A checkpoint/restore round trip stays on the manifold — and a
    torn checkpoint (invariant violator) is rejected, not resumed."""
    from repro.chaos import tear_checkpoint

    session = repro.SolverSession(problem400,
                                  method="frontier:segment_sum")
    for i, _ in enumerate(session.run()):
        if i >= 2:
            break
    session.checkpoint(str(tmp_path))
    restored = repro.SolverSession.restore(str(tmp_path), problem400)
    f, h = restored._driver.fluid()
    viol = _invariant_violation(problem400, restored._b, h, f)
    assert viol <= F32_RTOL * max(
        1.0, float(np.abs(restored._b).sum() + np.abs(h).sum()))
    # tear the only checkpoint: restore must refuse loudly
    tear_checkpoint(str(tmp_path / "step_000000001"))
    with pytest.raises(ValueError, match="invariant violated"):
        repro.SolverSession.restore(str(tmp_path), problem400)
