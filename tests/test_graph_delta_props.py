"""Property-based GraphDelta tests: random delta SEQUENCES stay bit-exact.

tests/test_graph_store.py pins handcrafted deltas; this suite drives the
patchers through *randomized multigraphs* and randomized
add/remove/reweight delta sequences, asserting after EVERY step that
each materialized view (CSR splice, BSR tile pool, bucketed layout,
tiled engine layout) is bit-identical to a from-scratch rebuild over the
patched edge list — the tier-2 graph-update-parity contract, now
explored instead of sampled.

With hypothesis installed the seeds are drawn by the shrinker; without
it the same core property runs over a deterministic seed sweep (the
test_kernels.py fallback pattern), folded from ``--repro-seed`` so a
logged failure replays exactly.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # optional dep: property tests skip, fallbacks run
    HAVE_HYPOTHESIS = False

from repro.graph import GraphDelta, GraphStore

BS = 8
N_BUCKETS = 3
ENGINE_KEY = (2, 4, 2, True, np.float32)  # k, b/dev, headroom, tiled, dtype


# --------------------------------------------------------------------------- #
# generators (plain-numpy so hypothesis and the fallback share them)
# --------------------------------------------------------------------------- #
def _random_store(seed: int) -> GraphStore:
    """A random multigraph: duplicate (src, dst) pairs and self-loops
    included — from_edges canonicalizes by weight summation."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(8, 48))
    m = int(rng.integers(0, 4 * n))
    src = rng.integers(0, n, size=m)
    dst = rng.integers(0, n, size=m)
    w = rng.uniform(0.1, 2.0, size=m)
    return GraphStore.from_edges(src, dst, w, n)


def _random_delta(store: GraphStore, rng: np.random.Generator) -> GraphDelta:
    """Disjoint random add/remove/reweight picks over the current edges."""
    csr = store.csr()
    src_e, dst_e, w_e = csr.edge_list()
    n, n_e = store.n, src_e.shape[0]
    k_total = int(rng.integers(0, n_e + 1)) if n_e else 0
    pick = (rng.choice(n_e, size=k_total, replace=False)
            if k_total else np.zeros(0, np.int64))
    n_rm = int(rng.integers(0, k_total + 1))
    rm, rw = pick[:n_rm], pick[n_rm:]
    existing = set(
        (int(s) << 32) | int(d) for s, d in zip(src_e, dst_e))
    added = []
    for _ in range(100):
        if len(added) >= int(rng.integers(0, 8)):
            break
        s, d = int(rng.integers(0, n)), int(rng.integers(0, n))
        if ((s << 32) | d) in existing:
            continue
        existing.add((s << 32) | d)
        added.append((s, d, float(rng.uniform(0.1, 2.0))))
    return GraphDelta.make(
        added_edges=np.array(added) if added else None,
        removed_edges=(np.stack([src_e[rm], dst_e[rm]], axis=1)
                       .astype(np.int64) if rm.size else None),
        reweighted=((src_e[rw].astype(np.int64),
                     dst_e[rw].astype(np.int64),
                     w_e[rw] * rng.uniform(0.5, 1.5, size=rw.size))
                    if rw.size else None),
    )


def _assert_bit_identical(patched: GraphStore, fresh: GraphStore,
                          ctx: str) -> None:
    a, b = patched.csr(), fresh.csr()
    np.testing.assert_array_equal(a.indptr, b.indptr, err_msg=ctx)
    np.testing.assert_array_equal(a.indices, b.indices, err_msg=ctx)
    np.testing.assert_array_equal(a.weights, b.weights, err_msg=ctx)

    ta, tb = patched.bsr(BS), fresh.bsr(BS)
    for name in ("block_row", "block_col", "blocks", "row_occupied"):
        np.testing.assert_array_equal(getattr(ta, name), getattr(tb, name),
                                      err_msg=f"{ctx}: bsr.{name}")

    ga, gb = patched.bucketed(N_BUCKETS), fresh.bucketed(N_BUCKETS)
    for name in ("node_of_slot", "slot_of_node", "src_slot", "dst", "wgt",
                 "out_deg"):
        np.testing.assert_array_equal(getattr(ga, name), getattr(gb, name),
                                      err_msg=f"{ctx}: bucketed.{name}")
    assert ga.n_edges == gb.n_edges, ctx

    la, lb = patched.engine_layout(*ENGINE_KEY), fresh.engine_layout(
        *ENGINE_KEY)
    for name in ("w", "src_slot", "dst_bucket", "dst_slot", "wgt",
                 "pos_of_bucket", "node_of_slot", "tiles", "tile_dst",
                 "slot_out_deg"):
        np.testing.assert_array_equal(getattr(la, name), getattr(lb, name),
                                      err_msg=f"{ctx}: engine.{name}")
    assert la.n_edges == lb.n_edges, ctx


def check_delta_sequence(graph_seed: int, delta_seed: int,
                         n_deltas: int) -> None:
    """THE property: after every delta of a random sequence, every
    patched view == a from-scratch rebuild, bit for bit."""
    store = _random_store(graph_seed)
    rng = np.random.default_rng(delta_seed)
    # materialize every view BEFORE the churn so each patcher exercises
    store.bsr(BS)
    store.bucketed(N_BUCKETS)
    store.engine_layout(*ENGINE_KEY)
    for i in range(n_deltas):
        delta = _random_delta(store, rng)
        version = store.version
        store.apply_delta(delta)
        if delta.is_empty:
            assert store.version == version
            continue
        assert store.version == version + 1
        fresh = GraphStore.from_csr(store.csr())
        _assert_bit_identical(
            store, fresh,
            ctx=f"graph_seed={graph_seed} delta_seed={delta_seed} "
                f"step={i} ({delta.n_changes} changes)")


# --------------------------------------------------------------------------- #
# hypothesis-driven exploration
# --------------------------------------------------------------------------- #
if HAVE_HYPOTHESIS:

    @settings(max_examples=25, deadline=None)
    @given(graph_seed=st.integers(0, 2**31 - 1),
           delta_seed=st.integers(0, 2**31 - 1),
           n_deltas=st.integers(1, 4))
    def test_delta_sequences_bit_identical_prop(graph_seed, delta_seed,
                                                n_deltas):
        check_delta_sequence(graph_seed, delta_seed, n_deltas)


# --------------------------------------------------------------------------- #
# deterministic fallbacks (always run; the only coverage without
# hypothesis — same pattern as test_kernels.py / test_partition.py)
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("case", range(8))
def test_delta_sequences_bit_identical_fallback(case, repro_seed):
    check_delta_sequence(graph_seed=repro_seed + 101 * case,
                         delta_seed=repro_seed + 7919 * case + 1,
                         n_deltas=3)


def test_fallback_sweep_actually_mutates(repro_seed):
    """Guard against a vacuous property: the deterministic sweep must
    exercise non-empty deltas of all three kinds somewhere."""
    kinds = set()
    for case in range(8):
        store = _random_store(repro_seed + 101 * case)
        rng = np.random.default_rng(repro_seed + 7919 * case + 1)
        for _ in range(3):
            d = _random_delta(store, rng)
            if d.added.shape[0]:
                kinds.add("added")
            if d.removed.shape[0]:
                kinds.add("removed")
            if d.reweighted.shape[0]:
                kinds.add("reweighted")
            store.apply_delta(d)
    assert kinds == {"added", "removed", "reweighted"}
