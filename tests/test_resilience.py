"""Self-healing serving: supervisor, retry/breaker, degradation ladder,
admission control, adversarial checkpoint dirs, and the streaming soak
acceptance (subprocess, 8 fake host devices).

Exactness contract under test (DESIGN.md §10): recovery and degradation
REPLAY the schedule an undisturbed twin would have run, so served
solutions agree with the twin exactly (|Δx|₁ = 0), not merely within
tolerance — determinism is the mechanism, checkpoints every request
boundary make it hold through kills.
"""
import json
import os
import stat
import subprocess
import sys
import tempfile
import textwrap

import numpy as np
import pytest

import repro
from repro.balance import LoadSignal, PressurePolicy
from repro.chaos import ChaosPlan, SessionInjector
from repro.core import webgraph_like
from repro.graph import GraphDelta, GraphStore, rotation_churn
from repro.resilience import (DEFAULT_RUNGS, CircuitBreaker,
                              DegradationLadder, EventLog, Quarantine,
                              RequestRejected, RetryPolicy, Rung,
                              SupervisedSession, validate_graph_update,
                              validate_rhs)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
ROOT = os.path.join(os.path.dirname(__file__), "..")


def _problem(n=192, seed=1, **kw):
    return repro.Problem.pagerank(
        GraphStore.from_csr(webgraph_like(n, seed=seed)), **kw)


def _delta(added=None, added_w=None, removed=None,
           reweighted=None, reweighted_w=None):
    z2 = np.zeros((0, 2), dtype=np.int64)
    z1 = np.zeros(0, dtype=np.float64)
    return GraphDelta(
        added=z2 if added is None else np.asarray(added, np.int64),
        added_w=z1 if added_w is None else np.asarray(added_w, float),
        removed=z2 if removed is None else np.asarray(removed, np.int64),
        reweighted=(z2 if reweighted is None
                    else np.asarray(reweighted, np.int64)),
        reweighted_w=(z1 if reweighted_w is None
                      else np.asarray(reweighted_w, float)))


# --------------------------------------------------------------------------- #
# retry / breaker
# --------------------------------------------------------------------------- #
def test_retry_policy_deterministic_backoff():
    rp = RetryPolicy(base_delay_s=0.1, multiplier=2.0, max_delay_s=0.5,
                     jitter=0.5, seed=3)
    # same attempt -> same jittered delay; growth honors base * mult^a
    assert rp.delay_s(1) == rp.delay_s(1)
    for a in (1, 2, 3, 4, 8):
        nominal = min(0.1 * 2.0 ** (a - 1), 0.5)
        assert 0.5 * nominal <= rp.delay_s(a) <= 1.5 * nominal
    # distinct attempts draw distinct jitter
    assert rp.delay_s(1) != rp.delay_s(2)
    with pytest.raises(ValueError):
        RetryPolicy(max_attempts=0)
    with pytest.raises(ValueError):
        RetryPolicy(jitter=1.0)


def test_circuit_breaker_trips_and_resets():
    br = CircuitBreaker(trip_after=3)
    assert not br.record_failure() and not br.record_failure()
    assert br.record_failure() and br.tripped
    br.reset()
    assert not br.tripped and br.trips == 1
    br.record_failure()
    br.record_success()  # success clears the consecutive streak
    assert br.consecutive_failures == 0


# --------------------------------------------------------------------------- #
# pressure signal + policy + ladder
# --------------------------------------------------------------------------- #
def test_load_signal_from_latency():
    sig = LoadSignal.from_latency(0.5, 1.0, queue_depth=4, queue_cap=8)
    assert sig.kind == "latency"
    assert float(sig.values[0]) == pytest.approx(0.5 + 0.5)
    with pytest.raises(ValueError):
        LoadSignal.from_latency(1.0, 0.0)


def test_pressure_policy_hysteresis():
    pol = PressurePolicy(eta=1.0, z=2, hi=1.0, lo=0.5, patience=2)
    hi = LoadSignal.from_latency(2.0, 1.0)
    lo = LoadSignal.from_latency(0.1, 1.0)
    # patience gates the first +1; cooldown suppresses the next
    assert [pol.update(hi) for _ in range(3)] == [0, 1, 0]
    downs = [pol.update(lo) for _ in range(8)]
    assert -1 in downs and 1 not in downs
    with pytest.raises(ValueError):
        PressurePolicy(hi=0.5, lo=0.5)


def test_degradation_ladder_walks_and_saturates():
    lad = DegradationLadder(
        policy=PressurePolicy(eta=1.0, z=0, hi=1.0, lo=0.5, patience=1))
    hi = LoadSignal.from_latency(5.0, 1.0)
    lo = LoadSignal.from_latency(0.01, 1.0)
    top = len(lad.rungs) - 1
    for _ in range(top + 3):  # saturates at the last rung
        lad.observe(hi)
    assert lad.index == top and lad.engaged
    assert lad.until(1e-3) == 1e-3 * lad.rung.target_scale
    for _ in range(top + 3):
        lad.observe(lo)
    assert lad.index == 0 and not lad.engaged
    assert lad.rung.name == "nominal"


def test_rung_validation_and_defaults():
    with pytest.raises(ValueError):
        Rung("bad", target_scale=0.5)
    with pytest.raises(ValueError):
        Rung("bad", occupancy_threshold=1.0)
    names = [r.name for r in DEFAULT_RUNGS]
    assert names[0] == "nominal" and len(names) >= 4
    # monotone: later rungs never tighten the target
    scales = [r.target_scale for r in DEFAULT_RUNGS]
    assert scales == sorted(scales)


# --------------------------------------------------------------------------- #
# admission
# --------------------------------------------------------------------------- #
def test_validate_rhs_rejects_poison():
    n = 8
    good = validate_rhs(np.ones(n), n)
    assert good.dtype == np.float64 and good.shape == (n,)
    for bad, reason in [
        (np.ones(n - 1), "bad-shape"),
        (np.concatenate([[np.nan], np.ones(n - 1)]), "non-finite"),
        (np.concatenate([[-1.0], np.ones(n - 1)]), "negative-mass"),
        (np.zeros(n), "zero-mass"),
    ]:
        with pytest.raises(RequestRejected) as ei:
            validate_rhs(bad, n)
        assert ei.value.reason == reason


def test_validate_graph_update_membership_and_versions():
    prob = _problem(64)
    store = prob.graph
    ok = rotation_churn(store, 2, seed=3)
    validate_graph_update(store, ok, store_version=store.version)
    with pytest.raises(RequestRejected) as ei:
        validate_graph_update(store, ok, store_version=store.version + 5)
    assert ei.value.reason == "stale-store-version"
    # queued deltas shift the logical version the client sees
    validate_graph_update(store, ok, store_version=store.version + 3,
                          queued=3, check_membership=False)
    with pytest.raises(RequestRejected) as ei:
        validate_graph_update(store, "nope")
    assert ei.value.reason == "malformed-delta"
    src, dst, _ = store.csr().edge_list()
    exists = np.array([[src[0], dst[0]]])
    with pytest.raises(RequestRejected) as ei:
        validate_graph_update(store, _delta(
            added=exists, added_w=[0.1]))
    assert ei.value.reason == "duplicate-edge"
    missing = np.array([[int(src[0]), int(dst[0])]])
    # find a (src, dst) pair not in the store
    while True:
        cand = (int(missing[0, 0]), (int(missing[0, 1]) + 1) % store.n)
        keys = set(zip(src.tolist(), dst.tolist()))
        if cand not in keys:
            missing = np.array([cand])
            break
        missing[0, 1] += 1
    with pytest.raises(RequestRejected) as ei:
        validate_graph_update(store, _delta(removed=missing))
    assert ei.value.reason == "missing-edge"
    with pytest.raises(RequestRejected) as ei:
        validate_graph_update(store, _delta(
            added=[[0, store.n]], added_w=[0.1]))
    assert ei.value.reason == "bad-endpoint"
    with pytest.raises(RequestRejected) as ei:
        validate_graph_update(store, _delta(
            added=missing, added_w=[np.inf]))
    assert ei.value.reason == "bad-weight"


def test_quarantine_counters():
    q = Quarantine()
    q.record("a", "non-finite")
    q.record("b", "non-finite")
    q.record("c", "stale-store-version")
    assert q.total == 3
    assert q.by_reason["non-finite"] == 2
    assert q.to_jsonable()["by_reason"]["stale-store-version"] == 1


def test_event_log_virtual_clock():
    t = {"now": 0.0}
    log = EventLog(clock=lambda: t["now"])
    log.record("start")
    t["now"] = 2.5
    e = log.record("fault", pid=3)
    assert e.t == 2.5 and e.seq == 1 and e.detail["pid"] == 3
    assert log.counts() == {"start": 1, "fault": 1}
    assert [d["kind"] for d in log.to_jsonable()] == ["start", "fault"]


# --------------------------------------------------------------------------- #
# supervised serving (in-process, k=1 engine)
# --------------------------------------------------------------------------- #
def _supervised(td, n=192, **kw):
    kw.setdefault("options", repro.SolverOptions(k=1))
    kw.setdefault("sleep", lambda s: None)
    kw.setdefault("retry", RetryPolicy(base_delay_s=1e-4, max_delay_s=1e-3))
    return SupervisedSession(_problem(n), method="engine:chunk",
                             ckpt_dir=td, **kw)


def test_supervised_kill_retry_is_exact():
    n = 192
    rng = np.random.default_rng(0)
    with tempfile.TemporaryDirectory() as td:
        sup = _supervised(td, n)
        ref = repro.SolverSession(_problem(n), method="engine:chunk",
                                  options=repro.SolverOptions(k=1))
        b = np.asarray(sup.session.problem.b)
        for i in range(4):
            b = np.abs(b * (1 + 0.01 * rng.standard_normal(n)))
            chaos = (SessionInjector(ChaosPlan().kill(0, round=1))
                     if i == 2 else None)
            out = sup.serve_rank(b, request_id=i, chaos=chaos)
            assert out.ok
            ref.warm_start(b)
            ref.solve()
            assert float(np.abs(out.x - ref.x).sum()) == 0.0
            if i == 2:
                assert out.restores >= 1 and out.attempts >= 2
        counts = sup.log.counts()
        assert counts.get("fault", 0) >= 1
        assert counts.get("restore", 0) + counts.get("cold_restart", 0) >= 1


def test_supervised_poison_does_not_kill_session():
    n = 192
    with tempfile.TemporaryDirectory() as td:
        sup = _supervised(td, n)
        b = np.asarray(sup.session.problem.b)
        bad = b.copy()
        bad[5] = np.nan
        out = sup.serve_rank(bad, request_id="p")
        assert out.rejected and out.reject_reason == "non-finite"
        out2 = sup.serve_rank(b, request_id="ok")
        assert out2.ok and out2.converged
        assert sup.quarantine.by_reason == {"non-finite": 1}
        assert sup.log.counts().get("request_rejected") == 1


def test_supervised_deferral_and_flush_exact():
    n = 192
    with tempfile.TemporaryDirectory() as td:
        sup = _supervised(td, n)
        ref = repro.SolverSession(_problem(n), method="engine:chunk",
                                  options=repro.SolverOptions(k=1))
        sup.ladder.index = 1  # defer-updates rung
        assert sup.ladder.rung.defer_updates
        d = rotation_churn(sup.session.problem.graph, 3, seed=7)
        out = sup.serve_update(
            d, store_version=sup.session.problem.store_version,
            request_id="u")
        assert out.deferred and sup.deferred_updates == 1
        b = np.abs(np.asarray(sup.session.problem.b) * 1.02)
        o1 = sup.serve_rank(b, request_id=0)  # served on the STALE graph
        ref.warm_start(b)
        ref.solve()
        assert float(np.abs(o1.x - ref.x).sum()) == 0.0
        sup.ladder.index = 0
        assert sup.flush_deferred() == 1 and sup.deferred_updates == 0
        ref.update_graph(rotation_churn(ref.problem.graph, 3, seed=7))
        ref.solve()
        b2 = np.abs(b * 1.02)
        o2 = sup.serve_rank(b2, request_id=1)
        ref.warm_start(b2)
        ref.solve()
        assert float(np.abs(o2.x - ref.x).sum()) == 0.0
        assert sup.log.counts().get("update_applied") == 1


def test_supervised_stale_version_and_conflict_quarantine():
    n = 192
    with tempfile.TemporaryDirectory() as td:
        sup = _supervised(td, n)
        d = rotation_churn(sup.session.problem.graph, 2, seed=5)
        out = sup.serve_update(d, store_version=999, request_id="s")
        assert out.rejected and out.reject_reason == "stale-store-version"
        # a delta removing a nonexistent edge is caught by admission when
        # the queue is empty
        src, dst, _ = sup.session.problem.graph.csr().edge_list()
        keys = set(zip(src.tolist(), dst.tolist()))
        cand = next((s, t) for s in range(n) for t in range(n)
                    if (s, t) not in keys)
        ghost = _delta(removed=[cand])
        out = sup.serve_update(ghost, request_id="g")
        assert out.rejected and out.reject_reason == "missing-edge"
        # ... but while DEFERRING, admission skips membership; the
        # conflict surfaces at apply time and is quarantined, not fatal
        sup.ladder.index = 1
        out = sup.serve_update(ghost, request_id="g2")
        assert out.deferred
        sup.ladder.index = 0
        sup.flush_deferred()
        assert sup.quarantine.by_reason.get("conflict-at-apply") == 1
        assert sup.log.counts().get("update_conflict") == 1
        # session still serves
        out = sup.serve_rank(np.asarray(sup.session.problem.b),
                             request_id="after")
        assert out.ok


def test_supervised_accounting_parity_without_chaos():
    """No faults, no degradation: the supervisor's unified §2.3 ops
    accounting equals a plain session running the same stream."""
    n = 192
    rng = np.random.default_rng(1)
    with tempfile.TemporaryDirectory() as td:
        sup = _supervised(td, n)
        ref = repro.SolverSession(_problem(n), method="engine:chunk",
                                  options=repro.SolverOptions(k=1))
        b = np.asarray(sup.session.problem.b)
        for i in range(3):
            b = np.abs(b * (1 + 0.01 * rng.standard_normal(n)))
            out = sup.serve_rank(b, request_id=i)
            assert out.ok
            ref.warm_start(b)
            ref.solve()
        assert sup.total_ops == ref.lifetime_ops
        assert sup.wasted_ops == 0 and sup.restores == 0


def test_supervised_requests_stay_device_resident():
    """Between requests the engine state never round-trips through the
    host re-seed path: warm starts go through the device-resident
    ``warm_seed`` (only b uploads), and with ``want_x=False`` the
    solution is never gathered either."""
    n = 192
    rng = np.random.default_rng(2)
    with tempfile.TemporaryDirectory() as td:
        sup = _supervised(td, n)
        d = sup.session._driver
        calls = {"seed": 0, "x": 0}
        orig_seed, orig_x = d.seed, d.x
        d.seed = lambda *a, **k: (calls.__setitem__(
            "seed", calls["seed"] + 1), orig_seed(*a, **k))[1]
        d.x = lambda *a, **k: (calls.__setitem__(
            "x", calls["x"] + 1), orig_x(*a, **k))[1]
        b = np.asarray(sup.session.problem.b)
        for i in range(3):
            b = np.abs(b * (1 + 0.01 * rng.standard_normal(n)))
            out = sup.serve_rank(b, request_id=i, want_x=False)
            assert out.ok and out.x is None
        assert calls["seed"] == 0, "host re-seed on the warm path"
        assert calls["x"] == 0, "solution gathered despite want_x=False"


def test_supervised_op_budget_serves_degraded():
    n = 192
    with tempfile.TemporaryDirectory() as td:
        sup = _supervised(td, n, op_budget=1)
        out = sup.serve_rank(np.asarray(sup.session.problem.b),
                             request_id=0)
        assert out.ok  # served, not dropped
        assert out.budget_exhausted and out.degraded


# --------------------------------------------------------------------------- #
# adversarial checkpoint directories (satellite: restore provenance)
# --------------------------------------------------------------------------- #
def _session_with_steps(td, n=128, steps=3):
    ses = repro.SolverSession(_problem(n), method="engine:chunk",
                              options=repro.SolverOptions(k=1))
    ses.solve()
    for _ in range(steps):
        ses.checkpoint(td)
    return ses


def test_restore_empty_dir_raises_cleanly():
    with tempfile.TemporaryDirectory() as td:
        with pytest.raises(FileNotFoundError):
            repro.SolverSession.restore(td, _problem(128))


def test_restore_skips_torn_and_missing_leaf_steps():
    n = 128
    with tempfile.TemporaryDirectory() as td:
        ses = _session_with_steps(td, n)
        steps = sorted(os.listdir(td))
        assert len(steps) == 3
        # newest: torn manifest (crash mid-write)
        with open(os.path.join(td, steps[-1], "manifest.json"), "w") as f:
            f.write('{"step": 3, "leav')
        # middle: manifest intact but a leaf file is gone
        victim = os.path.join(td, steps[-2])
        os.remove(os.path.join(victim, "arr_00001.npy"))
        restored = repro.SolverSession.restore(td, _problem(n))
        info = restored.restored_from
        assert info["step"] == 1  # oldest survives
        reasons = dict(info["rejected"])
        assert len(info["rejected"]) == 2
        assert "incomplete or unreadable manifest" in reasons[3]
        assert "unreadable" in reasons[2]
        # the restored state is the real step-1 state: it solves on
        assert float(np.abs(restored.x - ses.x).sum()) <= 1e-6


@pytest.mark.skipif(os.geteuid() == 0,
                    reason="permission bits are advisory for root")
def test_restore_permission_denied_step_is_rejected():
    n = 128
    with tempfile.TemporaryDirectory() as td:
        _session_with_steps(td, n)
        steps = sorted(os.listdir(td))
        locked = os.path.join(td, steps[-1])
        os.chmod(locked, 0)
        try:
            restored = repro.SolverSession.restore(td, _problem(n))
            assert restored.restored_from["step"] == 2
            assert any("unreadable" in r or "incomplete" in r
                       for _, r in restored.restored_from["rejected"])
        finally:
            os.chmod(locked, stat.S_IRWXU)


def test_restore_all_steps_invalid_raises_with_provenance():
    n = 128
    with tempfile.TemporaryDirectory() as td:
        _session_with_steps(td, n, steps=2)
        for name in sorted(os.listdir(td)):
            os.remove(os.path.join(td, name, "arr_00000.npy"))
        with pytest.raises(ValueError, match="step 2: unreadable"):
            repro.SolverSession.restore(td, _problem(n))


def test_supervisor_cold_restarts_when_checkpoints_rot():
    """Every checkpoint rots away mid-stream: recovery degrades to a
    cold restart (logged), the request still completes and converges."""
    n = 192
    with tempfile.TemporaryDirectory() as td:
        sup = _supervised(td, n)
        b = np.asarray(sup.session.problem.b)
        assert sup.serve_rank(b, request_id=0).ok
        for name in sorted(os.listdir(td)):  # rot: every leaf vanishes
            step = os.path.join(td, name)
            for leaf in os.listdir(step):
                if leaf.endswith(".npy"):
                    os.remove(os.path.join(step, leaf))
        chaos = SessionInjector(ChaosPlan().kill(0, round=1))
        out = sup.serve_rank(np.abs(b * 1.01), request_id=1, chaos=chaos)
        assert out.ok and out.converged
        assert sup.log.counts().get("cold_restart", 0) >= 1


# --------------------------------------------------------------------------- #
# serve.py rank loop: failed update rolls back, stream continues
# --------------------------------------------------------------------------- #
def test_session_update_graph_failure_rolls_back():
    """Regression: a rejected delta leaves the session serving the
    pre-delta graph — the next request must succeed and match a session
    that never saw the bad delta."""
    n = 192
    ses = repro.SolverSession(_problem(n), method="engine:chunk",
                              options=repro.SolverOptions(k=1))
    ses.solve()
    v0 = ses.problem.store_version
    src, dst, _ = ses.problem.graph.csr().edge_list()
    keys = set(zip(src.tolist(), dst.tolist()))
    cand = next((s, t) for s in range(n) for t in range(n)
                if (s, t) not in keys)
    with pytest.raises(ValueError):
        ses.update_graph(_delta(removed=[cand]))
    assert ses.problem.store_version == v0
    ref = repro.SolverSession(_problem(n), method="engine:chunk",
                              options=repro.SolverOptions(k=1))
    ref.solve()
    b = np.abs(np.asarray(ses.problem.b) * 1.03)
    ses.warm_start(b)
    ses.solve()
    ref.warm_start(b)
    ref.solve()
    assert float(np.abs(ses.x - ref.x).sum()) == 0.0


SERVE_SCRIPT_TIMEOUT = 600


def test_serve_cli_quarantines_poison_and_continues():
    """`launch/serve.py` admission: poisoned rank requests quarantine
    per-request; the stream keeps serving and exits cleanly."""
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "rank",
         "--n", "400", "--requests", "6", "--batch", "2",
         "--poison-every", "3", "--churn", "0.002", "--churn-every", "2"],
        capture_output=True, text=True, timeout=SERVE_SCRIPT_TIMEOUT,
        env={**os.environ, "PYTHONPATH": os.path.abspath(SRC)},
        cwd=ROOT,
    )
    assert r.returncode == 0, r.stderr[-4000:]
    assert "[quarantine" in r.stdout
    assert "rank request rejected" in r.stdout


# --------------------------------------------------------------------------- #
# ACCEPTANCE: streaming soak (subprocess, 8 fake host devices)
# --------------------------------------------------------------------------- #
SOAK_SCRIPT = textwrap.dedent(
    """
    import os, sys, tempfile
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    sys.path.insert(0, {src!r})
    sys.path.insert(0, {root!r})
    import numpy as np
    from benchmarks.stream_bench import (StreamSpec, replay_reference,
                                         run_stream, stream_row)

    spec = StreamSpec(
        n=4096, k=8, requests=500, churn_every=10, poison_every=37,
        stale_update_at=209, kill_at=(48, 260),
        rescale_at={{150: 6, 330: 8}}, straggler=(380, 430, 6.0),
        queue_burst=6, sample_every=10, seed=0)
    with tempfile.TemporaryDirectory() as ckpt:
        run = run_stream(spec, ckpt)
    ref = replay_reference(spec, run)
    row = stream_row("soak", spec, run, ref)

    # zero dropped non-poison requests; every sampled point EXACT
    assert row["requests"] == 500 and row["dropped"] == 0, row
    assert row["served"] >= 400, row
    assert row["checked_points"] >= 40, row
    assert row["max_dx_l1"] <= 1e-6, row["max_dx_l1"]
    assert row["converged"], row

    # chaos actually happened: >= 2 kills, >= 2 rescales, churn applied
    counts = run["sup"].log.counts()
    assert counts.get("fault", 0) >= 2, counts
    assert counts.get("restore", 0) >= 2, counts
    assert counts.get("rescale", 0) >= 2, counts
    assert counts.get("update_applied", 0) >= 30, counts
    assert counts.get("straggler", 0) >= 2, counts

    # poison + the stale update quarantined, stream unharmed
    q = run["sup"].quarantine.by_reason
    assert q.get("non-finite", 0) >= 10, q
    assert q.get("stale-store-version", 0) == 1, q

    # the ladder observably engaged AND fully recovered (from the log)
    assert counts.get("degrade", 0) >= 1, counts
    assert counts.get("recover", 0) >= counts.get("degrade", 0), counts
    assert run["sup"].ladder.index == 0
    assert run["sup"].deferred_updates == 0

    # recovery-time accounting: killed requests carry backoff latency
    assert row["recovery_p95_s"] > 0, row
    assert row["wasted_ops"] > 0, row
    print("SOAK_OK", row["served"], row["max_dx_l1"], row["total_ops"])
    """
)


def test_stream_soak_acceptance_subprocess():
    """ISSUE acceptance: 500-request evolving-web stream under seeded
    chaos (2 kills, 2 rescales, churn, straggler window, poison) — zero
    dropped non-poison requests, every sampled solution exact vs the
    undisturbed effective-schedule replay, ladder engages and fully
    recovers."""
    r = subprocess.run(
        [sys.executable, "-c",
         SOAK_SCRIPT.format(src=os.path.abspath(SRC),
                            root=os.path.abspath(ROOT))],
        capture_output=True, text=True, timeout=900,
    )
    assert r.returncode == 0, (r.stdout[-2000:], r.stderr[-4000:])
    assert "SOAK_OK" in r.stdout


BREAKER_SCRIPT = textwrap.dedent(
    """
    import os, sys, tempfile
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    sys.path.insert(0, {src!r})
    import numpy as np
    import repro
    from repro.chaos import ChaosPlan, SessionInjector
    from repro.core import webgraph_like
    from repro.graph import GraphStore
    from repro.resilience import (CircuitBreaker, RetryPolicy,
                                  SupervisedSession)

    n = 1024
    prob = repro.Problem.pagerank(GraphStore.from_csr(
        webgraph_like(n, seed=1)))
    with tempfile.TemporaryDirectory() as td:
        sup = SupervisedSession(
            prob, method="engine:chunk",
            options=repro.SolverOptions(k=4), ckpt_dir=td,
            retry=RetryPolicy(max_attempts=5, base_delay_s=1e-4,
                              max_delay_s=1e-3),
            breaker=CircuitBreaker(trip_after=3), sleep=lambda s: None)
        # three kills in one request: the breaker trips on the third
        # and escalates -> restore + rescale to the surviving width
        plan = (ChaosPlan(seed=0).kill(3, round=1).kill(3, round=2)
                .kill(3, round=3))
        out = sup.serve_rank(np.asarray(prob.b), request_id=0,
                             chaos=SessionInjector(plan))
        assert out.ok and out.converged, out
        assert out.attempts >= 4, out
        counts = sup.log.counts()
        assert counts.get("breaker_trip", 0) >= 1, counts
        rescales = [e for e in sup.log.of_kind("rescale")
                    if not e.detail["planned"]]
        assert rescales and rescales[0].detail["k_new"] == 3, counts
        assert sup.session._driver.cfg.k == 3
        assert sup.breaker.trips == 1
    print("BREAKER_OK")
    """
)


def test_breaker_trip_escalates_to_rescale_subprocess():
    r = subprocess.run(
        [sys.executable, "-c",
         BREAKER_SCRIPT.format(src=os.path.abspath(SRC))],
        capture_output=True, text=True, timeout=600,
    )
    assert r.returncode == 0, (r.stdout[-2000:], r.stderr[-4000:])
    assert "BREAKER_OK" in r.stdout
