"""Cross-backend parity on corner-case graphs (the PR 4 bug class).

``CSRGraph.to_dense`` once mis-merged parallel edges — the class of bug
where a *representation* detail (duplicate edge entries, self-loops,
empty rows, column sums above 1) silently changes the matrix one
backend solves.  Guard: for each corner graph, every registry backend
must land within tolerance of a dense reference built INDEPENDENTLY by
``np.add.at`` accumulation over the raw edge list (not through
``to_dense`` or any view), so a representation bug in any layer shows
up as cross-backend divergence.

Corner graphs:
* ``self_loops``       — every node carries a self-edge (diagonal P)
* ``dangling_heavy``   — 60% zero-out-degree nodes (PageRank dangling
                          mass dominates; §2.3 charges them 1 op each)
* ``parallel_edges``   — multigraph input: duplicate (src, dst) entries
                          must merge by weight summation everywhere
* ``overweight_rows``  — weighted columns summing above 1 (spectral
                          radius still < 1): schedules see transient
                          |F|₁ growth
"""
import numpy as np
import pytest

import repro
from repro.core import pagerank_system, power_law_graph
from repro.core.graph import CSRGraph

ALL_BACKENDS = ("sequential", "frontier:segment_sum", "frontier:pallas",
                "engine:chunk", "engine:bsr", "simulator")


def _dense_from_edges(p: CSRGraph) -> np.ndarray:
    """Independent dense build: accumulate raw edges, no view code."""
    src, dst, w = p.edge_list()
    m = np.zeros((p.n, p.n))
    np.add.at(m, (dst, src), w)
    return m


def _self_loops():
    n = 60
    src = np.concatenate([np.arange(n), np.arange(n)])
    dst = np.concatenate([(np.arange(n) + 1) % n, np.arange(n)])
    w = np.concatenate([np.full(n, 0.4), np.full(n, 0.45)])
    p = CSRGraph.from_edges(src.astype(np.int32), dst.astype(np.int32),
                            w, n)
    b = np.abs(np.sin(np.arange(n) + 1.0)) / n + 1e-3
    return repro.Problem.linear(p, b, eps=0.15, target_error=1e-5)


def _dangling_heavy():
    rng = np.random.default_rng(5)
    n = 80
    talkers = np.arange(n // 5 * 2)  # 40% have out-links, 60% dangle
    src = np.repeat(talkers, 3)
    dst = rng.integers(0, n, size=src.shape[0]).astype(np.int32)
    keep = src != dst
    g = CSRGraph.from_edges(src[keep].astype(np.int32), dst[keep],
                            np.ones(keep.sum()), n)
    assert (g.out_degree() == 0).sum() >= 0.5 * n
    p, b = pagerank_system(g, damping=0.85)
    return repro.Problem.linear(p, b, eps=0.15, target_error=1e-5)


def _parallel_edges():
    g0 = power_law_graph(50, seed=2)
    p0, _ = pagerank_system(g0)
    src, dst, w = p0.edge_list()
    # duplicate a third of the edges with split weights: the multigraph
    # must canonicalize to the same matrix everywhere
    pick = np.arange(0, src.shape[0], 3)
    src2 = np.concatenate([src, src[pick]])
    dst2 = np.concatenate([dst, dst[pick]])
    w2 = np.concatenate([w, 0.1 * w[pick]])
    w2[pick] *= 0.9  # total per-pair weight back to the original
    p = CSRGraph.from_edges(src2, dst2, w2, p0.n)
    b = np.full(p0.n, 0.15 / p0.n)
    return repro.Problem.linear(p, b, eps=0.15, target_error=1e-5)


def _overweight_rows():
    n = 40
    ring_src = np.arange(n)
    ring_dst = (np.arange(n) + 1) % n
    ring_w = np.full(n, 0.3)
    # a hot 2-cycle whose columns sum above 1 (0.3 + 1.3) while the
    # spectral radius stays < 1
    src = np.concatenate([ring_src, [0, 1]])
    dst = np.concatenate([ring_dst, [1, 0]])
    w = np.concatenate([ring_w, [1.3, 0.5]])
    p = CSRGraph.from_edges(src.astype(np.int32), dst.astype(np.int32),
                            w, n)
    dense = _dense_from_edges(p)
    rho = float(np.max(np.abs(np.linalg.eigvals(dense))))
    assert 1.0 < dense.sum(axis=0).max() and rho < 0.95
    b = np.abs(np.cos(np.arange(n) + 1.0)) / n + 1e-3
    return repro.Problem.linear(p, b, eps=0.1, target_error=1e-5)


CORNERS = {
    "self_loops": _self_loops,
    "dangling_heavy": _dangling_heavy,
    "parallel_edges": _parallel_edges,
    "overweight_rows": _overweight_rows,
}


@pytest.mark.parametrize("method", ALL_BACKENDS)
@pytest.mark.parametrize("corner", sorted(CORNERS))
def test_corner_graph_parity(corner, method):
    problem = CORNERS[corner]()
    x_ref = np.linalg.solve(
        np.eye(problem.n) - _dense_from_edges(problem.p), problem.b)
    opts = {}
    if method == "frontier:pallas":
        opts = {"interpret": True, "bs": 16}
    elif method == "simulator":
        opts = {"k": 2, "mode": "batch", "record_every": 50}
    rep = repro.solve(problem, method=method,
                      options=repro.SolverOptions(**opts))
    assert rep.converged, (corner, method, rep.residual)
    # the stopping rule leaves |x − h|₁ ≤ |F|₁·‖(I−P)⁻¹‖₁ ≈ 1e-5 here;
    # a representation bug (wrong matrix) diverges by orders of
    # magnitude more, so 1e-4 separates the failure mode cleanly
    l1 = float(np.abs(rep.x - x_ref).sum())
    assert l1 <= 1e-4, (corner, method, l1)
    assert rep.n_ops > 0


def test_dangling_ops_accounting_parity():
    """§2.3: every backend charges dangling diffusions 1 op, so the
    normalized costs stay within schedule slack of each other even when
    60% of the mass flows through dangling nodes."""
    problem = CORNERS["dangling_heavy"]()
    costs = {}
    for method in ("sequential", "frontier:segment_sum", "engine:chunk"):
        rep = repro.solve(problem, method=method)
        assert rep.converged
        costs[method] = rep.cost_iterations
    ref = costs["sequential"]
    for method, c in costs.items():
        assert 0.5 * ref <= c <= 2.0 * ref, (method, costs)
