"""GNN models: losses, grads, invariance/equivariance properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import build_triplets, molecule_batch
from repro.models import gnn


def mk_batch(n=24, e=60, f=6, seed=0, classes=0, graphs=0, trip=False):
    rng = np.random.default_rng(seed)
    b = {
        "x": rng.standard_normal((n, f)).astype(np.float32),
        "pos": rng.standard_normal((n, 3)).astype(np.float32),
        "z": rng.integers(0, 8, n).astype(np.int32),
        "src": rng.integers(0, n, e).astype(np.int32),
        "dst": rng.integers(0, n, e).astype(np.int32),
        "node_mask": np.ones(n, np.float32),
        "edge_mask": np.ones(e, np.float32),
    }
    if trip:
        te, tf = build_triplets(b["src"], b["dst"], n, 4, seed)
        b["trip_e"], b["trip_f"] = te, tf
        b["trip_mask"] = np.ones(te.shape[0], np.float32)
    if graphs:
        b["graph_ids"] = np.sort(rng.integers(0, graphs, n)).astype(np.int32)
        b["labels"] = rng.standard_normal(graphs).astype(np.float32)
    elif classes:
        b["labels"] = rng.integers(0, classes, n).astype(np.int32)
    else:
        b["labels"] = rng.standard_normal((n, 1)).astype(np.float32)
    return {k: jnp.asarray(v) for k, v in b.items()}


CONFIGS = {
    "gin": gnn.GNNConfig(name="g", arch="gin", n_layers=3, d_hidden=16,
                         d_feat=6, n_classes=5),
    "meshgraphnet": gnn.GNNConfig(name="m", arch="meshgraphnet", n_layers=3,
                                  d_hidden=16, d_feat=6, d_edge=4, d_out=1),
    "egnn": gnn.GNNConfig(name="e", arch="egnn", n_layers=2, d_hidden=16,
                          d_feat=6, d_out=1),
    "dimenet": gnn.GNNConfig(name="d", arch="dimenet", n_layers=2,
                             d_hidden=16, d_feat=6, n_bilinear=4,
                             n_spherical=4, n_radial=4, d_out=1,
                             task="graph"),
}


@pytest.mark.parametrize("arch", list(CONFIGS))
def test_loss_and_grads(arch):
    cfg = CONFIGS[arch]
    batch = mk_batch(
        classes=cfg.n_classes,
        graphs=4 if cfg.task == "graph" else 0,
        trip=(arch == "dimenet"),
    )
    p = gnn.init_params(cfg, jax.random.PRNGKey(0))
    loss, g = jax.value_and_grad(lambda p: gnn.loss_fn(p, batch, cfg))(p)
    assert np.isfinite(float(loss))
    assert all(bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(g))


def test_gin_permutation_invariance():
    """Graph-level readout must be invariant to node relabelling."""
    cfg = gnn.GNNConfig(name="g", arch="gin", n_layers=3, d_hidden=16,
                        d_feat=6, n_classes=0, d_out=2, task="graph")
    b = mk_batch(graphs=1)
    p = gnn.init_params(cfg, jax.random.PRNGKey(0))
    out1 = gnn.forward(p, b, cfg)
    pooled1 = np.asarray(out1.sum(0))
    n = b["x"].shape[0]
    perm = np.random.default_rng(1).permutation(n)
    inv = np.empty(n, np.int64)
    inv[perm] = np.arange(n)
    b2 = dict(b)
    b2["x"] = b["x"][perm]
    b2["src"] = jnp.asarray(inv)[b["src"]]
    b2["dst"] = jnp.asarray(inv)[b["dst"]]
    out2 = gnn.forward(p, b2, cfg)
    pooled2 = np.asarray(out2.sum(0))
    np.testing.assert_allclose(pooled1, pooled2, rtol=1e-4, atol=1e-4)


def test_egnn_translation_invariance():
    """EGNN h-outputs depend on relative positions only."""
    cfg = CONFIGS["egnn"]
    b = mk_batch()
    p = gnn.init_params(cfg, jax.random.PRNGKey(0))
    out1 = np.asarray(gnn.forward(p, b, cfg))
    b2 = dict(b)
    b2["pos"] = b["pos"] + jnp.asarray([5.0, -3.0, 2.0])
    out2 = np.asarray(gnn.forward(p, b2, cfg))
    np.testing.assert_allclose(out1, out2, rtol=1e-4, atol=1e-4)


def test_egnn_rotation_invariance():
    cfg = CONFIGS["egnn"]
    b = mk_batch()
    p = gnn.init_params(cfg, jax.random.PRNGKey(0))
    out1 = np.asarray(gnn.forward(p, b, cfg))
    theta = 0.7
    rot = jnp.asarray(
        [[np.cos(theta), -np.sin(theta), 0],
         [np.sin(theta), np.cos(theta), 0],
         [0, 0, 1.0]], dtype=jnp.float32)
    b2 = dict(b)
    b2["pos"] = b["pos"] @ rot.T
    out2 = np.asarray(gnn.forward(p, b2, cfg))
    np.testing.assert_allclose(out1, out2, rtol=1e-3, atol=1e-3)


def test_dimenet_rotation_invariance():
    """DimeNet uses distances + angles only -> rotation invariant."""
    cfg = CONFIGS["dimenet"]
    b = mk_batch(trip=True, graphs=2)
    p = gnn.init_params(cfg, jax.random.PRNGKey(0))
    out1 = np.asarray(gnn.forward(p, b, cfg))
    theta = -0.4
    rot = jnp.asarray(
        [[1, 0, 0],
         [0, np.cos(theta), -np.sin(theta)],
         [0, np.sin(theta), np.cos(theta)]], dtype=jnp.float32)
    b2 = dict(b)
    b2["pos"] = b["pos"] @ rot.T
    out2 = np.asarray(gnn.forward(p, b2, cfg))
    np.testing.assert_allclose(out1, out2, rtol=1e-3, atol=1e-3)


def test_edge_mask_zeroes_padding():
    """Padded edges must not affect the output."""
    cfg = CONFIGS["gin"]
    b = mk_batch(classes=5)
    p = gnn.init_params(cfg, jax.random.PRNGKey(0))
    out1 = np.asarray(gnn.forward(p, b, cfg))
    # add junk padding edges with mask 0
    b2 = dict(b)
    e_extra = 16
    rng = np.random.default_rng(9)
    b2["src"] = jnp.concatenate(
        [b["src"], jnp.asarray(rng.integers(0, 24, e_extra), jnp.int32)])
    b2["dst"] = jnp.concatenate(
        [b["dst"], jnp.asarray(rng.integers(0, 24, e_extra), jnp.int32)])
    b2["edge_mask"] = jnp.concatenate(
        [b["edge_mask"], jnp.zeros(e_extra, jnp.float32)])
    out2 = np.asarray(gnn.forward(p, b2, cfg))
    np.testing.assert_allclose(out1, out2, rtol=1e-5, atol=1e-5)


def test_molecule_batch_builder():
    b = molecule_batch(8, with_triplets=True)
    assert b["x"].shape == (240, 16)
    assert b["graph_ids"].max() == 7
    assert b["trip_e"].max() < b["src"].shape[0]
