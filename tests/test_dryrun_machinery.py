"""Dry-run lowering machinery on a small fake-device mesh (subprocess so the
main test process keeps seeing 1 device)."""
import os
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, {src!r})
    import dataclasses
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import get_arch
    from repro.configs.common import ArchSpec, ShapeCell, sds, lm_cells
    from repro.launch.steps import build_cell_step
    from repro.launch.dryrun import parse_collectives
    from repro.parallel.axes import axis_rules
    from repro.parallel.compat import cost_analysis_dict

    # a tiny LM spec with the same machinery as the real cells
    from repro.models.transformer import TransformerConfig
    cfg = TransformerConfig(
        name="tiny", n_layers=3, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=96, vocab=128, dtype=jnp.float32, ce_chunk=16)
    cell = ShapeCell(
        name="train_tiny", kind="train",
        inputs=lambda: {{"tokens": sds((8, 32), jnp.int32),
                        "labels": sds((8, 32), jnp.int32)}},
        input_axes={{"tokens": ("batch", None), "labels": ("batch", None)}},
        overrides={{"n_microbatches": 2}},
        meta={{"tokens": 256, "batch": 8, "seq": 32}})
    spec = ArchSpec(arch_id="tiny-lm", family="lm", model_cfg=cfg,
                    cells={{"train_tiny": cell}})

    from repro.parallel.compat import make_mesh
    mesh = make_mesh((2, 4), ("data", "model"))
    rules = {{"batch": "data", "embed": "data", "act_embed": None,
             "act_seq": "model", "heads": "model", "mlp": "model",
             "vocab": "model", "kv_seq": "model"}}
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    with axis_rules(rules):
        step, args, in_specs = build_cell_step(
            spec, cell, rules, dp_shards=2, axis_sizes=sizes)
        shards = jax.tree.map(
            lambda s: NamedSharding(mesh, s), in_specs,
            is_leaf=lambda x: isinstance(x, P))
        with mesh:
            compiled = jax.jit(step, in_shardings=shards).lower(
                *args).compile()
    ca = cost_analysis_dict(compiled)
    assert ca.get("flops", 0) > 0
    mem = compiled.memory_analysis()
    assert mem.argument_size_in_bytes > 0
    colls = parse_collectives(compiled.as_text(), trip_candidates={{3, 2}})
    assert len(colls) > 0, "expected collectives on a 2x4 mesh"
    assert any(c["trips"] == 3 for c in colls), (
        "layer-scan collectives must be trip-attributed: "
        + str(sorted({{c['trips'] for c in colls}})))
    print("DRYRUN_MACHINERY_OK", len(colls))
    """
)


def test_small_mesh_lowering():
    src = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT.format(src=src)],
        capture_output=True, text=True, timeout=600,
    )
    assert r.returncode == 0, r.stderr[-3000:]
    assert "DRYRUN_MACHINERY_OK" in r.stdout
