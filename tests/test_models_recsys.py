"""FM recsys model: logits vs naive pairwise, retrieval factorisation."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models import recsys


CFG = recsys.FMConfig(name="fm", n_fields=8, vocab_per_field=50,
                      embed_dim=6)


def test_fm_logits_match_naive():
    rng = np.random.default_rng(0)
    p = recsys.init_params(CFG, jax.random.PRNGKey(0))
    ids = jnp.asarray(rng.integers(0, 50, (16, 8)).astype(np.int32))
    logits = np.asarray(recsys.forward_logits(p, ids, CFG))
    # naive: explicit pairwise dot products
    rows = np.asarray(ids) + np.arange(8) * 50
    v = np.asarray(p["table"])[rows]  # [B, F, D]
    lin = np.asarray(p["lin_table"])[rows].sum(-1)
    pair = np.zeros(16)
    for i in range(8):
        for j in range(i + 1, 8):
            pair += (v[:, i] * v[:, j]).sum(-1)
    ref = float(np.asarray(p["bias"])) + lin + pair
    np.testing.assert_allclose(logits, ref, rtol=1e-4, atol=1e-4)


def test_retrieval_matches_forward():
    rng = np.random.default_rng(1)
    p = recsys.init_params(CFG, jax.random.PRNGKey(1))
    user = jnp.asarray(rng.integers(0, 50, 7).astype(np.int32))
    cands = jnp.arange(30, dtype=jnp.int32)
    sc = np.asarray(recsys.retrieval_score(p, user, cands, CFG))
    full_ids = jnp.concatenate(
        [jnp.broadcast_to(user, (30, 7)), cands[:, None]], axis=1
    )
    sc2 = np.asarray(recsys.forward_logits(p, full_ids, CFG))
    np.testing.assert_allclose(sc, sc2, rtol=1e-4, atol=1e-4)


def test_bce_loss_and_grads():
    rng = np.random.default_rng(2)
    p = recsys.init_params(CFG, jax.random.PRNGKey(2))
    batch = {
        "ids": jnp.asarray(rng.integers(0, 50, (64, 8)).astype(np.int32)),
        "labels": jnp.asarray(rng.integers(0, 2, 64).astype(np.int32)),
    }
    loss, g = jax.value_and_grad(
        lambda p: recsys.loss_fn(p, batch, CFG))(p)
    assert np.isfinite(float(loss))
    assert float(jnp.abs(g["table"]).sum()) > 0


def test_training_reduces_loss():
    """A few SGD steps on a fixed batch must reduce BCE."""
    rng = np.random.default_rng(3)
    p = recsys.init_params(CFG, jax.random.PRNGKey(3))
    batch = {
        "ids": jnp.asarray(rng.integers(0, 50, (256, 8)).astype(np.int32)),
        "labels": jnp.asarray(rng.integers(0, 2, 256).astype(np.int32)),
    }
    grad_fn = jax.jit(jax.value_and_grad(
        lambda p: recsys.loss_fn(p, batch, CFG)))
    l0 = None
    for _ in range(25):
        loss, g = grad_fn(p)
        if l0 is None:
            l0 = float(loss)
        p = jax.tree.map(lambda a, b: a - 0.5 * b, p, g)
    assert float(loss) < l0
