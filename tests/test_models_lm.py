"""Transformer LM: losses, grads, decode-vs-forward consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.transformer import (
    MoEConfig,
    TransformerConfig,
    decode_step,
    init_cache,
    init_params,
    prefill_step,
    train_loss,
)


def tiny(moe=False, **kw):
    base = dict(
        name="tiny", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
        d_ff=64, vocab=61, qkv_bias=True, dtype=jnp.float32, ce_chunk=8,
    )
    if moe:
        base["moe"] = MoEConfig(
            n_experts=6, top_k=2, d_ff_expert=16, n_shared=1,
            pad_experts_to=8,
        )
    base.update(kw)
    return TransformerConfig(**base)


@pytest.fixture(scope="module")
def batch():
    rng = np.random.default_rng(0)
    toks = rng.integers(0, 61, (4, 33)).astype(np.int32)
    return {
        "tokens": jnp.asarray(toks[:, :-1]),
        "labels": jnp.asarray(toks[:, 1:]),
    }


@pytest.mark.parametrize("moe", [False, True])
def test_loss_and_grads_finite(batch, moe):
    cfg = tiny(moe=moe)
    p = init_params(cfg, jax.random.PRNGKey(0))
    loss, g = jax.value_and_grad(lambda p: train_loss(p, batch, cfg))(p)
    assert np.isfinite(float(loss))
    assert float(loss) < np.log(61) * 2
    assert all(bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(g))


def test_microbatch_equivalence(batch):
    """Grad-accumulation microbatching must not change the loss."""
    cfg1 = tiny(n_microbatches=1)
    cfg2 = tiny(n_microbatches=2)
    p = init_params(cfg1, jax.random.PRNGKey(0))
    l1 = float(train_loss(p, batch, cfg1))
    l2 = float(train_loss(p, batch, cfg2))
    assert abs(l1 - l2) < 1e-4


def test_chunked_attention_equivalence(batch):
    cfg1 = tiny()
    cfg2 = tiny(attn_q_chunk=8)
    p = init_params(cfg1, jax.random.PRNGKey(0))
    assert abs(float(train_loss(p, batch, cfg1))
               - float(train_loss(p, batch, cfg2))) < 1e-4


def test_ce_chunk_equivalence(batch):
    cfg1 = tiny(ce_chunk=32)
    cfg2 = tiny(ce_chunk=4)
    p = init_params(cfg1, jax.random.PRNGKey(0))
    assert abs(float(train_loss(p, batch, cfg1))
               - float(train_loss(p, batch, cfg2))) < 1e-4


def test_decode_matches_prefill(batch):
    """Teacher-forced decode must reproduce prefill logits position-wise."""
    cfg = tiny()
    p = init_params(cfg, jax.random.PRNGKey(1))
    toks = batch["tokens"][:, :16]
    # full prefill over 16 tokens
    cache_full, logits_full_last = prefill_step(p, toks, cfg)
    # prefill 8, then decode tokens 8..15 one by one
    cache, _ = prefill_step(p, toks[:, :8], cfg, max_seq=16)
    last = None
    for t in range(8, 16):
        last, cache = decode_step(p, cache, toks[:, t], cfg)
    np.testing.assert_allclose(
        np.asarray(last), np.asarray(logits_full_last), rtol=2e-3, atol=2e-3
    )


def test_moe_router_load_balance_loss(batch):
    """Aux loss present and differentiable for the MoE config."""
    cfg = tiny(moe=True)
    p = init_params(cfg, jax.random.PRNGKey(0))
    g = jax.grad(lambda p: train_loss(p, batch, cfg))(p)
    rg = np.asarray(jnp.abs(g["layers"]["router"]).sum())
    assert rg > 0  # router receives gradient through aux + gating


def test_param_count_formula():
    for moe in (False, True):
        cfg = tiny(moe=moe)
        p = init_params(cfg, jax.random.PRNGKey(0))
        n_actual = sum(x.size for x in jax.tree.leaves(p))
        if not moe:
            assert n_actual == cfg.n_params
        else:
            # padded experts add dead weights beyond the formula count
            m = cfg.moe
            dead = cfg.n_layers * (m.e_pad - m.n_experts) * 3 * \
                cfg.d_model * m.d_ff_expert
            assert n_actual == cfg.n_params + dead


def test_cache_shapes():
    cfg = tiny()
    c = init_cache(cfg, batch=3, max_seq=64)
    assert c["k"].shape == (2, 3, 64, 2, 8)


def test_int8_kv_cache_decode():
    """int8 KV decode (per-token-head scales) tracks the bf16 path."""
    cfg = tiny()
    p = init_params(cfg, jax.random.PRNGKey(1))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, 61, (4, 8)), jnp.int32)
    cache, _ = prefill_step(p, toks, cfg, max_seq=16)
    ks = jnp.max(jnp.abs(cache["k"]), axis=-1) / 127.0 + 1e-8
    vs = jnp.max(jnp.abs(cache["v"]), axis=-1) / 127.0 + 1e-8
    qcache = {
        "k": jnp.clip(jnp.round(cache["k"] / ks[..., None]),
                      -127, 127).astype(jnp.int8),
        "v": jnp.clip(jnp.round(cache["v"] / vs[..., None]),
                      -127, 127).astype(jnp.int8),
        "k_scale": ks.astype(jnp.float32),
        "v_scale": vs.astype(jnp.float32),
        "pos": cache["pos"],
    }
    nxt = jnp.asarray(rng.integers(0, 61, (4,)), jnp.int32)
    l_ref, _ = decode_step(p, cache, nxt, cfg)
    l_q, qc2 = decode_step(p, qcache, nxt, cfg)
    rel = float(jnp.abs(l_q - l_ref).max()) / (
        float(jnp.abs(l_ref).max()) + 1e-9)
    assert rel < 0.05, rel
    assert qc2["k"].dtype == jnp.int8
    assert int(qc2["pos"]) == int(cache["pos"]) + 1
