"""GraphStore: view parity under deltas + the warm delta re-solve.

Acceptance (ISSUE 4):

* delta-patched views (CSR splice, dirty BSR tiles, dirty buckets,
  dirty engine rows) are **bit-identical** to a from-scratch rebuild —
  the tier-2 ``graph-update-parity`` CI contract;
* 1% edge churn on the N=4096 webgraph re-solves through
  ``SolverSession.update_graph`` with ≥ 5× fewer edge pushes than a
  cold solve, on both a frontier and an engine backend;
* the warm delta re-solve matches the cold solve to |Δx|₁ ≤ 1e-6 at a
  tight target;
* ``Problem.with_graph`` shares the store; ``GraphStore.from_edge_file``
  opens SNAP-style real-graph workloads.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import repro
from repro.core import pagerank_system, power_law_graph, webgraph_like
from repro.core.graph import bucketize
from repro.graph import (
    GraphDelta,
    GraphStore,
    pagerank_edge_churn,
    rotation_churn,
)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _mixed_delta(store, seed=0, n_rm=7, n_add=7, n_rew=5):
    """Hand-rolled add/remove/reweight batch touching random edges."""
    rng = np.random.default_rng(seed)
    csr = store.csr()
    src_e, dst_e, w_e = csr.edge_list()
    keys = set((int(s) << 32) | int(d) for s, d in zip(src_e, dst_e))
    pick = rng.choice(src_e.shape[0], size=n_rm + n_rew, replace=False)
    removed = np.stack([src_e[pick[:n_rm]], dst_e[pick[:n_rm]]],
                       axis=1).astype(np.int64)
    rew_idx = pick[n_rm:]
    rew = (src_e[rew_idx].astype(np.int64), dst_e[rew_idx].astype(np.int64),
           w_e[rew_idx] * 1.5)
    added = []
    while len(added) < n_add:
        s, d = int(rng.integers(0, csr.n)), int(rng.integers(0, csr.n))
        k = (s << 32) | d
        if s != d and k not in keys:
            added.append((s, d, 0.01 * (len(added) + 1)))
            keys.add(k)
    return GraphDelta.make(
        added_edges=np.array(added),
        removed_edges=removed,
        reweighted=rew,
    )


# --------------------------------------------------------------------------- #
# canonical store + constructors
# --------------------------------------------------------------------------- #
def test_store_csr_roundtrip():
    g = power_law_graph(300, seed=3)
    p, _ = pagerank_system(g)
    store = GraphStore.from_csr(p)
    csr = store.csr()
    assert store.n == p.n and store.n_edges == p.n_edges
    # same matrix (canonical order may differ from the input order)
    np.testing.assert_array_equal(csr.indptr, p.indptr)
    np.testing.assert_allclose(csr.to_dense(), p.to_dense(), atol=0)
    np.testing.assert_array_equal(store.out_degree(), p.out_degree())
    np.testing.assert_array_equal(store.dangling_mask(), p.dangling_mask())


def test_multigraph_csr_merges_parallel_edges():
    """Legacy multigraph CSRGraphs (parallel edges) canonicalize by
    weight summation — the same semantics as CSRGraph.to_dense — so
    store-backed backends solve the identical matrix."""
    from repro.core.graph import CSRGraph

    p = CSRGraph.from_edges(np.array([0, 0, 1], dtype=np.int32),
                            np.array([1, 1, 0], dtype=np.int32),
                            np.array([0.3, 0.2, 0.4]), 2)
    store = GraphStore.from_csr(p)
    assert store.n_edges == 2
    np.testing.assert_allclose(store.csr().to_dense(), p.to_dense())
    b = np.array([1.0, 0.5])
    problem = repro.Problem.linear(p, b, rho=0.9, target_error=1e-10)
    x_dense = np.linalg.solve(np.eye(2) - p.to_dense(), b)
    rep = repro.solve(problem, method="engine:chunk")
    np.testing.assert_allclose(rep.x, x_dense, atol=1e-6)


def test_bucketize_is_store_view():
    """The legacy bucketize() alias and the store view are identical."""
    g = power_law_graph(200, seed=1)
    store = GraphStore.from_csr(g)
    bg_legacy = bucketize(store.csr(), 5)
    bg_view = store.bucketed(5)
    for name in ("node_of_slot", "slot_of_node", "src_slot", "dst", "wgt",
                 "out_deg"):
        np.testing.assert_array_equal(getattr(bg_legacy, name),
                                      getattr(bg_view, name))
    # the view is cached, the alias is not
    assert store.bucketed(5) is bg_view


# --------------------------------------------------------------------------- #
# bit-identical delta patching (the tier-2 graph-update-parity contract)
# --------------------------------------------------------------------------- #
def _assert_views_bit_identical(patched: GraphStore, fresh: GraphStore,
                                bs: int, n_buckets: int, engine_key):
    a, b = patched.csr(), fresh.csr()
    np.testing.assert_array_equal(a.indptr, b.indptr)
    np.testing.assert_array_equal(a.indices, b.indices)
    np.testing.assert_array_equal(a.weights, b.weights)

    ta, tb = patched.bsr(bs), fresh.bsr(bs)
    np.testing.assert_array_equal(ta.block_row, tb.block_row)
    np.testing.assert_array_equal(ta.block_col, tb.block_col)
    np.testing.assert_array_equal(ta.blocks, tb.blocks)
    np.testing.assert_array_equal(ta.row_occupied, tb.row_occupied)

    ga, gb = patched.bucketed(n_buckets), fresh.bucketed(n_buckets)
    for name in ("node_of_slot", "slot_of_node", "src_slot", "dst", "wgt",
                 "out_deg"):
        np.testing.assert_array_equal(getattr(ga, name), getattr(gb, name))
    assert ga.n_edges == gb.n_edges

    la = patched.engine_layout(*engine_key)
    lb = fresh.engine_layout(*engine_key)
    for name in ("w", "src_slot", "dst_bucket", "dst_slot", "wgt",
                 "pos_of_bucket", "node_of_slot", "tiles", "tile_dst",
                 "slot_out_deg"):
        va, vb = getattr(la, name), getattr(lb, name)
        if va is None:
            assert vb is None, name
        else:
            np.testing.assert_array_equal(va, vb, err_msg=name)
    assert la.n_edges == lb.n_edges


@pytest.mark.parametrize("churn", ["pagerank", "mixed", "rotation"])
def test_apply_delta_views_bit_identical(churn):
    """Patched views == from-scratch rebuild, bit for bit, across the
    CSR splice, dirty BSR tiles, dirty buckets and dirty engine rows."""
    g = webgraph_like(1024, seed=1)
    p, _ = pagerank_system(g)
    store = GraphStore.from_csr(p)
    bs, n_buckets = 64, 6
    engine_key = (2, 5, 2, True, np.float32)
    # materialize every view BEFORE the delta so all patchers exercise
    store.bsr(bs)
    store.bucketed(n_buckets)
    store.engine_layout(*engine_key)

    if churn == "pagerank":
        rng = np.random.default_rng(0)
        csr = store.csr()
        src_e, dst_e, _ = csr.edge_list()
        deg = csr.out_degree()
        cand = np.nonzero(deg[src_e] > 1)[0]
        rm = rng.choice(cand, size=12, replace=False)
        removed = np.stack([src_e[rm], dst_e[rm]], axis=1).astype(np.int64)
        keys = set((int(s) << 32) | int(d)
                   for s, d in zip(src_e, dst_e))
        added = []
        while len(added) < 12:
            s, d = int(rng.integers(0, 1024)), int(rng.integers(0, 1024))
            if s != d and ((s << 32) | d) not in keys and deg[s] > 0:
                added.append((s, d))
                keys.add((s << 32) | d)
        delta = pagerank_edge_churn(
            store, added_links=np.array(added, dtype=np.int64),
            removed_links=removed)
    elif churn == "mixed":
        delta = _mixed_delta(store, seed=3)
    else:
        delta = rotation_churn(store, 25, seed=5)

    store.apply_delta(delta)
    assert store.version == 1
    fresh = GraphStore.from_csr(store.csr())
    _assert_views_bit_identical(store, fresh, bs, n_buckets, engine_key)


def test_apply_delta_ordered_engine_layout_parity():
    """A layout built with a custom node order (e.g. CB packing) must
    patch against its OWN ordered bucketed view, not the default one."""
    g = webgraph_like(512, seed=4)
    store = GraphStore.from_csr(g)
    rng = np.random.default_rng(9)
    order = rng.permutation(512).astype(np.int64)
    key = (2, 4, 1, True, np.float32)
    store.engine_layout(*key, order=order)
    delta = rotation_churn(store, 20, seed=6)
    store.apply_delta(delta)
    fresh = GraphStore.from_csr(store.csr())
    la = store.engine_layout(*key, order=order)
    lb = fresh.engine_layout(*key, order=order)
    for name in ("w", "src_slot", "dst_bucket", "dst_slot", "wgt",
                 "node_of_slot", "tiles", "tile_dst", "slot_out_deg"):
        np.testing.assert_array_equal(getattr(la, name), getattr(lb, name),
                                      err_msg=name)


def test_apply_delta_on_empty_store():
    """Adding the first edges to an edgeless store works; removing from
    one raises the intended ValueError (not IndexError)."""
    store = GraphStore.from_edges(np.zeros(0, np.int64),
                                  np.zeros(0, np.int64),
                                  np.zeros(0, np.float64), 8)
    assert store.n_edges == 0
    with pytest.raises(ValueError, match="does not exist"):
        store.apply_delta(GraphDelta.make(removed_edges=np.array([[0, 1]])))
    store.apply_delta(GraphDelta.make(
        added_edges=np.array([[0, 1, 0.5], [3, 2, 0.25]])))
    assert store.n_edges == 2
    np.testing.assert_array_equal(store.out_degree(),
                                  [1, 0, 0, 1, 0, 0, 0, 0])


def test_patch_bsr_from_empty_drops_placeholder():
    """A BSR view materialized over ZERO edges holds csr_to_bsr's
    all-zero placeholder tile; the first real delta must not carry it
    into the merge (bit parity with a fresh build, clean occupancy)."""
    store = GraphStore.from_edges(np.zeros(0, np.int64),
                                  np.zeros(0, np.int64),
                                  np.zeros(0, np.float64), 64)
    t0 = store.bsr(bs=16)
    assert t0.n_blocks == 1 and not np.any(t0.blocks)
    # the added edge lands OUTSIDE block key 0, so the placeholder is
    # not in the dirty set and would survive a naive clean-mask merge
    store.apply_delta(GraphDelta.make(added_edges=np.array([[40, 33, .5]])))
    patched = store.bsr(bs=16)
    fresh = GraphStore.from_csr(store.csr()).bsr(bs=16)
    np.testing.assert_array_equal(patched.block_row, fresh.block_row)
    np.testing.assert_array_equal(patched.block_col, fresh.block_col)
    np.testing.assert_array_equal(patched.blocks, fresh.blocks)
    np.testing.assert_array_equal(patched.row_occupied, fresh.row_occupied)
    assert patched.n_blocks == 1 and not patched.row_occupied[0]


def test_stale_session_refuses_to_run():
    """A second session sharing the store must fail loudly after the
    first one applies a delta (views are patched in place)."""
    g = webgraph_like(512, seed=1)
    problem = repro.Problem.pagerank(g)
    _ = problem.graph  # materialize the shared store
    a = repro.SolverSession(problem, method="frontier:segment_sum")
    b = repro.SolverSession(problem, method="frontier:segment_sum")
    a.solve()
    b.solve()
    a.update_graph(rotation_churn(a.problem.graph, 5, seed=0))
    with pytest.raises(ValueError, match="stale Problem snapshot"):
        b.warm_start(problem.b)
    with pytest.raises(ValueError, match="stale Problem snapshot"):
        b.solve()
    a.solve()  # the updating session itself stays healthy


def test_apply_delta_capacity_growth_parity():
    """A delta that outgrows the bucket edge capacity (one node gains
    many edges) re-pads and still matches the from-scratch build."""
    g = power_law_graph(256, seed=2)
    store = GraphStore.from_csr(g)
    store.bucketed(4)
    store.bsr(32)
    store.engine_layout(1, 6, 2, True, np.float32)
    csr = store.csr()
    keys = set()
    src_e, dst_e, _ = csr.edge_list()
    for s, d in zip(src_e, dst_e):
        keys.add((int(s) << 32) | int(d))
    added = [(5, d, 1.0) for d in range(256)
             if d != 5 and ((5 << 32) | d) not in keys]
    delta = GraphDelta.make(added_edges=np.array(added))
    store.apply_delta(delta)
    fresh = GraphStore.from_csr(store.csr())
    _assert_views_bit_identical(store, fresh, 32, 4,
                                (1, 6, 2, True, np.float32))


def test_apply_delta_tile_drop_and_insert():
    """Removing a block's only edge drops the tile; adding an edge in a
    fresh block inserts one — matching csr_to_bsr's structure."""
    # two isolated edges in distinct blocks
    src = np.array([0, 40])
    dst = np.array([33, 2])
    w = np.array([0.5, 0.25])
    store = GraphStore.from_edges(src, dst, w, 64)
    t = store.bsr(bs=16)
    assert t.n_blocks == 2
    delta = GraphDelta.make(
        added_edges=np.array([[50, 60, 0.3]]),
        removed_edges=np.array([[0, 33]]),
    )
    store.apply_delta(delta)
    t2 = store.bsr(bs=16)
    fresh = GraphStore.from_csr(store.csr()).bsr(bs=16)
    np.testing.assert_array_equal(t2.block_row, fresh.block_row)
    np.testing.assert_array_equal(t2.block_col, fresh.block_col)
    np.testing.assert_array_equal(t2.blocks, fresh.blocks)
    assert t2.n_blocks == 2  # one dropped, one inserted
    assert t2.row_occupied[60 // 16] and not t2.row_occupied[33 // 16]


def test_delta_validation():
    g = power_law_graph(100, seed=0)
    store = GraphStore.from_csr(g)
    csr = store.csr()
    s0 = int(np.nonzero(csr.out_degree() > 0)[0][0])
    d0 = int(csr.out_neighbors(s0)[0][0])
    with pytest.raises(ValueError, match="already exists"):
        store.apply_delta(GraphDelta.make(
            added_edges=np.array([[s0, d0, 1.0]])))
    nbrs = set(csr.out_neighbors(s0)[0].tolist())
    d_missing = next(d for d in range(100) if d not in nbrs and d != s0)
    with pytest.raises(ValueError, match="does not exist"):
        store.apply_delta(GraphDelta.make(
            removed_edges=np.array([[s0, d_missing]])))
    with pytest.raises(ValueError, match="duplicate"):
        GraphDelta.make(added_edges=np.array([[1, 2, 0.5]]),
                        removed_edges=np.array([[1, 2]]))
    with pytest.raises(TypeError):
        store.apply_delta("not a delta")
    v = store.version
    store.apply_delta(GraphDelta.make())  # empty = no-op
    assert store.version == v


def test_from_edge_file(tmp_path):
    path = tmp_path / "snap.txt"
    path.write_text(textwrap.dedent("""\
        # SNAP-style comment header
        # src dst
        0 1
        1 2
        2 0
        2 2
        0 1
        3 1
        """))
    store = GraphStore.from_edge_file(str(path))
    assert store.n == 4
    assert store.n_edges == 4  # self-loop dropped, duplicate deduped
    csr = store.csr()
    np.testing.assert_array_equal(csr.out_degree(), [1, 1, 1, 1])

    wpath = tmp_path / "weighted.txt"
    wpath.write_text("0 1 0.5\n1 0 0.25\n")
    ws = GraphStore.from_edge_file(str(wpath), weighted=True)
    np.testing.assert_allclose(np.sort(ws.csr().weights), [0.25, 0.5])

    with pytest.raises(ValueError, match="ids >= n"):
        GraphStore.from_edge_file(str(path), n=2)
    # solves end-to-end through the front door
    rep = repro.solve(repro.Problem.pagerank(store),
                      method="frontier:segment_sum")
    assert rep.converged and rep.x.shape == (4,)


# --------------------------------------------------------------------------- #
# Problem integration (the with_graph satellite)
# --------------------------------------------------------------------------- #
def test_problem_with_graph_shares_store():
    g = webgraph_like(512, seed=1)
    problem = repro.Problem.pagerank(g)
    store = problem.graph  # lazily created, then pinned
    assert problem.graph is store
    delta = rotation_churn(store, 5, seed=0)
    store.apply_delta(delta)
    p2 = problem.with_graph(store)
    assert p2.graph is store
    assert p2.b is problem.b and p2.target_error == problem.target_error
    # the new snapshot reflects the patched matrix
    assert p2.p.n_edges == store.n_edges
    with pytest.raises(ValueError, match="cannot change N"):
        problem.with_graph(GraphStore.from_csr(webgraph_like(256, seed=2)))
    # the ORIGINAL problem is now a stale snapshot (its store advanced
    # past the version it captured) — using it must fail loudly instead
    # of silently solving a mixed system
    with pytest.raises(ValueError, match="stale Problem snapshot"):
        problem.graph


def test_graph_churn_load_signal():
    from repro.balance.signals import LoadSignal

    sig = LoadSignal.from_graph_churn(
        np.array([30, 10, 0, 0]), sizes=np.array([4, 4, 4, 4]), step=3)
    assert sig.kind == "graph-churn"
    np.testing.assert_allclose(sig.values, [0.75, 0.25, 0.0, 0.0])
    g = power_law_graph(64, seed=0)
    store = GraphStore.from_csr(g)
    delta = rotation_churn(store, 4, seed=1)
    churn = delta.churn_per_node(64)
    assert churn.sum() == delta.n_changes
    assert churn.shape == (64,)


# --------------------------------------------------------------------------- #
# the delta re-solve acceptance scenario (1% churn, N=4096 webgraph)
# --------------------------------------------------------------------------- #
@pytest.fixture(scope="module")
def web4096_graph():
    return webgraph_like(4096, seed=1)


@pytest.mark.parametrize("method", ["frontier:segment_sum", "engine:bsr"])
def test_update_graph_5x_fewer_ops_than_cold(web4096_graph, method):
    """1% edge churn (link rotations in the non-hub tail) re-solves
    with >= 5x fewer edge pushes than a cold solve of the same patched
    problem — on a frontier AND an engine backend (acceptance)."""
    # each test owns its Problem: update_graph mutates the shared store
    problem = repro.Problem.pagerank(web4096_graph)
    session = repro.SolverSession(problem, method=method)
    cold_pre = session.solve()
    assert cold_pre.converged

    n_rot = int(0.01 * problem.n_edges) // 2  # 2 changed edges / rotation
    delta = rotation_churn(session.problem.graph, n_rot, seed=7,
                           rank=cold_pre.x, exclude_top=0.2)
    assert delta.n_changes >= int(0.009 * problem.n_edges)

    resid0 = session.update_graph(delta)
    assert 0 < resid0 < np.abs(problem.b).sum()
    warm = session.solve()
    assert warm.converged

    cold = repro.SolverSession(session.problem, method=method).solve()
    assert cold.converged
    assert cold.n_ops >= 5 * warm.n_ops, (method, cold.n_ops, warm.n_ops)
    # both drained to the same target: solutions within 2*target_error
    assert np.abs(warm.x - cold.x).sum() <= 2 * problem.target_error


def test_update_graph_matches_cold_tight():
    """At a tight target the warm delta re-solve lands within
    |Δx|₁ <= 1e-6 of the cold solve of the patched problem (the
    graph-update-parity CI tolerance)."""
    g = webgraph_like(4096, seed=1)
    for method in ("frontier:segment_sum", "engine:bsr"):
        problem = repro.Problem.pagerank(g, target_error=2.5e-7)
        session = repro.SolverSession(problem, method=method)
        session.solve()
        delta = rotation_churn(session.problem.graph, 40, seed=3)
        session.update_graph(delta)
        warm = session.solve()
        cold = repro.SolverSession(session.problem, method=method).solve()
        assert warm.converged and cold.converged
        l1 = np.abs(warm.x - cold.x).sum()
        assert l1 <= 1e-6, (method, l1)


def test_update_graph_identity_noop_is_cheap(web4096_graph):
    """Reweighting edges to their CURRENT weights injects only f32
    re-derivation noise: the follow-up solve is (near) free."""
    problem = repro.Problem.pagerank(web4096_graph)
    session = repro.SolverSession(problem, method="frontier:segment_sum")
    first = session.solve()
    csr = session.problem.graph.csr()
    src_e, dst_e, w_e = csr.edge_list()
    rng = np.random.default_rng(11)
    pick = rng.choice(src_e.shape[0], size=64, replace=False)
    delta = GraphDelta.make(reweighted=(
        src_e[pick].astype(np.int64), dst_e[pick].astype(np.int64),
        w_e[pick]))
    resid0 = session.update_graph(delta)
    assert resid0 == pytest.approx(first.residual, rel=0.05)
    again = session.solve()
    assert again.n_ops <= max(64, first.n_ops // 100)


# --------------------------------------------------------------------------- #
# engine churn signal -> balance control plane (multi-device subprocess)
# --------------------------------------------------------------------------- #
CHURN_SIGNAL_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, {src!r})
    import numpy as np
    import repro
    from repro.api.session import _DRIVERS
    from repro.balance.plan import MovePlan
    from repro.core import webgraph_like
    from repro.graph import rotation_churn

    g = webgraph_like(2048, seed=1)
    problem = repro.Problem.pagerank(g)
    options = repro.SolverOptions(k=4).validated()
    driver = _DRIVERS["engine:chunk"](problem, options)
    driver.seed(problem.b)

    class Recorder:
        def __init__(self):
            self.signals = []
        def propose(self, sig):
            self.signals.append(sig)
            return [MovePlan(src=0, dst=1, units=1, kind="bucket")]
        def reset_worker(self, k):
            pass

    rec = Recorder()
    driver.engine.rebalancer = rec
    delta = rotation_churn(problem.graph, 50, seed=2)
    driver.note_graph_churn(delta.churn_per_node(problem.n))
    assert len(rec.signals) == 1, rec.signals
    sig = rec.signals[0]
    assert sig.kind == "graph-churn"
    assert sig.values.shape == (4,)
    assert abs(sig.values.sum() - 1.0) < 1e-12
    # the proposed move executed and was logged
    assert driver.move_log(), "churn-driven MovePlan was not executed"

    # the session-level path end-to-end: engine with a real policy
    session = repro.SolverSession(problem, method="engine:chunk",
                                  options=repro.SolverOptions(
                                      k=4, policy="hysteresis"))
    session.solve()
    d2 = rotation_churn(session.problem.graph, 50, seed=3)
    session.update_graph(d2)
    warm = session.solve()
    cold = repro.SolverSession(session.problem, method="engine:chunk",
                               options=repro.SolverOptions(
                                   k=4, policy="hysteresis")).solve()
    assert warm.converged and cold.converged
    assert np.abs(warm.x - cold.x).sum() <= 2 * session.problem.target_error
    print("CHURN-SIGNAL-OK")
    """
)


def test_engine_churn_signal_feeds_rebalancer():
    """Graph churn maps onto owning devices, reaches the rebalancer as
    a graph-churn LoadSignal, and its MovePlans execute (subprocess
    with 8 fake host devices)."""
    script = CHURN_SIGNAL_SCRIPT.format(src=os.path.abspath(SRC))
    res = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, res.stderr[-4000:]
    assert "CHURN-SIGNAL-OK" in res.stdout
