"""The repro.api front door: registry, parity, sessions, validation.

Acceptance (ISSUE 3): for a fixed N=4096 webgraph problem, ``solve()``
via every registered backend returns :class:`SolveReport`\\ s whose x
agree to a 1e-6-scaled |Δx|_1 tolerance and whose ``n_ops`` fields use
the same edge-push accounting; ``SolverSession.warm_start`` reaches the
target with strictly fewer edge pushes than a cold solve on both
``frontier:segment_sum`` and ``engine:bsr``; ``repro.api.__all__`` is
snapshot-pinned so accidental surface breaks fail loudly.
"""
import numpy as np
import pytest

import repro
from repro.api import Problem, SolverOptions, SolverSession, solve
from repro.core import pagerank_system, power_law_graph, webgraph_like

ALL_BACKENDS = (
    "engine:bsr",
    "engine:chunk",
    "frontier:pallas",
    "frontier:segment_sum",
    "sequential",
    "simulator",
)

# frozen public surface — extend deliberately, never by accident
# (PR 4 deliberately added GraphStore/GraphDelta: the delta layer)
API_SURFACE = [
    "BackendCapabilities",
    "GraphDelta",
    "GraphStore",
    "Problem",
    "RoundReport",
    "SolveReport",
    "SolverOptions",
    "SolverSession",
    "get_backend",
    "list_backends",
    "register_backend",
    "solve",
]


def test_api_surface_snapshot():
    assert sorted(repro.api.__all__) == API_SURFACE
    assert sorted(repro.__all__) == API_SURFACE
    for name in API_SURFACE:
        assert getattr(repro, name) is getattr(repro.api, name)


def test_registry_lists_all_backends_with_capabilities():
    caps = repro.list_backends()
    assert tuple(sorted(caps)) == tuple(sorted(ALL_BACKENDS))
    # capability matrix spot checks (DESIGN.md §4 table)
    assert caps["simulator"].supports_dynamic_partition
    assert caps["engine:bsr"].supports_dynamic_partition
    assert caps["frontier:segment_sum"].supports_batch
    assert caps["frontier:segment_sum"].supports_warm_start
    assert caps["engine:bsr"].supports_warm_start
    assert not caps["sequential"].supports_warm_start
    assert caps["simulator"].configurable_k
    assert not caps["frontier:pallas"].configurable_k
    with pytest.raises(KeyError):
        repro.get_backend("no-such-backend")


# --------------------------------------------------------------------------- #
# cross-backend parity (the acceptance criterion)
# --------------------------------------------------------------------------- #
@pytest.fixture(scope="module")
def web4096_reports():
    g = webgraph_like(4096, seed=1)
    problem = Problem.pagerank(g, target_error=2.5e-7)
    reports = {}
    for method in ALL_BACKENDS:
        reports[method] = solve(
            problem, method=method,
            options=SolverOptions(
                k=4 if method == "simulator" else None, record_every=100),
        )
    return problem, reports


def test_backend_parity_x(web4096_reports):
    """Every backend lands within the 1e-6-scaled |Δx|_1 ball."""
    problem, reports = web4096_reports
    ref = reports["sequential"].x
    for method, rep in reports.items():
        assert rep.converged, method
        assert rep.x.shape == (problem.n,)
        l1 = np.abs(rep.x - ref).sum()
        # each backend stops at |F|_1 <= te*eps => |x - x*|_1 <= te;
        # pairwise therefore <= 2*te = 5e-7, plus f32 headroom
        assert l1 <= 1e-6, (method, l1)


def test_backend_parity_ops_accounting(web4096_reports):
    """n_ops is the same §2.3 edge-push unit on every backend: the
    normalized costs of all six tiers agree to schedule slack, and the
    report-level invariant cost_iterations == n_ops/L holds exactly."""
    problem, reports = web4096_reports
    costs = {}
    for method, rep in reports.items():
        assert rep.n_ops > 0, method
        assert rep.cost_iterations == pytest.approx(
            rep.n_ops / problem.n_edges)
        costs[method] = rep.cost_iterations
    ref = costs["frontier:segment_sum"]
    for method, c in costs.items():
        assert 0.7 * ref <= c <= 1.43 * ref, (method, c, ref)


def test_backend_parity_report_fields(web4096_reports):
    """Strict field parity: every backend fills every unified field."""
    _, reports = web4096_reports
    for method, rep in reports.items():
        assert rep.method == method
        assert rep.trace, method
        assert rep.trace[-1].n_ops == rep.n_ops
        assert rep.trace[-1].residual == pytest.approx(rep.residual)
        rounds = [t.round for t in rep.trace]
        assert rounds == sorted(rounds), method
        assert rep.n_rounds >= rounds[-1] if rounds else True
        assert rep.wall_time_s > 0
        assert isinstance(rep.move_log, list)
        assert np.isfinite(rep.residual)


# --------------------------------------------------------------------------- #
# SolverSession: warm start + streaming + batch
# --------------------------------------------------------------------------- #
# every warm-startable backend must beat its own cold solve after an
# RHS drift; frontier:pallas runs the real kernel in interpret mode on
# a smaller instance (emulation speed), with a looser x tolerance to
# absorb its f32 round-trip at the default 1/N target
@pytest.mark.parametrize("method,n,target_error,opts,x_atol", [
    ("frontier:segment_sum", 2000, 1e-6, {}, 1e-5),
    ("engine:bsr", 2000, 1e-6, {}, 1e-5),
    ("engine:chunk", 2000, 1e-6, {}, 1e-5),
    ("frontier:pallas", 512, None, {"interpret": True, "bs": 64}, 1e-3),
])
def test_warm_start_strictly_fewer_ops(method, n, target_error, opts,
                                       x_atol):
    """After perturbing B, the warm-started solve reaches target_error
    with strictly fewer edge-push ops than a cold solve (satellite)."""
    g = webgraph_like(n, seed=1)
    problem = Problem.pagerank(g, target_error=target_error)
    options = SolverOptions(**opts)
    session = SolverSession(problem, method=method, options=options)
    session.solve()

    rng = np.random.default_rng(7)
    b_new = problem.b * (1.0 + 0.05 * rng.standard_normal(g.n))
    b_new = np.abs(b_new)

    cold = SolverSession(problem.with_b(b_new), method=method,
                         options=options).solve()
    assert cold.converged

    resid0 = session.warm_start(b_new)
    warm = session.solve()
    assert warm.converged
    assert resid0 < np.abs(b_new).sum()  # H absorbed most of the fluid
    assert warm.n_ops < cold.n_ops, (method, warm.n_ops, cold.n_ops)
    np.testing.assert_allclose(warm.x, cold.x, atol=x_atol)


def test_warm_start_identity_exact(small_pagerank):
    """F' = B' − (I−P)H: warm-starting with the *same* B leaves only the
    converged residual (up to f32 re-derivation noise), so the follow-up
    solve is free — zero further edge pushes."""
    p, b, x = small_pagerank
    problem = Problem.linear(p, b, eps=0.15, target_error=1e-5)
    session = SolverSession(problem, method="frontier:segment_sum")
    first = session.solve()
    resid0 = session.warm_start(b)
    # the re-derived fluid is the converged residual (f32 noise aside) …
    assert resid0 == pytest.approx(first.residual, rel=0.05)
    again = session.solve()
    # … so the follow-up solve is (near) free: the converged state sat
    # knife-edge under tol, a handful of pushes at most to re-dip
    assert again.n_ops <= max(64, first.n_ops // 100), (
        again.n_ops, first.n_ops)
    np.testing.assert_allclose(again.x, first.x, atol=1e-6)


def test_session_streaming_rounds(small_pagerank):
    p, b, x = small_pagerank
    problem = Problem.linear(p, b, eps=0.15, target_error=1e-7)
    session = SolverSession(problem, method="frontier:segment_sum",
                            options=SolverOptions(trace_every=16))
    reports = list(session.run())
    assert len(reports) >= 2
    rounds = [r.round for r in reports]
    assert rounds == sorted(rounds)
    assert all(b.n_ops >= a.n_ops for a, b in zip(reports, reports[1:]))
    assert reports[-1].residual <= problem.tol
    np.testing.assert_allclose(session.x, x, atol=1e-5)


def test_session_rejects_one_shot_backends(small_pagerank):
    p, b, _ = small_pagerank
    problem = Problem.linear(p, b, eps=0.15, target_error=1e-6)
    with pytest.raises(ValueError, match="one-shot"):
        SolverSession(problem, method="sequential")


def test_solve_batch_matches_single_columns(small_pagerank):
    """Multi-RHS vmapped solve == per-column single solves."""
    p, b, _ = small_pagerank
    problem = Problem.linear(p, b, eps=0.15, target_error=1e-7)
    rng = np.random.default_rng(3)
    bmat = np.abs(rng.random((p.n, 3))) / p.n
    session = SolverSession(problem, method="frontier:segment_sum")
    batch = session.solve_batch(bmat)
    assert batch.converged and batch.x.shape == (p.n, 3)
    assert batch.extras["batch"] == 3
    for c in range(3):
        single = solve(problem.with_b(bmat[:, c]),
                       method="frontier:segment_sum")
        np.testing.assert_allclose(batch.x[:, c], single.x, atol=1e-5)


def test_batched_problem_auto_dispatch():
    """A personalization batch routes to a batch-capable backend."""
    g = power_law_graph(200, seed=5)
    pref = np.zeros((g.n, 2))
    pref[0, 0] = pref[1, 1] = 1.0
    problem = Problem.pagerank(g, target_error=1e-6,
                               personalization=pref)
    rep = solve(problem)  # method="auto"
    assert rep.x.shape == (g.n, 2)
    assert repro.list_backends()[rep.method].supports_batch
    with pytest.raises(ValueError, match="multi-RHS"):
        solve(problem, method="simulator")


# --------------------------------------------------------------------------- #
# options validation (the satellite: no silently-ignored flags)
# --------------------------------------------------------------------------- #
def test_policy_implies_dynamic(small_pagerank):
    p, b, _ = small_pagerank
    problem = Problem.linear(p, b, eps=0.15, target_error=1e-6)
    rep = solve(problem, method="simulator", k=4, policy="hysteresis",
                record_every=50)
    assert rep.converged  # ran with the controller enabled
    # and the normalization is visible on the options object itself
    assert SolverOptions(policy="slope_ema").validated().dynamic


def test_inconsistent_flags_raise(small_pagerank):
    p, b, _ = small_pagerank
    problem = Problem.linear(p, b, eps=0.15, target_error=1e-6)
    with pytest.raises(ValueError, match="single-process"):
        solve(problem, method="sequential", k=4)
    with pytest.raises(ValueError, match="k >= 2"):
        solve(problem, method="simulator", k=1, dynamic=True)
    with pytest.raises(ValueError, match="dynamic partition"):
        solve(problem, method="frontier:segment_sum", dynamic=True)
    with pytest.raises(ValueError, match="unknown policy"):
        SolverOptions(policy="nope").validated()
    with pytest.raises(ValueError, match="physical devices"):
        solve(problem, method="engine:chunk", k=64)


def test_auto_dispatch_honors_k_on_one_device_host(small_pagerank):
    """k>1 without enough devices: auto falls back to virtual PIDs."""
    import jax

    p, b, _ = small_pagerank
    problem = Problem.linear(p, b, eps=0.15, target_error=1e-6)
    k = len(jax.devices()) + 1
    rep = solve(problem, k=k, record_every=50)
    assert rep.method == "simulator"
    assert rep.converged


def test_problem_validation():
    g = power_law_graph(50, seed=0)
    p, b = pagerank_system(g)
    with pytest.raises(ValueError, match="shape"):
        Problem.linear(p, b[:-1], eps=0.15)
    with pytest.raises(ValueError, match="eps or rho"):
        Problem.linear(p, b)
    with pytest.raises(ValueError, match="target_error"):
        Problem.linear(p, b, eps=0.15, target_error=0.0)
    with pytest.raises(ValueError, match="personalization"):
        Problem.pagerank(g, personalization=np.ones((g.n - 1, 2)))
    prob = Problem.pagerank(g)
    assert prob.target_error == pytest.approx(1.0 / g.n)
    assert prob.eps == pytest.approx(0.15)
    assert prob.tol == pytest.approx(0.15 / g.n)


# --------------------------------------------------------------------------- #
# deprecated shims delegate through the registry
# --------------------------------------------------------------------------- #
def test_deprecated_entrypoints_warn_and_agree(small_pagerank):
    from repro.core import solve_frontier_jnp, solve_sequential

    p, b, x = small_pagerank
    with pytest.warns(DeprecationWarning, match="repro.solve"):
        legacy = solve_sequential(p, b, target_error=1e-7, eps=0.15)
    new = solve(Problem.linear(p, b, eps=0.15, target_error=1e-7),
                method="sequential")
    np.testing.assert_allclose(legacy.x, new.x, atol=0)
    assert legacy.n_ops == new.n_ops
    assert legacy.n_sweeps == new.n_rounds
    with pytest.warns(DeprecationWarning, match="repro.solve"):
        legacy_f = solve_frontier_jnp(p, b, target_error=1e-7, eps=0.15)
    np.testing.assert_allclose(legacy_f.x, new.x, atol=1e-5)
