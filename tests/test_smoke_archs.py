"""Per-architecture smoke tests: reduced same-family config, one
forward + one train step on CPU, output shapes + no NaNs (deliverable f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_arch
from repro.configs.smoke import smoke_setup
from repro.models import gnn as gnn_model
from repro.models import recsys as fm_model
from repro.models import transformer as lm
from repro.optim import AdamWConfig, adamw_init, adamw_update


def _finite_tree(t):
    return all(bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(t))


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_train_step(arch_id):
    cfg, batch, family = smoke_setup(arch_id)
    key = jax.random.PRNGKey(0)
    ocfg = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=10)
    if family == "lm":
        params = lm.init_params(cfg, key)
        loss_fn = lambda p: lm.train_loss(p, batch, cfg)
    elif family == "gnn":
        params = gnn_model.init_params(cfg, key)
        loss_fn = lambda p: gnn_model.loss_fn(p, batch, cfg)
    else:
        params = fm_model.init_params(cfg, key)
        loss_fn = lambda p: fm_model.loss_fn(p, batch, cfg)
    opt = adamw_init(params)
    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert np.isfinite(float(loss)), (arch_id, float(loss))
    assert _finite_tree(grads), arch_id
    new_params, opt, metrics = adamw_update(
        grads, opt, ocfg, param_dtype=cfg.dtype
    )
    assert _finite_tree(new_params), arch_id
    # shapes preserved by the update
    same = jax.tree.map(lambda a, b: a.shape == b.shape, params, new_params)
    assert all(jax.tree.leaves(same)), arch_id


@pytest.mark.parametrize(
    "arch_id",
    [a for a in ARCH_IDS if get_arch(a).family == "lm"],
)
def test_smoke_lm_decode(arch_id):
    """Decode shapes apply to every (decoder) LM arch: prefill + 2 steps."""
    cfg, batch, _ = smoke_setup(arch_id)
    params = lm.init_params(cfg, jax.random.PRNGKey(1))
    toks = batch["tokens"][:, :8]
    cache, logits = lm.prefill_step(params, toks, cfg, max_seq=12)
    assert logits.shape == (toks.shape[0], cfg.vocab)
    for t in (8, 9):
        logits, cache = lm.decode_step(
            params, cache, batch["tokens"][:, t], cfg)
        assert logits.shape == (toks.shape[0], cfg.vocab)
        assert bool(jnp.isfinite(logits).all())
    assert int(cache["pos"]) == 10


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_full_configs_are_exact(arch_id):
    """The FULL configs carry the assignment-table dimensions exactly."""
    spec = get_arch(arch_id)
    c = spec.model_cfg
    expect = {
        "qwen2-moe-a2.7b": lambda: (
            c.n_layers == 24 and c.d_model == 2048 and c.n_heads == 16
            and c.n_kv_heads == 16 and c.vocab == 151936
            and c.moe.n_experts == 60 and c.moe.top_k == 4
            and c.moe.n_shared == 4),
        "granite-moe-1b-a400m": lambda: (
            c.n_layers == 24 and c.d_model == 1024 and c.n_kv_heads == 8
            and c.vocab == 49155 and c.moe.n_experts == 32
            and c.moe.top_k == 8 and c.moe.d_ff_expert == 512),
        "command-r-plus-104b": lambda: (
            c.n_layers == 64 and c.d_model == 12288 and c.n_heads == 96
            and c.n_kv_heads == 8 and c.d_ff == 33792
            and c.vocab == 256000 and not c.qkv_bias),
        "qwen1.5-0.5b": lambda: (
            c.n_layers == 24 and c.d_model == 1024 and c.n_heads == 16
            and c.n_kv_heads == 16 and c.d_ff == 2816
            and c.vocab == 151936 and c.qkv_bias),
        "mistral-large-123b": lambda: (
            c.n_layers == 88 and c.d_model == 12288 and c.n_heads == 96
            and c.n_kv_heads == 8 and c.d_ff == 28672 and c.vocab == 32768),
        "meshgraphnet": lambda: (c.n_layers == 15 and c.d_hidden == 128),
        "egnn": lambda: (c.n_layers == 4 and c.d_hidden == 64),
        "gin-tu": lambda: (c.n_layers == 5 and c.d_hidden == 64
                           and c.eps_learnable),
        "dimenet": lambda: (c.n_layers == 6 and c.d_hidden == 128
                            and c.n_bilinear == 8 and c.n_spherical == 7
                            and c.n_radial == 6),
        "fm": lambda: (c.n_fields == 39 and c.embed_dim == 10
                       and c.n_rows == 39_000_000),
    }
    assert expect[arch_id](), f"{arch_id} config drifted from assignment"


def test_forty_cells_present():
    total = 0
    for a in ARCH_IDS:
        total += sum(1 for c in get_arch(a).cells.values()
                     if not c.meta.get("extra"))
    assert total == 40
