"""Reference D-iteration solvers vs dense oracle (paper §2.1)."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # optional dep: property tests skip, fallbacks run
    HAVE_HYPOTHESIS = False

from repro.core import (
    jacobi_solve,
    pagerank_system,
    power_law_graph,
    random_dd_system,
    solve_frontier_jnp,
    solve_sequential,
)


def test_sequential_matches_dense(small_pagerank):
    p, b, x = small_pagerank
    res = solve_sequential(p, b, target_error=1e-8, eps=0.15)
    assert res.residual <= 1e-8 * 0.15
    np.testing.assert_allclose(res.x, x, atol=1e-7)


def test_frontier_matches_dense(small_pagerank):
    p, b, x = small_pagerank
    res = solve_frontier_jnp(p, b, target_error=1e-7, eps=0.15)
    np.testing.assert_allclose(res.x, x, atol=1e-5)


def test_frontier_and_sequential_agree(small_pagerank):
    """Any schedule converges to the same fixed point (schedule-freedom)."""
    p, b, x = small_pagerank
    r1 = solve_sequential(p, b, target_error=1e-8, eps=0.15)
    r2 = solve_frontier_jnp(p, b, target_error=1e-8, eps=0.15)
    np.testing.assert_allclose(r1.x, r2.x, atol=1e-5)


def test_jacobi_agrees(small_pagerank):
    p, b, x = small_pagerank
    xj, iters = jacobi_solve(p, b, target_error=1e-10, eps=0.15)
    np.testing.assert_allclose(xj, x, atol=1e-8)
    assert iters > 1


def test_diteration_cheaper_than_jacobi(small_pagerank):
    """Paper claim C4: D-iteration needs fewer normalized matvecs."""
    p, b, _ = small_pagerank
    res = solve_sequential(p, b, target_error=1e-6, eps=0.15)
    _, jac_iters = jacobi_solve(p, b, target_error=1e-6, eps=0.15)
    assert res.cost_iterations < jac_iters


def test_signed_general_system():
    """General DD case: entries of P and B may be negative (paper §2)."""
    g, b = random_dd_system(80, density=0.1, rho=0.7, seed=1, signed=True)
    x = np.linalg.solve(np.eye(g.n) - g.to_dense(), b)
    res = solve_sequential(g, b, target_error=1e-10, eps=0.3)
    np.testing.assert_allclose(res.x, x, atol=1e-6)


def _check_dd_system_converges(n, rho, seed):
    """Any spectral-radius<1 system is solved by the diffusion."""
    g, b = random_dd_system(n, density=0.15, rho=rho, seed=seed, signed=True)
    x = np.linalg.solve(np.eye(n) - g.to_dense(), b)
    res = solve_sequential(g, b, target_error=1e-9, eps=1 - rho)
    np.testing.assert_allclose(res.x, x, atol=1e-5)


if HAVE_HYPOTHESIS:

    @settings(max_examples=10, deadline=None)
    @given(
        n=st.integers(20, 60),
        rho=st.floats(0.3, 0.9),
        seed=st.integers(0, 10_000),
    )
    def test_property_dd_systems_converge(n, rho, seed):
        _check_dd_system_converges(n, rho, seed)


@pytest.mark.parametrize(
    "n,rho,seed", [(20, 0.3, 0), (40, 0.6, 7), (60, 0.9, 1234)]
)
def test_dd_systems_converge_cases(n, rho, seed):
    """Deterministic fallback for the property test (no hypothesis)."""
    _check_dd_system_converges(n, rho, seed)


def test_h_plus_f_invariant(small_pagerank):
    """Conservation: H_n + F_n ``covers`` B exactly — at any stopping point
    X_exact - H = (I-P)^{-1} F (error controlled by |F|)."""
    p, b, x = small_pagerank
    res = solve_sequential(p, b, target_error=1e-3, eps=0.15)
    err = np.abs(res.x - x).sum()
    # |x - h|_1 <= |F|_1 / (1 - rho); rho <= damping = 0.85
    assert err <= res.residual / (1 - 0.85) + 1e-12
