"""Reference D-iteration solvers vs dense oracle (paper §2.1)."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # optional dep: property tests skip, fallbacks run
    HAVE_HYPOTHESIS = False

from repro.core import (
    CSRGraph,
    frontier_step,
    jacobi_solve,
    pagerank_system,
    power_law_graph,
    random_dd_system,
    solve_frontier_jnp,
    solve_sequential,
)


def test_sequential_matches_dense(small_pagerank):
    p, b, x = small_pagerank
    res = solve_sequential(p, b, target_error=1e-8, eps=0.15)
    assert res.residual <= 1e-8 * 0.15
    np.testing.assert_allclose(res.x, x, atol=1e-7)


def test_frontier_matches_dense(small_pagerank):
    p, b, x = small_pagerank
    res = solve_frontier_jnp(p, b, target_error=1e-7, eps=0.15)
    np.testing.assert_allclose(res.x, x, atol=1e-5)


def test_frontier_and_sequential_agree(small_pagerank):
    """Any schedule converges to the same fixed point (schedule-freedom)."""
    p, b, x = small_pagerank
    r1 = solve_sequential(p, b, target_error=1e-8, eps=0.15)
    r2 = solve_frontier_jnp(p, b, target_error=1e-8, eps=0.15)
    np.testing.assert_allclose(r1.x, r2.x, atol=1e-5)


def test_jacobi_agrees(small_pagerank):
    p, b, x = small_pagerank
    xj, iters = jacobi_solve(p, b, target_error=1e-10, eps=0.15)
    np.testing.assert_allclose(xj, x, atol=1e-8)
    assert iters > 1


def test_diteration_cheaper_than_jacobi(small_pagerank):
    """Paper claim C4: D-iteration needs fewer normalized matvecs."""
    p, b, _ = small_pagerank
    res = solve_sequential(p, b, target_error=1e-6, eps=0.15)
    _, jac_iters = jacobi_solve(p, b, target_error=1e-6, eps=0.15)
    assert res.cost_iterations < jac_iters


def test_signed_general_system():
    """General DD case: entries of P and B may be negative (paper §2)."""
    g, b = random_dd_system(80, density=0.1, rho=0.7, seed=1, signed=True)
    x = np.linalg.solve(np.eye(g.n) - g.to_dense(), b)
    res = solve_sequential(g, b, target_error=1e-10, eps=0.3)
    np.testing.assert_allclose(res.x, x, atol=1e-6)


def _check_dd_system_converges(n, rho, seed):
    """Any spectral-radius<1 system is solved by the diffusion."""
    g, b = random_dd_system(n, density=0.15, rho=rho, seed=seed, signed=True)
    x = np.linalg.solve(np.eye(n) - g.to_dense(), b)
    res = solve_sequential(g, b, target_error=1e-9, eps=1 - rho)
    np.testing.assert_allclose(res.x, x, atol=1e-5)


if HAVE_HYPOTHESIS:

    @settings(max_examples=10, deadline=None)
    @given(
        n=st.integers(20, 60),
        rho=st.floats(0.3, 0.9),
        seed=st.integers(0, 10_000),
    )
    def test_property_dd_systems_converge(n, rho, seed):
        _check_dd_system_converges(n, rho, seed)


@pytest.mark.parametrize(
    "n,rho,seed", [(20, 0.3, 0), (40, 0.6, 7), (60, 0.9, 1234)]
)
def test_dd_systems_converge_cases(n, rho, seed):
    """Deterministic fallback for the property test (no hypothesis)."""
    _check_dd_system_converges(n, rho, seed)


def test_frontier_pallas_backend_matches_dense(small_pagerank):
    """BSR-kernel solve path reaches the same fixed point as the dense
    oracle and the per-edge segment_sum path (schedule equivalence)."""
    p, b, x = small_pagerank
    r_edge = solve_frontier_jnp(p, b, target_error=1e-7, eps=0.15)
    r_bsr = solve_frontier_jnp(p, b, target_error=1e-7, eps=0.15,
                               backend="pallas")
    np.testing.assert_allclose(r_bsr.x, x, atol=1e-5)
    np.testing.assert_allclose(r_bsr.x, r_edge.x, atol=1e-5)
    # same schedule -> same §2.3 cost accounting (tiny f32 drift tolerated)
    assert r_bsr.n_sweeps == pytest.approx(r_edge.n_sweeps, rel=0.02)
    assert r_bsr.n_ops == pytest.approx(r_edge.n_ops, rel=0.02)


def test_frontier_pallas_interpret_solve():
    """End-to-end solve through the real Pallas kernel (interpret mode)."""
    g = power_law_graph(150, seed=5)
    p, b = pagerank_system(g)
    x = np.linalg.solve(np.eye(g.n) - p.to_dense(), b)
    res = solve_frontier_jnp(p, b, target_error=1e-6, eps=0.15,
                             backend="pallas", interpret=True)
    np.testing.assert_allclose(res.x, x, atol=1e-5)


# --------------------------------------------------------------------------- #
# §2.3 op accounting on the frontier path (dangling charged one op)
# --------------------------------------------------------------------------- #
def _ops_graph():
    """Node 0 -> {1,2,3}; node 1 -> 2; node 4 dangling."""
    src = np.array([0, 0, 0, 1], np.int32)
    dst = np.array([1, 2, 3, 2], np.int32)
    w = np.full(4, 0.2)
    return CSRGraph.from_edges(src, dst, w, 5)


def test_frontier_step_charges_edges_and_dangling():
    """A frontier round costs one op per edge push plus one per selected
    dangling node — NOT one per selected node (the historical formula
    ``sum(edge_active) + (sum(sel) - sum(edge_active))`` collapsed to the
    diffusion count and undercounted every node with out-degree > 1)."""
    import jax.numpy as jnp

    g = _ops_graph()
    src, dst, wgt = g.edge_list()
    f = jnp.asarray(np.array([10.0, 0.5, 0.0, 0.0, 8.0]))
    h = jnp.zeros(5)
    weights = jnp.ones(5)
    dang = jnp.asarray(g.dangling_mask())
    # T = 1: nodes 0 (outdeg 3) and 4 (dangling) are selected
    _f, _h, _t, ops = frontier_step(
        f, h, jnp.asarray(1.0), jnp.asarray(src), jnp.asarray(dst),
        jnp.asarray(wgt), weights, dang, 5)
    assert int(ops) == 3 + 1, int(ops)


def test_frontier_ops_parity_with_sequential_on_dangling_graph():
    """Both schedules charge max(out_degree, 1) per diffusion (§2.3), so
    their normalized costs on a dangling-heavy graph must agree to within
    schedule slack — the pre-fix frontier accounting (one op per diffused
    node) sat at ~1/avg_degree of the sequential cost and fails this."""
    g = power_law_graph(400, seed=11)
    assert g.dangling_mask().sum() > 0  # dangling nodes really present
    p, b = pagerank_system(g)
    r_seq = solve_frontier_jnp(p, b, target_error=1e-6, eps=0.15)
    r_ref = solve_sequential(p, b, target_error=1e-6, eps=0.15)
    assert r_seq.n_ops > 0 and r_ref.n_ops > 0
    ratio = r_seq.n_ops / r_ref.n_ops
    assert 0.5 < ratio < 3.0, ratio
    # pallas backend runs the same schedule with the same accounting
    r_bsr = solve_frontier_jnp(p, b, target_error=1e-6, eps=0.15,
                               backend="pallas")
    assert r_bsr.n_ops == pytest.approx(r_seq.n_ops, rel=0.02)


def test_h_plus_f_invariant(small_pagerank):
    """Conservation: H_n + F_n ``covers`` B exactly — at any stopping point
    X_exact - H = (I-P)^{-1} F (error controlled by |F|)."""
    p, b, x = small_pagerank
    res = solve_sequential(p, b, target_error=1e-3, eps=0.15)
    err = np.abs(res.x - x).sum()
    # |x - h|_1 <= |F|_1 / (1 - rho); rho <= damping = 0.85
    assert err <= res.residual / (1 - 0.85) + 1e-12
