import numpy as np
import pytest

from repro.core import CSRGraph, pagerank_system, power_law_graph


@pytest.fixture(scope="session")
def small_pagerank():
    """(P, b, x_dense) for a 300-node power-law PageRank system."""
    g = power_law_graph(300, seed=3)
    p, b = pagerank_system(g, damping=0.85)
    x = np.linalg.solve(np.eye(g.n) - p.to_dense(), b)
    return p, b, x


@pytest.fixture(scope="session")
def skewed_pagerank():
    """Out-degree-ordered 1000-node system (paper Table 2 protocol)."""
    g = power_law_graph(1000, seed=0)
    order = np.argsort(-g.out_degree(), kind="stable")
    g = g.reorder(order)
    p, b = pagerank_system(g, damping=0.85)
    x = np.linalg.solve(np.eye(g.n) - p.to_dense(), b)
    return p, b, x
