import numpy as np
import pytest

from repro.core import CSRGraph, pagerank_system, power_law_graph


def pytest_addoption(parser):
    parser.addoption(
        "--repro-seed", action="store", type=int, default=0,
        help="base RNG seed for seeded_rng-consuming tests "
        "(chaos/property suites) — replay a failure log by passing the "
        "seed it printed",
    )


@pytest.fixture(scope="session")
def repro_seed(request) -> int:
    """The --repro-seed value: fold into any test-local derived seeds."""
    return int(request.config.getoption("--repro-seed"))


@pytest.fixture
def seeded_rng(repro_seed) -> np.random.Generator:
    """THE generator randomized tests draw from.  Centralized so every
    chaos/property run is replayable: `pytest --repro-seed=N` reproduces
    the exact graphs, deltas, and chaos plans of a logged failure."""
    return np.random.default_rng(repro_seed)


@pytest.fixture(scope="session")
def small_pagerank():
    """(P, b, x_dense) for a 300-node power-law PageRank system."""
    g = power_law_graph(300, seed=3)
    p, b = pagerank_system(g, damping=0.85)
    x = np.linalg.solve(np.eye(g.n) - p.to_dense(), b)
    return p, b, x


@pytest.fixture(scope="session")
def skewed_pagerank():
    """Out-degree-ordered 1000-node system (paper Table 2 protocol)."""
    g = power_law_graph(1000, seed=0)
    order = np.argsort(-g.out_degree(), kind="stable")
    g = g.reorder(order)
    p, b = pagerank_system(g, damping=0.85)
    x = np.linalg.solve(np.eye(g.n) - p.to_dense(), b)
    return p, b, x
