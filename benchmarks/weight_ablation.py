"""Ablation the paper names but does not evaluate (§2.2.1): the node
selection weight w_i ∈ {1 (greedy), 1/#out (default), 1/(#out·#in)}.

Runs the K=1 and K=8-dynamic costs for each weight mode on the synthetic
α=1.5 graph and the web-graph stand-in. Appends a CSV to results/paper/.
"""
from __future__ import annotations

import csv
import os

from repro.core import (
    DistributedSimulator,
    SimulatorConfig,
    pagerank_system,
    power_law_graph,
    webgraph_like,
)

OUT = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "results", "paper",
                 "weight_ablation.csv"))


def run(verbose=True):
    rows = []
    for gname, g in (
        ("powerlaw1k", power_law_graph(1000, seed=0)),
        ("web10k", webgraph_like(10_000, seed=1)),
    ):
        p, b = pagerank_system(g)
        for mode in ("greedy", "inv_out", "inv_out_in"):
            for k, dyn in ((1, False), (8, True)):
                cfg = SimulatorConfig(
                    k=k, target_error=1.0 / g.n, eps=0.15, dynamic=dyn,
                    weight_mode=mode, mode="batch", record_every=100,
                )
                res = DistributedSimulator(p, b, cfg).run()
                rows.append([gname, mode, k, int(dyn),
                             f"{res.cost_iterations:.3f}",
                             int(res.converged)])
                if verbose:
                    print(f"  {gname} w={mode:<11} K={k} "
                          f"{'dyn' if dyn else 'sta'}: "
                          f"cost={res.cost_iterations:.2f}")
    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    with open(OUT, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["graph", "weight_mode", "K", "dynamic", "cost",
                    "converged"])
        w.writerows(rows)
    return rows


if __name__ == "__main__":
    run()
