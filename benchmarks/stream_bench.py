"""Streaming soak bench: a supervised serving session under chaos.

Drives a timestamped evolving-web trace — drifting personalization
vectors, continuous link churn from a simulated crawler, poison
requests — through a :class:`repro.resilience.SupervisedSession` while
a seeded chaos schedule kills devices mid-request, rescales the pid
axis, and opens a straggler window, then replays the *effective*
schedule (the requests, update-apply points, and rescales that
actually executed) through an undisturbed twin on a separate GraphStore
replica.  Determinism is the exactness oracle: every served solution
must match the twin **exactly** at matching trace points (DESIGN.md
§10) — recovery replays the identical trajectory, so |Δx|₁ = 0.

Scenarios:

* ``soak``               — the headline stream: kills + rescales +
                           churn + straggler + poison, zero dropped
                           non-poison requests, exact agreement
* ``frontier:defer-*``   — staleness-vs-cost frontier: the same
                           overloaded stream at increasing defer
                           budgets (graph-update deferral is the
                           *exact* rung: dx stays 0, staleness grows)
* ``rung:*``             — accuracy cost of the lossy ladder rungs
                           (loosen-target, shed-occupancy, survival)
                           against an exact nominal reference

  PYTHONPATH=src python -m benchmarks.stream_bench            # full
  PYTHONPATH=src python -m benchmarks.stream_bench --smoke    # tiny CI

Emits ``BENCH_stream.json`` (schema-guarded by ``python -m
benchmarks.run --smoke``, counters folded into the perf gate).
"""
from __future__ import annotations

import dataclasses
import json
import os
import sys
import tempfile
from typing import Dict, List, Optional, Tuple

# fake 8 host devices for the engine's pid axis (standalone runs only;
# under benchmarks.run the real device count rules)
if "jax" not in sys.modules:
    _flags = os.environ.get("XLA_FLAGS", "")
    if "host_platform_device_count" not in _flags:
        os.environ["XLA_FLAGS"] = (
            _flags + " --xla_force_host_platform_device_count=8").strip()

import numpy as np


@dataclasses.dataclass
class StreamSpec:
    """One deterministic stream scenario (trace + chaos + config)."""

    n: int = 4096
    k: int = 8
    method: str = "engine:chunk"
    requests: int = 500
    drift: float = 0.003
    churn_every: int = 10           # every i-th request is a graph update
    churn_rot: int = 8              # link rotations per update
    poison_every: int = 0           # every i-th rank request is poison
    stale_update_at: Optional[int] = None  # inject one stale-version update
    kill_at: Tuple[int, ...] = ()   # request indices killed mid-solve
    rescale_at: Dict[int, int] = dataclasses.field(default_factory=dict)
    straggler: Optional[Tuple[int, int, float]] = None  # (start, end, slow)
    queue_burst: int = 6            # queue depth during the straggler window
    defer_cap: int = 64
    deadline_s: Optional[float] = 0.05
    op_rate: float = 2e6
    target_error: Optional[float] = None
    chunk_rounds: int = 32
    seed: int = 0
    sample_every: int = 10          # |dx| checked at every i-th request
    rungs: Optional[tuple] = None   # None = (nominal, defer-updates)
    pressure_hi: float = 1.0
    pressure_lo: float = 0.5


def build_problem(n: int, seed: int = 1, target_error=None):
    import repro
    from repro.core import webgraph_like
    from repro.graph import GraphStore

    store = GraphStore.from_csr(webgraph_like(n, seed=seed))
    return repro.Problem.pagerank(store, target_error=target_error)


def make_trace(spec: StreamSpec, problem) -> List[dict]:
    """The request stream, fully materialized up front so the soak and
    the reference replay consume bit-identical payloads."""
    rng = np.random.default_rng(spec.seed)
    b = np.asarray(problem.b, dtype=np.float64)
    trace: List[dict] = []
    for i in range(spec.requests):
        if (spec.churn_every and i % spec.churn_every
                == spec.churn_every - 1):
            trace.append({"kind": "update", "seed": 5000 + i,
                          "stale": i == spec.stale_update_at})
            continue
        b = np.abs(b * (1.0 + spec.drift * rng.standard_normal(problem.n)))
        poison = bool(spec.poison_every and i % spec.poison_every
                      == spec.poison_every - 1)
        entry = {"kind": "rank", "b": b, "poison": poison}
        if poison:
            bad = b.copy()
            bad[int(rng.integers(problem.n))] = np.nan
            entry["b_poison"] = bad
        trace.append(entry)
    return trace


def run_stream(spec: StreamSpec, ckpt_dir: str) -> dict:
    """Drive the supervised soak; returns outcomes + the effective log
    + sampled solutions (by trace index)."""
    import repro
    from repro.chaos import ChaosPlan, SessionInjector
    from repro.graph import rotation_churn
    from repro.resilience import (DegradationLadder, RetryPolicy, Rung,
                                  SupervisedSession)
    from repro.balance import PressurePolicy

    problem = build_problem(spec.n, target_error=spec.target_error)
    trace = make_trace(spec, problem)
    is_engine = spec.method.startswith("engine")
    options = repro.SolverOptions(
        k=spec.k if is_engine else None,
        chunk_rounds=spec.chunk_rounds if is_engine else 4)
    rungs = spec.rungs if spec.rungs is not None else (
        Rung("nominal"), Rung("defer-updates", defer_updates=True))
    ladder = DegradationLadder(
        rungs=rungs,
        policy=PressurePolicy(eta=0.6, z=3, hi=spec.pressure_hi,
                              lo=spec.pressure_lo, patience=2))
    sup = SupervisedSession(
        problem, method=spec.method, options=options, ckpt_dir=ckpt_dir,
        ladder=ladder, deadline_s=spec.deadline_s, op_rate=spec.op_rate,
        defer_cap=spec.defer_cap, sleep=lambda s: None,
        retry=RetryPolicy(base_delay_s=0.005, max_delay_s=0.02, seed=0))
    # the "crawler": owns its own replica and applies every delta it
    # emits immediately, so queued deltas compose in emission order no
    # matter how long the ladder defers them
    crawler = build_problem(spec.n).graph
    crawler_v0 = crawler.version
    deltas_by_seed: Dict[int, object] = {}
    emitted = 0

    effective: List[tuple] = []
    pending: List[int] = []         # churn seeds awaiting apply events
    samples: Dict[int, np.ndarray] = {}
    outcomes = []
    staleness: List[int] = []       # queued updates at each serve point
    ev_cursor = 0
    for i, req in enumerate(trace):
        if i in spec.rescale_at:
            sup.rescale(spec.rescale_at[i])
        if spec.straggler is not None:
            start, end, slow = spec.straggler
            if i == start:
                sup.note_straggler(min(1, spec.k - 1), slow)
            if i == end:
                sup.note_straggler(min(1, spec.k - 1), 1.0)
        in_burst = (spec.straggler is not None
                    and spec.straggler[0] <= i < spec.straggler[1])
        if req["kind"] == "rank":
            if req["poison"]:
                out = sup.serve_rank(req["b_poison"], request_id=i,
                                     want_x=False)
                outcomes.append(out)
                ev_cursor = len(sup.log)
                continue
            chaos = None
            if i in spec.kill_at:
                # target the last pid of the CURRENT width (rescales may
                # have shrunk the session since the trace was authored)
                k_now = getattr(getattr(sup.session, "_driver", None),
                                "cfg", None)
                k_now = getattr(k_now, "k", 1)
                chaos = SessionInjector(ChaosPlan(seed=i).kill(
                    pid=max(k_now - 1, 0), round=2))
            want = (i % spec.sample_every == 0)
            out = sup.serve_rank(
                req["b"], request_id=i, chaos=chaos,
                queue_depth=spec.queue_burst if in_burst else 0,
                want_x=want)
            if out.ok and want:
                samples[i] = out.x
        else:
            delta = rotation_churn(crawler, spec.churn_rot,
                                   seed=req["seed"])
            if req["stale"]:
                # wrong version pin: rejected at admission, so the
                # crawler must NOT count it either — both sides agree
                # the delta never happened
                sv = 0
            else:
                sv = crawler_v0 + emitted  # version this delta targets
                crawler.apply_delta(delta)
                emitted += 1
                deltas_by_seed[req["seed"]] = delta
                pending.append(req["seed"])
            out = sup.serve_update(delta, store_version=sv, request_id=i)
            if out.rejected and not req["stale"] and pending:
                pending.pop()       # never reached the queue after all
        outcomes.append(out)
        staleness.append(sup.deferred_updates)
        # fold the supervisor's new events into the effective schedule
        for ev in list(sup.log)[ev_cursor:]:
            if ev.kind == "request_served":
                effective.append(("rank", ev.detail["request_id"]))
            elif ev.kind == "update_applied":
                effective.append(("update", pending.pop(0)))
            elif ev.kind == "update_conflict":
                pending.pop(0)      # quarantined at apply: not effective
            elif ev.kind == "rescale":
                effective.append(("rescale", ev.detail["k_new"]))
        ev_cursor = len(sup.log)
    # drain anything still deferred so the stream ends caught-up
    sup.flush_deferred(reason="end-of-stream")
    for ev in list(sup.log)[ev_cursor:]:
        if ev.kind == "update_applied":
            effective.append(("update", pending.pop(0)))
        elif ev.kind == "update_conflict":
            pending.pop(0)
    return {
        "sup": sup, "trace": trace, "effective": effective,
        "samples": samples, "outcomes": outcomes,
        "deltas_by_seed": deltas_by_seed, "staleness": staleness,
    }


def replay_reference(spec: StreamSpec, run: dict) -> dict:
    """Undisturbed twin on a fresh GraphStore replica: replays the
    soak's effective schedule (requests, update applies, rescales) with
    no chaos, no ladder, no retries — at the NOMINAL target, so lossy
    rungs show up as measured error rather than vanishing into a
    matching degraded reference."""
    import repro

    problem = build_problem(spec.n, target_error=spec.target_error)
    is_engine = spec.method.startswith("engine")
    options = repro.SolverOptions(
        k=spec.k if is_engine else None,
        chunk_rounds=spec.chunk_rounds if is_engine else 4)
    ref = repro.SolverSession(problem, method=spec.method,
                              options=options)
    b_by_index = {i: e["b"] for i, e in enumerate(run["trace"])
                  if e["kind"] == "rank" and not e["poison"]}
    ref_samples: Dict[int, np.ndarray] = {}
    total_ops = 0
    for entry in run["effective"]:
        kind = entry[0]
        if kind == "rank":
            i = entry[1]
            ref.warm_start(b_by_index[i])
            rep = ref.solve()
            total_ops += rep.n_ops
            if i in run["samples"]:
                ref_samples[i] = rep.x
        elif kind == "update":
            # regenerate from the replica store: identical churn seeds
            # on identical store content produce identical deltas
            delta = run["deltas_by_seed"][entry[1]]
            ref.update_graph(delta)
            rep = ref.solve()
            total_ops += rep.n_ops
        elif kind == "rescale":
            ref.rescale(entry[1])
    return {"ref": ref, "samples": ref_samples,
            "undisturbed_ops": ref.lifetime_ops}


def _percentile(values: List[float], q: float) -> float:
    return float(np.percentile(np.asarray(values), q)) if values else 0.0


def stream_row(scenario: str, spec: StreamSpec, run: dict,
               ref: dict) -> dict:
    sup = run["sup"]
    outs = run["outcomes"]
    ranks = [o for o in outs if o.kind == "rank" and not o.rejected]
    served = [o for o in ranks if o.ok]
    dropped = [o for o in ranks if not o.ok]
    rejected = [o for o in outs if o.rejected]
    dxs = {i: float(np.abs(run["samples"][i] - ref["samples"][i]).sum())
           for i in run["samples"] if i in ref["samples"]}
    lat = [o.latency_s for o in served]
    kill_lat = [o.latency_s for o in served if o.restores > 0]
    counts = sup.log.counts()
    stale = run["staleness"]
    return {
        "scenario": scenario,
        "method": spec.method,
        "n": spec.n,
        "k": spec.k,
        "requests": spec.requests,
        "served": len(served),
        "dropped": len(dropped),
        "rejected": len(rejected),
        "applied_updates": counts.get("update_applied", 0),
        "deferred_peak": max(stale) if stale else 0,
        "mean_staleness": round(float(np.mean(stale)), 3) if stale else 0.0,
        "total_ops": int(sup.total_ops),
        "undisturbed_ops": int(ref["undisturbed_ops"]),
        "wasted_ops": int(sup.wasted_ops),
        "max_dx_l1": max(dxs.values()) if dxs else float("nan"),
        "checked_points": len(dxs),
        "p50_latency_s": round(_percentile(lat, 50), 6),
        "p95_latency_s": round(_percentile(lat, 95), 6),
        "recovery_p50_s": round(_percentile(kill_lat, 50), 6),
        "recovery_p95_s": round(_percentile(kill_lat, 95), 6),
        "degraded_frac": round(
            sum(1 for o in served if o.degraded) / max(len(served), 1), 4),
        "kills": counts.get("fault", 0),
        "restores": sup.restores,
        "rescales": counts.get("rescale", 0),
        "degrades": counts.get("degrade", 0),
        "recovers": counts.get("recover", 0),
        "converged": bool(all(o.converged for o in served)),
    }


def soak_cell(spec: StreamSpec, scenario: str = "soak") -> dict:
    with tempfile.TemporaryDirectory() as ckpt:
        run = run_stream(spec, ckpt)
    ref = replay_reference(spec, run)
    return stream_row(scenario, spec, run, ref)


def frontier_cells(n: int, requests: int, defer_caps=(1, 4, 16)) -> list:
    """Staleness-vs-cost frontier: identical overloaded stream, defer
    budget swept.  Deferral is the exact rung — the frontier trades
    peak/mean staleness against ops concentrated in the overload
    window, never accuracy."""
    rows = []
    for cap in defer_caps:
        spec = StreamSpec(
            n=n, k=4, requests=requests, churn_every=4,
            straggler=(requests // 4, 3 * requests // 4, 8.0),
            queue_burst=8, defer_cap=cap, deadline_s=0.02,
            sample_every=5, seed=1)
        rows.append(soak_cell(spec, scenario=f"frontier:defer-{cap}"))
    return rows


def rung_cells(n: int, requests: int) -> list:
    """Accuracy cost of the lossy rungs, measured against an exact
    nominal reference (the bounded/best-effort rows of DESIGN.md §10)."""
    from repro.resilience import Rung

    cells = [
        ("rung:loosen-target", "engine:chunk",
         Rung("loosen-target", target_scale=8.0)),
        ("rung:shed-occupancy", "frontier:pallas",
         Rung("shed-occupancy", occupancy_threshold=0.25)),
        ("rung:survival", "engine:chunk",
         Rung("survival", target_scale=32.0, round_cap=8)),
    ]
    rows = []
    for scenario, method, rung in cells:
        spec = StreamSpec(
            n=n, k=4, method=method, requests=requests, churn_every=6,
            deadline_s=None, sample_every=4, seed=2,
            rungs=(rung,))        # pinned: the rung is always active
        rows.append(soak_cell(spec, scenario=scenario))
    return rows


def main(smoke: bool = False, out_path: str = "BENCH_stream.json") -> dict:
    import jax

    n_dev = len(jax.devices())
    rows = []
    if smoke:
        soak = StreamSpec(
            n=1024, k=min(4, n_dev), requests=100, churn_every=8,
            poison_every=25, stale_update_at=55, kill_at=(22,),
            rescale_at={60: max(min(4, n_dev) - 1, 1)},
            straggler=(35, 50, 6.0), sample_every=8, seed=0)
        rows.append(soak_cell(soak, scenario="soak"))
        rows.extend(frontier_cells(512, requests=24, defer_caps=(1, 8)))
    else:
        k = min(8, n_dev)
        soak = StreamSpec(
            n=4096, k=k, requests=500, churn_every=10, poison_every=37,
            stale_update_at=209, kill_at=(48, 260),
            rescale_at={150: max(k - 2, 1), 330: k},
            straggler=(380, 430, 6.0), sample_every=10, seed=0)
        rows.append(soak_cell(soak, scenario="soak"))
        rows.extend(frontier_cells(1024, requests=48))
        rows.extend(rung_cells(1024, requests=24))
    for r in rows:
        print(f"  {r['scenario']:24s} served={r['served']}/{r['requests']} "
              f"dropped={r['dropped']} rejected={r['rejected']} "
              f"|dx|max={r['max_dx_l1']:.2e} "
              f"stale(mean/peak)={r['mean_staleness']}/{r['deferred_peak']} "
              f"p95={r['p95_latency_s']*1e3:.1f}ms "
              f"degraded={r['degraded_frac']:.0%}")
    from benchmarks._meta import std_meta

    payload = {
        "meta": std_meta("stream_soak", graph="webgraph_like",
                         n_devices=n_dev),
        "rows": rows,
    }
    with open(out_path, "w") as fh:
        json.dump(payload, fh, indent=1)
    print(f"[stream bench] wrote {out_path} ({len(rows)} rows)")
    return payload


if __name__ == "__main__":
    _out = "BENCH_stream.json"
    if "--out" in sys.argv:
        _out = sys.argv[sys.argv.index("--out") + 1]
    _payload = main(smoke="--smoke" in sys.argv, out_path=_out)
    _rows = _payload["rows"]
    _soak = [r for r in _rows if r["scenario"] == "soak"]
    _exact = _soak + [r for r in _rows
                      if r["scenario"].startswith("frontier:")]
    _ok = (
        bool(_soak)
        and all(r["dropped"] == 0 for r in _rows)
        # exact scenarios: determinism must hold to the bit
        and all(r["max_dx_l1"] <= 1e-6 for r in _exact)
        and all(r["converged"] for r in _exact)
    )
    sys.exit(0 if _ok else 1)
