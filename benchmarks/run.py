"""Benchmark suite entry: ``python -m benchmarks.run [--quick|--full|--smoke]``.

One section per paper table/figure + kernel microbench + roofline summary.
Asserts the paper's qualitative claims (C1–C4, DESIGN.md §1) on the
regenerated data and prints CSV-ish lines throughout.

``--smoke`` is the CI guard for the perf-trajectory artifacts: it runs a
tiny frontier sweep + engine bench end-to-end, validates the JSON schema
they emit, and validates any committed ``BENCH_*.json`` against the same
schema — so a schema break is caught before it lands.

``--consolidate`` (also run at the end of ``--smoke``) folds the
per-suite artifacts (``BENCH_kernels.json`` / ``BENCH_engine.json`` /
``BENCH_api.json`` / ``BENCH_graph.json``) into ONE schema-guarded
``BENCH.json`` trajectory, so perf history is machine-readable in one
place: ``{"meta": ..., "sections": {name: {meta, rows}}}`` — and then
runs the perf-regression gate (benchmarks/perf_gate.py) against the
committed baseline when one is present, so a regressed artifact cannot
land silently.
"""
from __future__ import annotations

import json
import os
import sys
import time

from benchmarks._meta import META_KEYS, std_meta

KERNEL_ROW_KEYS = {
    "n", "c", "density", "n_edges", "n_blocks", "n_blocks_active",
    "segment_sum_us", "bsr_full_us", "pallas_skip_us",
    "speedup_vs_segment_sum", "buffer_depth", "roofline_fraction",
    "dma_compute_ratio",
}
ENGINE_ROW_KEYS = {
    "n", "k", "backend", "n_edges", "bucket_size", "chunk_ms", "rounds",
    "us_per_round", "residual_after",
}
API_ROW_KEYS = {
    "method", "resolved", "n", "n_edges", "wall_s", "n_ops",
    "cost_iterations", "residual", "converged",
}
GRAPH_ROW_KEYS = {
    "n", "method", "n_edges", "churn_frac", "changed_edges", "f0_resid",
    "warm_ops", "cold_ops", "ops_ratio", "patch_s", "rebuild_s",
    "patch_speedup", "converged",
}
CHAOS_ROW_KEYS = {
    "scenario", "method", "n", "k", "n_edges", "undisturbed_ops",
    "disturbed_ops", "overhead_ops", "overhead_frac", "x_err_l1",
    "converged",
}
STREAM_ROW_KEYS = {
    "scenario", "method", "n", "k", "requests", "served", "dropped",
    "rejected", "applied_updates", "deferred_peak", "mean_staleness",
    "total_ops", "undisturbed_ops", "wasted_ops", "max_dx_l1",
    "checked_points", "p50_latency_s", "p95_latency_s",
    "recovery_p50_s", "recovery_p95_s", "degraded_frac", "kills",
    "restores", "rescales", "converged",
}

SERVE_ROW_KEYS = {
    "scenario", "n", "requests", "max_lanes", "clusters", "served",
    "dropped", "rejected", "qps", "seq_qps", "seq_sample",
    "speedup_vs_sequential", "p50_latency_s", "p99_latency_s",
    "pool_hit_rate", "pool_miss_rate", "mean_occupancy",
    "padding_waste", "bucket", "bit_parity", "max_dx_l1_seq",
    "max_dx_l1_ref", "dx_bound", "total_ops", "degrades",
    "applied_updates", "degraded_frac", "converged",
}

# one registry drives per-suite validation AND the BENCH.json merge
BENCH_SECTIONS = {
    "kernels": ("BENCH_kernels.json", KERNEL_ROW_KEYS),
    "engine": ("BENCH_engine.json", ENGINE_ROW_KEYS),
    "api": ("BENCH_api.json", API_ROW_KEYS),
    "graph": ("BENCH_graph.json", GRAPH_ROW_KEYS),
    "chaos": ("BENCH_chaos.json", CHAOS_ROW_KEYS),
    "stream": ("BENCH_stream.json", STREAM_ROW_KEYS),
    "serve": ("BENCH_serve.json", SERVE_ROW_KEYS),
}


def _validate_bench(payload: dict, required: set, name: str) -> None:
    meta = payload.get("meta")
    assert isinstance(meta, dict), f"{name}: missing meta"
    meta_missing = META_KEYS - meta.keys()
    assert not meta_missing, (
        f"{name}: meta missing normalized keys {sorted(meta_missing)} "
        "(emit it via benchmarks._meta.std_meta)")
    rows = payload.get("rows")
    assert isinstance(rows, list) and rows, f"{name}: missing rows"
    real = [r for r in rows if "skipped" not in r]
    assert real, f"{name}: every row skipped"
    for r in real:
        missing = required - r.keys()
        assert not missing, f"{name}: row missing keys {sorted(missing)}"
    print(f"  {name}: {len(real)} measured rows, schema OK")


def consolidate(out_path: str = "BENCH.json") -> dict:
    """Merge the per-suite BENCH_*.json into one validated trajectory."""
    sections = {}
    for name, (path, keys) in BENCH_SECTIONS.items():
        if not os.path.exists(path):
            print(f"  {name}: {path} not present, section omitted")
            continue
        with open(path) as fh:
            payload = json.load(fh)
        _validate_bench(payload, keys, path)
        sections[name] = payload
    payload = {
        "meta": std_meta(
            "consolidated_perf_trajectory",
            sections_present=sorted(sections),
            section_files={n: BENCH_SECTIONS[n][0] for n in sections},
        ),
        "sections": sections,
    }
    if sections:
        with open(out_path, "w") as fh:
            json.dump(payload, fh, indent=1)
        print(f"  wrote {out_path} ({len(sections)} sections)")
    return payload


def _validate_consolidated(path: str = "BENCH.json") -> None:
    if not os.path.exists(path):
        print(f"  {path} not present (perf trajectory not seeded yet)")
        return
    with open(path) as fh:
        payload = json.load(fh)
    assert isinstance(payload.get("meta"), dict), f"{path}: missing meta"
    sections = payload.get("sections")
    assert isinstance(sections, dict) and sections, (
        f"{path}: missing sections")
    for name, sec in sections.items():
        assert name in BENCH_SECTIONS, f"{path}: unknown section {name!r}"
        _validate_bench(sec, BENCH_SECTIONS[name][1], f"{path}:{name}")


def smoke() -> int:
    """Fast end-to-end bench smoke + BENCH_*.json schema validation."""
    from benchmarks import api_bench, engine_bench, graph_bench, kernel_bench

    print("[smoke] frontier kernel sweep (tiny)")
    kp = kernel_bench.frontier_sweep(
        ns=(2**12,), cs=(1, 2), densities=(1.0, 0.5), iters=1,
        out_path="BENCH_kernels.smoke.json")
    _validate_bench(kp, KERNEL_ROW_KEYS, "kernel sweep (smoke)")
    print("[smoke] engine bench (tiny)")
    ep = engine_bench.main(smoke=True, out_path="BENCH_engine.smoke.json")
    _validate_bench(ep, ENGINE_ROW_KEYS, "engine bench (smoke)")
    print("[smoke] api auto-dispatch bench (tiny)")
    ap = api_bench.main(smoke=True, out_path="BENCH_api.smoke.json")
    _validate_bench(ap, API_ROW_KEYS, "api bench (smoke)")
    auto_rows = [r for r in ap["rows"]
                 if r.get("method") == "auto" and "skipped" not in r]
    assert auto_rows and auto_rows[0]["resolved"] != "auto", (
        "auto dispatch did not resolve to a concrete backend")
    print("[smoke] graph delta-vs-cold bench (tiny)")
    gp = graph_bench.main(smoke=True, out_path="BENCH_graph.smoke.json")
    _validate_bench(gp, GRAPH_ROW_KEYS, "graph bench (smoke)")
    warm_rows = [r for r in gp["rows"] if "skipped" not in r]
    assert warm_rows and all(r["ops_ratio"] > 1.0 for r in warm_rows), (
        "delta re-solve did not beat the cold solve")
    print("[smoke] chaos recovery-overhead bench (tiny)")
    from benchmarks import chaos_bench

    cp = chaos_bench.main(smoke=True, out_path="BENCH_chaos.smoke.json")
    _validate_bench(cp, CHAOS_ROW_KEYS, "chaos bench (smoke)")
    chaos_rows = [r for r in cp["rows"] if "skipped" not in r]
    assert chaos_rows and all(r["converged"] for r in chaos_rows), (
        "a chaos scenario failed to converge after recovery")
    print("[smoke] stream soak bench (shortened, seeded chaos)")
    from benchmarks import stream_bench

    sp = stream_bench.main(smoke=True, out_path="BENCH_stream.smoke.json")
    _validate_bench(sp, STREAM_ROW_KEYS, "stream bench (smoke)")
    soak = [r for r in sp["rows"] if r["scenario"] == "soak"]
    assert soak, "stream smoke emitted no soak row"
    s = soak[0]
    assert s["requests"] >= 100, s  # shortened soak still streams >=100
    assert s["kills"] >= 1 and s["restores"] >= 1, s
    assert s["rescales"] >= 1, s
    assert s["applied_updates"] >= 1, s  # continuous churn reached apply
    assert s["dropped"] == 0, "supervised stream dropped a request"
    assert s["max_dx_l1"] <= 1e-6, (
        "served solutions diverged from the effective-schedule replay")
    print("[smoke] continuous-batching serve bench (tiny)")
    from benchmarks import serve_bench

    vp = serve_bench.main(smoke=True, out_path="BENCH_serve.smoke.json")
    _validate_bench(vp, SERVE_ROW_KEYS, "serve bench (smoke)")
    head = [r for r in vp["rows"] if r["scenario"] == "serving"]
    over = [r for r in vp["rows"] if r["scenario"] == "overload"]
    assert head and over, "serve smoke missing a scenario"
    assert all(r["dropped"] == 0 for r in vp["rows"]), (
        "continuous batching dropped a request")
    assert head[0]["served"] == head[0]["requests"], head[0]
    assert head[0]["speedup_vs_sequential"] > 1.0, (
        "continuous batching did not beat the sequential path")
    assert head[0]["max_dx_l1_seq"] <= head[0]["dx_bound"], (
        "batched solutions diverged from the sequential twin")
    assert all(r["bit_parity"] for r in vp["rows"]), (
        "pow2 lane padding changed the solution bits")
    assert over[0]["degrades"] >= 1, (
        "overload cell never engaged the pressure ladder")
    for tmp in ("BENCH_kernels.smoke.json", "BENCH_engine.smoke.json",
                "BENCH_serve.smoke.json",
                "BENCH_api.smoke.json", "BENCH_graph.smoke.json",
                "BENCH_chaos.smoke.json", "BENCH_stream.smoke.json"):
        if os.path.exists(tmp):
            os.remove(tmp)
    # consolidate() validates each committed per-suite artifact as it
    # merges them, then the merged BENCH.json is re-checked on disk
    print("[smoke] committed artifacts -> consolidated trajectory")
    consolidate()
    _validate_consolidated()
    print("[smoke] OK")
    return 0


def main():
    quick = "--quick" in sys.argv
    full = "--full" in sys.argv
    if "--smoke" in sys.argv:
        return smoke()
    if "--consolidate" in sys.argv:
        consolidate()
        _validate_consolidated()
        # perf-regression gate: compare the consolidated trajectory
        # against the committed baseline (skipped until one is seeded)
        from benchmarks import perf_gate

        if os.path.exists(perf_gate.BASELINE_PATH):
            return perf_gate.main(["--check"])
        print(f"  {perf_gate.BASELINE_PATH} not present — gate skipped "
              "(seed it with python -m benchmarks.perf_gate "
              "--update-baseline)")
        return 0
    t0 = time.time()
    print("=" * 70)
    print("D-iteration dynamic-partition benchmark suite")
    print("=" * 70)

    # ---------------- Tables 1–3 ----------------
    from benchmarks import paper_tables

    tables = paper_tables.main(quick=quick)
    t1, t2, t3 = tables["table1"], tables["table2"], tables["table3"]

    def chk(name, cond, detail=""):
        print(f"  CLAIM {name}: {'PASS' if cond else 'FAIL'} {detail}")
        return cond

    print("\n[claims vs paper]")
    ok = True
    # C4: K=1 cost is a few normalized iterations at target 1/N
    ok &= chk("C4 K=1 cost O(1) matvecs", t1[(1, 'uniform', False)] < 15,
              f"cost={t1[(1, 'uniform', False)]:.2f}")
    if not quick:
        # C2: dynamic rescues skewed orderings at K=16 (Tables 2/3 pattern)
        ok &= chk(
            "C2 dynamic beats static on out-degree order (K=16, unif)",
            t2[(16, 'uniform', True)] < t2[(16, 'uniform', False)],
            f"{t2[(16, 'uniform', True)]:.2f} < "
            f"{t2[(16, 'uniform', False)]:.2f}")
        ok &= chk(
            "C2 dynamic beats static on in-degree order (K=16, cb)",
            t3[(16, 'cb', True)] < t3[(16, 'cb', False)],
            f"{t3[(16, 'cb', True)]:.2f} < {t3[(16, 'cb', False)]:.2f}")
        # parallel speedup exists (C3 direction)
        ok &= chk("C3 K=16 cheaper than K=1 (random order)",
                  t1[(16, 'uniform', False)] < t1[(1, 'uniform', False)],
                  f"{t1[(16, 'uniform', False)]:.2f} < "
                  f"{t1[(1, 'uniform', False)]:.2f}")

    # ---------------- Figures 1–4, 15–18 ----------------
    from benchmarks import fig_convergence

    fig_convergence.main(quick=quick)

    # ---------------- Figures 5/6 ----------------
    from benchmarks import webgraph_speedup

    rows = webgraph_speedup.run(
        ns=(1000,) if quick else ((1000, 10000, 100000) if full
                                  else (1000, 10000)),
        ks=(1, 2, 4) if quick else (1, 2, 4, 8, 16, 32, 64),
    )
    if not quick:
        # C1: with exchange cost charged, parallel EFFICIENCY collapses for
        # large K at small N ("the gain is limited ... when N/K becomes too
        # small"): static-uniform efficiency at K=max is under half of the
        # K=4 efficiency (the curve is also non-monotone, see fig5_6.csv).
        n1 = [r for r in rows if r[0] == 1000 and r[2] == "uniform"
              and r[3] == 0]
        speeds = {r[1]: float(r[5]) for r in n1}
        best = max(speeds.values())
        k_max = max(speeds)
        eff_max = speeds[k_max] / k_max
        eff_4 = speeds.get(4, speeds[min(speeds)]) / 4
        ok &= chk("C1 efficiency collapses at small N/K (static)",
                  eff_max < 0.5 * eff_4,
                  f"eff(K={k_max})={eff_max:.2f} vs eff(K=4)={eff_4:.2f}")
        # C3: larger N sustains speedup to larger K
        n2 = [r for r in rows if r[0] == 10000 and r[2] == "uniform"
              and r[3] == 1]
        if n2:
            sp2 = {r[1]: float(r[5]) for r in n2}
            ok &= chk("C3 larger N, larger useful K (dyn)",
                      max(sp2.values()) >= best * 0.9,
                      f"N=10k best={max(sp2.values()):.2f} vs "
                      f"N=1k best={best:.2f}")

    # ---------------- kernel microbench ----------------
    print("\n[kernel microbench]")
    from benchmarks import kernel_bench

    kernel_bench.main()

    # ---------------- roofline summary ----------------
    print("\n[roofline (from BENCH_kernels.json, if present)]")
    from benchmarks import roofline

    try:
        rows_r = roofline.build_table()
        if rows_r:
            bounds = {}
            for r in rows_r:
                bounds[r["bound"]] = bounds.get(r["bound"], 0) + 1
            print(f"  {len(rows_r)} rows analysed; binding wall: {bounds}")
            worst = sorted(rows_r,
                           key=lambda r: r["roofline_fraction"])[:5]
            for r in worst:
                print(f"  worst-frac: n={r['n']} c={r['c']} "
                      f"density={r['density']} depth={r['buffer_depth']} "
                      f"frac={r['roofline_fraction']:.4f} "
                      f"bound={r['bound']} "
                      f"dma/compute={r['dma_compute_ratio']:.2f}")
        else:
            print("  (no BENCH_kernels.json — run "
                  "python -m benchmarks.kernel_bench --sweep first)")
    except Exception as e:  # pragma: no cover
        print("  roofline summary unavailable:", e)

    print(f"\nsuite finished in {time.time()-t0:.0f}s; "
          f"claims {'ALL PASS' if ok else 'SOME FAILED'}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
