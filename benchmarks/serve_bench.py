"""Serving-tier bench: continuous batching vs the sequential path.

Drives the :class:`repro.serving.Scheduler` (queue -> lanes -> pool)
over a clustered, drifting personalization stream and measures the
numbers DESIGN.md §11 commits to:

* ``serving`` (headline) — N=4096, 64 concurrent personalization RHS
  across 8 drift clusters through 16 lanes; wall-clock QPS against a
  sampled sequential twin (:func:`repro.serving.solo_reference`, the
  pre-batching ``serve.py rank`` path), per-request |Δx|₁ parity
  against both the twin and a tighter-tolerance one-shot
  ``solve_batch`` reference, pool-hit rate, lane occupancy, virtual
  p50/p99 latency;
* ``overload`` — open-loop arrivals beyond capacity plus mid-stream
  churn: the pressure ladder must shed *quality* (loosened targets,
  round caps, deferred updates) while ``dropped`` stays exactly zero;
* ``bucket:cC`` — the pow2 lane-padding discipline: padded vs unpadded
  ``solve_batch`` must agree **bitwise** (zero-fill lanes are inert),
  with the padding waste it buys reported.

The headline cell runs under ``jax_enable_x64`` (full mode only, set
before any kernel traces) so the |Δx|₁ ≤ 1e-6 acceptance bound at
N=4096 is not eaten by f32 accumulation noise; smoke keeps the
default dtype and scales the bound to the served target instead
(two converged solves differ by ≤ 2x the target error).

  PYTHONPATH=src python -m benchmarks.serve_bench            # full
  PYTHONPATH=src python -m benchmarks.serve_bench --smoke    # tiny CI

Emits ``BENCH_serve.json`` (schema-guarded by ``python -m
benchmarks.run --smoke``, counters folded into the perf gate).
"""
from __future__ import annotations

import json
import sys
import time
from typing import Dict, List, Optional, Tuple

import numpy as np


def build_problem(n: int, seed: int = 1, target_error=None):
    import repro
    from repro.core import webgraph_like
    from repro.graph import GraphStore

    store = GraphStore.from_csr(webgraph_like(n, seed=seed))
    return repro.Problem.pagerank(store, target_error=target_error)


def make_requests(problem, requests: int, clusters: int,
                  drift: float = 0.02, seed: int = 0
                  ) -> List[Tuple[int, int, np.ndarray]]:
    """A clustered personalization stream: each cluster is a drifting
    chain around its own anchor RHS (the pool's reuse unit), requests
    round-robin the clusters.  Returns ``[(request_id, cluster, b)]``."""
    rng = np.random.default_rng(seed)
    base = np.asarray(problem.b, dtype=np.float64)
    anchors = [np.abs(base * (1.0 + 0.3 * rng.standard_normal(problem.n)))
               for _ in range(clusters)]
    out = []
    for i in range(requests):
        c = i % clusters
        b = np.abs(anchors[c] * (1.0 + drift
                                 * rng.standard_normal(problem.n)))
        anchors[c] = b
        out.append((i, c, b))
    return out


def _blank_row(scenario: str, n: int) -> Dict:
    """Every cell shares one schema; fields a cell does not measure
    stay at their null-ish defaults."""
    return {
        "scenario": scenario, "n": n, "requests": 0, "max_lanes": 0,
        "clusters": 0, "served": 0, "dropped": 0, "rejected": 0,
        "qps": 0.0, "seq_qps": 0.0, "seq_sample": 0,
        "speedup_vs_sequential": 0.0, "p50_latency_s": 0.0,
        "p99_latency_s": 0.0, "pool_hit_rate": 0.0,
        "pool_miss_rate": 0.0, "mean_occupancy": 0.0,
        "padding_waste": 0.0, "bucket": 0, "bit_parity": True,
        "max_dx_l1_seq": 0.0, "max_dx_l1_ref": 0.0, "dx_bound": 0.0,
        "total_ops": 0, "degrades": 0, "applied_updates": 0,
        "degraded_frac": 0.0, "converged": True,
    }


def serving_cell(n: int, requests: int, clusters: int, max_lanes: int,
                 seq_sample: int, target_error=None,
                 rounds_per_tick: int = 64, drift: float = 0.02,
                 seed: int = 0) -> Dict:
    """Headline: dense burst of concurrent RHS through the scheduler,
    sequential twin sampled for wall-clock QPS + direct parity, a
    tighter one-shot ``solve_batch`` checking parity for EVERY request."""
    from repro.api.session import SolverSession
    from repro.serving import Scheduler, solo_reference

    problem = build_problem(n, target_error=target_error)
    te = problem.target_error
    reqs = make_requests(problem, requests, clusters, drift=drift,
                         seed=seed)

    # headline measures throughput at NOMINAL quality: the deadline is
    # parked far away so the dense burst cannot trip the ladder (the
    # overload cell exercises that on purpose)
    sch = Scheduler(problem, max_lanes=max_lanes,
                    rounds_per_tick=rounds_per_tick,
                    pool_capacity=2 * clusters, queue_cap=requests,
                    deadline_s=1e9)
    t0 = time.perf_counter()
    for i, c, b in reqs:
        sch.submit(b, cluster=c, request_id=i, arrival_t=0.0)
    sch.run_until_idle()
    wall = time.perf_counter() - t0
    by_id = {r.request_id: r for r in sch.results}
    assert len(by_id) == requests, "a request went unserved"

    # sequential twin (the pre-batching serve.py path), sampled
    stride = max(1, requests // seq_sample)
    sample_ids = list(range(0, requests, stride))[:seq_sample]
    bs_sample = np.stack([reqs[i][2] for i in sample_ids], axis=1)
    xs_seq, _seq_ops, wall_seq = solo_reference(problem, bs_sample)
    dx_seq = max(float(np.abs(by_id[i].x - xs_seq[:, j]).sum())
                 for j, i in enumerate(sample_ids))

    # every request against a tighter-tolerance one-shot batch solve
    bs_all = np.stack([b for _, _, b in reqs], axis=1)
    ref = SolverSession(problem).solve_batch(bs_all, until=te / 8)
    dx_ref = max(float(np.abs(by_id[i].x - ref.x[:, i]).sum())
                 for i in range(requests))

    qps = requests / wall
    seq_qps = len(sample_ids) / wall_seq
    lat = sch.latency_percentiles()
    row = _blank_row("serving", n)
    row.update({
        "requests": requests, "max_lanes": sch.batcher.max_lanes,
        "clusters": clusters, "served": len(sch.results),
        "dropped": sch.dropped, "rejected": sch.quarantine.total,
        "qps": round(qps, 4), "seq_qps": round(seq_qps, 4),
        "seq_sample": len(sample_ids),
        "speedup_vs_sequential": round(qps / seq_qps, 3),
        "p50_latency_s": round(lat["p50"], 6),
        "p99_latency_s": round(lat["p99"], 6),
        "pool_hit_rate": round(sch.pool.hit_rate, 4),
        "pool_miss_rate": round(1.0 - sch.pool.hit_rate, 4),
        "mean_occupancy": round(sch.batcher.mean_occupancy, 4),
        "max_dx_l1_seq": dx_seq, "max_dx_l1_ref": dx_ref,
        # two solves converged to |F|1 <= te*eps differ by <= 2*te
        "dx_bound": 2.0 * te,
        "total_ops": int(sch.batcher.ops_total),
        "degrades": sch.log.counts().get("degrade", 0),
        "degraded_frac": round(
            sum(1 for r in sch.results if r.degraded)
            / max(len(sch.results), 1), 4),
        "converged": bool(all(r.converged for r in sch.results)),
    })
    return row


def overload_cell(n: int, requests: int, max_lanes: int,
                  update_at: Tuple[int, ...] = (8, 16),
                  arrival_dt: float = 0.002, seed: int = 3) -> Dict:
    """Open-loop arrivals beyond virtual service capacity plus
    mid-stream churn: the ladder must degrade (and serve every request
    anyway) — ``dropped`` is gated at exactly zero."""
    from repro.graph import rotation_churn
    from repro.serving import Scheduler

    problem = build_problem(n)
    reqs = make_requests(problem, requests, clusters=4, seed=seed)
    sch = Scheduler(problem, max_lanes=max_lanes, rounds_per_tick=16,
                    deadline_s=0.02, queue_cap=8, defer_cap=4)
    for i, c, b in reqs:
        sch.submit(b, cluster=c, request_id=i, arrival_t=i * arrival_dt)
    steps = 0
    while (sch._future or sch.queue.depth or sch.batcher.occupied
           or sch.deferred_updates):
        if steps in update_at:
            delta = rotation_churn(sch.problem.graph, 4,
                                   seed=7000 + steps)
            sch.submit_update(
                delta,
                store_version=(sch.problem.store_version
                               + len(sch.deferred_updates)))
        sch.step()
        steps += 1
        assert steps < 200_000, "overload cell failed to drain"
    counts = sch.log.counts()
    lat = sch.latency_percentiles()
    row = _blank_row("overload", n)
    row.update({
        "requests": requests, "max_lanes": sch.batcher.max_lanes,
        "clusters": 4, "served": len(sch.results),
        "dropped": sch.dropped, "rejected": sch.quarantine.total,
        "qps": 0.0, "p50_latency_s": round(lat["p50"], 6),
        "p99_latency_s": round(lat["p99"], 6),
        "pool_hit_rate": round(sch.pool.hit_rate, 4),
        "pool_miss_rate": round(1.0 - sch.pool.hit_rate, 4),
        "mean_occupancy": round(sch.batcher.mean_occupancy, 4),
        "total_ops": int(sch.batcher.ops_total),
        "degrades": counts.get("degrade", 0),
        "applied_updates": sch.applied_updates,
        "degraded_frac": round(
            sum(1 for r in sch.results if r.degraded)
            / max(len(sch.results), 1), 4),
        "converged": bool(all(r.converged for r in sch.results)),
    })
    return row


def bucket_cell(n: int, c: int, seed: int = 5) -> Dict:
    """Padded vs unpadded ``solve_batch``: bitwise-identical solutions
    and op counts (zero-fill lanes are inert), waste reported."""
    from repro.api.session import SolverSession

    problem = build_problem(n)
    rng = np.random.default_rng(seed)
    base = np.asarray(problem.b, dtype=np.float64)[:, None]
    bs = np.abs(base * (1.0 + 0.1 * rng.standard_normal((problem.n, c))))
    r_pad = SolverSession(problem).solve_batch(bs, pad=True)
    r_raw = SolverSession(problem).solve_batch(bs, pad=False)
    bit = (bool(np.array_equal(r_pad.x, r_raw.x))
           and r_pad.extras["ops_per_column"]
           == r_raw.extras["ops_per_column"])
    row = _blank_row(f"bucket:c{c}", n)
    row.update({
        "requests": c, "max_lanes": r_pad.extras["bucket"],
        "bucket": r_pad.extras["bucket"],
        "padding_waste": round(r_pad.extras["padding_waste"], 4),
        "bit_parity": bit, "served": c,
        "total_ops": int(r_pad.n_ops),
        "converged": bool(r_pad.converged and r_raw.converged),
    })
    return row


def main(smoke: bool = False, out_path: str = "BENCH_serve.json") -> dict:
    if not smoke:
        # x64 BEFORE any kernel traces: the N=4096 parity bound needs
        # f64 accumulation.  Never under run.py --smoke, which shares
        # the process (and its traced f32 kernels) with other benches.
        import jax

        jax.config.update("jax_enable_x64", True)
    import jax

    rows = []
    if smoke:
        rows.append(serving_cell(n=512, requests=12, clusters=3,
                                 max_lanes=4, seq_sample=2,
                                 rounds_per_tick=32))
        rows.append(overload_cell(n=400, requests=16, max_lanes=4,
                                  update_at=(4,)))
        rows.append(bucket_cell(n=400, c=3))
    else:
        rows.append(serving_cell(n=4096, requests=64, clusters=8,
                                 max_lanes=16, seq_sample=4,
                                 target_error=1e-7))
        rows.append(overload_cell(n=1024, requests=48, max_lanes=8))
        rows.append(bucket_cell(n=1024, c=3))
        rows.append(bucket_cell(n=1024, c=5))
    for r in rows:
        if r["scenario"] == "serving":
            print(f"  {r['scenario']:12s} served={r['served']}"
                  f"/{r['requests']} qps={r['qps']:.2f} "
                  f"seq_qps={r['seq_qps']:.3f} "
                  f"speedup={r['speedup_vs_sequential']:.1f}x "
                  f"pool_hit={r['pool_hit_rate']:.2f} "
                  f"occ={r['mean_occupancy']:.2f} "
                  f"|dx|seq={r['max_dx_l1_seq']:.2e} "
                  f"|dx|ref={r['max_dx_l1_ref']:.2e} "
                  f"(bound {r['dx_bound']:.1e})")
        elif r["scenario"] == "overload":
            print(f"  {r['scenario']:12s} served={r['served']}"
                  f"/{r['requests']} dropped={r['dropped']} "
                  f"degrades={r['degrades']} "
                  f"degraded={r['degraded_frac']:.0%} "
                  f"updates={r['applied_updates']} "
                  f"p99={r['p99_latency_s']*1e3:.1f}ms")
        else:
            print(f"  {r['scenario']:12s} bucket={r['bucket']} "
                  f"waste={r['padding_waste']:.2f} "
                  f"bit_parity={r['bit_parity']}")
    from benchmarks._meta import std_meta

    payload = {
        "meta": std_meta("serve_continuous_batching",
                         graph="webgraph_like",
                         x64=bool(jax.config.jax_enable_x64)),
        "rows": rows,
    }
    with open(out_path, "w") as fh:
        json.dump(payload, fh, indent=1)
    print(f"[serve bench] wrote {out_path} ({len(rows)} rows)")
    return payload


if __name__ == "__main__":
    _out = "BENCH_serve.json"
    if "--out" in sys.argv:
        _out = sys.argv[sys.argv.index("--out") + 1]
    _smoke = "--smoke" in sys.argv
    _payload = main(smoke=_smoke, out_path=_out)
    _rows = _payload["rows"]
    _head = [r for r in _rows if r["scenario"] == "serving"]
    _over = [r for r in _rows if r["scenario"] == "overload"]
    _ok = (
        bool(_head) and bool(_over)
        and all(r["dropped"] == 0 for r in _rows)
        and all(r["served"] == r["requests"] for r in _head + _over)
        and all(r["bit_parity"] for r in _rows)
        and all(r["max_dx_l1_seq"] <= r["dx_bound"]
                and r["max_dx_l1_ref"] <= r["dx_bound"]
                and r["degrades"] == 0 and r["converged"]
                for r in _head)
        # full mode enforces the §11 acceptance numbers outright
        and (_smoke or all(r["speedup_vs_sequential"] >= 4.0
                           and r["max_dx_l1_seq"] <= 1e-6
                           and r["max_dx_l1_ref"] <= 1e-6
                           for r in _head))
        and all(r["degrades"] >= 1 for r in _over)
    )
    sys.exit(0 if _ok else 1)
