"""One normalized ``meta`` block for every BENCH_*.json emitter.

Historically each bench grew its own meta spelling (``backend`` vs
``platform``, ``device`` with no kind, no jax version, no timestamp),
which made the artifacts impossible to diff mechanically.  ``std_meta``
is the single constructor: the perf gate keys its platform matching and
staleness reporting on these fields, and ``run.py`` schema-guards them
in every emitted *and* committed artifact.
"""
from __future__ import annotations

from datetime import datetime, timezone

# every BENCH_*.json meta carries at least these (perf-gate contract)
META_KEYS = {
    "bench", "platform", "device_kind", "device", "jax_version", "seed",
    "timestamp_utc",
}


def std_meta(bench: str, seed: int = 0, **extra) -> dict:
    """Normalized meta block; ``extra`` holds bench-specific context."""
    import jax

    dev = jax.devices()[0]
    meta = {
        "bench": bench,
        "platform": jax.default_backend(),
        "device_kind": dev.device_kind,
        "device": str(dev),
        "jax_version": jax.__version__,
        "seed": seed,
        "timestamp_utc": datetime.now(timezone.utc).isoformat(),
    }
    meta.update(extra)
    return meta
