"""Perf-regression gate over the consolidated ``BENCH.json`` trajectory.

``python -m benchmarks.run --consolidate`` ends by running this gate, so
a PR that regenerates benchmark artifacts cannot land a regression
silently: every committed baseline metric is re-extracted from the fresh
``BENCH.json`` and compared inside a tolerance band.

Two metric kinds with different bands:

* ``counter`` — deterministic work counts (``n_ops``, ``rounds``,
  ``warm_ops``, ``disturbed_ops``).  These are seeded and
  platform-stable, so the band is tight (:data:`COUNTER_BAND`) and they
  are enforced everywhere.
* ``wall`` — wall-clock timings.  Machine-dependent, so the band is wide
  (:data:`WALL_BAND`) and they are enforced **only when the current
  platform matches the baseline's** — a TPU artifact is never judged
  against a CPU baseline.

A metric present in the baseline but absent from the current trajectory
is a failure too (coverage must not silently shrink); metrics new in the
current trajectory are reported informationally.

CLI::

    python -m benchmarks.perf_gate --check             # exit 1 on fail
    python -m benchmarks.perf_gate --update-baseline   # reseed baseline
"""
from __future__ import annotations

import json
import os
import sys
from typing import Dict, List, Optional, Tuple

BASELINE_PATH = "benchmarks/perf_baseline.json"
BENCH_PATH = "BENCH.json"

COUNTER_BAND = 1.10  # deterministic op counts: 10% headroom
WALL_BAND = 2.0  # wall time: CI machines are noisy; 2x is a regression
BANDS = {"counter": COUNTER_BAND, "wall": WALL_BAND}


def extract_metrics(payload: Dict) -> Dict[str, Dict]:
    """``{metric_name: {"kind", "value"}}`` from a consolidated payload.

    Names are hierarchical (``section/field/row-id``) so a report line is
    self-describing; the row-id spells the sweep coordinates.
    """
    metrics: Dict[str, Dict] = {}

    def rows(section: str) -> List[Dict]:
        sec = payload.get("sections", {}).get(section, {})
        return [r for r in sec.get("rows", []) if "skipped" not in r]

    def put(name: str, kind: str, value) -> None:
        if value is None:
            return
        metrics[name] = {"kind": kind, "value": float(value)}

    for r in rows("kernels"):
        rid = (f"n{r['n']}.c{r['c']}.d{r['density']}"
               f".bd{r.get('buffer_depth', 1)}")
        put(f"kernels/pallas_skip_us/{rid}", "wall", r["pallas_skip_us"])
        put(f"kernels/segment_sum_us/{rid}", "wall", r["segment_sum_us"])
    for r in rows("engine"):
        rid = f"{r['backend']}.n{r['n']}.k{r['k']}"
        put(f"engine/us_per_round/{rid}", "wall", r["us_per_round"])
        put(f"engine/rounds/{rid}", "counter", r["rounds"])
    for r in rows("api"):
        rid = f"{r['method']}.n{r['n']}"
        put(f"api/n_ops/{rid}", "counter", r["n_ops"])
        put(f"api/wall_s/{rid}", "wall", r["wall_s"])
    for r in rows("graph"):
        rid = f"{r['method']}.n{r['n']}.churn{r['churn_frac']}"
        put(f"graph/warm_ops/{rid}", "counter", r["warm_ops"])
        put(f"graph/patch_s/{rid}", "wall", r["patch_s"])
    for r in rows("chaos"):
        rid = f"{r['scenario']}.{r['method']}.n{r['n']}"
        put(f"chaos/disturbed_ops/{rid}", "counter", r["disturbed_ops"])
    for r in rows("stream"):
        rid = f"{r['scenario']}.{r['method']}.n{r['n']}.k{r['k']}"
        put(f"stream/total_ops/{rid}", "counter", r["total_ops"])
        # zero-valued baselines are enforced as exactly-zero (see
        # compare): an exactness or request-drop regression fails hard
        put(f"stream/max_dx_l1/{rid}", "counter", r["max_dx_l1"])
        put(f"stream/dropped/{rid}", "counter", r["dropped"])
    for r in rows("serve"):
        rid = f"{r['scenario']}.n{r['n']}.lanes{r['max_lanes']}"
        put(f"serve/total_ops/{rid}", "counter", r["total_ops"])
        # dropped is a zero baseline: enforced as exactly-zero
        put(f"serve/dropped/{rid}", "counter", r["dropped"])
        if r["scenario"] == "serving":
            # gate QPS inverted (us/request) so a throughput regression
            # fails upward through the wall band
            if r["qps"] > 0:
                put(f"serve/us_per_request/{rid}", "wall",
                    1e6 / r["qps"])
            # virtual-clock latencies + miss rate are deterministic
            put(f"serve/p50_latency_s/{rid}", "counter",
                r["p50_latency_s"])
            put(f"serve/p99_latency_s/{rid}", "counter",
                r["p99_latency_s"])
            put(f"serve/pool_miss_rate/{rid}", "counter",
                r["pool_miss_rate"])
        if r["scenario"].startswith("bucket:"):
            put(f"serve/padding_waste/{rid}", "counter",
                r["padding_waste"])
    return metrics


def compare(current: Dict[str, Dict], baseline: Dict,
            platform: Optional[str] = None
            ) -> Tuple[List[Dict], bool]:
    """Band-compare ``current`` metrics against a ``baseline`` record.

    Returns ``(results, ok)``; each result row carries ``metric``,
    ``kind``, ``base``, ``cur``, ``band``, ``status`` where status is one
    of ``ok`` / ``improved`` / ``fail`` / ``missing`` /
    ``skipped_platform`` / ``new``.
    """
    bands = dict(BANDS)
    bands.update(baseline.get("bands", {}))
    base_platform = baseline.get("meta", {}).get("platform")
    wall_enforced = (platform is None or base_platform is None
                     or platform == base_platform)
    results: List[Dict] = []
    ok = True
    for name, rec in sorted(baseline.get("metrics", {}).items()):
        kind = rec["kind"]
        band = float(bands.get(kind, WALL_BAND))
        row = {"metric": name, "kind": kind, "base": rec["value"],
               "band": band, "cur": None}
        cur = current.get(name)
        if cur is None:
            row["status"] = "missing"
            ok = False
        else:
            row["cur"] = cur["value"]
            if kind == "wall" and not wall_enforced:
                row["status"] = "skipped_platform"
            elif rec["value"] <= 0:
                row["status"] = "ok" if cur["value"] <= 0 else "fail"
                ok &= row["status"] == "ok"
            else:
                ratio = cur["value"] / rec["value"]
                if ratio > band:
                    row["status"] = "fail"
                    ok = False
                elif ratio < 1.0 / band:
                    row["status"] = "improved"
                else:
                    row["status"] = "ok"
        results.append(row)
    for name in sorted(set(current) - set(baseline.get("metrics", {}))):
        results.append({"metric": name, "kind": current[name]["kind"],
                        "base": None, "cur": current[name]["value"],
                        "band": None, "status": "new"})
    return results, ok


def make_baseline(payload: Dict) -> Dict:
    """Baseline record (committed JSON) from a consolidated payload."""
    from benchmarks._meta import std_meta

    return {
        "meta": std_meta("perf_baseline",
                         source_bench=payload.get("meta", {}).get(
                             "timestamp_utc")),
        "bands": dict(BANDS),
        "metrics": extract_metrics(payload),
    }


def _load(path: str) -> Dict:
    with open(path) as fh:
        return json.load(fh)


def report(results: List[Dict]) -> None:
    counts: Dict[str, int] = {}
    for r in results:
        counts[r["status"]] = counts.get(r["status"], 0) + 1
        if r["status"] in ("fail", "missing"):
            if r["status"] == "missing":
                print(f"  FAIL {r['metric']}: in baseline "
                      f"({r['base']:.6g}) but absent from BENCH.json")
            else:
                print(f"  FAIL {r['metric']}: {r['base']:.6g} -> "
                      f"{r['cur']:.6g} "
                      f"({r['cur'] / r['base']:.2f}x > band "
                      f"{r['band']:.2f}x)")
        elif r["status"] == "improved":
            print(f"  improved {r['metric']}: {r['base']:.6g} -> "
                  f"{r['cur']:.6g}")
    print(f"  perf gate: {counts}")


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    bench_path = BENCH_PATH
    baseline_path = BASELINE_PATH
    if "--bench" in argv:
        bench_path = argv[argv.index("--bench") + 1]
    if "--baseline" in argv:
        baseline_path = argv[argv.index("--baseline") + 1]
    if "--update-baseline" in argv:
        payload = _load(bench_path)
        baseline = make_baseline(payload)
        with open(baseline_path, "w") as fh:
            json.dump(baseline, fh, indent=1)
        print(f"  wrote {baseline_path} "
              f"({len(baseline['metrics'])} metrics)")
        return 0
    # --check (the default)
    if not os.path.exists(baseline_path):
        print(f"  {baseline_path} not present — nothing to gate")
        return 0
    if not os.path.exists(bench_path):
        print(f"  FAIL: {bench_path} not present but a baseline is "
              "committed — run python -m benchmarks.run --consolidate")
        return 1
    import jax

    baseline = _load(baseline_path)
    current = extract_metrics(_load(bench_path))
    results, ok = compare(current, baseline,
                          platform=jax.default_backend())
    report(results)
    print(f"  perf gate: {'PASS' if ok else 'FAIL'} "
          f"(baseline platform={baseline.get('meta', {}).get('platform')},"
          f" current platform={jax.default_backend()})")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
